"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and data; the kernels must match ref.py at f32
tolerance across the whole sweep — this is the core correctness signal
for everything the rust runtime executes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.matmul import matmul
from compile.kernels.spmm_hd import spmm_hd
from compile.kernels.spmm_ld import spmm_ld


def rand_case(rng, n, f, r, k, scale=1.0):
    x = rng.standard_normal((n, f)).astype(np.float32) * scale
    cols = rng.integers(0, n, size=(r, k)).astype(np.int32)
    w = rng.standard_normal((r, k)).astype(np.float32)
    # zero out a random suffix of each row (padding pattern)
    for i in range(r):
        pad = rng.integers(0, k + 1)
        if pad:
            w[i, k - pad :] = 0.0
            cols[i, k - pad :] = 0
    return x, cols, w


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([4, 17, 64, 256]),
    f=st.sampled_from([1, 4, 32]),
    r_tiles=st.integers(1, 3),
    k=st.sampled_from([1, 3, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmm_ld_matches_ref(n, f, r_tiles, k, seed):
    rng = np.random.default_rng(seed)
    row_tile = 32
    r = r_tiles * row_tile
    x, cols, w = rand_case(rng, n, f, r, k)
    got = spmm_ld(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(w), row_tile=row_tile)
    want = ref.spmm_ell_ref(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([8, 100, 512]),
    f=st.sampled_from([4, 32]),
    h_tiles=st.integers(1, 2),
    chunks=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmm_hd_matches_ref(n, f, h_tiles, chunks, seed):
    rng = np.random.default_rng(seed)
    slot_tile, chunk = 4, 32
    h, k_hd = h_tiles * slot_tile, chunks * chunk
    x, cols, w = rand_case(rng, n, f, h, k_hd)
    got = spmm_hd(
        jnp.asarray(x), jnp.asarray(cols), jnp.asarray(w),
        slot_tile=slot_tile, chunk=chunk,
    )
    want = ref.spmm_ell_ref(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m_tiles=st.integers(1, 4),
    k=st.sampled_from([4, 32, 33]),
    n=st.sampled_from([5, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m_tiles, k, n, seed):
    rng = np.random.default_rng(seed)
    tm = 64
    m = m_tiles * tm
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = matmul(jnp.asarray(a), jnp.asarray(b), tm=tm)
    want = ref.matmul_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_ld_kernel_rejects_untileable():
    x = jnp.zeros((8, 4), jnp.float32)
    cols = jnp.zeros((10, 3), jnp.int32)
    w = jnp.zeros((10, 3), jnp.float32)
    with pytest.raises(ValueError):
        spmm_ld(x, cols, w, row_tile=4)


def test_hd_scatter_handles_duplicate_slots():
    # two HD slots scatter-adding into the same row (a split wide row)
    y = jnp.zeros((4, 2), jnp.float32)
    hd_idx = jnp.asarray([2, 2, 0], jnp.int32)
    contrib = jnp.asarray([[1.0, 2.0], [3.0, 4.0], [5.0, 6.0]], jnp.float32)
    out = ref.hd_scatter_ref(y, hd_idx, contrib)
    np.testing.assert_allclose(np.asarray(out[2]), [4.0, 6.0])
    np.testing.assert_allclose(np.asarray(out[0]), [5.0, 6.0])

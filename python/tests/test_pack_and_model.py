"""Packing + whole-model tests: pack_graph vs direct CSR aggregation,
pallas-model vs reference-model equivalence, training smoke on synthetic
graphs, tensor_io roundtrip."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import dataset as ds
from compile import model as M
from compile import tensor_io
from compile.kernels import ref


def random_csr(rng, n, avg_deg, hub_frac=0.0, hub_deg=0):
    """Random symmetric-ish CSR with optional high-degree hubs."""
    rows = []
    for u in range(n):
        deg = int(rng.integers(0, 2 * avg_deg + 1))
        if hub_frac and rng.random() < hub_frac:
            deg = hub_deg
        nbrs = rng.integers(0, n, size=deg)
        rows.append(np.unique(nbrs))
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    for u in range(n):
        row_ptr[u + 1] = row_ptr[u] + len(rows[u])
    col_idx = np.concatenate(rows) if rows else np.zeros(0)
    return row_ptr, col_idx.astype(np.int32)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(3, 300),
    avg_deg=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_graph_matches_dense_aggregation(n, avg_deg, seed):
    rng = np.random.default_rng(seed)
    row_ptr, col_idx = random_csr(rng, n, avg_deg, hub_frac=0.05, hub_deg=40)
    n_bucket = 512
    k_ld, h_bucket, k_hd = 16, 64, 32
    packed = ref.pack_graph(row_ptr, col_idx, n_bucket, k_ld, h_bucket, k_hd)
    x = np.zeros((n_bucket, 4), dtype=np.float32)
    x[:n] = rng.standard_normal((n, 4)).astype(np.float32)
    ld_cols, ld_w, hd_idx, hd_cols, hd_w = [jnp.asarray(t) for t in packed]
    got = ref.aggregate_ref(jnp.asarray(x), ld_cols, ld_w, hd_idx, hd_cols, hd_w)
    want = ref.aggregate_dense_ref(row_ptr, col_idx, x)
    np.testing.assert_allclose(np.asarray(got)[:n], want[:n], rtol=2e-4, atol=2e-4)
    # padding rows aggregate to zero
    np.testing.assert_allclose(np.asarray(got)[n:], 0.0, atol=1e-6)


def test_pack_graph_overflow_raises():
    row_ptr = np.array([0, 40], dtype=np.int64)
    col_idx = np.zeros(40, dtype=np.int32)
    with pytest.raises(ValueError):
        ref.pack_graph(row_ptr, col_idx, n_bucket=8, k_ld=4, h_bucket=1, k_hd=8)


def test_pallas_model_matches_reference_model():
    """The AOT-lowered (pallas) forward must equal the training (ref)
    forward — this is what makes trained weights transferable."""
    rng = np.random.default_rng(0)
    n_bucket, k_ld, h_bucket, k_hd = 1024, 16, 16, 512
    row_ptr, col_idx = random_csr(rng, 700, 3, hub_frac=0.02, hub_deg=600)
    packed = ref.pack_graph(row_ptr, col_idx, n_bucket, k_ld, h_bucket, k_hd)
    x = np.zeros((n_bucket, M.FEATURE_DIM), dtype=np.float32)
    x[:700] = rng.standard_normal((700, M.FEATURE_DIM)).astype(np.float32)
    params = M.init_params(seed=1)
    args = [jnp.asarray(x)] + [jnp.asarray(t) for t in packed]
    got = M.sage_forward(*args, params)
    want = M.sage_forward_train(*args, params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_training_learns_synthetic_rule():
    """Training smoke: a tiny graph whose labels are derivable from
    features + neighborhood should reach high accuracy quickly."""
    rng = np.random.default_rng(3)
    n = 300
    feats = np.zeros((n, 4), dtype=np.float32)
    labels = np.zeros((n,), dtype=np.int32)
    edges = []
    for u in range(n):
        cls = u % 3
        labels[u] = cls
        feats[u] = rng.standard_normal(4) * 0.1
        feats[u, cls] += 2.0
        edges.append((u, (u + 1) % n))
    g = ds.GraphData(feats, labels, np.array(edges))
    params, acc = M_train(g)
    assert acc > 0.95, f"train accuracy {acc}"


def M_train(g):
    from compile.train import train_on_graph

    return train_on_graph(g, epochs=150, verbose=False)


def test_tensor_io_roundtrip(tmp_path):
    path = str(tmp_path / "b.bin")
    tensors = {
        "w": np.arange(6, dtype=np.float32).reshape(2, 3),
        "i": np.array([-1, 5], dtype=np.int32),
    }
    tensor_io.write_bundle(path, tensors)
    back = tensor_io.read_bundle(path)
    assert set(back) == {"w", "i"}
    np.testing.assert_array_equal(back["w"], tensors["w"])
    np.testing.assert_array_equal(back["i"], tensors["i"])


def test_params_bundle_roundtrip():
    params = M.init_params(seed=7)
    bundle = M.params_to_bundle(params)
    assert set(bundle) == set(M.PARAM_NAMES)
    back = M.bundle_to_params(bundle)
    for (a, b, c), (x, y, z) in zip(params, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(x))
        np.testing.assert_array_equal(np.asarray(b), np.asarray(y))
        np.testing.assert_array_equal(np.asarray(c), np.asarray(z))

"""Dataset loading for the training path.

`groot gen-dataset` (rust) writes one EDA graph as three text files:
    <stem>.features.txt   one "f0 f1 f2 f3" row per node
    <stem>.labels.txt     one class id per node
    <stem>.edges.txt      one "src dst" directed edge per line

This module loads them, builds the symmetric CSR the GNN aggregates over,
and packs it into bucket tensors (shared packer in kernels/ref.py).
"""

from __future__ import annotations

import os

import numpy as np

from .kernels.ref import pack_graph


class GraphData:
    def __init__(self, features, labels, edges, name="graph"):
        self.features = np.asarray(features, dtype=np.float32)
        self.labels = np.asarray(labels, dtype=np.int32)
        self.edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        self.name = name
        self.n = self.features.shape[0]
        assert self.labels.shape[0] == self.n

    def symmetric_csr(self):
        """Sorted, deduped symmetric CSR (matches rust Csr::symmetric_...)."""
        e = self.edges
        both = np.concatenate([e, e[:, ::-1]], axis=0)
        both = both[both[:, 0] != both[:, 1]]
        # unique (src, dst) pairs
        key = both[:, 0] * self.n + both[:, 1]
        order = np.argsort(key, kind="stable")
        key_sorted = key[order]
        keep = np.ones(len(key_sorted), dtype=bool)
        keep[1:] = key_sorted[1:] != key_sorted[:-1]
        uniq = both[order][keep]
        counts = np.bincount(uniq[:, 0], minlength=self.n)
        row_ptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(counts, out=row_ptr[1:])
        return row_ptr, uniq[:, 1].astype(np.int32)

    def pack(self, n_bucket, k_ld=16, h_bucket=None, k_hd=512):
        if h_bucket is None:
            h_bucket = max(n_bucket // 64, 8)
        row_ptr, col_idx = self.symmetric_csr()
        packed = pack_graph(row_ptr, col_idx, n_bucket, k_ld, h_bucket, k_hd)
        x = np.zeros((n_bucket, self.features.shape[1]), dtype=np.float32)
        x[: self.n] = self.features
        labels = np.zeros((n_bucket,), dtype=np.int32)
        labels[: self.n] = self.labels
        mask = np.zeros((n_bucket,), dtype=np.float32)
        mask[: self.n] = 1.0
        return x, packed, labels, mask


def load_graph(dataset_dir: str, stem: str) -> GraphData:
    def path(ext):
        return os.path.join(dataset_dir, f"{stem}.{ext}.txt")

    features = np.loadtxt(path("features"), dtype=np.float32, ndmin=2)
    labels = np.loadtxt(path("labels"), dtype=np.int32, ndmin=1)
    edges = np.loadtxt(path("edges"), dtype=np.int64, ndmin=2)
    return GraphData(features, labels, edges, name=stem)


def bucket_for(n: int, buckets=(1024, 4096, 16384, 65536)) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"graph of {n} nodes exceeds the largest bucket")

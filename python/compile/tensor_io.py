"""GRTW bundle I/O — the binary tensor interchange format shared with the
rust side (rust/src/util/tensor.rs implements the identical layout).

Layout (little-endian):
    magic   b"GRTW"
    u32     version (1)
    u32     tensor count
    per tensor:
        u16     name length, then utf-8 name bytes
        u8      dtype (0 = f32, 1 = i32)
        u8      ndim
        u64*d   dims
        bytes   row-major data
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"GRTW"

_DTYPES = {0: np.float32, 1: np.int32}
_DTYPE_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_bundle(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write name→array mapping. Arrays must be float32 or int32."""
    parts = [MAGIC, struct.pack("<II", 1, len(tensors))]
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name])
        code = _DTYPE_CODES.get(arr.dtype)
        if code is None:
            raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
        nb = name.encode("utf-8")
        parts.append(struct.pack("<H", len(nb)))
        parts.append(nb)
        parts.append(struct.pack("<BB", code, arr.ndim))
        for d in arr.shape:
            parts.append(struct.pack("<Q", d))
        parts.append(arr.tobytes())
    with open(path, "wb") as f:
        f.write(b"".join(parts))


def read_bundle(path: str) -> dict[str, np.ndarray]:
    with open(path, "rb") as f:
        data = f.read()
    off = 0

    def take(n: int) -> bytes:
        nonlocal off
        if off + n > len(data):
            raise ValueError(f"truncated bundle at offset {off}")
        chunk = data[off : off + n]
        off += n
        return chunk

    if take(4) != MAGIC:
        raise ValueError("bad magic")
    version, count = struct.unpack("<II", take(8))
    if version != 1:
        raise ValueError(f"unsupported version {version}")
    out: dict[str, np.ndarray] = {}
    for _ in range(count):
        (name_len,) = struct.unpack("<H", take(2))
        name = take(name_len).decode("utf-8")
        dtype_code, ndim = struct.unpack("<BB", take(2))
        dims = [struct.unpack("<Q", take(8))[0] for _ in range(ndim)]
        dtype = _DTYPES[dtype_code]
        numel = int(np.prod(dims)) if dims else 1
        arr = np.frombuffer(take(numel * 4), dtype=dtype).reshape(dims)
        out[name] = arr.copy()
    return out

"""L2 — GraphSAGE model for AIG node classification (build-time JAX).

Architecture (paper §III-C): GraphSAGE mean-aggregation, 3 layers
(4 → 32 → 32 → 5), final layer linear logits over the 5 node classes
{PO, MAJ, XOR, AND, PI}. The aggregation is the GROOT HD/LD split:
low-degree rows through the ELL LD-kernel, high-degree rows through the
chunked HD-kernel plus scatter-add (see kernels/).

The *inference* path (what aot.py lowers and the rust runtime executes)
calls the Pallas kernels. The *training* path uses the pure-jnp reference
(identical math — asserted by python/tests/test_kernel.py) because
pallas_call has no registered VJP; weights transfer exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.matmul import matmul
from .kernels.spmm_hd import spmm_hd
from .kernels.spmm_ld import spmm_ld

NUM_CLASSES = 5
FEATURE_DIM = 4
HIDDEN_DIM = 32
LAYER_DIMS = [FEATURE_DIM, HIDDEN_DIM, HIDDEN_DIM, NUM_CLASSES]

# Canonical parameter order for the flattened AOT signature; the rust
# runtime feeds literals in exactly this order after the graph tensors.
PARAM_NAMES = [
    f"l{i}.{leaf}"
    for i in range(len(LAYER_DIMS) - 1)
    for leaf in ("w_self", "w_neigh", "b")
]


def init_params(seed: int = 0, dims=None):
    """Glorot-uniform init; returns list of (w_self, w_neigh, b)."""
    dims = dims or LAYER_DIMS
    rng = np.random.default_rng(seed)
    params = []
    for din, dout in zip(dims[:-1], dims[1:]):
        lim = float(np.sqrt(6.0 / (din + dout)))
        ws = rng.uniform(-lim, lim, size=(din, dout)).astype(np.float32)
        wn = rng.uniform(-lim, lim, size=(din, dout)).astype(np.float32)
        b = np.zeros((dout,), dtype=np.float32)
        params.append((jnp.asarray(ws), jnp.asarray(wn), jnp.asarray(b)))
    return params


def params_to_bundle(params) -> dict[str, np.ndarray]:
    out = {}
    for i, (ws, wn, b) in enumerate(params):
        out[f"l{i}.w_self"] = np.asarray(ws)
        out[f"l{i}.w_neigh"] = np.asarray(wn)
        out[f"l{i}.b"] = np.asarray(b)
    return out


def bundle_to_params(bundle: dict[str, np.ndarray]):
    n_layers = len({k.split(".")[0] for k in bundle})
    return [
        (
            jnp.asarray(bundle[f"l{i}.w_self"]),
            jnp.asarray(bundle[f"l{i}.w_neigh"]),
            jnp.asarray(bundle[f"l{i}.b"]),
        )
        for i in range(n_layers)
    ]


def aggregate(h, ld_cols, ld_w, hd_idx, hd_cols, hd_w):
    """GROOT aggregation via the Pallas kernels."""
    y = spmm_ld(h, ld_cols, ld_w)
    contrib = spmm_hd(h, hd_cols, hd_w)
    return y.at[hd_idx].add(contrib)


def sage_forward(x, ld_cols, ld_w, hd_idx, hd_cols, hd_w, params):
    """Inference forward pass (Pallas kernels) → logits [N, 5]."""
    h = x
    for li, (ws, wn, b) in enumerate(params):
        agg = aggregate(h, ld_cols, ld_w, hd_idx, hd_cols, hd_w)
        out = matmul(h, ws) + matmul(agg, wn) + b
        h = jnp.maximum(out, 0.0) if li + 1 < len(params) else out
    return h


def sage_forward_train(x, ld_cols, ld_w, hd_idx, hd_cols, hd_w, params):
    """Differentiable forward (pure-jnp reference kernels)."""
    return ref.sage_forward_ref(x, ld_cols, ld_w, hd_idx, hd_cols, hd_w, params)


def cross_entropy_loss(logits, labels, mask):
    """Masked mean CE. mask selects real (non-padding) nodes."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def accuracy(logits, labels, mask):
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == labels).astype(jnp.float32) * mask
    return jnp.sum(correct) / jnp.maximum(jnp.sum(mask), 1.0)


# ----------------------------------------------------------------------
# Hand-rolled Adam (optax not available offline).
# ----------------------------------------------------------------------


def adam_init(params):
    zeros = lambda p: jax.tree_util.tree_map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-2, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vh_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m * mh_scale) / (jnp.sqrt(v * vh_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}

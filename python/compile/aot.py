"""AOT lowering — jax → HLO *text* artifacts for the rust PJRT runtime.

One executable per shape bucket: the coordinator pads every re-grown
partition into the smallest bucket that fits and runs the matching
executable. Interchange is HLO text (NOT serialized protos): jax ≥ 0.5
emits 64-bit instruction ids that the xla crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--buckets 1024,4096,...]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

K_LD = 16
K_HD = 512
DEFAULT_BUCKETS = (1024, 4096, 16384, 65536)


def h_for(n_bucket: int) -> int:
    return max(n_bucket // 64, 8)


def infer_fn(x, ld_cols, ld_w, hd_idx, hd_cols, hd_w, *flat_params):
    """Flattened-signature inference (weights are runtime inputs so one
    HLO serves any trained variant)."""
    assert len(flat_params) % 3 == 0
    params = [
        (flat_params[i], flat_params[i + 1], flat_params[i + 2])
        for i in range(0, len(flat_params), 3)
    ]
    logits = M.sage_forward(x, ld_cols, ld_w, hd_idx, hd_cols, hd_w, params)
    return (logits,)


def bucket_arg_specs(n: int):
    h = h_for(n)
    f32, i32 = jnp.float32, jnp.int32
    specs = [
        jax.ShapeDtypeStruct((n, M.FEATURE_DIM), f32),   # x
        jax.ShapeDtypeStruct((n, K_LD), i32),            # ld_cols
        jax.ShapeDtypeStruct((n, K_LD), f32),            # ld_w
        jax.ShapeDtypeStruct((h,), i32),                 # hd_idx
        jax.ShapeDtypeStruct((h, K_HD), i32),            # hd_cols
        jax.ShapeDtypeStruct((h, K_HD), f32),            # hd_w
    ]
    dims = M.LAYER_DIMS
    for din, dout in zip(dims[:-1], dims[1:]):
        specs.append(jax.ShapeDtypeStruct((din, dout), f32))  # w_self
        specs.append(jax.ShapeDtypeStruct((din, dout), f32))  # w_neigh
        specs.append(jax.ShapeDtypeStruct((dout,), f32))      # b
    return specs


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(n: int) -> str:
    specs = bucket_arg_specs(n)
    lowered = jax.jit(infer_fn).lower(*specs)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", required=True)
    ap.add_argument(
        "--buckets", default=",".join(str(b) for b in DEFAULT_BUCKETS)
    )
    args = ap.parse_args()
    buckets = [int(b) for b in args.buckets.split(",")]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = [
        f"feature_dim {M.FEATURE_DIM}",
        f"num_classes {M.NUM_CLASSES}",
        f"k_ld {K_LD}",
        f"k_hd {K_HD}",
        "params " + " ".join(M.PARAM_NAMES),
    ]
    for n in buckets:
        fname = f"sage_n{n}.hlo.txt"
        text = lower_bucket(n)
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"bucket n={n} h={h_for(n)} file={fname}")
        print(f"lowered bucket {n}: {len(text)} chars -> {path}")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest with {len(buckets)} buckets")


if __name__ == "__main__":
    main()

"""Pure-jnp correctness oracles for the Pallas kernels and the model.

Everything here is the specification; the Pallas kernels in spmm_ld.py /
spmm_hd.py / matmul.py must match these (allclose at f32).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def spmm_ell_ref(x, cols, w):
    """Weighted ELL gather-sum: y[i] = sum_k w[i,k] * x[cols[i,k]].

    x: [N, F] float32; cols: [R, K] int32 (padding slots must carry w = 0);
    w: [R, K] float32. Returns [R, F].
    """
    gathered = x[cols]              # [R, K, F]
    return jnp.einsum("rk,rkf->rf", w, gathered)


def hd_scatter_ref(y, hd_idx, hd_contrib):
    """Scatter-add HD slot contributions into row-space y.

    y: [N, F]; hd_idx: [H] int32 (padding slots may point anywhere as long
    as their contribution row is zero); hd_contrib: [H, F].
    """
    return y.at[hd_idx].add(hd_contrib)


def aggregate_ref(x, ld_cols, ld_w, hd_idx, hd_cols, hd_w):
    """Full GROOT aggregation: LD ELL + HD chunked scatter-add (mean agg —
    the 1/deg factors live inside ld_w / hd_w)."""
    y = spmm_ell_ref(x, ld_cols, ld_w)
    contrib = spmm_ell_ref(x, hd_cols, hd_w)
    return hd_scatter_ref(y, hd_idx, contrib)


def matmul_ref(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def sage_layer_ref(h, agg, w_self, w_neigh, b, relu=True):
    """GraphSAGE layer: act(h·W_self + agg·W_neigh + b)."""
    out = matmul_ref(h, w_self) + matmul_ref(agg, w_neigh) + b
    return jnp.maximum(out, 0.0) if relu else out


def sage_forward_ref(x, ld_cols, ld_w, hd_idx, hd_cols, hd_w, params):
    """Whole-model forward (3 GraphSAGE layers, last one linear logits).

    params: list of (w_self, w_neigh, b) triples.
    """
    h = x
    for li, (ws, wn, b) in enumerate(params):
        agg = aggregate_ref(h, ld_cols, ld_w, hd_idx, hd_cols, hd_w)
        h = sage_layer_ref(h, agg, ws, wn, b, relu=(li + 1 < len(params)))
    return h


# ---------------------------------------------------------------------------
# Graph packing (numpy) — mirrors rust/src/coordinator/pack.rs. The packer
# turns a CSR adjacency into the fixed-shape (ld_cols, ld_w, hd_idx,
# hd_cols, hd_w) bucket tensors the AOT-compiled model consumes.
# ---------------------------------------------------------------------------


def pack_graph(row_ptr, col_idx, n_bucket, k_ld, h_bucket, k_hd):
    """Pack a CSR graph (numpy arrays) into bucket tensors.

    Rows with degree ≤ k_ld go to the ELL block; heavier rows are split
    into ≤ k_hd chunks occupying HD slots (scatter-added by row id).
    Raises ValueError if the graph does not fit the bucket.
    """
    n = len(row_ptr) - 1
    if n > n_bucket:
        raise ValueError(f"graph rows {n} exceed bucket {n_bucket}")
    ld_cols = np.zeros((n_bucket, k_ld), dtype=np.int32)
    ld_w = np.zeros((n_bucket, k_ld), dtype=np.float32)
    hd_idx = np.zeros((h_bucket,), dtype=np.int32)
    hd_cols = np.zeros((h_bucket, k_hd), dtype=np.int32)
    hd_w = np.zeros((h_bucket, k_hd), dtype=np.float32)
    slot = 0
    for u in range(n):
        lo, hi = row_ptr[u], row_ptr[u + 1]
        deg = hi - lo
        if deg == 0:
            continue
        inv = np.float32(1.0 / deg)
        if deg <= k_ld:
            ld_cols[u, :deg] = col_idx[lo:hi]
            ld_w[u, :deg] = inv
        else:
            for c0 in range(lo, hi, k_hd):
                c1 = min(c0 + k_hd, hi)
                if slot >= h_bucket:
                    raise ValueError("out of HD slots; use a larger bucket")
                hd_idx[slot] = u
                hd_cols[slot, : c1 - c0] = col_idx[c0:c1]
                hd_w[slot, : c1 - c0] = inv
                slot += 1
    return ld_cols, ld_w, hd_idx, hd_cols, hd_w


def aggregate_dense_ref(row_ptr, col_idx, x):
    """Direct CSR mean aggregation (float64 accumulation) — the packing-
    independent oracle used to validate pack_graph + aggregate_ref."""
    n = len(row_ptr) - 1
    out = np.zeros((x.shape[0], x.shape[1]), dtype=np.float64)
    for u in range(n):
        lo, hi = row_ptr[u], row_ptr[u + 1]
        if hi > lo:
            out[u] = x[col_idx[lo:hi]].astype(np.float64).mean(axis=0)
    return out.astype(np.float32)

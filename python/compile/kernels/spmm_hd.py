"""HD-kernel (Pallas): chunked high-degree row SpMM — §IV Fig. 4 re-thought
for TPU.

The paper's CUDA HD-kernel splits each high-degree row's nonzeros into 32
equal workloads spread over warps. On TPU the analogous move is to split
each HD slot's K_HD-wide nonzero strip into `CHUNK`-wide VMEM tiles and
accumulate partial sums across the chunk grid dimension: grid = (H/TH,
K_HD/CHUNK); the first chunk initializes the output tile, subsequent chunks
accumulate in place (revolving VMEM accumulator ≙ the paper's shared-memory
partial sums). Rows wider than K_HD were already split across multiple HD
slots by the packer and meet again in the jnp scatter-add downstream (the
atomics of the CUDA version).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_SLOT_TILE = 8
DEFAULT_CHUNK = 128


def _hd_kernel(x_ref, cols_ref, w_ref, o_ref):
    """Grid (slot_tile h, chunk c): accumulate chunk partial sums into o."""
    c = pl.program_id(1)
    x = x_ref[...]          # [N, F]
    cols = cols_ref[...]    # [TH, CHUNK]
    w = w_ref[...]          # [TH, CHUNK]
    gathered = x[cols]      # [TH, CHUNK, F]
    partial = jnp.einsum(
        "rk,rkf->rf", w, gathered, preferred_element_type=jnp.float32
    )

    @pl.when(c == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(c != 0)
    def _accum():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("slot_tile", "chunk"))
def spmm_hd(x, cols, w, slot_tile: int = DEFAULT_SLOT_TILE, chunk: int = DEFAULT_CHUNK):
    """Per-slot contributions for high-degree rows.

    x: [N, F]; cols/w: [H, K_HD] → [H, F]. K_HD must divide by `chunk` and
    H by `slot_tile` (bucket shapes are chosen so they do).
    """
    h, k_hd = cols.shape
    n, f = x.shape
    slot_tile = min(slot_tile, h)
    chunk = min(chunk, k_hd)
    if h % slot_tile != 0 or k_hd % chunk != 0:
        raise ValueError(f"shape ({h},{k_hd}) not tileable by ({slot_tile},{chunk})")
    grid = (h // slot_tile, k_hd // chunk)
    return pl.pallas_call(
        _hd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, f), lambda i, c: (0, 0)),
            pl.BlockSpec((slot_tile, chunk), lambda i, c: (i, c)),
            pl.BlockSpec((slot_tile, chunk), lambda i, c: (i, c)),
        ],
        # Output block does not depend on c → same VMEM tile revisited
        # across the chunk dimension (the accumulator).
        out_specs=pl.BlockSpec((slot_tile, f), lambda i, c: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, f), jnp.float32),
        interpret=True,
    )(x, cols, w)

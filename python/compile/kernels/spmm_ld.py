"""LD-kernel (Pallas): degree-sorted, row-packed ELL SpMM — §IV Fig. 5
re-thought for TPU.

The paper's CUDA LD-kernel aggregates many small-degree rows per warp so
warps stay busy and the output writes coalesce. The TPU translation packs
low-degree rows into dense ELL tiles `[TR, K]`: one grid step processes TR
rows at once as a *dense* gather + masked weighted sum — a fully
vectorizable VPU op with contiguous `[TR, F]` output tiles (the "coalesced
dump"). The degree-sort happens upstream in the packer; zero-weight slots
make the tile rectangular.

VMEM budget per grid step (BlockSpec): TR·K ints (cols) + TR·K f32 (w)
+ TR·K·F f32 gathered + TR·F f32 out. With TR=256, K=16, F=32 that is
≈ 0.6 MB — comfortably double-bufferable in 16 MB VMEM. The feature matrix
x stays resident (N·F f32; 8 MB at the largest bucket), streamed on real
hardware via an HBM→VMEM gather that BlockSpec expresses with a whole-array
block; interpret=True executes the same schedule on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 256


def _ld_kernel(x_ref, cols_ref, w_ref, o_ref):
    """One grid step: rows tile [TR, K] against the whole x [N, F]."""
    x = x_ref[...]          # [N, F]
    cols = cols_ref[...]    # [TR, K] int32
    w = w_ref[...]          # [TR, K] f32
    gathered = x[cols]      # [TR, K, F] — dense gather (VPU)
    # Masked weighted sum over K: padding slots carry w == 0.
    o_ref[...] = jnp.einsum(
        "rk,rkf->rf", w, gathered, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("row_tile",))
def spmm_ld(x, cols, w, row_tile: int = DEFAULT_ROW_TILE):
    """y[i] = Σ_k w[i,k] · x[cols[i,k]] for ELL-packed low-degree rows.

    x: [N, F] f32; cols: [R, K] i32; w: [R, K] f32 → [R, F] f32.
    R must be a multiple of row_tile (the packer pads buckets so it is).
    """
    r, k = cols.shape
    n, f = x.shape
    row_tile = min(row_tile, r)
    if r % row_tile != 0:
        raise ValueError(f"rows {r} not a multiple of tile {row_tile}")
    grid = (r // row_tile,)
    return pl.pallas_call(
        _ld_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, f), lambda i: (0, 0)),          # x resident
            pl.BlockSpec((row_tile, k), lambda i: (i, 0)),   # cols tile
            pl.BlockSpec((row_tile, k), lambda i: (i, 0)),   # w tile
        ],
        out_specs=pl.BlockSpec((row_tile, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, f), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x, cols, w)

"""Dense matmul (Pallas) for the GraphSAGE weight updates — the MXU side
of the kernel design.

The aggregation kernels are VPU/gather-bound; the W_self/W_neigh updates
are plain dense matmuls and belong on the MXU. Tiled [TM, K] × [K, TN]
with a K-striding accumulator grid, the canonical Pallas matmul schedule.
Feature dims here are small (4/32/5 — padded to the tile), so on real
hardware this runs one MXU pass per tile; interpret=True validates the
schedule on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TM = 256


def _mm_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tm",))
def matmul(a, b, tm: int = DEFAULT_TM):
    """a [M, K] · b [K, N] → [M, N], row-tiled over M.

    K and N are small model dims (≤ 64) and stay whole per tile; M is the
    node dimension and is tiled by `tm` (must divide M — buckets are
    multiples of 256).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    tm = min(tm, m)
    if m % tm != 0:
        raise ValueError(f"M {m} not a multiple of tile {tm}")
    return pl.pallas_call(
        _mm_kernel,
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)

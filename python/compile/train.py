"""Build-time training — trains the GraphSAGE classifier on a small
multiplier (the paper trains on 8-bit) and writes the weight bundle the
rust runtime and the AOT model consume.

Run by `make artifacts` after `groot gen-dataset` has produced the
training graphs. Python never runs at verification time.

Usage:
    python -m compile.train --data ../artifacts/datasets --stem csa8 \
        --out ../artifacts/weights_csa8.bin [--epochs 400] [--eval-stem csa16]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset as ds
from . import model as M
from . import tensor_io


def train_on_graph(
    graph: ds.GraphData,
    epochs: int = 400,
    lr: float = 1e-2,
    seed: int = 0,
    log_every: int = 50,
    verbose: bool = True,
):
    """Full-batch Adam training; returns (params, final_train_acc)."""
    n_bucket = ds.bucket_for(graph.n)
    x, packed, labels, mask = graph.pack(n_bucket)
    ld_cols, ld_w, hd_idx, hd_cols, hd_w = [jnp.asarray(t) for t in packed]
    x, labels, mask = jnp.asarray(x), jnp.asarray(labels), jnp.asarray(mask)

    params = M.init_params(seed)
    opt = M.adam_init(params)

    def loss_fn(params):
        logits = M.sage_forward_train(x, ld_cols, ld_w, hd_idx, hd_cols, hd_w, params)
        return M.cross_entropy_loss(logits, labels, mask), logits

    @jax.jit
    def step(params, opt):
        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = M.adam_update(params, grads, opt, lr=lr)
        acc = M.accuracy(logits, labels, mask)
        return params, opt, loss, acc

    t0 = time.time()
    acc = 0.0
    for epoch in range(epochs):
        params, opt, loss, acc = step(params, opt)
        if verbose and (epoch % log_every == 0 or epoch == epochs - 1):
            print(
                f"epoch {epoch:4d}  loss {float(loss):.4f}  "
                f"train-acc {float(acc):.4f}  ({time.time()-t0:.1f}s)"
            )
    return params, float(acc)


def gamora_features(features: np.ndarray) -> np.ndarray:
    """GAMORA 3-dim re-encoding (mirrors rust EdaGraph::gamora_features),
    zero-padded to 4 so the model shapes stay identical."""
    t1, t0, pl, pr = features[:, 0], features[:, 1], features[:, 2], features[:, 3]
    internal = ((t1 == 1.0) & (t0 == 1.0)).astype(np.float32)
    out = np.zeros_like(features)
    out[:, 0] = internal
    out[:, 1] = pl
    out[:, 2] = pr
    return out


def evaluate_on_graph(params, graph: ds.GraphData) -> float:
    """Node accuracy of `params` on a (possibly larger) graph."""
    n_bucket = ds.bucket_for(graph.n)
    x, packed, labels, mask = graph.pack(n_bucket)
    ld_cols, ld_w, hd_idx, hd_cols, hd_w = [jnp.asarray(t) for t in packed]
    logits = M.sage_forward_train(
        jnp.asarray(x), ld_cols, ld_w, hd_idx, hd_cols, hd_w, params
    )
    return float(M.accuracy(logits, jnp.asarray(labels), jnp.asarray(mask)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", required=True, help="dataset directory")
    ap.add_argument("--stem", default="csa8", help="training graph stem")
    ap.add_argument("--out", required=True, help="output weights bundle")
    ap.add_argument("--epochs", type=int, default=400)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-stem", default=None, help="optional held-out graph")
    ap.add_argument(
        "--features",
        default="groot",
        choices=["groot", "gamora"],
        help="gamora = drop the PI/PO type distinction (3-dim, zero-padded "
        "to 4) — the feature ablation baseline",
    )
    args = ap.parse_args()

    graph = ds.load_graph(args.data, args.stem)
    if args.features == "gamora":
        graph.features = gamora_features(graph.features)
    print(f"training on {args.stem}: {graph.n} nodes, {len(graph.edges)} edges")
    params, train_acc = train_on_graph(
        graph, epochs=args.epochs, lr=args.lr, seed=args.seed
    )
    print(f"final train accuracy: {train_acc:.4f}")
    if args.eval_stem:
        held = ds.load_graph(args.data, args.eval_stem)
        acc = evaluate_on_graph(params, held)
        print(f"held-out accuracy on {args.eval_stem} ({held.n} nodes): {acc:.4f}")

    tensor_io.write_bundle(args.out, M.params_to_bundle(params))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

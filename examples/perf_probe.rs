//! Perf probe used by the EXPERIMENTS.md §Perf iteration log.
use groot::datasets::{self, DatasetKind};
use groot::graph::Csr;
use groot::spmm::{all_engines, SpmmEngine};
use groot::util::rng::Rng;
use groot::util::timer::{bench, fmt_dur};

fn main() -> anyhow::Result<()> {
    let dim: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(32);
    let graph = datasets::build(DatasetKind::Booth, 128)?;
    let csr = Csr::symmetric_from_edges(graph.num_nodes, &graph.edges);
    let mut rng = Rng::new(9);
    let x: Vec<f32> = (0..csr.num_nodes() * dim).map(|_| rng.f32()).collect();
    println!("booth128: {} rows, {} nnz, dim {dim}", csr.num_nodes(), csr.num_entries());
    let mut engines = all_engines(1);
    engines.push(Box::new(groot::spmm::GrootSpmm::with_config(1, groot::spmm::groot::GrootConfig { ld_degree_sort: false, ..Default::default() })));
    let mut out = vec![0.0f32; csr.num_nodes() * dim];
    for e in &engines {
        let s = bench(3, 15, || e.spmm_mean_into(&csr, &x, dim, &mut out));
        let gflops = 2.0 * csr.num_entries() as f64 * dim as f64 / s.median_secs() / 1e9;
        let tag = if matches!(engines.iter().position(|x| std::ptr::eq(x.as_ref() as *const _ as *const u8, e.as_ref() as *const _ as *const u8)), Some(4)) { " (no deg-sort)" } else { "" };
        println!("{:>16}{tag}: median {} ({gflops:.2} GFLOP/s)", e.name(), fmt_dur(s.median));
    }
    Ok(())
}
// appended: groot degree-sort ablation

//! Train-quickstart — the train→verify loop end-to-end, from nothing but
//! the circuit generators.
//!
//! Trains GraphSAGE on the 8-bit CSA multiplier with partition-aware
//! mini-batches (the same re-grown sub-graphs inference executes),
//! checkpoints to the GRTW bundle format, then reloads the checkpoint
//! through the ordinary serving path (`backend_by_name` → `Session`) and
//! classifies the held-out 16-bit design — the paper's
//! train-on-8-bit / verify-large protocol (Fig. 6) in one binary.
//!
//! Run: `cargo run --release --example train_quickstart`

use groot::coordinator::{Session, SessionConfig};
use groot::datasets::{self, DatasetKind};
use groot::train::{self, TrainConfig};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    println!("== GROOT train quickstart: csa8 → checkpoint → verify csa16 ==\n");

    // 1. Datasets straight from the generators (features + cut-matcher
    // ground truth, no artifacts needed).
    let train_graph = datasets::build(DatasetKind::Csa, 8)?;
    let val_graph = datasets::build(DatasetKind::Csa, 16)?;
    println!(
        "train csa8: {} nodes / {} edges;  held-out csa16: {} nodes",
        train_graph.num_nodes,
        train_graph.num_edges(),
        val_graph.num_nodes
    );

    // 2. Train: seeded init, Adam, class-weighted cross-entropy,
    // partition-aware batches. Short schedule — the quickstart shows the
    // loop; `groot train` runs the full 200-epoch default.
    let ckpt = std::env::temp_dir().join("groot_train_quickstart.bin");
    let cfg = TrainConfig {
        hidden: vec![32, 32],
        epochs: 60,
        lr: 0.01,
        partitions: 4,
        seed: 1,
        eval_every: 20,
        checkpoint_every: 0,
        out: Some(ckpt.clone()),
        ..Default::default()
    };
    let report = train::train(
        std::slice::from_ref(&train_graph),
        &[("csa16".to_string(), val_graph.clone())],
        &cfg,
        |e| {
            if e.epoch % 10 == 0 || e.epoch == 1 {
                println!(
                    "epoch {:>3}  loss {:.5}  train acc {:.4}{}",
                    e.epoch,
                    e.loss,
                    e.train_acc,
                    e.val_acc.map(|a| format!("  val acc {a:.4}")).unwrap_or_default()
                );
            }
        },
    )?;
    println!(
        "\ntrained: loss {:.5} → {:.5}; checkpoint {}",
        report.first_loss(),
        report.final_loss(),
        ckpt.display()
    );

    // 3. The checkpoint is a plain GRTW weight bundle: load it through
    // the SAME path every harness uses and classify the held-out design.
    let bundle = groot::util::tensor::read_bundle(&ckpt)?;
    let backend = groot::backend::backend_by_name(
        "native",
        &bundle,
        Path::new("artifacts"),
        usize::MAX,
        groot::util::pool::default_threads(),
    )?;
    let session = Session::new(
        backend,
        SessionConfig { num_partitions: 8, ..Default::default() },
    );
    let res = session.classify(&val_graph)?;
    println!(
        "checkpoint → Session::classify(csa16): accuracy {:.4} \
         ({} partitions, re-grown)",
        res.accuracy, res.stats.num_partitions
    );

    println!("\ntrain quickstart OK");
    Ok(())
}

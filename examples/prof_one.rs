use groot::datasets::{self, DatasetKind};
use groot::graph::Csr;
use groot::spmm::{CsrRowParallel, SpmmEngine};
use groot::util::rng::Rng;
fn main() {
    let which = std::env::args().nth(1).unwrap_or_default();
    let graph = datasets::build(DatasetKind::Booth, 128).unwrap();
    let csr = Csr::symmetric_from_edges(graph.num_nodes, &graph.edges);
    let mut rng = Rng::new(9);
    let dim = 32;
    let x: Vec<f32> = (0..csr.num_nodes() * dim).map(|_| rng.f32()).collect();
    let t0 = std::time::Instant::now();
    let mut sink = 0.0f32;
    for _ in 0..20 {
        let y = if which == "merge" {
            groot::spmm::MergePathSpmm::new(1).spmm_mean(&csr, &x, dim)
        } else {
            CsrRowParallel::new(1).spmm_mean(&csr, &x, dim)
        };
        sink += y[0];
    }
    println!("{which}: {:?} (sink {sink})", t0.elapsed() / 20);
}

//! Large-design verification — the paper's core scenario (§V-B/C):
//! a multiplier too large to classify in one device-sized piece is
//! partitioned, boundary-re-grown, streamed through the model bucket by
//! bucket, and verified; memory drops with the partition count while
//! accuracy is preserved by re-growth.
//!
//! Uses the staged pipeline the way a sweep should: the graph is
//! prepared ONCE (CSR + features + fingerprint), each partition count is
//! one plan over it, and every plan executes as a single batched backend
//! call. Then the algebraic check runs once with the best setting.
//!
//! Sweeps partition counts on a 64-bit CSA multiplier (≈40k graph nodes;
//! override with --bits) and prints the memory/accuracy/runtime trade-off
//! table.
//!
//! Run: `make artifacts && cargo run --release --example large_verify [-- --bits 128]`

use groot::coordinator::{PlanOptions, PreparedGraph, Session, SessionConfig};
use groot::datasets::{self, DatasetKind};
use groot::memmodel::MemModel;
use groot::util::cli::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(&[]);
    let bits: usize = args.parse_or("bits", 64)?;
    let graph = datasets::build(DatasetKind::Csa, bits)?;
    let aig = groot::aig::mult::csa_multiplier(bits);
    println!(
        "== large_verify: {bits}-bit CSA, {} nodes / {} edges ==",
        graph.num_nodes,
        graph.num_edges()
    );

    let bundle = groot::util::tensor::read_bundle(Path::new("artifacts/weights_csa8.bin"))?;
    let model = groot::gnn::SageModel::from_bundle(&bundle)?;
    let mem = MemModel::default();
    let session = Session::native(model, SessionConfig::default());

    // Stage 1 once for the whole sweep; each row below only plans+executes.
    let prepared = PreparedGraph::new(&graph);
    println!("prepared once: fingerprint {:016x}", prepared.fingerprint());

    println!(
        "\n{:>6} {:>10} {:>12} {:>12} {:>10} {:>12}",
        "parts", "acc", "peak nodes", "mem (MB)", "infer", "rss (MB)"
    );
    let mut best_pred: Option<Vec<u8>> = None;
    for parts in [1usize, 2, 4, 8, 16, 32, 64] {
        let plan =
            prepared.plan(&PlanOptions { partitions: parts, ..Default::default() });
        let res = session.classify_plan(&prepared, &plan, false)?;
        let peak = res.stats.max_partition_nodes.max(graph.num_nodes / parts.max(1));
        println!(
            "{:>6} {:>10.4} {:>12} {:>12.0} {:>10} {:>12.0}",
            parts,
            res.accuracy,
            peak,
            mem.groot_mb(peak),
            groot::util::timer::fmt_dur(res.stats.infer_time),
            groot::util::timer::peak_rss_bytes() as f64 / 1e6,
        );
        if parts == 16 {
            best_pred = Some(res.pred);
        }
    }

    let pred = best_pred.expect("16-partition run");

    // Out-of-core replay of the 16-partition setting: compact columnar
    // ingestion (no dense feature matrix anywhere) + windowed execution.
    // Peak execution memory is the largest 4-partition window — this is
    // the path that scales past device-sized graphs.
    let compact = PreparedGraph::from_source(groot::aig::mult::csa_source(bits, 8192))?;
    let stream_session = Session::native(
        groot::gnn::SageModel::from_bundle(&bundle)?,
        SessionConfig { num_partitions: 16, ..Default::default() },
    );
    let streamed = stream_session.classify_streaming(&compact, 4)?;
    anyhow::ensure!(
        streamed.pred == pred,
        "streaming predictions diverged from the eager 16-partition plan"
    );
    println!(
        "\nstreaming (16 parts, window 4): store {:.1} B/node vs legacy {:.1}; \
         exec working set {:.2} MB; predictions byte-identical ✓",
        compact.resident_bytes() as f64 / compact.num_nodes() as f64,
        graph.resident_bytes() as f64 / graph.num_nodes as f64,
        streamed.stats.peak_resident_bytes as f64 / 1e6
    );

    let t0 = std::time::Instant::now();
    let outcome = groot::verify::verify_multiplier_pred(
        &aig,
        compact.num_nodes(),
        compact.num_aig_nodes(),
        &streamed.pred,
    )?;
    println!(
        "\nalgebraic verification (streamed predictions): {} in {:?} \
         ({} adders, peak {} monomials)",
        if outcome.equivalent { "EQUIVALENT ✓" } else { "NOT PROVEN ✗" },
        t0.elapsed(),
        outcome.adders_used,
        outcome.peak_terms
    );
    anyhow::ensure!(outcome.equivalent, "{:?}", outcome.reason);
    Ok(())
}

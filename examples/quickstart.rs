//! Quickstart — verify a 16-bit CSA multiplier end-to-end.
//!
//! Exercises the full GROOT stack: circuit generation → EDA graph →
//! partitioning → Algorithm-1 edge re-growth → GNN node classification
//! (AOT PJRT executables when built with `--features xla` and
//! `artifacts/` exists, rust-native fallback otherwise) → algebraic
//! verification against the multiplier spec.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use groot::backend::{backend_by_name, InferenceBackend};
use groot::coordinator::{PlanOptions, PreparedGraph, Session, SessionConfig};
use groot::datasets::{self, DatasetKind};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let bits = 16;
    println!("== GROOT quickstart: verifying a {bits}-bit CSA multiplier ==\n");

    // 1. Build the circuit and its EDA graph (features + ground truth).
    let aig = groot::aig::mult::csa_multiplier(bits);
    let graph = datasets::build(DatasetKind::Csa, bits)?;
    println!(
        "circuit: {} AND gates, {} PIs, {} POs -> EDA graph {} nodes / {} edges",
        aig.num_ands(),
        aig.num_pis(),
        aig.num_outputs(),
        graph.num_nodes,
        graph.num_edges()
    );

    // 2. Load the 8-bit-trained model; prefer the AOT PJRT path when this
    // build carries it (cargo feature `xla`), falling back to rust-native.
    let weights_path = Path::new("artifacts/weights_csa8.bin");
    anyhow::ensure!(
        weights_path.exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let bundle = groot::util::tensor::read_bundle(weights_path)?;
    let threads = groot::util::pool::default_threads();
    let backend = match backend_by_name("xla", &bundle, Path::new("artifacts"), 4096, threads) {
        Ok(b) => {
            println!("backend: {}", b.name());
            b
        }
        Err(e) => {
            println!("backend: native (XLA unavailable: {e:#})");
            backend_by_name("native", &bundle, Path::new("artifacts"), 4096, threads)?
        }
    };

    // 3. The staged pipeline, spelled out: prepare the graph once
    // (symmetric CSR + dense features + content fingerprint), build a
    // 4-partition re-grown plan from it, then execute the whole plan
    // through one batched backend call. `Session::classify` is exactly
    // this composition for callers that reuse nothing.
    let backend_name = backend.name();
    let session = Session::new(backend, SessionConfig::default());
    let prepared = PreparedGraph::new(&graph);
    println!(
        "\nprepared: fingerprint {:016x}; {} csr entries",
        prepared.fingerprint(),
        prepared.csr().num_entries()
    );
    let plan = prepared.plan(&PlanOptions { partitions: 4, ..Default::default() });
    println!(
        "plan: {} partitions, {} boundary nodes re-grown, peak partition {} nodes",
        plan.num_partitions(),
        plan.stats.regrowth.total_boundary_nodes,
        plan.stats.regrowth.max_partition_nodes
    );
    let res = session.classify_plan(&prepared, &plan, false)?;
    println!(
        "classification: accuracy {:.4} over {} nodes (one infer_batch of {} partitions)",
        res.accuracy, graph.num_nodes, res.stats.batch_size
    );
    println!(
        "timings: partition {:?}, regrowth {:?}, gather {:?}, inference {:?}",
        res.stats.partition_time,
        res.stats.regrowth_time,
        res.stats.pack_time,
        res.stats.infer_time
    );

    // 4. The same circuit through STREAMING ingestion: a chunked
    // GraphSource into the compact columnar store (1 packed byte of
    // features per node, flat u32 edge arrays), executed one bounded
    // window of partitions at a time. Predictions are byte-identical;
    // the execution working set is a fraction of the eager plan's.
    let compact = PreparedGraph::from_source(groot::aig::mult::csa_source(bits, 8192))?;
    let stream_session = Session::new(
        backend_by_name("native", &bundle, Path::new("artifacts"), 4096, threads)?,
        SessionConfig { num_partitions: 4, ..Default::default() },
    );
    let streamed = stream_session.classify_streaming(&compact, 2)?;
    // The byte-identity contract holds per backend; only claim (and
    // check) it when the eager run above used the same native backend.
    let parity = if backend_name == "native" {
        anyhow::ensure!(
            streamed.pred == res.pred,
            "streaming and eager predictions must be byte-identical"
        );
        " — identical predictions"
    } else {
        " (eager ran on xla; cross-backend parity not asserted)"
    };
    println!(
        "\nstreaming path: compact store {:.1} B/node (legacy {:.1}); exec working set \
         {:.2} MB vs eager {:.2} MB{parity}",
        compact.resident_bytes() as f64 / compact.num_nodes() as f64,
        graph.resident_bytes() as f64 / graph.num_nodes as f64,
        streamed.stats.peak_resident_bytes as f64 / 1e6,
        res.stats.peak_resident_bytes as f64 / 1e6
    );

    // 5. Algebraic verification driven by the predicted XOR/MAJ nodes.
    let t0 = std::time::Instant::now();
    let outcome = groot::verify::verify_multiplier(&aig, &graph, &res.pred)?;
    println!(
        "\nalgebraic check: {} in {:?} (adder substitutions {}, peak {} monomials)",
        if outcome.equivalent { "EQUIVALENT ✓" } else { "NOT PROVEN ✗" },
        t0.elapsed(),
        outcome.adders_used,
        outcome.peak_terms
    );
    anyhow::ensure!(outcome.equivalent, "verification failed: {:?}", outcome.reason);
    println!("\nquickstart OK");
    println!(
        "next: `cargo run --release --example serve` runs this as a concurrent \
         service (N workers × split thread budget — see --workers / \
         SessionConfig::workers), and `groot harness bench --serve` sweeps its \
         throughput."
    );
    Ok(())
}

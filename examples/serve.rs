//! Verification service — GROOT as a long-running server (the run-time
//! verification deployment the paper motivates): a router thread owns the
//! model, clients submit circuits concurrently, and each request's
//! partition count adapts to the design size.
//!
//! Submits a mixed batch of multipliers (csa/booth/wallace at several
//! widths), overlapping the requests, and reports per-request latency +
//! aggregate throughput.
//!
//! Run: `make artifacts && cargo run --release --example serve`

use groot::backend::NativeBackend;
use groot::coordinator::server::Server;
use groot::coordinator::{Backend, SessionConfig};
use groot::datasets::{self, DatasetKind};
use std::path::Path;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let server = Server::spawn(SessionConfig::default(), || -> anyhow::Result<Backend> {
        let bundle =
            groot::util::tensor::read_bundle(Path::new("artifacts/weights_csa8.bin"))?;
        let model = groot::gnn::SageModel::from_bundle(&bundle)?;
        Ok(Box::new(NativeBackend::new(model)))
    });
    let handle = server.handle();

    let workload: Vec<(DatasetKind, usize)> = vec![
        (DatasetKind::Csa, 16),
        (DatasetKind::Booth, 16),
        (DatasetKind::Csa, 32),
        (DatasetKind::Wallace, 16),
        (DatasetKind::Csa, 48),
        (DatasetKind::Booth, 32),
        (DatasetKind::Csa, 64),
        (DatasetKind::Wallace, 32),
    ];

    println!("== GROOT verification service: {} requests ==\n", workload.len());
    let t_all = Instant::now();
    // submit everything up front (the router drains the queue in order,
    // like a single-accelerator deployment would)
    let mut pending = Vec::new();
    for (kind, bits) in &workload {
        let graph = datasets::build(*kind, *bits)?;
        // adaptive partitioning: ~4k nodes per partition
        let parts = (graph.num_nodes / 4096).max(1);
        let submitted = Instant::now();
        let rx = handle.submit(graph, Some(parts))?;
        pending.push((kind.name(), *bits, parts, submitted, rx));
    }
    println!(
        "{:>10} {:>6} {:>6} {:>10} {:>12} {:>10}",
        "dataset", "bits", "parts", "acc", "latency", "nodes"
    );
    let mut total_nodes = 0usize;
    for (name, bits, parts, submitted, rx) in pending {
        let res = rx.recv()??;
        total_nodes += res.pred.len();
        println!(
            "{:>10} {:>6} {:>6} {:>10.4} {:>12} {:>10}",
            name,
            bits,
            parts,
            res.accuracy,
            groot::util::timer::fmt_dur(submitted.elapsed()),
            res.pred.len()
        );
    }
    let wall = t_all.elapsed();
    println!(
        "\nthroughput: {} requests / {} = {:.1} knodes/s classified",
        workload.len(),
        groot::util::timer::fmt_dur(wall),
        total_nodes as f64 / wall.as_secs_f64() / 1e3
    );
    Ok(())
}

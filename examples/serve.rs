//! Verification service — GROOT as a long-running concurrent server (the
//! run-time verification deployment the paper motivates): N worker
//! threads pull from a bounded submission queue, each owns its own
//! backend, and all share one sharded partition-plan cache. Clients
//! submit circuits with per-request [`VerifyOptions`]; each request's
//! partition count adapts to the design size.
//!
//! The workload deliberately repeats circuits: repeat requests hit the
//! shared plan cache (no partitioning/re-growth/gathering — on ANY
//! worker, warmed by whichever worker planned first) and the per-request
//! stats show it. Within a request all partitions go through one
//! `infer_batch` call, which fans them out across the backend's thread
//! budget. Workers × per-worker threads stay ≤ the machine budget.
//!
//! Run: `make artifacts && cargo run --release --example serve`

use groot::backend::NativeBackend;
use groot::coordinator::server::{Server, VerifyOptions};
use groot::coordinator::{Backend, SessionConfig};
use groot::datasets::{self, DatasetKind};
use std::path::Path;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // Split the machine budget: 4 serving workers, each backend getting
    // an equal share of the cores for its partition lanes / SpMM threads.
    let total_threads = groot::util::pool::default_threads();
    let workers = total_threads.clamp(1, 4);
    let per_worker_threads = (total_threads / workers).max(1);
    // Cache sized to hold the whole workload's distinct keys so every
    // repeat is a guaranteed warm hit in the printout.
    let server = Server::spawn_with_cache(
        SessionConfig { workers, threads: per_worker_threads, ..Default::default() },
        32,
        move || -> anyhow::Result<Backend> {
            // Runs once on EACH worker thread (backends never migrate).
            let bundle =
                groot::util::tensor::read_bundle(Path::new("artifacts/weights_csa8.bin"))?;
            let model = groot::gnn::SageModel::from_bundle(&bundle)?;
            Ok(Box::new(NativeBackend::with_threads(model, per_worker_threads)))
        },
    );
    let handle = server.handle();

    // Mixed families and widths, with repeats: a verification service
    // sees the same design again after every incremental synthesis step.
    let workload: Vec<(DatasetKind, usize)> = vec![
        (DatasetKind::Csa, 16),
        (DatasetKind::Booth, 16),
        (DatasetKind::Csa, 32),
        (DatasetKind::Csa, 16),   // repeat → plan-cache hit
        (DatasetKind::Wallace, 16),
        (DatasetKind::Csa, 48),
        (DatasetKind::Booth, 16), // repeat → plan-cache hit
        (DatasetKind::Booth, 32),
        (DatasetKind::Csa, 64),
        (DatasetKind::Csa, 32),   // repeat → plan-cache hit
        (DatasetKind::Wallace, 32),
    ];

    println!(
        "== GROOT verification service: {} requests, {workers} workers × \
         {per_worker_threads} threads ==\n",
        workload.len()
    );
    let t_all = Instant::now();
    // submit everything up front: the bounded queue feeds all workers at
    // once, so independent circuits verify concurrently
    let mut pending = Vec::new();
    for (kind, bits) in &workload {
        let graph = datasets::build(*kind, *bits)?;
        // adaptive partitioning: ~4k nodes per partition
        let parts = (graph.num_nodes / 4096).max(1);
        let submitted = Instant::now();
        let rx = handle.submit(graph, VerifyOptions::partitions(parts))?;
        pending.push((kind.name(), *bits, parts, submitted, rx));
    }
    println!(
        "{:>10} {:>6} {:>6} {:>6} {:>10} {:>12} {:>10} {:>6}",
        "dataset", "bits", "parts", "batch", "acc", "latency", "nodes", "plan"
    );
    let mut total_nodes = 0usize;
    let mut cache_hits = 0usize;
    for (name, bits, parts, submitted, rx) in pending {
        let res = rx.recv()??;
        total_nodes += res.pred.len();
        cache_hits += res.stats.plan_cache_hit as usize;
        println!(
            "{:>10} {:>6} {:>6} {:>6} {:>10.4} {:>12} {:>10} {:>6}",
            name,
            bits,
            parts,
            res.stats.batch_size,
            res.accuracy,
            groot::util::timer::fmt_dur(submitted.elapsed()),
            res.pred.len(),
            if res.stats.plan_cache_hit { "warm" } else { "cold" }
        );
    }
    let wall = t_all.elapsed();
    let (hits, misses) = server.cache_stats();
    println!(
        "\nthroughput: {} requests / {} = {:.1} knodes/s classified; \
         {} plan-cache hits ({} hits / {} misses server-wide)",
        workload.len(),
        groot::util::timer::fmt_dur(wall),
        total_nodes as f64 / wall.as_secs_f64() / 1e3,
        cache_hits,
        hits,
        misses
    );
    // Explicit deterministic shutdown even though `handle` is still alive.
    server.shutdown();
    Ok(())
}

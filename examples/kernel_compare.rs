//! Kernel comparison — a compact Fig. 9: the four SpMM engines on one
//! polarized EDA graph, with the degree profile that motivates the HD/LD
//! split printed first.
//!
//! Run: `cargo run --release --example kernel_compare [-- --bits 128 --dataset booth]`

use groot::datasets::{self, DatasetKind};
use groot::graph::{Csr, DegreeProfile};
use groot::spmm::all_engines;
use groot::util::cli::Args;
use groot::util::rng::Rng;
use groot::util::timer::{bench_for, fmt_dur};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(&[]);
    let bits: usize = args.parse_or("bits", 128)?;
    let kind = DatasetKind::parse(&args.get_or("dataset", "booth"))?;
    let dim: usize = args.parse_or("dim", 32)?;
    let threads = groot::util::pool::default_threads();

    let graph = datasets::build(kind, bits)?;
    let csr = Csr::symmetric_from_edges(graph.num_nodes, &graph.edges);
    let profile = DegreeProfile::new(&csr, 64, 12);
    println!(
        "== kernel_compare: {}{} — {} rows, {} nnz, dim {dim}, {threads} threads ==",
        kind.name(),
        bits,
        csr.num_nodes(),
        csr.num_entries()
    );
    println!(
        "degree profile: max {}, hd rows(≥64) {} holding {:.1}% of nnz, ld rows {}",
        profile.max_degree,
        profile.hd_rows.len(),
        100.0 * profile.hd_nnz_fraction(&csr),
        profile.ld_rows.len()
    );

    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..csr.num_nodes() * dim).map(|_| rng.f32()).collect();
    let reference = csr.spmm_mean_reference(&x, dim);

    println!("\n{:>16} {:>12} {:>10}", "engine", "median", "speedup");
    let mut baseline = None;
    for engine in all_engines(threads) {
        // correctness first
        let y = engine.spmm_mean(&csr, &x, dim);
        let diff = Csr::max_abs_diff(&y, &reference);
        assert!(diff < 1e-4, "{} wrong by {diff}", engine.name());
        let stats = bench_for(Duration::from_millis(500), || engine.spmm_mean(&csr, &x, dim));
        let med = stats.median_secs();
        let speedup = match baseline {
            None => {
                baseline = Some(med);
                1.0
            }
            Some(b) => b / med,
        };
        println!(
            "{:>16} {:>12} {:>9.2}x",
            engine.name(),
            fmt_dur(Duration::from_secs_f64(med)),
            speedup
        );
    }
    println!("\n(speedup relative to cusparse-like; correctness checked vs dense reference)");
    Ok(())
}

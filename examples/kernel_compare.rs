//! Kernel comparison — a compact Fig. 9: the four SpMM engines on one
//! polarized EDA graph, with the degree profile that motivates the HD/LD
//! split printed first; then the same engines inside a full GraphSAGE
//! forward pass through [`NativeBackend`] (the scratch-arena inference
//! path — no artifacts or XLA toolchain needed; on the GROOT engine the
//! forward is allocation-free apart from the returned logits vector).
//!
//! Run: `cargo run --release --example kernel_compare [-- --bits 128 --dataset booth]`

use groot::backend::{InferenceBackend, NativeBackend, PartitionInput};
use groot::datasets::{self, DatasetKind};
use groot::gnn::{SageLayer, SageModel};
use groot::graph::{Csr, DegreeProfile};
use groot::spmm::{all_engines, SpmmEngine};
use groot::util::cli::Args;
use groot::util::rng::Rng;
use groot::util::timer::{bench_for, fmt_dur};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(&[]);
    let bits: usize = args.parse_or("bits", 128)?;
    let kind = DatasetKind::parse(&args.get_or("dataset", "booth"))?;
    let dim: usize = args.parse_or("dim", 32)?;
    let threads = groot::util::pool::default_threads();

    let graph = datasets::build(kind, bits)?;
    let csr = Csr::symmetric_from_edges(graph.num_nodes, &graph.edges);
    let profile = DegreeProfile::new(&csr, 64, 12);
    println!(
        "== kernel_compare: {}{} — {} rows, {} nnz, dim {dim}, {threads} threads ==",
        kind.name(),
        bits,
        csr.num_nodes(),
        csr.num_entries()
    );
    println!(
        "degree profile: max {}, hd rows(≥64) {} holding {:.1}% of nnz, ld rows {}",
        profile.max_degree,
        profile.hd_rows.len(),
        100.0 * profile.hd_nnz_fraction(&csr),
        profile.ld_rows.len()
    );

    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..csr.num_nodes() * dim).map(|_| rng.f32()).collect();
    let reference = csr.spmm_mean_reference(&x, dim);

    println!("\n{:>16} {:>12} {:>10}", "engine", "median", "speedup");
    let mut baseline = None;
    let mut out = vec![0.0f32; csr.num_nodes() * dim];
    for engine in all_engines(threads) {
        // correctness first
        let y = engine.spmm_mean(&csr, &x, dim);
        let diff = Csr::max_abs_diff(&y, &reference);
        assert!(diff < 1e-4, "{} wrong by {diff}", engine.name());
        // bench the in-place hot path the model actually runs (reused
        // output buffer, no per-call allocation for the result)
        let stats = bench_for(Duration::from_millis(500), || {
            engine.spmm_mean_into(&csr, &x, dim, &mut out)
        });
        let med = stats.median_secs();
        let speedup = match baseline {
            None => {
                baseline = Some(med);
                1.0
            }
            Some(b) => b / med,
        };
        println!(
            "{:>16} {:>12} {:>9.2}x",
            engine.name(),
            fmt_dur(Duration::from_secs_f64(med)),
            speedup
        );
    }
    println!("\n(speedup relative to cusparse-like; correctness checked vs dense reference)");

    // --- The same engines as the aggregation kernel of a full GraphSAGE
    // forward pass, via the pluggable NativeBackend. ---
    let model = random_model(&mut rng, dim, 16, 5);
    println!(
        "\n== GraphSAGE forward ({} → 16 → 5) per engine, NativeBackend ==",
        dim
    );
    println!("{:>16} {:>12} {:>10}", "engine", "median", "speedup");
    let mut reference_logits: Option<Vec<f32>> = None;
    let mut baseline = None;
    for engine in all_engines(threads) {
        let name = engine.name();
        let backend = NativeBackend::with_engine(model.clone(), engine);
        let input = PartitionInput { csr: &csr, features: &x, feature_dim: dim };
        let out = backend.infer(input)?;
        if let Some(want) = reference_logits.as_deref() {
            let diff = Csr::max_abs_diff(&out.logits, want);
            assert!(diff < 1e-3, "{name} logits diverge by {diff}");
        } else {
            reference_logits = Some(out.logits);
        }
        let stats = bench_for(Duration::from_millis(500), || {
            backend.infer(input).expect("forward")
        });
        let med = stats.median_secs();
        let speedup = match baseline {
            None => {
                baseline = Some(med);
                1.0
            }
            Some(b) => b / med,
        };
        println!(
            "{:>16} {:>12} {:>9.2}x",
            name,
            fmt_dur(Duration::from_secs_f64(med)),
            speedup
        );
    }
    println!("(all engines agree on the logits; forward reuses the scratch arena)");
    Ok(())
}

/// Random two-layer model so the forward pass exercises the ping-pong
/// buffers; weights are small to keep activations finite.
fn random_model(rng: &mut Rng, din: usize, hidden: usize, classes: usize) -> SageModel {
    let mut w = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.f32() * 0.2 - 0.1).collect() };
    SageModel {
        layers: vec![
            SageLayer {
                din,
                dout: hidden,
                w_self: w(din * hidden),
                w_neigh: w(din * hidden),
                bias: w(hidden),
            },
            SageLayer {
                din: hidden,
                dout: classes,
                w_self: w(hidden * classes),
                w_neigh: w(hidden * classes),
                bias: w(classes),
            },
        ],
    }
}

//! Network serving quickstart — the `groot serve` / `groot client` pair
//! as a library: bind a [`NetDaemon`] on a Unix socket, connect a
//! [`GrootClient`], classify the same design twice (cold plan build,
//! then plan-cache-warm), and read the daemon's observability snapshot.
//!
//! The same wire protocol backs `groot serve --listen unix:/path` +
//! `groot client classify --connect unix:/path`; this example is the
//! in-process equivalent with no artifacts required (synthetic weights).
//!
//! Run: `cargo run --release --example net_quickstart`

use groot::backend::NativeBackend;
use groot::coordinator::server::{Server, VerifyOptions};
use groot::coordinator::{Backend, SessionConfig};
use groot::datasets::{self, DatasetKind};
use groot::gnn::{SageLayer, SageModel};
use groot::net::{BindAddr, GrootClient, NetConfig, NetDaemon, Reply};

/// Tiny deterministic 4→8→5 model so the example runs without trained
/// artifacts (it demonstrates the transport, not the accuracy).
fn tiny_model() -> SageModel {
    let wave = |n: usize| -> Vec<f32> {
        (0..n).map(|i| ((i as f32 * 0.9).sin()) * 0.25).collect()
    };
    SageModel {
        layers: vec![
            SageLayer { din: 4, dout: 8, w_self: wave(32), w_neigh: wave(32), bias: wave(8) },
            SageLayer { din: 8, dout: 5, w_self: wave(40), w_neigh: wave(40), bias: wave(5) },
        ],
    }
}

fn main() -> anyhow::Result<()> {
    // 2 serving workers, each with a single-threaded backend.
    let server = Server::spawn(
        SessionConfig { workers: 2, threads: 1, ..Default::default() },
        || -> anyhow::Result<Backend> {
            Ok(Box::new(NativeBackend::with_threads(tiny_model(), 1)))
        },
    );
    let sock = std::env::temp_dir().join(format!("groot_net_qs_{}.sock", std::process::id()));
    let daemon = NetDaemon::bind(&BindAddr::Unix(sock.clone()), server, NetConfig::default())?;
    println!("daemon listening on {}", daemon.bound());

    let mut client = GrootClient::connect(&BindAddr::Unix(sock))?;
    let circuit = datasets::build(DatasetKind::Csa, 16)?.to_circuit()?;
    let opts = VerifyOptions::partitions(8);

    for round in ["cold", "warm"] {
        match client.classify_circuit(&circuit, &opts)? {
            Reply::Result(res) => println!(
                "{round}: {} nodes, {} partitions, accuracy {:.4}, plan {}",
                res.pred.len(),
                res.stats.num_partitions,
                res.accuracy,
                if res.stats.plan_cache_hit { "cache-warm" } else { "built" }
            ),
            Reply::Busy => println!("{round}: daemon busy (bounded queue full), try again"),
        }
    }

    let stats = client.stats()?;
    println!(
        "served {} requests across {} workers; plan cache {} hits / {} misses; p95 {:.2} ms",
        stats.requests_served,
        stats.workers,
        stats.plan_cache_hits,
        stats.plan_cache_misses,
        stats.p95_ms
    );
    daemon.shutdown();
    Ok(())
}

//! Analytic accelerator-memory model — regenerates the paper's memory
//! results (Fig. 1a, Fig. 8, Table II) on hardware we don't have.
//!
//! The paper measures GPU memory for GAMORA (full-graph PyG on A100) and
//! GROOT (partitioned, single GPU). Neither an A100 nor CUDA exists in
//! this container, so the *shape* is computed exactly from graph
//! arithmetic (node/edge counts, partition sizes, re-grown boundaries —
//! all measured by running our own partitioner) and the *scale* comes
//! from two linear models calibrated against Table II:
//!
//! ```text
//! GAMORA:  mem(N)        = base_f + β_f · N
//! GROOT:   mem(N, P)     = base_g + β_g · (N/P + B̄_P)
//! ```
//!
//! Calibration (Table II, CSA batch 16): β_f ≈ 838 B/node from the
//! 256→512-bit row pair, base_f ≈ 1226 MB (CUDA context + allocator
//! floor); β_g ≈ 730 B/node, base_g ≈ 2391 MB from the P ∈ {2,4,8} rows.
//! B̄_P is the mean re-grown partition overhead (boundary nodes), measured
//! exactly at widths this container can build and extrapolated by the
//! fitted cut-growth law above that. The measured-RSS column printed by
//! the harnesses next to the model keeps us honest about the shape.

use crate::coordinator::{PlanOptions, PreparedGraph};

/// Bytes-per-node and base constants calibrated against Table II.
#[derive(Clone, Copy, Debug)]
pub struct MemModel {
    pub gamora_base_mb: f64,
    pub gamora_bytes_per_node: f64,
    pub groot_base_mb: f64,
    pub groot_bytes_per_node: f64,
    /// Device capacity used for OOM marking (A100-SXM 80 GB).
    pub device_mb: f64,
}

impl Default for MemModel {
    fn default() -> Self {
        MemModel {
            gamora_base_mb: 1226.0,
            gamora_bytes_per_node: 838.0,
            groot_base_mb: 2391.0,
            groot_bytes_per_node: 730.0,
            device_mb: 80.0 * 1024.0,
        }
    }
}

impl MemModel {
    /// GAMORA full-graph footprint (MB) for `nodes` graph nodes.
    pub fn gamora_mb(&self, nodes: usize) -> f64 {
        self.gamora_base_mb + self.gamora_bytes_per_node * nodes as f64 / 1e6
    }

    /// GROOT footprint (MB): the device holds one re-grown partition at a
    /// time; `peak_partition_nodes` = max over partitions of |S_p ∪ B_p|.
    pub fn groot_mb(&self, peak_partition_nodes: usize) -> f64 {
        self.groot_base_mb + self.groot_bytes_per_node * peak_partition_nodes as f64 / 1e6
    }

    pub fn is_oom(&self, mb: f64) -> bool {
        mb > self.device_mb
    }
}

/// CSA node count at the *paper's* graph density — its 1024-bit batch-16
/// workload has 134,103,040 nodes, i.e. 134,103,040/16 ≈ 7.995 · bits²
/// per graph (ABC's generator is slightly denser-optimized than ours).
/// Used when reproducing the paper's memory tables at their scale.
pub fn csa_nodes_paper(bits: usize, batch: usize) -> usize {
    ((7.995 * (bits as f64) * (bits as f64)) as usize) * batch
}

/// CSA multiplier EDA-graph node count of *our* generator: exact by
/// construction below 256 bits, closed-form (measured density ≈ 9.96·n²)
/// beyond.
pub fn csa_nodes(bits: usize, batch: usize) -> usize {
    let per_graph = if bits <= 256 {
        let g = crate::aig::mult::csa_multiplier(bits);
        g.num_nodes() + g.num_outputs()
    } else {
        (9.96 * (bits as f64) * (bits as f64)) as usize
    };
    per_graph * batch
}

/// Measured peak re-grown partition size for a graph this container can
/// build: one stats-only pipeline probe (real partitioner + Algorithm 1,
/// no per-partition buffer materialization). Callers sweeping partition
/// counts should hold a [`PreparedGraph`] and call
/// [`PreparedGraph::plan_stats`] directly so the CSR is built once.
pub fn measured_peak_partition(
    graph: &crate::features::EdaGraph,
    partitions: usize,
    regrow: bool,
    seed: u64,
) -> crate::regrowth::RegrowthStats {
    PreparedGraph::new(graph)
        .plan_stats(&PlanOptions { partitions, regrow, seed, ..Default::default() })
        .regrowth
}

/// Boundary-overhead extrapolation: measure the re-grown boundary
/// fraction φ(P) at a feasible width, apply it at the target size.
/// EDA-graph cuts scale near-linearly in the bit width (the array has a
/// 1-D column structure), so φ(P) is roughly width-independent — which we
/// check by measuring two widths in the harness.
pub fn extrapolated_peak_partition(nodes: usize, partitions: usize, phi: f64) -> usize {
    let per = nodes as f64 / partitions.max(1) as f64;
    (per * (1.0 + phi)) as usize
}

/// Convenience: Table II style row (model only, for sizes beyond measure).
pub fn tab2_row(model: &MemModel, nodes: usize, partitions: &[usize], phi: &[f64]) -> Vec<f64> {
    partitions
        .iter()
        .zip(phi)
        .map(|(&p, &f)| model.groot_mb(extrapolated_peak_partition(nodes, p, f)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_reproduces_table2_gamora() {
        let m = MemModel::default();
        // paper: 256-bit → 8,263 MB; 512-bit → 29,375 MB; 1024-bit → OOM
        let n256 = csa_nodes_paper(256, 16);
        let n512 = csa_nodes_paper(512, 16);
        let n1024 = csa_nodes_paper(1024, 16);
        let e256 = (m.gamora_mb(n256) - 8263.0).abs() / 8263.0;
        let e512 = (m.gamora_mb(n512) - 29375.0).abs() / 29375.0;
        assert!(e256 < 0.10, "256-bit rel err {e256}");
        assert!(e512 < 0.10, "512-bit rel err {e512}");
        assert!(m.is_oom(m.gamora_mb(n1024)), "1024-bit must be OOM");
    }

    #[test]
    fn calibration_reproduces_table2_groot() {
        let m = MemModel::default();
        let n256 = csa_nodes_paper(256, 16);
        // paper GROOT rows for 256-bit: P=2 → 5457, P=4 → 3923, P=8 → 3157
        for (p, want) in [(2usize, 5457.0), (4, 3923.0), (8, 3157.0)] {
            let got = m.groot_mb(extrapolated_peak_partition(n256, p, 0.0));
            let rel = (got - want).abs() / want;
            assert!(rel < 0.10, "P={p}: got {got} want {want} rel {rel}");
        }
    }

    #[test]
    fn csa_nodes_formula_matches_paper_anchor() {
        // paper: 1024-bit, batch 16 → 134,103,040 nodes
        let n = csa_nodes_paper(1024, 16);
        let rel = (n as f64 - 134_103_040.0).abs() / 134_103_040.0;
        assert!(rel < 0.01, "1024b16 nodes {n}");
    }

    #[test]
    fn our_generator_density_is_close_to_papers() {
        // our unoptimized array generator is ~25% denser than ABC's; the
        // closed form for large widths must match our measured density
        let exact = csa_nodes(256, 1);
        let formula = (9.96 * 256.0 * 256.0) as usize;
        let rel = (exact as f64 - formula as f64).abs() / exact as f64;
        assert!(rel < 0.05, "exact {exact} vs formula {formula}");
    }

    #[test]
    fn measured_boundary_fraction_is_small() {
        let g = crate::datasets::build(crate::datasets::DatasetKind::Csa, 32).unwrap();
        let s = measured_peak_partition(&g, 8, true, 1);
        let phi = s.total_boundary_nodes as f64 / s.total_core_nodes as f64;
        assert!(phi < 0.5, "boundary fraction {phi}");
        // memory decreases with more partitions
        let m = MemModel::default();
        let s2 = measured_peak_partition(&g, 2, true, 1);
        assert!(m.groot_mb(s.max_partition_nodes) < m.groot_mb(s2.max_partition_nodes));
    }
}

//! Content-addressed per-partition prediction cache — the third cache
//! tier (after the in-memory plan LRU and the persistent plan store).
//!
//! Keyed by [`PlannedPartition::digest`]: the digest covers the core
//! count, global node list, local CSR, and feature bits — everything
//! inference and stitching consume — so a hit may stitch the cached
//! core-prediction bytes verbatim in place of an `infer_batch` row,
//! byte-identically under a deterministic backend.
//!
//! An optional persistent tier writes each entry through to the
//! [`PlanStore`] as a sibling record type (GPPR files, see
//! `coordinator::planstore`), tagged with a model tag so predictions
//! from a different weight bundle can never be stitched.
//!
//! [`PlannedPartition::digest`]: crate::coordinator::PlannedPartition

use crate::coordinator::PlanStore;
use crate::obs::metrics;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Process-wide prediction-cache counters, labeled like the plan-cache
/// family so dashboards can diff the two tiers directly.
struct PredMetrics {
    hits: metrics::Counter,
    misses: metrics::Counter,
    disk_hits: metrics::Counter,
}

fn pred_metrics() -> &'static PredMetrics {
    static M: OnceLock<PredMetrics> = OnceLock::new();
    M.get_or_init(|| {
        let r = metrics::registry();
        const HELP: &str =
            "Incremental prediction-cache lookups by tier and outcome, across every instance.";
        PredMetrics {
            hits: r.counter(
                "groot_incremental_pred_cache_lookups_total",
                HELP,
                &[("tier", "memory"), ("outcome", "hit")],
            ),
            misses: r.counter(
                "groot_incremental_pred_cache_lookups_total",
                HELP,
                &[("tier", "memory"), ("outcome", "miss")],
            ),
            disk_hits: r.counter(
                "groot_incremental_pred_cache_lookups_total",
                HELP,
                &[("tier", "disk"), ("outcome", "hit")],
            ),
        }
    })
}

/// Default entry capacity: per-partition core predictions are one byte
/// per core node, so even thousands of entries cost megabytes, not the
/// gigabytes a plan cache of the same depth would.
pub const DEFAULT_PREDICTION_CACHE_CAPACITY: usize = 4096;

/// Model tag for the persistent tier: FNV-1a over the serialized weight
/// bundle. Two daemons tag identically iff they serve byte-identical
/// weights, so a restarted daemon with retrained weights can never
/// stitch a stale on-disk prediction record.
pub fn model_tag_for_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Vec-based LRU (index 0 = eviction candidate), mirroring `PlanCache`.
struct PredLru {
    capacity: usize,
    entries: Vec<(u64, Arc<Vec<u8>>)>,
}

impl PredLru {
    fn get(&mut self, digest: u64) -> Option<Arc<Vec<u8>>> {
        let i = self.entries.iter().position(|(d, _)| *d == digest)?;
        let entry = self.entries.remove(i);
        let out = entry.1.clone();
        self.entries.push(entry);
        Some(out)
    }

    fn insert(&mut self, digest: u64, core: Arc<Vec<u8>>) {
        if let Some(i) = self.entries.iter().position(|(d, _)| *d == digest) {
            self.entries.remove(i);
        } else if self.entries.len() >= self.capacity {
            self.entries.remove(0);
        }
        self.entries.push((digest, core));
    }
}

/// Thread-safe digest → core-prediction-bytes cache with an optional
/// persistent tier. Shared by every serving worker through
/// [`super::IncrementalState`].
pub struct PredictionCache {
    inner: Mutex<PredLru>,
    hits: AtomicU64,
    misses: AtomicU64,
    disk_hits: AtomicU64,
    /// Persistent tier + the model tag stamped into every record. The
    /// tag pins records to one weight bundle: the in-memory map lives
    /// and dies with one backend, but disk records outlive restarts
    /// that may load different weights.
    store: Option<(PlanStore, u64)>,
}

impl Default for PredictionCache {
    fn default() -> Self {
        PredictionCache::new(DEFAULT_PREDICTION_CACHE_CAPACITY)
    }
}

impl PredictionCache {
    pub fn new(capacity: usize) -> PredictionCache {
        PredictionCache {
            inner: Mutex::new(PredLru { capacity: capacity.max(1), entries: Vec::new() }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            store: None,
        }
    }

    /// [`Self::new`] plus a persistent tier: memory miss → validated
    /// disk load → caller re-infers; inserts write through best-effort.
    pub fn with_store(capacity: usize, store: PlanStore, model_tag: u64) -> PredictionCache {
        let mut cache = Self::new(capacity);
        cache.store = Some((store, model_tag));
        cache
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::SeqCst)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::SeqCst)
    }

    /// In-memory misses the persistent tier answered.
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.load(Ordering::SeqCst)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up the core predictions for a partition digest, refreshing
    /// LRU recency on a hit and falling back to the persistent tier on
    /// a memory miss (a disk hit is promoted into memory).
    pub fn get(&self, digest: u64) -> Option<Arc<Vec<u8>>> {
        let mut guard = self.inner.lock().unwrap();
        if let Some(core) = guard.get(digest) {
            self.hits.fetch_add(1, Ordering::SeqCst);
            pred_metrics().hits.inc();
            return Some(core);
        }
        self.misses.fetch_add(1, Ordering::SeqCst);
        pred_metrics().misses.inc();
        if let Some((store, tag)) = &self.store {
            if let Some(core) = store.load_predictions(digest, *tag) {
                let core = Arc::new(core);
                guard.insert(digest, core.clone());
                self.disk_hits.fetch_add(1, Ordering::SeqCst);
                pred_metrics().disk_hits.inc();
                return Some(core);
            }
        }
        None
    }

    /// Insert (or refresh) one partition's core predictions, writing
    /// through to the persistent tier best-effort (a full disk must not
    /// fail the classify that produced the predictions).
    pub fn insert(&self, digest: u64, core: Arc<Vec<u8>>) {
        if let Some((store, tag)) = &self.store {
            let _ = store.save_predictions(digest, *tag, &core);
        }
        self.inner.lock().unwrap().insert(digest, core);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_semantics_and_counters() {
        let cache = PredictionCache::new(2);
        assert!(cache.get(1).is_none());
        cache.insert(1, Arc::new(vec![1]));
        cache.insert(2, Arc::new(vec![2]));
        assert_eq!(cache.get(1).unwrap().as_slice(), &[1]);
        cache.insert(3, Arc::new(vec![3])); // evicts 2 (LRU after the get)
        assert!(cache.get(2).is_none());
        assert_eq!(cache.get(1).unwrap().as_slice(), &[1]);
        assert_eq!(cache.get(3).unwrap().as_slice(), &[3]);
        assert_eq!(cache.hits(), 3);
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.len(), 2);
    }
}

//! The edit model: a small algebra of circuit modifications
//! ([`GraphEdit`]) applied to a compact [`CircuitGraph`] to produce the
//! edited design. Edits are the unit the incremental verifier reasons
//! about — `classify_delta` re-executes only the partitions whose
//! content digest the edit actually moved.
//!
//! Edits deliberately mirror what production flows do between
//! verification runs: local function/polarity rewrites (resynthesis),
//! rewiring (edge remove + add), and appended logic cones (ECOs).

use crate::graph::circuit::{desc_features, desc_kind, pack_desc, CircuitGraph, KIND_AND, KIND_PO};
use anyhow::Result;

/// One circuit modification. Node ids refer to the graph the edit list
/// is applied to, except inside [`GraphEdit::AppendCone`] (see its
/// field docs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GraphEdit {
    /// Rewrite a node's function descriptor (kind + fanin polarities)
    /// in place. Topology-preserving: the edge structure — and
    /// therefore the symmetric CSR and the k-way assignment — is
    /// untouched, which is what lets `classify_delta` reuse the base
    /// partitioning without re-running the partitioner.
    SetFunction { node: u32, kind: u8, inv_l: bool, inv_r: bool },
    /// Append a fanin edge `src → dst` (after any existing fanins of
    /// `dst`).
    AddEdge { src: u32, dst: u32 },
    /// Remove the first fanin edge `src → dst`. Errors if no such edge
    /// exists. Pairing with [`GraphEdit::AddEdge`] expresses a rewire;
    /// structural validity is checked once after the whole edit list is
    /// applied, so transiently under-wired AND nodes are fine.
    RemoveEdge { src: u32, dst: u32 },
    /// Append a cone of new logic nodes — the ECO case. The cone is
    /// spliced in at the end of the AIG-node prefix (existing PO nodes
    /// shift up by the cone size, edges are remapped automatically).
    /// `fanins` are `(src, dst)` pairs where `dst` is a cone-relative
    /// index (`0..desc.len()`) and `src` is a node id in the EDITED
    /// numbering — an existing AIG node (`< num_aig_nodes`) or an
    /// earlier cone node (`num_aig_nodes + j` with `j < dst`).
    AppendCone { desc: Vec<u8>, labels: Vec<u8>, fanins: Vec<(u32, u32)> },
}

impl GraphEdit {
    /// True iff applying this edit cannot change the edge structure.
    /// All-topology-preserving edit lists keep the symmetric CSR
    /// byte-identical, so the deterministic partitioner would reproduce
    /// the base assignment exactly — the reuse precondition.
    pub fn preserves_topology(&self) -> bool {
        matches!(self, GraphEdit::SetFunction { .. })
    }
}

/// Apply an edit list to a circuit, producing the edited circuit. The
/// result passes full structural validation ([`CircuitGraph::check`]);
/// intermediate states may be transiently invalid (e.g. a rewire
/// expressed as remove + add).
pub fn apply_edits(base: &CircuitGraph, edits: &[GraphEdit]) -> Result<CircuitGraph> {
    let n = base.num_nodes();
    let mut num_aig = base.num_aig_nodes();
    let mut desc = base.desc_slice(0, n).to_vec();
    let mut labels = base.labels_u8().to_vec();
    let mut edges: Vec<(u32, u32)> = base.edges_iter().collect();

    for (i, edit) in edits.iter().enumerate() {
        match edit {
            GraphEdit::SetFunction { node, kind, inv_l, inv_r } => {
                let u = *node as usize;
                anyhow::ensure!(u < desc.len(), "edit {i}: node {node} out of range");
                anyhow::ensure!(*kind <= KIND_PO, "edit {i}: invalid node kind {kind}");
                desc[u] = pack_desc(*kind, *inv_l, *inv_r);
            }
            GraphEdit::AddEdge { src, dst } => {
                anyhow::ensure!(
                    (*src as usize) < desc.len() && (*dst as usize) < desc.len(),
                    "edit {i}: edge ({src}, {dst}) endpoint out of range"
                );
                edges.push((*src, *dst));
            }
            GraphEdit::RemoveEdge { src, dst } => {
                let at = edges.iter().position(|&e| e == (*src, *dst));
                let at = at
                    .ok_or_else(|| anyhow::anyhow!("edit {i}: no edge ({src}, {dst}) to remove"))?;
                edges.remove(at);
            }
            GraphEdit::AppendCone { desc: cone_desc, labels: cone_labels, fanins } => {
                anyhow::ensure!(
                    cone_desc.len() == cone_labels.len(),
                    "edit {i}: cone has {} descriptors but {} labels",
                    cone_desc.len(),
                    cone_labels.len()
                );
                let k = cone_desc.len();
                let at = num_aig as u32;
                // Existing nodes at or after the splice point (the PO
                // suffix) shift up by the cone size.
                for (s, d) in edges.iter_mut() {
                    if *s >= at {
                        *s += k as u32;
                    }
                    if *d >= at {
                        *d += k as u32;
                    }
                }
                for (j, (&cd, &cl)) in cone_desc.iter().zip(cone_labels).enumerate() {
                    desc.insert(num_aig + j, cd);
                    labels.insert(num_aig + j, cl);
                }
                for &(src, dst_rel) in fanins {
                    anyhow::ensure!(
                        (dst_rel as usize) < k,
                        "edit {i}: cone fanin destination {dst_rel} outside cone of {k}"
                    );
                    anyhow::ensure!(
                        src < at + dst_rel,
                        "edit {i}: cone fanin source {src} is not an earlier node \
                         (cone node {dst_rel} is id {})",
                        at + dst_rel
                    );
                    edges.push((src, at + dst_rel));
                }
                num_aig += k;
            }
        }
    }

    CircuitGraph::from_components(base.name.clone(), num_aig, desc, labels, &edges)
}

/// Deterministic synthetic edit generator: flip the left-fanin polarity
/// of `count` distinct AND nodes chosen by a seeded PRNG. Topology-
/// preserving by construction — the workload the CI job and the
/// incremental harness sweep, because it models the smallest real
/// resynthesis deltas while keeping the k-way assignment reusable.
pub fn synthetic_polarity_edits(circuit: &CircuitGraph, count: usize, seed: u64) -> Vec<GraphEdit> {
    let ands: Vec<u32> = (0..circuit.num_nodes() as u32)
        .filter(|&u| desc_kind(circuit.desc(u as usize)) == KIND_AND)
        .collect();
    if ands.is_empty() {
        return Vec::new();
    }
    let count = count.min(ands.len());
    let mut rng = crate::util::rng::Rng::new(seed ^ 0x1CF0_EDD1);
    let picks = rng.sample_indices(ands.len(), count);
    picks
        .into_iter()
        .map(|i| {
            let node = ands[i];
            let row = desc_features(circuit.desc(node as usize));
            GraphEdit::SetFunction {
                node,
                kind: KIND_AND,
                inv_l: row[2] == 0.0, // flip the left polarity bit
                inv_r: row[3] != 0.0,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::circuit::KIND_INPUT;

    fn circuit() -> CircuitGraph {
        CircuitGraph::from_source(crate::aig::mult::csa_source(4, 64)).unwrap()
    }

    #[test]
    fn set_function_changes_only_features() {
        let base = circuit();
        let edits = synthetic_polarity_edits(&base, 3, 42);
        assert_eq!(edits.len(), 3);
        assert!(edits.iter().all(|e| e.preserves_topology()));
        let edited = apply_edits(&base, &edits).unwrap();
        assert_eq!(edited.num_nodes(), base.num_nodes());
        assert_eq!(
            edited.edges_iter().collect::<Vec<_>>(),
            base.edges_iter().collect::<Vec<_>>(),
            "polarity edits must not move edges"
        );
        let changed = (0..base.num_nodes())
            .filter(|&u| base.desc(u) != edited.desc(u))
            .count();
        assert_eq!(changed, 3);
        // deterministic: same seed, same edits
        assert_eq!(edits, synthetic_polarity_edits(&base, 3, 42));
        assert_ne!(edits, synthetic_polarity_edits(&base, 3, 43));
    }

    #[test]
    fn rewire_and_bad_edits_are_validated() {
        let base = circuit();
        // a rewire: retarget one AND fanin through remove + add
        let (src, dst) = base.edges_iter().next().unwrap();
        let rewire = vec![
            GraphEdit::RemoveEdge { src, dst },
            GraphEdit::AddEdge { src, dst },
        ];
        let edited = apply_edits(&base, &rewire).unwrap();
        assert_eq!(edited.num_edges(), base.num_edges());

        // removing a non-existent edge errors with the edit index
        let err = apply_edits(&base, &[GraphEdit::RemoveEdge { src: 0, dst: 0 }])
            .unwrap_err()
            .to_string();
        assert!(err.contains("edit 0"), "{err}");

        // out-of-range SetFunction rejected
        assert!(apply_edits(
            &base,
            &[GraphEdit::SetFunction {
                node: base.num_nodes() as u32,
                kind: KIND_AND,
                inv_l: false,
                inv_r: false
            }]
        )
        .is_err());
    }

    #[test]
    fn append_cone_splices_before_po_suffix() {
        let base = circuit();
        let at = base.num_aig_nodes() as u32;
        let cone = GraphEdit::AppendCone {
            desc: vec![
                pack_desc(KIND_INPUT, false, false),
                pack_desc(KIND_AND, true, false),
            ],
            labels: vec![0, 0],
            fanins: vec![(0, 1), (at, 1)], // node 0 and cone node 0 feed cone node 1
        };
        let edited = apply_edits(&base, &[cone]).unwrap();
        assert_eq!(edited.num_nodes(), base.num_nodes() + 2);
        assert_eq!(edited.num_aig_nodes(), base.num_aig_nodes() + 2);
        assert_eq!(edited.num_edges(), base.num_edges() + 2);
        // the PO suffix kept its descriptors, shifted up by two
        for u in base.num_aig_nodes()..base.num_nodes() {
            assert_eq!(edited.desc(u + 2), base.desc(u));
        }
        assert_eq!(edited.fanins(at as usize + 1), &[0, at]);

        // forward references inside the cone are rejected
        let bad = GraphEdit::AppendCone {
            desc: vec![pack_desc(KIND_AND, false, false)],
            labels: vec![0],
            fanins: vec![(at, 0)], // cone node 0 feeding itself
        };
        assert!(apply_edits(&base, &[bad]).is_err());
    }
}

//! Incremental verification: make repeat verification cost proportional
//! to the *edit*, not the *design*.
//!
//! Production flows verify the same design repeatedly under small edits
//! (resynthesis, ECOs, local rewrites). The paper's partitioned
//! execution model makes the partition the natural cache unit: each
//! [`PlannedPartition`] carries a content digest over everything
//! inference consumes, so after an edit the partitions whose digests
//! are unchanged — including regrowth-halo effects, because the digest
//! covers the re-grown boundary's nodes and features — can stitch
//! their cached core predictions verbatim, and only the *dirty*
//! partitions go through `infer_batch`.
//!
//! The pieces:
//!
//! * [`GraphEdit`] / [`apply_edits`] (`edit`): the edit algebra applied
//!   to a compact [`CircuitGraph`].
//! * [`PredictionCache`] (`cache`): digest → core-prediction bytes,
//!   in-memory LRU with an optional persistent tier ([`PlanStore`]
//!   GPPR records, model-tagged).
//! * [`IncrementalState`]: the per-server registry of base designs
//!   (circuit + reusable k-way assignments) plus the shared prediction
//!   cache — one instance shared by every serving worker.
//! * [`execute_plan_delta`]: the delta executor — cache-stitch clean
//!   partitions, ONE `infer_batch` over dirty ones.
//!
//! Determinism contract: `Session::classify_delta` output is pinned
//! byte-identical to a from-scratch `classify` of the edited graph.
//! Cached entries are keyed by the partition content digest (core
//! count, global node list, local CSR, feature bits), so a hit implies
//! the backend would have received identical inputs and stitched to
//! identical targets; topology-preserving edit lists additionally reuse
//! the base assignment, which the deterministic partitioner would have
//! reproduced bit-for-bit anyway (asserted by tests, observable via the
//! flat `kway_invocations` counter).
//!
//! [`PlanStore`]: crate::coordinator::PlanStore

pub mod cache;
pub mod edit;

pub use cache::{model_tag_for_bytes, PredictionCache, DEFAULT_PREDICTION_CACHE_CAPACITY};
pub use edit::{apply_edits, synthetic_polarity_edits, GraphEdit};

use crate::backend::{InferenceBackend, PartitionInput};
use crate::coordinator::{ExecStats, PartitionPlan, PlanOptions, PlannedPartition};
use crate::features::GROOT_FEATURE_DIM;
use crate::graph::CircuitGraph;
use crate::obs::{self, metrics};
use crate::partition::Partitioning;
use anyhow::Result;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Partition-level outcome counters for delta execution.
struct DeltaMetrics {
    dirty: metrics::Counter,
    clean: metrics::Counter,
}

fn delta_metrics() -> &'static DeltaMetrics {
    static M: OnceLock<DeltaMetrics> = OnceLock::new();
    M.get_or_init(|| {
        let r = metrics::registry();
        const HELP: &str =
            "Partitions processed by delta execution, by state (dirty = re-inferred, \
             clean = stitched from the prediction cache).";
        DeltaMetrics {
            dirty: r.counter("groot_incremental_partitions_total", HELP, &[("state", "dirty")]),
            clean: r.counter("groot_incremental_partitions_total", HELP, &[("state", "clean")]),
        }
    })
}

/// How many base designs one state retains (each entry holds an
/// `Arc<CircuitGraph>` plus its assignments — bounded like the plan
/// cache so a long-lived daemon cannot accrete every design it ever
/// saw).
pub const DEFAULT_BASE_CAPACITY: usize = 16;

struct BaseEntry {
    fingerprint: u64,
    circuit: Arc<CircuitGraph>,
    /// Reusable k-way assignments per plan-option set (tiny: one
    /// `u32`/node each; a base rarely sees more than a couple).
    assignments: Vec<(PlanOptions, Arc<Partitioning>)>,
}

struct Inner {
    capacity: usize,
    /// LRU order: index 0 is the eviction candidate.
    bases: Mutex<Vec<BaseEntry>>,
    predictions: PredictionCache,
}

/// Shared incremental-verification state: the base-design registry and
/// the prediction cache. Cheap to clone (`Arc` inside); the serving
/// layer creates ONE and hands it to every worker's `Session` so
/// cached predictions and registered bases are visible across workers.
#[derive(Clone)]
pub struct IncrementalState {
    inner: Arc<Inner>,
}

impl Default for IncrementalState {
    fn default() -> Self {
        IncrementalState::new()
    }
}

impl IncrementalState {
    pub fn new() -> IncrementalState {
        Self::with_predictions(PredictionCache::default())
    }

    /// Build around a specific prediction cache (e.g. one with a
    /// persistent [`crate::coordinator::PlanStore`] tier).
    pub fn with_predictions(predictions: PredictionCache) -> IncrementalState {
        IncrementalState {
            inner: Arc::new(Inner {
                capacity: DEFAULT_BASE_CAPACITY,
                bases: Mutex::new(Vec::new()),
                predictions,
            }),
        }
    }

    pub fn predictions(&self) -> &PredictionCache {
        &self.inner.predictions
    }

    /// Number of registered base designs.
    pub fn num_bases(&self) -> usize {
        self.inner.bases.lock().unwrap().len()
    }

    /// Register (or refresh) a base design under its content
    /// fingerprint, evicting the least-recently-used base at capacity.
    pub fn register_base(&self, fingerprint: u64, circuit: Arc<CircuitGraph>) {
        let mut bases = self.inner.bases.lock().unwrap();
        if let Some(i) = bases.iter().position(|b| b.fingerprint == fingerprint) {
            let mut entry = bases.remove(i);
            entry.circuit = circuit;
            bases.push(entry);
            return;
        }
        if bases.len() >= self.inner.capacity {
            bases.remove(0);
        }
        bases.push(BaseEntry { fingerprint, circuit, assignments: Vec::new() });
    }

    /// The registered base circuit for a fingerprint (refreshes LRU
    /// recency — a looked-up base is about to be edited, keep it).
    pub fn base(&self, fingerprint: u64) -> Option<Arc<CircuitGraph>> {
        let mut bases = self.inner.bases.lock().unwrap();
        let i = bases.iter().position(|b| b.fingerprint == fingerprint)?;
        let entry = bases.remove(i);
        let circuit = entry.circuit.clone();
        bases.push(entry);
        Some(circuit)
    }

    /// Attach a reusable k-way assignment to a registered base.
    pub fn store_assignment(
        &self,
        fingerprint: u64,
        opts: &PlanOptions,
        partitioning: Partitioning,
    ) {
        let mut bases = self.inner.bases.lock().unwrap();
        if let Some(entry) = bases.iter_mut().find(|b| b.fingerprint == fingerprint) {
            match entry.assignments.iter_mut().find(|(o, _)| o == opts) {
                Some((_, slot)) => *slot = Arc::new(partitioning),
                None => entry.assignments.push((opts.clone(), Arc::new(partitioning))),
            }
        }
    }

    /// The stored assignment for `(base, options)`, if any.
    pub fn assignment(&self, fingerprint: u64, opts: &PlanOptions) -> Option<Arc<Partitioning>> {
        let bases = self.inner.bases.lock().unwrap();
        let entry = bases.iter().find(|b| b.fingerprint == fingerprint)?;
        entry.assignments.iter().find(|(o, _)| o == opts).map(|(_, a)| a.clone())
    }

    /// Seed the prediction cache from a freshly classified plan: each
    /// non-empty partition's core predictions, keyed by its digest.
    pub fn prime_predictions(&self, plan: &PartitionPlan, pred: &[u8]) {
        for part in plan.parts.iter().filter(|p| !p.is_empty()) {
            let core: Vec<u8> =
                part.nodes[..part.num_core].iter().map(|&g| pred[g as usize]).collect();
            self.inner.predictions.insert(part.digest, Arc::new(core));
        }
    }
}

/// Outcome of [`execute_plan_delta`].
pub struct DeltaExec {
    /// Graph-ordered predictions — byte-identical to `execute_plan` on
    /// the same plan.
    pub pred: Vec<u8>,
    pub stats: ExecStats,
    /// Non-empty partitions that went through `infer_batch`.
    pub dirty: usize,
    /// Non-empty partitions stitched from the prediction cache.
    pub clean: usize,
}

/// The delta executor: stitch cached core predictions for every
/// partition whose digest hits the cache, run ONE `infer_batch` over
/// the remaining (dirty) partitions, and stitch + cache those. The
/// output is byte-identical to `execute_plan` on the same plan: a
/// digest hit implies the backend would have received identical inputs
/// and stitched identical bytes to identical targets.
pub fn execute_plan_delta(
    backend: &dyn InferenceBackend,
    plan: &PartitionPlan,
    cache: &PredictionCache,
) -> Result<DeltaExec> {
    let classes = backend.num_classes();
    let mut pred = vec![0u8; plan.num_nodes];
    let mut dirty: Vec<&PlannedPartition> = Vec::new();
    let mut clean = 0usize;
    {
        let _span = obs::span("delta-stitch-cached", "incremental");
        for part in plan.parts.iter().filter(|p| !p.is_empty()) {
            match cache.get(part.digest) {
                // Defensive: a colliding or corrupt record with the
                // wrong shape is treated as a miss, never stitched.
                Some(core) if core.len() == part.num_core => {
                    for (i, &g) in part.nodes[..part.num_core].iter().enumerate() {
                        pred[g as usize] = core[i];
                    }
                    clean += 1;
                }
                _ => dirty.push(part),
            }
        }
    }
    delta_metrics().clean.add(clean as u64);
    delta_metrics().dirty.add(dirty.len() as u64);

    let mut stats = ExecStats { batch_size: dirty.len(), ..ExecStats::default() };
    if dirty.is_empty() {
        return Ok(DeltaExec { pred, stats, dirty: 0, clean });
    }

    let inputs: Vec<PartitionInput<'_>> = dirty
        .iter()
        .map(|p| PartitionInput {
            csr: &p.csr,
            features: &p.features,
            feature_dim: GROOT_FEATURE_DIM,
        })
        .collect();
    stats.peak_resident_bytes = inputs
        .iter()
        .map(|i| i.resident_bytes() + i.csr.num_nodes() * classes * std::mem::size_of::<f32>())
        .sum();

    let t0 = Instant::now();
    let outs = {
        let _span = obs::span_with_arg("delta-infer", "incremental", "partitions", || {
            inputs.len().to_string()
        });
        backend.infer_batch(&inputs)?
    };
    stats.infer_time = t0.elapsed();
    anyhow::ensure!(
        outs.len() == inputs.len(),
        "backend returned {} outputs for {} dirty partitions",
        outs.len(),
        inputs.len()
    );

    {
        let _span = obs::span("delta-stitch-inferred", "incremental");
        for (part, out) in dirty.iter().zip(&outs) {
            stats.peak_bucket_n = stats.peak_bucket_n.max(out.bucket_rows);
            anyhow::ensure!(
                out.logits.len() >= part.num_core * classes,
                "partition {}: {} logits < {} core nodes × {classes} classes",
                part.part_id,
                out.logits.len(),
                part.num_core,
            );
            let mut core = Vec::with_capacity(part.num_core);
            for (i, &g) in part.nodes[..part.num_core].iter().enumerate() {
                let row = &out.logits[i * classes..(i + 1) * classes];
                let cls = crate::gnn::argmax(row);
                pred[g as usize] = cls;
                core.push(cls);
            }
            cache.insert(part.digest, Arc::new(core));
        }
    }
    Ok(DeltaExec { pred, stats, dirty: dirty.len(), clean })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn circuit() -> Arc<CircuitGraph> {
        Arc::new(CircuitGraph::from_source(crate::aig::mult::csa_source(4, 64)).unwrap())
    }

    #[test]
    fn base_registry_is_lru_bounded() {
        let state = IncrementalState::new();
        let c = circuit();
        for fp in 0..(DEFAULT_BASE_CAPACITY as u64 + 4) {
            state.register_base(fp, c.clone());
        }
        assert_eq!(state.num_bases(), DEFAULT_BASE_CAPACITY);
        assert!(state.base(0).is_none(), "oldest base must be evicted");
        assert!(state.base(DEFAULT_BASE_CAPACITY as u64 + 3).is_some());
    }

    #[test]
    fn assignments_attach_to_registered_bases() {
        let state = IncrementalState::new();
        let c = circuit();
        state.register_base(7, c.clone());
        let opts = PlanOptions { partitions: 2, ..PlanOptions::default() };
        assert!(state.assignment(7, &opts).is_none());
        let partitioning =
            Partitioning { k: 2, assignment: vec![0; c.num_nodes()] };
        state.store_assignment(7, &opts, partitioning);
        let got = state.assignment(7, &opts).unwrap();
        assert_eq!(got.k, 2);
        // different options miss; unregistered fingerprints are ignored
        assert!(state
            .assignment(7, &PlanOptions { partitions: 3, ..PlanOptions::default() })
            .is_none());
        state.store_assignment(
            99,
            &opts,
            Partitioning { k: 2, assignment: vec![0; c.num_nodes()] },
        );
        assert!(state.assignment(99, &opts).is_none());
    }
}

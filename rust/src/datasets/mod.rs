//! Dataset registry — the four families the paper evaluates plus wallace
//! for ablations. Single entry point for harnesses, the CLI, and the
//! python training export.

use crate::aig::{booth::booth_multiplier, mult::csa_multiplier, wallace::wallace_multiplier};
use crate::features::{EdaGraph, EdaGraphSource};
use crate::graph::{GraphSource, ReplicateSource};
use crate::mapping::{map_cells, map_fpga};
use anyhow::{bail, Result};
use std::path::Path;

/// The paper's dataset families.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// Carry-save array multiplier (Figs 1, 6a/b, 8a/b, 10, Tab II).
    Csa,
    /// Radix-4 Booth multiplier (Figs 6c, 8c, 9).
    Booth,
    /// Wallace-tree multiplier (ablation extra).
    Wallace,
    /// Standard-cell mapped CSA — ASAP7 substitute (Figs 6d, 8d, 9).
    Mapped7nm,
    /// FPGA 4-LUT mapped CSA (Figs 7, 9).
    Fpga4Lut,
}

impl DatasetKind {
    pub fn parse(s: &str) -> Result<DatasetKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "csa" => DatasetKind::Csa,
            "booth" => DatasetKind::Booth,
            "wallace" => DatasetKind::Wallace,
            "7nm" | "mapped" | "mapped7nm" | "techmap" => DatasetKind::Mapped7nm,
            "fpga" | "fpga4lut" | "lut4" => DatasetKind::Fpga4Lut,
            other => bail!("unknown dataset '{other}' (csa|booth|wallace|7nm|fpga)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Csa => "csa",
            DatasetKind::Booth => "booth",
            DatasetKind::Wallace => "wallace",
            DatasetKind::Mapped7nm => "7nm",
            DatasetKind::Fpga4Lut => "fpga",
        }
    }

    /// Stem used for on-disk dataset files, e.g. `csa8`, `fpga64`.
    pub fn stem(&self, bits: usize) -> String {
        format!("{}{}", self.name(), bits)
    }
}

/// Build one EDA graph (features + ground-truth labels) for a dataset
/// family at a bit width.
pub fn build(kind: DatasetKind, bits: usize) -> Result<EdaGraph> {
    Ok(match kind {
        DatasetKind::Csa => EdaGraph::from_aig(&csa_multiplier(bits)),
        DatasetKind::Booth => EdaGraph::from_aig(&booth_multiplier(bits)),
        DatasetKind::Wallace => EdaGraph::from_aig(&wallace_multiplier(bits)),
        DatasetKind::Mapped7nm => map_cells(&csa_multiplier(bits))?.to_eda_graph(),
        DatasetKind::Fpga4Lut => map_fpga(&csa_multiplier(bits))?.to_eda_graph(),
    })
}

/// Streaming counterpart of [`build`]: the dataset as a chunked
/// [`GraphSource`] feeding the compact columnar
/// [`crate::graph::CircuitGraph`] — no dense-feature `EdaGraph` is
/// materialized for the AIG families. The mapped families construct
/// their (much smaller, cell-level) legacy graph and adapt it.
pub fn source(kind: DatasetKind, bits: usize, chunk: usize) -> Result<Box<dyn GraphSource>> {
    Ok(match kind {
        DatasetKind::Csa => Box::new(crate::aig::mult::csa_source(bits, chunk)),
        DatasetKind::Booth => Box::new(crate::aig::booth::booth_source(bits, chunk)),
        DatasetKind::Wallace => Box::new(crate::aig::wallace::wallace_source(bits, chunk)),
        DatasetKind::Mapped7nm => {
            Box::new(EdaGraphSource::new(map_cells(&csa_multiplier(bits))?.to_eda_graph(), chunk))
        }
        DatasetKind::Fpga4Lut => {
            Box::new(EdaGraphSource::new(map_fpga(&csa_multiplier(bits))?.to_eda_graph(), chunk))
        }
    })
}

/// [`source`] with the paper's disjoint-copy batch replication applied
/// (batch 1 passes the base source through unbuffered).
pub fn replicated_source(
    kind: DatasetKind,
    bits: usize,
    batch: usize,
    chunk: usize,
) -> Result<Box<dyn GraphSource>> {
    let base = source(kind, bits, chunk)?;
    if batch <= 1 {
        return Ok(base);
    }
    Ok(Box::new(ReplicateSource::new(base, batch, chunk)?))
}

/// Export a graph as the text triplet `python/compile/dataset.py` loads.
pub fn export_text(graph: &EdaGraph, dir: &Path, stem: &str) -> Result<()> {
    crate::aig::aiger::write_dataset_text(
        dir,
        stem,
        &graph.features,
        &graph.labels_u8(),
        &graph.edges,
    )
}

/// Build + export in one go; returns the graph for reporting.
pub fn generate(kind: DatasetKind, bits: usize, dir: &Path) -> Result<EdaGraph> {
    let g = build(kind, bits)?;
    export_text(&g, dir, &kind.stem(bits))?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kinds_build_small() {
        for kind in [
            DatasetKind::Csa,
            DatasetKind::Booth,
            DatasetKind::Wallace,
            DatasetKind::Mapped7nm,
            DatasetKind::Fpga4Lut,
        ] {
            let g = build(kind, 4).unwrap();
            g.check().unwrap();
            assert!(g.num_nodes > 10, "{kind:?}");
            assert!(g.num_edges() > 10, "{kind:?}");
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for (s, k) in [
            ("csa", DatasetKind::Csa),
            ("booth", DatasetKind::Booth),
            ("7nm", DatasetKind::Mapped7nm),
            ("fpga", DatasetKind::Fpga4Lut),
        ] {
            assert_eq!(DatasetKind::parse(s).unwrap(), k);
        }
        assert!(DatasetKind::parse("nope").is_err());
    }

    #[test]
    fn export_and_shape() {
        let dir = std::env::temp_dir().join("groot_ds_test");
        let g = generate(DatasetKind::Csa, 3, &dir).unwrap();
        let stem = DatasetKind::Csa.stem(3);
        for ext in ["features", "labels", "edges"] {
            let p = dir.join(format!("{stem}.{ext}.txt"));
            assert!(p.exists(), "{}", p.display());
        }
        let lines = std::fs::read_to_string(dir.join(format!("{stem}.labels.txt"))).unwrap();
        assert_eq!(lines.lines().count(), g.num_nodes);
    }
}

//! Span tracer with Chrome trace-event JSON output (Perfetto-loadable).
//!
//! A span is an RAII guard: [`span`] stamps a monotonic start, the drop
//! stamps the duration and appends one complete ("ph":"X") event to a
//! process-global buffer. Thread ids are assigned lazily per OS thread
//! and carried on every event, so per-partition inference spans from
//! pooled lanes land on their own Perfetto tracks.
//!
//! Cost model: when tracing is disabled (the default), [`span`] is one
//! relaxed atomic load and the guard holds `None` — no clock read, no
//! allocation, no lock. When enabled, each span costs two `Instant`
//! reads and one short mutex push at drop. Tracing therefore NEVER
//! changes what the pipeline computes — predictions are byte-identical
//! either way (pinned by rust/tests/observability.rs).
//!
//! Enable with `GROOT_TRACE=out.json` (the CLI flushes on exit) or
//! programmatically with [`enable`] + [`write_chrome_trace`]. Load the
//! file at <https://ui.perfetto.dev> or `chrome://tracing`.

use std::borrow::Cow;
use std::cell::Cell;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Hard cap on buffered events: a long-running daemon with tracing left
/// on must not grow without bound. Events beyond the cap are counted and
/// dropped (the count is reported in the trace metadata).
const MAX_EVENTS: usize = 1 << 20;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// One completed span.
#[derive(Clone, Debug)]
pub struct Event {
    pub name: Cow<'static, str>,
    pub cat: &'static str,
    /// Microseconds since the process trace epoch.
    pub ts_us: f64,
    pub dur_us: f64,
    pub tid: u64,
    pub arg: Option<(&'static str, String)>,
}

struct Collector {
    events: Mutex<Vec<Event>>,
    /// (tid, thread name) pairs, recorded once per OS thread.
    threads: Mutex<Vec<(u64, String)>>,
    dropped: AtomicU64,
}

fn collector() -> &'static Collector {
    static C: OnceLock<Collector> = OnceLock::new();
    C.get_or_init(|| Collector {
        events: Mutex::new(Vec::new()),
        threads: Mutex::new(Vec::new()),
        dropped: AtomicU64::new(0),
    })
}

fn epoch() -> Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    *E.get_or_init(Instant::now)
}

thread_local! {
    static TID: Cell<Option<u64>> = const { Cell::new(None) };
}

fn current_tid() -> u64 {
    TID.with(|t| {
        if let Some(id) = t.get() {
            return id;
        }
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let id = NEXT.fetch_add(1, Ordering::Relaxed);
        t.set(Some(id));
        let name = std::thread::current()
            .name()
            .map(|n| n.to_string())
            .unwrap_or_else(|| format!("thread-{id}"));
        collector().threads.lock().unwrap().push((id, name));
        id
    })
}

/// Is tracing currently on? One relaxed load — THE fast-path check.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on (idempotent). Also pins the trace epoch so the first
/// span does not pay the `OnceLock` init inside a measured region.
pub fn enable() {
    let _ = epoch();
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn tracing off; already-buffered events stay until drained.
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// The `GROOT_TRACE` output path, when set to a non-empty value.
pub fn env_trace_path() -> Option<PathBuf> {
    match std::env::var("GROOT_TRACE") {
        Ok(v) if !v.trim().is_empty() => Some(PathBuf::from(v)),
        _ => None,
    }
}

/// Enable tracing if `GROOT_TRACE` names an output file; returns whether
/// it did. The CLI calls this once at startup and
/// [`flush_env_trace`] at exit.
pub fn init_from_env() -> bool {
    if env_trace_path().is_some() {
        enable();
        true
    } else {
        false
    }
}

/// Write the buffered trace to the `GROOT_TRACE` path, if configured and
/// tracing was enabled. Returns the number of events written.
pub fn flush_env_trace() -> std::io::Result<usize> {
    match env_trace_path() {
        Some(path) if enabled() || !collector().events.lock().unwrap().is_empty() => {
            write_chrome_trace(&path)
        }
        _ => Ok(0),
    }
}

/// RAII span: records one complete event on drop. Construct via
/// [`span`] / [`span_with_arg`] — holds `None` (a no-op) when tracing is
/// disabled.
pub struct SpanGuard(Option<SpanData>);

struct SpanData {
    name: Cow<'static, str>,
    cat: &'static str,
    start: Instant,
    arg: Option<(&'static str, String)>,
}

/// Open a span named `name` in category `cat` (e.g. "pipeline",
/// "kernel", "net"). Near-zero cost when tracing is disabled.
#[inline]
pub fn span(name: &'static str, cat: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard(None);
    }
    SpanGuard(Some(SpanData { name: Cow::Borrowed(name), cat, start: Instant::now(), arg: None }))
}

/// [`span`] carrying one key/value argument (request ids, partition
/// indices). The value is only materialized when tracing is on: pass it
/// through the closure so disabled paths never allocate.
#[inline]
pub fn span_with_arg(
    name: &'static str,
    cat: &'static str,
    key: &'static str,
    value: impl FnOnce() -> String,
) -> SpanGuard {
    if !enabled() {
        return SpanGuard(None);
    }
    SpanGuard(Some(SpanData {
        name: Cow::Borrowed(name),
        cat,
        start: Instant::now(),
        arg: Some((key, value())),
    }))
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(data) = self.0.take() else { return };
        let dur = data.start.elapsed();
        let ts = data.start.duration_since(epoch());
        let c = collector();
        let mut events = c.events.lock().unwrap();
        if events.len() >= MAX_EVENTS {
            c.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        events.push(Event {
            name: data.name,
            cat: data.cat,
            ts_us: ts.as_secs_f64() * 1e6,
            dur_us: dur.as_secs_f64() * 1e6,
            tid: current_tid(),
            arg: data.arg,
        });
    }
}

/// Number of events currently buffered (tests/diagnostics).
pub fn buffered_events() -> usize {
    collector().events.lock().unwrap().len()
}

/// Drain the buffer and render Chrome trace-event JSON (the
/// `{"traceEvents": […]}` object form both Perfetto and chrome://tracing
/// accept). Thread-name metadata events precede the spans.
pub fn render_chrome_trace() -> String {
    let c = collector();
    let events: Vec<Event> = std::mem::take(&mut *c.events.lock().unwrap());
    let threads: Vec<(u64, String)> = c.threads.lock().unwrap().clone();
    let dropped = c.dropped.swap(0, Ordering::Relaxed);
    let mut entries = Vec::with_capacity(events.len() + threads.len());
    for (tid, name) in &threads {
        entries.push(format!(
            "{{\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\"name\":\"thread_name\",\
             \"args\":{{\"name\":{}}}}}",
            super::metrics_json_string(name)
        ));
    }
    for e in &events {
        let args = match &e.arg {
            Some((k, v)) => format!(
                ",\"args\":{{{}:{}}}",
                super::metrics_json_string(k),
                super::metrics_json_string(v)
            ),
            None => String::new(),
        };
        entries.push(format!(
            "{{\"ph\":\"X\",\"pid\":1,\"tid\":{},\"name\":{},\"cat\":\"{}\",\
             \"ts\":{:.3},\"dur\":{:.3}{args}}}",
            e.tid,
            super::metrics_json_string(&e.name),
            e.cat,
            e.ts_us,
            e.dur_us
        ));
    }
    format!(
        "{{\"traceEvents\":[\n{}\n],\"displayTimeUnit\":\"ms\",\
         \"otherData\":{{\"droppedEvents\":\"{dropped}\"}}}}\n",
        entries.join(",\n")
    )
}

/// Drain the buffer into a Chrome trace JSON file. Returns the number of
/// span events written.
pub fn write_chrome_trace(path: &Path) -> std::io::Result<usize> {
    let n = buffered_events();
    std::fs::write(path, render_chrome_trace())?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tracing state is process-global; these tests flip it, so they
    /// serialize on one lock instead of racing each other.
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _g = test_lock();
        disable();
        let before = buffered_events();
        {
            let _s = span("noop", "test");
        }
        assert_eq!(buffered_events(), before);
    }

    #[test]
    fn enabled_span_lands_in_the_buffer_with_nesting() {
        let _g = test_lock();
        enable();
        {
            let _outer = span("outer_span_xyz", "test");
            std::thread::sleep(std::time::Duration::from_millis(1));
            let _inner = span_with_arg("inner_span_xyz", "test", "id", || "42".to_string());
        }
        disable();
        let json = render_chrome_trace();
        assert!(json.contains("\"outer_span_xyz\""));
        assert!(json.contains("\"inner_span_xyz\""));
        assert!(json.contains("\"id\":\"42\""));
        assert!(json.contains("\"traceEvents\""));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        // drained: a second render has no spans left
        assert!(!render_chrome_trace().contains("outer_span_xyz"));
    }

    #[test]
    fn span_value_closure_not_called_when_disabled() {
        let _g = test_lock();
        disable();
        let mut called = false;
        {
            let _s = span_with_arg("lazy", "test", "k", || {
                called = true;
                String::new()
            });
        }
        assert!(!called, "arg closure must not run while tracing is off");
    }
}

//! Process-wide metrics registry with Prometheus text exposition.
//!
//! Registration (name + help + labels) takes a mutex once and hands back
//! a clonable handle wrapping an `Arc`'d atomic; every subsequent update
//! is a single relaxed atomic op — hot paths (SpMM kernels, the pool's
//! steal loop, the daemon's request path) cache their handle in a
//! `OnceLock` and never touch the registry lock again. Registering the
//! same (name, labels) twice returns the same underlying metric, so
//! independent call sites can share a counter without coordination.
//!
//! Exposition is deterministic: families sort by name, series by label
//! set — byte-stable output for tests and CI `grep`s. The
//! [`parse_prometheus`] round-trip parser exists for exactly those
//! consumers.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotonically increasing event count.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    /// Overwrite the count — for mirroring a counter whose source of
    /// truth lives elsewhere (e.g. a pre-existing atomic that tests pin).
    pub fn store(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed level (queue depths, live jobs).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn sub(&self, n: i64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket histogram (Prometheus semantics: cumulative `le`
/// buckets + `_sum` + `_count`). Bucket bounds are fixed at
/// registration; `observe` is a linear scan over a handful of bounds
/// plus three relaxed atomic ops.
#[derive(Clone)]
pub struct Histogram(Arc<HistogramCore>);

struct HistogramCore {
    /// Upper bounds, ascending; an implicit +Inf bucket follows.
    bounds: Vec<f64>,
    /// Per-bucket (NON-cumulative) counts; len = bounds.len() + 1.
    counts: Vec<AtomicU64>,
    /// f64 bits, updated by CAS (no atomic f64 in std).
    sum_bits: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn observe(&self, v: f64) {
        let core = &self.0;
        let idx = core
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(core.bounds.len());
        core.counts[idx].fetch_add(1, Ordering::Relaxed);
        core.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = core.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match core.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// Quantile estimate by linear interpolation inside the bucket that
    /// crosses rank `q·count` — the standard Prometheus
    /// `histogram_quantile` approximation, here for in-process reports.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = q.clamp(0.0, 1.0) * total as f64;
        let mut seen = 0u64;
        let mut lower = 0.0f64;
        for (i, c) in self.0.counts.iter().enumerate() {
            let c = c.load(Ordering::Relaxed);
            let upper = self
                .0
                .bounds
                .get(i)
                .copied()
                .unwrap_or(f64::INFINITY);
            if (seen + c) as f64 >= rank {
                if upper.is_infinite() {
                    return lower; // best effort beyond the last bound
                }
                let within = if c == 0 { 0.0 } else { (rank - seen as f64) / c as f64 };
                return lower + (upper - lower) * within;
            }
            seen += c;
            lower = upper;
        }
        lower
    }
}

/// Request/latency bucket ladder in seconds: 0.5 ms … 10 s.
pub const LATENCY_BUCKETS: &[f64] = &[
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
];

/// Kernel-time bucket ladder in seconds: 10 µs … 250 ms.
pub const KERNEL_BUCKETS: &[f64] = &[
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.25,
];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

enum Handle {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicI64>),
    Histogram(Arc<HistogramCore>),
}

struct Family {
    help: &'static str,
    kind: Kind,
    /// Keyed by the rendered label string (sorted keys) so series order
    /// is deterministic.
    series: BTreeMap<String, Handle>,
}

/// Exposition format negotiated over the wire and on the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricsFormat {
    Prometheus,
    Json,
}

impl MetricsFormat {
    pub fn as_u8(self) -> u8 {
        match self {
            MetricsFormat::Prometheus => 0,
            MetricsFormat::Json => 1,
        }
    }
    pub fn from_u8(v: u8) -> Option<MetricsFormat> {
        match v {
            0 => Some(MetricsFormat::Prometheus),
            1 => Some(MetricsFormat::Json),
            _ => None,
        }
    }
}

/// One flattened sample — the unit both [`Registry::samples`] and
/// [`parse_prometheus`] speak, so render→parse round-trips structurally.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    /// Label lookup helper for tests and reports.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// A metric registry. Most code uses the process-global [`registry`];
/// tests build private ones.
pub struct Registry {
    inner: Mutex<BTreeMap<String, Family>>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry { inner: Mutex::new(BTreeMap::new()) }
    }

    /// Get-or-register a counter series.
    pub fn counter(&self, name: &str, help: &'static str, labels: &[(&str, &str)]) -> Counter {
        let key = render_labels(labels);
        let mut inner = self.inner.lock().unwrap();
        let fam = inner.entry(name.to_string()).or_insert_with(|| Family {
            help,
            kind: Kind::Counter,
            series: BTreeMap::new(),
        });
        debug_assert_eq!(fam.kind, Kind::Counter, "metric {name} re-registered as a counter");
        let handle = fam
            .series
            .entry(key)
            .or_insert_with(|| Handle::Counter(Arc::new(AtomicU64::new(0))));
        match handle {
            Handle::Counter(a) => Counter(Arc::clone(a)),
            _ => unreachable!("kind checked above"),
        }
    }

    /// Get-or-register a gauge series.
    pub fn gauge(&self, name: &str, help: &'static str, labels: &[(&str, &str)]) -> Gauge {
        let key = render_labels(labels);
        let mut inner = self.inner.lock().unwrap();
        let fam = inner.entry(name.to_string()).or_insert_with(|| Family {
            help,
            kind: Kind::Gauge,
            series: BTreeMap::new(),
        });
        debug_assert_eq!(fam.kind, Kind::Gauge, "metric {name} re-registered as a gauge");
        let handle = fam
            .series
            .entry(key)
            .or_insert_with(|| Handle::Gauge(Arc::new(AtomicI64::new(0))));
        match handle {
            Handle::Gauge(a) => Gauge(Arc::clone(a)),
            _ => unreachable!("kind checked above"),
        }
    }

    /// Get-or-register a histogram series with the given bucket bounds
    /// (ascending; +Inf is implicit). Bounds are fixed by the FIRST
    /// registration of the series.
    pub fn histogram(
        &self,
        name: &str,
        help: &'static str,
        labels: &[(&str, &str)],
        bounds: &[f64],
    ) -> Histogram {
        let key = render_labels(labels);
        let mut inner = self.inner.lock().unwrap();
        let fam = inner.entry(name.to_string()).or_insert_with(|| Family {
            help,
            kind: Kind::Histogram,
            series: BTreeMap::new(),
        });
        debug_assert_eq!(fam.kind, Kind::Histogram, "metric {name} re-registered as a histogram");
        let handle = fam.series.entry(key).or_insert_with(|| {
            Handle::Histogram(Arc::new(HistogramCore {
                bounds: bounds.to_vec(),
                counts: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
                sum_bits: AtomicU64::new(0.0f64.to_bits()),
                count: AtomicU64::new(0),
            }))
        });
        match handle {
            Handle::Histogram(a) => Histogram(Arc::clone(a)),
            _ => unreachable!("kind checked above"),
        }
    }

    /// Flatten every series to samples (histograms expand to cumulative
    /// `_bucket` samples plus `_sum`/`_count`) — the profile report and
    /// the JSON renderer both consume this.
    pub fn samples(&self) -> Vec<Sample> {
        let inner = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for (name, fam) in inner.iter() {
            for (labelstr, handle) in &fam.series {
                let labels = parse_labels(labelstr);
                match handle {
                    Handle::Counter(a) => out.push(Sample {
                        name: name.clone(),
                        labels,
                        value: a.load(Ordering::Relaxed) as f64,
                    }),
                    Handle::Gauge(a) => out.push(Sample {
                        name: name.clone(),
                        labels,
                        value: a.load(Ordering::Relaxed) as f64,
                    }),
                    Handle::Histogram(core) => {
                        let mut cum = 0u64;
                        for (i, c) in core.counts.iter().enumerate() {
                            cum += c.load(Ordering::Relaxed);
                            let le = core
                                .bounds
                                .get(i)
                                .map(|b| format_f64(*b))
                                .unwrap_or_else(|| "+Inf".to_string());
                            let mut bl = labels.clone();
                            bl.push(("le".to_string(), le));
                            out.push(Sample {
                                name: format!("{name}_bucket"),
                                labels: bl,
                                value: cum as f64,
                            });
                        }
                        out.push(Sample {
                            name: format!("{name}_sum"),
                            labels: labels.clone(),
                            value: f64::from_bits(core.sum_bits.load(Ordering::Relaxed)),
                        });
                        out.push(Sample {
                            name: format!("{name}_count"),
                            labels,
                            value: core.count.load(Ordering::Relaxed) as f64,
                        });
                    }
                }
            }
        }
        out
    }

    pub fn render(&self, format: MetricsFormat) -> String {
        match format {
            MetricsFormat::Prometheus => self.render_prometheus(),
            MetricsFormat::Json => self.render_json(),
        }
    }

    /// Prometheus text exposition format 0.0.4.
    pub fn render_prometheus(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        for (name, fam) in inner.iter() {
            out.push_str(&format!("# HELP {name} {}\n", fam.help));
            out.push_str(&format!("# TYPE {name} {}\n", fam.kind.as_str()));
            for (labelstr, handle) in &fam.series {
                match handle {
                    Handle::Counter(a) => {
                        out.push_str(&format!("{name}{labelstr} {}\n", a.load(Ordering::Relaxed)));
                    }
                    Handle::Gauge(a) => {
                        out.push_str(&format!("{name}{labelstr} {}\n", a.load(Ordering::Relaxed)));
                    }
                    Handle::Histogram(core) => {
                        let mut cum = 0u64;
                        for (i, c) in core.counts.iter().enumerate() {
                            cum += c.load(Ordering::Relaxed);
                            let le = core
                                .bounds
                                .get(i)
                                .map(|b| format_f64(*b))
                                .unwrap_or_else(|| "+Inf".to_string());
                            out.push_str(&format!(
                                "{name}_bucket{} {cum}\n",
                                merge_label(labelstr, "le", &le)
                            ));
                        }
                        out.push_str(&format!(
                            "{name}_sum{labelstr} {}\n",
                            format_f64(f64::from_bits(core.sum_bits.load(Ordering::Relaxed)))
                        ));
                        out.push_str(&format!(
                            "{name}_count{labelstr} {}\n",
                            core.count.load(Ordering::Relaxed)
                        ));
                    }
                }
            }
        }
        out
    }

    /// JSON exposition for scripting (`--json`): one object per series.
    pub fn render_json(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut entries = Vec::new();
        for (name, fam) in inner.iter() {
            for (labelstr, handle) in &fam.series {
                let labels_json = labels_to_json(&parse_labels(labelstr));
                match handle {
                    Handle::Counter(a) => entries.push(format!(
                        "{{\"name\":{},\"type\":\"counter\",\"labels\":{},\"value\":{}}}",
                        json_string(name),
                        labels_json,
                        a.load(Ordering::Relaxed)
                    )),
                    Handle::Gauge(a) => entries.push(format!(
                        "{{\"name\":{},\"type\":\"gauge\",\"labels\":{},\"value\":{}}}",
                        json_string(name),
                        labels_json,
                        a.load(Ordering::Relaxed)
                    )),
                    Handle::Histogram(core) => {
                        let mut buckets = Vec::new();
                        let mut cum = 0u64;
                        for (i, c) in core.counts.iter().enumerate() {
                            cum += c.load(Ordering::Relaxed);
                            let le = core
                                .bounds
                                .get(i)
                                .map(|b| format_f64(*b))
                                .unwrap_or_else(|| "Infinity".to_string());
                            buckets.push(format!("{{\"le\":\"{le}\",\"count\":{cum}}}"));
                        }
                        entries.push(format!(
                            "{{\"name\":{},\"type\":\"histogram\",\"labels\":{},\"count\":{},\"sum\":{},\"buckets\":[{}]}}",
                            json_string(name),
                            labels_json,
                            core.count.load(Ordering::Relaxed),
                            format_f64(f64::from_bits(core.sum_bits.load(Ordering::Relaxed))),
                            buckets.join(",")
                        ));
                    }
                }
            }
        }
        format!("{{\"metrics\":[\n{}\n]}}\n", entries.join(",\n"))
    }
}

/// The process-global registry every runtime layer reports into.
pub fn registry() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// f64 formatting that round-trips and never produces exponent notation
/// surprises for bucket bounds (Rust's shortest-round-trip Display).
fn format_f64(v: f64) -> String {
    if v.is_infinite() {
        return if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() };
    }
    v.to_string()
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn unescape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    let mut chars = v.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some(other) => out.push(other),
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

/// Render labels as `{k="v",…}` with sorted keys ("" when empty).
fn render_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut sorted: Vec<_> = labels.to_vec();
    sorted.sort();
    let body: Vec<String> = sorted
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    format!("{{{}}}", body.join(","))
}

/// Inverse of [`render_labels`] for a single rendered label string.
fn parse_labels(labelstr: &str) -> Vec<(String, String)> {
    if labelstr.is_empty() {
        return Vec::new();
    }
    let inner = labelstr.trim_start_matches('{').trim_end_matches('}');
    split_label_body(inner)
}

/// Insert one more label pair into a rendered label string.
fn merge_label(labelstr: &str, key: &str, value: &str) -> String {
    let extra = format!("{key}=\"{}\"", escape_label(value));
    if labelstr.is_empty() {
        format!("{{{extra}}}")
    } else {
        format!("{},{extra}}}", labelstr.trim_end_matches('}'))
    }
}

fn labels_to_json(labels: &[(String, String)]) -> String {
    let body: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{}:{}", json_string(k), json_string(v)))
        .collect();
    format!("{{{}}}", body.join(","))
}

pub(crate) fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Split `k="v",k2="v2"` respecting escaped quotes inside values.
fn split_label_body(body: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    let mut rest = body;
    while !rest.is_empty() {
        let eq = match rest.find('=') {
            Some(i) => i,
            None => break,
        };
        let key = rest[..eq].trim().to_string();
        let after = &rest[eq + 1..];
        if !after.starts_with('"') {
            break;
        }
        // find the closing unescaped quote
        let bytes = after.as_bytes();
        let mut i = 1;
        while i < bytes.len() {
            if bytes[i] == b'\\' {
                i += 2;
                continue;
            }
            if bytes[i] == b'"' {
                break;
            }
            i += 1;
        }
        let val = unescape_label(&after[1..i.min(after.len())]);
        out.push((key, val));
        rest = after[(i + 1).min(after.len())..].trim_start_matches(',');
    }
    out
}

/// Parse Prometheus text exposition back into flat [`Sample`]s —
/// comment/`# TYPE`/`# HELP` lines are skipped. Used by the round-trip
/// tests and by `groot metrics` consumers that want structured access.
pub fn parse_prometheus(text: &str) -> Result<Vec<Sample>, String> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        // name[{labels}] value
        let (name_part, value_part) = match line.rfind(' ') {
            Some(i) => (&line[..i], &line[i + 1..]),
            None => return Err(format!("line {}: no value: {line}", lineno + 1)),
        };
        let (name, labels) = match name_part.find('{') {
            Some(b) => {
                if !name_part.ends_with('}') {
                    return Err(format!("line {}: unterminated labels: {line}", lineno + 1));
                }
                (
                    name_part[..b].to_string(),
                    split_label_body(&name_part[b + 1..name_part.len() - 1]),
                )
            }
            None => (name_part.to_string(), Vec::new()),
        };
        if name.is_empty()
            || !name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        {
            return Err(format!("line {}: bad metric name '{name}'", lineno + 1));
        }
        let value = match value_part {
            "+Inf" => f64::INFINITY,
            "-Inf" => f64::NEG_INFINITY,
            v => v
                .parse::<f64>()
                .map_err(|e| format!("line {}: bad value '{v}': {e}", lineno + 1))?,
        };
        out.push(Sample { name, labels, value });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_round_trip() {
        let reg = Registry::new();
        let c = reg.counter("test_requests_total", "requests", &[("kind", "a")]);
        c.inc();
        c.add(4);
        let g = reg.gauge("test_depth", "depth", &[]);
        g.set(7);
        g.sub(2);
        let text = reg.render_prometheus();
        let samples = parse_prometheus(&text).unwrap();
        let c_s = samples.iter().find(|s| s.name == "test_requests_total").unwrap();
        assert_eq!(c_s.value, 5.0);
        assert_eq!(c_s.label("kind"), Some("a"));
        let g_s = samples.iter().find(|s| s.name == "test_depth").unwrap();
        assert_eq!(g_s.value, 5.0);
    }

    #[test]
    fn same_series_shares_one_atomic() {
        let reg = Registry::new();
        let a = reg.counter("shared_total", "x", &[("l", "v")]);
        let b = reg.counter("shared_total", "x", &[("l", "v")]);
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2);
        // different labels → independent series
        let c = reg.counter("shared_total", "x", &[("l", "w")]);
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn histogram_buckets_are_cumulative_with_inf() {
        let reg = Registry::new();
        let h = reg.histogram("test_lat_seconds", "latency", &[], &[0.01, 0.1, 1.0]);
        for v in [0.005, 0.05, 0.5, 5.0, 0.05] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 5.61).abs() < 1e-9);
        let text = reg.render_prometheus();
        let samples = parse_prometheus(&text).unwrap();
        let bucket = |le: &str| {
            samples
                .iter()
                .find(|s| s.name == "test_lat_seconds_bucket" && s.label("le") == Some(le))
                .unwrap()
                .value
        };
        assert_eq!(bucket("0.01"), 1.0);
        assert_eq!(bucket("0.1"), 3.0);
        assert_eq!(bucket("1"), 4.0);
        assert_eq!(bucket("+Inf"), 5.0);
        let count = samples.iter().find(|s| s.name == "test_lat_seconds_count").unwrap();
        assert_eq!(count.value, 5.0);
    }

    #[test]
    fn histogram_quantile_interpolates() {
        let reg = Registry::new();
        let h = reg.histogram("q_seconds", "q", &[], &[0.1, 0.2, 0.4, 0.8]);
        for _ in 0..100 {
            h.observe(0.15); // all in the (0.1, 0.2] bucket
        }
        let p50 = h.quantile(0.5);
        assert!(p50 > 0.1 && p50 <= 0.2, "p50 {p50}");
        assert_eq!(Histogram::quantile(&reg.histogram("empty", "e", &[], &[1.0]), 0.9), 0.0);
    }

    #[test]
    fn exposition_is_deterministic_and_typed() {
        let reg = Registry::new();
        reg.counter("b_total", "bees", &[]).inc();
        reg.gauge("a_depth", "ays", &[("w", "1")]).set(3);
        let t1 = reg.render_prometheus();
        let t2 = reg.render_prometheus();
        assert_eq!(t1, t2);
        // families sorted by name; HELP/TYPE precede samples
        let a_pos = t1.find("# TYPE a_depth gauge").unwrap();
        let b_pos = t1.find("# TYPE b_total counter").unwrap();
        assert!(a_pos < b_pos);
    }

    #[test]
    fn label_escaping_round_trips() {
        let reg = Registry::new();
        reg.counter("esc_total", "e", &[("path", "a\"b\\c\nd")]).inc();
        let samples = parse_prometheus(&reg.render_prometheus()).unwrap();
        assert_eq!(samples[0].label("path"), Some("a\"b\\c\nd"));
    }

    #[test]
    fn json_render_is_balanced_and_mentions_series() {
        let reg = Registry::new();
        reg.counter("j_total", "j", &[("k", "v")]).add(2);
        reg.histogram("j_seconds", "js", &[], &[0.5]).observe(0.1);
        let js = reg.render_json();
        assert_eq!(js.matches('{').count(), js.matches('}').count());
        assert_eq!(js.matches('[').count(), js.matches(']').count());
        assert!(js.contains("\"j_total\""));
        assert!(js.contains("\"buckets\""));
    }

    #[test]
    fn parser_rejects_garbage() {
        assert!(parse_prometheus("no_value_here").is_err());
        assert!(parse_prometheus("bad name 1.0").is_err());
        assert!(parse_prometheus("ok_total 1.0").is_ok());
    }
}

//! Observability — the measurement substrate under the serving runtime.
//!
//! Three zero-dependency pieces, all std-only and safe to leave enabled
//! in production paths:
//!
//! * [`metrics`] — a process-wide registry of lock-free counters, gauges
//!   and fixed-bucket histograms keyed by name + labels, rendered as
//!   Prometheus text exposition (served by the daemon's `REQ_METRICS`
//!   wire frame and the `groot metrics` CLI) or JSON.
//! * [`trace`] — a low-overhead span tracer: thread-local thread ids,
//!   monotonic clocks, one relaxed atomic load when disabled. Spans from
//!   the full classify path (prepare → partition → regrowth → gather →
//!   per-partition infer → stitch) plus daemon request spans land in a
//!   Chrome trace-event JSON file loadable in Perfetto
//!   (`GROOT_TRACE=out.json` or `--trace out.json`).
//! * [`log`] — a `GROOT_LOG`-gated leveled logger (error/warn/info/
//!   debug) for the daemon, server and plan store, replacing ad-hoc
//!   stderr prints.
//!
//! Everything here is **behavior-neutral**: predictions are byte-
//! identical with tracing/metrics on or off (pinned by
//! rust/tests/observability.rs) — observation reads clocks and bumps
//! atomics, never data.

pub mod log;
pub mod metrics;
pub mod trace;

pub use metrics::{registry, MetricsFormat, Registry};
pub use trace::{span, span_with_arg, SpanGuard};

pub(crate) use metrics::json_string as metrics_json_string;

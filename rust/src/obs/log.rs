//! `GROOT_LOG`-gated leveled logger for the serving runtime.
//!
//! Levels: `off < error < warn < info < debug`, parsed once from the
//! `GROOT_LOG` environment variable (default **warn** — operational
//! anomalies like plan-store quarantines and slow requests surface
//! without opting in, routine chatter does not). [`set_level`]
//! overrides at run time (tests, future CLI flags).
//!
//! The check is one relaxed atomic load; formatting only happens for
//! enabled records (call sites pass `format_args!`, which is lazy until
//! rendered). Output goes to stderr as one line per record:
//! `groot[warn] net::daemon: slow request …`.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

impl Level {
    fn as_str(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" | "0" => Some(Level::Off),
            "error" | "1" => Some(Level::Error),
            "warn" | "warning" | "2" => Some(Level::Warn),
            "info" | "3" => Some(Level::Info),
            "debug" | "4" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// Sentinel meaning "not initialized yet — read GROOT_LOG on first use".
const UNINIT: u8 = u8::MAX;

static LEVEL: AtomicU8 = AtomicU8::new(UNINIT);

fn level_from_u8(v: u8) -> Level {
    match v {
        0 => Level::Off,
        1 => Level::Error,
        2 => Level::Warn,
        3 => Level::Info,
        _ => Level::Debug,
    }
}

/// The active maximum level (records above it are dropped).
pub fn max_level() -> Level {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != UNINIT {
        return level_from_u8(v);
    }
    let parsed = std::env::var("GROOT_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Warn);
    // A racing first use parses the same env — last store wins, same value.
    LEVEL.store(parsed as u8, Ordering::Relaxed);
    parsed
}

/// Override the level at run time (wins over `GROOT_LOG`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Would a record at `level` be emitted?
#[inline]
pub fn enabled(level: Level) -> bool {
    level <= max_level() && level != Level::Off
}

/// Emit one record. `target` names the subsystem (`net::daemon`,
/// `coordinator::planstore`, …).
pub fn log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    eprintln!("groot[{}] {target}: {args}", level.as_str());
}

pub fn error(target: &str, args: fmt::Arguments<'_>) {
    log(Level::Error, target, args);
}

pub fn warn(target: &str, args: fmt::Arguments<'_>) {
    log(Level::Warn, target, args);
}

pub fn info(target: &str, args: fmt::Arguments<'_>) {
    log(Level::Info, target, args);
}

pub fn debug(target: &str, args: fmt::Arguments<'_>) {
    log(Level::Debug, target, args);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_gates_records() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Off);
        assert!(!enabled(Level::Error));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        // restore the default so other tests see warn-level behavior
        set_level(Level::Warn);
    }

    #[test]
    fn parse_accepts_names_and_numbers() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("2"), Some(Level::Warn));
        assert_eq!(Level::parse("off"), Some(Level::Off));
        assert_eq!(Level::parse("banana"), None);
    }
}

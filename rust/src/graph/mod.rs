//! CSR graph structures and degree profiling.
//!
//! The GNN aggregates over the *symmetric closure* of the EDA graph (the
//! paper's re-grown partitions support message passing in both directions),
//! so [`Csr::symmetric_from_edges`] is the canonical adjacency used by the
//! SpMM engines, the partitioner, and the runtime packers.
//!
//! [`DegreeProfile`] reproduces the §IV observation GROOT's kernels are
//! built on: EDA graphs have a polarized degree distribution — a sea of
//! low-degree nodes (AIG fanin ≤ 2) plus a few extremely high-degree
//! macro rows.

pub mod csr;
pub mod profile;

pub use csr::Csr;
pub use profile::DegreeProfile;

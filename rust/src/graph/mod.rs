//! CSR graph structures and degree profiling.
//!
//! The GNN aggregates over the *symmetric closure* of the EDA graph (the
//! paper's re-grown partitions support message passing in both directions),
//! so [`Csr::symmetric_from_edges`] is the canonical adjacency used by the
//! SpMM engines, the partitioner, and the runtime packers.
//!
//! [`DegreeProfile`] reproduces the §IV observation GROOT's kernels are
//! built on: EDA graphs have a polarized degree distribution — a sea of
//! low-degree nodes (AIG fanin ≤ 2) plus a few extremely high-degree
//! macro rows.

//! [`CircuitGraph`] is the compact columnar circuit store (packed
//! descriptor bytes + flat CSR edge arrays) that [`GraphSource`]
//! streaming ingestion produces — the paper-scale replacement for the
//! dense-feature `EdaGraph` layout.

pub mod circuit;
pub mod csr;
pub mod profile;
pub mod source;

pub use circuit::CircuitGraph;
pub use csr::Csr;
pub use profile::DegreeProfile;
pub use source::{GraphSource, NodeChunk, ReplicateSource, DEFAULT_CHUNK_NODES};

//! Streaming graph ingestion — the `GraphSource` seam.
//!
//! Every circuit producer (the AIG generators, the AIGER reader, the
//! legacy `EdaGraph` adapter) emits the graph as a sequence of bounded
//! [`NodeChunk`]s instead of handing over one monolithic object, and
//! [`super::CircuitGraph::from_source`] folds the chunks into the compact
//! columnar store. Ingestion peak memory is therefore
//! `columnar store + one chunk`, never `producer + dense features +
//! tuple edge list` all at once — the graph-construction-as-API framing
//! the Verilog-to-PyG line of work argues for (PAPERS.md).
//!
//! Chunk contract (validated by `from_source`):
//! * chunks cover node ids contiguously from 0;
//! * `edges` are fanin edges `(src, dst)` whose `dst` lies in the chunk,
//!   in non-decreasing `dst` order (sources may reference any node id);
//! * `desc`/`labels` are the packed descriptor and class columns for the
//!   chunk's nodes (see [`super::circuit`]).

use super::circuit::CircuitGraph;
use anyhow::Result;

/// Default nodes-per-chunk for the in-crate sources: small enough that a
/// chunk is noise next to the columnar store, large enough to amortize
/// the per-chunk bookkeeping.
pub const DEFAULT_CHUNK_NODES: usize = 8192;

/// One bounded slice of a streamed circuit: nodes
/// `start..start + desc.len()` plus the fanin edges that terminate in it.
#[derive(Clone, Debug, Default)]
pub struct NodeChunk {
    /// Global id of the chunk's first node.
    pub start: usize,
    /// Packed node descriptors (see [`super::circuit::pack_desc`]).
    pub desc: Vec<u8>,
    /// Ground-truth class per node.
    pub labels: Vec<u8>,
    /// Fanin edges `(src, dst)` with `dst` inside this chunk, grouped by
    /// non-decreasing `dst`.
    pub edges: Vec<(u32, u32)>,
}

impl NodeChunk {
    pub fn len(&self) -> usize {
        self.desc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.desc.is_empty()
    }
}

/// A chunked circuit emitter. Implemented by the AIG generator frontends
/// (`aig::{adders, mult, booth, wallace}` via `features::stream::AigSource`),
/// the AIGER reader (`aig::aiger::source_from_aag`), and the back-compat
/// `EdaGraph` adapter (`features::stream::EdaGraphSource`).
pub trait GraphSource {
    /// Circuit name (becomes `CircuitGraph::name`).
    fn name(&self) -> &str;

    /// Total nodes this source will emit, if known up front (enables
    /// exact preallocation of the columnar store).
    fn num_nodes_hint(&self) -> Option<usize> {
        None
    }

    /// The `num_aig_nodes` value to stamp on the ingested graph (`None`
    /// = every node, the convention for layouts without an AIG prefix).
    fn aig_prefix(&self) -> Option<usize> {
        None
    }

    /// Emit the next chunk, or `None` when the circuit is exhausted.
    fn next_chunk(&mut self) -> Result<Option<NodeChunk>>;
}

impl<S: GraphSource + ?Sized> GraphSource for Box<S> {
    fn name(&self) -> &str {
        (**self).name()
    }
    fn num_nodes_hint(&self) -> Option<usize> {
        (**self).num_nodes_hint()
    }
    fn aig_prefix(&self) -> Option<usize> {
        (**self).aig_prefix()
    }
    fn next_chunk(&mut self) -> Result<Option<NodeChunk>> {
        (**self).next_chunk()
    }
}

/// Batch replication as a source combinator: emits `batch` disjoint
/// copies of a base circuit (copy `c`'s node `u` becomes `c·n + u`),
/// mirroring `EdaGraph::replicate` — the paper's "batch size 16"
/// workloads are 16 disjoint graph copies processed together. The base
/// is ingested once into its compact columnar form and re-emitted with
/// offset arithmetic, so peak memory is one compact copy, not `batch`
/// legacy graphs.
pub struct ReplicateSource {
    base: CircuitGraph,
    name: String,
    batch: usize,
    chunk: usize,
    /// Next global node id to emit, over `0..batch * base.num_nodes()`.
    cursor: usize,
}

impl ReplicateSource {
    pub fn new<S: GraphSource>(base: S, batch: usize, chunk: usize) -> Result<ReplicateSource> {
        anyhow::ensure!(batch >= 1, "batch must be ≥ 1");
        let base = CircuitGraph::from_source(base)?;
        Ok(Self::from_circuit(base, batch, chunk))
    }

    pub fn from_circuit(base: CircuitGraph, batch: usize, chunk: usize) -> ReplicateSource {
        let name = if batch == 1 {
            base.name.clone()
        } else {
            format!("{}_x{batch}", base.name)
        };
        ReplicateSource { base, name, batch, chunk: chunk.max(1), cursor: 0 }
    }
}

impl GraphSource for ReplicateSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn num_nodes_hint(&self) -> Option<usize> {
        Some(self.base.num_nodes() * self.batch)
    }

    fn aig_prefix(&self) -> Option<usize> {
        // per-copy layout preserved, matching EdaGraph::replicate
        Some(self.base.num_aig_nodes() * self.batch)
    }

    fn next_chunk(&mut self) -> Result<Option<NodeChunk>> {
        let n = self.base.num_nodes();
        if n == 0 || self.cursor >= n * self.batch {
            return Ok(None);
        }
        let copy = self.cursor / n;
        let local = self.cursor - copy * n;
        // never cross a copy boundary: keeps the offset math per-chunk
        let take = self.chunk.min(n - local);
        let off = (copy * n) as u32;
        let mut edges = Vec::new();
        for v in local..local + take {
            for &s in self.base.fanins(v) {
                edges.push((s + off, v as u32 + off));
            }
        }
        let chunk = NodeChunk {
            start: self.cursor,
            desc: self.base.desc_slice(local, take).to_vec(),
            labels: self.base.labels_u8()[local..local + take].to_vec(),
            edges,
        };
        self.cursor += take;
        Ok(Some(chunk))
    }
}

#[cfg(test)]
mod tests {
    use super::super::circuit::{pack_desc, KIND_AND, KIND_INPUT, KIND_PO};
    use super::*;

    #[derive(Default)]
    struct Tiny {
        done: bool,
    }

    impl GraphSource for Tiny {
        fn name(&self) -> &str {
            "tiny"
        }
        fn num_nodes_hint(&self) -> Option<usize> {
            Some(3)
        }
        fn aig_prefix(&self) -> Option<usize> {
            Some(2)
        }
        fn next_chunk(&mut self) -> Result<Option<NodeChunk>> {
            // one-shot source: PI, AND(PI), PO
            if std::mem::replace(&mut self.done, true) {
                return Ok(None);
            }
            Ok(Some(NodeChunk {
                start: 0,
                desc: vec![
                    pack_desc(KIND_INPUT, false, false),
                    pack_desc(KIND_AND, false, true),
                    pack_desc(KIND_PO, false, false),
                ],
                labels: vec![4, 3, 0],
                edges: vec![(0, 1), (0, 1), (1, 2)],
            }))
        }
    }

    #[test]
    fn replicate_source_offsets_copies() {
        let base = CircuitGraph::from_source(Tiny::default()).unwrap();
        let r = ReplicateSource::from_circuit(base.clone(), 3, 2);
        let g = CircuitGraph::from_source(r).unwrap();
        assert_eq!(g.num_nodes(), 9);
        assert_eq!(g.num_aig_nodes(), 6);
        assert_eq!(g.num_edges(), 3 * base.num_edges());
        // copy 2's AND node reads copy 2's PI
        assert_eq!(g.fanins(7), &[6, 6]);
        assert_eq!(g.feature_row(7), base.feature_row(1));
        assert_eq!(g.labels_u8()[6..9], *base.labels_u8());
        // no edge crosses copies
        for (s, d) in g.edges_iter() {
            assert_eq!(s / 3, d / 3, "edge {s}->{d} crosses copies");
        }
    }

    #[test]
    fn replicate_batch_one_is_identity() {
        let base = CircuitGraph::from_source(Tiny::default()).unwrap();
        let g =
            CircuitGraph::from_source(ReplicateSource::from_circuit(base.clone(), 1, 1)).unwrap();
        assert_eq!(g.num_nodes(), base.num_nodes());
        assert_eq!(g.name, base.name);
        assert_eq!(
            g.edges_iter().collect::<Vec<_>>(),
            base.edges_iter().collect::<Vec<_>>()
        );
    }
}

//! Degree-distribution profiling (§IV) — the workload analysis that
//! motivates the HD/LD kernel split.
//!
//! The paper observes EDA graphs (especially batched "macro node" rows)
//! have a polarized distribution: most rows have degree ≤ 12 while a few
//! rows (PIs fanning out to whole partial-product columns, batched macro
//! rows) have degree ≥ 512. [`DegreeProfile`] computes the split a
//! [`crate::spmm::GrootSpmm`] instance uses, with the paper's default
//! thresholds.

use super::Csr;

/// Paper thresholds: HD rows have degree ≥ 512, LD rows ≤ 12.
pub const HD_THRESHOLD: usize = 512;
pub const LD_THRESHOLD: usize = 12;

/// Row partition by degree class.
#[derive(Clone, Debug)]
pub struct DegreeProfile {
    pub hd_threshold: usize,
    pub ld_threshold: usize,
    /// Rows with degree ≥ hd_threshold, descending degree.
    pub hd_rows: Vec<u32>,
    /// Rows with 0 < degree < hd_threshold, ascending degree (the paper's
    /// LD degree-sort); rows in (ld, hd) land here too — the mid band is
    /// processed by the LD path with wider packing.
    pub ld_rows: Vec<u32>,
    /// Rows with degree 0 (padding rows, isolated nodes).
    pub empty_rows: Vec<u32>,
    pub max_degree: usize,
    pub total_entries: usize,
}

impl DegreeProfile {
    pub fn new(csr: &Csr, hd_threshold: usize, ld_threshold: usize) -> Self {
        let n = csr.num_nodes();
        let mut hd = Vec::new();
        let mut ld = Vec::new();
        let mut empty = Vec::new();
        let mut max_degree = 0;
        for u in 0..n {
            let d = csr.degree(u);
            max_degree = max_degree.max(d);
            if d == 0 {
                empty.push(u as u32);
            } else if d >= hd_threshold {
                hd.push(u as u32);
            } else {
                ld.push(u as u32);
            }
        }
        // HD: descending degree (big rows first → static chunking balances).
        hd.sort_by_key(|&u| std::cmp::Reverse(csr.degree(u as usize)));
        // LD: ascending degree — the paper's count-sort ordering; stable
        // sort keeps row order within a degree class for coalesced output.
        ld.sort_by_key(|&u| csr.degree(u as usize));
        DegreeProfile {
            hd_threshold,
            ld_threshold,
            hd_rows: hd,
            ld_rows: ld,
            empty_rows: empty,
            max_degree,
            total_entries: csr.num_entries(),
        }
    }

    pub fn with_paper_thresholds(csr: &Csr) -> Self {
        Self::new(csr, HD_THRESHOLD, LD_THRESHOLD)
    }

    /// Fraction of nonzeros living in HD rows — the polarization statistic
    /// reported by the fig9 harness.
    pub fn hd_nnz_fraction(&self, csr: &Csr) -> f64 {
        if self.total_entries == 0 {
            return 0.0;
        }
        let hd_nnz: usize = self.hd_rows.iter().map(|&u| csr.degree(u as usize)).sum();
        hd_nnz as f64 / self.total_entries as f64
    }

    /// Group LD rows into runs of equal degree: (degree, slice range into
    /// `ld_rows`). The LD kernel assigns warps per group (§IV Fig. 5).
    pub fn ld_degree_groups(&self, csr: &Csr) -> Vec<(usize, std::ops::Range<usize>)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.ld_rows.len() {
            let d = csr.degree(self.ld_rows[i] as usize);
            let mut j = i + 1;
            while j < self.ld_rows.len() && csr.degree(self.ld_rows[j] as usize) == d {
                j += 1;
            }
            out.push((d, i..j));
            i = j;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::EdaGraph;

    fn star_plus_chain() -> Csr {
        // node 0 = hub of degree 6; nodes 7..10 a chain.
        let mut edges = vec![];
        for v in 1..=6u32 {
            edges.push((0u32, v));
        }
        edges.push((7, 8));
        edges.push((8, 9));
        Csr::symmetric_from_edges(10, &edges)
    }

    #[test]
    fn split_respects_thresholds() {
        let csr = star_plus_chain();
        let p = DegreeProfile::new(&csr, 5, 2);
        assert_eq!(p.hd_rows, vec![0]);
        assert!(p.ld_rows.len() == 9 - p.empty_rows.len() + 0 || !p.ld_rows.is_empty());
        assert!(!p.ld_rows.contains(&0));
        // ld sorted ascending by degree
        let degs: Vec<usize> = p.ld_rows.iter().map(|&u| csr.degree(u as usize)).collect();
        for w in degs.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn groups_cover_ld_rows() {
        let csr = star_plus_chain();
        let p = DegreeProfile::new(&csr, 5, 2);
        let groups = p.ld_degree_groups(&csr);
        let covered: usize = groups.iter().map(|(_, r)| r.len()).sum();
        assert_eq!(covered, p.ld_rows.len());
        for (d, r) in groups {
            for k in r {
                assert_eq!(csr.degree(p.ld_rows[k] as usize), d);
            }
        }
    }

    #[test]
    fn eda_graphs_are_polarized() {
        // The paper's observation: multiplier EDA graphs have low median
        // degree (AIG fanin 2 + fanouts) with a tail of high-degree rows.
        let g = crate::aig::mult::csa_multiplier(16);
        let eg = EdaGraph::from_aig(&g);
        let csr = Csr::symmetric_from_edges(eg.num_nodes, &eg.edges);
        let p = DegreeProfile::new(&csr, 16, 12);
        // Most rows are LD at a tiny threshold.
        assert!(p.ld_rows.len() > 9 * eg.num_nodes / 10);
        assert!(p.max_degree >= 8, "max degree {}", p.max_degree);
    }
}

//! Compressed sparse row adjacency.

use crate::util::pool::parallel_for_static;

/// CSR adjacency over `n` nodes. `row_ptr.len() == n+1`; neighbors of `u`
/// are `col_idx[row_ptr[u]..row_ptr[u+1]]`, sorted ascending.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Csr {
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
}

impl Csr {
    pub fn num_nodes(&self) -> usize {
        self.row_ptr.len().saturating_sub(1)
    }

    pub fn num_entries(&self) -> usize {
        self.col_idx.len()
    }

    #[inline]
    pub fn neighbors(&self, u: usize) -> &[u32] {
        &self.col_idx[self.row_ptr[u]..self.row_ptr[u + 1]]
    }

    #[inline]
    pub fn degree(&self, u: usize) -> usize {
        self.row_ptr[u + 1] - self.row_ptr[u]
    }

    /// Build from directed edges as-is (parallel-edge duplicates removed).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Csr {
        Self::build(n, edges.iter().copied())
    }

    /// Build the symmetric closure: for every (s,d), both s→d and d→s.
    /// This is the adjacency the GNN aggregation uses.
    pub fn symmetric_from_edges(n: usize, edges: &[(u32, u32)]) -> Csr {
        Self::symmetric_from_edge_iter(n, edges.iter().copied())
    }

    /// Symmetric closure from any re-iterable edge stream — lets the
    /// columnar [`super::CircuitGraph`] build its adjacency without
    /// materializing an 8-byte tuple per edge first.
    pub fn symmetric_from_edge_iter(
        n: usize,
        edges: impl Iterator<Item = (u32, u32)> + Clone,
    ) -> Csr {
        let doubled = edges
            .flat_map(|(s, d)| [(s, d), (d, s)])
            .filter(|&(s, d)| s != d);
        Self::build(n, doubled)
    }

    /// Heap bytes held by the adjacency arrays (memory-accounting hook
    /// for the streaming executor and harnesses).
    pub fn resident_bytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.col_idx.len() * std::mem::size_of::<u32>()
    }

    fn build(n: usize, edges: impl Iterator<Item = (u32, u32)> + Clone) -> Csr {
        let mut deg = vec![0usize; n];
        for (s, _) in edges.clone() {
            deg[s as usize] += 1;
        }
        let mut row_ptr = vec![0usize; n + 1];
        for u in 0..n {
            row_ptr[u + 1] = row_ptr[u] + deg[u];
        }
        let mut col_idx = vec![0u32; row_ptr[n]];
        let mut cursor = row_ptr[..n].to_vec();
        for (s, d) in edges {
            col_idx[cursor[s as usize]] = d;
            cursor[s as usize] += 1;
        }
        // Sort + dedupe each row.
        let mut out_ptr = vec![0usize; n + 1];
        for u in 0..n {
            let row = &mut col_idx[row_ptr[u]..row_ptr[u + 1]];
            row.sort_unstable();
        }
        // Compact after dedup.
        let mut compact = Vec::with_capacity(col_idx.len());
        for u in 0..n {
            let row = &col_idx[row_ptr[u]..row_ptr[u + 1]];
            let before = compact.len();
            let mut last: Option<u32> = None;
            for &v in row {
                if last != Some(v) {
                    compact.push(v);
                    last = Some(v);
                }
            }
            out_ptr[u + 1] = out_ptr[u] + (compact.len() - before);
        }
        Csr { row_ptr: out_ptr, col_idx: compact }
    }

    /// Extract the induced subgraph over `nodes` (must be unique).
    /// Returns (sub_csr, local→global map). Node k of the subgraph is
    /// `nodes[k]`.
    pub fn induced_subgraph(&self, nodes: &[u32]) -> (Csr, Vec<u32>) {
        let mut global_to_local: std::collections::HashMap<u32, u32> =
            std::collections::HashMap::with_capacity(nodes.len());
        for (k, &g) in nodes.iter().enumerate() {
            global_to_local.insert(g, k as u32);
        }
        let mut row_ptr = vec![0usize; nodes.len() + 1];
        let mut col_idx = Vec::new();
        for (k, &g) in nodes.iter().enumerate() {
            for &nb in self.neighbors(g as usize) {
                if let Some(&l) = global_to_local.get(&nb) {
                    col_idx.push(l);
                }
            }
            row_ptr[k + 1] = col_idx.len();
        }
        for k in 0..nodes.len() {
            col_idx[row_ptr[k]..row_ptr[k + 1]].sort_unstable();
        }
        (Csr { row_ptr, col_idx }, nodes.to_vec())
    }

    /// Total degree histogram as (degree, count) sorted by degree.
    pub fn degree_histogram(&self) -> Vec<(usize, usize)> {
        let mut map = std::collections::BTreeMap::new();
        for u in 0..self.num_nodes() {
            *map.entry(self.degree(u)).or_insert(0usize) += 1;
        }
        map.into_iter().collect()
    }

    /// Dense SpMM reference: Y = A · X where A is this adjacency with
    /// uniform weights `w(u,v) = 1/deg(u)` (mean aggregation) — the
    /// single-threaded oracle the SpMM engines are tested against.
    pub fn spmm_mean_reference(&self, x: &[f32], dim: usize) -> Vec<f32> {
        let n = self.num_nodes();
        assert_eq!(x.len(), n * dim);
        let mut y = vec![0.0f32; n * dim];
        for u in 0..n {
            let nbs = self.neighbors(u);
            if nbs.is_empty() {
                continue;
            }
            let inv = 1.0 / nbs.len() as f32;
            let yrow = &mut y[u * dim..(u + 1) * dim];
            for &v in nbs {
                let xrow = &x[v as usize * dim..(v as usize + 1) * dim];
                for d in 0..dim {
                    yrow[d] += xrow[d];
                }
            }
            for v in yrow.iter_mut() {
                *v *= inv;
            }
        }
        y
    }

    /// Dense reference for the *transpose* of mean aggregation:
    /// Y = (D⁻¹A)ᵀ X = A D⁻¹ X for a symmetric adjacency, i.e.
    /// `y[v] = Σ_{u ∈ N(v)} x[u] / deg(u)` — the gradient of
    /// [`Csr::spmm_mean_reference`] with respect to its input, which is
    /// what `SpmmEngine::spmm_mean_backward_into` implementations are
    /// tested against. Rows whose neighbor has no out-entries contribute
    /// nothing (only reachable on non-symmetric adjacencies).
    pub fn spmm_mean_backward_reference(&self, x: &[f32], dim: usize) -> Vec<f32> {
        let n = self.num_nodes();
        assert_eq!(x.len(), n * dim);
        let mut y = vec![0.0f32; n * dim];
        for v in 0..n {
            let yrow = &mut y[v * dim..(v + 1) * dim];
            for &u in self.neighbors(v) {
                let deg = self.degree(u as usize);
                if deg == 0 {
                    continue;
                }
                let w = 1.0 / deg as f32;
                let xrow = &x[u as usize * dim..(u as usize + 1) * dim];
                for d in 0..dim {
                    yrow[d] += xrow[d] * w;
                }
            }
        }
        y
    }

    /// Parallel check helper: max |a-b| over two feature matrices.
    /// Each thread accumulates its own partial maximum into a private
    /// slot; the slots are reduced serially at the end — no lock on the
    /// parallel path.
    pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len());
        let nthreads = crate::util::pool::default_threads().max(1);
        let mut partials = vec![0.0f32; nthreads];
        let slots = crate::util::pool::SendPtr(partials.as_mut_ptr());
        parallel_for_static(nthreads, a.len(), |t, s, e| {
            let mut local = 0.0f32;
            for i in s..e {
                local = local.max((a[i] - b[i]).abs());
            }
            // SAFETY: parallel_for_static hands each thread index t < nthreads
            // exactly one contiguous range, so slot t is written by one thread.
            unsafe { *slots.0.add(t) = local };
        });
        partials.iter().fold(0.0f32, |m, &x| m.max(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn builds_sorted_deduped_symmetric() {
        let edges = vec![(0u32, 1u32), (0, 1), (2, 0), (1, 2)];
        let g = Csr::symmetric_from_edges(3, &edges);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.neighbors(2), &[0, 1]);
    }

    #[test]
    fn directed_build_keeps_direction() {
        let edges = vec![(0u32, 1u32), (1, 2)];
        let g = Csr::from_edges(3, &edges);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[2]);
        assert_eq!(g.neighbors(2), &[] as &[u32]);
    }

    #[test]
    fn induced_subgraph_local_ids() {
        let edges = vec![(0u32, 1), (1, 2), (2, 3), (3, 0)];
        let g = Csr::symmetric_from_edges(4, &edges);
        let (sub, map) = g.induced_subgraph(&[1, 2, 3]);
        assert_eq!(map, vec![1, 2, 3]);
        // local 0 = node1: neighbors node0(excluded), node2(local 1)
        assert_eq!(sub.neighbors(0), &[1]);
        assert_eq!(sub.neighbors(1), &[0, 2]);
        assert_eq!(sub.neighbors(2), &[1]);
    }

    #[test]
    fn symmetric_closure_is_symmetric_property() {
        check("csr symmetric", 50, |g| {
            let n = g.usize(2..40);
            let m = g.usize(1..80);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (g.usize(0..n) as u32, g.usize(0..n) as u32))
                .collect();
            let csr = Csr::symmetric_from_edges(n, &edges);
            for u in 0..n {
                for &v in csr.neighbors(u) {
                    assert!(
                        csr.neighbors(v as usize).contains(&(u as u32)),
                        "edge {u}->{v} missing reverse"
                    );
                }
                // sorted & deduped
                let nb = csr.neighbors(u);
                for w in nb.windows(2) {
                    assert!(w[0] < w[1]);
                }
            }
        });
    }

    #[test]
    fn max_abs_diff_reduces_per_thread_partials() {
        assert_eq!(Csr::max_abs_diff(&[], &[]), 0.0);
        let a: Vec<f32> = (0..10_000).map(|i| i as f32).collect();
        let mut b = a.clone();
        assert_eq!(Csr::max_abs_diff(&a, &b), 0.0);
        b[7_777] += 3.5; // single spike, deep inside one thread's range
        b[123] -= 1.25;
        assert!((Csr::max_abs_diff(&a, &b) - 3.5).abs() < 1e-6);
    }

    #[test]
    fn spmm_backward_reference_is_adjoint_of_forward() {
        // ⟨A_mean x, g⟩ must equal ⟨x, A_meanᵀ g⟩ for any x, g.
        let edges = vec![(0u32, 1), (0, 2), (0, 3), (2, 3)];
        let csr = Csr::symmetric_from_edges(5, &edges); // node 4 isolated
        let dim = 3;
        let n = csr.num_nodes();
        let mut st = 0x1234u64;
        let mut next = || {
            (crate::util::rng::splitmix64(&mut st) >> 40) as f32 / (1u64 << 24) as f32 - 0.5
        };
        let x: Vec<f32> = (0..n * dim).map(|_| next()).collect();
        let g: Vec<f32> = (0..n * dim).map(|_| next()).collect();
        let y = csr.spmm_mean_reference(&x, dim);
        let gx = csr.spmm_mean_backward_reference(&g, dim);
        let dot = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(&p, &q)| p as f64 * q as f64).sum()
        };
        assert!((dot(&y, &g) - dot(&x, &gx)).abs() < 1e-5, "adjoint identity violated");
    }

    #[test]
    fn spmm_reference_mean() {
        // star: node 0 connected to 1,2,3
        let edges = vec![(0u32, 1), (0, 2), (0, 3)];
        let g = Csr::symmetric_from_edges(4, &edges);
        let x = vec![
            1.0, 10.0, // node0
            2.0, 20.0, // node1
            4.0, 40.0, // node2
            6.0, 60.0, // node3
        ];
        let y = g.spmm_mean_reference(&x, 2);
        assert_eq!(&y[0..2], &[4.0, 40.0]); // mean of nodes 1,2,3
        assert_eq!(&y[2..4], &[1.0, 10.0]); // node 1 sees only node 0
    }
}

//! Compact columnar circuit store — the ingestion-layer representation
//! sized for paper-scale widths (the 1,024-bit CSA multiplier is 134M
//! nodes / 268M edges; a dense `[f32; 4]` feature matrix alone would be
//! 2.1 GB before a single partition executes).
//!
//! The observation (see `features`): GROOT's 4-dim node features are a
//! pure function of (node kind, fanin polarities), so the whole feature
//! row fits in ONE packed descriptor byte per node. Dense `f32` matrices
//! are materialized *per partition on demand* by the execution stages —
//! never whole-graph. Edges are stored as flat `u32` CSR-by-destination
//! arrays (4 B/edge + 4 B/node) instead of `Vec<(u32, u32)>` tuples
//! (8 B/edge).
//!
//! Per-node cost, with the EDA-graph average of ≈2.1 fanin edges/node:
//!
//! | store                  | node bytes            | edge bytes | ≈ B/node |
//! |------------------------|-----------------------|------------|----------|
//! | legacy `EdaGraph`      | 16 (features) + 1 (label) | 8 (tuple)  | ~34  |
//! | compact `CircuitGraph` | 1 (desc) + 1 (label) + 4 (ptr) | 4 (src) | ~14.4 |
//!
//! — a ≥50% ingestion-store reduction, the in-crate counterpart of the
//! paper's 59.38% memory-footprint claim. `groot harness memory` writes
//! the measured numbers to BENCH_memory.json.

use super::source::GraphSource;
use super::Csr;
use crate::labels::NUM_CLASSES;
use anyhow::Result;

/// Node-kind field of a packed descriptor (low 2 bits).
pub const KIND_INPUT: u8 = 0; // PI or constant
pub const KIND_AND: u8 = 1;
pub const KIND_PO: u8 = 2;

const INV_L: u8 = 1 << 2;
const INV_R: u8 = 1 << 3;

/// Magic + format version of [`CircuitGraph::to_bytes`].
pub const BYTES_MAGIC: [u8; 4] = *b"GRCG";
pub const BYTES_VERSION: u16 = 1;

/// Pack (kind, left/right fanin polarity) into one descriptor byte.
/// PO nodes store their driver polarity in BOTH bits, mirroring the
/// `[0, 1, inv, inv]` feature row of the legacy encoding.
#[inline]
pub fn pack_desc(kind: u8, inv_l: bool, inv_r: bool) -> u8 {
    debug_assert!(kind <= KIND_PO);
    kind | if inv_l { INV_L } else { 0 } | if inv_r { INV_R } else { 0 }
}

#[inline]
pub fn desc_kind(d: u8) -> u8 {
    d & 0b11
}

/// Decode a descriptor byte into the GROOT 4-dim feature row — exactly
/// the values `EdaGraph::from_aig` writes, so gathered matrices are
/// bit-identical across representations.
#[inline]
pub fn desc_features(d: u8) -> [f32; 4] {
    let pl = ((d & INV_L) != 0) as u8 as f32;
    let pr = ((d & INV_R) != 0) as u8 as f32;
    match desc_kind(d) {
        KIND_INPUT => [0.0, 0.0, 0.0, 0.0],
        KIND_AND => [1.0, 1.0, pl, pr],
        _ => [0.0, 1.0, pl, pr], // KIND_PO (kind 3 is rejected by check())
    }
}

/// Columnar EDA graph: packed descriptor bytes, `u8` labels, and fanin
/// edges in CSR-by-destination form. This is what [`GraphSource`]
/// ingestion produces and what the streaming execution path reads;
/// dense feature matrices exist only as per-partition gather outputs.
#[derive(Clone, Debug)]
pub struct CircuitGraph {
    pub name: String,
    /// Number of underlying AIG nodes (PO graph nodes start at this
    /// index for single-copy graphs; replicated layouts only guarantee
    /// `num_aig_nodes ≤ num_nodes`).
    num_aig_nodes: usize,
    /// One packed descriptor byte per node (see [`pack_desc`]).
    desc: Vec<u8>,
    /// Ground-truth class per node (`0..NUM_CLASSES`).
    labels: Vec<u8>,
    /// Fanin sources of node `v` are
    /// `edge_src[edge_ptr[v] as usize..edge_ptr[v + 1] as usize]`,
    /// in emission order.
    edge_ptr: Vec<u32>,
    edge_src: Vec<u32>,
}

impl CircuitGraph {
    /// Drain a [`GraphSource`] into a columnar store. Chunks must arrive
    /// contiguously from node 0; each chunk's edges must target nodes of
    /// that chunk in non-decreasing destination order (every in-crate
    /// source emits fanin edges grouped by their defining node, which
    /// satisfies this for free).
    pub fn from_source<S: GraphSource>(mut src: S) -> Result<CircuitGraph> {
        let hint = src.num_nodes_hint().unwrap_or(0);
        let name = src.name().to_string();
        let mut desc: Vec<u8> = Vec::with_capacity(hint);
        let mut labels: Vec<u8> = Vec::with_capacity(hint);
        let mut edge_ptr: Vec<u32> = Vec::with_capacity(hint + 1);
        edge_ptr.push(0);
        let mut edge_src: Vec<u32> = Vec::new();
        while let Some(chunk) = src.next_chunk()? {
            anyhow::ensure!(
                chunk.start == desc.len(),
                "source '{name}' emitted chunk at {} but {} nodes are ingested",
                chunk.start,
                desc.len()
            );
            anyhow::ensure!(
                chunk.desc.len() == chunk.labels.len(),
                "chunk at {}: {} descriptors vs {} labels",
                chunk.start,
                chunk.desc.len(),
                chunk.labels.len()
            );
            let end = chunk.start + chunk.desc.len();
            anyhow::ensure!(
                u32::try_from(end).is_ok()
                    && u32::try_from(edge_src.len() + chunk.edges.len()).is_ok(),
                "graph exceeds u32 node/edge index space"
            );
            let mut last_dst = chunk.start as u32;
            for &(s, d) in &chunk.edges {
                anyhow::ensure!(
                    (chunk.start..end).contains(&(d as usize)) && d >= last_dst,
                    "chunk at {}: edge destination {d} out of order or range",
                    chunk.start
                );
                // close the rows between the previous destination and d
                while edge_ptr.len() <= d as usize {
                    edge_ptr.push(edge_src.len() as u32);
                }
                edge_src.push(s);
                last_dst = d;
            }
            while edge_ptr.len() <= end {
                edge_ptr.push(edge_src.len() as u32);
            }
            desc.extend_from_slice(&chunk.desc);
            labels.extend_from_slice(&chunk.labels);
        }
        let num_aig_nodes = src.aig_prefix().unwrap_or(desc.len());
        let g = CircuitGraph { name, num_aig_nodes, desc, labels, edge_ptr, edge_src };
        g.check()?;
        Ok(g)
    }

    /// Assemble a graph from loose columns plus a `(src, dst)` edge
    /// list — the rebuild path for [`crate::incremental`] graph edits.
    /// Edges are regrouped by ascending destination with a stable
    /// counting sort, so same-destination edges keep their relative
    /// order and the result matches [`Self::from_source`] emission
    /// order (content fingerprints stay representation-independent).
    pub fn from_components(
        name: String,
        num_aig_nodes: usize,
        desc: Vec<u8>,
        labels: Vec<u8>,
        edges: &[(u32, u32)],
    ) -> Result<CircuitGraph> {
        let n = desc.len();
        anyhow::ensure!(
            u32::try_from(n).is_ok() && u32::try_from(edges.len()).is_ok(),
            "graph exceeds u32 node/edge index space"
        );
        for &(_, d) in edges {
            anyhow::ensure!((d as usize) < n, "edge destination {d} out of range (n={n})");
        }
        let mut edge_ptr = vec![0u32; n + 1];
        for &(_, d) in edges {
            edge_ptr[d as usize + 1] += 1;
        }
        for v in 0..n {
            edge_ptr[v + 1] += edge_ptr[v];
        }
        let mut cursor: Vec<u32> = edge_ptr[..n].to_vec();
        let mut edge_src = vec![0u32; edges.len()];
        for &(s, d) in edges {
            edge_src[cursor[d as usize] as usize] = s;
            cursor[d as usize] += 1;
        }
        let g = CircuitGraph { name, num_aig_nodes, desc, labels, edge_ptr, edge_src };
        g.check()?;
        Ok(g)
    }

    pub fn num_nodes(&self) -> usize {
        self.desc.len()
    }

    pub fn num_aig_nodes(&self) -> usize {
        self.num_aig_nodes
    }

    pub fn num_edges(&self) -> usize {
        self.edge_src.len()
    }

    pub fn labels_u8(&self) -> &[u8] {
        &self.labels
    }

    pub fn desc(&self, u: usize) -> u8 {
        self.desc[u]
    }

    /// Contiguous descriptor bytes for nodes `start..start + len` (used
    /// by re-emitting source combinators).
    pub fn desc_slice(&self, start: usize, len: usize) -> &[u8] {
        &self.desc[start..start + len]
    }

    /// Decoded feature row of one node.
    pub fn feature_row(&self, u: usize) -> [f32; 4] {
        desc_features(self.desc[u])
    }

    /// Fanin sources of node `v` (the directed edge list row).
    pub fn fanins(&self, v: usize) -> &[u32] {
        &self.edge_src[self.edge_ptr[v] as usize..self.edge_ptr[v + 1] as usize]
    }

    /// Directed edges `(src, dst)` grouped by ascending destination —
    /// for AIG-built circuits this is exactly the legacy `EdaGraph`
    /// emission order, which keeps content fingerprints representation-
    /// independent.
    pub fn edges_iter(&self) -> impl Iterator<Item = (u32, u32)> + Clone + '_ {
        (0..self.num_nodes()).flat_map(move |v| {
            self.fanins(v).iter().map(move |&s| (s, v as u32))
        })
    }

    /// Append the decoded feature rows of `nodes` to `out` — the
    /// per-partition gather that replaces the whole-graph dense matrix.
    pub fn gather_features_into(&self, nodes: &[u32], out: &mut Vec<f32>) {
        out.reserve(nodes.len() * 4);
        for &u in nodes {
            out.extend_from_slice(&desc_features(self.desc[u as usize]));
        }
    }

    /// Symmetric closure of the stored fanin edges — the aggregation
    /// operand, built without materializing a tuple edge list.
    pub fn symmetric_csr(&self) -> Csr {
        Csr::symmetric_from_edge_iter(self.num_nodes(), self.edges_iter())
    }

    /// Heap bytes of the columnar store (exact content bytes; the
    /// quantity BENCH_memory.json compares against the legacy layout).
    pub fn resident_bytes(&self) -> usize {
        self.desc.len()
            + self.labels.len()
            + self.edge_ptr.len() * std::mem::size_of::<u32>()
            + self.edge_src.len() * std::mem::size_of::<u32>()
    }

    /// Canonical byte encoding of the columnar store — the compact wire
    /// payload of the network protocol (`net::wire`). Layout (all
    /// little-endian):
    ///
    /// ```text
    /// magic "GRCG" | version u16 | name_len u16 | name utf-8 |
    /// num_nodes u64 | num_aig_nodes u64 | num_edges u64 |
    /// desc  u8 × n | labels u8 × n | edge_ptr u32 × (n+1) | edge_src u32 × m
    /// ```
    ///
    /// Names longer than `u16::MAX` bytes are truncated (the name is
    /// display-only; fingerprints hash content, not names).
    pub fn to_bytes(&self) -> Vec<u8> {
        let n = self.num_nodes();
        let m = self.num_edges();
        let name_bytes = self.name.as_bytes();
        let mut name_len = name_bytes.len().min(u16::MAX as usize);
        while !self.name.is_char_boundary(name_len) {
            name_len -= 1;
        }
        let mut b = Vec::with_capacity(8 + name_len + 24 + 2 * n + (n + 1) * 4 + m * 4);
        b.extend_from_slice(&BYTES_MAGIC);
        b.extend_from_slice(&BYTES_VERSION.to_le_bytes());
        b.extend_from_slice(&(name_len as u16).to_le_bytes());
        b.extend_from_slice(&name_bytes[..name_len]);
        b.extend_from_slice(&(n as u64).to_le_bytes());
        b.extend_from_slice(&(self.num_aig_nodes as u64).to_le_bytes());
        b.extend_from_slice(&(m as u64).to_le_bytes());
        b.extend_from_slice(&self.desc);
        b.extend_from_slice(&self.labels);
        for &p in &self.edge_ptr {
            b.extend_from_slice(&p.to_le_bytes());
        }
        for &s in &self.edge_src {
            b.extend_from_slice(&s.to_le_bytes());
        }
        b
    }

    /// Decode [`Self::to_bytes`] output. Section lengths are validated
    /// against the buffer BEFORE any column is allocated (a malformed
    /// header must not drive a huge allocation), and the reassembled
    /// graph passes through [`Self::check`] — a decoded graph is exactly
    /// as trusted as an ingested one.
    pub fn from_bytes(buf: &[u8]) -> Result<CircuitGraph> {
        fn take<'a>(buf: &'a [u8], at: &mut usize, n: usize, what: &str) -> Result<&'a [u8]> {
            anyhow::ensure!(
                buf.len() - *at >= n,
                "circuit bytes: truncated {what} (need {n} bytes at offset {at}, have {})",
                buf.len() - *at
            );
            let out = &buf[*at..*at + n];
            *at += n;
            Ok(out)
        }
        fn take_u64(buf: &[u8], at: &mut usize, what: &str) -> Result<u64> {
            let b = take(buf, at, 8, what)?;
            Ok(u64::from_le_bytes(b.try_into().unwrap()))
        }
        let mut at = 0usize;
        let magic = take(buf, &mut at, 4, "magic")?;
        anyhow::ensure!(magic == BYTES_MAGIC, "circuit bytes: bad magic {magic:02x?}");
        let version = u16::from_le_bytes(take(buf, &mut at, 2, "version")?.try_into().unwrap());
        anyhow::ensure!(
            version == BYTES_VERSION,
            "circuit bytes: unsupported version {version} (want {BYTES_VERSION})"
        );
        let name_len =
            u16::from_le_bytes(take(buf, &mut at, 2, "name length")?.try_into().unwrap()) as usize;
        let name = std::str::from_utf8(take(buf, &mut at, name_len, "name")?)
            .map_err(|_| anyhow::anyhow!("circuit bytes: name is not utf-8"))?
            .to_string();
        let n64 = take_u64(buf, &mut at, "num_nodes")?;
        let aig64 = take_u64(buf, &mut at, "num_aig_nodes")?;
        let m64 = take_u64(buf, &mut at, "num_edges")?;
        anyhow::ensure!(
            n64 <= u32::MAX as u64 && m64 <= u32::MAX as u64 && aig64 <= n64,
            "circuit bytes: header counts out of range (n={n64} aig={aig64} m={m64})"
        );
        let (n, m) = (n64 as usize, m64 as usize);
        let need = 2 * n + (n + 1) * 4 + m * 4;
        anyhow::ensure!(
            buf.len() - at == need,
            "circuit bytes: payload length mismatch (header implies {need} column bytes, have {})",
            buf.len() - at
        );
        let desc = take(buf, &mut at, n, "desc column")?.to_vec();
        let labels = take(buf, &mut at, n, "label column")?.to_vec();
        let edge_ptr: Vec<u32> = take(buf, &mut at, (n + 1) * 4, "edge_ptr column")?
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let edge_src: Vec<u32> = take(buf, &mut at, m * 4, "edge_src column")?
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let g = CircuitGraph {
            name,
            num_aig_nodes: aig64 as usize,
            desc,
            labels,
            edge_ptr,
            edge_src,
        };
        g.check()
            .map_err(|e| anyhow::anyhow!("circuit bytes: decoded graph failed validation: {e:#}"))?;
        Ok(g)
    }

    /// Structural validator. Checkpoint/AIGER ingestion makes malformed
    /// graphs a real input, so out-of-range labels, descriptor kinds,
    /// edge endpoints, and inconsistent section arithmetic are all
    /// rejected loudly here (and by [`Self::from_source`]).
    pub fn check(&self) -> Result<()> {
        let n = self.num_nodes();
        anyhow::ensure!(
            self.num_aig_nodes <= n,
            "num_aig_nodes {} exceeds num_nodes {n}",
            self.num_aig_nodes
        );
        anyhow::ensure!(self.labels.len() == n, "label column length");
        anyhow::ensure!(self.edge_ptr.len() == n + 1, "edge_ptr length");
        anyhow::ensure!(
            self.edge_ptr[0] == 0 && self.edge_ptr[n] as usize == self.edge_src.len(),
            "edge_ptr bounds"
        );
        anyhow::ensure!(
            self.edge_ptr.windows(2).all(|w| w[0] <= w[1]),
            "edge_ptr not monotone"
        );
        for (u, &d) in self.desc.iter().enumerate() {
            anyhow::ensure!(desc_kind(d) <= KIND_PO, "node {u}: invalid descriptor kind");
        }
        for (u, &l) in self.labels.iter().enumerate() {
            anyhow::ensure!(
                (l as usize) < NUM_CLASSES,
                "node {u}: label {l} out of range (0..{NUM_CLASSES})"
            );
        }
        for &s in &self.edge_src {
            anyhow::ensure!((s as usize) < n, "edge source {s} out of range");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::source::{GraphSource, NodeChunk};

    /// Minimal scripted source for exercising the ingest validator.
    struct Scripted {
        chunks: Vec<NodeChunk>,
        at: usize,
        aig_prefix: Option<usize>,
    }

    impl GraphSource for Scripted {
        fn name(&self) -> &str {
            "scripted"
        }
        fn num_nodes_hint(&self) -> Option<usize> {
            None
        }
        fn aig_prefix(&self) -> Option<usize> {
            self.aig_prefix
        }
        fn next_chunk(&mut self) -> Result<Option<NodeChunk>> {
            if self.at >= self.chunks.len() {
                return Ok(None);
            }
            self.at += 1;
            Ok(Some(self.chunks[self.at - 1].clone()))
        }
    }

    fn two_chunk_source() -> Scripted {
        Scripted {
            chunks: vec![
                NodeChunk {
                    start: 0,
                    desc: vec![pack_desc(KIND_INPUT, false, false); 2],
                    labels: vec![4, 4],
                    edges: vec![],
                },
                NodeChunk {
                    start: 2,
                    desc: vec![pack_desc(KIND_AND, true, false), pack_desc(KIND_PO, true, true)],
                    labels: vec![3, 0],
                    edges: vec![(0, 2), (1, 2), (2, 3)],
                },
            ],
            at: 0,
            aig_prefix: Some(3),
        }
    }

    #[test]
    fn from_source_builds_columns() {
        let g = CircuitGraph::from_source(two_chunk_source()).unwrap();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_aig_nodes(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.fanins(2), &[0, 1]);
        assert_eq!(g.fanins(3), &[2]);
        assert_eq!(g.feature_row(2), [1.0, 1.0, 1.0, 0.0]);
        assert_eq!(g.feature_row(3), [0.0, 1.0, 1.0, 1.0]);
        assert_eq!(g.edges_iter().collect::<Vec<_>>(), vec![(0, 2), (1, 2), (2, 3)]);
    }

    #[test]
    fn from_source_rejects_gaps_and_bad_edges() {
        let mut s = two_chunk_source();
        s.chunks[1].start = 3; // gap
        assert!(CircuitGraph::from_source(s).is_err());

        let mut s = two_chunk_source();
        s.chunks[1].edges = vec![(0, 1)]; // dst outside the chunk
        assert!(CircuitGraph::from_source(s).is_err());

        let mut s = two_chunk_source();
        s.chunks[1].edges = vec![(2, 3), (0, 2)]; // dst order violated
        assert!(CircuitGraph::from_source(s).is_err());
    }

    #[test]
    fn from_components_matches_source_build() {
        let g = CircuitGraph::from_source(two_chunk_source()).unwrap();
        // Scramble the edge order: the stable regroup must restore it.
        let edges = vec![(2u32, 3u32), (0, 2), (1, 2)];
        let back = CircuitGraph::from_components(
            g.name.clone(),
            g.num_aig_nodes(),
            g.desc.clone(),
            g.labels.clone(),
            &edges,
        )
        .unwrap();
        assert_eq!(back.edges_iter().collect::<Vec<_>>(), g.edges_iter().collect::<Vec<_>>());
        for v in 0..g.num_nodes() {
            assert_eq!(back.fanins(v), g.fanins(v));
        }
        // Out-of-range destinations are rejected before any sort work.
        assert!(CircuitGraph::from_components(
            "bad".into(),
            0,
            vec![0],
            vec![0],
            &[(0, 9)],
        )
        .is_err());
    }

    #[test]
    fn check_rejects_malformed_columns() {
        let good = CircuitGraph::from_source(two_chunk_source()).unwrap();
        good.check().unwrap();

        let mut bad = good.clone();
        bad.labels[1] = NUM_CLASSES as u8; // out-of-range label
        assert!(bad.check().is_err());

        let mut bad = good.clone();
        bad.num_aig_nodes = bad.num_nodes() + 1; // aig prefix overruns
        assert!(bad.check().is_err());

        let mut bad = good.clone();
        bad.edge_src[0] = 99; // dangling source
        assert!(bad.check().is_err());

        let mut bad = good;
        bad.desc[0] = 0b11; // invalid kind
        assert!(bad.check().is_err());
    }

    #[test]
    fn aig_prefix_overrun_rejected_at_ingest() {
        let mut s = two_chunk_source();
        s.aig_prefix = Some(5);
        assert!(CircuitGraph::from_source(s).is_err());
    }

    #[test]
    fn desc_roundtrip_covers_all_rows() {
        for (kind, pl, pr, want) in [
            (KIND_INPUT, false, false, [0.0, 0.0, 0.0, 0.0]),
            (KIND_AND, false, false, [1.0, 1.0, 0.0, 0.0]),
            (KIND_AND, true, false, [1.0, 1.0, 1.0, 0.0]),
            (KIND_AND, false, true, [1.0, 1.0, 0.0, 1.0]),
            (KIND_AND, true, true, [1.0, 1.0, 1.0, 1.0]),
            (KIND_PO, false, false, [0.0, 1.0, 0.0, 0.0]),
            (KIND_PO, true, true, [0.0, 1.0, 1.0, 1.0]),
        ] {
            assert_eq!(desc_features(pack_desc(kind, pl, pr)), want);
        }
    }

    #[test]
    fn resident_bytes_counts_all_columns() {
        let g = CircuitGraph::from_source(two_chunk_source()).unwrap();
        // 4 desc + 4 labels + 5×4 ptr + 3×4 src
        assert_eq!(g.resident_bytes(), 4 + 4 + 20 + 12);
    }

    #[test]
    fn bytes_roundtrip_is_lossless() {
        let g = CircuitGraph::from_source(two_chunk_source()).unwrap();
        let back = CircuitGraph::from_bytes(&g.to_bytes()).unwrap();
        assert_eq!(back.name, g.name);
        assert_eq!(back.num_nodes(), g.num_nodes());
        assert_eq!(back.num_aig_nodes(), g.num_aig_nodes());
        assert_eq!(back.num_edges(), g.num_edges());
        assert_eq!(back.labels_u8(), g.labels_u8());
        for v in 0..g.num_nodes() {
            assert_eq!(back.desc(v), g.desc(v));
            assert_eq!(back.fanins(v), g.fanins(v));
        }
    }

    #[test]
    fn from_bytes_rejects_malformed_buffers() {
        let bytes = CircuitGraph::from_source(two_chunk_source()).unwrap().to_bytes();
        // bad magic
        let mut b = bytes.clone();
        b[0] = b'X';
        assert!(CircuitGraph::from_bytes(&b).unwrap_err().to_string().contains("magic"));
        // unknown version
        let mut b = bytes.clone();
        b[4] = 99;
        assert!(CircuitGraph::from_bytes(&b).unwrap_err().to_string().contains("version"));
        // truncation at every prefix must error, never panic
        for cut in 0..bytes.len() {
            assert!(CircuitGraph::from_bytes(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        // trailing junk
        let mut b = bytes.clone();
        b.push(0);
        assert!(CircuitGraph::from_bytes(&b).is_err());
        // content corruption that only check() can see: out-of-range label
        let mut b = bytes.clone();
        let labels_at = b.len() - (5 * 4 + 3 * 4) - 4; // first byte of the label column
        b[labels_at] = NUM_CLASSES as u8;
        assert!(CircuitGraph::from_bytes(&b).is_err());
    }

    #[test]
    fn symmetric_csr_matches_tuple_build() {
        let g = CircuitGraph::from_source(two_chunk_source()).unwrap();
        let edges: Vec<(u32, u32)> = g.edges_iter().collect();
        let want = Csr::symmetric_from_edges(g.num_nodes(), &edges);
        assert_eq!(g.symmetric_csr(), want);
    }
}

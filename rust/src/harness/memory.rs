//! Memory harnesses: Fig. 1a (motivation: full-graph memory vs bit width),
//! Fig. 8 (memory vs #partitions per dataset), Table II (large-multiplier
//! MB comparison). Model-extrapolated rows are marked `model`; measured
//! rows come from running the real partitioner + Algorithm 1 and the
//! process RSS.

use super::Table;
use crate::coordinator::{PlanOptions, PreparedGraph, Session, SessionConfig};
use crate::datasets::{self, DatasetKind};
use crate::memmodel::{csa_nodes_paper, MemModel};
use anyhow::{Context, Result};

/// Fig. 1a — GPU memory needed for full-graph verification of CSA
/// multipliers vs bit width and batch size, with device capacities.
pub fn fig1a() -> Result<()> {
    let m = MemModel::default();
    let mut t = Table::new(
        "Fig 1a — full-graph (GAMORA-style) memory vs width/batch [model]",
        &["bits", "batch", "nodes", "mem (MB)", "RTX2080 11GB", "A100 40GB", "A100 80GB"],
    );
    for bits in [256usize, 512, 768, 1024] {
        for batch in [1usize, 8, 16] {
            let nodes = csa_nodes_paper(bits, batch);
            let mb = m.gamora_mb(nodes);
            let fits = |cap_gb: f64| if mb > cap_gb * 1024.0 { "OOM" } else { "fits" };
            t.row(vec![
                bits.to_string(),
                batch.to_string(),
                nodes.to_string(),
                format!("{mb:.0}"),
                fits(11.0).into(),
                fits(40.0).into(),
                fits(80.0).into(),
            ]);
        }
    }
    t.print();
    println!(
        "paper's motivation reproduced: 1024-bit @ batch 16 exceeds even A100-80GB."
    );
    Ok(())
}

/// Fig. 8 — memory vs #partitions for the four dataset families:
/// measured partition/boundary arithmetic at container-feasible widths,
/// converted to MB with the Table-II-calibrated model.
pub fn fig8(quick: bool) -> Result<()> {
    let m = MemModel::default();
    let datasets: Vec<(DatasetKind, usize, usize)> = if quick {
        vec![(DatasetKind::Csa, 32, 1), (DatasetKind::Booth, 32, 1)]
    } else {
        vec![
            (DatasetKind::Csa, 64, 1),
            (DatasetKind::Csa, 32, 4), // batch panel (b)
            (DatasetKind::Booth, 64, 1),
            (DatasetKind::Mapped7nm, 64, 1),
            (DatasetKind::Fpga4Lut, 64, 1), // Fig 7c panel
        ]
    };
    for (kind, bits, batch) in datasets {
        let graph = datasets::build(kind, bits)?.replicate(batch);
        let mut t = Table::new(
            format!(
                "Fig 8 — memory vs #partitions ({}{} batch {batch}; {} nodes)",
                kind.name(),
                bits,
                graph.num_nodes
            ),
            &[
                "partitions",
                "peak part nodes",
                "boundary nodes",
                "marginal MB",
                "vs P=1",
                "total model MB",
                "process RSS (MB)",
            ],
        );
        // marginal = β·peak (device data); total adds the allocator/base
        // floor that dominates at container scale but is constant in P.
        let marginal = |peak: usize| m.groot_bytes_per_node * peak as f64 / 1e6;
        let full_marginal = marginal(graph.num_nodes);
        // one prepared graph per dataset; each row is a plan over it
        let prepared = PreparedGraph::new(&graph);
        for parts in [1usize, 2, 4, 8, 16, 32, 64] {
            let s = prepared
                .plan_stats(&PlanOptions { partitions: parts, seed: 1, ..Default::default() })
                .regrowth;
            let mb = marginal(s.max_partition_nodes);
            t.row(vec![
                parts.to_string(),
                s.max_partition_nodes.to_string(),
                s.total_boundary_nodes.to_string(),
                format!("{mb:.1}"),
                format!("{:+.1}%", 100.0 * (mb - full_marginal) / full_marginal),
                format!("{:.0}", m.groot_mb(s.max_partition_nodes)),
                format!("{:.0}", crate::util::timer::peak_rss_bytes() as f64 / 1e6),
            ]);
        }
        t.print();
    }
    println!(
        "shape check: memory decays with partitions and flattens once the\n\
         re-grown boundary dominates the per-partition size (paper: ≥16 parts)."
    );
    Ok(())
}

/// One measured row of `groot harness memory`, serialized into
/// BENCH_memory.json.
struct MemoryRow {
    dataset: String,
    nodes: usize,
    edges: usize,
    legacy_bytes_per_node: f64,
    compact_bytes_per_node: f64,
    reduction_pct: f64,
    /// Eager execute_plan working set (all partitions' CSRs + gathered
    /// features + logits live at once).
    eager_exec_bytes: usize,
    /// Streaming executor peak (largest window), same (partitions, seed).
    stream_exec_peak_bytes: usize,
    partitions: usize,
    window: usize,
}

/// `groot harness memory` — the ingestion-layer footprint comparison the
/// compact columnar store exists for: measured bytes/node of the legacy
/// `EdaGraph` (dense `[f32; 4]` rows + tuple edges) vs the packed
/// `CircuitGraph` (descriptor byte + label + flat u32 CSR), plus the
/// eager-vs-streaming execution working set at a fixed partition count.
/// Writes BENCH_memory.json so successive PRs track the trajectory; the
/// per-store reduction is the in-crate counterpart of the paper's 59.38%
/// memory claim and must stay ≥ 50% (CI fails the run otherwise).
pub fn bench_memory(quick: bool, out_path: &str) -> Result<()> {
    let cases: Vec<(DatasetKind, usize)> = if quick {
        vec![(DatasetKind::Csa, 16)]
    } else {
        vec![
            (DatasetKind::Csa, 32),
            (DatasetKind::Csa, 64),
            (DatasetKind::Booth, 32),
            (DatasetKind::Wallace, 32),
        ]
    };
    let (partitions, window) = (8usize, 2usize);
    let session = Session::native(
        super::bench::synthetic_model(),
        SessionConfig { num_partitions: partitions, ..Default::default() },
    );

    let mut t = Table::new(
        "Ingestion memory — legacy EdaGraph vs compact CircuitGraph (measured)",
        &[
            "dataset",
            "nodes",
            "B/node legacy",
            "B/node compact",
            "reduction",
            "exec eager (MB)",
            "exec stream peak (MB)",
        ],
    );
    let mut rows = Vec::new();
    for (kind, bits) in cases {
        let legacy = datasets::build(kind, bits)?;
        let compact = PreparedGraph::from_source(datasets::source(kind, bits, 4096)?)?;
        let n = legacy.num_nodes as f64;
        let legacy_bpn = legacy.resident_bytes() as f64 / n;
        let compact_bpn = compact.resident_bytes() as f64 / n;
        let reduction = 100.0 * (1.0 - compact_bpn / legacy_bpn);

        // execution working set on the same plan options, both paths
        let eager = session.classify(&legacy)?;
        let streamed = session.classify_streaming(&compact, window)?;
        anyhow::ensure!(
            streamed.pred == eager.pred,
            "streaming predictions diverged from eager on {}{bits}",
            kind.name()
        );

        let row = MemoryRow {
            dataset: kind.stem(bits),
            nodes: legacy.num_nodes,
            edges: legacy.num_edges(),
            legacy_bytes_per_node: legacy_bpn,
            compact_bytes_per_node: compact_bpn,
            reduction_pct: reduction,
            eager_exec_bytes: eager.stats.peak_resident_bytes,
            stream_exec_peak_bytes: streamed.stats.peak_resident_bytes,
            partitions,
            window,
        };
        t.row(vec![
            row.dataset.clone(),
            row.nodes.to_string(),
            format!("{legacy_bpn:.1}"),
            format!("{compact_bpn:.1}"),
            format!("-{reduction:.1}%"),
            format!("{:.2}", row.eager_exec_bytes as f64 / 1e6),
            format!("{:.2}", row.stream_exec_peak_bytes as f64 / 1e6),
        ]);
        anyhow::ensure!(
            reduction >= 50.0,
            "{}: compact store reduction {reduction:.1}% fell below the 50% floor",
            row.dataset
        );
        rows.push(row);
    }
    t.print();
    println!(
        "\ncompact store ≥50% below legacy on every family (paper's Table II \
         claim: 59.38% GPU-footprint reduction at 1,024-bit)."
    );

    std::fs::write(out_path, render_memory_json(&rows))
        .with_context(|| format!("write {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// Hand-rolled JSON (no serde in the dependency set), matching the other
/// BENCH_*.json files.
fn render_memory_json(rows: &[MemoryRow]) -> String {
    let mut s = String::from("{\n  \"bench\": \"memory_footprint\",\n");
    s.push_str("  \"unit\": \"bytes per node (measured)\",\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"dataset\": \"{}\", \"nodes\": {}, \"edges\": {}, \
             \"legacy_bytes_per_node\": {:.2}, \"compact_bytes_per_node\": {:.2}, \
             \"reduction_pct\": {:.2}, \"eager_exec_bytes\": {}, \
             \"stream_exec_peak_bytes\": {}, \"partitions\": {}, \"window\": {}}}{}\n",
            r.dataset,
            r.nodes,
            r.edges,
            r.legacy_bytes_per_node,
            r.compact_bytes_per_node,
            r.reduction_pct,
            r.eager_exec_bytes,
            r.stream_exec_peak_bytes,
            r.partitions,
            r.window,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Table II — large multiplier GPU memory (MB), batch 16. GAMORA row from
/// the calibrated full-graph model; GROOT rows from per-partition size +
/// boundary fraction φ measured with the real partitioner at a feasible
/// width and applied at paper scale.
pub fn tab2() -> Result<()> {
    let m = MemModel::default();
    // measure φ(P) at 64-bit (≈ width-independent; see memmodel docs)
    let probe = datasets::build(DatasetKind::Csa, 64)?;
    let prepared = PreparedGraph::new(&probe);
    let parts_list = [2usize, 4, 8, 16, 32, 64];
    let mut phi = Vec::new();
    for &p in &parts_list {
        let s = prepared
            .plan_stats(&PlanOptions { partitions: p, seed: 1, ..Default::default() })
            .regrowth;
        let per = probe.num_nodes as f64 / p as f64;
        phi.push((s.max_partition_nodes as f64 / per) - 1.0);
    }
    let mut t = Table::new(
        "Table II — large multiplier memory (MB), batch 16 [model + measured φ]",
        &["# Part.", "256-Bit", "512-Bit", "1,024-Bit"],
    );
    let widths = [256usize, 512, 1024];
    let gamora: Vec<String> = widths
        .iter()
        .map(|&b| {
            let mb = m.gamora_mb(csa_nodes_paper(b, 16));
            if m.is_oom(mb) {
                "OOM".into()
            } else {
                format!("{mb:.0}")
            }
        })
        .collect();
    t.row(
        std::iter::once("GAMORA [7]".to_string())
            .chain(gamora)
            .collect(),
    );
    for (i, &p) in parts_list.iter().enumerate() {
        let row: Vec<String> = widths
            .iter()
            .map(|&b| {
                let nodes = csa_nodes_paper(b, 16);
                let peak = crate::memmodel::extrapolated_peak_partition(nodes, p, phi[i]);
                format!("{:.0}", m.groot_mb(peak))
            })
            .collect();
        t.row(
            std::iter::once(format!("GROOT {p} Part."))
                .chain(row)
                .collect(),
        );
    }
    t.print();
    println!("paper anchors: GAMORA 8263/29375/OOM; GROOT@16 2901/7909/27997 MB.");
    println!("measured boundary fractions φ(P) at csa64: {:?}", phi
        .iter()
        .map(|f| format!("{:.3}", f))
        .collect::<Vec<_>>());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_json_is_well_formed_ish() {
        let rows = vec![MemoryRow {
            dataset: "csa16".into(),
            nodes: 1700,
            edges: 3600,
            legacy_bytes_per_node: 33.9,
            compact_bytes_per_node: 14.4,
            reduction_pct: 57.5,
            eager_exec_bytes: 200_000,
            stream_exec_peak_bytes: 60_000,
            partitions: 8,
            window: 2,
        }];
        let s = render_memory_json(&rows);
        assert!(s.contains("\"bench\": \"memory_footprint\""));
        assert!(s.contains("\"reduction_pct\": 57.50"));
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn compact_store_halves_the_ingestion_footprint() {
        // The acceptance floor, enforced in tier-1: ≥50% bytes/node
        // reduction vs the legacy representation on a real dataset.
        let legacy = datasets::build(DatasetKind::Csa, 16).unwrap();
        let compact = legacy.to_circuit().unwrap();
        let l = legacy.resident_bytes() as f64;
        let c = compact.resident_bytes() as f64;
        assert!(
            c <= 0.5 * l,
            "compact {c:.0} B vs legacy {l:.0} B — reduction {:.1}% < 50%",
            100.0 * (1.0 - c / l)
        );
    }
}

//! Memory harnesses: Fig. 1a (motivation: full-graph memory vs bit width),
//! Fig. 8 (memory vs #partitions per dataset), Table II (large-multiplier
//! MB comparison). Model-extrapolated rows are marked `model`; measured
//! rows come from running the real partitioner + Algorithm 1 and the
//! process RSS.

use super::Table;
use crate::coordinator::{PlanOptions, PreparedGraph};
use crate::datasets::{self, DatasetKind};
use crate::memmodel::{csa_nodes_paper, MemModel};
use anyhow::Result;

/// Fig. 1a — GPU memory needed for full-graph verification of CSA
/// multipliers vs bit width and batch size, with device capacities.
pub fn fig1a() -> Result<()> {
    let m = MemModel::default();
    let mut t = Table::new(
        "Fig 1a — full-graph (GAMORA-style) memory vs width/batch [model]",
        &["bits", "batch", "nodes", "mem (MB)", "RTX2080 11GB", "A100 40GB", "A100 80GB"],
    );
    for bits in [256usize, 512, 768, 1024] {
        for batch in [1usize, 8, 16] {
            let nodes = csa_nodes_paper(bits, batch);
            let mb = m.gamora_mb(nodes);
            let fits = |cap_gb: f64| if mb > cap_gb * 1024.0 { "OOM" } else { "fits" };
            t.row(vec![
                bits.to_string(),
                batch.to_string(),
                nodes.to_string(),
                format!("{mb:.0}"),
                fits(11.0).into(),
                fits(40.0).into(),
                fits(80.0).into(),
            ]);
        }
    }
    t.print();
    println!(
        "paper's motivation reproduced: 1024-bit @ batch 16 exceeds even A100-80GB."
    );
    Ok(())
}

/// Fig. 8 — memory vs #partitions for the four dataset families:
/// measured partition/boundary arithmetic at container-feasible widths,
/// converted to MB with the Table-II-calibrated model.
pub fn fig8(quick: bool) -> Result<()> {
    let m = MemModel::default();
    let datasets: Vec<(DatasetKind, usize, usize)> = if quick {
        vec![(DatasetKind::Csa, 32, 1), (DatasetKind::Booth, 32, 1)]
    } else {
        vec![
            (DatasetKind::Csa, 64, 1),
            (DatasetKind::Csa, 32, 4), // batch panel (b)
            (DatasetKind::Booth, 64, 1),
            (DatasetKind::Mapped7nm, 64, 1),
            (DatasetKind::Fpga4Lut, 64, 1), // Fig 7c panel
        ]
    };
    for (kind, bits, batch) in datasets {
        let graph = datasets::build(kind, bits)?.replicate(batch);
        let mut t = Table::new(
            format!(
                "Fig 8 — memory vs #partitions ({}{} batch {batch}; {} nodes)",
                kind.name(),
                bits,
                graph.num_nodes
            ),
            &[
                "partitions",
                "peak part nodes",
                "boundary nodes",
                "marginal MB",
                "vs P=1",
                "total model MB",
                "process RSS (MB)",
            ],
        );
        // marginal = β·peak (device data); total adds the allocator/base
        // floor that dominates at container scale but is constant in P.
        let marginal = |peak: usize| m.groot_bytes_per_node * peak as f64 / 1e6;
        let full_marginal = marginal(graph.num_nodes);
        // one prepared graph per dataset; each row is a plan over it
        let prepared = PreparedGraph::new(&graph);
        for parts in [1usize, 2, 4, 8, 16, 32, 64] {
            let s = prepared
                .plan_stats(&PlanOptions { partitions: parts, regrow: true, seed: 1 })
                .regrowth;
            let mb = marginal(s.max_partition_nodes);
            t.row(vec![
                parts.to_string(),
                s.max_partition_nodes.to_string(),
                s.total_boundary_nodes.to_string(),
                format!("{mb:.1}"),
                format!("{:+.1}%", 100.0 * (mb - full_marginal) / full_marginal),
                format!("{:.0}", m.groot_mb(s.max_partition_nodes)),
                format!("{:.0}", crate::util::timer::peak_rss_bytes() as f64 / 1e6),
            ]);
        }
        t.print();
    }
    println!(
        "shape check: memory decays with partitions and flattens once the\n\
         re-grown boundary dominates the per-partition size (paper: ≥16 parts)."
    );
    Ok(())
}

/// Table II — large multiplier GPU memory (MB), batch 16. GAMORA row from
/// the calibrated full-graph model; GROOT rows from per-partition size +
/// boundary fraction φ measured with the real partitioner at a feasible
/// width and applied at paper scale.
pub fn tab2() -> Result<()> {
    let m = MemModel::default();
    // measure φ(P) at 64-bit (≈ width-independent; see memmodel docs)
    let probe = datasets::build(DatasetKind::Csa, 64)?;
    let prepared = PreparedGraph::new(&probe);
    let parts_list = [2usize, 4, 8, 16, 32, 64];
    let mut phi = Vec::new();
    for &p in &parts_list {
        let s = prepared
            .plan_stats(&PlanOptions { partitions: p, regrow: true, seed: 1 })
            .regrowth;
        let per = probe.num_nodes as f64 / p as f64;
        phi.push((s.max_partition_nodes as f64 / per) - 1.0);
    }
    let mut t = Table::new(
        "Table II — large multiplier memory (MB), batch 16 [model + measured φ]",
        &["# Part.", "256-Bit", "512-Bit", "1,024-Bit"],
    );
    let widths = [256usize, 512, 1024];
    let gamora: Vec<String> = widths
        .iter()
        .map(|&b| {
            let mb = m.gamora_mb(csa_nodes_paper(b, 16));
            if m.is_oom(mb) {
                "OOM".into()
            } else {
                format!("{mb:.0}")
            }
        })
        .collect();
    t.row(
        std::iter::once("GAMORA [7]".to_string())
            .chain(gamora)
            .collect(),
    );
    for (i, &p) in parts_list.iter().enumerate() {
        let row: Vec<String> = widths
            .iter()
            .map(|&b| {
                let nodes = csa_nodes_paper(b, 16);
                let peak = crate::memmodel::extrapolated_peak_partition(nodes, p, phi[i]);
                format!("{:.0}", m.groot_mb(peak))
            })
            .collect();
        t.row(
            std::iter::once(format!("GROOT {p} Part."))
                .chain(row)
                .collect(),
        );
    }
    t.print();
    println!("paper anchors: GAMORA 8263/29375/OOM; GROOT@16 2901/7909/27997 MB.");
    println!("measured boundary fractions φ(P) at csa64: {:?}", phi
        .iter()
        .map(|f| format!("{:.3}", f))
        .collect::<Vec<_>>());
    Ok(())
}

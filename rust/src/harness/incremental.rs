//! Incremental-verification sweep — `groot harness incremental`.
//!
//! For each edit size E, measures `Session::classify_delta` (base
//! registered once, every iteration edits E fresh nodes so the dirty
//! partitions genuinely re-infer) against a cold full classify of the
//! same edited design (prepare + plan + execute — what a non-incremental
//! flow pays per edit), asserts the two produce byte-identical
//! predictions, and writes BENCH_incremental.json. The interesting
//! curve is speedup vs edit size: the smaller the edit, the larger the
//! clean fraction stitched from the prediction cache.

use super::Table;
use crate::coordinator::{PlanOptions, PreparedGraph, Session, SessionConfig};
use crate::datasets::{self, DatasetKind};
use crate::incremental::{apply_edits, synthetic_polarity_edits};
use crate::util::timer::{bench_for, fmt_dur};
use anyhow::{ensure, Context, Result};
use std::sync::Arc;
use std::time::Duration;

/// One edit-size measurement, serialized into BENCH_incremental.json.
struct IncRow {
    dataset: String,
    nodes: usize,
    partitions: usize,
    edit_nodes: usize,
    dirty: usize,
    clean: usize,
    delta_median_s: f64,
    full_median_s: f64,
    speedup: f64,
    /// Prediction-cache hit rate over the delta bench window (memory +
    /// disk hits over all lookups) — how much of the stitch came from
    /// cache rather than re-inference.
    pred_cache_hit_rate: f64,
}

pub fn bench_incremental(weights: &str, quick: bool, out_path: &str) -> Result<()> {
    let model = super::native_model(weights).unwrap_or_else(|_| super::bench::synthetic_model());
    let (bits, partitions) = if quick { (16usize, 8usize) } else { (64, 16) };
    let budget = Duration::from_millis(if quick { 150 } else { 600 });
    let edit_sizes: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16, 64] };

    let cfg = SessionConfig { num_partitions: partitions, ..Default::default() };
    let opts = PlanOptions::from_config(&cfg);
    let session = Session::native(model, cfg);

    let graph = datasets::build(DatasetKind::Csa, bits)?;
    let circuit = Arc::new(graph.to_circuit()?);
    let (base_fp, _base) = session.prime_base(circuit.clone())?;
    println!(
        "incremental sweep: csa{bits} ({} nodes, {partitions} partitions), \
         base fingerprint {base_fp:016x}",
        circuit.num_nodes()
    );

    let mut t = Table::new(
        "Incremental verification — delta vs cold full classify, by edit size",
        &[
            "edits", "dirty", "clean", "delta median", "full median", "speedup",
            "pred-cache hit rate",
        ],
    );
    let mut rows = Vec::new();
    for &size in edit_sizes {
        // Byte-identity gate first: one delta against a cold classify of
        // the identically edited circuit. A perf number for a path that
        // diverges from the from-scratch pipeline would be meaningless.
        let check_edits = synthetic_polarity_edits(&circuit, size, 4242 + size as u64);
        ensure!(!check_edits.is_empty(), "no editable AND nodes at edit size {size}");
        let dres = session.classify_delta(base_fp, &check_edits)?;
        let edited = apply_edits(&circuit, &check_edits)?;
        let prepared = PreparedGraph::from_circuit_ref(&edited);
        let plan = prepared.plan(&opts);
        let cold = session.classify_plan(&prepared, &plan, false)?;
        ensure!(
            dres.result.pred == cold.pred,
            "edit size {size}: classify_delta diverged from a cold classify of the edited graph"
        );
        ensure!(
            dres.clean > 0 || partitions == 1,
            "edit size {size}: every partition re-inferred (clean=0) — caching is inert"
        );

        // Delta bench: a fresh seed per iteration edits new sites, so
        // each iteration's dirty partitions miss the cache and re-infer
        // (steady state would otherwise stitch everything and measure
        // only the all-clean path).
        let pred = session.incremental().predictions();
        let (h0, d0, m0) = (pred.hits(), pred.disk_hits(), pred.misses());
        let mut seed = 0u64;
        let mut last = None;
        let delta = bench_for(budget, || {
            seed += 1;
            let edits = synthetic_polarity_edits(&circuit, size, seed);
            last = Some(session.classify_delta(base_fp, &edits).expect("delta classify"));
        });
        let last = last.expect("delta bench ran at least once");
        let pred = session.incremental().predictions();
        let (hits, lookups) = (
            (pred.hits() - h0) + (pred.disk_hits() - d0),
            (pred.hits() - h0) + (pred.misses() - m0),
        );

        // Cold full classify of one edited variant — the per-edit cost
        // of a flow with no incremental path.
        let full = bench_for(budget, || {
            let prepared = PreparedGraph::from_circuit_ref(&edited);
            let plan = prepared.plan(&opts);
            session.classify_plan(&prepared, &plan, false).expect("full classify");
        });

        let row = IncRow {
            dataset: format!("csa{bits}"),
            nodes: circuit.num_nodes(),
            partitions,
            edit_nodes: size,
            dirty: last.dirty,
            clean: last.clean,
            delta_median_s: delta.median_secs(),
            full_median_s: full.median_secs(),
            speedup: full.median_secs() / delta.median_secs().max(1e-12),
            pred_cache_hit_rate: hits as f64 / (lookups as f64).max(1.0),
        };
        t.row(vec![
            row.edit_nodes.to_string(),
            row.dirty.to_string(),
            row.clean.to_string(),
            fmt_dur(delta.median),
            fmt_dur(full.median),
            format!("{:.2}x", row.speedup),
            format!("{:.0}%", 100.0 * row.pred_cache_hit_rate),
        ]);
        rows.push(row);
    }
    t.print();

    std::fs::write(out_path, render_incremental_json(&rows))
        .with_context(|| format!("write {out_path}"))?;
    println!("\nwrote {out_path}");
    Ok(())
}

/// Hand-rolled JSON (no serde in the dependency set): stable key order,
/// one row object per edit size.
fn render_incremental_json(rows: &[IncRow]) -> String {
    let mut s = String::from("{\n  \"bench\": \"incremental_delta\",\n");
    s.push_str("  \"unit\": \"seconds (median)\",\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"dataset\": \"{}\", \"nodes\": {}, \"partitions\": {}, \
             \"edit_nodes\": {}, \"dirty\": {}, \"clean\": {}, \
             \"delta_median_s\": {:.6}, \"full_median_s\": {:.6}, \
             \"speedup\": {:.3}, \"pred_cache_hit_rate\": {:.3}}}{}\n",
            r.dataset,
            r.nodes,
            r.partitions,
            r.edit_nodes,
            r.dirty,
            r.clean,
            r.delta_median_s,
            r.full_median_s,
            r.speedup,
            r.pred_cache_hit_rate,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn incremental_json_is_well_formed_ish() {
        let rows = vec![IncRow {
            dataset: "csa16".into(),
            nodes: 9000,
            partitions: 8,
            edit_nodes: 4,
            dirty: 3,
            clean: 5,
            delta_median_s: 0.002,
            full_median_s: 0.01,
            speedup: 5.0,
            pred_cache_hit_rate: 0.625,
        }];
        let s = render_incremental_json(&rows);
        assert!(s.contains("\"bench\": \"incremental_delta\""));
        assert!(s.contains("\"edit_nodes\": 4"));
        assert!(s.contains("\"clean\": 5"));
        assert!(s.contains("\"speedup\": 5.000"));
        assert!(s.contains("\"pred_cache_hit_rate\": 0.625"));
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn quick_sweep_runs_and_writes_json() {
        let out = std::env::temp_dir()
            .join(format!("groot_bench_incremental_{}.json", std::process::id()));
        let out_s = out.to_str().unwrap().to_string();
        bench_incremental("nonexistent-weights.bin", true, &out_s).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        assert!(text.contains("\"bench\": \"incremental_delta\""));
        assert!(text.contains("\"edit_nodes\": 1"));
        let _ = std::fs::remove_file(&out);
    }
}

//! Pipeline throughput bench — `groot harness bench`.
//!
//! Measures end-to-end classify throughput of the staged pipeline on CSA
//! multipliers, cold (prepare + plan + execute every request, what the
//! monolithic API always paid) vs plan-cache-warm (execute only, what a
//! repeat server request pays), and writes the rows to
//! `BENCH_pipeline.json` so successive PRs can track the trajectory.
//!
//! `--serve` instead sweeps the CONCURRENT serving runtime: in-flight
//! clients × worker threads at one fixed total thread budget (workers
//! share it: per-worker backend budget = total / workers, so a 1-worker
//! row is the single-router baseline at EQUAL hardware), each shape
//! measured over BOTH transports — `in-process` (ServerHandle straight
//! into the queue) and `socket` (wire protocol through `NetDaemon` over
//! a Unix socket) — writing BENCH_serve.json with throughput and
//! p50/p95/p99 latency per transport.
//!
//! Works with or without trained artifacts: if the weights bundle is
//! missing, a fixed synthetic two-layer model is used — the bench times
//! the pipeline, not the accuracy.

use super::Table;
use crate::coordinator::server::{Server, VerifyOptions};
use crate::coordinator::{PlanCache, PlanOptions, PreparedGraph, Session, SessionConfig};
use crate::datasets::{self, DatasetKind};
use crate::gnn::{SageLayer, SageModel};
use crate::net::{BindAddr, GrootClient, NetConfig, NetDaemon, Reply};
use crate::util::timer::{bench_for, fmt_dur};
use anyhow::{Context, Result};
use std::time::{Duration, Instant};

/// One measured row, serialized into BENCH_pipeline.json.
struct BenchRow {
    dataset: String,
    nodes: usize,
    partitions: usize,
    cold_median_s: f64,
    cold_p95_s: f64,
    cold_p99_s: f64,
    warm_median_s: f64,
    warm_p95_s: f64,
    warm_p99_s: f64,
    speedup: f64,
    warm_knodes_per_s: f64,
    /// Out-of-core path: compact store + windowed execution (window 4).
    stream_median_s: f64,
    /// Peak execution-buffer bytes, streaming vs eager — the measured
    /// out-of-core memory ratio.
    stream_peak_bytes: usize,
    eager_exec_bytes: usize,
}

pub fn bench_pipeline(weights: &str, quick: bool, out_path: &str) -> Result<()> {
    let model = super::native_model(weights).unwrap_or_else(|_| synthetic_model());
    let session = Session::native(model, SessionConfig::default());
    let budget = Duration::from_millis(if quick { 200 } else { 1000 });

    let cases: Vec<(usize, usize)> = if quick {
        vec![(16, 8)]
    } else {
        vec![(16, 8), (32, 8), (32, 32)]
    };

    let mut t = Table::new(
        "Pipeline classify throughput — cold vs plan-cache-warm vs streaming (window 4)",
        &[
            "dataset",
            "nodes",
            "parts",
            "cold median",
            "warm median",
            "warm p95",
            "warm p99",
            "speedup",
            "warm knodes/s",
            "stream median",
            "exec mem stream/eager",
        ],
    );
    let mut rows = Vec::new();
    for (bits, parts) in cases {
        let graph = datasets::build(DatasetKind::Csa, bits)?;
        let opts = PlanOptions { partitions: parts, ..Default::default() };

        // cold: the full request path with nothing reusable
        let cold = bench_for(budget, || {
            let prepared = PreparedGraph::new(&graph);
            let plan = prepared.plan(&opts);
            session.classify_plan(&prepared, &plan, false).expect("cold classify")
        });

        // warm: plan served from the LRU, execution stage only (the last
        // benched result doubles as the eager exec-memory sample)
        let prepared = PreparedGraph::new(&graph);
        let mut cache = PlanCache::default();
        cache.get_or_build(&prepared, &opts); // populate
        let mut eager_last = None;
        let warm = bench_for(budget, || {
            let (plan, hit) = cache.get_or_build(&prepared, &opts);
            assert!(hit, "warm path must hit the plan cache");
            eager_last =
                Some(session.classify_plan(&prepared, &plan, hit).expect("warm classify"));
        });
        let eager_res = eager_last.expect("warm bench ran at least once");

        // streaming: compact columnar store, windowed execution over a
        // prebuilt lean plan — bounded memory is the point; the bench
        // records the execution-stage time cost
        let compact =
            PreparedGraph::from_source(datasets::source(DatasetKind::Csa, bits, 4096)?)?;
        let stream_plan = compact.plan_stream(&opts);
        let mut stream_last = None;
        let stream = bench_for(budget, || {
            stream_last = Some(
                session
                    .classify_stream_plan(&compact, &stream_plan, 4)
                    .expect("stream classify"),
            );
        });
        let stream_res = stream_last.expect("stream bench ran at least once");

        let row = BenchRow {
            dataset: format!("csa{bits}"),
            nodes: graph.num_nodes,
            partitions: parts,
            cold_median_s: cold.median_secs(),
            cold_p95_s: cold.p95_secs(),
            cold_p99_s: cold.p99_secs(),
            warm_median_s: warm.median_secs(),
            warm_p95_s: warm.p95_secs(),
            warm_p99_s: warm.p99_secs(),
            speedup: cold.median_secs() / warm.median_secs().max(1e-12),
            warm_knodes_per_s: graph.num_nodes as f64
                / warm.median_secs().max(1e-12)
                / 1e3,
            stream_median_s: stream.median_secs(),
            stream_peak_bytes: stream_res.stats.peak_resident_bytes,
            eager_exec_bytes: eager_res.stats.peak_resident_bytes,
        };
        t.row(vec![
            row.dataset.clone(),
            row.nodes.to_string(),
            row.partitions.to_string(),
            fmt_dur(cold.median),
            fmt_dur(warm.median),
            fmt_dur(warm.p95),
            fmt_dur(warm.p99),
            format!("{:.2}x", row.speedup),
            format!("{:.1}", row.warm_knodes_per_s),
            fmt_dur(stream.median),
            format!(
                "{:.0}%",
                100.0 * row.stream_peak_bytes as f64 / row.eager_exec_bytes.max(1) as f64
            ),
        ]);
        rows.push(row);
    }
    t.print();

    std::fs::write(out_path, render_json(&rows))
        .with_context(|| format!("write {out_path}"))?;
    println!("\nwrote {out_path}");
    Ok(())
}

/// Hand-rolled JSON (no serde in the dependency set): stable key order,
/// one row object per case.
fn render_json(rows: &[BenchRow]) -> String {
    let mut s = String::from("{\n  \"bench\": \"pipeline_classify\",\n");
    s.push_str("  \"unit\": \"seconds (median)\",\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"dataset\": \"{}\", \"nodes\": {}, \"partitions\": {}, \
             \"cold_median_s\": {:.6}, \"cold_p95_s\": {:.6}, \"cold_p99_s\": {:.6}, \
             \"warm_median_s\": {:.6}, \"warm_p95_s\": {:.6}, \"warm_p99_s\": {:.6}, \
             \"plan_cache_speedup\": {:.3}, \"warm_knodes_per_s\": {:.1}, \
             \"stream_median_s\": {:.6}, \"stream_peak_bytes\": {}, \
             \"eager_exec_bytes\": {}}}{}\n",
            r.dataset,
            r.nodes,
            r.partitions,
            r.cold_median_s,
            r.cold_p95_s,
            r.cold_p99_s,
            r.warm_median_s,
            r.warm_p95_s,
            r.warm_p99_s,
            r.speedup,
            r.warm_knodes_per_s,
            r.stream_median_s,
            r.stream_peak_bytes,
            r.eager_exec_bytes,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// One measured serving row, serialized into BENCH_serve.json.
struct ServeBenchRow {
    dataset: String,
    nodes: usize,
    partitions: usize,
    /// `in-process` (ServerHandle straight into the queue) or `socket`
    /// (wire protocol over a Unix socket through `NetDaemon`) — the
    /// delta between the two at equal shape is the transport overhead.
    transport: &'static str,
    workers: usize,
    clients: usize,
    total_threads: usize,
    requests: usize,
    throughput_rps: f64,
    knodes_per_s: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
}

/// `groot harness bench --serve` — the serving concurrency sweep:
/// 1/2/4/8 in-flight clients × worker counts, all at ONE total thread
/// budget (per-worker backend budget = total / workers). The workers=1
/// row is the old single-router shape, so each column's speedup over it
/// is the multi-worker win at equal hardware. Requests repeat the same
/// circuit (the run-time verification loop), so after one warm-up the
/// sweep measures the steady plan-cache-warm serving path.
pub fn bench_serve(
    weights: &str,
    quick: bool,
    out_path: &str,
    max_workers: Option<usize>,
) -> Result<()> {
    let model = super::native_model(weights).unwrap_or_else(|_| synthetic_model());
    let (bits, partitions) = if quick { (16usize, 8usize) } else { (64, 8) };
    let graph = datasets::build(DatasetKind::Csa, bits)?;
    let total_threads = crate::util::pool::default_threads().max(4);
    // `--workers N` pins the sweep to {1, N} (baseline + requested);
    // otherwise sweep the default ladder.
    let worker_counts: Vec<usize> = match max_workers {
        Some(w) if w > 1 => vec![1, w],
        Some(_) => vec![1],
        None if quick => vec![1, 2],
        None => vec![1, 2, 4],
    };
    let client_counts: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let per_client = if quick { 6 } else { 25 };

    let mut t = Table::new(
        format!(
            "Serving concurrency sweep — csa{bits}, {partitions} partitions, \
             total thread budget {total_threads}"
        ),
        &[
            "transport", "workers", "clients", "reqs", "throughput req/s", "knodes/s",
            "p50", "p95", "p99",
        ],
    );
    // Sorted client latencies → one finished bench row.
    let make_row = |transport: &'static str,
                    workers: usize,
                    clients: usize,
                    requests: usize,
                    wall: f64,
                    latencies: &[f64]|
     -> ServeBenchRow {
        let pct = |p: f64| -> f64 {
            let idx = ((latencies.len() as f64 - 1.0) * p).round() as usize;
            latencies[idx]
        };
        ServeBenchRow {
            dataset: format!("csa{bits}"),
            nodes: graph.num_nodes,
            partitions,
            transport,
            workers,
            clients,
            total_threads,
            requests,
            throughput_rps: requests as f64 / wall,
            knodes_per_s: (requests * graph.num_nodes) as f64 / wall / 1e3,
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
        }
    };
    // Pre-encoded wire payload for the socket arm: the encode cost is
    // paid once, so the socket rows measure transport + serving, not
    // client-side serialization.
    let circuit_bytes = std::sync::Arc::new(graph.to_circuit()?.to_bytes());
    let mut rows: Vec<ServeBenchRow> = Vec::new();
    for &workers in &worker_counts {
        let per_worker_threads = (total_threads / workers).max(1);
        let spawn_server = |model: crate::gnn::SageModel| -> Server {
            Server::spawn(
                SessionConfig {
                    num_partitions: partitions,
                    threads: per_worker_threads,
                    workers,
                    ..Default::default()
                },
                move || -> Result<crate::coordinator::Backend> {
                    Ok(Box::new(crate::backend::NativeBackend::with_threads(
                        model.clone(),
                        per_worker_threads,
                    )))
                },
            )
        };

        // ---- transport: in-process (ServerHandle into the queue) ----
        let server = spawn_server(model.clone());
        let handle = server.handle();
        // one warm-up request builds the shared plan (single-flight)
        handle.verify_blocking(graph.clone(), VerifyOptions::default())?;
        for &clients in client_counts {
            let requests = clients * per_client;
            // Closed-loop clients run as jobs on the work-stealing
            // ThreadPool (one worker per client): the pool IS part of
            // the runtime under test, and each client keeps exactly one
            // request in flight.
            let pool = crate::util::pool::ThreadPool::new(clients);
            let (lat_tx, lat_rx) = std::sync::mpsc::channel::<Vec<f64>>();
            let wall_start = Instant::now();
            for _ in 0..clients {
                let handle = handle.clone();
                let graph = graph.clone();
                let lat_tx = lat_tx.clone();
                pool.execute(move || {
                    let mut lat = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let t0 = Instant::now();
                        let res = handle
                            .verify_blocking(graph.clone(), VerifyOptions::default())
                            .expect("serve bench request failed");
                        lat.push(t0.elapsed().as_secs_f64() * 1e3);
                        assert_eq!(res.pred.len(), graph.num_nodes);
                    }
                    let _ = lat_tx.send(lat);
                })
                .expect("client pool closed early");
            }
            drop(lat_tx);
            // iter() ends once every client job finished and dropped its
            // sender — that instant is the sweep's wall-clock endpoint.
            let mut latencies: Vec<f64> = lat_rx.iter().flatten().collect();
            let wall = wall_start.elapsed().as_secs_f64().max(1e-9);
            drop(pool); // shutdown + join the client workers
            latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
            rows.push(make_row("in-process", workers, clients, requests, wall, &latencies));
        }
        server.shutdown();

        // ---- transport: socket (wire protocol over a Unix socket) ----
        let sock = std::env::temp_dir()
            .join(format!("groot_bench_serve_{}_{workers}.sock", std::process::id()));
        let daemon = NetDaemon::bind(
            &BindAddr::Unix(sock.clone()),
            spawn_server(model.clone()),
            NetConfig::default(),
        )?;
        let addr = BindAddr::Unix(sock);
        {
            let mut warm = GrootClient::connect(&addr)?;
            match warm.classify_circuit_bytes(&circuit_bytes, &VerifyOptions::default())? {
                Reply::Result(r) => assert_eq!(r.pred.len(), graph.num_nodes),
                Reply::Busy => anyhow::bail!("serve bench warm-up got BUSY"),
            }
        }
        for &clients in client_counts {
            let requests = clients * per_client;
            let pool = crate::util::pool::ThreadPool::new(clients);
            let (lat_tx, lat_rx) = std::sync::mpsc::channel::<Vec<f64>>();
            let wall_start = Instant::now();
            for _ in 0..clients {
                let addr = addr.clone();
                let bytes = std::sync::Arc::clone(&circuit_bytes);
                let lat_tx = lat_tx.clone();
                let nodes = graph.num_nodes;
                pool.execute(move || {
                    let mut client =
                        GrootClient::connect(&addr).expect("serve bench socket connect");
                    let mut lat = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let t0 = Instant::now();
                        loop {
                            match client
                                .classify_circuit_bytes(&bytes, &VerifyOptions::default())
                                .expect("serve bench socket request failed")
                            {
                                Reply::Result(res) => {
                                    assert_eq!(res.pred.len(), nodes);
                                    break;
                                }
                                // bounded queue full: honest retry loop
                                Reply::Busy => std::thread::yield_now(),
                            }
                        }
                        lat.push(t0.elapsed().as_secs_f64() * 1e3);
                    }
                    let _ = lat_tx.send(lat);
                })
                .expect("client pool closed early");
            }
            drop(lat_tx);
            let mut latencies: Vec<f64> = lat_rx.iter().flatten().collect();
            let wall = wall_start.elapsed().as_secs_f64().max(1e-9);
            drop(pool);
            latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
            rows.push(make_row("socket", workers, clients, requests, wall, &latencies));
        }
        daemon.shutdown();
    }
    for row in &rows {
        t.row(vec![
            row.transport.to_string(),
            row.workers.to_string(),
            row.clients.to_string(),
            row.requests.to_string(),
            format!("{:.1}", row.throughput_rps),
            format!("{:.1}", row.knodes_per_s),
            format!("{:.2} ms", row.p50_ms),
            format!("{:.2} ms", row.p95_ms),
            format!("{:.2} ms", row.p99_ms),
        ]);
    }
    t.print();

    // headline: best multi-worker throughput over the 1-worker baseline
    // at the SAME client load (equal total thread budget by construction)
    let speedup_at = |clients: usize| -> Option<f64> {
        let base = rows
            .iter()
            .find(|r| r.transport == "in-process" && r.workers == 1 && r.clients == clients)?
            .throughput_rps;
        let best = rows
            .iter()
            .filter(|r| r.transport == "in-process" && r.clients == clients && r.workers > 1)
            .map(|r| r.throughput_rps)
            .fold(f64::NAN, f64::max);
        (base > 0.0 && best.is_finite()).then_some(best / base)
    };
    if let Some(s) = speedup_at(*client_counts.last().unwrap()) {
        println!(
            "\nmulti-worker speedup at {} clients (equal {total_threads}-thread budget): {s:.2}x",
            client_counts.last().unwrap()
        );
    }

    std::fs::write(out_path, render_serve_json(&rows))
        .with_context(|| format!("write {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

fn render_serve_json(rows: &[ServeBenchRow]) -> String {
    let mut s = String::from("{\n  \"bench\": \"serve_concurrency\",\n");
    s.push_str("  \"unit\": \"requests/second; latency ms\",\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"dataset\": \"{}\", \"nodes\": {}, \"partitions\": {}, \
             \"transport\": \"{}\", \
             \"workers\": {}, \"clients\": {}, \"total_threads\": {}, \
             \"requests\": {}, \"throughput_rps\": {:.3}, \
             \"knodes_per_s\": {:.1}, \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \
             \"p99_ms\": {:.3}}}{}\n",
            r.dataset,
            r.nodes,
            r.partitions,
            r.transport,
            r.workers,
            r.clients,
            r.total_threads,
            r.requests,
            r.throughput_rps,
            r.knodes_per_s,
            r.p50_ms,
            r.p95_ms,
            r.p99_ms,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// One measured training row, serialized into BENCH_train.json.
struct TrainBenchRow {
    dataset: String,
    nodes: usize,
    partitions: usize,
    epochs: usize,
    epoch_median_s: f64,
    knodes_per_s: f64,
    first_loss: f64,
    final_loss: f64,
}

/// `groot harness bench --train` — the training perf trajectory: epoch
/// wall time and core-nodes/sec for the default `groot train`
/// configuration, plus first→final loss so regressions in *convergence*
/// (not just speed) show up in the same file.
pub fn bench_train(quick: bool, out_path: &str) -> Result<()> {
    use crate::train::{self, TrainConfig};

    let cases: Vec<(usize, usize)> = if quick { vec![(8, 4)] } else { vec![(8, 4), (16, 8)] };
    let epochs = if quick { 2 } else { 5 };

    let mut t = Table::new(
        "Training throughput — default model (4→64→64→5), partition-aware batches",
        &["dataset", "nodes", "parts", "epochs", "epoch median", "knodes/s", "loss first→final"],
    );
    let mut rows = Vec::new();
    for (bits, parts) in cases {
        let graph = datasets::build(DatasetKind::Csa, bits)?;
        let cfg = TrainConfig {
            epochs,
            partitions: parts,
            seed: 1,
            eval_every: usize::MAX, // benching the train loop, not eval
            checkpoint_every: 0,
            out: None,
            ..Default::default()
        };
        let report = train::train(std::slice::from_ref(&graph), &[], &cfg, |_| {})?;
        // Drop epoch 1: it carries one-time SpMM plan builds and arena
        // warm-up, and the file tracks steady-state throughput.
        let warm_skip = usize::from(report.history.len() > 1);
        let mut secs: Vec<f64> =
            report.history.iter().skip(warm_skip).map(|e| e.secs).collect();
        secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = secs[secs.len() / 2];
        let row = TrainBenchRow {
            dataset: format!("csa{bits}"),
            nodes: graph.num_nodes,
            partitions: parts,
            epochs,
            epoch_median_s: median,
            knodes_per_s: graph.num_nodes as f64 / median.max(1e-12) / 1e3,
            first_loss: report.first_loss(),
            final_loss: report.final_loss(),
        };
        t.row(vec![
            row.dataset.clone(),
            row.nodes.to_string(),
            row.partitions.to_string(),
            row.epochs.to_string(),
            fmt_dur(Duration::from_secs_f64(median)),
            format!("{:.1}", row.knodes_per_s),
            format!("{:.4} → {:.4}", row.first_loss, row.final_loss),
        ]);
        rows.push(row);
    }
    t.print();

    std::fs::write(out_path, render_train_json(&rows))
        .with_context(|| format!("write {out_path}"))?;
    println!("\nwrote {out_path}");
    Ok(())
}

fn render_train_json(rows: &[TrainBenchRow]) -> String {
    let mut s = String::from("{\n  \"bench\": \"train_epoch\",\n");
    s.push_str("  \"unit\": \"seconds per epoch (median)\",\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"dataset\": \"{}\", \"nodes\": {}, \"partitions\": {}, \
             \"epochs\": {}, \"epoch_median_s\": {:.6}, \"knodes_per_s\": {:.1}, \
             \"first_loss\": {:.6}, \"final_loss\": {:.6}}}{}\n",
            r.dataset,
            r.nodes,
            r.partitions,
            r.epochs,
            r.epoch_median_s,
            r.knodes_per_s,
            r.first_loss,
            r.final_loss,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// One SpMM engine's SIMD-vs-scalar measurement, serialized into
/// BENCH_kernels.json.
struct KernelRow {
    engine: &'static str,
    scalar_median_s: f64,
    simd_median_s: f64,
    speedup: f64,
}

/// A paired A/B timing (scalar-vs-SIMD matmul, f32-vs-int8 forward,
/// unfused-vs-fused batch) for the kernels report.
struct PairRow {
    name: &'static str,
    base_median_s: f64,
    fast_median_s: f64,
    speedup: f64,
}

/// `groot harness bench --kernels` — the kernel microbench:
///
/// * per-SpMM-engine forward aggregation (dim 64) under
///   `simd::force_scalar(true)` vs the dispatched SIMD path — the two
///   produce byte-identical output (see `rust/tests/kernel_parity.rs`),
///   so the ratio is pure kernel speedup;
/// * `matmul_add` scalar vs SIMD on the dense-layer GEMM shape;
/// * full native forward at f32 vs int8 weights (per-channel symmetric);
/// * `infer_batch` with the fused stacked GEMM vs per-partition matmuls
///   at the SAME thread budget.
///
/// Writes BENCH_kernels.json; `assert_speedup` (CI: 1.5) fails the run
/// if the best per-engine SpMM speedup lands below it — skipped when the
/// dispatch ladder resolved to scalar (no SIMD on this host, nothing to
/// assert).
pub fn bench_kernels(
    weights: &str,
    quick: bool,
    out_path: &str,
    assert_speedup: Option<f64>,
) -> Result<()> {
    use crate::backend::{InferenceBackend, NativeBackend, PartitionInput};
    use crate::features::GROOT_FEATURE_DIM;
    use crate::gnn::{matmul_add_with, Precision};
    use crate::util::simd;

    let bits = if quick { 16 } else { 64 };
    let budget = Duration::from_millis(if quick { 150 } else { 600 });
    let threads = crate::util::pool::default_threads();

    let graph = datasets::build(DatasetKind::Csa, bits)?;
    let prepared = PreparedGraph::new(&graph);
    let csr = prepared.csr();
    let n = csr.num_nodes();
    let plan_stats = prepared.plan_stats(&PlanOptions::default());

    println!(
        "kernel bench: csa{bits} ({n} nodes, hd/ld rows {}/{}), simd={}, threads={threads}",
        plan_stats.hd_rows,
        plan_stats.ld_rows,
        simd::active()
    );

    // --- SpMM forward, dim 64, per engine, scalar vs dispatched SIMD ---
    let dim = 64usize;
    let x: Vec<f32> = (0..n * dim).map(|i| ((i as f32) * 0.37).sin()).collect();
    let mut out = vec![0.0f32; n * dim];
    let mut spmm_rows = Vec::new();
    for engine in crate::spmm::all_engines(threads) {
        simd::force_scalar(true);
        let scalar = bench_for(budget, || engine.spmm_mean_into(csr, &x, dim, &mut out));
        simd::force_scalar(false);
        let fast = bench_for(budget, || engine.spmm_mean_into(csr, &x, dim, &mut out));
        spmm_rows.push(KernelRow {
            engine: engine.name(),
            scalar_median_s: scalar.median_secs(),
            simd_median_s: fast.median_secs(),
            speedup: scalar.median_secs() / fast.median_secs().max(1e-12),
        });
    }

    // --- dense GEMM (matmul_add), the SAGE layer shape n×64 · 64×64 ---
    let k = 64usize;
    let m = 64usize;
    let a: Vec<f32> = (0..n * k).map(|i| ((i as f32) * 0.11).cos()).collect();
    let b: Vec<f32> = (0..k * m).map(|i| ((i as f32) * 0.23).sin() * 0.1).collect();
    let mut gout = vec![0.0f32; n * m];
    simd::force_scalar(true);
    let mm_scalar = bench_for(budget, || {
        gout.fill(0.0);
        matmul_add_with(threads, &a, &b, &mut gout, n, k, m);
    });
    simd::force_scalar(false);
    let mm_fast = bench_for(budget, || {
        gout.fill(0.0);
        matmul_add_with(threads, &a, &b, &mut gout, n, k, m);
    });
    let matmul = PairRow {
        name: "matmul_add",
        base_median_s: mm_scalar.median_secs(),
        fast_median_s: mm_fast.median_secs(),
        speedup: mm_scalar.median_secs() / mm_fast.median_secs().max(1e-12),
    };

    // --- f32 vs int8 full forward through the native backend ---
    let model = super::native_model(weights).unwrap_or_else(|_| synthetic_model());
    let part = PartitionInput {
        csr,
        features: prepared.features(),
        feature_dim: GROOT_FEATURE_DIM,
    };
    let f32_backend = NativeBackend::with_precision(model.clone(), threads, Precision::F32);
    let int8_backend = NativeBackend::with_precision(model.clone(), threads, Precision::Int8);
    let f32_t = bench_for(budget, || f32_backend.infer(part).expect("f32 infer"));
    let int8_t = bench_for(budget, || int8_backend.infer(part).expect("int8 infer"));
    let int8 = PairRow {
        name: "int8_forward",
        base_median_s: f32_t.median_secs(),
        fast_median_s: int8_t.median_secs(),
        speedup: f32_t.median_secs() / int8_t.median_secs().max(1e-12),
    };

    // --- fused stacked GEMM vs per-partition infer_batch, equal budget ---
    let parts = 4usize;
    let batch: Vec<PartitionInput<'_>> = (0..parts).map(|_| part).collect();
    let batch_budget = threads.max(parts);
    let fused_backend =
        NativeBackend::with_precision(model.clone(), batch_budget, Precision::F32);
    let mut unfused_backend =
        NativeBackend::with_precision(model, batch_budget, Precision::F32);
    unfused_backend.set_fused(false);
    let unfused_t =
        bench_for(budget, || unfused_backend.infer_batch(&batch).expect("unfused batch"));
    let fused_t =
        bench_for(budget, || fused_backend.infer_batch(&batch).expect("fused batch"));
    let fused = PairRow {
        name: "fused_batch",
        base_median_s: unfused_t.median_secs(),
        fast_median_s: fused_t.median_secs(),
        speedup: unfused_t.median_secs() / fused_t.median_secs().max(1e-12),
    };

    let mut t = Table::new(
        "Kernel microbench — scalar vs SIMD / f32 vs int8 / per-part vs fused",
        &["kernel", "baseline median", "fast median", "speedup"],
    );
    for r in &spmm_rows {
        t.row(vec![
            format!("spmm {}", r.engine),
            format!("{:.3}ms", r.scalar_median_s * 1e3),
            format!("{:.3}ms", r.simd_median_s * 1e3),
            format!("{:.2}x", r.speedup),
        ]);
    }
    for p in [&matmul, &int8, &fused] {
        t.row(vec![
            p.name.to_string(),
            format!("{:.3}ms", p.base_median_s * 1e3),
            format!("{:.3}ms", p.fast_median_s * 1e3),
            format!("{:.2}x", p.speedup),
        ]);
    }
    t.print();

    std::fs::write(
        out_path,
        render_kernels_json(
            bits,
            n,
            plan_stats.hd_rows,
            plan_stats.ld_rows,
            simd::active(),
            &spmm_rows,
            &[&matmul, &int8, &fused],
        ),
    )
    .with_context(|| format!("write {out_path}"))?;
    println!("\nwrote {out_path}");

    if let Some(min) = assert_speedup {
        if simd::active() == "scalar" {
            println!("--assert-simd-speedup skipped: dispatch resolved to scalar on this host");
        } else {
            let best = spmm_rows.iter().map(|r| r.speedup).fold(0.0f64, f64::max);
            anyhow::ensure!(
                best >= min,
                "best SpMM SIMD speedup {best:.2}x below required {min:.2}x \
                 (simd={})",
                simd::active()
            );
            println!("SIMD speedup assertion passed: best {best:.2}x >= {min:.2}x");
        }
    }
    Ok(())
}

/// Hand-rolled JSON for BENCH_kernels.json: stable key order, one object
/// per SpMM engine plus the paired A/B rows.
#[allow(clippy::too_many_arguments)]
fn render_kernels_json(
    bits: usize,
    nodes: usize,
    hd_rows: usize,
    ld_rows: usize,
    simd_level: &str,
    spmm: &[KernelRow],
    pairs: &[&PairRow],
) -> String {
    let mut s = String::from("{\n  \"bench\": \"kernels\",\n");
    s.push_str(&format!(
        "  \"dataset\": \"csa{bits}\", \"nodes\": {nodes}, \
         \"hd_rows\": {hd_rows}, \"ld_rows\": {ld_rows}, \
         \"simd\": \"{simd_level}\",\n"
    ));
    s.push_str("  \"spmm\": [\n");
    for (i, r) in spmm.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"engine\": \"{}\", \"scalar_median_s\": {:.6}, \
             \"simd_median_s\": {:.6}, \"speedup\": {:.3}}}{}\n",
            r.engine,
            r.scalar_median_s,
            r.simd_median_s,
            r.speedup,
            if i + 1 < spmm.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n  \"pairs\": [\n");
    for (i, p) in pairs.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"base_median_s\": {:.6}, \
             \"fast_median_s\": {:.6}, \"speedup\": {:.3}}}{}\n",
            p.name,
            p.base_median_s,
            p.fast_median_s,
            p.speedup,
            if i + 1 < pairs.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// One measured plan-build row, serialized into BENCH_plan.json.
struct PlanBenchRow {
    dataset: String,
    nodes: usize,
    partitions: usize,
    threads: usize,
    cold_median_s: f64,
    cold_p95_s: f64,
    /// 1-thread cold median over this row's cold median.
    speedup_vs_1t: f64,
    /// Loading the same plan from the persistent GPLN store (the PR-7
    /// warm-restart path) — cold-vs-warm in one artifact.
    store_warm_median_s: f64,
    edge_cut: usize,
    replication: f64,
    balance: f64,
}

/// `groot harness bench --plan` — the cold plan-build sweep: partition +
/// re-growth + gather across thread budgets {1, 2, 4, 8} (clamped to the
/// host), asserting in-process that every budget produces the SAME
/// plan-level content digest (the determinism contract), plus a
/// plan-store warm-load row per case so the parallel-build win and the
/// persistence win are tracked side by side. `assert_speedup` (CI: 2.0)
/// fails the run if the 4-thread build on the largest case lands below
/// it vs 1 thread — auto-skipped only when the host has fewer than 4
/// cores.
pub fn bench_plan(quick: bool, out_path: &str, assert_speedup: Option<f64>) -> Result<()> {
    use crate::coordinator::PlanStore;

    let cases: Vec<(usize, usize)> =
        if quick { vec![(256, 24)] } else { vec![(64, 8), (256, 24)] };
    let budget = Duration::from_millis(if quick { 300 } else { 1500 });
    let cores =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let sweep: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&t| t == 1 || t <= cores).collect();

    let mut t = Table::new(
        format!("Cold plan build — thread sweep on {cores} cores (output pinned byte-identical) + plan-store warm load"),
        &[
            "dataset", "nodes", "parts", "threads", "cold median", "cold p95",
            "speedup vs 1t", "store warm", "edge cut", "replication", "balance",
        ],
    );
    let mut rows: Vec<PlanBenchRow> = Vec::new();
    let mut gate_speedup: Option<f64> = None;
    for &(bits, parts) in &cases {
        let graph = datasets::build(DatasetKind::Csa, bits)?;
        let prepared = PreparedGraph::new(&graph);
        // Force the shared symmetric closure outside every timer: the
        // sweep measures planning, and the CSR is budget-independent.
        prepared.csr();
        let opts = PlanOptions { partitions: parts, seed: 1, ..Default::default() };

        // Reference plan (untimed) → persistent store → warm-load bench.
        let reference = prepared.plan(&PlanOptions { threads: 1, ..opts.clone() });
        let dir = std::env::temp_dir()
            .join(format!("groot-bench-plan-{}-{bits}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = PlanStore::open(&dir)?;
        store.save(&reference)?;
        let fp = prepared.fingerprint();
        let warm = bench_for(budget, || {
            let loaded = store.load(fp, &opts).expect("plan-store warm load");
            assert_eq!(loaded.stats.content_digest, reference.stats.content_digest);
        });
        let _ = std::fs::remove_dir_all(&dir);

        let mut median_1t = f64::NAN;
        for &threads in &sweep {
            let run_opts = PlanOptions { threads, ..opts.clone() };
            let mut last = None;
            let cold = bench_for(budget, || last = Some(prepared.plan(&run_opts)));
            let plan = last.expect("cold bench ran at least once");
            // The determinism contract, enforced where the numbers are
            // made: every budget must build the byte-identical plan.
            assert_eq!(
                plan.stats.content_digest, reference.stats.content_digest,
                "plan content diverged at {threads} threads (csa{bits}, k={parts})"
            );
            if threads == 1 {
                median_1t = cold.median_secs();
            }
            let row = PlanBenchRow {
                dataset: format!("csa{bits}"),
                nodes: graph.num_nodes,
                partitions: parts,
                threads,
                cold_median_s: cold.median_secs(),
                cold_p95_s: cold.p95_secs(),
                speedup_vs_1t: median_1t / cold.median_secs().max(1e-12),
                store_warm_median_s: warm.median_secs(),
                edge_cut: plan.stats.edge_cut,
                replication: plan.stats.replication,
                balance: plan.stats.balance,
            };
            if threads == 4 && (bits, parts) == *cases.last().unwrap() {
                gate_speedup = Some(row.speedup_vs_1t);
            }
            t.row(vec![
                row.dataset.clone(),
                row.nodes.to_string(),
                row.partitions.to_string(),
                row.threads.to_string(),
                fmt_dur(cold.median),
                fmt_dur(cold.p95),
                format!("{:.2}x", row.speedup_vs_1t),
                fmt_dur(warm.median),
                row.edge_cut.to_string(),
                format!("{:.3}", row.replication),
                format!("{:.3}", row.balance),
            ]);
            rows.push(row);
        }
    }
    t.print();

    std::fs::write(out_path, render_plan_json(&rows))
        .with_context(|| format!("write {out_path}"))?;
    println!("\nwrote {out_path}");

    if let Some(min) = assert_speedup {
        if cores < 4 {
            println!("--assert-plan-speedup skipped: only {cores} cores available");
        } else {
            let s = gate_speedup
                .context("no 4-thread row on the largest case for --assert-plan-speedup")?;
            anyhow::ensure!(
                s >= min,
                "cold plan-build speedup {s:.2}x at 4 threads below required {min:.2}x"
            );
            println!("plan-build speedup assertion passed: {s:.2}x >= {min:.2}x at 4 threads");
        }
    }
    Ok(())
}

fn render_plan_json(rows: &[PlanBenchRow]) -> String {
    let mut s = String::from("{\n  \"bench\": \"plan_build\",\n");
    s.push_str("  \"unit\": \"seconds (median)\",\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"dataset\": \"{}\", \"nodes\": {}, \"partitions\": {}, \
             \"threads\": {}, \"cold_median_s\": {:.6}, \"cold_p95_s\": {:.6}, \
             \"speedup_vs_1t\": {:.3}, \"store_warm_median_s\": {:.6}, \
             \"edge_cut\": {}, \"replication\": {:.4}, \"balance\": {:.4}}}{}\n",
            r.dataset,
            r.nodes,
            r.partitions,
            r.threads,
            r.cold_median_s,
            r.cold_p95_s,
            r.speedup_vs_1t,
            r.store_warm_median_s,
            r.edge_cut,
            r.replication,
            r.balance,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Fixed-weight 4→16→5 model for artifact-free benching (values are
/// arbitrary but deterministic; small enough to keep activations finite).
/// Shared with the memory harness, which measures footprints, not
/// accuracy.
pub(crate) fn synthetic_model() -> SageModel {
    let wave = |n: usize, scale: f32| -> Vec<f32> {
        (0..n).map(|i| ((i as f32 * 0.7).sin()) * scale).collect()
    };
    SageModel {
        layers: vec![
            SageLayer {
                din: 4,
                dout: 16,
                w_self: wave(4 * 16, 0.3),
                w_neigh: wave(4 * 16, 0.2),
                bias: wave(16, 0.1),
            },
            SageLayer {
                din: 16,
                dout: 5,
                w_self: wave(16 * 5, 0.3),
                w_neigh: wave(16 * 5, 0.2),
                bias: wave(5, 0.1),
            },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_is_well_formed_ish() {
        let rows = vec![BenchRow {
            dataset: "csa16".into(),
            nodes: 9000,
            partitions: 8,
            cold_median_s: 0.01,
            cold_p95_s: 0.015,
            cold_p99_s: 0.016,
            warm_median_s: 0.002,
            warm_p95_s: 0.003,
            warm_p99_s: 0.004,
            speedup: 5.0,
            warm_knodes_per_s: 4500.0,
            stream_median_s: 0.012,
            stream_peak_bytes: 50_000,
            eager_exec_bytes: 220_000,
        }];
        let s = render_json(&rows);
        assert!(s.contains("\"dataset\": \"csa16\""));
        assert!(s.contains("\"plan_cache_speedup\": 5.000"));
        assert!(s.contains("\"warm_p95_s\": 0.003000"));
        assert!(s.contains("\"cold_p99_s\": 0.016000"));
        assert!(s.contains("\"stream_peak_bytes\": 50000"));
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn serve_json_is_well_formed_ish() {
        let rows = vec![ServeBenchRow {
            dataset: "csa64".into(),
            nodes: 37000,
            partitions: 8,
            transport: "socket",
            workers: 4,
            clients: 8,
            total_threads: 4,
            requests: 200,
            throughput_rps: 123.4,
            knodes_per_s: 4565.8,
            p50_ms: 7.5,
            p95_ms: 12.25,
            p99_ms: 14.5,
        }];
        let s = render_serve_json(&rows);
        assert!(s.contains("\"bench\": \"serve_concurrency\""));
        assert!(s.contains("\"workers\": 4"));
        assert!(s.contains("\"transport\": \"socket\""));
        assert!(s.contains("\"p95_ms\": 12.250"));
        assert!(s.contains("\"p99_ms\": 14.500"));
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn kernels_json_is_well_formed_ish() {
        let spmm = vec![KernelRow {
            engine: "groot",
            scalar_median_s: 0.004,
            simd_median_s: 0.002,
            speedup: 2.0,
        }];
        let pair = PairRow {
            name: "matmul_add",
            base_median_s: 0.01,
            fast_median_s: 0.004,
            speedup: 2.5,
        };
        let s = render_kernels_json(64, 37000, 12, 34000, "avx2", &spmm, &[&pair]);
        assert!(s.contains("\"bench\": \"kernels\""));
        assert!(s.contains("\"simd\": \"avx2\""));
        assert!(s.contains("\"hd_rows\": 12"));
        assert!(s.contains("\"engine\": \"groot\""));
        assert!(s.contains("\"speedup\": 2.500"));
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn train_json_is_well_formed_ish() {
        let rows = vec![TrainBenchRow {
            dataset: "csa8".into(),
            nodes: 600,
            partitions: 4,
            epochs: 2,
            epoch_median_s: 0.01,
            knodes_per_s: 60.0,
            first_loss: 1.6,
            final_loss: 1.2,
        }];
        let s = render_train_json(&rows);
        assert!(s.contains("\"bench\": \"train_epoch\""));
        assert!(s.contains("\"final_loss\": 1.200000"));
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn plan_json_is_well_formed_ish() {
        let rows = vec![PlanBenchRow {
            dataset: "csa256".into(),
            nodes: 150_000,
            partitions: 24,
            threads: 4,
            cold_median_s: 0.25,
            cold_p95_s: 0.3,
            speedup_vs_1t: 2.5,
            store_warm_median_s: 0.01,
            edge_cut: 1234,
            replication: 1.08,
            balance: 1.05,
        }];
        let s = render_plan_json(&rows);
        assert!(s.contains("\"bench\": \"plan_build\""));
        assert!(s.contains("\"threads\": 4"));
        assert!(s.contains("\"speedup_vs_1t\": 2.500"));
        assert!(s.contains("\"edge_cut\": 1234"));
        assert!(s.contains("\"replication\": 1.0800"));
        assert!(s.contains("\"store_warm_median_s\": 0.010000"));
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn synthetic_model_shapes_line_up() {
        let m = synthetic_model();
        assert_eq!(m.input_dim(), 4);
        assert_eq!(m.num_classes(), 5);
    }
}

//! Accuracy harnesses: Fig. 6 (accuracy vs #partitions, ± re-growth),
//! Fig. 7 (FPGA dataset, 8-bit vs 64-bit training), and two ablations
//! (partitioner choice, GROOT vs GAMORA features) DESIGN.md calls out.

use super::{native_model, Table};
use crate::coordinator::{PlanOptions, PreparedGraph, Session, SessionConfig};
use crate::datasets::{self, DatasetKind};
use anyhow::Result;

fn widths_for(kind: DatasetKind, quick: bool) -> Vec<usize> {
    match (kind, quick) {
        (DatasetKind::Fpga4Lut, true) => vec![8, 16],
        (DatasetKind::Fpga4Lut, false) => vec![8, 16, 32, 64],
        (_, true) => vec![16, 32],
        (_, false) => vec![16, 32, 64, 128],
    }
}

fn partition_counts(quick: bool) -> Vec<usize> {
    if quick {
        vec![1, 4, 16]
    } else {
        vec![1, 2, 4, 8, 16, 32, 64]
    }
}

/// Weights trained on the 8-bit design of the SAME family (the paper's
/// Fig. 6 caption: "All the multipliers were trained using 8-bits"),
/// falling back to the csa8 bundle when the family bundle is absent.
fn family_weights(default: &str, kind: DatasetKind) -> String {
    let family = match kind {
        DatasetKind::Booth => "artifacts/weights_booth8.bin",
        DatasetKind::Mapped7nm => "artifacts/weights_7nm8.bin",
        DatasetKind::Fpga4Lut => "artifacts/weights_fpga8.bin",
        _ => return default.to_string(),
    };
    if std::path::Path::new(family).exists() {
        family.to_string()
    } else {
        default.to_string()
    }
}

/// Fig. 6: accuracy vs number of partitions, dashed (no re-growth) and
/// solid (re-grown) series, model trained on the family's 8-bit design.
pub fn fig6(weights: &str, kind: DatasetKind, batch: usize, quick: bool) -> Result<()> {
    let weights = family_weights(weights, kind);
    let model = native_model(&weights)?;
    let mut t = Table::new(
        format!(
            "Fig 6 ({}) — accuracy vs #partitions, batch {batch}, trained on {weights}",
            kind.name()
        ),
        &["bits", "partitions", "acc (cut only)", "acc (re-grown)", "recovery"],
    );
    // One backend for the whole figure; one PreparedGraph (CSR + features
    // + fingerprint) per width — each sweep cell only plans + executes.
    let session = Session::native(model, SessionConfig::default());
    for bits in widths_for(kind, quick) {
        let graph = datasets::build(kind, bits)?.replicate(batch);
        let prepared = PreparedGraph::new(&graph);
        for parts in partition_counts(quick) {
            let mut acc = [0.0f64; 2];
            for (i, regrow) in [false, true].into_iter().enumerate() {
                let plan =
                    prepared.plan(&PlanOptions { partitions: parts, regrow, ..Default::default() });
                acc[i] = session.classify_plan(&prepared, &plan, false)?.accuracy;
            }
            t.row(vec![
                bits.to_string(),
                parts.to_string(),
                format!("{:.4}", acc[0]),
                format!("{:.4}", acc[1]),
                format!("{:+.2}%", 100.0 * (acc[1] - acc[0])),
            ]);
        }
    }
    t.print();
    Ok(())
}

/// Fig. 7: FPGA-mapped accuracy with 8-bit-trained vs 64-bit-trained
/// weights (the paper's +18.98% headline for 64-bit training).
pub fn fig7(weights_8: &str, weights_fpga64: &str, quick: bool) -> Result<()> {
    // paper fig 7a: trained on the FPGA family's own 8-bit design
    let w8 = family_weights(weights_8, DatasetKind::Fpga4Lut);
    let m8 = native_model(&w8)?;
    let m64 = native_model(weights_fpga64).ok();
    let mut t = Table::new(
        format!("Fig 7 — FPGA 4-LUT dataset: 8-bit ({w8}) vs 64-bit training"),
        &["bits", "partitions", "acc (8b-trained)", "acc (fpga64-trained)", "boost"],
    );
    let parts_list = if quick { vec![1, 8] } else { vec![1, 2, 4, 8, 16] };
    // Two sessions (one per training run) share every plan: the partition
    // structure depends only on the graph, not on the weights.
    let s8 = Session::native(m8, SessionConfig::default());
    let s64 = m64.map(|m| Session::native(m, SessionConfig::default()));
    for bits in widths_for(DatasetKind::Fpga4Lut, quick) {
        let graph = datasets::build(DatasetKind::Fpga4Lut, bits)?;
        let prepared = PreparedGraph::new(&graph);
        for &parts in &parts_list {
            let plan =
                prepared.plan(&PlanOptions { partitions: parts, ..Default::default() });
            let a8 = s8.classify_plan(&prepared, &plan, false)?.accuracy;
            let (a64s, boost) = match &s64 {
                Some(s) => {
                    let a = s.classify_plan(&prepared, &plan, false)?.accuracy;
                    (format!("{a:.4}"), format!("{:+.2}%", 100.0 * (a - a8)))
                }
                None => ("(weights_fpga64.bin missing)".into(), "-".into()),
            };
            t.row(vec![
                bits.to_string(),
                parts.to_string(),
                format!("{a8:.4}"),
                a64s,
                boost,
            ]);
        }
    }
    t.print();
    Ok(())
}

/// Ablation: multilevel vs BFS vs random partitioning at equal k — the
/// DESIGN.md design-choice bench (cut quality → accuracy after regrowth).
pub fn ablation_partitioners(weights: &str, quick: bool) -> Result<()> {
    use crate::graph::Csr;
    use crate::partition::{partition_bfs, partition_kway, partition_random};
    use crate::regrowth::regrow_partitions;

    let model = native_model(weights)?;
    let bits = if quick { 16 } else { 32 };
    let graph = datasets::build(DatasetKind::Csa, bits)?;
    let csr = Csr::symmetric_from_edges(graph.num_nodes, &graph.edges);
    let mut t = Table::new(
        format!("Ablation — partitioner choice (csa{bits}, k=8)"),
        &["partitioner", "edge cut", "boundary nodes", "acc (cut only)", "acc (re-grown)"],
    );
    let k = 8;
    let parts: Vec<(&str, crate::partition::Partitioning)> = vec![
        ("multilevel", partition_kway(&csr, k, 0)),
        ("bfs-chunks", partition_bfs(&csr, k)),
        ("random", partition_random(csr.num_nodes(), k, 0)),
    ];
    for (name, p) in parts {
        let cut = p.edge_cut(&csr);
        let stats = crate::regrowth::stats(&regrow_partitions(&csr, &p, true));
        // run the pipeline with this fixed partitioning via a session that
        // reuses the assignment (emulated by classifying per partitioning
        // through the internal path: use Session with the same k/seed for
        // multilevel; for others compute directly).
        let acc = |regrow: bool| -> Result<f64> {
            let rparts = regrow_partitions(&csr, &p, regrow);
            let mut pred = vec![0u8; graph.num_nodes];
            for part in &rparts {
                if part.nodes.is_empty() {
                    continue;
                }
                let local = part.csr();
                let mut feats = Vec::with_capacity(part.nodes.len() * 4);
                for &g in &part.nodes {
                    feats.extend_from_slice(&graph.features[g as usize]);
                }
                let engine = crate::spmm::GrootSpmm::new(crate::util::pool::default_threads());
                let local_pred = model.predict(&local, &feats, &engine);
                for (i, &gid) in part.nodes[..part.num_core].iter().enumerate() {
                    pred[gid as usize] = local_pred[i];
                }
            }
            Ok(crate::gnn::accuracy(&pred, &graph.labels_u8()))
        };
        t.row(vec![
            name.to_string(),
            cut.to_string(),
            stats.total_boundary_nodes.to_string(),
            format!("{:.4}", acc(false)?),
            format!("{:.4}", acc(true)?),
        ]);
    }
    t.print();
    Ok(())
}

/// Ablation: GROOT 4-dim features vs GAMORA 3-dim features. Requires the
/// GAMORA-trained weights bundle (artifacts/weights_gamora.bin, trained by
/// `compile.train --features gamora`); prints what it can otherwise.
pub fn ablation_features(weights: &str, quick: bool) -> Result<()> {
    let model = native_model(weights)?;
    let gamora = native_model("artifacts/weights_gamora.bin").ok();
    let bits_list = if quick { vec![16] } else { vec![16, 32, 64] };
    let mut t = Table::new(
        "Ablation — GROOT 4-dim vs GAMORA 3-dim node features",
        &["bits", "acc (groot 4f)", "acc (gamora 3f)"],
    );
    for bits in bits_list {
        let graph = datasets::build(DatasetKind::Csa, bits)?;
        let session = Session::native(model.clone(), SessionConfig::default());
        let a4 = session.classify(&graph)?.accuracy;
        let a3 = match &gamora {
            Some(m) => {
                // GAMORA features: re-encode graph features as 3-dim padded
                // to 4 (model trained with the same padding).
                let mut g3 = graph.clone();
                for (f, g) in g3.features.iter_mut().zip(graph.gamora_features()) {
                    *f = [g[0], g[1], g[2], 0.0];
                }
                let s = Session::native(m.clone(), SessionConfig::default());
                format!("{:.4}", s.classify(&g3)?.accuracy)
            }
            None => "(weights_gamora.bin missing)".into(),
        };
        t.row(vec![bits.to_string(), format!("{a4:.4}"), a3]);
    }
    t.print();
    Ok(())
}

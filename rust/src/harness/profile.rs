//! Kernel-level HD/LD profile report — `groot harness profile`.
//!
//! Runs the full classify pipeline a few times and reports metrics
//! registry deltas: per-kernel (HD vs LD) call count, wall time, rows
//! and nonzeros — the paper's degree-polarization evidence measured
//! from the runtime itself rather than from a static graph scan — plus
//! every other pipeline counter the run touched. Works without trained
//! artifacts (synthetic model): the report profiles kernels, not
//! accuracy.

use super::Table;
use crate::coordinator::{Session, SessionConfig};
use crate::datasets::{self, DatasetKind};
use crate::obs::metrics;
use crate::util::timer::fmt_dur;
use anyhow::Result;
use std::collections::BTreeMap;
use std::time::Instant;

/// Flatten the registry to `name{k=v,...}` → value for delta arithmetic.
fn snapshot() -> BTreeMap<String, f64> {
    metrics::registry()
        .samples()
        .into_iter()
        .map(|s| {
            let labels = s
                .labels
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(",");
            (format!("{}{{{labels}}}", s.name), s.value)
        })
        .collect()
}

pub fn profile(weights: &str, quick: bool) -> Result<()> {
    let model =
        super::native_model(weights).unwrap_or_else(|_| super::bench::synthetic_model());
    let (bits, reps) = if quick { (16usize, 3usize) } else { (32, 10) };
    let graph = datasets::build(DatasetKind::Csa, bits)?;
    let session = Session::native(
        model,
        SessionConfig { num_partitions: 8, ..Default::default() },
    );

    let before = snapshot();
    let t0 = Instant::now();
    for _ in 0..reps {
        session.classify(&graph)?;
    }
    let wall = t0.elapsed();
    let after = snapshot();
    let delta = |key: &str| -> f64 {
        after.get(key).copied().unwrap_or(0.0) - before.get(key).copied().unwrap_or(0.0)
    };

    println!(
        "profile: csa{bits} ({} nodes), {reps} classify runs, wall {}",
        graph.num_nodes,
        fmt_dur(wall)
    );

    let kernel_secs =
        |k: &str| -> f64 { delta(&format!("groot_kernel_seconds_sum{{kernel={k}}}")) };
    let total_kernel_s = kernel_secs("ld") + kernel_secs("hd");
    let mut t = Table::new(
        "HD/LD kernel profile — registry deltas over the run",
        &["kernel", "calls", "time", "share", "rows", "nnz", "ns/nnz"],
    );
    for kernel in ["hd", "ld"] {
        let secs = kernel_secs(kernel);
        let calls = delta(&format!("groot_kernel_seconds_count{{kernel={kernel}}}"));
        let rows = delta(&format!("groot_kernel_rows_total{{kernel={kernel}}}"));
        let nnz = delta(&format!("groot_kernel_nnz_total{{kernel={kernel}}}"));
        t.row(vec![
            kernel.to_uppercase(),
            format!("{calls:.0}"),
            format!("{:.3} ms", secs * 1e3),
            format!(
                "{:.0}%",
                if total_kernel_s > 0.0 { 100.0 * secs / total_kernel_s } else { 0.0 }
            ),
            format!("{rows:.0}"),
            format!("{nnz:.0}"),
            format!("{:.1}", if nnz > 0.0 { secs * 1e9 / nnz } else { 0.0 }),
        ]);
    }
    t.print();

    // Everything else the run touched: nonzero non-kernel deltas. Bucket
    // samples are cumulative duplicates of `_count`, so skip them.
    let mut c = Table::new("Pipeline counter deltas", &["metric", "delta"]);
    for (key, after_v) in &after {
        if key.contains("_bucket{") || key.starts_with("groot_kernel_") {
            continue;
        }
        let d = after_v - before.get(key).copied().unwrap_or(0.0);
        if d != 0.0 {
            c.row(vec![key.clone(), format!("{d:.3}")]);
        }
    }
    c.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_runs_and_observes_kernels() {
        let before = snapshot();
        profile("/nonexistent/weights.bin", true).expect("profile harness failed");
        let after = snapshot();
        let key = "groot_kernel_seconds_count{kernel=ld}";
        let d = after.get(key).copied().unwrap_or(0.0)
            - before.get(key).copied().unwrap_or(0.0);
        assert!(d > 0.0, "profile run recorded no LD kernel calls");
    }
}

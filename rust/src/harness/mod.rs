//! Experiment harness — one entry per table/figure in the paper's
//! evaluation (see DESIGN.md §Experiment-index). Each function prints the
//! same rows/series the paper reports; the `cargo bench` targets and the
//! `groot harness <id>` CLI both route here.
//!
//! Scale policy: the paper's largest workloads (1024-bit × batch 16) do
//! not fit this CPU-only container. Every harness sweeps the widest
//! configuration that fits and, where the paper's absolute scale matters
//! (Tab. II, Fig. 1a), prints model-extrapolated rows next to measured
//! ones, clearly marked.

pub mod accuracy;
pub mod bench;
pub mod incremental;
pub mod memory;
pub mod profile;
pub mod runtime;

use crate::util::cli::Args;
use anyhow::{bail, Result};

/// Dispatch a harness target by figure/table id.
pub fn run(which: &str, args: &mut Args) -> Result<()> {
    let quick = args.flag("quick");
    let weights = args.get_or("weights", "artifacts/weights_csa8.bin");
    match which {
        "fig1a" => memory::fig1a(),
        "fig6a" => accuracy::fig6(&weights, crate::datasets::DatasetKind::Csa, 1, quick),
        "fig6b" => accuracy::fig6(&weights, crate::datasets::DatasetKind::Csa, 4, quick),
        "fig6c" => accuracy::fig6(&weights, crate::datasets::DatasetKind::Booth, 1, quick),
        "fig6d" => accuracy::fig6(&weights, crate::datasets::DatasetKind::Mapped7nm, 1, quick),
        "fig6" => {
            accuracy::fig6(&weights, crate::datasets::DatasetKind::Csa, 1, quick)?;
            accuracy::fig6(&weights, crate::datasets::DatasetKind::Csa, 4, quick)?;
            accuracy::fig6(&weights, crate::datasets::DatasetKind::Booth, 1, quick)?;
            accuracy::fig6(&weights, crate::datasets::DatasetKind::Mapped7nm, 1, quick)
        }
        "fig7" => accuracy::fig7(
            &weights,
            &args.get_or("weights-fpga", "artifacts/weights_fpga64.bin"),
            quick,
        ),
        "fig8" => memory::fig8(quick),
        "tab2" => memory::tab2(),
        "memory" => {
            let out = args.get_or("out", "BENCH_memory.json");
            memory::bench_memory(quick, &out)
        }
        "fig9" => runtime::fig9(quick),
        "fig10" => runtime::fig10(&weights, quick),
        "bench" => {
            if args.flag("train") {
                let out = args.get_or("out", "BENCH_train.json");
                bench::bench_train(quick, &out)
            } else if args.flag("serve") {
                let out = args.get_or("out", "BENCH_serve.json");
                let workers = args.parse_or("workers", 0usize)?;
                bench::bench_serve(&weights, quick, &out, (workers > 0).then_some(workers))
            } else if args.flag("kernels") {
                let out = args.get_or("out", "BENCH_kernels.json");
                let min = args.parse_or("assert-simd-speedup", 0.0f64)?;
                bench::bench_kernels(&weights, quick, &out, (min > 0.0).then_some(min))
            } else if args.flag("plan") {
                let out = args.get_or("out", "BENCH_plan.json");
                let min = args.parse_or("assert-plan-speedup", 0.0f64)?;
                bench::bench_plan(quick, &out, (min > 0.0).then_some(min))
            } else {
                let out = args.get_or("out", "BENCH_pipeline.json");
                bench::bench_pipeline(&weights, quick, &out)
            }
        }
        "profile" => profile::profile(&weights, quick),
        "incremental" => {
            let out = args.get_or("out", "BENCH_incremental.json");
            incremental::bench_incremental(&weights, quick, &out)
        }
        "ablation-partitioners" => accuracy::ablation_partitioners(&weights, quick),
        "ablation-features" => accuracy::ablation_features(&weights, quick),
        other => bail!(
            "unknown harness '{other}' \
             (fig1a|fig6a..d|fig7|fig8|fig9|fig10|tab2|bench|memory|profile|incremental|\
              ablation-partitioners|ablation-features)"
        ),
    }
}

/// Markdown-ish table printer shared by harnesses.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                } else {
                    widths.push(c.len());
                }
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                let w = widths.get(i).copied().unwrap_or(c.len());
                s.push_str(&format!("{c:<w$} | "));
            }
            s
        };
        println!("{}", fmt_row(&self.header));
        println!(
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        );
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

/// Load a weight bundle into a native-backend model.
pub fn native_model(weights_path: &str) -> Result<crate::gnn::SageModel> {
    let bundle = crate::util::tensor::read_bundle(std::path::Path::new(weights_path))?;
    crate::gnn::SageModel::from_bundle(&bundle)
}

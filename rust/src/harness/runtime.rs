//! Runtime harnesses: Fig. 9 (SpMM kernel comparison) and Fig. 10
//! (verification time GROOT vs GAMORA vs ABC).

use super::{native_model, Table};
use crate::coordinator::{PlanOptions, PreparedGraph, Session, SessionConfig};
use crate::datasets::{self, DatasetKind};
use crate::spmm::{all_engines, SpmmEngine};
use crate::util::rng::Rng;
use crate::util::timer::{bench_for, fmt_dur};
use anyhow::Result;
use std::time::Duration;

/// Fig. 9 — SpMM runtime of GROOT-GPU vs cuSPARSE-like, MergePath-SpMM,
/// and GNNAdvisor-like, on Booth / 7nm-mapped / FPGA graphs, embedding
/// dim 32; accelerations are reported relative to GNNAdvisor (the paper's
/// dashed line at 1.0).
pub fn fig9(quick: bool) -> Result<()> {
    let dim = 32;
    let widths: Vec<usize> = if quick { vec![64, 128] } else { vec![64, 128, 256, 512] };
    let kinds = [DatasetKind::Booth, DatasetKind::Mapped7nm, DatasetKind::Fpga4Lut];
    let threads = crate::util::pool::default_threads();
    // The paper's comparison is about load balance across parallel lanes.
    // This container exposes a single CPU, so we report BOTH the measured
    // serial time (per-element efficiency: layout, overhead, cache) AND
    // each strategy's analytic makespan on `lanes` parallel workers — the
    // exact quantity GPU speedups derive from (see SpmmEngine::worker_loads).
    let lanes = 256usize;
    let budget = Duration::from_millis(if quick { 150 } else { 600 });
    let mut t = Table::new(
        format!(
            "Fig 9 — SpMM, dim {dim}: measured serial time + {lanes}-lane balance \
             (ratios vs gnnadvisor; >1 = faster)"
        ),
        &["dataset", "bits", "nnz", "engine", "serial median", "serial ratio",
          "imbalance", "makespan ratio", "combined ratio"],
    );
    // (dataset label, bits, batch) — the ×16 batched rows share PI nodes,
    // creating the paper's degree-≥512 macro rows the HD kernel targets.
    let mut cases: Vec<(String, DatasetKind, usize, usize)> = Vec::new();
    for kind in kinds {
        for &bits in &widths {
            // 7nm/FPGA mapping at 512 bits is slow to build in quick runs
            if quick && kind != DatasetKind::Booth && bits > 128 {
                continue;
            }
            cases.push((kind.name().to_string(), kind, bits, 1));
        }
    }
    cases.push(("booth x16".into(), DatasetKind::Booth, if quick { 64 } else { 128 }, 16));
    for (label, kind, bits, batch) in cases {
        {
            let graph = datasets::build(kind, bits)?.replicate_shared_inputs(batch);
            // stage-1 of the pipeline builds the symmetric closure the
            // kernels aggregate over (same CSR the classify path uses)
            let prepared = PreparedGraph::new(&graph);
            let csr = prepared.csr();
            let mut rng = Rng::new(9);
            let x: Vec<f32> = (0..csr.num_nodes() * dim).map(|_| rng.f32()).collect();
            let engines = all_engines(threads);
            let mut medians = Vec::new();
            let mut makespans = Vec::new();
            // reused output buffer: time the in-place hot path, not the
            // allocating convenience wrapper
            let mut out = vec![0.0f32; csr.num_nodes() * dim];
            for e in &engines {
                let stats = bench_for(budget, || e.spmm_mean_into(csr, &x, dim, &mut out));
                medians.push(stats.median_secs());
                makespans.push(crate::spmm::balance_report(e.as_ref(), csr, lanes));
            }
            let adv_serial = medians[2];
            let adv_span = makespans[2].makespan.max(1) as f64;
            for (i, e) in engines.iter().enumerate() {
                // predicted parallel time ∝ serial per-nnz cost × makespan
                let per_nnz = medians[i] / csr.num_entries().max(1) as f64;
                let combined = (adv_serial / csr.num_entries().max(1) as f64) * adv_span
                    / (per_nnz * makespans[i].makespan.max(1) as f64);
                t.row(vec![
                    label.clone(),
                    bits.to_string(),
                    csr.num_entries().to_string(),
                    e.name().into(),
                    fmt_dur(Duration::from_secs_f64(medians[i])),
                    format!("{:.2}x", adv_serial / medians[i]),
                    format!("{:.2}", makespans[i].imbalance),
                    format!("{:.2}x", adv_span / makespans[i].makespan.max(1) as f64),
                    format!("{combined:.2}x"),
                ]);
            }
        }
    }
    t.print();
    println!(
        "paper shape: groot-gpu leads in most cells and the gap widens with\n\
         bit width (paper peak: 10.28x on booth-512 vs gnnadvisor).\n\
         serial ratio = per-element efficiency (1 CPU); makespan ratio =\n\
         {lanes}-lane load balance; combined = their product (GPU-analogue)."
    );
    Ok(())
}

/// Fig. 10 — verification time: GROOT pipeline (partitioned GNN +
/// algebraic check) vs GAMORA-like (full-graph GNN + same check) vs the
/// ABC-like structural baseline, plus the published ABC curve the paper
/// compares against (this container cannot run days-long ABC jobs).
pub fn fig10(weights: &str, quick: bool) -> Result<()> {
    let model = native_model(weights)?;
    let widths: Vec<usize> = if quick { vec![16, 32] } else { vec![16, 32, 64, 128] };
    let mut t = Table::new(
        "Fig 10 — CSA verification time",
        &[
            "bits",
            "groot (64 parts)",
            "groot acc",
            "gamora-like (full)",
            "abc-like (measured)",
            "abc (published curve)",
            "groot vs abc-pub",
        ],
    );
    let session = Session::native(model, SessionConfig::default());
    for bits in widths {
        let graph = datasets::build(DatasetKind::Csa, bits)?;
        let aig = crate::aig::mult::csa_multiplier(bits);

        // Cold end-to-end timing per row: prepare + plan + batched
        // execute + algebraic check (the staged pipeline, uncached).
        let run = |parts: usize| -> Result<(f64, f64, bool)> {
            let t0 = std::time::Instant::now();
            let prepared = PreparedGraph::new(&graph);
            let plan =
                prepared.plan(&PlanOptions { partitions: parts, ..Default::default() });
            let res = session.classify_plan(&prepared, &plan, false)?;
            let outcome = crate::verify::verify_multiplier(&aig, &graph, &res.pred)?;
            Ok((t0.elapsed().as_secs_f64(), res.accuracy, outcome.equivalent))
        };
        let parts = 64.min(graph.num_nodes / 4).max(1);
        let (groot_s, acc, eq) = run(parts)?;
        let (gamora_s, _, _) = run(1)?;
        let t0 = std::time::Instant::now();
        let abc = crate::verify::abc_like::verify_structural(&aig, 4_000_000);
        let abc_s = t0.elapsed().as_secs_f64();
        let abc_pub = crate::verify::abc_like::abc_published_runtime_secs(bits);
        t.row(vec![
            bits.to_string(),
            format!("{groot_s:.3}s{}", if eq { "" } else { " (!)" }),
            format!("{acc:.4}"),
            format!("{gamora_s:.3}s"),
            format!(
                "{abc_s:.3}s{}",
                if abc.outcome.equivalent { "" } else { " (!)" }
            ),
            format!("{abc_pub:.1}s"),
            format!("{:.0}x", abc_pub / groot_s),
        ]);
    }
    t.print();
    println!(
        "paper shape: ABC grows super-polynomially (1.23e5x at 1024-bit/64\n\
         parts); GROOT tracks GAMORA with a small partitioning overhead."
    );
    Ok(())
}

//! Tiny binary tensor interchange format shared with the python compile
//! path (serde/npz are unavailable offline). `python/compile/tensor_io.py`
//! implements the same layout.
//!
//! Bundle file layout (little-endian):
//! ```text
//! magic  b"GRTW"
//! u32    version (1)
//! u32    tensor count
//! per tensor:
//!   u16   name length, then name bytes (utf-8)
//!   u8    dtype (0 = f32, 1 = i32)
//!   u8    ndim
//!   u64 × ndim   dims
//!   bytes        row-major data
//! ```

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"GRTW";

/// A named dense tensor (f32 only is needed on the rust side; i32 is kept
/// for completeness of the interchange format).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: TensorData,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data: TensorData::F32(data) }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data: TensorData::I32(data) }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// An ordered name → tensor map (BTreeMap so serialization is canonical).
pub type Bundle = BTreeMap<String, Tensor>;

pub fn write_bundle(path: &Path, bundle: &Bundle) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&1u32.to_le_bytes());
    buf.extend_from_slice(&(bundle.len() as u32).to_le_bytes());
    for (name, t) in bundle {
        let nb = name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        buf.extend_from_slice(nb);
        let (dtype, payload): (u8, Vec<u8>) = match &t.data {
            TensorData::F32(v) => (0, v.iter().flat_map(|x| x.to_le_bytes()).collect()),
            TensorData::I32(v) => (1, v.iter().flat_map(|x| x.to_le_bytes()).collect()),
        };
        buf.push(dtype);
        buf.push(t.dims.len() as u8);
        for d in &t.dims {
            buf.extend_from_slice(&(*d as u64).to_le_bytes());
        }
        buf.extend_from_slice(&payload);
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    f.write_all(&buf)?;
    Ok(())
}

pub fn read_bundle(path: &Path) -> Result<Bundle> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?
        .read_to_end(&mut bytes)?;
    parse_bundle(&bytes).with_context(|| format!("parse {}", path.display()))
}

pub fn parse_bundle(bytes: &[u8]) -> Result<Bundle> {
    let mut off = 0usize;
    let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
        if *off + n > bytes.len() {
            bail!("truncated bundle at offset {off}");
        }
        let s = &bytes[*off..*off + n];
        *off += n;
        Ok(s)
    };
    if take(&mut off, 4)? != MAGIC {
        bail!("bad magic");
    }
    let version = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap());
    if version != 1 {
        bail!("unsupported bundle version {version}");
    }
    let count = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap());
    let mut out = Bundle::new();
    for _ in 0..count {
        let name_len = u16::from_le_bytes(take(&mut off, 2)?.try_into().unwrap()) as usize;
        let name = String::from_utf8(take(&mut off, name_len)?.to_vec())?;
        let dtype = take(&mut off, 1)?[0];
        let ndim = take(&mut off, 1)?[0] as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let d = u64::from_le_bytes(take(&mut off, 8)?.try_into().unwrap());
            let d = usize::try_from(d).map_err(|_| {
                anyhow::anyhow!("tensor '{name}': dim {d} exceeds this platform's address space")
            })?;
            dims.push(d);
        }
        // Checked shape arithmetic: a corrupt header whose dims product
        // wraps could otherwise claim a tiny payload and silently parse
        // garbage into a "valid" tensor. Zero-sized tensors are rejected
        // outright — no writer produces them and every reader (model
        // loading, checkpoint resume) would only break later and worse.
        let numel = dims
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .with_context(|| format!("tensor '{name}': dims {dims:?} overflow"))?;
        anyhow::ensure!(numel > 0, "tensor '{name}': zero-sized (dims {dims:?})");
        let nbytes = numel
            .checked_mul(4)
            .with_context(|| format!("tensor '{name}': byte size overflows"))?;
        let data = match dtype {
            0 => {
                let raw = take(&mut off, nbytes).with_context(|| {
                    format!("tensor '{name}': payload for dims {dims:?} truncated")
                })?;
                TensorData::F32(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            1 => {
                let raw = take(&mut off, nbytes).with_context(|| {
                    format!("tensor '{name}': payload for dims {dims:?} truncated")
                })?;
                TensorData::I32(
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            _ => bail!("unknown dtype {dtype}"),
        };
        out.insert(name, Tensor { dims, data });
    }
    anyhow::ensure!(
        off == bytes.len(),
        "{} trailing bytes after the last declared tensor (corrupt or \
         mis-declared bundle)",
        bytes.len() - off
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bundle() {
        let mut b = Bundle::new();
        b.insert("w1".into(), Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        b.insert("idx".into(), Tensor::i32(vec![4], vec![-1, 0, 7, 42]));
        let dir = std::env::temp_dir().join("groot_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.bin");
        write_bundle(&path, &b).unwrap();
        let b2 = read_bundle(&path).unwrap();
        assert_eq!(b, b2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_bundle(b"nope").is_err());
        assert!(parse_bundle(b"GRTW\x01\x00\x00\x00").is_err());
    }

    /// Serialize a bundle to bytes (the write path without the file).
    /// `stem` keeps parallel tests off each other's temp files.
    fn bundle_bytes(b: &Bundle, stem: &str) -> Vec<u8> {
        let dir = std::env::temp_dir().join("groot_tensor_hardening");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{stem}.bin"));
        write_bundle(&path, b).unwrap();
        std::fs::read(&path).unwrap()
    }

    #[test]
    fn rejects_truncated_payload() {
        let mut b = Bundle::new();
        b.insert("w".into(), Tensor::f32(vec![4, 4], vec![1.0; 16]));
        let bytes = bundle_bytes(&b, "truncated");
        // chop mid-payload: declared dims no longer match what's on disk
        let err = parse_bundle(&bytes[..bytes.len() - 7]).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut b = Bundle::new();
        b.insert("w".into(), Tensor::f32(vec![2], vec![1.0, 2.0]));
        let mut bytes = bundle_bytes(&b, "trailing");
        bytes.extend_from_slice(&[0xAB, 0xCD]);
        let err = parse_bundle(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
    }

    #[test]
    fn rejects_zero_sized_tensor() {
        // Hand-build a header declaring dims [0] — no writer produces
        // this, so the parser must refuse rather than yield an empty
        // tensor checkpoint loading trips over later.
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u16.to_le_bytes());
        bytes.push(b'z');
        bytes.push(0); // dtype f32
        bytes.push(1); // ndim
        bytes.extend_from_slice(&0u64.to_le_bytes()); // dim 0
        let err = parse_bundle(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("zero-sized"), "{err:#}");
    }

    #[test]
    fn rejects_overflowing_dims_product() {
        // dims [2^40, 2^40] — the product wraps usize; the old parser
        // could end up asking for a tiny payload and "succeeding".
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u16.to_le_bytes());
        bytes.push(b'w');
        bytes.push(0); // dtype f32
        bytes.push(2); // ndim
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes());
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes());
        assert!(parse_bundle(&bytes).is_err());
    }

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::f32(vec![2, 2], vec![0.0; 4]);
        assert_eq!(t.numel(), 4);
        assert!(t.as_i32().is_err());
    }
}

//! Runtime-dispatched SIMD kernels for the SpMM / GEMM hot loops.
//!
//! Every primitive here exists in two byte-identical implementations: a
//! portable scalar form (the reference, always compiled) and an AVX2 form
//! (x86_64 only, selected at runtime via `is_x86_feature_detected!`). The
//! dispatch ladder is
//!
//! ```text
//! GROOT_SIMD=scalar env / force_scalar(true)  →  scalar
//! x86_64 with AVX2 detected                   →  avx2
//! anything else                               →  scalar
//! ```
//!
//! **Determinism contract.** The AVX2 kernels are bit-for-bit identical to
//! the scalar reference, not merely close. Two rules make this hold:
//!
//! 1. *No FMA.* `mul` then `add` round separately in the scalar code, so
//!    the vector code uses `_mm256_add_ps(acc, _mm256_mul_ps(..))` — never
//!    `_mm256_fmadd_ps`, which rounds once and drifts.
//! 2. *Fixed accumulation order.* Vector lanes span the feature dimension
//!    (`d` / output column `j`); the reduction order per output element —
//!    over neighbors / over `k` — is exactly the scalar loop order. Lanes
//!    never sum across the reduction axis, so no re-association happens.
//!
//! The scalar twins are `pub` so parity tests and the bench harness can
//! pin the dispatched output against them; [`force_scalar`] flips the
//! whole process to the scalar path for same-binary A/B timing.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// 0 = auto (detect), 1 = forced scalar.
static FORCE: AtomicU8 = AtomicU8::new(0);
static ENV_INIT: OnceLock<()> = OnceLock::new();

fn env_init() {
    ENV_INIT.get_or_init(|| {
        if std::env::var("GROOT_SIMD").as_deref() == Ok("scalar") {
            FORCE.store(1, Ordering::Relaxed);
        }
    });
}

/// Force (or un-force) the scalar path process-wide. Used by the bench
/// harness and parity tests to time/compare both implementations in one
/// process; overrides the `GROOT_SIMD` env once called.
pub fn force_scalar(on: bool) {
    env_init();
    FORCE.store(u8::from(on), Ordering::Relaxed);
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    static DETECT: OnceLock<bool> = OnceLock::new();
    *DETECT.get_or_init(|| std::is_x86_feature_detected!("avx2"))
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

#[inline]
fn use_avx2() -> bool {
    env_init();
    FORCE.load(Ordering::Relaxed) == 0 && avx2_available()
}

/// The instruction set the dispatcher would pick right now
/// (`"avx2"` or `"scalar"`). Reported by `plan_stats` consumers and
/// BENCH_kernels.json so a scalar-only run is visible in artifacts.
pub fn active() -> &'static str {
    if use_avx2() {
        "avx2"
    } else {
        "scalar"
    }
}

// ---------------------------------------------------------------------------
// gather_sum: orow[d] += Σ_{c ∈ cols} x[c*dim + d]
// ---------------------------------------------------------------------------

/// Unweighted neighbor gather: accumulate each column's feature row into
/// `orow`, in `cols` order. The forward-SpMM inner loop (mean scale is
/// applied afterwards by [`scale_assign`]).
#[inline]
pub fn gather_sum(x: &[f32], dim: usize, cols: &[u32], orow: &mut [f32]) {
    debug_assert_eq!(orow.len(), dim);
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 support was just detected at runtime.
        unsafe { gather_sum_avx2(x, dim, cols, orow) };
        return;
    }
    gather_sum_scalar(x, dim, cols, orow);
}

/// Scalar reference for [`gather_sum`]. Const-dim specializations for the
/// model's dims keep the accumulator in registers instead of bouncing
/// through the output row per neighbor (§Perf: +35% on booth128/dim32 —
/// predates the AVX2 path but still carries the portable fallback).
#[inline]
pub fn gather_sum_scalar(x: &[f32], dim: usize, cols: &[u32], orow: &mut [f32]) {
    match dim {
        4 => gather_sum_const::<4>(x, cols, orow),
        8 => gather_sum_const::<8>(x, cols, orow),
        16 => gather_sum_const::<16>(x, cols, orow),
        32 => gather_sum_const::<32>(x, cols, orow),
        64 => gather_sum_const::<64>(x, cols, orow),
        _ => {
            for &c in cols {
                let xrow = &x[c as usize * dim..(c as usize + 1) * dim];
                for (o, &v) in orow.iter_mut().zip(xrow) {
                    *o += v;
                }
            }
        }
    }
}

#[inline]
fn gather_sum_const<const DIM: usize>(x: &[f32], cols: &[u32], orow: &mut [f32]) {
    let mut acc: [f32; DIM] = orow[..DIM].try_into().unwrap();
    // NOTE §Perf: a software-prefetch variant (_mm_prefetch of the k+4th
    // neighbor row) was tried and REVERTED — AIG rows are short (deg 2–5)
    // so the prefetch rarely fired but its branch + enumerate bookkeeping
    // de-vectorized the loop (3x slower on this VM).
    for &c in cols {
        let xrow: &[f32; DIM] = x[c as usize * DIM..(c as usize + 1) * DIM]
            .try_into()
            .unwrap();
        for d in 0..DIM {
            acc[d] += xrow[d];
        }
    }
    orow[..DIM].copy_from_slice(&acc);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gather_sum_avx2(x: &[f32], dim: usize, cols: &[u32], orow: &mut [f32]) {
    use std::arch::x86_64::*;
    let xp = x.as_ptr();
    let op = orow.as_mut_ptr();
    let mut d = 0usize;
    // 16-wide: two ymm accumulators stay in registers across the whole
    // neighbor loop — the HD-row payoff (one pass over cols per 16 lanes).
    while d + 16 <= dim {
        let mut a0 = _mm256_loadu_ps(op.add(d));
        let mut a1 = _mm256_loadu_ps(op.add(d + 8));
        for &c in cols {
            let p = xp.add(c as usize * dim + d);
            a0 = _mm256_add_ps(a0, _mm256_loadu_ps(p));
            a1 = _mm256_add_ps(a1, _mm256_loadu_ps(p.add(8)));
        }
        _mm256_storeu_ps(op.add(d), a0);
        _mm256_storeu_ps(op.add(d + 8), a1);
        d += 16;
    }
    while d + 8 <= dim {
        let mut a0 = _mm256_loadu_ps(op.add(d));
        for &c in cols {
            a0 = _mm256_add_ps(a0, _mm256_loadu_ps(xp.add(c as usize * dim + d)));
        }
        _mm256_storeu_ps(op.add(d), a0);
        d += 8;
    }
    while d < dim {
        let mut acc = *op.add(d);
        for &c in cols {
            acc += *xp.add(c as usize * dim + d);
        }
        *op.add(d) = acc;
        d += 1;
    }
}

// ---------------------------------------------------------------------------
// gather_weighted: orow[d] += Σ_{c ∈ cols, deg(c)>0} x[c*dim+d] / deg(c)
// ---------------------------------------------------------------------------

/// Column-degree-weighted gather — the backward-SpMM inner loop. Degrees
/// come from `row_ptr` (`deg(c) = row_ptr[c+1] - row_ptr[c]`); zero-degree
/// columns contribute nothing (same guard as the scalar engines).
#[inline]
pub fn gather_weighted(x: &[f32], dim: usize, cols: &[u32], row_ptr: &[usize], orow: &mut [f32]) {
    debug_assert_eq!(orow.len(), dim);
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 support was just detected at runtime.
        unsafe { gather_weighted_avx2(x, dim, cols, row_ptr, orow) };
        return;
    }
    gather_weighted_scalar(x, dim, cols, row_ptr, orow);
}

/// Scalar reference for [`gather_weighted`], const-dim specialized like
/// [`gather_sum_scalar`].
#[inline]
pub fn gather_weighted_scalar(
    x: &[f32],
    dim: usize,
    cols: &[u32],
    row_ptr: &[usize],
    orow: &mut [f32],
) {
    match dim {
        4 => gather_weighted_const::<4>(x, cols, row_ptr, orow),
        8 => gather_weighted_const::<8>(x, cols, row_ptr, orow),
        16 => gather_weighted_const::<16>(x, cols, row_ptr, orow),
        32 => gather_weighted_const::<32>(x, cols, row_ptr, orow),
        64 => gather_weighted_const::<64>(x, cols, row_ptr, orow),
        _ => {
            for &c in cols {
                let c = c as usize;
                let deg = row_ptr[c + 1] - row_ptr[c];
                if deg == 0 {
                    continue;
                }
                let w = 1.0 / deg as f32;
                let xrow = &x[c * dim..(c + 1) * dim];
                for (o, &v) in orow.iter_mut().zip(xrow) {
                    *o += v * w;
                }
            }
        }
    }
}

#[inline]
fn gather_weighted_const<const DIM: usize>(
    x: &[f32],
    cols: &[u32],
    row_ptr: &[usize],
    orow: &mut [f32],
) {
    let mut acc: [f32; DIM] = orow[..DIM].try_into().unwrap();
    for &c in cols {
        let c = c as usize;
        let deg = row_ptr[c + 1] - row_ptr[c];
        if deg == 0 {
            continue;
        }
        let w = 1.0 / deg as f32;
        let xrow: &[f32; DIM] = x[c * DIM..(c + 1) * DIM].try_into().unwrap();
        for d in 0..DIM {
            acc[d] += xrow[d] * w;
        }
    }
    orow[..DIM].copy_from_slice(&acc);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gather_weighted_avx2(
    x: &[f32],
    dim: usize,
    cols: &[u32],
    row_ptr: &[usize],
    orow: &mut [f32],
) {
    use std::arch::x86_64::*;
    let xp = x.as_ptr();
    let op = orow.as_mut_ptr();
    let mut d = 0usize;
    while d + 16 <= dim {
        let mut a0 = _mm256_loadu_ps(op.add(d));
        let mut a1 = _mm256_loadu_ps(op.add(d + 8));
        for &c in cols {
            let c = c as usize;
            let deg = row_ptr[c + 1] - row_ptr[c];
            if deg == 0 {
                continue;
            }
            let w = _mm256_set1_ps(1.0 / deg as f32);
            let p = xp.add(c * dim + d);
            a0 = _mm256_add_ps(a0, _mm256_mul_ps(_mm256_loadu_ps(p), w));
            a1 = _mm256_add_ps(a1, _mm256_mul_ps(_mm256_loadu_ps(p.add(8)), w));
        }
        _mm256_storeu_ps(op.add(d), a0);
        _mm256_storeu_ps(op.add(d + 8), a1);
        d += 16;
    }
    while d + 8 <= dim {
        let mut a0 = _mm256_loadu_ps(op.add(d));
        for &c in cols {
            let c = c as usize;
            let deg = row_ptr[c + 1] - row_ptr[c];
            if deg == 0 {
                continue;
            }
            let w = _mm256_set1_ps(1.0 / deg as f32);
            a0 = _mm256_add_ps(a0, _mm256_mul_ps(_mm256_loadu_ps(xp.add(c * dim + d)), w));
        }
        _mm256_storeu_ps(op.add(d), a0);
        d += 8;
    }
    while d < dim {
        let mut acc = *op.add(d);
        for &c in cols {
            let c = c as usize;
            let deg = row_ptr[c + 1] - row_ptr[c];
            if deg == 0 {
                continue;
            }
            acc += *xp.add(c * dim + d) * (1.0 / deg as f32);
        }
        *op.add(d) = acc;
        d += 1;
    }
}

// ---------------------------------------------------------------------------
// scale_assign / add_assign
// ---------------------------------------------------------------------------

/// `v[i] *= s` — the mean scale applied after [`gather_sum`].
#[inline]
pub fn scale_assign(v: &mut [f32], s: f32) {
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 support was just detected at runtime.
        unsafe { scale_assign_avx2(v, s) };
        return;
    }
    for o in v.iter_mut() {
        *o *= s;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_assign_avx2(v: &mut [f32], s: f32) {
    use std::arch::x86_64::*;
    let sv = _mm256_set1_ps(s);
    let p = v.as_mut_ptr();
    let mut i = 0usize;
    while i + 8 <= v.len() {
        _mm256_storeu_ps(p.add(i), _mm256_mul_ps(_mm256_loadu_ps(p.add(i)), sv));
        i += 8;
    }
    while i < v.len() {
        *p.add(i) *= s;
        i += 1;
    }
}

/// `acc[i] += x[i]` — the HD scratch-slot reduction in the GROOT engine.
#[inline]
pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 support was just detected at runtime.
        unsafe { add_assign_avx2(acc, x) };
        return;
    }
    for (o, &v) in acc.iter_mut().zip(x) {
        *o += v;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn add_assign_avx2(acc: &mut [f32], x: &[f32]) {
    use std::arch::x86_64::*;
    let ap = acc.as_mut_ptr();
    let xp = x.as_ptr();
    let mut i = 0usize;
    while i + 8 <= acc.len() {
        _mm256_storeu_ps(
            ap.add(i),
            _mm256_add_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(xp.add(i))),
        );
        i += 8;
    }
    while i < acc.len() {
        *ap.add(i) += *xp.add(i);
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// matmul_row_add: orow[j] += Σ_k arow[k] * b[k*m + j]
// ---------------------------------------------------------------------------

/// One output row of a dense GEMM accumulate: `orow += arow · b` with `b`
/// row-major `[k × m]`. The register-blocked micro-kernel: the output row
/// is tiled 16 floats wide, each tile held in two ymm accumulators across
/// the whole `k` loop with `arow[k]` broadcast. Zero activations are
/// skipped in both forms — load-bearing for ReLU sparsity *and* for the
/// non-finite semantics (`0 * inf` never materializes, same as scalar).
#[inline]
pub fn matmul_row_add(arow: &[f32], b: &[f32], m: usize, orow: &mut [f32]) {
    debug_assert_eq!(b.len(), arow.len() * m);
    debug_assert_eq!(orow.len(), m);
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 support was just detected at runtime.
        unsafe { matmul_row_add_avx2(arow, b, m, orow) };
        return;
    }
    matmul_row_add_scalar(arow, b, m, orow);
}

/// Scalar reference for [`matmul_row_add`]: `b` row offsets hoisted via
/// `chunks_exact`, inner loop over zipped slices so bounds checks drop.
#[inline]
pub fn matmul_row_add_scalar(arow: &[f32], b: &[f32], m: usize, orow: &mut [f32]) {
    for (&av, brow) in arow.iter().zip(b.chunks_exact(m)) {
        if av != 0.0 {
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn matmul_row_add_avx2(arow: &[f32], b: &[f32], m: usize, orow: &mut [f32]) {
    use std::arch::x86_64::*;
    let bp = b.as_ptr();
    let op = orow.as_mut_ptr();
    let mut j = 0usize;
    while j + 16 <= m {
        let mut a0 = _mm256_loadu_ps(op.add(j));
        let mut a1 = _mm256_loadu_ps(op.add(j + 8));
        for (kk, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let bv = _mm256_set1_ps(av);
                let p = bp.add(kk * m + j);
                a0 = _mm256_add_ps(a0, _mm256_mul_ps(_mm256_loadu_ps(p), bv));
                a1 = _mm256_add_ps(a1, _mm256_mul_ps(_mm256_loadu_ps(p.add(8)), bv));
            }
        }
        _mm256_storeu_ps(op.add(j), a0);
        _mm256_storeu_ps(op.add(j + 8), a1);
        j += 16;
    }
    while j + 8 <= m {
        let mut a0 = _mm256_loadu_ps(op.add(j));
        for (kk, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                a0 = _mm256_add_ps(
                    a0,
                    _mm256_mul_ps(_mm256_loadu_ps(bp.add(kk * m + j)), _mm256_set1_ps(av)),
                );
            }
        }
        _mm256_storeu_ps(op.add(j), a0);
        j += 8;
    }
    while j < m {
        let mut acc = *op.add(j);
        for (kk, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                acc += av * *bp.add(kk * m + j);
            }
        }
        *op.add(j) = acc;
        j += 1;
    }
}

// ---------------------------------------------------------------------------
// matmul_row_add_q: int8-weight / f32-activation variant
// ---------------------------------------------------------------------------

/// Quantized twin of [`matmul_row_add`]: `acc[j] += Σ_k arow[k] *
/// (bq[k*m+j] as f32)`. Weights are per-output-channel symmetric int8;
/// the caller applies the channel scales in the epilogue (fused dequant),
/// so this kernel accumulates in the integer-exact f32 domain. i8→f32
/// conversion is exact, mul/add order matches the scalar twin — the int8
/// path is byte-deterministic across dispatch choices too.
#[inline]
pub fn matmul_row_add_q(arow: &[f32], bq: &[i8], m: usize, acc: &mut [f32]) {
    debug_assert_eq!(bq.len(), arow.len() * m);
    debug_assert_eq!(acc.len(), m);
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 support was just detected at runtime.
        unsafe { matmul_row_add_q_avx2(arow, bq, m, acc) };
        return;
    }
    matmul_row_add_q_scalar(arow, bq, m, acc);
}

/// Scalar reference for [`matmul_row_add_q`].
#[inline]
pub fn matmul_row_add_q_scalar(arow: &[f32], bq: &[i8], m: usize, acc: &mut [f32]) {
    for (&av, brow) in arow.iter().zip(bq.chunks_exact(m)) {
        if av != 0.0 {
            for (o, &bv) in acc.iter_mut().zip(brow) {
                *o += av * bv as f32;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn matmul_row_add_q_avx2(arow: &[f32], bq: &[i8], m: usize, acc: &mut [f32]) {
    use std::arch::x86_64::*;
    /// 8 consecutive i8 → 8 f32 lanes (sign-extended; conversion exact).
    #[inline]
    unsafe fn cvt8(p: *const i8) -> __m256 {
        let bytes = _mm_loadl_epi64(p.cast());
        _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(bytes))
    }
    let bp = bq.as_ptr();
    let op = acc.as_mut_ptr();
    let mut j = 0usize;
    while j + 16 <= m {
        let mut a0 = _mm256_loadu_ps(op.add(j));
        let mut a1 = _mm256_loadu_ps(op.add(j + 8));
        for (kk, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let bv = _mm256_set1_ps(av);
                let p = bp.add(kk * m + j);
                a0 = _mm256_add_ps(a0, _mm256_mul_ps(cvt8(p), bv));
                a1 = _mm256_add_ps(a1, _mm256_mul_ps(cvt8(p.add(8)), bv));
            }
        }
        _mm256_storeu_ps(op.add(j), a0);
        _mm256_storeu_ps(op.add(j + 8), a1);
        j += 16;
    }
    while j + 8 <= m {
        let mut a0 = _mm256_loadu_ps(op.add(j));
        for (kk, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                a0 = _mm256_add_ps(
                    a0,
                    _mm256_mul_ps(cvt8(bp.add(kk * m + j)), _mm256_set1_ps(av)),
                );
            }
        }
        _mm256_storeu_ps(op.add(j), a0);
        j += 8;
    }
    while j < m {
        let mut s = *op.add(j);
        for (kk, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                s += av * *bp.add(kk * m + j) as f32;
            }
        }
        *op.add(j) = s;
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect()
    }

    /// Run the AVX2 kernel directly (when the host has it) and compare
    /// bytes with the scalar twin — no global force toggling, so these
    /// tests are safe under the parallel test runner.
    #[test]
    fn gather_sum_simd_matches_scalar_bytes() {
        let mut rng = Rng::new(11);
        for &dim in &[1usize, 2, 3, 4, 5, 7, 8, 15, 16, 17, 31, 33, 64] {
            let n = 37;
            let x = rand_vec(&mut rng, n * dim);
            let cols: Vec<u32> = (0..25).map(|_| rng.below(n) as u32).collect();
            let mut a = rand_vec(&mut rng, dim);
            let mut b = a.clone();
            gather_sum_scalar(&x, dim, &cols, &mut a);
            #[cfg(target_arch = "x86_64")]
            if avx2_available() {
                unsafe { gather_sum_avx2(&x, dim, &cols, &mut b) };
                assert_eq!(a, b, "dim {dim}");
                continue;
            }
            gather_sum(&x, dim, &cols, &mut b);
            assert_eq!(a, b, "dim {dim}");
        }
    }

    #[test]
    fn gather_weighted_simd_matches_scalar_bytes() {
        let mut rng = Rng::new(13);
        for &dim in &[1usize, 3, 5, 8, 16, 19, 64] {
            let n = 29;
            // row_ptr with some zero-degree rows
            let mut row_ptr = vec![0usize; n + 1];
            for i in 0..n {
                let deg = if rng.below(4) == 0 { 0 } else { rng.range(1, 6) };
                row_ptr[i + 1] = row_ptr[i] + deg;
            }
            let x = rand_vec(&mut rng, n * dim);
            let cols: Vec<u32> = (0..40).map(|_| rng.below(n) as u32).collect();
            let mut a = rand_vec(&mut rng, dim);
            let mut b = a.clone();
            gather_weighted_scalar(&x, dim, &cols, &row_ptr, &mut a);
            #[cfg(target_arch = "x86_64")]
            if avx2_available() {
                unsafe { gather_weighted_avx2(&x, dim, &cols, &row_ptr, &mut b) };
                assert_eq!(a, b, "dim {dim}");
                continue;
            }
            gather_weighted(&x, dim, &cols, &row_ptr, &mut b);
            assert_eq!(a, b, "dim {dim}");
        }
    }

    #[test]
    fn matmul_row_add_simd_matches_scalar_bytes() {
        let mut rng = Rng::new(17);
        for &(k, m) in &[(1usize, 1usize), (3, 5), (4, 16), (16, 5), (16, 64), (7, 23), (64, 17)] {
            let mut arow = rand_vec(&mut rng, k);
            arow[rng.below(k)] = 0.0; // exercise the skip
            let b = rand_vec(&mut rng, k * m);
            let mut oa = rand_vec(&mut rng, m);
            let mut ob = oa.clone();
            matmul_row_add_scalar(&arow, &b, m, &mut oa);
            #[cfg(target_arch = "x86_64")]
            if avx2_available() {
                unsafe { matmul_row_add_avx2(&arow, &b, m, &mut ob) };
                assert_eq!(oa, ob, "k {k} m {m}");
                continue;
            }
            matmul_row_add(&arow, &b, m, &mut ob);
            assert_eq!(oa, ob, "k {k} m {m}");
        }
    }

    #[test]
    fn matmul_row_add_q_simd_matches_scalar_bytes() {
        let mut rng = Rng::new(19);
        for &(k, m) in &[(1usize, 1usize), (4, 16), (16, 5), (16, 64), (9, 21)] {
            let arow = rand_vec(&mut rng, k);
            let bq: Vec<i8> = (0..k * m).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let mut oa = vec![0.0f32; m];
            let mut ob = vec![0.0f32; m];
            matmul_row_add_q_scalar(&arow, &bq, m, &mut oa);
            #[cfg(target_arch = "x86_64")]
            if avx2_available() {
                unsafe { matmul_row_add_q_avx2(&arow, &bq, m, &mut ob) };
                assert_eq!(oa, ob, "k {k} m {m}");
                continue;
            }
            matmul_row_add_q(&arow, &bq, m, &mut ob);
            assert_eq!(oa, ob, "k {k} m {m}");
        }
    }

    #[test]
    fn scale_and_add_assign_simd_match_scalar_bytes() {
        let mut rng = Rng::new(23);
        for &n in &[1usize, 7, 8, 9, 16, 33] {
            let x = rand_vec(&mut rng, n);
            let mut a = rand_vec(&mut rng, n);
            let mut b = a.clone();
            let mut a2 = a.clone();
            let mut b2 = a.clone();
            for (o, &v) in a.iter_mut().zip(&x) {
                *o += v;
            }
            add_assign(&mut b, &x);
            // add_assign may dispatch either way; both must equal scalar
            assert_eq!(a, b, "add n {n}");
            for o in a2.iter_mut() {
                *o *= 0.37;
            }
            scale_assign(&mut b2, 0.37);
            assert_eq!(a2, b2, "scale n {n}");
        }
    }

    #[test]
    fn active_reports_a_known_level() {
        assert!(matches!(active(), "avx2" | "scalar"));
    }
}

//! Minimal property-based testing framework (proptest is unavailable
//! offline). Provides generators over `Rng` and a `check` runner that
//! reports the failing seed + case index so failures are reproducible.
//!
//! Usage (doctest disabled: doctest binaries bypass the workspace rpath
//! flags and cannot find the nix-store libstdc++ this image needs):
//! ```text
//! use groot::util::prop::{check, Gen};
//! check("addition commutes", 200, |g| {
//!     let a = g.usize(0..1000);
//!     let b = g.usize(0..1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;
use std::ops::Range;

/// Case-local generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    /// Free-form description of the generated case, printed on failure.
    trace: Vec<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), trace: Vec::new() }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Record a human-readable note about the generated case.
    pub fn note(&mut self, s: impl Into<String>) {
        self.trace.push(s.into());
    }

    pub fn usize(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end);
        r.start + self.rng.below(r.end - r.start)
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn i64(&mut self, r: Range<i64>) -> i64 {
        assert!(r.start < r.end);
        // Width may exceed i64::MAX (e.g. -2^62..2^62); go through u64.
        let width = (r.end as i128 - r.start as i128) as u64;
        let off = if width as usize as u64 == width {
            self.rng.below(width as usize) as u64
        } else {
            self.rng.next_u64() % width
        };
        (r.start as i128 + off as i128) as i64
    }

    pub fn f32_unit(&mut self) -> f32 {
        self.rng.f32()
    }

    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.f32()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    /// Vec of length in `len`, elements from `f`.
    pub fn vec<T>(&mut self, len: Range<usize>, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        let n = self.usize(len);
        (0..n).map(|_| f(self)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `cases` random cases of property `f`. Panics (re-raising the inner
/// panic) with the seed and case index on first failure.
///
/// Override the base seed with env `GROOT_PROP_SEED` to replay a failure;
/// override case count with `GROOT_PROP_CASES`.
pub fn check(name: &str, cases: usize, f: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base_seed: u64 = std::env::var("GROOT_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let cases: usize = std::env::var("GROOT_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            f(&mut g);
            g
        });
        match result {
            Ok(_) => {}
            Err(e) => {
                eprintln!(
                    "property '{name}' FAILED at case {case}/{cases} \
                     (replay with GROOT_PROP_SEED={base_seed} and this case index; seed={seed})"
                );
                std::panic::resume_unwind(e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("sum symmetric", 50, |g| {
            let a = g.usize(0..100);
            let b = g.usize(0..100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        check("always fails eventually", 50, |g| {
            let x = g.usize(0..10);
            assert!(x < 9, "hit the 10% case");
        });
    }

    #[test]
    fn gen_vec_length_bounds() {
        let mut g = Gen::new(5);
        for _ in 0..100 {
            let v = g.vec(3..7, |g| g.bool());
            assert!((3..7).contains(&v.len()));
        }
    }
}

//! Hand-rolled thread pool with a `parallel_for` primitive.
//!
//! rayon is not available in this offline environment, so the SpMM engines
//! (`crate::spmm`) and the coordinator run on this pool instead. The design
//! mirrors what the paper's CUDA kernels need from the host side: static
//! work partitioning (chunked ranges) plus a work-stealing-free dynamic mode
//! (atomic chunk counter) for skewed workloads.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size thread pool. Jobs are closures; `scope`-style helpers below
/// provide data-parallel loops over index ranges.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("groot-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { workers, tx: Some(tx), size }
    }

    /// Pool sized to the number of available CPUs.
    pub fn with_default_size() -> Self {
        Self::new(default_threads())
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool send");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Number of worker threads to default to (respects GROOT_THREADS).
/// Resolved once per process and cached: this sits on the per-layer hot
/// path (`matmul_add`), and `env::var` allocates its value on every call.
pub fn default_threads() -> usize {
    static DEFAULT: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *DEFAULT.get_or_init(|| {
        if let Ok(v) = std::env::var("GROOT_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    })
}

/// Statically-chunked parallel for: splits `0..n` into `nthreads` contiguous
/// ranges and runs `f(range)` on scoped threads. `f` receives (thread_idx,
/// start, end). This is the analogue of the paper's *static* workload
/// partitioning for HD rows.
pub fn parallel_for_static<F>(nthreads: usize, n: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let nthreads = nthreads.max(1).min(n.max(1));
    if nthreads <= 1 || n == 0 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(nthreads);
    thread::scope(|s| {
        for t in 0..nthreads {
            let f = &f;
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                continue;
            }
            s.spawn(move || f(t, start, end));
        }
    });
}

/// Dynamically-chunked parallel for: threads grab `chunk`-sized blocks from
/// an atomic counter until exhausted. Used for skewed workloads (LD rows of
/// wildly varying degree) where static splits would imbalance.
pub fn parallel_for_dynamic<F>(nthreads: usize, n: usize, chunk: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let nthreads = nthreads.max(1);
    let chunk = chunk.max(1);
    if nthreads <= 1 || n <= chunk {
        f(0, 0, n);
        return;
    }
    let cursor = AtomicUsize::new(0);
    thread::scope(|s| {
        for t in 0..nthreads {
            let f = &f;
            let cursor = &cursor;
            s.spawn(move || loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                f(t, start, end);
            });
        }
    });
}

/// Run `f(i)` for every i in 0..n, writing results into a returned Vec.
/// Convenience wrapper over `parallel_for_static` for map-style workloads.
pub fn parallel_map<T, F>(nthreads: usize, n: usize, f: F) -> Vec<T>
where
    T: Default + Clone + Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = SendPtr(out.as_mut_ptr());
        parallel_for_static(nthreads, n, |_, s, e| {
            let slots = &slots;
            for i in s..e {
                // SAFETY: each index i is written by exactly one thread
                // (ranges are disjoint) and `out` outlives the scope.
                unsafe { *slots.0.add(i) = f(i) };
            }
        });
    }
    out
}

/// Shareable raw pointer for disjoint-range writes from scoped threads.
pub struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn static_for_covers_range_once() {
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_static(7, n, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn dynamic_for_covers_range_once() {
        let n = 1234;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_dynamic(5, n, 17, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_map_matches_serial() {
        let out = parallel_map(4, 257, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn edge_cases_empty_and_single() {
        parallel_for_static(4, 0, |_, s, e| assert_eq!(s, e));
        let out = parallel_map(4, 1, |i| i + 1);
        assert_eq!(out, vec![1]);
    }
}

//! Hand-rolled thread pool with work stealing and data-parallel loops.
//!
//! rayon is not available in this offline environment, so the SpMM engines
//! (`crate::spmm`), the backends, and the coordinator run on this module
//! instead. Three layers:
//!
//! * [`ThreadPool`] — a fixed set of workers, each with its OWN job deque;
//!   submissions round-robin across the deques and idle workers steal a
//!   chunk (half) of a victim's queue instead of contending on one shared
//!   `Mutex<Receiver>`. This is the host-side analogue of the paper's
//!   dynamic workload dispatch: queues stay local until imbalance appears.
//! * scoped loops — [`parallel_for_static`] (contiguous ranges, the HD-row
//!   static split), [`parallel_for_dynamic`] (atomic chunk counter for
//!   skewed work), [`parallel_map`] (per-index results, no `Default +
//!   Clone` bound), and [`parallel_join`] (run two closures concurrently,
//!   the primitive the streaming executor overlaps gather/infer with).
//! * budget splitting — [`split_threads`] divides one thread budget
//!   between outer task lanes and the inner parallelism each lane gets,
//!   so inter-partition and intra-SpMM parallelism share cores instead of
//!   oversubscribing (`P partition lanes × T SpMM threads ≤ budget`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Pool-wide observability handles (shared by every [`ThreadPool`] in
/// the process): a steal-event counter and a queued-jobs gauge,
/// registered once in the global metrics registry and updated with one
/// relaxed atomic op per enqueue/dequeue.
fn pool_metrics() -> &'static (crate::obs::metrics::Counter, crate::obs::metrics::Gauge) {
    static M: std::sync::OnceLock<(crate::obs::metrics::Counter, crate::obs::metrics::Gauge)> =
        std::sync::OnceLock::new();
    M.get_or_init(|| {
        let reg = crate::obs::metrics::registry();
        (
            reg.counter(
                "groot_pool_steals_total",
                "work-stealing events across all thread pools (an idle worker drained half of a victim's queue)",
                &[],
            ),
            reg.gauge(
                "groot_pool_queue_depth",
                "jobs sitting in thread-pool deques, submitted but not yet started",
                &[],
            ),
        )
    })
}

/// Total work-steal events across every pool in the process.
pub fn steal_count() -> u64 {
    pool_metrics().0.get()
}

/// Jobs currently queued (submitted, not yet started) across every pool.
pub fn queued_jobs() -> i64 {
    pool_metrics().1.get()
}

/// Error returned by [`ThreadPool::execute`] once the pool has shut down
/// (explicitly or because it is mid-drop).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolClosed;

impl std::fmt::Display for PoolClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "thread pool is shut down")
    }
}

impl std::error::Error for PoolClosed {}

struct PoolShared {
    /// One deque per worker: the owner pops from the front, thieves
    /// drain the oldest half in one go. Separate locks keep submissions
    /// and local pops off each other's cache lines; the old single
    /// `Mutex<Receiver>` serialized every dequeue through one lock.
    queues: Vec<Mutex<VecDeque<Job>>>,
    /// Open flag, doubling as the sleep/wake lock: every submission
    /// pushes UNDER this lock before notifying, and an idle worker
    /// re-scans the queues while holding it before waiting — so a job
    /// enqueued between a worker's scan and its `wait` is impossible
    /// (the submitter blocks on the lock until the worker is parked).
    open: Mutex<bool>,
    idle: Condvar,
}

impl PoolShared {
    fn any_queued(&self) -> bool {
        self.queues.iter().any(|q| !q.lock().unwrap().is_empty())
    }

    /// Pop from `me`'s own queue, else steal up to half of the FIRST
    /// non-empty victim in ring order from `me` (chunk stealing: one
    /// lock round-trip amortizes over several jobs; the leftovers land
    /// in `me`'s queue for local pops). Ring order — not fullest-first —
    /// keeps the scan at one lock per victim; round-robin submission
    /// keeps queue depths close enough that victim choice matters
    /// little.
    fn pop_or_steal(&self, me: usize) -> Option<Job> {
        if let Some(job) = self.queues[me].lock().unwrap().pop_front() {
            pool_metrics().1.sub(1);
            return Some(job);
        }
        let k = self.queues.len();
        for off in 1..k {
            let victim = (me + off) % k;
            let mut grabbed: Vec<Job> = {
                let mut vq = self.queues[victim].lock().unwrap();
                let take = vq.len().div_ceil(2);
                if take == 0 {
                    continue;
                }
                // Steal the OLDEST half from the front: the victim keeps
                // its most recently pushed (cache-warm) work.
                vq.drain(..take).collect()
            }; // victim lock released before touching our own queue
            let (steals, depth) = pool_metrics();
            steals.inc();
            depth.sub(1); // the job we are about to run; the rest stay queued
            let first = grabbed.remove(0);
            if !grabbed.is_empty() {
                let mut mine = self.queues[me].lock().unwrap();
                mine.extend(grabbed);
            }
            return Some(first);
        }
        None
    }
}

/// A fixed-size work-stealing thread pool. Jobs are closures; the scoped
/// helpers below provide data-parallel loops over index ranges without
/// going through the pool at all.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    shared: Arc<PoolShared>,
    /// Round-robin submission cursor.
    next: AtomicUsize,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(PoolShared {
            queues: (0..size).map(|_| Mutex::new(VecDeque::new())).collect(),
            open: Mutex::new(true),
            idle: Condvar::new(),
        });
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("groot-pool-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { workers, shared, next: AtomicUsize::new(0), size }
    }

    /// Pool sized to the process-default thread count (respects
    /// `GROOT_THREADS`); explicit sizes always override — see
    /// [`default_threads`].
    pub fn with_default_size() -> Self {
        Self::new(default_threads())
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a fire-and-forget job onto the next queue (round-robin).
    /// Fails with [`PoolClosed`] after [`Self::shutdown`] instead of
    /// panicking on a dead channel.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) -> Result<(), PoolClosed> {
        let slot = self.next.fetch_add(1, Ordering::Relaxed) % self.size;
        // Lock order is open → queue everywhere (the idle scan does the
        // same), so holding `open` across the push cannot deadlock, and
        // it makes the enqueue atomic with the wakeup protocol.
        let open = self.shared.open.lock().unwrap();
        if !*open {
            return Err(PoolClosed);
        }
        self.shared.queues[slot].lock().unwrap().push_back(Box::new(f));
        pool_metrics().1.add(1);
        drop(open);
        self.shared.idle.notify_one();
        Ok(())
    }

    /// Stop accepting new jobs. Already-queued jobs still run; workers
    /// exit once every queue is drained. Idempotent; `drop` calls this
    /// and then joins the workers.
    pub fn shutdown(&self) {
        *self.shared.open.lock().unwrap() = false;
        self.shared.idle.notify_all();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &PoolShared, me: usize) {
    loop {
        if let Some(job) = shared.pop_or_steal(me) {
            job();
            continue;
        }
        let mut open = shared.open.lock().unwrap();
        loop {
            // Re-check under the open lock: submissions push under this
            // same lock before notifying, so a job enqueued between our
            // scan and this wait cannot be missed.
            if shared.any_queued() {
                break;
            }
            if !*open {
                return;
            }
            open = shared.idle.wait(open).unwrap();
        }
    }
}

/// Number of worker threads the PROCESS defaults to (respects
/// `GROOT_THREADS`). Resolved once and cached: this sits on per-layer hot
/// paths, and `env::var` allocates its value on every call. The cache
/// makes the env var a process-wide default ONLY — code that needs a
/// different width in the same process (per-backend budgets, the serve
/// sweep, tests) passes an explicit count to `ThreadPool::new`,
/// `SessionConfig::threads`, or the `*_with`/`*_threads` kernel variants
/// instead of re-exporting the env var.
pub fn default_threads() -> usize {
    static DEFAULT: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *DEFAULT.get_or_init(|| {
        if let Ok(v) = std::env::var("GROOT_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    })
}

/// Split a total thread `budget` between `tasks` independent outer lanes:
/// returns `(outer, inner)` with `outer × inner ≤ budget` — `outer`
/// lanes run concurrently and each gets `inner` threads of nested
/// parallelism. This is how inter-partition and intra-SpMM parallelism
/// share one budget instead of multiplying (8 partitions × 8-thread SpMM
/// on 8 cores would oversubscribe 8×; `split_threads(8, 8) == (8, 1)`).
pub fn split_threads(budget: usize, tasks: usize) -> (usize, usize) {
    let budget = budget.max(1);
    let outer = budget.min(tasks.max(1));
    (outer, (budget / outer).max(1))
}

/// Run two closures, potentially in parallel (`b` on a scoped thread,
/// `a` inline), and return both results. Panics in either closure
/// propagate. This is the overlap primitive `execute_plan_streaming`
/// uses to gather window W+1 while window W infers.
pub fn parallel_join<A, B, RA, RB>(a: A, b: B) -> (RA, RB)
where
    A: FnOnce() -> RA + Send,
    B: FnOnce() -> RB + Send,
    RA: Send,
    RB: Send,
{
    thread::scope(|s| {
        let hb = s.spawn(b);
        let ra = a();
        match hb.join() {
            Ok(rb) => (ra, rb),
            Err(payload) => std::panic::resume_unwind(payload),
        }
    })
}

/// Statically-chunked parallel for: splits `0..n` into `nthreads` contiguous
/// ranges and runs `f(range)` on scoped threads. `f` receives (thread_idx,
/// start, end). This is the analogue of the paper's *static* workload
/// partitioning for HD rows.
pub fn parallel_for_static<F>(nthreads: usize, n: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let nthreads = nthreads.max(1).min(n.max(1));
    if nthreads <= 1 || n == 0 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(nthreads);
    thread::scope(|s| {
        for t in 0..nthreads {
            let f = &f;
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                continue;
            }
            s.spawn(move || f(t, start, end));
        }
    });
}

/// Dynamically-chunked parallel for: threads grab `chunk`-sized blocks from
/// an atomic counter until exhausted. Used for skewed workloads (LD rows of
/// wildly varying degree) where static splits would imbalance.
pub fn parallel_for_dynamic<F>(nthreads: usize, n: usize, chunk: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let nthreads = nthreads.max(1);
    let chunk = chunk.max(1);
    if nthreads <= 1 || n <= chunk {
        f(0, 0, n);
        return;
    }
    let cursor = AtomicUsize::new(0);
    thread::scope(|s| {
        for t in 0..nthreads {
            let f = &f;
            let cursor = &cursor;
            s.spawn(move || loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                f(t, start, end);
            });
        }
    });
}

/// Run `f(i)` for every i in 0..n, writing results into a returned Vec in
/// index order. Results are written via `MaybeUninit` into disjoint
/// slots, so `T` needs neither `Default` nor `Clone` — `Result<_, _>`
/// maps (the parallel `infer_batch` path) work directly.
///
/// If `f` panics the panic propagates out of the scope; already-written
/// results are leaked (never dropped), which is safe — just not tidy —
/// and only reachable on a panicking path.
pub fn parallel_map<T, F>(nthreads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<std::mem::MaybeUninit<T>> =
        (0..n).map(|_| std::mem::MaybeUninit::uninit()).collect();
    {
        let slots = SendPtr(out.as_mut_ptr());
        parallel_for_static(nthreads, n, |_, s, e| {
            let slots = &slots;
            for i in s..e {
                // SAFETY: static ranges are disjoint and cover 0..n, so
                // each slot is written exactly once; `out` outlives the
                // scope.
                unsafe { (*slots.0.add(i)).write(f(i)) };
            }
        });
    }
    // SAFETY: every slot 0..n was initialized above (parallel_for_static
    // covers the full range even in its inline nthreads<=1 form).
    // Vec<MaybeUninit<T>> and Vec<T> have identical layout.
    let mut out = std::mem::ManuallyDrop::new(out);
    unsafe { Vec::from_raw_parts(out.as_mut_ptr().cast::<T>(), out.len(), out.capacity()) }
}

/// Shareable raw pointer for disjoint-range writes from scoped threads.
pub struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::AtomicU64;
    use std::sync::mpsc;
    use std::time::Duration;

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        drop(pool); // joins after draining
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn execute_after_shutdown_errors_instead_of_panicking() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {}).unwrap();
        pool.shutdown();
        assert_eq!(pool.execute(|| {}).unwrap_err(), PoolClosed);
        // shutdown is idempotent and drop still joins cleanly
        pool.shutdown();
    }

    #[test]
    fn queued_jobs_drain_on_shutdown() {
        let counter = Arc::new(AtomicU64::new(0));
        let pool = ThreadPool::new(2);
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(Duration::from_micros(200));
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        drop(pool); // shutdown + join must run everything already queued
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn idle_workers_steal_queued_work() {
        // Round-robin spreads submissions, but slow jobs pile up behind a
        // long-running one; with per-worker queues + stealing, more than
        // one thread must end up executing jobs.
        let pool = ThreadPool::new(4);
        let seen: Arc<Mutex<HashSet<thread::ThreadId>>> =
            Arc::new(Mutex::new(HashSet::new()));
        for _ in 0..64 {
            let seen = Arc::clone(&seen);
            pool.execute(move || {
                seen.lock().unwrap().insert(thread::current().id());
                thread::sleep(Duration::from_millis(1));
            })
            .unwrap();
        }
        drop(pool);
        assert!(
            seen.lock().unwrap().len() >= 2,
            "64 sleeping jobs were all run by one worker — stealing is dead"
        );
    }

    #[test]
    fn metrics_track_queue_and_steals() {
        // The registry is process-global and other tests run pools
        // concurrently, so assert monotonicity and the drained
        // invariant rather than exact deltas.
        let before_steals = steal_count();
        let pool = ThreadPool::new(4);
        for _ in 0..64 {
            pool.execute(move || thread::sleep(Duration::from_micros(500))).unwrap();
        }
        drop(pool); // drains every queued job
        assert!(steal_count() >= before_steals, "steal counter went backwards");
        assert!(queued_jobs() >= 0, "queue-depth gauge went negative");
    }

    #[test]
    fn parallel_join_runs_both_and_returns_in_order() {
        let (ra, rb) = parallel_join(|| 1 + 1, || "b");
        assert_eq!((ra, rb), (2, "b"));
    }

    #[test]
    fn parallel_join_is_actually_concurrent() {
        // `a` blocks until `b` signals: sequential execution of a-then-b
        // would deadlock, so completing within the timeout proves overlap.
        let (tx, rx) = mpsc::channel();
        let (ra, _) = parallel_join(
            move || rx.recv_timeout(Duration::from_secs(30)).expect("b never ran concurrently"),
            move || tx.send(42usize).unwrap(),
        );
        assert_eq!(ra, 42);
    }

    #[test]
    fn split_threads_never_oversubscribes() {
        for budget in 1..=16usize {
            for tasks in 1..=20usize {
                let (outer, inner) = split_threads(budget, tasks);
                assert!(outer * inner <= budget.max(1), "{budget} {tasks}");
                assert!(outer >= 1 && inner >= 1);
                assert!(outer <= tasks.max(1));
            }
        }
        assert_eq!(split_threads(8, 8), (8, 1));
        assert_eq!(split_threads(8, 2), (2, 4));
        assert_eq!(split_threads(4, 100), (4, 1));
        assert_eq!(split_threads(0, 5), (1, 1));
    }

    #[test]
    fn static_for_covers_range_once() {
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_static(7, n, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn dynamic_for_covers_range_once() {
        let n = 1234;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_dynamic(5, n, 17, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_map_matches_serial() {
        let out = parallel_map(4, 257, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_supports_non_default_non_clone_types() {
        // Results that are neither Default nor Clone (anyhow::Result of a
        // non-Clone payload is the real consumer).
        struct NoDefault(usize);
        let out = parallel_map(3, 100, NoDefault);
        assert!(out.iter().enumerate().all(|(i, v)| v.0 == i));

        let out: Vec<Result<String, std::io::Error>> =
            parallel_map(4, 20, |i| Ok(format!("v{i}")));
        let collected: Result<Vec<String>, _> = out.into_iter().collect();
        assert_eq!(collected.unwrap()[7], "v7");
    }

    #[test]
    fn parallel_map_drops_results_exactly_once() {
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct CountsDrops;
        impl Drop for CountsDrops {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::SeqCst);
            }
        }
        let out = parallel_map(4, 37, |_| CountsDrops);
        assert_eq!(DROPS.load(Ordering::SeqCst), 0, "no drops while alive");
        drop(out);
        assert_eq!(DROPS.load(Ordering::SeqCst), 37, "each result dropped once");
    }

    #[test]
    fn edge_cases_empty_and_single() {
        parallel_for_static(4, 0, |_, s, e| assert_eq!(s, e));
        let out = parallel_map(4, 1, |i| i + 1);
        assert_eq!(out, vec![1]);
        let empty: Vec<usize> = parallel_map(4, 0, |i| i);
        assert!(empty.is_empty());
    }
}

//! Deterministic xorshift/splitmix PRNG.
//!
//! `rand` is unavailable offline; every stochastic component in the repo
//! (dataset shuffles, property tests, partitioner tie-breaks, workload
//! generators) draws from this generator so runs are reproducible from a
//! seed recorded in EXPERIMENTS.md.

/// splitmix64 — used to seed and as a one-shot hash.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Fast, decent quality, tiny.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (k <= n), unordered.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(10)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(9);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}

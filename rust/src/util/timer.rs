//! Benchmark timing helpers (criterion is unavailable offline).
//!
//! `bench` runs warmups then measured iterations and reports robust stats;
//! the harnesses in `rust/benches/` print rows from these.

use std::time::{Duration, Instant};

/// Result of a timed measurement series.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub stddev: Duration,
    /// 95th/99th percentile of the sample series (nearest-rank; equal to
    /// `max` for small n) — tail visibility for the bench tables and
    /// BENCH_*.json, where a clean median can hide stutter.
    pub p95: Duration,
    pub p99: Duration,
    /// Sorted samples, kept so [`Self::percentile`] can answer any
    /// quantile after the fact (bench series are small — tens to a few
    /// thousand entries).
    sorted: Vec<Duration>,
}

impl BenchStats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
    pub fn p95_secs(&self) -> f64 {
        self.p95.as_secs_f64()
    }
    pub fn p99_secs(&self) -> f64 {
        self.p99.as_secs_f64()
    }

    /// Generic nearest-rank percentile over the measured samples,
    /// `p` in [0, 1]: `percentile(0.5)` is the median, `percentile(1.0)`
    /// the max.
    pub fn percentile(&self, p: f64) -> Duration {
        percentile_of_sorted(&self.sorted, p)
    }
}

/// Nearest-rank percentile of an ascending-sorted series (empty → zero).
fn percentile_of_sorted(sorted: &[Duration], p: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let idx = (p.clamp(0.0, 1.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "median {:>10} mean {:>10} ± {:<10} (n={}, min {}, p95 {}, p99 {}, max {})",
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.stddev),
            self.iters,
            fmt_dur(self.min),
            fmt_dur(self.p95),
            fmt_dur(self.p99),
            fmt_dur(self.max),
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

/// Time a single run of `f`.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed())
}

/// Run `warmup` unmeasured then `iters` measured iterations of `f`.
/// A `black_box`-style sink prevents the optimizer from deleting the work:
/// callers should return something data-dependent from `f`.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let start = Instant::now();
        black_box(f());
        samples.push(start.elapsed());
    }
    stats_of(&mut samples)
}

/// Adaptive variant: runs until `budget` wall time is spent (min 3 iters).
pub fn bench_for<T>(budget: Duration, mut f: impl FnMut() -> T) -> BenchStats {
    black_box(f()); // warmup
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while samples.len() < 3 || (t0.elapsed() < budget && samples.len() < 10_000) {
        let start = Instant::now();
        black_box(f());
        samples.push(start.elapsed());
    }
    stats_of(&mut samples)
}

fn stats_of(samples: &mut [Duration]) -> BenchStats {
    samples.sort_unstable();
    let n = samples.len();
    let total: Duration = samples.iter().sum();
    let mean = total / n as u32;
    let median = samples[n / 2];
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|d| {
            let x = d.as_secs_f64() - mean_s;
            x * x
        })
        .sum::<f64>()
        / n as f64;
    BenchStats {
        iters: n,
        mean,
        median,
        min: samples[0],
        max: samples[n - 1],
        stddev: Duration::from_secs_f64(var.sqrt()),
        p95: percentile_of_sorted(samples, 0.95),
        p99: percentile_of_sorted(samples, 0.99),
        sorted: samples.to_vec(),
    }
}

/// Optimizer barrier (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Peak resident set size of this process, in bytes (linux only; returns 0
/// elsewhere). Used by the memory harnesses to report *measured* footprint
/// next to the analytic model.
pub fn peak_rss_bytes() -> u64 {
    if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: u64 = rest
                    .trim()
                    .trim_end_matches("kB")
                    .trim()
                    .parse()
                    .unwrap_or(0);
                return kb * 1024;
            }
        }
    }
    0
}

/// Current RSS in bytes (linux only).
pub fn current_rss_bytes() -> u64 {
    if let Ok(statm) = std::fs::read_to_string("/proc/self/statm") {
        let fields: Vec<&str> = statm.split_whitespace().collect();
        if fields.len() >= 2 {
            if let Ok(pages) = fields[1].parse::<u64>() {
                return pages * 4096;
            }
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench(1, 10, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(s.iters, 10);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.median <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn percentiles_hit_known_ranks() {
        let mut samples: Vec<Duration> = (1..=100u64).map(Duration::from_micros).collect();
        let s = stats_of(&mut samples);
        assert_eq!(s.percentile(0.0), Duration::from_micros(1));
        assert_eq!(s.percentile(1.0), Duration::from_micros(100));
        // nearest-rank over 100 evenly spaced samples
        assert_eq!(s.p95, Duration::from_micros(95));
        assert_eq!(s.p99, Duration::from_micros(99));
        assert_eq!(s.percentile(0.5), s.median);
        // out-of-range p clamps instead of panicking
        assert_eq!(s.percentile(2.0), Duration::from_micros(100));
        assert_eq!(s.percentile(-1.0), Duration::from_micros(1));
    }

    #[test]
    fn rss_is_positive_on_linux() {
        if cfg!(target_os = "linux") {
            assert!(peak_rss_bytes() > 0);
            assert!(current_rss_bytes() > 0);
        }
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_nanos(5)).ends_with("ns"));
    }
}

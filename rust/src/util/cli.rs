//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each binary declares its options by querying an `Args` instance.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Option keys that were consumed by typed getters (for unknown-arg checks).
    consumed: std::collections::BTreeSet<String>,
}

impl Args {
    /// Parse from `std::env::args()[1..]`, treating `known_flags` as
    /// valueless booleans (anything else starting with `--` takes a value).
    pub fn parse(known_flags: &[&str]) -> Self {
        Self::from_vec(std::env::args().skip(1).collect(), known_flags)
    }

    pub fn from_vec(argv: Vec<String>, known_flags: &[&str]) -> Self {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.options
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if known_flags.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    // trailing --foo with no value: treat as flag
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&mut self, name: &str) -> Option<String> {
        self.consumed.insert(name.to_string());
        self.options.get(name).cloned()
    }

    pub fn get_or(&mut self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or_else(|| default.to_string())
    }

    pub fn parse_or<T: std::str::FromStr>(&mut self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => match v.parse::<T>() {
                Ok(x) => Ok(x),
                Err(_) => bail!("--{name}: cannot parse '{v}'"),
            },
        }
    }

    /// Comma-separated list of T.
    pub fn parse_list<T: std::str::FromStr>(&mut self, name: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: Clone,
    {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<T>()
                        .map_err(|_| anyhow::anyhow!("--{name}: cannot parse '{s}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positional() {
        let mut a = Args::from_vec(argv("verify --width 64 --regrow --parts=8 out.txt"), &["regrow"]);
        assert_eq!(a.positional, vec!["verify", "out.txt"]);
        assert!(a.flag("regrow"));
        assert_eq!(a.get("width").as_deref(), Some("64"));
        assert_eq!(a.get("parts").as_deref(), Some("8"));
    }

    #[test]
    fn typed_getters() {
        let mut a = Args::from_vec(argv("--n 5 --xs 1,2,3"), &[]);
        assert_eq!(a.parse_or("n", 0usize).unwrap(), 5);
        assert_eq!(a.parse_or("missing", 7usize).unwrap(), 7);
        assert_eq!(a.parse_list::<u32>("xs", &[]).unwrap(), vec![1, 2, 3]);
        assert!(a.parse_or::<usize>("xs", 0).is_err());
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::from_vec(argv("--verbose"), &[]);
        assert!(a.flag("verbose"));
    }
}

//! Shared infrastructure substrates: thread pool, PRNG, property testing,
//! tensor interchange, timing, CLI parsing.
//!
//! These exist because the offline build environment has no rayon / rand /
//! proptest / serde / clap / criterion; each submodule is a minimal,
//! well-tested replacement scoped to what this repo needs.

pub mod cli;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod tensor;
pub mod timer;

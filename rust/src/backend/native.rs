//! Rust-native inference backend — [`crate::gnn::SageModel`] on a
//! pluggable [`SpmmEngine`], operating directly on each partition's local
//! CSR. No artifacts, no device runtime; also serves as the GAMORA-like
//! full-graph comparator in the Fig. 10 harness.
//!
//! Concurrency model: the backend owns a checkout/return pool of
//! **lanes** — (SpMM engine, [`ForwardScratch`] arena) pairs. Every
//! inference call checks a lane out, runs the forward pass in it, and
//! returns it; the pool grows on demand and never shrinks, so each
//! lane's arena and the GROOT engine's cached plan stay warm. Checkouts
//! are gated by a thread-budget SEMAPHORE: a lane running `inner`
//! threads holds `inner` permits out of the backend's budget, so the
//! total parallelism across every concurrently live lane — one
//! `infer_batch`'s split ([`split_threads`]: 8 threads over an
//! 8-partition plan run 8 single-threaded lanes, over a 2-partition
//! plan 2 four-threaded lanes, never `8 × 8`), several concurrent
//! batches, or independent `infer` callers — never exceeds the budget;
//! excess callers wait their turn.
//!
//! Steady-state inference stays allocation-free per lane (the arena
//! ping-pongs activations, the GROOT engine caches its plan + HD
//! scratch); the only per-call allocation is the returned logits vector.

use super::{InferenceBackend, PartitionInput, PartitionLogits};
use crate::gnn::{ForwardScratch, Precision, QuantizedSage, SageModel};
use crate::spmm::{GrootSpmm, SpmmEngine};
use crate::util::pool::{parallel_map, split_threads};
use anyhow::Result;
use std::sync::{Condvar, Mutex};

/// One execution lane: an engine plus its scratch arena. Checked out by
/// exactly one thread at a time, so neither needs internal locking
/// beyond what the engine already has. `permits` records how many
/// thread-budget permits this checkout holds (returned by `put_back`).
struct Lane {
    engine: Box<dyn SpmmEngine>,
    scratch: ForwardScratch,
    permits: usize,
}

struct PoolInner {
    free: Vec<Lane>,
    /// Thread-budget permits not currently held by a checked-out lane.
    /// A checkout for `inner` threads consumes `inner` permits, so the
    /// SUM of thread parallelism across all concurrently live lanes —
    /// whether they came from one `infer_batch` split or from many
    /// independent `infer` callers — never exceeds the backend budget.
    available: usize,
}

/// Checkout/return pool of [`Lane`]s, gated by a thread-budget
/// semaphore. Lanes are grow-only (minted up to at most `budget`, since
/// each holds ≥ 1 permit) and keep their arenas and SpMM plan caches
/// warm across checkouts.
struct LanePool {
    inner: Mutex<PoolInner>,
    returned: Condvar,
    /// Total permits (the backend's thread budget).
    budget: usize,
    /// `true` — mint a fresh GROOT lane when none is free (the standard
    /// path). `false` — the caller supplied ONE specific engine
    /// (`with_engine`, the kernel-comparison path): checkouts beyond it
    /// wait for it to come back, preserving exactly-that-engine
    /// semantics.
    grow: bool,
}

impl LanePool {
    fn new(budget: usize, grow: bool, seed_lanes: Vec<Lane>) -> LanePool {
        LanePool {
            inner: Mutex::new(PoolInner { free: seed_lanes, available: budget.max(1) }),
            returned: Condvar::new(),
            budget: budget.max(1),
            grow,
        }
    }

    /// Acquire a lane holding `inner_threads` permits, blocking while
    /// the budget (or, for a fixed pool, the lone engine) is exhausted.
    /// The returned guard gives the lane back — permits included — on
    /// drop, so a panic mid-forward cannot leak permits and wedge every
    /// later checkout.
    fn checkout(&self, inner_threads: usize) -> LaneGuard<'_> {
        let want = inner_threads.clamp(1, self.budget);
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.available >= want {
                if let Some(mut lane) = g.free.pop() {
                    g.available -= want;
                    drop(g);
                    if self.grow {
                        // Re-budget a reused minted lane to the current
                        // split. A fixed caller-supplied engine keeps ITS
                        // configured thread count — it is the measurement
                        // subject.
                        lane.engine.set_threads(want);
                    }
                    lane.permits = want;
                    return LaneGuard { pool: self, lane: Some(lane) };
                }
                if self.grow {
                    g.available -= want;
                    drop(g);
                    let lane = Lane {
                        engine: Box::new(GrootSpmm::new(want)),
                        scratch: ForwardScratch::new(),
                        permits: want,
                    };
                    return LaneGuard { pool: self, lane: Some(lane) };
                }
            }
            g = self.returned.wait(g).unwrap();
        }
    }

    /// Acquire `count` lanes ATOMICALLY, each holding `inner_threads`
    /// permits — the fused-batch path needs one lane per partition held
    /// simultaneously, and acquiring them one `checkout` at a time can
    /// deadlock when two concurrent batches each grab half the budget and
    /// wait forever for the rest. Only valid on a growing pool (the
    /// fixed single-engine pool never fans out). The caller guarantees
    /// `count × inner_threads ≤ budget`.
    fn checkout_many(&self, count: usize, inner_threads: usize) -> Vec<LaneGuard<'_>> {
        debug_assert!(self.grow);
        let want = inner_threads.clamp(1, self.budget);
        let total = want * count;
        debug_assert!(total <= self.budget);
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.available >= total {
                g.available -= total;
                let reuse = count.min(g.free.len());
                let lanes: Vec<Lane> = g.free.drain(g.free.len() - reuse..).collect();
                drop(g);
                let mut guards = Vec::with_capacity(count);
                for mut lane in lanes {
                    lane.engine.set_threads(want);
                    lane.permits = want;
                    guards.push(LaneGuard { pool: self, lane: Some(lane) });
                }
                while guards.len() < count {
                    let lane = Lane {
                        engine: Box::new(GrootSpmm::new(want)),
                        scratch: ForwardScratch::new(),
                        permits: want,
                    };
                    guards.push(LaneGuard { pool: self, lane: Some(lane) });
                }
                return guards;
            }
            g = self.returned.wait(g).unwrap();
        }
    }

    fn put_back(&self, lane: Lane) {
        let mut g = self.inner.lock().unwrap();
        g.available += lane.permits;
        g.free.push(lane);
        drop(g);
        // notify_all: waiters may need different permit amounts.
        self.returned.notify_all();
    }
}

/// RAII checkout: returns the lane (and its permits) to the pool on
/// drop — including unwinds, so a panicking kernel can't strand the
/// thread budget.
struct LaneGuard<'a> {
    pool: &'a LanePool,
    lane: Option<Lane>,
}

impl LaneGuard<'_> {
    fn lane_mut(&mut self) -> &mut Lane {
        self.lane.as_mut().expect("lane present until drop")
    }

    /// Shared view — lets the fused path collect `&dyn SpmmEngine`s from
    /// several concurrently held guards.
    fn lane_ref(&self) -> &Lane {
        self.lane.as_ref().expect("lane present until drop")
    }
}

impl Drop for LaneGuard<'_> {
    fn drop(&mut self) {
        if let Some(lane) = self.lane.take() {
            self.pool.put_back(lane);
        }
    }
}

pub struct NativeBackend {
    model: SageModel,
    /// int8 twin of `model` when the backend was built with
    /// `Precision::Int8`; every forward then runs the quantized path.
    quant: Option<QuantizedSage>,
    /// Total thread budget this backend may use at once, split between
    /// partition lanes and each lane's SpMM/matmul threads.
    budget: usize,
    lanes: LanePool,
    engine_name: &'static str,
    /// Bucketed batched GEMM for `infer_batch` when one lane per
    /// partition fits the budget (on by default; `set_fused(false)` is
    /// the bench harness's A/B switch).
    fused: bool,
    /// Scratch arenas for fused batches, pooled so warm batches reuse the
    /// stacked buffers (one arena per concurrently running fused batch).
    fused_scratch: Mutex<Vec<ForwardScratch>>,
}

impl NativeBackend {
    /// Default engine: the paper's GROOT SpMM with the process-default
    /// thread budget.
    pub fn new(model: SageModel) -> NativeBackend {
        Self::with_threads(model, crate::util::pool::default_threads())
    }

    /// GROOT-engine backend with an explicit total thread budget. Lanes
    /// are minted on demand; a single `infer` gets the whole budget as
    /// SpMM/matmul threads, `infer_batch` splits it across partitions.
    pub fn with_threads(model: SageModel, threads: usize) -> NativeBackend {
        Self::with_precision(model, threads, Precision::F32)
    }

    /// [`Self::with_threads`] with an inference precision: `Int8`
    /// quantizes the weights once here (per-output-channel symmetric; see
    /// [`crate::gnn::quant`]) and every forward runs the fused-dequant
    /// int8 GEMMs.
    pub fn with_precision(model: SageModel, threads: usize, precision: Precision) -> NativeBackend {
        let budget = threads.max(1);
        let quant = match precision {
            Precision::F32 => None,
            Precision::Int8 => Some(QuantizedSage::from_model(&model)),
        };
        NativeBackend {
            model,
            quant,
            budget,
            lanes: LanePool::new(budget, true, Vec::new()),
            engine_name: GrootSpmm::new(1).name(),
            fused: true,
            fused_scratch: Mutex::new(Vec::new()),
        }
    }

    /// Run the model on one specific SpMM engine (the Fig. 9 comparison
    /// inside a real model workload). Single-lane: concurrent calls
    /// serialize on that engine, and `infer_batch` stays sequential —
    /// the measurement isolates the KERNEL, not the outer runtime. The
    /// engine keeps its own configured thread count; dense matmuls use
    /// the process-default budget (as the pre-pool backend did).
    pub fn with_engine(model: SageModel, engine: Box<dyn SpmmEngine>) -> NativeBackend {
        let engine_name = engine.name();
        let budget = crate::util::pool::default_threads();
        let seed = vec![Lane { engine, scratch: ForwardScratch::new(), permits: 0 }];
        NativeBackend {
            model,
            quant: None,
            budget,
            lanes: LanePool::new(budget, false, seed),
            engine_name,
            fused: false,
            fused_scratch: Mutex::new(Vec::new()),
        }
    }

    pub fn model(&self) -> &SageModel {
        &self.model
    }

    pub fn engine_name(&self) -> &'static str {
        self.engine_name
    }

    /// The precision this backend serves at.
    pub fn precision(&self) -> Precision {
        if self.quant.is_some() {
            Precision::Int8
        } else {
            Precision::F32
        }
    }

    /// Enable/disable the bucketed batched GEMM in `infer_batch`. On by
    /// default (for growing pools); the bench harness flips it off to
    /// measure the per-partition baseline at the same thread budget.
    pub fn set_fused(&mut self, on: bool) {
        self.fused = on;
    }

    /// Forward one partition inside a checked-out lane, at the backend's
    /// precision.
    fn infer_in_lane(
        &self,
        part: PartitionInput<'_>,
        lane: &mut Lane,
        threads: usize,
    ) -> PartitionLogits {
        let _span = crate::obs::span_with_arg("infer_partition", "backend", "rows", || {
            part.csr.num_nodes().to_string()
        });
        let logits = match &self.quant {
            Some(q) => q
                .forward_with_threads(
                    part.csr,
                    part.features,
                    lane.engine.as_ref(),
                    &mut lane.scratch,
                    threads,
                )
                .to_vec(),
            None => self
                .model
                .forward_with_threads(
                    part.csr,
                    part.features,
                    lane.engine.as_ref(),
                    &mut lane.scratch,
                    threads,
                )
                .to_vec(),
        };
        PartitionLogits { logits, bucket_rows: part.csr.num_nodes() }
    }
}

impl InferenceBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn num_classes(&self) -> usize {
        self.model.num_classes()
    }

    /// The constructor budget — NOT the process default: several of
    /// these run side by side under the serving workers, each holding
    /// its own share.
    fn thread_budget(&self) -> usize {
        self.budget
    }

    fn infer(&self, part: PartitionInput<'_>) -> Result<PartitionLogits> {
        part.validate(self.model.input_dim())?;
        let mut guard = self.lanes.checkout(self.budget);
        Ok(self.infer_in_lane(part, guard.lane_mut(), self.budget))
    }

    /// Batch override: validate all partitions up front, then split the
    /// thread budget into `outer` concurrent partition lanes × `inner`
    /// SpMM/matmul threads each, and run independent partitions in
    /// parallel — output order preserved, and bytes identical to the
    /// sequential path (each partition's forward is self-contained and
    /// thread-count-invariant). A budget of 1 keeps the old behavior:
    /// the whole plan streams through one warm lane.
    fn infer_batch(&self, parts: &[PartitionInput<'_>]) -> Result<Vec<PartitionLogits>> {
        for p in parts {
            p.validate(self.model.input_dim())?;
        }
        // A fixed single-engine backend never fans out: the lone lane IS
        // the measurement subject, so the batch streams through it.
        let (outer, inner) = if self.lanes.grow {
            split_threads(self.budget, parts.len())
        } else {
            (1, self.budget)
        };
        // Bucketed batched GEMM: when one lane per partition fits the
        // budget, stack every partition's rows (the model fixes all layer
        // dims, so same-model partitions are one shape bucket) and run
        // ONE dense GEMM pair per layer at the full budget instead of P
        // small matmuls. Byte-identical to the per-partition path (see
        // `forward_batch_fused`). The int8 path keeps per-partition
        // execution: its GEMM is epilogue-fused with dequant and has no
        // stacked variant (yet) — correctness first.
        if self.fused
            && self.lanes.grow
            && self.quant.is_none()
            && parts.len() > 1
            && outer == parts.len()
        {
            let guards = self.lanes.checkout_many(parts.len(), inner);
            let engines: Vec<&dyn SpmmEngine> =
                guards.iter().map(|g| g.lane_ref().engine.as_ref()).collect();
            let inputs: Vec<(&crate::graph::Csr, &[f32])> =
                parts.iter().map(|p| (p.csr, p.features)).collect();
            let mut scratch = self.fused_scratch.lock().unwrap().pop().unwrap_or_default();
            let logits =
                self.model.forward_batch_fused(&inputs, &engines, &mut scratch, self.budget);
            self.fused_scratch.lock().unwrap().push(scratch);
            drop(engines);
            drop(guards);
            return Ok(logits
                .into_iter()
                .zip(parts)
                .map(|(logits, p)| PartitionLogits { logits, bucket_rows: p.csr.num_nodes() })
                .collect());
        }
        if outer <= 1 || parts.len() <= 1 {
            let mut guard = self.lanes.checkout(self.budget);
            return Ok(parts
                .iter()
                .map(|p| self.infer_in_lane(*p, guard.lane_mut(), self.budget))
                .collect());
        }
        Ok(parallel_map(outer, parts.len(), |i| {
            let mut guard = self.lanes.checkout(inner);
            self.infer_in_lane(parts[i], guard.lane_mut(), inner)
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;
    use crate::spmm::CsrRowParallel;

    fn model() -> SageModel {
        SageModel {
            layers: vec![crate::gnn::SageLayer {
                din: 2,
                dout: 3,
                w_self: vec![0.4, -0.1, 0.2, 0.3, 0.8, -0.5],
                w_neigh: vec![0.25, 0.5, -0.75, 0.1, 0.0, 0.9],
                bias: vec![0.05, -0.05, 0.0],
            }],
        }
    }

    #[test]
    fn infer_matches_model_forward() {
        let csr = Csr::symmetric_from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)]);
        let x: Vec<f32> = (0..10).map(|i| (i as f32).sin()).collect();
        let m = model();
        let backend = NativeBackend::with_engine(m.clone(), Box::new(CsrRowParallel::new(1)));
        let input = PartitionInput { csr: &csr, features: &x, feature_dim: 2 };
        let out = backend.infer(input).unwrap();
        let want = m.forward(&csr, &x, &CsrRowParallel::new(1));
        assert_eq!(out.logits, want);
        assert_eq!(out.bucket_rows, 5);
    }

    #[test]
    fn infer_rejects_shape_mismatch() {
        let csr = Csr::symmetric_from_edges(2, &[(0, 1)]);
        let backend = NativeBackend::with_threads(model(), 1);
        let bad_dim = PartitionInput { csr: &csr, features: &[0.0; 6], feature_dim: 3 };
        assert!(backend.infer(bad_dim).is_err());
        let bad_len = PartitionInput { csr: &csr, features: &[0.0; 6], feature_dim: 2 };
        assert!(backend.infer(bad_len).is_err());
    }

    /// A batch of distinct partitions through every budget must produce
    /// the same bytes as budget-1 sequential execution — the invariant
    /// the whole concurrent runtime leans on.
    #[test]
    fn parallel_batch_is_byte_identical_to_sequential() {
        let graphs: Vec<Csr> = vec![
            Csr::symmetric_from_edges(4, &[(0, 1), (1, 2), (2, 3)]),
            Csr::symmetric_from_edges(3, &[(0, 1), (1, 2), (0, 2)]),
            Csr::symmetric_from_edges(6, &[(0, 1), (2, 3), (4, 5), (1, 4)]),
            Csr::symmetric_from_edges(5, &[(0, 4), (1, 3)]),
        ];
        let feats: Vec<Vec<f32>> = graphs
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                (0..g.num_nodes() * 2)
                    .map(|i| ((i + gi * 7) as f32 * 0.37).sin())
                    .collect()
            })
            .collect();
        let parts: Vec<PartitionInput<'_>> = graphs
            .iter()
            .zip(&feats)
            .map(|(csr, features)| PartitionInput { csr, features, feature_dim: 2 })
            .collect();
        let sequential = NativeBackend::with_threads(model(), 1);
        let want = sequential.infer_batch(&parts).unwrap();
        for budget in [2usize, 3, 4, 8] {
            let concurrent = NativeBackend::with_threads(model(), budget);
            // run twice: cold lanes, then warm reused lanes
            for round in 0..2 {
                let got = concurrent.infer_batch(&parts).unwrap();
                assert_eq!(got.len(), want.len());
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.logits, w.logits,
                        "budget {budget} round {round} partition {i} diverged"
                    );
                    assert_eq!(g.bucket_rows, w.bucket_rows);
                }
            }
        }
    }

    fn batch_parts() -> (Vec<Csr>, Vec<Vec<f32>>) {
        let graphs: Vec<Csr> = vec![
            Csr::symmetric_from_edges(4, &[(0, 1), (1, 2), (2, 3)]),
            Csr::symmetric_from_edges(3, &[(0, 1), (1, 2), (0, 2)]),
            Csr::symmetric_from_edges(6, &[(0, 1), (2, 3), (4, 5), (1, 4)]),
            Csr::symmetric_from_edges(5, &[(0, 4), (1, 3)]),
        ];
        let feats: Vec<Vec<f32>> = graphs
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                (0..g.num_nodes() * 2)
                    .map(|i| ((i + gi * 7) as f32 * 0.37).sin())
                    .collect()
            })
            .collect();
        (graphs, feats)
    }

    /// The bucketed batched GEMM path (budget ≥ partitions, fused on —
    /// the default) must be byte-identical to the per-partition path at
    /// the same budget AND to sequential budget-1 execution.
    #[test]
    fn fused_batch_is_byte_identical_to_per_partition() {
        let (graphs, feats) = batch_parts();
        let parts: Vec<PartitionInput<'_>> = graphs
            .iter()
            .zip(&feats)
            .map(|(csr, features)| PartitionInput { csr, features, feature_dim: 2 })
            .collect();
        let sequential = NativeBackend::with_threads(model(), 1);
        let want = sequential.infer_batch(&parts).unwrap();
        for budget in [4usize, 8] {
            let mut fused = NativeBackend::with_threads(model(), budget);
            let mut legacy = NativeBackend::with_threads(model(), budget);
            legacy.set_fused(false);
            // fused engages: budget ≥ 4 partitions ⇒ one lane each
            for round in 0..2 {
                let got_f = fused.infer_batch(&parts).unwrap();
                let got_l = legacy.infer_batch(&parts).unwrap();
                for (i, ((f, l), w)) in got_f.iter().zip(&got_l).zip(&want).enumerate() {
                    assert_eq!(
                        f.logits, w.logits,
                        "fused budget {budget} round {round} partition {i} diverged"
                    );
                    assert_eq!(l.logits, w.logits, "legacy path diverged");
                    assert_eq!(f.bucket_rows, w.bucket_rows);
                }
            }
            // toggling back restores the fused path
            fused.set_fused(true);
            let again = fused.infer_batch(&parts).unwrap();
            assert_eq!(again.len(), want.len());
        }
    }

    /// int8 serving: deterministic across budgets/rounds (the argmax
    /// parity vs f32 over the generator zoo lives in `kernel_parity`).
    #[test]
    fn int8_batch_is_byte_identical_across_budgets() {
        use crate::gnn::Precision;
        let (graphs, feats) = batch_parts();
        let parts: Vec<PartitionInput<'_>> = graphs
            .iter()
            .zip(&feats)
            .map(|(csr, features)| PartitionInput { csr, features, feature_dim: 2 })
            .collect();
        let sequential = NativeBackend::with_precision(model(), 1, Precision::Int8);
        assert_eq!(sequential.precision(), Precision::Int8);
        let want = sequential.infer_batch(&parts).unwrap();
        for budget in [2usize, 4, 8] {
            let concurrent = NativeBackend::with_precision(model(), budget, Precision::Int8);
            for round in 0..2 {
                let got = concurrent.infer_batch(&parts).unwrap();
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.logits, w.logits,
                        "int8 budget {budget} round {round} partition {i} diverged"
                    );
                }
            }
        }
        // and the f32 backend differs from int8 only within quant error
        let f32b = NativeBackend::with_threads(model(), 1);
        let base = f32b.infer_batch(&parts).unwrap();
        for (q, f) in want.iter().zip(&base) {
            let err = q
                .logits
                .iter()
                .zip(&f.logits)
                .map(|(&a, &b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(err < 0.1, "int8 drifted {err} from f32");
        }
    }

    #[test]
    fn concurrent_infer_calls_share_the_lane_pool() {
        // Many threads hammering `infer` on ONE backend: every result
        // must match the single-threaded answer (lanes isolate scratch).
        let csr = Csr::symmetric_from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let x: Vec<f32> = (0..10).map(|i| (i as f32 * 0.21).cos()).collect();
        let backend = NativeBackend::with_threads(model(), 4);
        let want = backend
            .infer(PartitionInput { csr: &csr, features: &x, feature_dim: 2 })
            .unwrap();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..5 {
                        let got = backend
                            .infer(PartitionInput { csr: &csr, features: &x, feature_dim: 2 })
                            .unwrap();
                        assert_eq!(got.logits, want.logits);
                    }
                });
            }
        });
    }
}

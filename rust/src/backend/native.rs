//! Rust-native inference backend — [`crate::gnn::SageModel`] on a
//! pluggable [`SpmmEngine`], operating directly on each partition's local
//! CSR. No artifacts, no device runtime; also serves as the GAMORA-like
//! full-graph comparator in the Fig. 10 harness.
//!
//! Steady-state inference is allocation-free: a persistent
//! [`ForwardScratch`] arena ping-pongs activations between two reusable
//! buffers (see [`SageModel::forward_with`]) and the default
//! [`GrootSpmm`] engine caches its execution plan and HD scratch per
//! graph. The only per-call allocation is the returned logits vector.

use super::{InferenceBackend, PartitionInput, PartitionLogits};
use crate::gnn::{ForwardScratch, SageModel};
use crate::spmm::{GrootSpmm, SpmmEngine};
use anyhow::Result;
use std::sync::Mutex;

pub struct NativeBackend {
    model: SageModel,
    engine: Box<dyn SpmmEngine>,
    /// Reused across calls; behind a Mutex only because `infer` takes
    /// `&self` — callers are single-threaded, so the lock is uncontended.
    scratch: Mutex<ForwardScratch>,
}

impl NativeBackend {
    /// Default engine: the paper's GROOT SpMM with the default thread
    /// budget.
    pub fn new(model: SageModel) -> NativeBackend {
        Self::with_threads(model, crate::util::pool::default_threads())
    }

    pub fn with_threads(model: SageModel, threads: usize) -> NativeBackend {
        Self::with_engine(model, Box::new(GrootSpmm::new(threads)))
    }

    /// Run the model on an arbitrary SpMM engine (the Fig. 9 comparison
    /// inside a real model workload).
    pub fn with_engine(model: SageModel, engine: Box<dyn SpmmEngine>) -> NativeBackend {
        NativeBackend { model, engine, scratch: Mutex::new(ForwardScratch::new()) }
    }

    pub fn model(&self) -> &SageModel {
        &self.model
    }

    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }
}

impl InferenceBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn num_classes(&self) -> usize {
        self.model.num_classes()
    }

    fn infer(&self, part: PartitionInput<'_>) -> Result<PartitionLogits> {
        let n = part.csr.num_nodes();
        part.validate(self.model.input_dim())?;
        let mut scratch = self.scratch.lock().unwrap();
        let logits =
            self.model
                .forward_with(part.csr, part.features, self.engine.as_ref(), &mut scratch);
        Ok(PartitionLogits { logits: logits.to_vec(), bucket_rows: n })
    }

    /// Batch override: validate all partitions up front, then run the
    /// whole plan under a single scratch acquisition — the arena stays
    /// warm at the batch's widest partition instead of being re-locked
    /// (and on first use re-grown) per partition.
    fn infer_batch(&self, parts: &[PartitionInput<'_>]) -> Result<Vec<PartitionLogits>> {
        for p in parts {
            p.validate(self.model.input_dim())?;
        }
        let mut scratch = self.scratch.lock().unwrap();
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            let logits =
                self.model
                    .forward_with(p.csr, p.features, self.engine.as_ref(), &mut scratch);
            out.push(PartitionLogits {
                logits: logits.to_vec(),
                bucket_rows: p.csr.num_nodes(),
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;
    use crate::spmm::CsrRowParallel;

    fn model() -> SageModel {
        SageModel {
            layers: vec![crate::gnn::SageLayer {
                din: 2,
                dout: 3,
                w_self: vec![0.4, -0.1, 0.2, 0.3, 0.8, -0.5],
                w_neigh: vec![0.25, 0.5, -0.75, 0.1, 0.0, 0.9],
                bias: vec![0.05, -0.05, 0.0],
            }],
        }
    }

    #[test]
    fn infer_matches_model_forward() {
        let csr = Csr::symmetric_from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)]);
        let x: Vec<f32> = (0..10).map(|i| (i as f32).sin()).collect();
        let m = model();
        let backend = NativeBackend::with_engine(m.clone(), Box::new(CsrRowParallel::new(1)));
        let input = PartitionInput { csr: &csr, features: &x, feature_dim: 2 };
        let out = backend.infer(input).unwrap();
        let want = m.forward(&csr, &x, &CsrRowParallel::new(1));
        assert_eq!(out.logits, want);
        assert_eq!(out.bucket_rows, 5);
    }

    #[test]
    fn infer_rejects_shape_mismatch() {
        let csr = Csr::symmetric_from_edges(2, &[(0, 1)]);
        let backend = NativeBackend::with_threads(model(), 1);
        let bad_dim = PartitionInput { csr: &csr, features: &[0.0; 6], feature_dim: 3 };
        assert!(backend.infer(bad_dim).is_err());
        let bad_len = PartitionInput { csr: &csr, features: &[0.0; 6], feature_dim: 2 };
        assert!(backend.infer(bad_len).is_err());
    }
}

//! Pluggable inference backends — the seam between the GROOT coordinator
//! and whatever executes the GNN.
//!
//! The coordinator's job (partition → re-grow → pack → stitch) is backend
//! agnostic; everything device-specific sits behind [`InferenceBackend`]:
//!
//! * [`NativeBackend`] — pure-rust GraphSAGE on a pluggable
//!   [`crate::spmm::SpmmEngine`], operating directly on the partition's
//!   local [`Csr`]. Allocation-free in steady state (a persistent
//!   [`crate::gnn::ForwardScratch`] ping-pongs activations). This is the
//!   default and the only backend the tier-1 environment can build.
//! * `XlaBackend` (cargo feature `xla`) — the AOT-compiled PJRT path:
//!   packs each partition into a fixed shape bucket
//!   ([`crate::runtime::PackedPartition`]) and runs the compiled HLO
//!   executable. Source-compatible with environments lacking the real
//!   XLA toolchain via the vendored API stub (see rust/vendor/xla-stub).
//!
//! Every entry point (CLI, examples, server) selects a backend by name
//! through [`backend_by_name`]; see rust/DESIGN.md §Backend selection.

pub mod native;
#[cfg(feature = "xla")]
pub mod xla;

pub use native::NativeBackend;
#[cfg(feature = "xla")]
pub use xla::XlaBackend;

use crate::graph::Csr;
use crate::util::tensor::Bundle;
use anyhow::Result;
use std::path::Path;

/// One partition's inference input: the local symmetric adjacency plus
/// row-major node features (`features.len() == csr.num_nodes() ×
/// feature_dim`). Rows are in partition-local order (core nodes first).
#[derive(Clone, Copy)]
pub struct PartitionInput<'a> {
    pub csr: &'a Csr,
    pub features: &'a [f32],
    pub feature_dim: usize,
}

impl PartitionInput<'_> {
    /// Shape validation every backend runs before touching the data, so
    /// malformed inputs get a uniform `Err` instead of a downstream panic.
    pub fn validate(&self, expected_dim: usize) -> Result<()> {
        anyhow::ensure!(
            self.feature_dim == expected_dim,
            "feature dim {} does not match backend feature dim {expected_dim}",
            self.feature_dim
        );
        anyhow::ensure!(
            self.features.len() == self.csr.num_nodes() * self.feature_dim,
            "features len {} != {} nodes × {} dims",
            self.features.len(),
            self.csr.num_nodes(),
            self.feature_dim
        );
        Ok(())
    }

    /// Heap bytes of this input's buffers (local CSR + gathered
    /// features) — the unit both executors account execution memory in
    /// (`RunStats::peak_resident_bytes`).
    pub fn resident_bytes(&self) -> usize {
        self.csr.resident_bytes() + std::mem::size_of_val(self.features)
    }
}

/// Logits for one partition.
#[derive(Clone, Debug)]
pub struct PartitionLogits {
    /// Row-major [csr.num_nodes() × num_classes]; bucket padding rows
    /// (if the backend materialized any) are already sliced off.
    pub logits: Vec<f32>,
    /// Rows the backend actually materialized — the partition size for
    /// native execution, the padded shape-bucket size for PJRT. Feeds the
    /// coordinator's peak-memory stats.
    pub bucket_rows: usize,
}

/// A pluggable inference executor for re-grown partitions.
///
/// `Send + Sync`: the concurrent runtime shares backends across threads
/// — the serving workers each own one (built by a factory on their own
/// thread), and the parallel batch path runs independent partitions
/// against `&self` from several lanes at once. Interior scratch state is
/// fine, but it must be pooled or locked, not exclusively owned
/// (`NativeBackend` keeps a checkout/return pool of scratch arenas; the
/// vendored PJRT stub's types are all plain data). An environment whose
/// real PJRT client is `Rc`-based would wrap it behind a thread-confined
/// proxy rather than weakening this seam.
pub trait InferenceBackend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Output classes per node.
    fn num_classes(&self) -> usize;

    /// Run the GNN on one partition; returns per-node logits. Must be
    /// safe to call from several threads at once (`&self`).
    fn infer(&self, part: PartitionInput<'_>) -> Result<PartitionLogits>;

    /// Total threads this backend may use at once — what the default
    /// [`Self::infer_batch`] splits into partition lanes. Defaults to
    /// the process-wide thread count; backends deployed several-to-a-
    /// machine (one per serving worker) override this with their share
    /// so workers × lanes never multiplies past the hardware
    /// ([`NativeBackend`] returns its constructor budget).
    fn thread_budget(&self) -> usize {
        crate::util::pool::default_threads()
    }

    /// Batch entry point — the call the coordinator's execution stage
    /// makes: ALL of a [`crate::coordinator::PartitionPlan`]'s partitions
    /// arrive in one call, in plan order, and outputs must come back in
    /// the same order. Partitions are independent by construction
    /// (re-growth already gave each one every feature row it reads), so
    /// the default runs them CONCURRENTLY through [`Self::infer`],
    /// [`Self::thread_budget`] lanes at a time, preserving output order
    /// — see [`infer_batch_parallel`]. The default assumes `infer` is
    /// internally (near-)serial: an implementation that fans out its own
    /// threads per `infer` call MUST override this method (or bound
    /// itself the way the native backend's lane-permit semaphore does),
    /// or lanes × internal threads will oversubscribe. Backends here
    /// override to amortize further: the native path splits its thread
    /// budget between partition lanes and SpMM threads, the PJRT path
    /// groups partitions by shape bucket.
    fn infer_batch(&self, parts: &[PartitionInput<'_>]) -> Result<Vec<PartitionLogits>> {
        let (lanes, _) = crate::util::pool::split_threads(self.thread_budget(), parts.len());
        infer_batch_parallel(self, parts, lanes)
    }
}

/// Run independent [`PartitionInput`]s concurrently through
/// `backend.infer`, `lanes` at a time, returning outputs in submission
/// order (the stitch contract). The first error wins; `lanes <= 1` (or a
/// batch of one) degenerates to the sequential stream-through.
///
/// Correctness note: per-partition inference must not depend on which
/// lane runs it — true for every backend here (and pinned by the
/// parity tests across worker counts).
pub fn infer_batch_parallel<B>(
    backend: &B,
    parts: &[PartitionInput<'_>],
    lanes: usize,
) -> Result<Vec<PartitionLogits>>
where
    B: InferenceBackend + ?Sized,
{
    let lanes = lanes.max(1).min(parts.len().max(1));
    if lanes <= 1 || parts.len() <= 1 {
        return parts.iter().map(|p| backend.infer(*p)).collect();
    }
    crate::util::pool::parallel_map(lanes, parts.len(), |i| backend.infer(parts[i]))
        .into_iter()
        .collect()
}

/// Build a backend from its CLI name.
///
/// * `"native"` — [`NativeBackend`] from the weight bundle, GROOT SpMM
///   engine with `threads` lanes; needs nothing else.
/// * `"xla"` (alias `"pjrt"`) — the AOT PJRT path: loads every compiled
///   bucket with n ≤ `max_bucket` from `artifacts_dir`. Errors unless the
///   crate was built with `--features xla`.
pub fn backend_by_name(
    name: &str,
    bundle: &Bundle,
    artifacts_dir: &Path,
    max_bucket: usize,
    threads: usize,
) -> Result<Box<dyn InferenceBackend>> {
    backend_by_name_precise(
        name,
        bundle,
        artifacts_dir,
        max_bucket,
        threads,
        crate::gnn::Precision::F32,
    )
}

/// [`backend_by_name`] with an inference precision. `Int8` quantizes the
/// native backend's weights at load (per-output-channel symmetric, fused
/// dequant — see [`crate::gnn::quant`]); the xla path has no quantized
/// artifacts, so any non-f32 request for it is an explicit error rather
/// than a silent fallback.
pub fn backend_by_name_precise(
    name: &str,
    bundle: &Bundle,
    artifacts_dir: &Path,
    max_bucket: usize,
    threads: usize,
    precision: crate::gnn::Precision,
) -> Result<Box<dyn InferenceBackend>> {
    match name {
        "native" => {
            let model = crate::gnn::SageModel::from_bundle(bundle)?;
            Ok(Box::new(NativeBackend::with_precision(model, threads, precision)))
        }
        "xla" | "pjrt" => {
            anyhow::ensure!(
                precision == crate::gnn::Precision::F32,
                "--precision {precision} is only supported by the native backend"
            );
            build_xla(bundle, artifacts_dir, max_bucket)
        }
        other => anyhow::bail!("unknown backend '{other}' (native|xla)"),
    }
}

#[cfg(feature = "xla")]
fn build_xla(
    bundle: &Bundle,
    artifacts_dir: &Path,
    max_bucket: usize,
) -> Result<Box<dyn InferenceBackend>> {
    Ok(Box::new(XlaBackend::load(artifacts_dir, bundle, max_bucket)?))
}

#[cfg(not(feature = "xla"))]
fn build_xla(
    _bundle: &Bundle,
    _artifacts_dir: &Path,
    _max_bucket: usize,
) -> Result<Box<dyn InferenceBackend>> {
    anyhow::bail!(
        "the xla backend requires building with `--features xla` \
         (and a real xla crate checkout; see rust/DESIGN.md)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::{SageLayer, SageModel};
    use crate::util::tensor::{Bundle, Tensor};

    fn bundle_1layer() -> Bundle {
        let mut b = Bundle::new();
        b.insert("l0.w_self".into(), Tensor::f32(vec![4, 5], vec![0.1; 20]));
        b.insert("l0.w_neigh".into(), Tensor::f32(vec![4, 5], vec![0.2; 20]));
        b.insert("l0.b".into(), Tensor::f32(vec![5], vec![0.0; 5]));
        b
    }

    #[test]
    fn backend_by_name_builds_native() {
        let b = bundle_1layer();
        let backend =
            backend_by_name("native", &b, Path::new("artifacts"), usize::MAX, 1).unwrap();
        assert_eq!(backend.name(), "native");
        assert_eq!(backend.num_classes(), 5);
    }

    #[test]
    fn backend_by_name_precise_handles_int8() {
        let b = bundle_1layer();
        let backend = backend_by_name_precise(
            "native",
            &b,
            Path::new("artifacts"),
            usize::MAX,
            1,
            crate::gnn::Precision::Int8,
        )
        .unwrap();
        assert_eq!(backend.name(), "native");
        assert_eq!(backend.num_classes(), 5);
        // the xla path has no quantized artifacts: explicit error
        let err = backend_by_name_precise(
            "xla",
            &b,
            Path::new("artifacts"),
            usize::MAX,
            1,
            crate::gnn::Precision::Int8,
        )
        .unwrap_err();
        assert!(err.to_string().contains("native backend"), "{err:#}");
    }

    #[test]
    fn backend_by_name_rejects_unknown() {
        let b = bundle_1layer();
        assert!(backend_by_name("cuda", &b, Path::new("x"), 0, 1).is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn xla_backend_requires_feature() {
        let b = bundle_1layer();
        let err = backend_by_name("xla", &b, Path::new("artifacts"), usize::MAX, 1)
            .unwrap_err();
        assert!(err.to_string().contains("--features xla"), "{err:#}");
    }

    fn identity_model() -> SageModel {
        SageModel {
            layers: vec![SageLayer {
                din: 2,
                dout: 2,
                w_self: vec![1.0, 0.0, 0.0, 1.0],
                w_neigh: vec![0.0; 4],
                bias: vec![0.0, 0.0],
            }],
        }
    }

    /// A backend that keeps the trait's default `infer_batch` (NativeBackend
    /// overrides it), pinning the stream-through-`infer` fallback contract.
    struct DefaultBatchBackend(NativeBackend);

    impl InferenceBackend for DefaultBatchBackend {
        fn name(&self) -> &'static str {
            "default-batch"
        }
        fn num_classes(&self) -> usize {
            self.0.num_classes()
        }
        fn infer(&self, part: PartitionInput<'_>) -> Result<PartitionLogits> {
            self.0.infer(part)
        }
    }

    #[test]
    fn infer_batch_matches_streaming_and_preserves_order() {
        let native = NativeBackend::with_threads(identity_model(), 1);
        let fallback = DefaultBatchBackend(NativeBackend::with_threads(identity_model(), 1));
        let g1 = Csr::symmetric_from_edges(2, &[(0, 1)]);
        let g2 = Csr::symmetric_from_edges(3, &[(0, 1), (1, 2)]);
        let x1 = vec![1.0, 2.0, 3.0, 4.0];
        let x2 = vec![0.5; 6];
        let parts = [
            PartitionInput { csr: &g1, features: &x1, feature_dim: 2 },
            PartitionInput { csr: &g2, features: &x2, feature_dim: 2 },
        ];
        for backend in [&native as &dyn InferenceBackend, &fallback] {
            let outs = backend.infer_batch(&parts).unwrap();
            assert_eq!(outs.len(), 2);
            assert_eq!(outs[0].logits.len(), 2 * 2);
            assert_eq!(outs[1].logits.len(), 3 * 2);
            // identity w_self, zero w_neigh/bias → logits == features
            assert_eq!(outs[0].logits, x1);
            assert_eq!(outs[1].logits, x2);
        }
    }
}

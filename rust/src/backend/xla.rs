//! PJRT/XLA inference backend (cargo feature `xla`) — adapts the
//! AOT-compiled [`Runtime`] to the [`InferenceBackend`] trait.
//!
//! Each partition is packed into the smallest compiled shape bucket that
//! fits (rows and HD slots) and executed; the padding rows are sliced off
//! before the logits are returned, so the coordinator stitches core
//! predictions identically for every backend.

use super::{InferenceBackend, PartitionInput, PartitionLogits};
use crate::runtime::packed::{hd_slots_needed, pack_partition};
use crate::runtime::Runtime;
use crate::util::tensor::Bundle;
use anyhow::Result;
use std::path::Path;

pub struct XlaBackend {
    rt: Runtime,
}

impl XlaBackend {
    pub fn new(rt: Runtime) -> XlaBackend {
        XlaBackend { rt }
    }

    /// Load every compiled bucket with n ≤ `max_bucket` from
    /// `artifacts_dir` and upload the weight bundle.
    pub fn load(artifacts_dir: &Path, weights: &Bundle, max_bucket: usize) -> Result<XlaBackend> {
        Ok(XlaBackend { rt: Runtime::load_buckets(artifacts_dir, weights, max_bucket)? })
    }

    /// The underlying PJRT runtime (bucket inspection, weight swaps).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.rt
    }
}

impl InferenceBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn num_classes(&self) -> usize {
        self.rt.manifest.num_classes
    }

    fn infer(&self, part: PartitionInput<'_>) -> Result<PartitionLogits> {
        let n = part.csr.num_nodes();
        part.validate(self.rt.manifest.feature_dim)?;
        let (k_ld, k_hd) = (self.rt.manifest.k_ld, self.rt.manifest.k_hd);
        let h_needed = hd_slots_needed(part.csr, k_ld, k_hd);
        let bucket = self.rt.bucket_for(n, h_needed)?;
        let spec = self.rt.bucket_spec(bucket);
        let packed = pack_partition(
            part.csr,
            part.features,
            part.feature_dim,
            spec.n,
            spec.h,
            k_ld,
            k_hd,
        )?;
        let bucket_rows = spec.n;
        let logits = self.rt.infer(bucket, &packed)?;
        let classes = self.rt.manifest.num_classes;
        anyhow::ensure!(
            logits.len() >= n * classes,
            "bucket returned {} logits, expected at least {}",
            logits.len(),
            n * classes
        );
        Ok(PartitionLogits { logits: logits[..n * classes].to_vec(), bucket_rows })
    }
}

//! PJRT/XLA inference backend (cargo feature `xla`) — adapts the
//! AOT-compiled [`Runtime`] to the [`InferenceBackend`] trait.
//!
//! Each partition is packed into the smallest compiled shape bucket that
//! fits (rows and HD slots) and executed; the padding rows are sliced off
//! before the logits are returned, so the coordinator stitches core
//! predictions identically for every backend.

use super::{InferenceBackend, PartitionInput, PartitionLogits};
use crate::runtime::packed::{hd_slots_needed, pack_partition};
use crate::runtime::Runtime;
use crate::util::tensor::Bundle;
use anyhow::Result;
use std::path::Path;

pub struct XlaBackend {
    rt: Runtime,
}

impl XlaBackend {
    pub fn new(rt: Runtime) -> XlaBackend {
        XlaBackend { rt }
    }

    /// Load every compiled bucket with n ≤ `max_bucket` from
    /// `artifacts_dir` and upload the weight bundle.
    pub fn load(artifacts_dir: &Path, weights: &Bundle, max_bucket: usize) -> Result<XlaBackend> {
        Ok(XlaBackend { rt: Runtime::load_buckets(artifacts_dir, weights, max_bucket)? })
    }

    /// The underlying PJRT runtime (bucket inspection, weight swaps).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.rt
    }
}

impl XlaBackend {
    /// Validate and pick the smallest compiled bucket that fits (rows and
    /// HD slots) — the per-partition setup shared by `infer` and
    /// `infer_batch`.
    fn resolve_bucket(&self, part: &PartitionInput<'_>) -> Result<usize> {
        part.validate(self.rt.manifest.feature_dim)?;
        let (k_ld, k_hd) = (self.rt.manifest.k_ld, self.rt.manifest.k_hd);
        let h_needed = hd_slots_needed(part.csr, k_ld, k_hd);
        self.rt.bucket_for(part.csr.num_nodes(), h_needed)
    }

    /// Pack into the already-resolved bucket, execute, slice padding off.
    fn infer_in_bucket(
        &self,
        part: PartitionInput<'_>,
        bucket: usize,
    ) -> Result<PartitionLogits> {
        let n = part.csr.num_nodes();
        let (k_ld, k_hd) = (self.rt.manifest.k_ld, self.rt.manifest.k_hd);
        let spec = self.rt.bucket_spec(bucket);
        let packed = pack_partition(
            part.csr,
            part.features,
            part.feature_dim,
            spec.n,
            spec.h,
            k_ld,
            k_hd,
        )?;
        let bucket_rows = spec.n;
        let logits = self.rt.infer(bucket, &packed)?;
        let classes = self.rt.manifest.num_classes;
        anyhow::ensure!(
            logits.len() >= n * classes,
            "bucket returned {} logits, expected at least {}",
            logits.len(),
            n * classes
        );
        Ok(PartitionLogits { logits: logits[..n * classes].to_vec(), bucket_rows })
    }
}

impl InferenceBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn num_classes(&self) -> usize {
        self.rt.manifest.num_classes
    }

    fn infer(&self, part: PartitionInput<'_>) -> Result<PartitionLogits> {
        let bucket = self.resolve_bucket(&part)?;
        self.infer_in_bucket(part, bucket)
    }

    /// Batch override: execute partitions grouped by their target shape
    /// bucket (stable within a bucket), so each compiled executable runs
    /// its padding-shaped work consecutively instead of ping-ponging
    /// between executables per partition. Buckets are resolved once here
    /// and reused for execution. Results are returned in the caller's
    /// submission order.
    fn infer_batch(&self, parts: &[PartitionInput<'_>]) -> Result<Vec<PartitionLogits>> {
        let mut order: Vec<(usize, usize)> = Vec::with_capacity(parts.len());
        for (i, p) in parts.iter().enumerate() {
            order.push((self.resolve_bucket(p)?, i));
        }
        order.sort_unstable();
        let mut out: Vec<Option<PartitionLogits>> = (0..parts.len()).map(|_| None).collect();
        for (bucket, i) in order {
            out[i] = Some(self.infer_in_bucket(parts[i], bucket)?);
        }
        Ok(out.into_iter().map(|o| o.expect("every index visited")).collect())
    }
}

//! Parallel SpMM engines — the §IV kernel comparison (Fig. 9), as CPU
//! analogues that preserve each design's *work-partitioning strategy*:
//!
//! * [`CsrRowParallel`] — cuSPARSE-style: rows split statically by count;
//!   no degree awareness (a thread stuck with hub rows straggles).
//! * [`MergePathSpmm`] — MergePath-SpMM: total nonzeros split evenly;
//!   boundary rows produce carry partials merged afterwards.
//! * [`GnnAdvisorLike`] — GNNAdvisor-style neighbor grouping: dynamic
//!   row chunks sized to a fixed nonzero budget (np/wp abstraction).
//! * [`GrootSpmm`] — the paper's HD/LD split: degree profile separates
//!   high-degree macro rows (each split into chunks processed in parallel
//!   and reduced) from degree-sorted low-degree rows (many rows per task,
//!   contiguous output = "coalesced dumping").
//!
//! All compute mean aggregation `y[u] = (1/deg u) Σ_v x[v]` over a
//! symmetric CSR, the exact op inside every GraphSAGE layer.

pub mod engines;
pub mod groot;

pub use engines::{CsrRowParallel, GnnAdvisorLike, MergePathSpmm};
pub use groot::{default_hd_threshold, GrootSpmm};

use crate::graph::Csr;

/// A pluggable SpMM strategy. `Send + Sync` so engines can live inside
/// the concurrent backends (`NativeBackend`'s lane pool hands engines
/// across partition lanes); every engine here is plain data plus at most
/// a `Mutex` around its cached plan.
pub trait SpmmEngine: Send + Sync {
    fn name(&self) -> &'static str;

    /// Re-budget this engine's internal parallelism (thread lanes). The
    /// lane pool calls this when a checked-out engine's previous budget
    /// differs from the current `split_threads` split, so outer
    /// (partition) × inner (SpMM) parallelism never oversubscribes.
    /// Engines with no internal parallelism may ignore it (default
    /// no-op). Serving engines must keep results thread-count-INVARIANT
    /// (the GROOT engine does: its plan and reduction orders never
    /// depend on the count); comparison baselines that split rows by
    /// thread count (MergePath) note their last-ulp caveat locally.
    fn set_threads(&mut self, _threads: usize) {}

    /// y = D⁻¹ A x written into caller-owned `out` (row-major [n × dim],
    /// `out.len() == n·dim`). Every element of `out` is overwritten
    /// (isolated rows become 0); prior contents are ignored. This is the
    /// hot path [`crate::gnn::SageModel::forward_with`] runs once per
    /// layer: engines never allocate the output. The serving engine
    /// ([`GrootSpmm`]) is fully allocation-free in steady state (cached
    /// per-graph plan + grow-only scratch); the comparison baselines may
    /// still build small internal task lists per call.
    fn spmm_mean_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]);

    /// Allocating convenience wrapper over [`SpmmEngine::spmm_mean_into`].
    /// (The fresh buffer is zeroed here and overwritten by the impl — the
    /// redundant memset is the price of the convenience path; hot code
    /// calls `spmm_mean_into` with a reused buffer instead.)
    fn spmm_mean(&self, csr: &Csr, x: &[f32], dim: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; csr.num_nodes() * dim];
        self.spmm_mean_into(csr, x, dim, &mut y);
        y
    }

    /// Transpose-mean SpMM — the backward of [`SpmmEngine::spmm_mean_into`]:
    /// `out[v] = Σ_{u ∈ N(v)} x[u] / deg(u)`, i.e. `out = (D⁻¹A)ᵀ x`
    /// (= `A D⁻¹ x` on the symmetric adjacencies this crate uses). This is
    /// the aggregation gradient every GraphSAGE layer's backward pass runs
    /// once per layer during training; like the forward, every element of
    /// `out` (row-major `[n × dim]`) is overwritten and engines never
    /// allocate the output.
    ///
    /// Engines override this with their own work-partitioning strategy —
    /// the default is the single-threaded reference loop so third-party
    /// engines stay source-compatible.
    fn spmm_mean_backward_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]) {
        let n = csr.num_nodes();
        assert_eq!(x.len(), n * dim);
        assert_eq!(out.len(), n * dim);
        out.fill(0.0);
        for (v, orow) in out.chunks_exact_mut(dim).enumerate() {
            engines::row_backward(csr, x, dim, v, orow);
        }
    }

    /// Nonzeros processed per worker if this strategy ran on `workers`
    /// parallel lanes — the quantity the paper's GPU speedups derive
    /// from. Containers without real parallelism (this one has 1 CPU)
    /// still evaluate each design's *balance* exactly.
    fn worker_loads(&self, csr: &Csr, workers: usize) -> Vec<u64>;
}

/// Parallel-makespan summary for one engine on one graph.
#[derive(Clone, Debug)]
pub struct BalanceReport {
    /// max over workers of assigned nonzeros (the makespan in nnz units)
    pub makespan: u64,
    /// total nnz / workers (the perfectly-balanced lower bound)
    pub ideal: f64,
    /// makespan / ideal (1.0 = perfect balance)
    pub imbalance: f64,
}

pub fn balance_report(engine: &dyn SpmmEngine, csr: &Csr, workers: usize) -> BalanceReport {
    let loads = engine.worker_loads(csr, workers);
    let makespan = loads.iter().copied().max().unwrap_or(0);
    let total: u64 = loads.iter().sum();
    let ideal = total as f64 / workers.max(1) as f64;
    BalanceReport {
        makespan,
        ideal,
        imbalance: if ideal > 0.0 { makespan as f64 / ideal } else { 1.0 },
    }
}

/// Greedy simulation of dynamic task dispatch: tasks (in issue order) go
/// to the least-loaded worker — how a task queue drains in practice.
pub(crate) fn simulate_dynamic(task_loads: impl Iterator<Item = u64>, workers: usize) -> Vec<u64> {
    let mut loads = vec![0u64; workers.max(1)];
    for t in task_loads {
        let (i, _) = loads
            .iter()
            .enumerate()
            .min_by_key(|(_, &l)| l)
            .unwrap();
        loads[i] += t;
    }
    loads
}

/// All four engines with the same thread budget (bench harness helper).
pub fn all_engines(threads: usize) -> Vec<Box<dyn SpmmEngine>> {
    vec![
        Box::new(CsrRowParallel::new(threads)),
        Box::new(MergePathSpmm::new(threads)),
        Box::new(GnnAdvisorLike::new(threads)),
        Box::new(GrootSpmm::new(threads)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Engine implementing only the required methods — pins the trait's
    /// default (serial reference) `spmm_mean_backward_into` so third-party
    /// engines get a correct backward for free.
    struct MinimalEngine;

    impl SpmmEngine for MinimalEngine {
        fn name(&self) -> &'static str {
            "minimal"
        }
        fn spmm_mean_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]) {
            out.copy_from_slice(&csr.spmm_mean_reference(x, dim));
        }
        fn worker_loads(&self, csr: &Csr, workers: usize) -> Vec<u64> {
            vec![csr.num_entries() as u64; workers.max(1)]
        }
    }

    #[test]
    fn default_backward_matches_reference() {
        test_support::check_engine_backward_matches_reference(&MinimalEngine);
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::util::rng::Rng;

    /// Random graph with planted high-degree hubs — the polarized shape
    /// the paper profiles.
    pub fn polarized_graph(rng: &mut Rng, n: usize, hubs: usize, hub_deg: usize) -> Csr {
        let mut edges = Vec::new();
        for u in 0..n as u32 {
            for _ in 0..rng.range(1, 4) {
                edges.push((u, rng.below(n) as u32));
            }
        }
        for h in 0..hubs {
            let hub = (h * (n / hubs.max(1))) as u32;
            for _ in 0..hub_deg {
                edges.push((hub, rng.below(n) as u32));
            }
        }
        Csr::symmetric_from_edges(n, &edges)
    }

    pub fn check_engine_matches_reference(engine: &dyn SpmmEngine) {
        let mut rng = Rng::new(0xFEED);
        for (n, hubs, hub_deg, dim) in
            [(50, 2, 30, 4), (300, 3, 200, 8), (1000, 4, 700, 32), (64, 0, 0, 1)]
        {
            let csr = polarized_graph(&mut rng, n, hubs, hub_deg);
            let x: Vec<f32> = (0..n * dim).map(|_| rng.f32() * 4.0 - 2.0).collect();
            let want = csr.spmm_mean_reference(&x, dim);
            let got = engine.spmm_mean(&csr, &x, dim);
            let diff = Csr::max_abs_diff(&got, &want);
            assert!(
                diff < 1e-4,
                "{}: n={n} hubs={hubs} dim={dim}: max diff {diff}",
                engine.name()
            );
            // The into-variant must fully overwrite a poisoned buffer
            // (large finite sentinel: NaN would be swallowed by max()).
            let mut dirty = vec![1e30f32; n * dim];
            engine.spmm_mean_into(&csr, &x, dim, &mut dirty);
            let diff = Csr::max_abs_diff(&dirty, &want);
            assert!(
                diff < 1e-4,
                "{} (into): n={n} hubs={hubs} dim={dim}: max diff {diff}",
                engine.name()
            );
        }
    }

    /// Backward (transpose-mean) counterpart of
    /// [`check_engine_matches_reference`]: same polarized shapes, checked
    /// against [`Csr::spmm_mean_backward_reference`], including the
    /// fully-overwrites-a-poisoned-buffer contract. Tolerance is scaled
    /// by the result's magnitude: unlike the forward, backward rows are
    /// unnormalized weighted sums (a hub row accumulates hundreds of
    /// terms), so engines that split rows across workers legitimately
    /// round differently than the serial reference.
    pub fn check_engine_backward_matches_reference(engine: &dyn SpmmEngine) {
        let mut rng = Rng::new(0xBACC);
        for (n, hubs, hub_deg, dim) in
            [(50, 2, 30, 4), (300, 3, 200, 8), (1000, 4, 700, 32), (64, 0, 0, 1)]
        {
            let csr = polarized_graph(&mut rng, n, hubs, hub_deg);
            let x: Vec<f32> = (0..n * dim).map(|_| rng.f32() * 4.0 - 2.0).collect();
            let want = csr.spmm_mean_backward_reference(&x, dim);
            let mut got = vec![1e30f32; n * dim];
            engine.spmm_mean_backward_into(&csr, &x, dim, &mut got);
            let diff = Csr::max_abs_diff(&got, &want);
            let scale = want.iter().fold(1.0f32, |m, &v| m.max(v.abs()));
            assert!(
                diff < 1e-4 * scale,
                "{} (backward): n={n} hubs={hubs} dim={dim}: max diff {diff} \
                 (scale {scale})",
                engine.name()
            );
        }
    }
}

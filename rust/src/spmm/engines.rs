//! Baseline SpMM engines: cuSPARSE-like row-parallel, MergePath, and
//! GNNAdvisor-like neighbor grouping. See module docs in [`super`].

use super::SpmmEngine;
use crate::graph::Csr;
use crate::util::pool::{parallel_for_dynamic, parallel_for_static, SendPtr};

/// cuSPARSE-style: contiguous row ranges split evenly *by row count*.
pub struct CsrRowParallel {
    threads: usize,
}

impl CsrRowParallel {
    pub fn new(threads: usize) -> Self {
        CsrRowParallel { threads: threads.max(1) }
    }
}

impl SpmmEngine for CsrRowParallel {
    fn name(&self) -> &'static str {
        "cusparse-like"
    }

    fn worker_loads(&self, csr: &Csr, workers: usize) -> Vec<u64> {
        // static even split BY ROW COUNT — blind to degree skew
        let n = csr.num_nodes();
        let workers = workers.max(1);
        let chunk = n.div_ceil(workers).max(1);
        (0..workers)
            .map(|w| {
                let s = (w * chunk).min(n);
                let e = ((w + 1) * chunk).min(n);
                (csr.row_ptr[e] - csr.row_ptr[s]) as u64
            })
            .collect()
    }

    fn spmm_mean_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]) {
        let n = csr.num_nodes();
        assert_eq!(x.len(), n * dim);
        assert_eq!(out.len(), n * dim);
        out.fill(0.0);
        if self.threads <= 1 {
            // serial fast path: safe chunked iteration lets LLVM see the
            // disjointness directly (§Perf)
            for (u, orow) in out.chunks_exact_mut(dim).enumerate() {
                row_mean(csr, x, dim, u, orow);
            }
            return;
        }
        let ptr = SendPtr(out.as_mut_ptr());
        parallel_for_static(self.threads, n, |_, s, e| {
            let ptr = &ptr;
            for u in s..e {
                let orow = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(u * dim), dim) };
                row_mean(csr, x, dim, u, orow);
            }
        });
    }
}

/// MergePath-SpMM: nonzeros split evenly; each worker handles the rows its
/// nonzero range touches, emitting carry partials for rows shared with a
/// neighboring range (merged serially afterwards — the CPU stand-in for
/// the paper's inter-block fixup).
pub struct MergePathSpmm {
    threads: usize,
}

impl MergePathSpmm {
    pub fn new(threads: usize) -> Self {
        MergePathSpmm { threads: threads.max(1) }
    }
}

impl SpmmEngine for MergePathSpmm {
    fn name(&self) -> &'static str {
        "mergepath-spmm"
    }

    fn worker_loads(&self, csr: &Csr, workers: usize) -> Vec<u64> {
        // nonzeros split exactly evenly — balanced by construction
        let nnz = csr.num_entries() as u64;
        let workers = workers.max(1) as u64;
        (0..workers)
            .map(|w| nnz / workers + u64::from(w < nnz % workers))
            .collect()
    }

    fn spmm_mean_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]) {
        let n = csr.num_nodes();
        let nnz = csr.num_entries();
        assert_eq!(x.len(), n * dim);
        assert_eq!(out.len(), n * dim);
        out.fill(0.0);
        if nnz == 0 {
            return;
        }
        let t = self.threads.min(nnz).max(1);
        let per = nnz.div_ceil(t);
        // carries[worker] = (first_row, partial for first row, last_row,
        // partial for last row) when those rows straddle range boundaries.
        let carries: Vec<std::sync::Mutex<Vec<(usize, Vec<f32>)>>> =
            (0..t).map(|_| std::sync::Mutex::new(Vec::new())).collect();
        let ptr = SendPtr(out.as_mut_ptr());
        parallel_for_static(t, t, |_, ws, we| {
            let ptr = &ptr;
            for w in ws..we {
                let z0 = w * per;
                let z1 = ((w + 1) * per).min(nnz);
                if z0 >= z1 {
                    continue;
                }
                // rows overlapping [z0, z1)
                let r0 = match csr.row_ptr.binary_search(&z0) {
                    Ok(r) => r,
                    Err(r) => r - 1,
                };
                let mut local_carry = Vec::new();
                let mut u = r0;
                while u < n && csr.row_ptr[u] < z1 {
                    let lo = csr.row_ptr[u].max(z0);
                    let hi = csr.row_ptr[u + 1].min(z1);
                    if lo >= hi {
                        u += 1;
                        continue;
                    }
                    let full = lo == csr.row_ptr[u] && hi == csr.row_ptr[u + 1];
                    let deg = csr.row_ptr[u + 1] - csr.row_ptr[u];
                    let inv = 1.0 / deg as f32;
                    if full {
                        let orow =
                            unsafe { std::slice::from_raw_parts_mut(ptr.0.add(u * dim), dim) };
                        for &v in &csr.col_idx[lo..hi] {
                            let xrow = &x[v as usize * dim..(v as usize + 1) * dim];
                            for d in 0..dim {
                                orow[d] += xrow[d];
                            }
                        }
                        for o in orow.iter_mut() {
                            *o *= inv;
                        }
                    } else {
                        let mut part = vec![0.0f32; dim];
                        for &v in &csr.col_idx[lo..hi] {
                            let xrow = &x[v as usize * dim..(v as usize + 1) * dim];
                            for d in 0..dim {
                                part[d] += xrow[d];
                            }
                        }
                        for p in part.iter_mut() {
                            *p *= inv;
                        }
                        local_carry.push((u, part));
                    }
                    u += 1;
                }
                if !local_carry.is_empty() {
                    *carries[w].lock().unwrap() = local_carry;
                }
            }
        });
        // Serial carry merge (boundary rows only: ≤ 2 per worker).
        for c in carries {
            for (u, part) in c.into_inner().unwrap() {
                for d in 0..dim {
                    out[u * dim + d] += part[d];
                }
            }
        }
    }
}

/// GNNAdvisor-style: dynamic scheduling of row chunks sized to a fixed
/// *neighbor-group* budget, approximating its neighbor-partitioning /
/// warp-aware mapping. Rows stay whole (their groups are contiguous), so
/// no atomics are needed; load balance comes from the nonzero-budgeted
/// chunking + dynamic dispatch.
pub struct GnnAdvisorLike {
    threads: usize,
    /// target nonzeros per scheduled task (neighbor group budget × groups
    /// per task)
    nnz_budget: usize,
}

impl GnnAdvisorLike {
    pub fn new(threads: usize) -> Self {
        Self::with_budget(threads, 512)
    }

    pub fn with_budget(threads: usize, nnz_budget: usize) -> Self {
        GnnAdvisorLike { threads: threads.max(1), nnz_budget: nnz_budget.max(1) }
    }
}

impl SpmmEngine for GnnAdvisorLike {
    fn name(&self) -> &'static str {
        "gnnadvisor-like"
    }

    fn worker_loads(&self, csr: &Csr, workers: usize) -> Vec<u64> {
        // dynamic dispatch of nnz-budgeted row chunks; rows stay whole, so
        // one giant row still bounds the makespan from below
        let n = csr.num_nodes();
        let mut tasks: Vec<u64> = Vec::new();
        let mut acc = 0u64;
        for u in 0..n {
            acc += csr.degree(u) as u64;
            if acc >= self.nnz_budget as u64 {
                tasks.push(acc);
                acc = 0;
            }
        }
        if acc > 0 {
            tasks.push(acc);
        }
        super::simulate_dynamic(tasks.into_iter(), workers)
    }

    fn spmm_mean_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]) {
        let n = csr.num_nodes();
        assert_eq!(x.len(), n * dim);
        assert_eq!(out.len(), n * dim);
        out.fill(0.0);
        if n == 0 {
            return;
        }
        // Pre-chunk rows into tasks of ≈ nnz_budget nonzeros.
        let mut tasks: Vec<(usize, usize)> = Vec::new(); // row ranges
        let mut start = 0usize;
        let mut acc = 0usize;
        for u in 0..n {
            acc += csr.degree(u);
            if acc >= self.nnz_budget {
                tasks.push((start, u + 1));
                start = u + 1;
                acc = 0;
            }
        }
        if start < n {
            tasks.push((start, n));
        }
        let ptr = SendPtr(out.as_mut_ptr());
        parallel_for_dynamic(self.threads, tasks.len(), 1, |_, ts, te| {
            let ptr = &ptr;
            for t in ts..te {
                let (s, e) = tasks[t];
                for u in s..e {
                    let orow = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(u * dim), dim) };
                    row_mean(csr, x, dim, u, orow);
                }
            }
        });
    }
}

/// Shared per-row mean kernel. Dispatches to a const-dim specialization
/// for the model's dims so the accumulator lives in SIMD registers
/// instead of bouncing through the output row per neighbor (§Perf: +35%
/// on booth128/dim32).
#[inline]
pub(crate) fn row_mean(csr: &Csr, x: &[f32], dim: usize, u: usize, orow: &mut [f32]) {
    match dim {
        4 => row_mean_const::<4>(csr, x, u, orow),
        8 => row_mean_const::<8>(csr, x, u, orow),
        16 => row_mean_const::<16>(csr, x, u, orow),
        32 => row_mean_const::<32>(csr, x, u, orow),
        64 => row_mean_const::<64>(csr, x, u, orow),
        _ => row_mean_dyn(csr, x, dim, u, orow),
    }
}

#[inline]
fn row_mean_const<const DIM: usize>(csr: &Csr, x: &[f32], u: usize, orow: &mut [f32]) {
    let nbs = csr.neighbors(u);
    if nbs.is_empty() {
        return;
    }
    let mut acc = [0.0f32; DIM];
    // NOTE §Perf: a software-prefetch variant (_mm_prefetch of the k+4th
    // neighbor row) was tried and REVERTED — AIG rows are short (deg 2–5)
    // so the prefetch rarely fired but its branch + enumerate bookkeeping
    // de-vectorized the loop (3x slower on this VM).
    for &v in nbs {
        let xrow: &[f32; DIM] = x[v as usize * DIM..(v as usize + 1) * DIM]
            .try_into()
            .unwrap();
        for d in 0..DIM {
            acc[d] += xrow[d];
        }
    }
    let inv = 1.0 / nbs.len() as f32;
    for d in 0..DIM {
        orow[d] = acc[d] * inv;
    }
}

#[inline]
fn row_mean_dyn(csr: &Csr, x: &[f32], dim: usize, u: usize, orow: &mut [f32]) {
    let nbs = csr.neighbors(u);
    if nbs.is_empty() {
        return;
    }
    for &v in nbs {
        let xrow = &x[v as usize * dim..(v as usize + 1) * dim];
        for d in 0..dim {
            orow[d] += xrow[d];
        }
    }
    let inv = 1.0 / nbs.len() as f32;
    for o in orow.iter_mut() {
        *o *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::test_support::check_engine_matches_reference;

    #[test]
    fn csr_rowparallel_matches_reference() {
        check_engine_matches_reference(&CsrRowParallel::new(4));
        check_engine_matches_reference(&CsrRowParallel::new(1));
    }

    #[test]
    fn mergepath_matches_reference() {
        check_engine_matches_reference(&MergePathSpmm::new(4));
        check_engine_matches_reference(&MergePathSpmm::new(3));
        check_engine_matches_reference(&MergePathSpmm::new(1));
    }

    #[test]
    fn gnnadvisor_matches_reference() {
        check_engine_matches_reference(&GnnAdvisorLike::new(4));
        check_engine_matches_reference(&GnnAdvisorLike::with_budget(2, 7));
    }
}

//! Baseline SpMM engines: cuSPARSE-like row-parallel, MergePath, and
//! GNNAdvisor-like neighbor grouping. See module docs in [`super`].

use super::SpmmEngine;
use crate::graph::Csr;
use crate::util::pool::{parallel_for_dynamic, parallel_for_static, SendPtr};
use crate::util::simd;

/// cuSPARSE-style: contiguous row ranges split evenly *by row count*.
pub struct CsrRowParallel {
    threads: usize,
}

impl CsrRowParallel {
    pub fn new(threads: usize) -> Self {
        CsrRowParallel { threads: threads.max(1) }
    }
}

impl SpmmEngine for CsrRowParallel {
    fn name(&self) -> &'static str {
        "cusparse-like"
    }

    fn set_threads(&mut self, threads: usize) {
        // rows are computed whole, so thread count never changes bytes
        self.threads = threads.max(1);
    }

    fn worker_loads(&self, csr: &Csr, workers: usize) -> Vec<u64> {
        // static even split BY ROW COUNT — blind to degree skew
        let n = csr.num_nodes();
        let workers = workers.max(1);
        let chunk = n.div_ceil(workers).max(1);
        (0..workers)
            .map(|w| {
                let s = (w * chunk).min(n);
                let e = ((w + 1) * chunk).min(n);
                (csr.row_ptr[e] - csr.row_ptr[s]) as u64
            })
            .collect()
    }

    fn spmm_mean_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]) {
        let n = csr.num_nodes();
        assert_eq!(x.len(), n * dim);
        assert_eq!(out.len(), n * dim);
        out.fill(0.0);
        if self.threads <= 1 {
            // serial fast path: safe chunked iteration lets LLVM see the
            // disjointness directly (§Perf)
            for (u, orow) in out.chunks_exact_mut(dim).enumerate() {
                row_mean(csr, x, dim, u, orow);
            }
            return;
        }
        let ptr = SendPtr(out.as_mut_ptr());
        parallel_for_static(self.threads, n, |_, s, e| {
            let ptr = &ptr;
            for u in s..e {
                let orow = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(u * dim), dim) };
                row_mean(csr, x, dim, u, orow);
            }
        });
    }

    fn spmm_mean_backward_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]) {
        // Same static row split as the forward: the transpose of a
        // symmetric adjacency has the identical sparsity, so rows remain
        // the natural (if skew-blind) work unit.
        let n = csr.num_nodes();
        assert_eq!(x.len(), n * dim);
        assert_eq!(out.len(), n * dim);
        out.fill(0.0);
        if self.threads <= 1 {
            for (v, orow) in out.chunks_exact_mut(dim).enumerate() {
                row_backward(csr, x, dim, v, orow);
            }
            return;
        }
        let ptr = SendPtr(out.as_mut_ptr());
        parallel_for_static(self.threads, n, |_, s, e| {
            let ptr = &ptr;
            for v in s..e {
                let orow = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(v * dim), dim) };
                row_backward(csr, x, dim, v, orow);
            }
        });
    }
}

/// MergePath-SpMM: nonzeros split evenly; each worker handles the rows its
/// nonzero range touches, emitting carry partials for rows shared with a
/// neighboring range (merged serially afterwards — the CPU stand-in for
/// the paper's inter-block fixup).
pub struct MergePathSpmm {
    threads: usize,
}

impl MergePathSpmm {
    pub fn new(threads: usize) -> Self {
        MergePathSpmm { threads: threads.max(1) }
    }
}

impl MergePathSpmm {
    /// Shared nnz-split executor — forward and backward traverse the
    /// identical sparsity (symmetric adjacency), so the range split,
    /// boundary-row detection, and carry merge live once; only the
    /// per-range kernel differs (see [`range_kernel`]). Backward partials
    /// are already column-weighted, so both directions carry-merge by
    /// plain addition.
    fn run(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32], backward: bool) {
        let n = csr.num_nodes();
        let nnz = csr.num_entries();
        assert_eq!(x.len(), n * dim);
        assert_eq!(out.len(), n * dim);
        out.fill(0.0);
        if nnz == 0 {
            return;
        }
        let t = self.threads.min(nnz).max(1);
        let per = nnz.div_ceil(t);
        // carries[worker]: partials for rows straddling range boundaries.
        let carries: Vec<std::sync::Mutex<Vec<(usize, Vec<f32>)>>> =
            (0..t).map(|_| std::sync::Mutex::new(Vec::new())).collect();
        let ptr = SendPtr(out.as_mut_ptr());
        parallel_for_static(t, t, |_, ws, we| {
            let ptr = &ptr;
            for w in ws..we {
                let z0 = w * per;
                let z1 = ((w + 1) * per).min(nnz);
                if z0 >= z1 {
                    continue;
                }
                // rows overlapping [z0, z1)
                let r0 = match csr.row_ptr.binary_search(&z0) {
                    Ok(r) => r,
                    Err(r) => r - 1,
                };
                let mut local_carry = Vec::new();
                let mut u = r0;
                while u < n && csr.row_ptr[u] < z1 {
                    let lo = csr.row_ptr[u].max(z0);
                    let hi = csr.row_ptr[u + 1].min(z1);
                    if lo >= hi {
                        u += 1;
                        continue;
                    }
                    let full = lo == csr.row_ptr[u] && hi == csr.row_ptr[u + 1];
                    if full {
                        let orow =
                            unsafe { std::slice::from_raw_parts_mut(ptr.0.add(u * dim), dim) };
                        range_kernel(csr, x, dim, u, lo, hi, orow, backward);
                    } else {
                        let mut part = vec![0.0f32; dim];
                        range_kernel(csr, x, dim, u, lo, hi, &mut part, backward);
                        local_carry.push((u, part));
                    }
                    u += 1;
                }
                if !local_carry.is_empty() {
                    *carries[w].lock().unwrap() = local_carry;
                }
            }
        });
        // Serial carry merge (boundary rows only: ≤ 2 per worker).
        for c in carries {
            for (u, part) in c.into_inner().unwrap() {
                for d in 0..dim {
                    out[u * dim + d] += part[d];
                }
            }
        }
    }
}

impl SpmmEngine for MergePathSpmm {
    fn name(&self) -> &'static str {
        "mergepath-spmm"
    }

    fn set_threads(&mut self, threads: usize) {
        // NOTE: the nnz split depends on the thread count, so boundary
        // rows may round differently across budgets — this engine is a
        // comparison baseline, not a serving engine (the parity-pinned
        // GROOT engine computes every partial from a thread-count-
        // independent plan).
        self.threads = threads.max(1);
    }

    fn worker_loads(&self, csr: &Csr, workers: usize) -> Vec<u64> {
        // nonzeros split exactly evenly — balanced by construction
        let nnz = csr.num_entries() as u64;
        let workers = workers.max(1) as u64;
        (0..workers)
            .map(|w| nnz / workers + u64::from(w < nnz % workers))
            .collect()
    }

    fn spmm_mean_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]) {
        self.run(csr, x, dim, out, false);
    }

    fn spmm_mean_backward_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]) {
        self.run(csr, x, dim, out, true);
    }
}

/// One sub-range `[lo, hi)` of row `u`'s entries into `orow` (pre-zeroed):
/// forward = raw neighbor sum scaled by `1/deg(u)` (the mean weight
/// distributes over a split row, so partials scale too); backward = the
/// column-degree-weighted gather with no row scale.
#[allow(clippy::too_many_arguments)]
#[inline]
fn range_kernel(
    csr: &Csr,
    x: &[f32],
    dim: usize,
    u: usize,
    lo: usize,
    hi: usize,
    orow: &mut [f32],
    backward: bool,
) {
    if backward {
        simd::gather_weighted(x, dim, &csr.col_idx[lo..hi], &csr.row_ptr, orow);
    } else {
        simd::gather_sum(x, dim, &csr.col_idx[lo..hi], orow);
        simd::scale_assign(orow, 1.0 / csr.degree(u) as f32);
    }
}

/// GNNAdvisor-style: dynamic scheduling of row chunks sized to a fixed
/// *neighbor-group* budget, approximating its neighbor-partitioning /
/// warp-aware mapping. Rows stay whole (their groups are contiguous), so
/// no atomics are needed; load balance comes from the nonzero-budgeted
/// chunking + dynamic dispatch.
pub struct GnnAdvisorLike {
    threads: usize,
    /// target nonzeros per scheduled task (neighbor group budget × groups
    /// per task)
    nnz_budget: usize,
}

impl GnnAdvisorLike {
    pub fn new(threads: usize) -> Self {
        Self::with_budget(threads, 512)
    }

    pub fn with_budget(threads: usize, nnz_budget: usize) -> Self {
        GnnAdvisorLike { threads: threads.max(1), nnz_budget: nnz_budget.max(1) }
    }
}

impl SpmmEngine for GnnAdvisorLike {
    fn name(&self) -> &'static str {
        "gnnadvisor-like"
    }

    fn set_threads(&mut self, threads: usize) {
        // rows stay whole inside nnz-budgeted tasks: bytes are invariant
        self.threads = threads.max(1);
    }

    fn worker_loads(&self, csr: &Csr, workers: usize) -> Vec<u64> {
        // dynamic dispatch of nnz-budgeted row chunks; rows stay whole, so
        // one giant row still bounds the makespan from below
        let n = csr.num_nodes();
        let mut tasks: Vec<u64> = Vec::new();
        let mut acc = 0u64;
        for u in 0..n {
            acc += csr.degree(u) as u64;
            if acc >= self.nnz_budget as u64 {
                tasks.push(acc);
                acc = 0;
            }
        }
        if acc > 0 {
            tasks.push(acc);
        }
        super::simulate_dynamic(tasks.into_iter(), workers)
    }

    fn spmm_mean_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]) {
        self.run(csr, x, dim, out, false);
    }

    fn spmm_mean_backward_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]) {
        // Identical nnz-budgeted row chunking + dynamic dispatch as the
        // forward (the transpose keeps the sparsity), with the per-row
        // kernel swapped for the column-degree-weighted gather.
        self.run(csr, x, dim, out, true);
    }
}

impl GnnAdvisorLike {
    /// Shared executor: nnz-budgeted row chunking + dynamic dispatch, the
    /// per-row kernel selected by direction.
    fn run(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32], backward: bool) {
        let n = csr.num_nodes();
        assert_eq!(x.len(), n * dim);
        assert_eq!(out.len(), n * dim);
        out.fill(0.0);
        if n == 0 {
            return;
        }
        // Pre-chunk rows into tasks of ≈ nnz_budget nonzeros.
        let mut tasks: Vec<(usize, usize)> = Vec::new(); // row ranges
        let mut start = 0usize;
        let mut acc = 0usize;
        for u in 0..n {
            acc += csr.degree(u);
            if acc >= self.nnz_budget {
                tasks.push((start, u + 1));
                start = u + 1;
                acc = 0;
            }
        }
        if start < n {
            tasks.push((start, n));
        }
        let ptr = SendPtr(out.as_mut_ptr());
        parallel_for_dynamic(self.threads, tasks.len(), 1, |_, ts, te| {
            let ptr = &ptr;
            for t in ts..te {
                let (s, e) = tasks[t];
                for u in s..e {
                    let orow = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(u * dim), dim) };
                    if backward {
                        row_backward(csr, x, dim, u, orow);
                    } else {
                        row_mean(csr, x, dim, u, orow);
                    }
                }
            }
        });
    }
}

/// Shared per-row mean kernel: gather + mean scale into a pre-zeroed
/// output row. The inner loops live in [`simd`]: AVX2 when the host has
/// it, a const-dim-specialized scalar form otherwise — both byte-identical
/// (the accumulation order per output element is the neighbor order either
/// way; see the determinism contract in [`simd`]'s module docs).
#[inline]
pub(crate) fn row_mean(csr: &Csr, x: &[f32], dim: usize, u: usize, orow: &mut [f32]) {
    let nbs = csr.neighbors(u);
    if nbs.is_empty() {
        return;
    }
    simd::gather_sum(x, dim, nbs, orow);
    simd::scale_assign(orow, 1.0 / nbs.len() as f32);
}

/// Shared per-row *backward* kernel: `orow = Σ_{u ∈ N(v)} x[u] / deg(u)`
/// — one row of the transpose-mean SpMM. On the symmetric adjacencies the
/// model runs on, every neighbor u has deg(u) ≥ 1 (it neighbors v back);
/// the deg==0 guard inside [`simd::gather_weighted`] only fires on
/// hand-built non-symmetric CSRs, where a zero-out-degree column
/// contributes nothing.
#[inline]
pub(crate) fn row_backward(csr: &Csr, x: &[f32], dim: usize, v: usize, orow: &mut [f32]) {
    simd::gather_weighted(x, dim, csr.neighbors(v), &csr.row_ptr, orow);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::test_support::{
        check_engine_backward_matches_reference, check_engine_matches_reference,
    };

    #[test]
    fn csr_rowparallel_matches_reference() {
        check_engine_matches_reference(&CsrRowParallel::new(4));
        check_engine_matches_reference(&CsrRowParallel::new(1));
    }

    #[test]
    fn mergepath_matches_reference() {
        check_engine_matches_reference(&MergePathSpmm::new(4));
        check_engine_matches_reference(&MergePathSpmm::new(3));
        check_engine_matches_reference(&MergePathSpmm::new(1));
    }

    #[test]
    fn gnnadvisor_matches_reference() {
        check_engine_matches_reference(&GnnAdvisorLike::new(4));
        check_engine_matches_reference(&GnnAdvisorLike::with_budget(2, 7));
    }

    #[test]
    fn csr_rowparallel_backward_matches_reference() {
        check_engine_backward_matches_reference(&CsrRowParallel::new(4));
        check_engine_backward_matches_reference(&CsrRowParallel::new(1));
    }

    #[test]
    fn mergepath_backward_matches_reference() {
        check_engine_backward_matches_reference(&MergePathSpmm::new(4));
        check_engine_backward_matches_reference(&MergePathSpmm::new(3));
        check_engine_backward_matches_reference(&MergePathSpmm::new(1));
    }

    #[test]
    fn gnnadvisor_backward_matches_reference() {
        check_engine_backward_matches_reference(&GnnAdvisorLike::new(4));
        check_engine_backward_matches_reference(&GnnAdvisorLike::with_budget(2, 7));
    }

    #[test]
    fn backward_handles_zero_out_degree_columns() {
        // Non-symmetric CSR: node 2 appears as a column but has no row
        // entries — its weight is 0 by the documented guard, not a panic
        // or an inf. (Row layout: 0→{1,2}, 1→{0}, 2→{}.)
        let csr = Csr::from_edges(3, &[(0, 1), (0, 2), (1, 0)]);
        let x = vec![1.0f32, 10.0, 100.0];
        let mut out = vec![f32::NAN; 3];
        let engine = CsrRowParallel::new(1);
        engine.spmm_mean_backward_into(&csr, &x, 1, &mut out);
        // out[v] = Σ_{u ∈ row v} x[u]/deg(u):
        //   v=0: x[1]/deg(1) + x[2]/deg(2)=skip → 10.0
        //   v=1: x[0]/deg(0) = 1.0/2
        //   v=2: (no entries) = 0
        assert_eq!(out, vec![10.0, 0.5, 0.0]);
    }
}

//! GROOT-GPU SpMM — the paper's HD/LD kernel pair (§IV), CPU analogue.
//!
//! * **HD path** (Fig. 4): each high-degree row's nonzeros are split into
//!   equal chunks processed by different workers (the 32-warp row split);
//!   per-chunk partials land in a scratch array and are reduced into the
//!   output row (shared-memory reduction analogue).
//! * **LD path** (Fig. 5): rows are degree-sorted (count sort, O(n)) and
//!   processed many-rows-per-task in ascending degree order; within a task
//!   the inner loop is over a fixed degree class, so the compiler
//!   vectorizes cleanly and the output rows of a task are written
//!   contiguously in sorted order ("coalesced dumping").
//!
//! The degree profile is cached per graph because the model runs one SpMM
//! per GraphSAGE layer on the same graph. The cache is keyed by the
//! graph's `row_ptr` *contents*: the plan depends only on the degree
//! structure (never on `col_idx`, which is re-read at execution time), so
//! equal row pointers make a cached plan valid — and, unlike the address
//! of a possibly-freed allocation, contents cannot alias a different
//! graph. The HD partial-sum scratch also lives in the cached plan so the
//! steady-state execution path performs no allocation.

use super::SpmmEngine;
use crate::graph::{Csr, DegreeProfile};
use crate::obs::{self, metrics};
use crate::util::pool::{parallel_for_dynamic, parallel_for_static, SendPtr};
use crate::util::simd;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Per-half kernel observability: execution-time histogram plus row and
/// nnz throughput counters, labeled `kernel="hd"|"ld"`. This is the
/// paper's HD/LD polarization evidence reproduced from the runtime
/// itself — `groot harness profile` reports it, the daemon exposes it.
struct KernelStats {
    time: metrics::Histogram,
    rows: metrics::Counter,
    nnz: metrics::Counter,
}

impl KernelStats {
    fn register(kernel: &'static str) -> KernelStats {
        let reg = metrics::registry();
        let labels = [("kernel", kernel)];
        KernelStats {
            time: reg.histogram(
                "groot_kernel_seconds",
                "GROOT SpMM kernel execution time per call, split by HD/LD half",
                &labels,
                metrics::KERNEL_BUCKETS,
            ),
            rows: reg.counter(
                "groot_kernel_rows_total",
                "rows processed by the GROOT SpMM kernels, split by HD/LD half",
                &labels,
            ),
            nnz: reg.counter(
                "groot_kernel_nnz_total",
                "nonzeros processed by the GROOT SpMM kernels, split by HD/LD half",
                &labels,
            ),
        }
    }

    fn record(&self, elapsed: std::time::Duration, rows: usize, nnz: usize) {
        self.time.observe(elapsed.as_secs_f64());
        self.rows.add(rows as u64);
        self.nnz.add(nnz as u64);
    }
}

/// (LD, HD) kernel stats — registered once, then lock-free updates.
fn kernel_stats() -> &'static (KernelStats, KernelStats) {
    static S: OnceLock<(KernelStats, KernelStats)> = OnceLock::new();
    S.get_or_init(|| (KernelStats::register("ld"), KernelStats::register("hd")))
}

/// Default HD/LD degree threshold: the `GROOT_HD_THRESHOLD` env override
/// when set to a positive integer, otherwise the paper's
/// [`crate::graph::profile::HD_THRESHOLD`] (512). Read once per process.
pub fn default_hd_threshold() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        std::env::var("GROOT_HD_THRESHOLD")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&v| v > 0)
            .unwrap_or(crate::graph::profile::HD_THRESHOLD)
    })
}

/// Tunables (paper defaults; ablations sweep these).
#[derive(Clone, Copy, Debug)]
pub struct GrootConfig {
    /// Degree at or above which a row takes the HD path.
    pub hd_threshold: usize,
    /// Nonzeros per HD chunk (the per-warp workload).
    pub hd_chunk: usize,
    /// Rows per LD task is chosen so each task has ≈ this many nonzeros
    /// (the paper's nz_max per-warp row aggregation).
    pub ld_nnz_per_task: usize,
    /// Degree-sort the LD rows (the paper's Fig. 5 count-sort). Shapes
    /// tasks for lane balance on wide machines; on a cache-based serial
    /// CPU it costs x-gather locality (§Perf ablation), so CPU-serial
    /// deployments may disable it — task *sizing* still follows degrees.
    pub ld_degree_sort: bool,
}

impl Default for GrootConfig {
    fn default() -> Self {
        GrootConfig {
            hd_threshold: 512,
            hd_chunk: 1024,
            ld_nnz_per_task: 2048,
            ld_degree_sort: true,
        }
    }
}

struct CachedPlan {
    /// Row pointers of the graph the plan was built for. The plan is a
    /// pure function of this degree structure, so content equality is the
    /// exact validity condition (an address-based key can be aliased by a
    /// freed graph's reused allocation and silently serve a stale plan).
    row_ptr: Vec<usize>,
    profile: DegreeProfile,
    /// LD rows grouped into tasks: (start, end) index ranges into
    /// profile.ld_rows.
    ld_tasks: Vec<(usize, usize)>,
    /// HD chunks: (row, col_start, col_end, scratch_slot).
    hd_chunks: Vec<(u32, usize, usize, usize)>,
    /// scratch slots per HD row: (row, slot_start, slot_count).
    hd_reduce: Vec<(u32, usize, usize)>,
    /// Grow-only HD partial-sum scratch (`total slots × dim` floats),
    /// reused across calls so steady-state execution is allocation-free.
    hd_scratch: Vec<f32>,
    /// Total nonzeros on each half — plan-time facts the per-call kernel
    /// metrics report without rescanning degrees.
    ld_nnz: usize,
    hd_nnz: usize,
}

pub struct GrootSpmm {
    threads: usize,
    pub config: GrootConfig,
    plan: Mutex<Option<CachedPlan>>,
}

impl GrootSpmm {
    /// Default engine: paper-faithful config, except the LD degree sort is
    /// only enabled when there are parallel lanes to shape — on a single
    /// thread it costs gather locality and buys nothing (§Perf ablation:
    /// −13% serial on booth128).
    pub fn new(threads: usize) -> Self {
        Self::with_config(
            threads,
            GrootConfig {
                hd_threshold: default_hd_threshold(),
                ld_degree_sort: threads > 1,
                ..GrootConfig::default()
            },
        )
    }

    /// Default config with an explicit HD/LD threshold — the bench
    /// harness's threshold sweep hook.
    pub fn with_threshold(threads: usize, hd_threshold: usize) -> Self {
        Self::with_config(
            threads,
            GrootConfig {
                hd_threshold: hd_threshold.max(1),
                ld_degree_sort: threads > 1,
                ..GrootConfig::default()
            },
        )
    }

    pub fn with_config(threads: usize, config: GrootConfig) -> Self {
        GrootSpmm { threads: threads.max(1), config, plan: Mutex::new(None) }
    }

    fn build_plan(&self, csr: &Csr) -> CachedPlan {
        let mut profile = DegreeProfile::new(csr, self.config.hd_threshold, 12);
        if !self.config.ld_degree_sort {
            // natural row order (cache-friendly serial variant)
            profile.ld_rows.sort_unstable();
        }
        // LD tasks: ascending-degree runs of ≈ ld_nnz_per_task nonzeros.
        // The budget adapts downward on small graphs so there are always
        // enough tasks to balance across lanes (§Perf: fixes the 1.35
        // imbalance seen on 64-bit graphs at 32 lanes).
        let total_ld_nnz: usize = profile
            .ld_rows
            .iter()
            .map(|&u| csr.degree(u as usize))
            .sum();
        let budget = self
            .config
            .ld_nnz_per_task
            .min((total_ld_nnz / 256).max(64));
        let mut ld_tasks = Vec::new();
        let mut start = 0usize;
        let mut acc = 0usize;
        for (i, &u) in profile.ld_rows.iter().enumerate() {
            acc += csr.degree(u as usize);
            if acc >= budget {
                ld_tasks.push((start, i + 1));
                start = i + 1;
                acc = 0;
            }
        }
        if start < profile.ld_rows.len() {
            ld_tasks.push((start, profile.ld_rows.len()));
        }
        // HD chunks + reduction plan.
        let mut hd_chunks = Vec::new();
        let mut hd_reduce = Vec::new();
        let mut slot = 0usize;
        for &u in &profile.hd_rows {
            let deg = csr.degree(u as usize);
            let nchunks = deg.div_ceil(self.config.hd_chunk);
            hd_reduce.push((u, slot, nchunks));
            for c in 0..nchunks {
                let c0 = c * self.config.hd_chunk;
                let c1 = ((c + 1) * self.config.hd_chunk).min(deg);
                hd_chunks.push((u, c0, c1, slot + c));
            }
            slot += nchunks;
        }
        let hd_nnz: usize = profile
            .hd_rows
            .iter()
            .map(|&u| csr.degree(u as usize))
            .sum();
        CachedPlan {
            row_ptr: csr.row_ptr.clone(),
            profile,
            ld_tasks,
            hd_chunks,
            hd_reduce,
            hd_scratch: Vec::new(),
            ld_nnz: total_ld_nnz,
            hd_nnz,
        }
    }
}

impl SpmmEngine for GrootSpmm {
    fn name(&self) -> &'static str {
        "groot-gpu"
    }

    fn set_threads(&mut self, threads: usize) {
        // The cached plan (HD chunks, LD tasks) is a function of the
        // CONFIG and the graph, never of the thread count, and every
        // partial reduces in fixed slot order — so re-budgeting a pooled
        // engine's lanes changes wall time only, never bytes. The config
        // (incl. ld_degree_sort) is deliberately left as constructed:
        // flipping it would invalidate a valid cached plan for no
        // correctness gain.
        self.threads = threads.max(1);
    }

    fn worker_loads(&self, csr: &Csr, workers: usize) -> Vec<u64> {
        // LD: degree-sorted nnz-budgeted tasks; HD: every wide row split
        // into hd_chunk-sized pieces — no single task exceeds hd_chunk,
        // which is the whole point of the HD kernel.
        let plan = self.build_plan(csr);
        let ld = plan.ld_tasks.iter().map(|&(s, e)| {
            plan.profile.ld_rows[s..e]
                .iter()
                .map(|&u| csr.degree(u as usize) as u64)
                .sum::<u64>()
        });
        let hd = plan
            .hd_chunks
            .iter()
            .map(|&(_, c0, c1, _)| (c1 - c0) as u64);
        super::simulate_dynamic(hd.chain(ld), workers)
    }

    fn spmm_mean_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]) {
        self.run(csr, x, dim, out, false);
    }

    fn spmm_mean_backward_into(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32]) {
        // The transpose keeps both the sparsity and the degree structure,
        // so the SAME cached plan (HD chunks, LD tasks, scratch) drives
        // the backward — training pays zero extra plan builds per step.
        self.run(csr, x, dim, out, true);
    }
}

impl GrootSpmm {
    /// Shared HD/LD executor. `backward = false` computes mean aggregation
    /// `out[u] = (1/deg u) Σ_{v∈N(u)} x[v]`; `backward = true` computes the
    /// transpose `out[v] = Σ_{u∈N(v)} x[u]/deg(u)` — identical traversal
    /// and work partitioning, the weighting just moves from the output row
    /// (applied at the end) to the gathered column (applied per entry, with
    /// no final scale).
    fn run(&self, csr: &Csr, x: &[f32], dim: usize, out: &mut [f32], backward: bool) {
        let n = csr.num_nodes();
        assert_eq!(x.len(), n * dim);
        assert_eq!(out.len(), n * dim);
        out.fill(0.0);
        if n == 0 {
            return;
        }
        // Fetch or rebuild the cached plan (content-keyed; see CachedPlan).
        let mut guard = self.plan.lock().unwrap();
        if guard
            .as_ref()
            .map(|p| p.row_ptr != csr.row_ptr)
            .unwrap_or(true)
        {
            *guard = Some(self.build_plan(csr));
        }
        // Split the plan into its read-only parts and the mutable scratch.
        let CachedPlan {
            ref profile,
            ref ld_tasks,
            ref hd_chunks,
            ref hd_reduce,
            ref mut hd_scratch,
            ld_nnz,
            hd_nnz,
            ..
        } = *guard.as_mut().unwrap();

        let ptr = SendPtr(out.as_mut_ptr());

        // --- LD path: dynamic over degree-sorted row tasks. ---
        // Kernel profiling hooks (time/rows/nnz per half) are a clock
        // read plus a few relaxed atomics per CALL — they never touch
        // the data path, so output bytes are identical with or without
        // tracing (the span is a no-op unless GROOT_TRACE is live).
        let t_ld = Instant::now();
        {
            let _span = obs::span(if backward { "spmm_ld_backward" } else { "spmm_ld" }, "kernel");
            parallel_for_dynamic(self.threads, ld_tasks.len(), 1, |_, ts, te| {
                let ptr = &ptr;
                for t in ts..te {
                    let (s, e) = ld_tasks[t];
                    for i in s..e {
                        let u = profile.ld_rows[i] as usize;
                        let orow =
                            unsafe { std::slice::from_raw_parts_mut(ptr.0.add(u * dim), dim) };
                        if backward {
                            super::engines::row_backward(csr, x, dim, u, orow);
                        } else {
                            super::engines::row_mean(csr, x, dim, u, orow);
                        }
                    }
                }
            });
        }
        kernel_stats().0.record(t_ld.elapsed(), profile.ld_rows.len(), ld_nnz);

        // --- HD path: chunk partials into scratch, then reduce. ---
        if !hd_chunks.is_empty() {
            let t_hd = Instant::now();
            let _span = obs::span(if backward { "spmm_hd_backward" } else { "spmm_hd" }, "kernel");
            let nslots: usize = hd_reduce.iter().map(|&(_, _, c)| c).sum();
            let need = nslots * dim;
            // zero the reused prefix; resize zero-fills any new tail itself
            let reused = hd_scratch.len().min(need);
            hd_scratch[..reused].fill(0.0);
            if hd_scratch.len() < need {
                hd_scratch.resize(need, 0.0);
            }
            let sptr = SendPtr(hd_scratch.as_mut_ptr());
            parallel_for_dynamic(self.threads, hd_chunks.len(), 1, |_, cs, ce| {
                let sptr = &sptr;
                for c in cs..ce {
                    let (u, c0, c1, slot) = hd_chunks[c];
                    let base = csr.row_ptr[u as usize];
                    let srow =
                        unsafe { std::slice::from_raw_parts_mut(sptr.0.add(slot * dim), dim) };
                    let cols = &csr.col_idx[base + c0..base + c1];
                    if backward {
                        simd::gather_weighted(x, dim, cols, &csr.row_ptr, srow);
                    } else {
                        simd::gather_sum(x, dim, cols, srow);
                    }
                }
            });
            // Reduction (parallel over HD rows). Backward partials are
            // already column-weighted, so they reduce by plain addition.
            let scratch: &[f32] = hd_scratch;
            parallel_for_static(self.threads, hd_reduce.len(), |_, rs, re| {
                let ptr = &ptr;
                for r in rs..re {
                    let (u, slot0, count) = hd_reduce[r];
                    let u = u as usize;
                    let orow =
                        unsafe { std::slice::from_raw_parts_mut(ptr.0.add(u * dim), dim) };
                    for s in slot0..slot0 + count {
                        simd::add_assign(orow, &scratch[s * dim..(s + 1) * dim]);
                    }
                    if !backward {
                        simd::scale_assign(orow, 1.0 / csr.degree(u) as f32);
                    }
                }
            });
            kernel_stats().1.record(t_hd.elapsed(), hd_reduce.len(), hd_nnz);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::test_support::{check_engine_matches_reference, polarized_graph};
    use crate::util::rng::Rng;

    #[test]
    fn groot_matches_reference() {
        check_engine_matches_reference(&GrootSpmm::new(4));
        check_engine_matches_reference(&GrootSpmm::new(1));
        // tiny thresholds force both paths to engage on small graphs
        check_engine_matches_reference(&GrootSpmm::with_config(
            3,
            GrootConfig { hd_threshold: 8, hd_chunk: 4, ld_nnz_per_task: 16, ..Default::default() },
        ));
    }

    #[test]
    fn groot_backward_matches_reference() {
        use crate::spmm::test_support::check_engine_backward_matches_reference;
        check_engine_backward_matches_reference(&GrootSpmm::new(4));
        check_engine_backward_matches_reference(&GrootSpmm::new(1));
        // tiny thresholds force the HD chunk/reduce path through backward
        check_engine_backward_matches_reference(&GrootSpmm::with_config(
            3,
            GrootConfig { hd_threshold: 8, hd_chunk: 4, ld_nnz_per_task: 16, ..Default::default() },
        ));
    }

    #[test]
    fn forward_and_backward_share_the_cached_plan() {
        let mut rng = Rng::new(5);
        let g = polarized_graph(&mut rng, 300, 2, 150);
        let engine = GrootSpmm::with_config(
            2,
            GrootConfig { hd_threshold: 16, hd_chunk: 8, ld_nnz_per_task: 64, ..Default::default() },
        );
        let x: Vec<f32> = (0..300 * 4).map(|i| ((i % 11) as f32) * 0.25 - 1.0).collect();
        let mut y = vec![0.0f32; 300 * 4];
        engine.spmm_mean_into(&g, &x, 4, &mut y);
        let ptr_before = {
            let guard = engine.plan.lock().unwrap();
            guard.as_ref().unwrap().row_ptr.as_ptr()
        };
        let mut gx = vec![0.0f32; 300 * 4];
        engine.spmm_mean_backward_into(&g, &x, 4, &mut gx);
        let ptr_after = {
            let guard = engine.plan.lock().unwrap();
            guard.as_ref().unwrap().row_ptr.as_ptr()
        };
        assert_eq!(ptr_before, ptr_after, "backward rebuilt the plan");
        let want = g.spmm_mean_backward_reference(&x, 4);
        let scale = want.iter().fold(1.0f32, |m, &v| m.max(v.abs()));
        assert!(crate::graph::Csr::max_abs_diff(&gx, &want) < 1e-4 * scale);
    }

    #[test]
    fn plan_cache_reused_and_invalidated() {
        let mut rng = Rng::new(1);
        let g1 = polarized_graph(&mut rng, 200, 2, 100, );
        let g2 = polarized_graph(&mut rng, 150, 1, 50);
        let engine = GrootSpmm::with_config(
            2,
            GrootConfig { hd_threshold: 16, hd_chunk: 8, ld_nnz_per_task: 64, ..Default::default() },
        );
        let x1 = vec![1.0f32; 200 * 2];
        let x2 = vec![1.0f32; 150 * 2];
        let y1a = engine.spmm_mean(&g1, &x1, 2);
        let y1b = engine.spmm_mean(&g1, &x1, 2); // cached plan
        assert_eq!(y1a, y1b);
        let y2 = engine.spmm_mean(&g2, &x2, 2); // invalidates
        let want = g2.spmm_mean_reference(&x2, 2);
        assert!(crate::graph::Csr::max_abs_diff(&y2, &want) < 1e-5);
    }

    #[test]
    fn plan_cache_keyed_by_degree_structure_not_address() {
        // Regression: the cache used to be keyed by (n, nnz, row_ptr
        // address); a freed graph's allocation reused at the same address
        // silently served a stale plan. Star and path below agree on n and
        // nnz but have different degree structures, and dropping the star
        // before building the path invites the allocator to reuse its
        // blocks. The content-keyed cache must rebuild regardless.
        let engine = GrootSpmm::with_config(
            2,
            GrootConfig { hd_threshold: 3, hd_chunk: 2, ld_nnz_per_task: 4, ..Default::default() },
        );
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let y_star = {
            let star = crate::graph::Csr::symmetric_from_edges(4, &[(0, 1), (0, 2), (0, 3)]);
            let want = star.spmm_mean_reference(&x, 2);
            let got = engine.spmm_mean(&star, &x, 2);
            assert!(crate::graph::Csr::max_abs_diff(&got, &want) < 1e-6);
            (star.num_nodes(), star.num_entries())
        }; // star (and its row_ptr allocation) dropped here
        let path = crate::graph::Csr::symmetric_from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert_eq!((path.num_nodes(), path.num_entries()), y_star);
        let want = path.spmm_mean_reference(&x, 2);
        let got = engine.spmm_mean(&path, &x, 2);
        assert!(
            crate::graph::Csr::max_abs_diff(&got, &want) < 1e-6,
            "stale plan served for a different graph with matching n/nnz"
        );
    }

    #[test]
    fn kernel_metrics_accumulate_per_half() {
        // The registry is process-global and other tests run engines
        // concurrently, so assert deltas as lower bounds.
        let (ld0, hd0) = {
            let (ld, hd) = kernel_stats();
            (ld.time.count(), hd.time.count())
        };
        let mut rng = Rng::new(9);
        let g = polarized_graph(&mut rng, 300, 2, 150);
        let engine = GrootSpmm::with_config(
            2,
            GrootConfig { hd_threshold: 16, hd_chunk: 8, ld_nnz_per_task: 64, ..Default::default() },
        );
        let x = vec![1.0f32; 300 * 2];
        let _ = engine.spmm_mean(&g, &x, 2);
        let (ld, hd) = kernel_stats();
        assert!(ld.time.count() > ld0, "LD kernel call was not recorded");
        assert!(hd.time.count() > hd0, "HD kernel call was not recorded");
        assert!(ld.rows.get() > 0 && hd.rows.get() > 0);
        assert!(ld.nnz.get() > 0 && hd.nnz.get() > 0);
    }

    #[test]
    fn hd_rows_split_into_multiple_chunks() {
        let mut rng = Rng::new(2);
        let g = polarized_graph(&mut rng, 400, 1, 300);
        let engine = GrootSpmm::with_config(
            4,
            GrootConfig { hd_threshold: 64, hd_chunk: 32, ld_nnz_per_task: 128, ..Default::default() },
        );
        let x: Vec<f32> = (0..400 * 4).map(|i| (i % 7) as f32).collect();
        let got = engine.spmm_mean(&g, &x, 4);
        let want = g.spmm_mean_reference(&x, 4);
        assert!(crate::graph::Csr::max_abs_diff(&got, &want) < 1e-4);
        // the plan actually used chunking
        let guard = engine.plan.lock().unwrap();
        let plan = guard.as_ref().unwrap();
        assert!(plan.hd_chunks.len() > plan.hd_reduce.len(), "no row was chunked");
    }
}

//! int8-weight / f32-activation quantized GraphSAGE inference.
//!
//! The task-aligned-GNN analysis (Kim, PAPERS.md) observes that EDA
//! node-classification heads have wide decision margins, so weight-only
//! low-precision inference should cost ~nothing in accuracy. The scheme
//! here is per-output-channel symmetric int8:
//!
//! * at bundle load, each weight column j gets a scale
//!   `s[j] = max_k |W[k][j]| / 127` (1.0 for an all-zero column) and the
//!   stored weights become `q = round(W / s)` clamped to `[-127, 127]`;
//! * the GEMM accumulates `Σ_k a[k] · (q[k][j] as f32)` in f32 — i8→f32
//!   conversion is exact and the sum of ≤64 terms of magnitude ≤127·|a|
//!   stays well inside f32's exact-integer-scaled range;
//! * the dequant multiply `acc[j] · s[j]` is fused into the GEMM epilogue
//!   together with the `out +=` accumulate — activations never exist in
//!   int8, so aggregation (SpMM) is byte-identical to the f32 path.
//!
//! Determinism contract: the int8 path is *not* byte-identical to f32
//! inference (weights moved), but it IS byte-deterministic — thread count
//! and SIMD dispatch never change its output, by the same fixed-order
//! argument as the f32 kernels. The serving-level guarantee is argmax
//! parity: zero prediction flips across the generator zoo (pinned by the
//! `kernel_parity` suite).

use super::{ForwardScratch, SageModel};
use crate::graph::Csr;
use crate::spmm::SpmmEngine;
use crate::util::pool::{parallel_for_static, SendPtr};
use crate::util::simd;

/// Inference precision knob (`SessionConfig::precision`, CLI
/// `--precision {f32,int8}`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    #[default]
    F32,
    Int8,
}

impl std::str::FromStr for Precision {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Precision> {
        match s {
            "f32" => Ok(Precision::F32),
            "int8" => Ok(Precision::Int8),
            other => anyhow::bail!("unknown precision '{other}' (expected f32 or int8)"),
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        })
    }
}

/// One layer's quantized parameters. Weights row-major `[din × dout]`
/// like [`super::SageLayer`]; scales and bias per output channel.
#[derive(Clone, Debug)]
pub struct QuantLayer {
    pub din: usize,
    pub dout: usize,
    pub w_self_q: Vec<i8>,
    pub w_neigh_q: Vec<i8>,
    pub scale_self: Vec<f32>,
    pub scale_neigh: Vec<f32>,
    pub bias: Vec<f32>,
}

/// Whole quantized model, derived from a loaded [`SageModel`].
#[derive(Clone, Debug)]
pub struct QuantizedSage {
    pub layers: Vec<QuantLayer>,
}

/// Per-output-channel symmetric quantization of one row-major `[k × m]`
/// weight matrix: returns `(q, scales)` with `scales.len() == m`.
fn quantize_per_channel(w: &[f32], k: usize, m: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(w.len(), k * m);
    let mut scales = vec![0.0f32; m];
    for row in w.chunks_exact(m) {
        for (s, &v) in scales.iter_mut().zip(row) {
            *s = s.max(v.abs());
        }
    }
    for s in scales.iter_mut() {
        // all-zero column: any scale works, 1.0 keeps dequant finite
        *s = if *s > 0.0 { *s / 127.0 } else { 1.0 };
    }
    let q = w
        .chunks_exact(m)
        .flat_map(|row| {
            row.iter()
                .zip(&scales)
                .map(|(&v, &s)| (v / s).round().clamp(-127.0, 127.0) as i8)
        })
        .collect();
    (q, scales)
}

impl QuantizedSage {
    /// Quantize a loaded f32 model (done once, at backend construction).
    pub fn from_model(model: &SageModel) -> QuantizedSage {
        let layers = model
            .layers
            .iter()
            .map(|l| {
                let (w_self_q, scale_self) = quantize_per_channel(&l.w_self, l.din, l.dout);
                let (w_neigh_q, scale_neigh) = quantize_per_channel(&l.w_neigh, l.din, l.dout);
                QuantLayer {
                    din: l.din,
                    dout: l.dout,
                    w_self_q,
                    w_neigh_q,
                    scale_self,
                    scale_neigh,
                    bias: l.bias.clone(),
                }
            })
            .collect();
        QuantizedSage { layers }
    }

    pub fn input_dim(&self) -> usize {
        self.layers[0].din
    }

    pub fn num_classes(&self) -> usize {
        self.layers.last().unwrap().dout
    }

    pub fn max_width(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.dout)
            .max()
            .unwrap_or(0)
            .max(self.input_dim())
    }

    /// Quantized forward pass — the shape-for-shape twin of
    /// [`SageModel::forward_with_threads`] with the dense matmuls swapped
    /// for [`matmul_add_q`] (int8 weights, fused dequant epilogue).
    /// Aggregation runs the same f32 SpMM engines.
    pub fn forward_with_threads<'s>(
        &self,
        csr: &Csr,
        features: &[f32],
        engine: &dyn SpmmEngine,
        scratch: &'s mut ForwardScratch,
        threads: usize,
    ) -> &'s [f32] {
        let n = csr.num_nodes();
        let mut dim = self.input_dim();
        assert_eq!(features.len(), n * dim);
        scratch.reserve_len(n * self.max_width());
        scratch.ping[..n * dim].copy_from_slice(features);
        let nlayers = self.layers.len();
        for (li, layer) in self.layers.iter().enumerate() {
            let h = &scratch.ping[..n * dim];
            engine.spmm_mean_into(csr, h, dim, &mut scratch.agg[..n * dim]);
            let out = &mut scratch.pong[..n * layer.dout];
            out.fill(0.0);
            matmul_add_q(threads, h, &layer.w_self_q, &layer.scale_self, out, n, dim, layer.dout);
            matmul_add_q(
                threads,
                &scratch.agg[..n * dim],
                &layer.w_neigh_q,
                &layer.scale_neigh,
                out,
                n,
                dim,
                layer.dout,
            );
            for row in out.chunks_exact_mut(layer.dout) {
                for (d, v) in row.iter_mut().enumerate() {
                    *v += layer.bias[d];
                }
            }
            if li + 1 < nlayers {
                for v in out.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            std::mem::swap(&mut scratch.ping, &mut scratch.pong);
            dim = layer.dout;
        }
        &scratch.ping[..n * dim]
    }
}

/// `out += dequant(a[n×k] · q[k×m])`: int8-weight GEMM with the
/// per-channel dequant (`· scales[j]`) fused into the accumulate
/// epilogue. Row-parallel like [`super::matmul_add_with`]; each thread
/// reuses one `m`-float accumulator across its rows, so the steady state
/// allocates one small buffer per thread per call.
#[allow(clippy::too_many_arguments)]
pub fn matmul_add_q(
    threads: usize,
    a: &[f32],
    q: &[i8],
    scales: &[f32],
    out: &mut [f32],
    n: usize,
    k: usize,
    m: usize,
) {
    assert_eq!(a.len(), n * k);
    assert_eq!(q.len(), k * m);
    assert_eq!(scales.len(), m);
    assert_eq!(out.len(), n * m);
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for_static(threads, n, |_, s, e| {
        let ptr = &ptr;
        let mut acc = vec![0.0f32; m];
        for u in s..e {
            acc.fill(0.0);
            simd::matmul_row_add_q(&a[u * k..(u + 1) * k], q, m, &mut acc);
            // SAFETY: disjoint row ranges per thread.
            let orow = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(u * m), m) };
            for ((o, &v), &sc) in orow.iter_mut().zip(&acc).zip(scales) {
                *o += v * sc;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::CsrRowParallel;

    fn wave_model() -> SageModel {
        use super::super::SageLayer;
        let wave = |n: usize, scale: f32| -> Vec<f32> {
            (0..n).map(|i| ((i as f32 * 0.7).sin()) * scale).collect()
        };
        SageModel {
            layers: vec![
                SageLayer {
                    din: 4,
                    dout: 16,
                    w_self: wave(64, 0.5),
                    w_neigh: wave(64, 0.3),
                    bias: wave(16, 0.1),
                },
                SageLayer {
                    din: 16,
                    dout: 5,
                    w_self: wave(80, 0.4),
                    w_neigh: wave(80, 0.2),
                    bias: wave(5, 0.05),
                },
            ],
        }
    }

    #[test]
    fn quantize_error_bounded_by_half_scale() {
        let m = wave_model();
        for l in &m.layers {
            let (q, s) = quantize_per_channel(&l.w_self, l.din, l.dout);
            for (kk, row) in l.w_self.chunks_exact(l.dout).enumerate() {
                for (j, &v) in row.iter().enumerate() {
                    let deq = q[kk * l.dout + j] as f32 * s[j];
                    assert!(
                        (v - deq).abs() <= s[j] * 0.5 + 1e-7,
                        "layer col {j}: {v} vs {deq} (scale {})",
                        s[j]
                    );
                }
            }
        }
    }

    #[test]
    fn zero_column_gets_unit_scale_and_zero_codes() {
        let w = vec![0.0f32, 1.0, 0.0, -2.0]; // [2×2], col 0 all zero
        let (q, s) = quantize_per_channel(&w, 2, 2);
        assert_eq!(s[0], 1.0);
        assert_eq!(q[0], 0);
        assert_eq!(q[2], 0);
        assert_eq!(q[3], -127);
    }

    #[test]
    fn int8_forward_tracks_f32_and_is_thread_invariant() {
        let model = wave_model();
        let qmodel = QuantizedSage::from_model(&model);
        let edges: Vec<(u32, u32)> = (0..63u32).map(|v| (v, v + 1)).collect();
        let csr = Csr::symmetric_from_edges(64, &edges);
        let x: Vec<f32> = (0..64 * 4).map(|i| (i as f32 * 0.13).sin()).collect();
        let engine = CsrRowParallel::new(1);
        let mut s_f = ForwardScratch::new();
        let f = model
            .forward_with_threads(&csr, &x, &engine, &mut s_f, 1)
            .to_vec();
        let mut s_q = ForwardScratch::new();
        let q = qmodel
            .forward_with_threads(&csr, &x, &engine, &mut s_q, 1)
            .to_vec();
        let err = f
            .iter()
            .zip(&q)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 0.05, "quantization error too large: {err}");
        // argmax parity on this model/graph (the zoo matrix runs in the
        // kernel_parity integration suite)
        assert_eq!(
            super::super::argmax_rows(&f, 5),
            super::super::argmax_rows(&q, 5)
        );
        for threads in [2usize, 3, 8] {
            let mut s = ForwardScratch::new();
            let got = qmodel.forward_with_threads(&csr, &x, &engine, &mut s, threads);
            assert_eq!(got, &q[..], "threads={threads} changed int8 bytes");
        }
    }
}

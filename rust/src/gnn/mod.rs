//! Rust-native GraphSAGE inference — the GAMORA-like full-graph baseline
//! and the numeric twin of the AOT model (used by tests to cross-check the
//! PJRT runtime, and by the Fig. 10 harness as the "GAMORA" comparator).
//!
//! Matches `python/compile/model.py` exactly: mean aggregation over the
//! symmetric adjacency, act(h·W_self + agg·W_neigh + b), ReLU on all but
//! the last layer. The aggregation runs on the pluggable SpMM engines from
//! [`crate::spmm`], which is how the Fig. 9 kernel comparison plugs into a
//! real model workload.

use crate::graph::Csr;
use crate::spmm::SpmmEngine;
use crate::util::tensor::Bundle;
use anyhow::{Context, Result};

/// One GraphSAGE layer's parameters (row-major [din × dout] weights).
#[derive(Clone, Debug)]
pub struct SageLayer {
    pub din: usize,
    pub dout: usize,
    pub w_self: Vec<f32>,
    pub w_neigh: Vec<f32>,
    pub bias: Vec<f32>,
}

/// Whole model: layers in order; last layer emits logits (no ReLU).
#[derive(Clone, Debug)]
pub struct SageModel {
    pub layers: Vec<SageLayer>,
}

impl SageModel {
    /// Load from a GRTW weight bundle (names `l{i}.w_self` etc).
    pub fn from_bundle(bundle: &Bundle) -> Result<SageModel> {
        let mut layers = Vec::new();
        for i in 0.. {
            let Some(ws) = bundle.get(&format!("l{i}.w_self")) else {
                break;
            };
            let wn = bundle
                .get(&format!("l{i}.w_neigh"))
                .with_context(|| format!("missing l{i}.w_neigh"))?;
            let b = bundle
                .get(&format!("l{i}.b"))
                .with_context(|| format!("missing l{i}.b"))?;
            anyhow::ensure!(ws.dims.len() == 2, "w_self must be 2-d");
            let (din, dout) = (ws.dims[0], ws.dims[1]);
            anyhow::ensure!(wn.dims == vec![din, dout], "w_neigh shape");
            anyhow::ensure!(b.dims == vec![dout], "bias shape");
            layers.push(SageLayer {
                din,
                dout,
                w_self: ws.as_f32()?.to_vec(),
                w_neigh: wn.as_f32()?.to_vec(),
                bias: b.as_f32()?.to_vec(),
            });
        }
        anyhow::ensure!(!layers.is_empty(), "bundle has no layers");
        Ok(SageModel { layers })
    }

    pub fn input_dim(&self) -> usize {
        self.layers[0].din
    }

    pub fn num_classes(&self) -> usize {
        self.layers.last().unwrap().dout
    }

    /// Full-graph forward pass: features [n × input_dim] → logits
    /// [n × num_classes]. Aggregation via the supplied SpMM engine.
    pub fn forward(&self, csr: &Csr, features: &[f32], engine: &dyn SpmmEngine) -> Vec<f32> {
        let n = csr.num_nodes();
        assert_eq!(features.len(), n * self.input_dim());
        let mut h = features.to_vec();
        let mut dim = self.input_dim();
        for (li, layer) in self.layers.iter().enumerate() {
            let agg = engine.spmm_mean(csr, &h, dim);
            let mut out = vec![0.0f32; n * layer.dout];
            matmul_add(&h, &layer.w_self, &mut out, n, dim, layer.dout);
            matmul_add(&agg, &layer.w_neigh, &mut out, n, dim, layer.dout);
            for u in 0..n {
                for d in 0..layer.dout {
                    out[u * layer.dout + d] += layer.bias[d];
                }
            }
            if li + 1 < self.layers.len() {
                for v in out.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            h = out;
            dim = layer.dout;
        }
        h
    }

    /// Argmax class per node from a forward pass.
    pub fn predict(&self, csr: &Csr, features: &[f32], engine: &dyn SpmmEngine) -> Vec<u8> {
        let logits = self.forward(csr, features, engine);
        argmax_rows(&logits, self.num_classes())
    }
}

/// out += a[n×k] · b[k×m] (row-major), parallel over rows.
pub fn matmul_add(a: &[f32], b: &[f32], out: &mut [f32], n: usize, k: usize, m: usize) {
    use crate::util::pool::{default_threads, parallel_for_static, SendPtr};
    assert_eq!(a.len(), n * k);
    assert_eq!(b.len(), k * m);
    assert_eq!(out.len(), n * m);
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for_static(default_threads(), n, |_, s, e| {
        let ptr = &ptr;
        for u in s..e {
            // SAFETY: disjoint row ranges per thread.
            let orow = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(u * m), m) };
            let arow = &a[u * k..(u + 1) * k];
            for (kk, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    let brow = &b[kk * m..(kk + 1) * m];
                    for d in 0..m {
                        orow[d] += av * brow[d];
                    }
                }
            }
        }
    });
}

/// Row-wise argmax → class ids.
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<u8> {
    logits
        .chunks_exact(classes)
        .map(|row| {
            let mut best = 0usize;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            best as u8
        })
        .collect()
}

/// Node-classification accuracy over the first `n` rows.
pub fn accuracy(pred: &[u8], labels: &[u8]) -> f64 {
    assert_eq!(pred.len(), labels.len());
    if pred.is_empty() {
        return 1.0;
    }
    let correct = pred.iter().zip(labels).filter(|(a, b)| a == b).count();
    correct as f64 / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::CsrRowParallel;
    use crate::util::tensor::Tensor;

    fn tiny_model() -> SageModel {
        // 2 → 2 identity-ish single layer for hand-checkable numbers.
        SageModel {
            layers: vec![SageLayer {
                din: 2,
                dout: 2,
                w_self: vec![1.0, 0.0, 0.0, 1.0],
                w_neigh: vec![0.0, 0.0, 0.0, 0.0],
                bias: vec![0.5, -0.5],
            }],
        }
    }

    #[test]
    fn forward_hand_checked() {
        let csr = Csr::symmetric_from_edges(2, &[(0, 1)]);
        let model = tiny_model();
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let engine = CsrRowParallel::new(1);
        let out = model.forward(&csr, &x, &engine);
        // last layer → no relu; w_self = I, bias (0.5, -0.5)
        assert_eq!(out, vec![1.5, 1.5, 3.5, 3.5]);
    }

    #[test]
    fn bundle_roundtrip() {
        let mut b = Bundle::new();
        b.insert("l0.w_self".into(), Tensor::f32(vec![2, 3], vec![0.0; 6]));
        b.insert("l0.w_neigh".into(), Tensor::f32(vec![2, 3], vec![0.0; 6]));
        b.insert("l0.b".into(), Tensor::f32(vec![3], vec![0.0; 3]));
        b.insert("l1.w_self".into(), Tensor::f32(vec![3, 5], vec![0.0; 15]));
        b.insert("l1.w_neigh".into(), Tensor::f32(vec![3, 5], vec![0.0; 15]));
        b.insert("l1.b".into(), Tensor::f32(vec![5], vec![0.0; 5]));
        let m = SageModel::from_bundle(&b).unwrap();
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.input_dim(), 2);
        assert_eq!(m.num_classes(), 5);
    }

    #[test]
    fn argmax_and_accuracy() {
        let logits = vec![0.1, 0.9, 0.5, 0.2, 3.0, -1.0];
        let pred = argmax_rows(&logits, 2);
        assert_eq!(pred, vec![1, 0, 0]);
        assert!((accuracy(&pred, &[1, 0, 1]) - 2.0 / 3.0).abs() < 1e-9);
    }
}

//! Rust-native GraphSAGE inference — the GAMORA-like full-graph baseline
//! and the numeric twin of the AOT model (used by tests to cross-check the
//! PJRT runtime, and by the Fig. 10 harness as the "GAMORA" comparator).
//!
//! Matches `python/compile/model.py` exactly: mean aggregation over the
//! symmetric adjacency, act(h·W_self + agg·W_neigh + b), ReLU on all but
//! the last layer. The aggregation runs on the pluggable SpMM engines from
//! [`crate::spmm`], which is how the Fig. 9 kernel comparison plugs into a
//! real model workload.

use crate::graph::Csr;
use crate::spmm::SpmmEngine;
use crate::util::tensor::Bundle;
use anyhow::{Context, Result};

pub mod quant;
pub use quant::{Precision, QuantizedSage};

/// One GraphSAGE layer's parameters (row-major [din × dout] weights).
#[derive(Clone, Debug)]
pub struct SageLayer {
    pub din: usize,
    pub dout: usize,
    pub w_self: Vec<f32>,
    pub w_neigh: Vec<f32>,
    pub bias: Vec<f32>,
}

/// Whole model: layers in order; last layer emits logits (no ReLU).
#[derive(Clone, Debug)]
pub struct SageModel {
    pub layers: Vec<SageLayer>,
}

/// Reusable buffer arena for [`SageModel::forward_with`].
///
/// Holds the two ping-pong activation buffers plus the aggregation buffer,
/// all sized `n × max_width` and grown on demand but never shrunk: after
/// the first forward pass at a given graph size, subsequent passes perform
/// zero heap allocations (the engine side is covered by
/// [`crate::spmm::SpmmEngine::spmm_mean_into`]).
#[derive(Debug, Default)]
pub struct ForwardScratch {
    /// Current layer input (the features on entry). Swapped with `pong`
    /// after every layer, so the final activations always end up here.
    ping: Vec<f32>,
    /// Current layer output.
    pong: Vec<f32>,
    /// Mean-aggregated neighborhood features for the current layer.
    agg: Vec<f32>,
}

impl ForwardScratch {
    pub fn new() -> ForwardScratch {
        ForwardScratch::default()
    }

    /// Grow (never shrink) all three buffers to at least `len` elements.
    fn reserve_len(&mut self, len: usize) {
        if self.ping.len() < len {
            self.ping.resize(len, 0.0);
        }
        if self.pong.len() < len {
            self.pong.resize(len, 0.0);
        }
        if self.agg.len() < len {
            self.agg.resize(len, 0.0);
        }
    }

    /// The (unordered) set of buffer base pointers — lets tests assert the
    /// arena is stable (no reallocation) across warm forward passes.
    pub fn buffer_ptrs(&self) -> [*const f32; 3] {
        let mut p = [self.ping.as_ptr(), self.pong.as_ptr(), self.agg.as_ptr()];
        p.sort();
        p
    }
}

impl SageModel {
    /// Load from a GRTW weight bundle (names `l{i}.w_self` etc).
    pub fn from_bundle(bundle: &Bundle) -> Result<SageModel> {
        let mut layers = Vec::new();
        for i in 0.. {
            let Some(ws) = bundle.get(&format!("l{i}.w_self")) else {
                break;
            };
            let wn = bundle
                .get(&format!("l{i}.w_neigh"))
                .with_context(|| format!("missing l{i}.w_neigh"))?;
            let b = bundle
                .get(&format!("l{i}.b"))
                .with_context(|| format!("missing l{i}.b"))?;
            anyhow::ensure!(ws.dims.len() == 2, "w_self must be 2-d");
            let (din, dout) = (ws.dims[0], ws.dims[1]);
            anyhow::ensure!(wn.dims == vec![din, dout], "w_neigh shape");
            anyhow::ensure!(b.dims == vec![dout], "bias shape");
            layers.push(SageLayer {
                din,
                dout,
                w_self: ws.as_f32()?.to_vec(),
                w_neigh: wn.as_f32()?.to_vec(),
                bias: b.as_f32()?.to_vec(),
            });
        }
        anyhow::ensure!(!layers.is_empty(), "bundle has no layers");
        Ok(SageModel { layers })
    }

    pub fn input_dim(&self) -> usize {
        self.layers[0].din
    }

    pub fn num_classes(&self) -> usize {
        self.layers.last().unwrap().dout
    }

    /// Widest activation row the forward pass materializes: the input dim
    /// and every layer's output dim. Sizes the [`ForwardScratch`] buffers.
    pub fn max_width(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.dout)
            .max()
            .unwrap_or(0)
            .max(self.input_dim())
    }

    /// Full-graph forward pass: features [n × input_dim] → logits
    /// [n × num_classes]. Aggregation via the supplied SpMM engine.
    ///
    /// Allocating wrapper over [`SageModel::forward_with`]; hot paths
    /// (e.g. [`crate::backend::NativeBackend`]) hold a [`ForwardScratch`]
    /// and call `forward_with` directly.
    pub fn forward(&self, csr: &Csr, features: &[f32], engine: &dyn SpmmEngine) -> Vec<f32> {
        let mut scratch = ForwardScratch::new();
        self.forward_with(csr, features, engine, &mut scratch).to_vec()
    }

    /// Forward pass into a caller-owned [`ForwardScratch`]: each layer
    /// aggregates into the scratch `agg` buffer and writes activations
    /// into the opposite ping-pong buffer — no per-layer allocation. The
    /// returned slice (the logits, [n × num_classes]) borrows the scratch
    /// and is valid until the next pass. Dense matmuls run on the
    /// process-default thread count; lanes that share a split thread
    /// budget (see [`crate::util::pool::split_threads`]) call
    /// [`Self::forward_with_threads`] instead.
    pub fn forward_with<'s>(
        &self,
        csr: &Csr,
        features: &[f32],
        engine: &dyn SpmmEngine,
        scratch: &'s mut ForwardScratch,
    ) -> &'s [f32] {
        self.forward_with_threads(csr, features, engine, scratch, {
            use crate::util::pool::default_threads;
            default_threads()
        })
    }

    /// [`Self::forward_with`] with an explicit dense-matmul thread count,
    /// so per-backend budgets are honored instead of the process-wide
    /// `GROOT_THREADS` default. Thread count never changes the numbers:
    /// each output row is accumulated by exactly one thread in a fixed
    /// order, so results are byte-identical for every `threads` value.
    pub fn forward_with_threads<'s>(
        &self,
        csr: &Csr,
        features: &[f32],
        engine: &dyn SpmmEngine,
        scratch: &'s mut ForwardScratch,
        threads: usize,
    ) -> &'s [f32] {
        let n = csr.num_nodes();
        let mut dim = self.input_dim();
        assert_eq!(features.len(), n * dim);
        scratch.reserve_len(n * self.max_width());
        scratch.ping[..n * dim].copy_from_slice(features);
        let nlayers = self.layers.len();
        for (li, layer) in self.layers.iter().enumerate() {
            let h = &scratch.ping[..n * dim];
            engine.spmm_mean_into(csr, h, dim, &mut scratch.agg[..n * dim]);
            dense_sage_layer(
                threads,
                layer,
                h,
                &scratch.agg[..n * dim],
                &mut scratch.pong[..n * layer.dout],
                n,
                dim,
                li + 1 == nlayers,
            );
            // ping-pong: this layer's output becomes the next layer's input
            std::mem::swap(&mut scratch.ping, &mut scratch.pong);
            dim = layer.dout;
        }
        &scratch.ping[..n * dim]
    }

    /// Bucketed batched forward over several partitions that share this
    /// model (and therefore every layer dimension): activations are
    /// stacked row-wise into ONE arena, the per-partition SpMMs run
    /// concurrently (one engine/lane per partition) into disjoint slices
    /// of the stacked aggregation buffer, and each layer's dense work is a
    /// single `[Σn × dim]` GEMM pair at the full `threads` budget instead
    /// of P independent small matmuls.
    ///
    /// Byte-identical to running [`Self::forward_with_threads`] per
    /// partition: every output row is still accumulated by exactly one
    /// thread in the same order (rows are independent in the dense
    /// kernels, and each partition's SpMM sees exactly its own contiguous
    /// activation slice).
    pub fn forward_batch_fused(
        &self,
        parts: &[(&Csr, &[f32])],
        engines: &[&dyn SpmmEngine],
        scratch: &mut ForwardScratch,
        threads: usize,
    ) -> Vec<Vec<f32>> {
        use crate::util::pool::{parallel_map, SendPtr};
        assert_eq!(parts.len(), engines.len());
        let rows: Vec<usize> = parts.iter().map(|(c, _)| c.num_nodes()).collect();
        let row_off: Vec<usize> = rows
            .iter()
            .scan(0usize, |acc, &n| {
                let o = *acc;
                *acc += n;
                Some(o)
            })
            .collect();
        let total: usize = rows.iter().sum();
        let mut dim = self.input_dim();
        scratch.reserve_len(total * self.max_width());
        for (i, (csr, feats)) in parts.iter().enumerate() {
            assert_eq!(feats.len(), csr.num_nodes() * dim, "partition {i}: feature len");
            scratch.ping[row_off[i] * dim..(row_off[i] + rows[i]) * dim].copy_from_slice(feats);
        }
        let nlayers = self.layers.len();
        for (li, layer) in self.layers.iter().enumerate() {
            let ForwardScratch { ping, pong, agg } = &mut *scratch;
            let h = &ping[..total * dim];
            // One lane per partition; slices of `agg` are disjoint by
            // construction of `row_off`.
            let aptr = SendPtr(agg.as_mut_ptr());
            parallel_map(parts.len(), parts.len(), |i| {
                let aptr = &aptr;
                // SAFETY: partition i's stacked rows are disjoint from
                // every other partition's.
                let arow = unsafe {
                    std::slice::from_raw_parts_mut(aptr.0.add(row_off[i] * dim), rows[i] * dim)
                };
                engines[i].spmm_mean_into(
                    parts[i].0,
                    &h[row_off[i] * dim..(row_off[i] + rows[i]) * dim],
                    dim,
                    arow,
                );
            });
            dense_sage_layer(
                threads,
                layer,
                h,
                &agg[..total * dim],
                &mut pong[..total * layer.dout],
                total,
                dim,
                li + 1 == nlayers,
            );
            std::mem::swap(&mut scratch.ping, &mut scratch.pong);
            dim = layer.dout;
        }
        (0..parts.len())
            .map(|i| scratch.ping[row_off[i] * dim..(row_off[i] + rows[i]) * dim].to_vec())
            .collect()
    }

    /// Argmax class per node from a forward pass.
    pub fn predict(&self, csr: &Csr, features: &[f32], engine: &dyn SpmmEngine) -> Vec<u8> {
        let logits = self.forward(csr, features, engine);
        argmax_rows(&logits, self.num_classes())
    }
}

/// The dense half of one SAGE layer over pre-aggregated inputs:
/// `out = act(h·W_self + agg·W_neigh + b)` with ReLU unless `last`.
/// Shared verbatim by the per-partition forward and the fused batched
/// forward so the two paths cannot drift numerically.
#[allow(clippy::too_many_arguments)]
fn dense_sage_layer(
    threads: usize,
    layer: &SageLayer,
    h: &[f32],
    agg: &[f32],
    out: &mut [f32],
    n: usize,
    dim: usize,
    last: bool,
) {
    out.fill(0.0);
    matmul_add_with(threads, h, &layer.w_self, out, n, dim, layer.dout);
    matmul_add_with(threads, agg, &layer.w_neigh, out, n, dim, layer.dout);
    for row in out.chunks_exact_mut(layer.dout) {
        for (d, v) in row.iter_mut().enumerate() {
            *v += layer.bias[d];
        }
    }
    if !last {
        for v in out.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

/// out += a[n×k] · b[k×m] (row-major), parallel over rows with the
/// process-default thread count ([`matmul_add_with`] takes an explicit
/// one).
pub fn matmul_add(a: &[f32], b: &[f32], out: &mut [f32], n: usize, k: usize, m: usize) {
    matmul_add_with(crate::util::pool::default_threads(), a, b, out, n, k, m)
}

/// [`matmul_add`] with an explicit thread count (per-row accumulation
/// order is fixed, so every thread count produces identical bytes).
pub fn matmul_add_with(
    threads: usize,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    k: usize,
    m: usize,
) {
    use crate::util::pool::{parallel_for_static, SendPtr};
    assert_eq!(a.len(), n * k);
    assert_eq!(b.len(), k * m);
    assert_eq!(out.len(), n * m);
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for_static(threads, n, |_, s, e| {
        let ptr = &ptr;
        for u in s..e {
            // SAFETY: disjoint row ranges per thread.
            let orow = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(u * m), m) };
            // Register-blocked micro-kernel (AVX2 when available, hoisted
            // slice-iterating scalar otherwise); zero activations are
            // skipped either way, and the per-element accumulation order
            // over k is fixed — bytes never depend on the dispatch choice.
            crate::util::simd::matmul_row_add(&a[u * k..(u + 1) * k], b, m, orow);
        }
    });
}

/// out += a[n×m] · bᵀ where b is row-major [k×m] — the "gradient times
/// transposed weight" product both terms of the SAGE input-gradient need
/// (`dh = dz·W_selfᵀ + Aᵀmean(dz·W_neighᵀ)`). Each output row is a dot
/// of an `a` row against `b` rows, so rows parallelize like
/// [`matmul_add`] and the accumulation order per row is fixed —
/// deterministic regardless of thread count.
pub fn matmul_abt_add(a: &[f32], b: &[f32], out: &mut [f32], n: usize, k: usize, m: usize) {
    use crate::util::pool::{default_threads, parallel_for_static, SendPtr};
    assert_eq!(a.len(), n * m);
    assert_eq!(b.len(), k * m);
    assert_eq!(out.len(), n * k);
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for_static(default_threads(), n, |_, s, e| {
        let ptr = &ptr;
        for u in s..e {
            // SAFETY: disjoint row ranges per thread.
            let orow = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(u * k), k) };
            let arow = &a[u * m..(u + 1) * m];
            for (i, o) in orow.iter_mut().enumerate() {
                let brow = &b[i * m..(i + 1) * m];
                let mut acc = 0.0f32;
                for j in 0..m {
                    acc += arow[j] * brow[j];
                }
                *o += acc;
            }
        }
    });
}

/// out += aᵀ[k×n] · g — the weight-gradient product `dW = hᵀ·dz`
/// ([k×m] += [n×k]ᵀ·[n×m]). Runs serially: every output element reduces
/// over all n rows, the model's weight matrices are tiny (≤ 64×64), and a
/// fixed accumulation order keeps training byte-deterministic.
pub fn matmul_at_b_add(a: &[f32], g: &[f32], out: &mut [f32], n: usize, k: usize, m: usize) {
    assert_eq!(a.len(), n * k);
    assert_eq!(g.len(), n * m);
    assert_eq!(out.len(), k * m);
    for u in 0..n {
        let arow = &a[u * k..(u + 1) * k];
        let grow = &g[u * m..(u + 1) * m];
        for (i, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let orow = &mut out[i * m..(i + 1) * m];
                for j in 0..m {
                    orow[j] += av * grow[j];
                }
            }
        }
    }
}

/// out[m] += column sums of g[n×m] — the bias gradient.
pub fn colsum_add(g: &[f32], out: &mut [f32], n: usize, m: usize) {
    assert_eq!(g.len(), n * m);
    assert_eq!(out.len(), m);
    for row in g.chunks_exact(m) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
}

/// Row argmax with deterministic tie- and NaN-handling: returns the
/// LOWEST index holding the maximum value; NaN entries are never
/// selected (a row of all NaNs returns 0). This is the ONE argmax in the
/// crate — serving re-exports it as `coordinator::argmax` and training
/// eval goes through [`argmax_rows`] — so the tie/NaN rule cannot
/// diverge between the two paths, and stitched predictions stay
/// reproducible across backends even when a numerically degenerate model
/// emits NaN logits.
pub fn argmax(row: &[f32]) -> u8 {
    let mut best: Option<usize> = None;
    for (i, &v) in row.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            None => best = Some(i),
            Some(b) => {
                if v > row[b] {
                    best = Some(i);
                }
            }
        }
    }
    best.unwrap_or(0) as u8
}

/// Row-wise argmax → class ids (delegates to [`argmax`] per row).
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<u8> {
    logits.chunks_exact(classes).map(argmax).collect()
}

/// Node-classification accuracy over the first `n` rows.
pub fn accuracy(pred: &[u8], labels: &[u8]) -> f64 {
    assert_eq!(pred.len(), labels.len());
    if pred.is_empty() {
        return 1.0;
    }
    let correct = pred.iter().zip(labels).filter(|(a, b)| a == b).count();
    correct as f64 / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::CsrRowParallel;
    use crate::util::tensor::Tensor;

    fn tiny_model() -> SageModel {
        // 2 → 2 identity-ish single layer for hand-checkable numbers.
        SageModel {
            layers: vec![SageLayer {
                din: 2,
                dout: 2,
                w_self: vec![1.0, 0.0, 0.0, 1.0],
                w_neigh: vec![0.0, 0.0, 0.0, 0.0],
                bias: vec![0.5, -0.5],
            }],
        }
    }

    #[test]
    fn forward_hand_checked() {
        let csr = Csr::symmetric_from_edges(2, &[(0, 1)]);
        let model = tiny_model();
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let engine = CsrRowParallel::new(1);
        let out = model.forward(&csr, &x, &engine);
        // last layer → no relu; w_self = I, bias (0.5, -0.5)
        assert_eq!(out, vec![1.5, 1.5, 3.5, 3.5]);
    }

    #[test]
    fn bundle_roundtrip() {
        let mut b = Bundle::new();
        b.insert("l0.w_self".into(), Tensor::f32(vec![2, 3], vec![0.0; 6]));
        b.insert("l0.w_neigh".into(), Tensor::f32(vec![2, 3], vec![0.0; 6]));
        b.insert("l0.b".into(), Tensor::f32(vec![3], vec![0.0; 3]));
        b.insert("l1.w_self".into(), Tensor::f32(vec![3, 5], vec![0.0; 15]));
        b.insert("l1.w_neigh".into(), Tensor::f32(vec![3, 5], vec![0.0; 15]));
        b.insert("l1.b".into(), Tensor::f32(vec![5], vec![0.0; 5]));
        let m = SageModel::from_bundle(&b).unwrap();
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.input_dim(), 2);
        assert_eq!(m.num_classes(), 5);
    }

    #[test]
    fn forward_with_matches_forward_and_reuses_buffers() {
        // two layers force at least one ping-pong swap
        let model = SageModel {
            layers: vec![
                SageLayer {
                    din: 2,
                    dout: 3,
                    w_self: vec![0.5, -0.25, 1.0, 0.75, 0.1, -0.6],
                    w_neigh: vec![-0.3, 0.2, 0.4, 0.9, -0.8, 0.05],
                    bias: vec![0.1, -0.2, 0.3],
                },
                SageLayer {
                    din: 3,
                    dout: 2,
                    w_self: vec![1.0, 0.0, 0.0, 1.0, 0.5, -0.5],
                    w_neigh: vec![0.2, 0.2, -0.1, 0.3, 0.0, 0.7],
                    bias: vec![0.0, 0.25],
                },
            ],
        };
        let csr = Csr::symmetric_from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.5 - 1.0).collect();
        let engine = CsrRowParallel::new(1);
        let want = model.forward(&csr, &x, &engine);

        let mut scratch = ForwardScratch::new();
        let p1 = model.forward_with(&csr, &x, &engine, &mut scratch).as_ptr();
        let bufs1 = scratch.buffer_ptrs();
        let got = model.forward_with(&csr, &x, &engine, &mut scratch);
        assert_eq!(got, &want[..], "forward_with diverges from forward");
        let p2 = got.as_ptr();
        // warm passes ping-pong inside the same arena: same logits buffer,
        // same three backing allocations — no reallocation happened
        assert_eq!(p1, p2, "logits buffer not stable across warm passes");
        assert_eq!(bufs1, scratch.buffer_ptrs(), "scratch arena reallocated");
    }

    #[test]
    fn forward_is_byte_identical_across_thread_counts() {
        // The concurrent runtime's hard invariant: matmul rows accumulate
        // in a fixed order regardless of how many threads split them.
        let model = SageModel {
            layers: vec![SageLayer {
                din: 2,
                dout: 4,
                w_self: (0..8).map(|i| (i as f32 * 0.3).sin()).collect(),
                w_neigh: (0..8).map(|i| (i as f32 * 0.7).cos()).collect(),
                bias: vec![0.1, -0.1, 0.2, -0.2],
            }],
        };
        let edges: Vec<(u32, u32)> = (0..63u32).map(|v| (v, v + 1)).collect();
        let csr = Csr::symmetric_from_edges(64, &edges);
        let x: Vec<f32> = (0..64 * 2).map(|i| (i as f32 * 0.11).sin()).collect();
        let engine = CsrRowParallel::new(1);
        let mut scratch = ForwardScratch::new();
        let want = model.forward_with_threads(&csr, &x, &engine, &mut scratch, 1).to_vec();
        for threads in [2usize, 3, 8] {
            let mut s = ForwardScratch::new();
            let got = model.forward_with_threads(&csr, &x, &engine, &mut s, threads);
            assert_eq!(got, &want[..], "threads={threads} changed the bytes");
        }
    }

    #[test]
    fn forward_batch_fused_matches_per_partition() {
        // Three ragged partitions through the stacked fused path must be
        // byte-identical to three independent forward passes.
        let model = SageModel {
            layers: vec![
                SageLayer {
                    din: 2,
                    dout: 3,
                    w_self: vec![0.5, -0.25, 1.0, 0.75, 0.1, -0.6],
                    w_neigh: vec![-0.3, 0.2, 0.4, 0.9, -0.8, 0.05],
                    bias: vec![0.1, -0.2, 0.3],
                },
                SageLayer {
                    din: 3,
                    dout: 2,
                    w_self: vec![1.0, 0.0, 0.0, 1.0, 0.5, -0.5],
                    w_neigh: vec![0.2, 0.2, -0.1, 0.3, 0.0, 0.7],
                    bias: vec![0.0, 0.25],
                },
            ],
        };
        let sizes = [5usize, 1, 9];
        let csrs: Vec<Csr> = sizes
            .iter()
            .map(|&n| {
                let edges: Vec<(u32, u32)> =
                    (0..n.saturating_sub(1) as u32).map(|v| (v, v + 1)).collect();
                Csr::symmetric_from_edges(n, &edges)
            })
            .collect();
        let feats: Vec<Vec<f32>> = sizes
            .iter()
            .map(|&n| (0..n * 2).map(|i| (i as f32 * 0.37).sin()).collect())
            .collect();
        let engines: Vec<CsrRowParallel> =
            (0..sizes.len()).map(|_| CsrRowParallel::new(1)).collect();

        let want: Vec<Vec<f32>> = csrs
            .iter()
            .zip(&feats)
            .zip(&engines)
            .map(|((c, f), e)| {
                let mut s = ForwardScratch::new();
                model.forward_with_threads(c, f, e, &mut s, 2).to_vec()
            })
            .collect();

        let parts: Vec<(&Csr, &[f32])> =
            csrs.iter().zip(&feats).map(|(c, f)| (c, f.as_slice())).collect();
        let engine_refs: Vec<&dyn crate::spmm::SpmmEngine> =
            engines.iter().map(|e| e as &dyn crate::spmm::SpmmEngine).collect();
        let mut scratch = ForwardScratch::new();
        let got = model.forward_batch_fused(&parts, &engine_refs, &mut scratch, 2);
        assert_eq!(got, want, "fused batched forward diverges");
        // warm second pass reuses the arena and stays identical
        let got2 = model.forward_batch_fused(&parts, &engine_refs, &mut scratch, 3);
        assert_eq!(got2, want, "warm fused pass diverges");
    }

    #[test]
    fn argmax_and_accuracy() {
        let logits = vec![0.1, 0.9, 0.5, 0.2, 3.0, -1.0];
        let pred = argmax_rows(&logits, 2);
        assert_eq!(pred, vec![1, 0, 0]);
        assert!((accuracy(&pred, &[1, 0, 1]) - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn argmax_rows_inherits_nan_and_tie_rules() {
        // argmax_rows delegates to the canonical argmax: lowest index on
        // ties, NaN never wins (a leading NaN used to win here by default).
        let logits = vec![f32::NAN, 1.0, 2.0, 2.0, f32::NAN, f32::NAN];
        assert_eq!(argmax_rows(&logits, 2), vec![1, 0, 0]);
    }

    #[test]
    fn matmul_abt_add_matches_hand_product() {
        // a [2×3] · bᵀ where b is [2×3] ⇒ out [2×2]
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = vec![1.0, 0.0, -1.0, 0.5, 0.5, 0.5];
        let mut out = vec![10.0, 0.0, 0.0, -10.0];
        matmul_abt_add(&a, &b, &mut out, 2, 2, 3);
        // row0: [1,2,3]·[1,0,-1] = -2 ; [1,2,3]·[.5,.5,.5] = 3
        // row1: [4,5,6]·[1,0,-1] = -2 ; [4,5,6]·[.5,.5,.5] = 7.5
        assert_eq!(out, vec![8.0, 3.0, -2.0, -2.5]);
    }

    #[test]
    fn matmul_at_b_add_matches_hand_product() {
        // aᵀ [2×3]ᵀ=[3×2]... here a [3×2], g [3×2] ⇒ out [2×2] += aᵀg
        let a = vec![1.0, 0.0, 2.0, 1.0, 0.0, 3.0];
        let g = vec![1.0, 1.0, 2.0, 0.0, -1.0, 1.0];
        let mut out = vec![0.0; 4];
        matmul_at_b_add(&a, &g, &mut out, 3, 2, 2);
        // out[0,:] = 1·[1,1] + 2·[2,0] + 0·[-1,1] = [5,1]
        // out[1,:] = 0·[1,1] + 1·[2,0] + 3·[-1,1] = [-1,3]
        assert_eq!(out, vec![5.0, 1.0, -1.0, 3.0]);
    }

    #[test]
    fn matmul_transposes_are_consistent_with_matmul_add() {
        // ⟨a·b, g⟩ = ⟨b, aᵀ·g⟩ = ⟨a, g·bᵀ⟩ for random-ish fixed inputs.
        let (n, k, m) = (4, 3, 5);
        let mut st = 7u64;
        let mut next = || {
            (crate::util::rng::splitmix64(&mut st) >> 40) as f32 / (1u64 << 24) as f32 - 0.5
        };
        let a: Vec<f32> = (0..n * k).map(|_| next()).collect();
        let b: Vec<f32> = (0..k * m).map(|_| next()).collect();
        let g: Vec<f32> = (0..n * m).map(|_| next()).collect();
        let mut ab = vec![0.0; n * m];
        matmul_add(&a, &b, &mut ab, n, k, m);
        let mut atg = vec![0.0; k * m];
        matmul_at_b_add(&a, &g, &mut atg, n, k, m);
        let mut gbt = vec![0.0; n * k];
        matmul_abt_add(&g, &b, &mut gbt, n, k, m);
        let dot = |x: &[f32], y: &[f32]| -> f64 {
            x.iter().zip(y).map(|(&p, &q)| p as f64 * q as f64).sum()
        };
        assert!((dot(&ab, &g) - dot(&b, &atg)).abs() < 1e-5);
        assert!((dot(&ab, &g) - dot(&a, &gbt)).abs() < 1e-5);
    }

    #[test]
    fn colsum_add_sums_columns() {
        let g = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out = vec![0.5, 0.0];
        colsum_add(&g, &mut out, 3, 2);
        assert_eq!(out, vec![9.5, 12.0]);
    }
}

//! Technology mapping — produces the paper's "7nm mapped" and "FPGA 4-LUT"
//! dataset families (Figs 6d, 7, 8d, 9).
//!
//! Cut-based structural mapper: enumerate k-feasible priority cuts with
//! truth tables (k ≤ 4, u16 tables), choose per-node best cuts
//! (depth-first, area-tie-broken), then cover the AIG from the POs. The
//! result is a mapped netlist whose nodes are cells/LUTs with up to k
//! inputs — the irregular multi-fanin graphs the paper stresses GROOT
//! with:
//!
//! * `map_fpga(aig)` — k=4 LUT mapping (the FPGA-4LUT dataset),
//! * `map_cells(aig)` — k=3 mapping + NPN cell-library matching, our
//!   substitute for an ASAP7-style standard-cell mapper (the multi-output
//!   cells of a real library appear here as shared-input cell clusters).
//!
//! Mapped graphs keep the EDA-graph feature layout: type bits identify
//! PI/internal/PO; the polarity bits carry cell-class information instead
//! of AIG edge polarity (documented deviation — mapped nets have no
//! complement edges).

use crate::aig::{lit_compl, lit_var, Aig, NodeKind};
use crate::features::{EdaGraph, GROOT_FEATURE_DIM};
use crate::labels::NodeClass;
use anyhow::Result;

/// A mapped node: a cell/LUT with ≤ k inputs and a truth table over them.
#[derive(Clone, Debug)]
pub struct MappedNode {
    /// Indices into `MappedNetlist::nodes`.
    pub inputs: Vec<u32>,
    /// Truth table over `inputs` (LSB-first row order), meaningful low
    /// 2^|inputs| bits.
    pub tt: u16,
    pub kind: MappedKind,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MappedKind {
    Pi,
    Cell,
    Po,
}

#[derive(Clone, Debug)]
pub struct MappedNetlist {
    pub name: String,
    pub nodes: Vec<MappedNode>,
    pub num_pis: usize,
}

const XOR2_TT: u16 = 0b0110;
const XNOR2_TT: u16 = 0b1001;
const XOR3_TT: u16 = 0x96;
const XNOR3_TT: u16 = 0x69;
const MAJ3_TT: u16 = 0xE8;
const NMAJ3_TT: u16 = 0x17;
const XOR4_TT: u16 = 0x6996;
const XNOR4_TT: u16 = !0x6996;

impl MappedNetlist {
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn num_cells(&self) -> usize {
        self.nodes.iter().filter(|n| n.kind == MappedKind::Cell).count()
    }

    /// Ground-truth class of a mapped node, from its cell function.
    fn node_class(&self, idx: usize) -> NodeClass {
        let n = &self.nodes[idx];
        match n.kind {
            MappedKind::Pi => NodeClass::Pi,
            MappedKind::Po => NodeClass::Po,
            MappedKind::Cell => {
                let m = n.inputs.len();
                let mask: u32 = if m >= 4 { 0xFFFF } else { (1u32 << (1 << m)) - 1 };
                let tt = (n.tt as u32 & mask) as u16;
                match (m, tt) {
                    (2, XOR2_TT) | (2, XNOR2_TT) => NodeClass::Xor,
                    (3, XOR3_TT) | (3, XNOR3_TT) => NodeClass::Xor,
                    (4, XOR4_TT) | (4, XNOR4_TT) => NodeClass::Xor,
                    (3, MAJ3_TT) | (3, NMAJ3_TT) => NodeClass::Maj,
                    _ => NodeClass::And,
                }
            }
        }
    }

    /// EDA graph with features + function-derived labels.
    pub fn to_eda_graph(&self) -> EdaGraph {
        let mut edges = Vec::new();
        let mut features = vec![[0.0f32; GROOT_FEATURE_DIM]; self.nodes.len()];
        let mut labels = vec![NodeClass::Pi; self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for &src in &n.inputs {
                edges.push((src, i as u32));
            }
            labels[i] = self.node_class(i);
            features[i] = match n.kind {
                MappedKind::Pi => [0.0, 0.0, 0.0, 0.0],
                MappedKind::Po => [0.0, 1.0, 0.0, 0.0],
                MappedKind::Cell => {
                    // polarity bits repurposed: [has >2 inputs, odd function
                    // parity] — structural hints a mapped netlist exposes.
                    let multi = (n.inputs.len() > 2) as u8 as f32;
                    let parity = ((n.tt.count_ones() & 1) == 1) as u8 as f32;
                    [1.0, 1.0, multi, parity]
                }
            };
        }
        EdaGraph {
            name: self.name.clone(),
            num_nodes: self.nodes.len(),
            num_aig_nodes: self.nodes.len()
                - self.nodes.iter().filter(|n| n.kind == MappedKind::Po).count(),
            edges,
            features,
            labels,
        }
    }

    /// Cell-name histogram (the "standard cell library" view; harness
    /// prints it for the 7nm dataset).
    pub fn cell_histogram(&self) -> std::collections::BTreeMap<String, usize> {
        let mut h = std::collections::BTreeMap::new();
        for n in &self.nodes {
            if n.kind == MappedKind::Cell {
                *h.entry(cell_name(n.inputs.len(), n.tt)).or_insert(0) += 1;
            }
        }
        h
    }
}

/// NPN-ish cell naming for the standard-cell view.
pub fn cell_name(m: usize, tt: u16) -> String {
    let mask: u32 = if m >= 4 { 0xFFFF } else { (1u32 << (1 << m)) - 1 };
    let tt = tt as u32 & mask;
    let named = match (m, tt as u16) {
        (1, 0b01) => Some("INV"),
        (1, 0b10) => Some("BUF"),
        (2, 0b1000) => Some("AND2"),
        (2, 0b0111) => Some("NAND2"),
        (2, 0b1110) => Some("OR2"),
        (2, 0b0001) => Some("NOR2"),
        (2, XOR2_TT) => Some("XOR2"),
        (2, XNOR2_TT) => Some("XNOR2"),
        (3, XOR3_TT) => Some("XOR3"),
        (3, XNOR3_TT) => Some("XNOR3"),
        (3, MAJ3_TT) => Some("MAJ3"),
        (3, NMAJ3_TT) => Some("MAJ3I"),
        (3, 0x80) => Some("AND3"),
        (3, 0xFE) => Some("OR3"),
        _ => None,
    };
    match named {
        Some(s) => s.to_string(),
        None => format!("LUT{m}_{tt:04X}"),
    }
}

// ---------------------------------------------------------------------
// k ≤ 4 priority-cut enumeration with u16 truth tables.
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
struct Cut4 {
    leaves: Vec<u32>, // sorted, ≤ 4
    tt: u16,
}

fn expand_tt4(tt: u16, from: &[u32], to: &[u32]) -> u16 {
    let m = to.len();
    let mut out = 0u16;
    for row in 0..(1usize << m) {
        let mut from_row = 0usize;
        for (fi, leaf) in from.iter().enumerate() {
            let ti = to.iter().position(|x| x == leaf).unwrap();
            if row & (1 << ti) != 0 {
                from_row |= 1 << fi;
            }
        }
        if tt & (1 << from_row) != 0 {
            out |= 1 << row;
        }
    }
    out
}

fn full_mask(m: usize) -> u16 {
    if m >= 4 {
        0xFFFF
    } else {
        ((1u32 << (1 << m)) - 1) as u16
    }
}

fn union4(a: &[u32], b: &[u32], k: usize) -> Option<Vec<u32>> {
    let mut out = Vec::with_capacity(k);
    let (mut i, mut j) = (0, 0);
    while i < a.len() || j < b.len() {
        let v = if i < a.len() && (j >= b.len() || a[i] <= b[j]) {
            let v = a[i];
            if j < b.len() && b[j] == v {
                j += 1;
            }
            i += 1;
            v
        } else {
            let v = b[j];
            j += 1;
            v
        };
        if out.len() == k {
            return None;
        }
        out.push(v);
    }
    Some(out)
}

/// Per-node best-cut selection state.
struct MapState {
    /// Priority cuts per node.
    cuts: Vec<Vec<Cut4>>,
    /// Depth of the best cut per node.
    depth: Vec<u32>,
    /// Chosen best cut per node (index into cuts).
    best: Vec<usize>,
}

fn enumerate_and_select(aig: &Aig, k: usize, max_cuts: usize) -> MapState {
    let n = aig.num_nodes();
    let mut st = MapState {
        cuts: vec![Vec::new(); n],
        depth: vec![0; n],
        best: vec![0; n],
    };
    for id in 0..n as u32 {
        match aig.kind(id) {
            NodeKind::Const | NodeKind::Pi(_) => {
                st.cuts[id as usize] = vec![Cut4 { leaves: vec![id], tt: 0b10 }];
                st.depth[id as usize] = 0;
            }
            NodeKind::And => {
                let (f0, f1) = aig.fanins(id);
                let (v0, c0) = (lit_var(f0), lit_compl(f0));
                let (v1, c1) = (lit_var(f1), lit_compl(f1));
                let mut new_cuts: Vec<Cut4> = Vec::new();
                for a in &st.cuts[v0 as usize] {
                    for b in &st.cuts[v1 as usize] {
                        let Some(leaves) = union4(&a.leaves, &b.leaves, k) else {
                            continue;
                        };
                        let ta = {
                            let t = expand_tt4(a.tt & full_mask(a.leaves.len()), &a.leaves, &leaves);
                            if c0 {
                                !t & full_mask(leaves.len())
                            } else {
                                t
                            }
                        };
                        let tb = {
                            let t = expand_tt4(b.tt & full_mask(b.leaves.len()), &b.leaves, &leaves);
                            if c1 {
                                !t & full_mask(leaves.len())
                            } else {
                                t
                            }
                        };
                        let cut = Cut4 { tt: ta & tb, leaves };
                        if !new_cuts.iter().any(|c| c.leaves == cut.leaves) {
                            new_cuts.push(cut);
                        }
                    }
                }
                // Depth-oriented priority: cut depth = 1 + max leaf depth;
                // prefer lower depth then fewer leaves.
                let cut_depth = |c: &Cut4| {
                    1 + c
                        .leaves
                        .iter()
                        .map(|&l| st.depth[l as usize])
                        .max()
                        .unwrap_or(0)
                };
                new_cuts.sort_by_key(|c| (cut_depth(c), c.leaves.len()));
                new_cuts.truncate(max_cuts);
                // Trivial cut as fallback (never selected unless only one).
                st.depth[id as usize] = new_cuts.first().map(cut_depth).unwrap_or(0);
                st.best[id as usize] = 0;
                new_cuts.push(Cut4 { leaves: vec![id], tt: 0b10 });
                st.cuts[id as usize] = new_cuts;
            }
        }
    }
    st
}

/// Map the AIG with k-input cells/LUTs. Each PO becomes a `Po` node fed by
/// the cell covering its driver (inverted drivers fold the complement into
/// the root cell's table — mapped netlists have no complement edges).
pub fn map_kluts(aig: &Aig, k: usize, name_suffix: &str) -> Result<MappedNetlist> {
    anyhow::ensure!((2..=4).contains(&k), "k must be 2..=4");
    let st = enumerate_and_select(aig, k, 8);

    // Cover from the POs backwards.
    let n = aig.num_nodes();
    let mut needed = vec![false; n];
    let mut stack: Vec<u32> = Vec::new();
    for o in &aig.outputs {
        let v = lit_var(o.lit);
        if aig.is_and(v) && !needed[v as usize] {
            needed[v as usize] = true;
            stack.push(v);
        }
    }
    while let Some(u) = stack.pop() {
        let cut = &st.cuts[u as usize][st.best[u as usize]];
        for &l in &cut.leaves {
            if aig.is_and(l) && !needed[l as usize] {
                needed[l as usize] = true;
                stack.push(l);
            }
        }
    }

    // Emit mapped nodes: const+PIs first, then cells in topo order, then POs.
    let mut map: Vec<Option<u32>> = vec![None; n];
    let mut nodes: Vec<MappedNode> = Vec::new();
    // const node rides as a PI-like node 0 (kept for index stability).
    nodes.push(MappedNode { inputs: vec![], tt: 0, kind: MappedKind::Pi });
    map[0] = Some(0);
    for &pi in aig.pi_ids() {
        map[pi as usize] = Some(nodes.len() as u32);
        nodes.push(MappedNode { inputs: vec![], tt: 0, kind: MappedKind::Pi });
    }
    let num_pis = nodes.len();
    for u in 0..n as u32 {
        if needed[u as usize] {
            let cut = &st.cuts[u as usize][st.best[u as usize]];
            let inputs: Vec<u32> = cut
                .leaves
                .iter()
                .map(|&l| map[l as usize].expect("leaf mapped before root (topo order)"))
                .collect();
            map[u as usize] = Some(nodes.len() as u32);
            nodes.push(MappedNode { inputs, tt: cut.tt, kind: MappedKind::Cell });
        }
    }
    for o in &aig.outputs {
        let v = lit_var(o.lit);
        let drv = map[v as usize].expect("PO driver mapped");
        // A complemented PO of a cell folds the inversion into a 1-input
        // PO-view; we keep POs as explicit sink nodes (class 0) whose tt
        // records the polarity.
        let tt = if lit_compl(o.lit) { 0b01 } else { 0b10 };
        nodes.push(MappedNode { inputs: vec![drv], tt, kind: MappedKind::Po });
    }
    Ok(MappedNetlist {
        name: format!("{}_{}", aig.name, name_suffix),
        nodes,
        num_pis,
    })
}

/// FPGA 4-LUT mapping.
pub fn map_fpga(aig: &Aig) -> Result<MappedNetlist> {
    map_kluts(aig, 4, "fpga4lut")
}

/// Standard-cell-style mapping (k=3 + cell naming) — the ASAP7 substitute.
pub fn map_cells(aig: &Aig) -> Result<MappedNetlist> {
    map_kluts(aig, 3, "cells7nm")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aig::mult::csa_multiplier;
    use crate::aig::sim::eval_bool;

    /// Evaluate a mapped netlist on boolean inputs.
    fn eval_mapped(m: &MappedNetlist, ins: &[bool]) -> Vec<bool> {
        let mut vals = vec![false; m.nodes.len()];
        let mut outs = Vec::new();
        let mut pi_iter = ins.iter();
        for (i, n) in m.nodes.iter().enumerate() {
            match n.kind {
                MappedKind::Pi => {
                    // node 0 is const false; real PIs consume inputs
                    vals[i] = if i == 0 { false } else { *pi_iter.next().unwrap() };
                }
                MappedKind::Cell => {
                    let mut row = 0usize;
                    for (k, &src) in n.inputs.iter().enumerate() {
                        if vals[src as usize] {
                            row |= 1 << k;
                        }
                    }
                    vals[i] = n.tt & (1 << row) != 0;
                }
                MappedKind::Po => {
                    let v = vals[n.inputs[0] as usize];
                    let v = if n.tt == 0b01 { !v } else { v };
                    vals[i] = v;
                    outs.push(v);
                }
            }
        }
        outs
    }

    #[test]
    fn mapping_preserves_function() {
        for k in 3..=4usize {
            let g = csa_multiplier(4);
            let m = map_kluts(&g, k, "t").unwrap();
            for va in 0..16u32 {
                for vb in 0..16u32 {
                    let mut ins = Vec::new();
                    for i in 0..4 {
                        ins.push(va & (1 << i) != 0);
                    }
                    for i in 0..4 {
                        ins.push(vb & (1 << i) != 0);
                    }
                    assert_eq!(
                        eval_mapped(&m, &ins),
                        eval_bool(&g, &ins),
                        "k={k} {va}*{vb}"
                    );
                }
            }
        }
    }

    #[test]
    fn mapping_reduces_node_count() {
        let g = csa_multiplier(8);
        let m4 = map_fpga(&g).unwrap();
        assert!(
            m4.num_cells() < g.num_ands(),
            "LUT4 {} vs AND {}",
            m4.num_cells(),
            g.num_ands()
        );
    }

    #[test]
    fn mapped_graph_has_multi_fanin_and_labels() {
        let g = csa_multiplier(8);
        let m = map_fpga(&g).unwrap();
        let eg = m.to_eda_graph();
        eg.check().unwrap();
        let max_fanin = m.nodes.iter().map(|n| n.inputs.len()).max().unwrap();
        assert!(max_fanin > 2, "no multi-fanin cells");
        let hist = crate::labels::class_histogram(&eg.labels);
        assert!(hist[NodeClass::Xor as usize] > 0, "{hist:?}");
    }

    #[test]
    fn cell_view_names_known_cells() {
        let g = csa_multiplier(6);
        let m = map_cells(&g).unwrap();
        let hist = m.cell_histogram();
        // an adder-heavy design must map XOR/MAJ cells
        let has_xorish = hist.keys().any(|k| k.contains("XOR") || k.contains("XNOR"));
        assert!(has_xorish, "{hist:?}");
    }
}

//! Network serving subsystem — the socket face of the L3 serving runtime.
//!
//! Three layers, strictly stacked:
//!
//! * [`wire`] — the versioned, length-prefixed binary frame codec. Pure
//!   functions over byte buffers; no sockets, no threads. Every frame is
//!   `magic | kind | u32 payload length | payload`, with a strict
//!   maximum frame size enforced *before* the payload allocates.
//! * [`daemon`] — `groot serve`: an accept loop over TCP or a Unix
//!   socket feeding the multi-worker [`crate::coordinator::server::Server`]
//!   through `try_submit` (queue saturation becomes an explicit BUSY
//!   reply, never an opaque stall). SIGTERM triggers the drain-on-shutdown
//!   contract: the listener closes first, in-flight and queued requests
//!   are answered, then the workers join.
//! * [`client`] — `GrootClient`, the blocking client library the
//!   `groot client` subcommands and the serve benchmarks drive.
//!
//! Everything is std-only (`std::net` + `std::os::unix::net`); there is
//! no async runtime and no external protocol dependency.

pub mod client;
pub mod daemon;
pub mod wire;

pub use client::{DeltaReply, GrootClient, Reply};
pub use daemon::{install_sigterm_handler, sigterm_pending, BindAddr, NetConfig, NetDaemon};

//! `groot serve` — the socket daemon over the multi-worker serving
//! runtime.
//!
//! ```text
//!                    ┌───────────────────────── NetDaemon ─────────────┐
//! TCP / unix socket ─► accept loop (nonblocking, polls stop flag)      │
//!                    │    └─► one handler thread per connection        │
//!                    │          frame read → decode → try_submit ──────┼─► Server
//!                    │          Busy → RESP_BUSY   result → RESP_RESULT│   (N workers,
//!                    └──────────────────────────────────────────────────┘    shared plan cache)
//! ```
//!
//! Shutdown (SIGTERM or [`NetDaemon::trigger_shutdown`]) is a strict
//! sequence, preserving the serving runtime's drain contract:
//!
//! 1. the stop flag is set; the accept loop exits and **closes the
//!    listener first** (a Unix socket file is unlinked) — new
//!    connections are refused from this point;
//! 2. connection handlers finish the request they are on (workers are
//!    still live) and reply; handlers idle at a frame boundary exit
//!    immediately; a handler mid-frame gets `drain_grace` to finish
//!    reading, then the connection is abandoned;
//! 3. handler threads are joined, then [`Server::shutdown`] drains and
//!    answers everything still queued and joins the workers.
//!
//! Malformed traffic never kills the daemon: a bad magic or oversize
//! length gets one structured [`wire::ERR_MALFORMED`] reply and the
//! connection is closed; an unparsable circuit gets
//! [`wire::ERR_BAD_REQUEST`] and the connection stays usable.

use super::wire::{self, FrameError, GraphPayload, WireStats};
use crate::coordinator::server::{DeltaSubmit, RequestGraph, Server, TrySubmit};
use crate::graph::CircuitGraph;
use crate::obs::{self, log, metrics, MetricsFormat};
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Where to listen: `host:port` TCP or `unix:/path/to.sock`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BindAddr {
    Tcp(String),
    Unix(PathBuf),
}

impl BindAddr {
    /// Parse the `--listen` / `--connect` syntax: a `unix:` prefix means
    /// a Unix-domain socket path, anything else is a TCP `host:port`.
    pub fn parse(s: &str) -> Result<BindAddr> {
        if let Some(path) = s.strip_prefix("unix:") {
            if path.is_empty() {
                bail!("empty unix socket path in {s:?}");
            }
            return Ok(BindAddr::Unix(PathBuf::from(path)));
        }
        if !s.contains(':') {
            bail!("bad address {s:?}: expected host:port or unix:/path.sock");
        }
        Ok(BindAddr::Tcp(s.to_string()))
    }
}

impl std::fmt::Display for BindAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BindAddr::Tcp(a) => write!(f, "{a}"),
            BindAddr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// Daemon tuning knobs; the defaults serve.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Maximum accepted frame payload ([`wire::DEFAULT_MAX_FRAME`]).
    pub max_frame: u32,
    /// Stop-flag poll cadence; doubles as the per-connection socket read
    /// timeout, so it bounds shutdown latency, not throughput.
    pub poll_interval: Duration,
    /// How long a handler mid-frame at shutdown waits for the client to
    /// finish sending before the connection is abandoned.
    pub drain_grace: Duration,
    /// Chunk size for streaming AIGER-text payloads into the columnar
    /// store.
    pub aiger_chunk: usize,
    /// Honor the process-wide SIGTERM flag (`groot serve` sets this;
    /// tests drive shutdown programmatically through the same path).
    pub watch_sigterm: bool,
    /// Classify requests slower than this emit one warn-level log record
    /// (`GROOT_SLOW_REQUEST_MS` overrides; default 1 s).
    pub slow_request: Duration,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        let slow_ms = std::env::var("GROOT_SLOW_REQUEST_MS")
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .unwrap_or(1000);
        NetConfig {
            max_frame: wire::DEFAULT_MAX_FRAME,
            poll_interval: Duration::from_millis(50),
            drain_grace: Duration::from_secs(2),
            aiger_chunk: crate::graph::DEFAULT_CHUNK_NODES,
            watch_sigterm: false,
            slow_request: Duration::from_millis(slow_ms),
        }
    }
}

// ---- SIGTERM ------------------------------------------------------------

static SIGTERM_FLAG: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_sigterm(_signum: i32) {
    // The only async-signal-safe thing worth doing: flip the flag. The
    // accept loop and handlers poll it.
    SIGTERM_FLAG.store(true, Ordering::SeqCst);
}

/// Route SIGTERM to the drain-on-shutdown flag. Std-only: `signal(2)` is
/// declared by hand (std already links libc on every Unix target).
#[cfg(unix)]
pub fn install_sigterm_handler() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_sigterm as extern "C" fn(i32) as usize);
    }
}

#[cfg(not(unix))]
pub fn install_sigterm_handler() {}

/// Has a SIGTERM been delivered since the last [`clear_sigterm`]?
pub fn sigterm_pending() -> bool {
    SIGTERM_FLAG.load(Ordering::SeqCst)
}

/// Reset the SIGTERM flag — for tests that raise the real signal and
/// must not leak the pending state into later daemons in the process.
pub fn clear_sigterm() {
    SIGTERM_FLAG.store(false, Ordering::SeqCst);
}

// ---- sockets ------------------------------------------------------------

/// The two stream flavors behind one object-safe face. Handlers only
/// need `Read + Write` plus a read timeout.
trait Conn: Read + Write + Send {
    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()>;
}

impl Conn for TcpStream {
    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        TcpStream::set_read_timeout(self, d)
    }
}

impl Conn for UnixStream {
    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        UnixStream::set_read_timeout(self, d)
    }
}

enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

impl Listener {
    /// Nonblocking accept: `Ok(None)` when no connection is pending.
    fn accept(&self) -> std::io::Result<Option<Box<dyn Conn>>> {
        match self {
            Listener::Tcp(l) => match l.accept() {
                Ok((s, _)) => {
                    let _ = s.set_nodelay(true);
                    Ok(Some(Box::new(s)))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                Err(e) => Err(e),
            },
            Listener::Unix(l) => match l.accept() {
                Ok((s, _)) => Ok(Some(Box::new(s))),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                Err(e) => Err(e),
            },
        }
    }
}

/// Bind a Unix listener, recovering the socket file a crashed daemon
/// left behind (it exists but nothing accepts on it). A LIVE daemon on
/// the path is an error, not a takeover.
fn bind_unix(path: &Path) -> Result<UnixListener> {
    match UnixListener::bind(path) {
        Ok(l) => Ok(l),
        Err(e) if e.kind() == std::io::ErrorKind::AddrInUse => {
            if UnixStream::connect(path).is_ok() {
                bail!("another daemon is already serving on {}", path.display());
            }
            std::fs::remove_file(path)
                .with_context(|| format!("remove stale socket {}", path.display()))?;
            UnixListener::bind(path)
                .with_context(|| format!("rebind unix socket {}", path.display()))
        }
        Err(e) => Err(e).with_context(|| format!("bind unix socket {}", path.display())),
    }
}

// ---- daemon -------------------------------------------------------------

/// How many request latencies the percentile ring retains.
const LATENCY_RING: usize = 4096;

const LOG_TARGET: &str = "net::daemon";

/// Monotonic classify-request id, process-wide — stamped on the request
/// span so a Perfetto trace can be joined against the slow-request log.
static REQUEST_ID: AtomicU64 = AtomicU64::new(0);

/// Daemon-level metric handles (request counter + latency histogram +
/// mirrored queue-depth gauge), registered once per process.
struct DaemonMetrics {
    served: metrics::Counter,
    latency: metrics::Histogram,
    queue_depth: metrics::Gauge,
}

fn daemon_metrics() -> &'static DaemonMetrics {
    static M: OnceLock<DaemonMetrics> = OnceLock::new();
    M.get_or_init(|| {
        let r = metrics::registry();
        DaemonMetrics {
            served: r.counter(
                "groot_requests_served_total",
                "Classify requests answered with RESP_RESULT, daemon-wide.",
                &[],
            ),
            latency: r.histogram(
                "groot_request_latency_seconds",
                "Wall-clock seconds from submit to reply per served classify request.",
                &[],
                metrics::LATENCY_BUCKETS,
            ),
            queue_depth: r.gauge(
                "groot_queue_depth",
                "Classify requests waiting in the serving submit queue (sampled at scrape).",
                &[],
            ),
        }
    })
}

struct Shared {
    server: Server,
    cfg: NetConfig,
    stop: AtomicBool,
    /// Classify requests answered with RESP_RESULT, daemon-wide.
    served: AtomicU64,
    /// Wall-clock ms per answered classify request (submission → reply
    /// decoded), most recent [`LATENCY_RING`].
    latencies: Mutex<VecDeque<f64>>,
}

impl Shared {
    fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst) || (self.cfg.watch_sigterm && sigterm_pending())
    }

    fn record_latency(&self, ms: f64) {
        self.served.fetch_add(1, Ordering::SeqCst);
        let m = daemon_metrics();
        m.served.inc();
        m.latency.observe(ms / 1e3);
        let mut l = self.latencies.lock().unwrap();
        if l.len() >= LATENCY_RING {
            l.pop_front();
        }
        l.push_back(ms);
    }

    /// Render the process-wide metrics registry for a REQ_METRICS scrape
    /// or the `groot metrics` CLI. Gauges that mirror live server state
    /// (queue depth) are refreshed here; everything else is updated at
    /// the source and just rendered.
    fn metrics_text(&self, format: MetricsFormat) -> String {
        daemon_metrics().queue_depth.set(self.server.stats().queue_depth as i64);
        metrics::registry().render(format)
    }

    fn stats(&self) -> WireStats {
        let s = self.server.stats();
        let mut v: Vec<f64> = self.latencies.lock().unwrap().iter().copied().collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let pct = |p: f64| -> f64 {
            if v.is_empty() {
                0.0
            } else {
                let idx = ((v.len() - 1) as f64 * p).round() as usize;
                v[idx.min(v.len() - 1)]
            }
        };
        WireStats {
            queue_depth: s.queue_depth as u64,
            workers: s.workers as u64,
            per_worker_requests: s.per_worker_requests,
            plan_cache_hits: s.plan_cache_hits,
            plan_cache_misses: s.plan_cache_misses,
            plan_disk_hits: s.plan_disk_hits,
            plan_store_writes: s.plan_store_writes,
            plan_store_quarantined: s.plan_store_quarantined,
            requests_served: self.served.load(Ordering::SeqCst),
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
        }
    }
}

/// A bound, serving daemon. Dropping it does NOT stop it cleanly — call
/// [`NetDaemon::shutdown`] (or [`trigger_shutdown`](Self::trigger_shutdown)
/// + [`join`](Self::join), which is what `groot serve` does around its
/// SIGTERM wait).
pub struct NetDaemon {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    bound: String,
    local_addr: Option<SocketAddr>,
}

impl NetDaemon {
    /// Bind the address and start accepting. The `server` is consumed:
    /// the daemon owns the worker fleet and shuts it down last.
    pub fn bind(addr: &BindAddr, server: Server, cfg: NetConfig) -> Result<NetDaemon> {
        let (listener, bound, local_addr, unix_path) = match addr {
            BindAddr::Tcp(a) => {
                let l = TcpListener::bind(a).with_context(|| format!("bind tcp {a}"))?;
                l.set_nonblocking(true)?;
                let la = l.local_addr()?;
                (Listener::Tcp(l), la.to_string(), Some(la), None)
            }
            BindAddr::Unix(p) => {
                let l = bind_unix(p)?;
                l.set_nonblocking(true)?;
                (Listener::Unix(l), format!("unix:{}", p.display()), None, Some(p.clone()))
            }
        };
        let shared = Arc::new(Shared {
            server,
            cfg,
            stop: AtomicBool::new(false),
            served: AtomicU64::new(0),
            latencies: Mutex::new(VecDeque::new()),
        });
        let sh = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("groot-net-accept".into())
            .spawn(move || accept_loop(sh, listener, unix_path))
            .context("spawn accept loop")?;
        log::info(LOG_TARGET, format_args!("listening on {bound}"));
        Ok(NetDaemon { shared, accept: Some(accept), bound, local_addr })
    }

    /// The resolved address: `ip:port` (with the OS-assigned port for
    /// `:0` binds) or `unix:/path`.
    pub fn bound(&self) -> &str {
        &self.bound
    }

    /// TCP only: the resolved socket address.
    pub fn local_addr(&self) -> Option<SocketAddr> {
        self.local_addr
    }

    /// Begin the drain sequence (idempotent, non-blocking): stop
    /// accepting, answer what is in flight, then stop the workers.
    pub fn trigger_shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
    }

    /// Daemon-side stats snapshot (same numbers a STATS request returns).
    pub fn stats(&self) -> WireStats {
        self.shared.stats()
    }

    /// Block until the daemon drains: returns after a SIGTERM (when
    /// `watch_sigterm`) or [`Self::trigger_shutdown`] has been fully
    /// honored — listener closed, connections finished, workers joined.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Every handler thread was joined by the accept loop, so this
        // unwrap succeeds and the worker fleet drains deterministically.
        // (A panicked accept loop leaves the Arc shared; the fleet then
        // drains when the last clone drops — Server::drop.)
        if let Ok(sh) = Arc::try_unwrap(self.shared) {
            sh.server.shutdown();
        }
        log::info(LOG_TARGET, format_args!("shutdown complete"));
    }

    /// [`trigger_shutdown`](Self::trigger_shutdown) + [`join`](Self::join).
    pub fn shutdown(self) {
        self.trigger_shutdown();
        self.join();
    }
}

fn accept_loop(shared: Arc<Shared>, listener: Listener, unix_path: Option<PathBuf>) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !shared.stopping() {
        match listener.accept() {
            Ok(Some(conn)) => {
                let sh = Arc::clone(&shared);
                match std::thread::Builder::new()
                    .name("groot-net-conn".into())
                    .spawn(move || handle_conn(sh, conn))
                {
                    Ok(h) => handlers.push(h),
                    Err(_) => { /* thread exhaustion: connection dropped */ }
                }
            }
            Ok(None) => std::thread::sleep(shared.cfg.poll_interval),
            Err(_) => std::thread::sleep(shared.cfg.poll_interval),
        }
        // Reap finished handlers so a long-lived daemon doesn't
        // accumulate one JoinHandle per connection ever served.
        let mut i = 0;
        while i < handlers.len() {
            if handlers[i].is_finished() {
                let _ = handlers.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
    }
    // Shutdown step 1: close the listener FIRST (unlinking a Unix socket
    // file), so new connections are refused while in-flight requests are
    // still being answered.
    log::info(LOG_TARGET, format_args!("draining: listener closed, finishing in-flight requests"));
    drop(listener);
    if let Some(p) = unix_path {
        let _ = std::fs::remove_file(&p);
    }
    // Step 2: wait for every handler to finish its in-flight work. The
    // worker fleet is still up — replies flow until the last handler is
    // done. Step 3 (Server::shutdown) happens in NetDaemon::join.
    for h in handlers {
        let _ = h.join();
    }
}

enum FrameRead {
    Frame(u8, Vec<u8>),
    /// Peer closed (cleanly or mid-frame) or transport error.
    Closed,
    /// The daemon is draining and the connection sits at a frame
    /// boundary — exit without touching the socket further.
    Shutdown,
    /// Protocol violation worth a structured reply before closing.
    Protocol(FrameError),
}

enum Fill {
    Done,
    Closed,
    Shutdown,
}

/// Read exactly `buf.len()` bytes, polling the stop flag on every read
/// timeout. `at_boundary` marks reads that may abort cleanly on
/// shutdown (nothing consumed yet); mid-frame reads instead get
/// `drain_grace` to complete before the connection is abandoned.
fn fill(conn: &mut dyn Conn, buf: &mut [u8], shared: &Shared, at_boundary: bool) -> Fill {
    let mut filled = 0usize;
    let mut stop_deadline: Option<Instant> = None;
    while filled < buf.len() {
        if shared.stopping() {
            if at_boundary && filled == 0 {
                return Fill::Shutdown;
            }
            let d = *stop_deadline
                .get_or_insert_with(|| Instant::now() + shared.cfg.drain_grace);
            if Instant::now() >= d {
                return Fill::Closed;
            }
        }
        match conn.read(&mut buf[filled..]) {
            Ok(0) => return Fill::Closed,
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(_) => return Fill::Closed,
        }
    }
    Fill::Done
}

fn read_frame_polling(conn: &mut dyn Conn, shared: &Shared) -> FrameRead {
    let mut header = [0u8; wire::HEADER_LEN];
    match fill(conn, &mut header, shared, true) {
        Fill::Done => {}
        Fill::Closed => return FrameRead::Closed,
        Fill::Shutdown => return FrameRead::Shutdown,
    }
    if header[..4] != wire::MAGIC {
        return FrameRead::Protocol(FrameError::BadMagic([
            header[0], header[1], header[2], header[3],
        ]));
    }
    let kind = header[4];
    let len = u32::from_le_bytes([header[5], header[6], header[7], header[8]]);
    if len > shared.cfg.max_frame {
        return FrameRead::Protocol(FrameError::Oversize { len, max: shared.cfg.max_frame });
    }
    let mut payload = vec![0u8; len as usize];
    match fill(conn, &mut payload, shared, false) {
        Fill::Done => FrameRead::Frame(kind, payload),
        Fill::Closed => FrameRead::Closed,
        Fill::Shutdown => FrameRead::Shutdown,
    }
}

/// Decode the request's circuit into a [`RequestGraph`]. Both payload
/// forms land in the compact columnar store; `CircuitGraph::from_bytes`
/// and the AIGER reader both validate before anything reaches a worker.
fn build_request_graph(shared: &Shared, payload: GraphPayload) -> Result<RequestGraph> {
    match payload {
        GraphPayload::CircuitBytes(b) => {
            Ok(RequestGraph::Circuit(CircuitGraph::from_bytes(&b)?))
        }
        GraphPayload::AagText(text) => {
            let aig = crate::aig::aiger::read_aag_text("wire", &text)?;
            let src = crate::features::AigSource::new(aig, shared.cfg.aiger_chunk);
            Ok(RequestGraph::Circuit(CircuitGraph::from_source(src)?))
        }
    }
}

fn handle_conn(shared: Arc<Shared>, mut conn: Box<dyn Conn>) {
    let _ = conn.set_read_timeout(Some(shared.cfg.poll_interval));
    let handle = shared.server.handle();
    loop {
        let (kind, payload) = match read_frame_polling(conn.as_mut(), &shared) {
            FrameRead::Frame(k, p) => (k, p),
            FrameRead::Closed | FrameRead::Shutdown => return,
            FrameRead::Protocol(err) => {
                // One structured reply, then close: after a framing
                // violation the byte stream cannot be trusted again.
                let _ = wire::write_frame(
                    &mut conn,
                    wire::RESP_ERROR,
                    &wire::encode_error(wire::ERR_MALFORMED, &err.to_string()),
                );
                return;
            }
        };
        let ok = match kind {
            wire::REQ_STATS => {
                let stats = shared.stats();
                wire::write_frame(&mut conn, wire::RESP_STATS, &wire::encode_stats(&stats))
                    .is_ok()
            }
            wire::REQ_METRICS => match wire::decode_metrics_request(&payload) {
                Ok(format) => {
                    let text = shared.metrics_text(format);
                    wire::write_frame(
                        &mut conn,
                        wire::RESP_METRICS,
                        &wire::encode_metrics_response(&text),
                    )
                    .is_ok()
                }
                Err(e) => {
                    let _ = wire::write_frame(
                        &mut conn,
                        wire::RESP_ERROR,
                        &wire::encode_error(wire::ERR_MALFORMED, &format!("{e:#}")),
                    );
                    false
                }
            },
            wire::REQ_CLASSIFY => {
                match serve_classify(&shared, &handle, &mut conn, &payload) {
                    ClassifyOutcome::Continue => true,
                    ClassifyOutcome::Close => false,
                }
            }
            wire::REQ_CLASSIFY_DELTA => {
                match serve_delta(&shared, &handle, &mut conn, &payload) {
                    ClassifyOutcome::Continue => true,
                    ClassifyOutcome::Close => false,
                }
            }
            other => wire::write_frame(
                &mut conn,
                wire::RESP_ERROR,
                &wire::encode_error(
                    wire::ERR_UNSUPPORTED,
                    &format!("unknown request kind {other:#04x}"),
                ),
            )
            .is_ok(),
        };
        if !ok {
            return;
        }
    }
}

enum ClassifyOutcome {
    Continue,
    Close,
}

fn serve_classify(
    shared: &Shared,
    handle: &crate::coordinator::server::ServerHandle,
    conn: &mut Box<dyn Conn>,
    payload: &[u8],
) -> ClassifyOutcome {
    let reply_err = |conn: &mut Box<dyn Conn>, code: u16, msg: &str| -> bool {
        wire::write_frame(conn, wire::RESP_ERROR, &wire::encode_error(code, msg)).is_ok()
    };
    let (options, graph_payload) = match wire::decode_classify(payload) {
        Ok(x) => x,
        Err(e) => {
            // The frame parsed but its payload didn't: the stream stays
            // synchronized, yet the client is clearly broken — reply,
            // then close.
            let _ = reply_err(conn, wire::ERR_MALFORMED, &format!("{e:#}"));
            return ClassifyOutcome::Close;
        }
    };
    let graph = match build_request_graph(shared, graph_payload) {
        Ok(g) => g,
        Err(e) => {
            // Semantically invalid circuit; the connection itself is
            // healthy — keep serving it.
            return if reply_err(conn, wire::ERR_BAD_REQUEST, &format!("{e:#}")) {
                ClassifyOutcome::Continue
            } else {
                ClassifyOutcome::Close
            };
        }
    };
    let req_id = REQUEST_ID.fetch_add(1, Ordering::Relaxed);
    let _span = obs::span_with_arg("request", "net", "request_id", || req_id.to_string());
    let t0 = Instant::now();
    let rx = match handle.try_submit(graph, options) {
        Err(_) => {
            let _ = reply_err(conn, wire::ERR_SHUTTING_DOWN, "daemon is draining");
            return ClassifyOutcome::Close;
        }
        Ok(TrySubmit::Busy { .. }) => {
            // Explicit wire-level back-pressure: the queue is full and
            // the request was NOT accepted. Retry is the client's call.
            return if wire::write_frame(conn, wire::RESP_BUSY, &[]).is_ok() {
                ClassifyOutcome::Continue
            } else {
                ClassifyOutcome::Close
            };
        }
        Ok(TrySubmit::Accepted(rx)) => rx,
    };
    match rx.recv() {
        Ok(Ok(res)) => {
            let elapsed = t0.elapsed();
            shared.record_latency(elapsed.as_secs_f64() * 1e3);
            if elapsed >= shared.cfg.slow_request {
                log::warn(
                    LOG_TARGET,
                    format_args!(
                        "slow request {req_id}: {:.1} ms (threshold {} ms, {} nodes, {} partitions)",
                        elapsed.as_secs_f64() * 1e3,
                        shared.cfg.slow_request.as_millis(),
                        res.stats.total_nodes,
                        res.stats.num_partitions,
                    ),
                );
            }
            if wire::write_frame(conn, wire::RESP_RESULT, &wire::encode_result(&res)).is_ok() {
                ClassifyOutcome::Continue
            } else {
                ClassifyOutcome::Close
            }
        }
        Ok(Err(e)) => {
            if reply_err(conn, wire::ERR_INTERNAL, &format!("{e:#}")) {
                ClassifyOutcome::Continue
            } else {
                ClassifyOutcome::Close
            }
        }
        Err(_) => {
            let _ = reply_err(conn, wire::ERR_INTERNAL, "worker dropped the reply channel");
            ClassifyOutcome::Close
        }
    }
}

/// Serve one REQ_CLASSIFY_DELTA frame. Same error taxonomy as
/// [`serve_classify`], with one addition: an unregistered base
/// fingerprint is the client's mistake (classify the base through this
/// daemon first), so it maps to [`wire::ERR_BAD_REQUEST`] and the
/// connection stays usable.
fn serve_delta(
    shared: &Shared,
    handle: &crate::coordinator::server::ServerHandle,
    conn: &mut Box<dyn Conn>,
    payload: &[u8],
) -> ClassifyOutcome {
    let reply_err = |conn: &mut Box<dyn Conn>, code: u16, msg: &str| -> bool {
        wire::write_frame(conn, wire::RESP_ERROR, &wire::encode_error(code, msg)).is_ok()
    };
    let (options, base_fingerprint, edits) = match wire::decode_delta(payload) {
        Ok(x) => x,
        Err(e) => {
            let _ = reply_err(conn, wire::ERR_MALFORMED, &format!("{e:#}"));
            return ClassifyOutcome::Close;
        }
    };
    let req_id = REQUEST_ID.fetch_add(1, Ordering::Relaxed);
    let _span = obs::span_with_arg("delta_request", "net", "request_id", || req_id.to_string());
    let t0 = Instant::now();
    let rx = match handle.try_submit_delta(base_fingerprint, edits, options) {
        Err(_) => {
            let _ = reply_err(conn, wire::ERR_SHUTTING_DOWN, "daemon is draining");
            return ClassifyOutcome::Close;
        }
        Ok(DeltaSubmit::Busy { .. }) => {
            return if wire::write_frame(conn, wire::RESP_BUSY, &[]).is_ok() {
                ClassifyOutcome::Continue
            } else {
                ClassifyOutcome::Close
            };
        }
        Ok(DeltaSubmit::Accepted(rx)) => rx,
    };
    match rx.recv() {
        Ok(Ok(res)) => {
            shared.record_latency(t0.elapsed().as_secs_f64() * 1e3);
            if wire::write_frame(conn, wire::RESP_DELTA_RESULT, &wire::encode_delta_result(&res))
                .is_ok()
            {
                ClassifyOutcome::Continue
            } else {
                ClassifyOutcome::Close
            }
        }
        Ok(Err(e)) => {
            let msg = format!("{e:#}");
            // Distinguish the client's mistakes (unknown base, invalid
            // edit list) from pipeline failures: the former keep the
            // ERR_BAD_REQUEST contract of every other request kind.
            let code = if msg.contains("unknown base") || msg.contains("edit ") {
                wire::ERR_BAD_REQUEST
            } else {
                wire::ERR_INTERNAL
            };
            if reply_err(conn, code, &msg) {
                ClassifyOutcome::Continue
            } else {
                ClassifyOutcome::Close
            }
        }
        Err(_) => {
            let _ = reply_err(conn, wire::ERR_INTERNAL, "worker dropped the reply channel");
            ClassifyOutcome::Close
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bind_addr_parses_both_flavors() {
        assert_eq!(
            BindAddr::parse("unix:/tmp/groot.sock").unwrap(),
            BindAddr::Unix(PathBuf::from("/tmp/groot.sock"))
        );
        assert_eq!(
            BindAddr::parse("127.0.0.1:7878").unwrap(),
            BindAddr::Tcp("127.0.0.1:7878".into())
        );
        assert!(BindAddr::parse("unix:").is_err());
        assert!(BindAddr::parse("no-port-here").is_err());
        assert_eq!(BindAddr::parse("unix:/a.sock").unwrap().to_string(), "unix:/a.sock");
        assert_eq!(BindAddr::parse("[::1]:9").unwrap().to_string(), "[::1]:9");
    }
}

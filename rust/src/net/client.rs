//! `GrootClient` — blocking client for the [`super::daemon`] wire
//! protocol. One connection, sequential request/reply; open several
//! clients for concurrency (the daemon spawns one handler per
//! connection).

use super::daemon::BindAddr;
use super::wire::{self, GraphPayload, WireStats};
use crate::coordinator::server::VerifyOptions;
use crate::coordinator::{ClassifyResult, DeltaResult};
use crate::graph::CircuitGraph;
use crate::incremental::GraphEdit;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::os::unix::net::UnixStream;

enum ClientStream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Read for ClientStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            ClientStream::Tcp(s) => s.read(buf),
            ClientStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for ClientStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            ClientStream::Tcp(s) => s.write(buf),
            ClientStream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            ClientStream::Tcp(s) => s.flush(),
            ClientStream::Unix(s) => s.flush(),
        }
    }
}

/// A classify reply the caller must branch on: the daemon answers BUSY
/// (bounded queue full — request NOT accepted) as a normal outcome, not
/// an error.
#[derive(Debug)]
pub enum Reply {
    Result(ClassifyResult),
    Busy,
}

/// A delta reply — same BUSY contract as [`Reply`].
#[derive(Debug)]
pub enum DeltaReply {
    Result(DeltaResult),
    Busy,
}

pub struct GrootClient {
    stream: ClientStream,
    max_frame: u32,
}

impl GrootClient {
    pub fn connect(addr: &BindAddr) -> Result<GrootClient> {
        let stream = match addr {
            BindAddr::Tcp(a) => {
                let s = TcpStream::connect(a).with_context(|| format!("connect tcp {a}"))?;
                let _ = s.set_nodelay(true);
                ClientStream::Tcp(s)
            }
            BindAddr::Unix(p) => ClientStream::Unix(
                UnixStream::connect(p)
                    .with_context(|| format!("connect unix socket {}", p.display()))?,
            ),
        };
        Ok(GrootClient { stream, max_frame: wire::DEFAULT_MAX_FRAME })
    }

    /// Parse-and-connect convenience for `--connect` strings.
    pub fn connect_str(addr: &str) -> Result<GrootClient> {
        GrootClient::connect(&BindAddr::parse(addr)?)
    }

    /// Classify a compact circuit (encoded client-side).
    pub fn classify_circuit(
        &mut self,
        circuit: &CircuitGraph,
        options: &VerifyOptions,
    ) -> Result<Reply> {
        self.classify_circuit_bytes(&circuit.to_bytes(), options)
    }

    /// Classify pre-encoded [`CircuitGraph::to_bytes`] columns — lets
    /// benchmark loops pay the encode cost once.
    pub fn classify_circuit_bytes(
        &mut self,
        bytes: &[u8],
        options: &VerifyOptions,
    ) -> Result<Reply> {
        self.classify_payload(&GraphPayload::CircuitBytes(bytes.to_vec()), options)
    }

    /// Classify ASCII-AIGER text (parsed server-side through the full
    /// streaming ingestion path).
    pub fn classify_aag(&mut self, text: &str, options: &VerifyOptions) -> Result<Reply> {
        self.classify_payload(&GraphPayload::AagText(text.to_string()), options)
    }

    /// Classify an already-built [`GraphPayload`] — the general form the
    /// typed helpers above delegate to.
    pub fn classify_payload(
        &mut self,
        graph: &GraphPayload,
        options: &VerifyOptions,
    ) -> Result<Reply> {
        wire::write_frame(
            &mut self.stream,
            wire::REQ_CLASSIFY,
            &wire::encode_classify(options, graph),
        )
        .context("send classify request")?;
        let (kind, payload) = self.recv_frame()?;
        match kind {
            wire::RESP_RESULT => Ok(Reply::Result(wire::decode_result(&payload)?)),
            wire::RESP_BUSY => Ok(Reply::Busy),
            wire::RESP_ERROR => {
                let (code, msg) = wire::decode_error(&payload)?;
                bail!("server error {code}: {msg}")
            }
            other => bail!("unexpected reply kind {other:#04x}"),
        }
    }

    /// Incremental verification: send an edit list against a base design
    /// this daemon has already classified (its fingerprint is the key).
    /// The daemon re-infers only the partitions the edits dirtied.
    pub fn classify_delta(
        &mut self,
        base_fingerprint: u64,
        edits: &[GraphEdit],
        options: &VerifyOptions,
    ) -> Result<DeltaReply> {
        wire::write_frame(
            &mut self.stream,
            wire::REQ_CLASSIFY_DELTA,
            &wire::encode_delta(options, base_fingerprint, edits),
        )
        .context("send delta request")?;
        let (kind, payload) = self.recv_frame()?;
        match kind {
            wire::RESP_DELTA_RESULT => Ok(DeltaReply::Result(wire::decode_delta_result(&payload)?)),
            wire::RESP_BUSY => Ok(DeltaReply::Busy),
            wire::RESP_ERROR => {
                let (code, msg) = wire::decode_error(&payload)?;
                bail!("server error {code}: {msg}")
            }
            other => bail!("unexpected reply kind {other:#04x}"),
        }
    }

    /// Fetch the daemon's observability snapshot.
    pub fn stats(&mut self) -> Result<WireStats> {
        wire::write_frame(&mut self.stream, wire::REQ_STATS, &[])
            .context("send stats request")?;
        let (kind, payload) = self.recv_frame()?;
        match kind {
            wire::RESP_STATS => wire::decode_stats(&payload),
            wire::RESP_ERROR => {
                let (code, msg) = wire::decode_error(&payload)?;
                bail!("server error {code}: {msg}")
            }
            other => bail!("unexpected reply kind {other:#04x}"),
        }
    }

    /// Scrape the daemon's metrics registry: Prometheus text exposition
    /// or the JSON rendering, per `format`.
    pub fn metrics(&mut self, format: crate::obs::MetricsFormat) -> Result<String> {
        wire::write_frame(
            &mut self.stream,
            wire::REQ_METRICS,
            &wire::encode_metrics_request(format),
        )
        .context("send metrics request")?;
        let (kind, payload) = self.recv_frame()?;
        match kind {
            wire::RESP_METRICS => wire::decode_metrics_response(&payload),
            wire::RESP_ERROR => {
                let (code, msg) = wire::decode_error(&payload)?;
                bail!("server error {code}: {msg}")
            }
            other => bail!("unexpected reply kind {other:#04x}"),
        }
    }

    /// Write raw bytes onto the connection — the protocol-fuzz tooling
    /// (`groot client fuzz`, the malformed-frame tests) uses this to
    /// send deliberately broken traffic.
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.stream.write_all(bytes)?;
        self.stream.flush()?;
        Ok(())
    }

    /// Read one raw frame off the connection.
    pub fn recv_frame(&mut self) -> Result<(u8, Vec<u8>)> {
        wire::read_frame(&mut self.stream, self.max_frame).map_err(anyhow::Error::from)
    }
}

//! Versioned length-prefixed binary wire protocol.
//!
//! Frame grammar (all integers little-endian):
//!
//! ```text
//! frame   := magic kind len payload
//! magic   := "GRT1"                  (4 bytes; version is IN the magic)
//! kind    := u8                      (REQ_* from clients, RESP_* back)
//! len     := u32                     (payload byte count)
//! payload := len bytes               (kind-specific, see encode_*)
//! ```
//!
//! Hard rules enforced by [`read_frame`]:
//! * a frame whose magic is wrong is rejected without reading further —
//!   the stream is unsynchronized and must be closed;
//! * `len` is checked against the configured maximum **before** the
//!   payload buffer allocates, so an adversarial 4 GiB length prefix
//!   costs nothing;
//! * EOF cleanly between frames is [`FrameError::Eof`] (normal client
//!   disconnect); EOF inside a frame is [`FrameError::Truncated`].
//!
//! Payload encodings are hand-rolled (the crate has no serde): fixed
//! little-endian scalars and u64-counted vectors, mirrored by a bounds-
//! checked [`Reader`] on the decode side. Every decoder finishes with a
//! trailing-bytes check — a frame that parses but has leftover bytes is
//! malformed, not "close enough".

use crate::coordinator::server::VerifyOptions;
use crate::coordinator::{ClassifyResult, DeltaResult, RunStats};
use crate::incremental::GraphEdit;
use crate::obs::MetricsFormat;
use anyhow::{bail, Result};
use std::io::{Read, Write};
use std::time::Duration;

/// Frame magic: protocol identity AND version. A breaking change mints
/// "GRT2" — old peers then fail with BadMagic instead of misparsing.
pub const MAGIC: [u8; 4] = *b"GRT1";
/// magic(4) + kind(1) + payload_len(4)
pub const HEADER_LEN: usize = 9;
/// Default maximum payload size accepted per frame (64 MiB) — far above
/// any realistic circuit column store, far below a memory-exhaustion DoS.
pub const DEFAULT_MAX_FRAME: u32 = 64 * 1024 * 1024;

// ---- frame kinds -------------------------------------------------------
pub const REQ_CLASSIFY: u8 = 0x01;
pub const REQ_STATS: u8 = 0x02;
pub const REQ_METRICS: u8 = 0x03;
/// Incremental verification: a registered base fingerprint + edit list
/// (no graph payload). Answered with [`RESP_DELTA_RESULT`].
pub const REQ_CLASSIFY_DELTA: u8 = 0x04;
pub const RESP_RESULT: u8 = 0x81;
pub const RESP_ERROR: u8 = 0x82;
pub const RESP_BUSY: u8 = 0x83;
pub const RESP_STATS: u8 = 0x84;
pub const RESP_METRICS: u8 = 0x85;
pub const RESP_DELTA_RESULT: u8 = 0x86;

// ---- structured error codes (RESP_ERROR payload) -----------------------
/// Frame or payload did not parse; the connection is closed after this.
pub const ERR_MALFORMED: u16 = 1;
/// Frame parsed but the request content is invalid (e.g. bad AIGER text).
pub const ERR_BAD_REQUEST: u16 = 2;
/// The pipeline failed serving a well-formed request.
pub const ERR_INTERNAL: u16 = 3;
/// The daemon is draining; no new work is accepted.
pub const ERR_SHUTTING_DOWN: u16 = 4;
/// Unknown request kind (client newer than server).
pub const ERR_UNSUPPORTED: u16 = 5;

/// Why a frame read failed. `Io`/`Eof`/`Truncated` are transport-fatal;
/// `BadMagic`/`Oversize` are protocol-fatal (the daemon sends one ERROR
/// reply, then closes).
#[derive(Debug)]
pub enum FrameError {
    Io(std::io::Error),
    /// First four bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Declared payload length exceeds the configured maximum.
    Oversize { len: u32, max: u32 },
    /// Clean EOF at a frame boundary — the peer hung up between frames.
    Eof,
    /// EOF mid-frame — the peer died (or lied about `len`).
    Truncated,
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "frame io error: {e}"),
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            FrameError::Oversize { len, max } => {
                write!(f, "frame payload {len} bytes exceeds maximum {max}")
            }
            FrameError::Eof => write!(f, "connection closed"),
            FrameError::Truncated => write!(f, "connection closed mid-frame"),
        }
    }
}

impl std::error::Error for FrameError {}

/// Write one frame. Payloads larger than `u32::MAX` are an error (the
/// length prefix cannot express them).
pub fn write_frame<W: Write>(w: &mut W, kind: u8, payload: &[u8]) -> std::io::Result<()> {
    let len = u32::try_from(payload.len()).map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidInput, "frame payload exceeds u32")
    })?;
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&MAGIC);
    header[4] = kind;
    header[5..9].copy_from_slice(&len.to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()
}

/// Read exactly `buf.len()` bytes; distinguishes EOF-before-anything
/// (`had_any = false` → Eof) from EOF mid-read (Truncated).
fn read_exact_or(r: &mut impl Read, buf: &mut [u8], mut had_any: bool) -> Result<(), FrameError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(if had_any || filled > 0 {
                    FrameError::Truncated
                } else {
                    FrameError::Eof
                })
            }
            Ok(n) => {
                filled += n;
                had_any = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(())
}

/// Read one frame: `(kind, payload)`. See [`FrameError`] for the failure
/// taxonomy; `max_len` bounds the payload before it allocates.
pub fn read_frame(r: &mut impl Read, max_len: u32) -> Result<(u8, Vec<u8>), FrameError> {
    let mut header = [0u8; HEADER_LEN];
    read_exact_or(r, &mut header, false)?;
    if header[..4] != MAGIC {
        return Err(FrameError::BadMagic([header[0], header[1], header[2], header[3]]));
    }
    let kind = header[4];
    let len = u32::from_le_bytes([header[5], header[6], header[7], header[8]]);
    if len > max_len {
        return Err(FrameError::Oversize { len, max: max_len });
    }
    let mut payload = vec![0u8; len as usize];
    read_exact_or(r, &mut payload, true)?;
    Ok((kind, payload))
}

// ---- little-endian scalar helpers --------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// Bounds-checked payload reader. Every `decode_*` constructs one, pulls
/// typed fields in layout order, and calls [`Reader::finish`].
struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, at: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self.at.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(end) => {
                let s = &self.buf[self.at..end];
                self.at = end;
                Ok(s)
            }
            None => bail!(
                "truncated payload: {what} needs {n} bytes at offset {}, have {}",
                self.at,
                self.buf.len() - self.at
            ),
        }
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    /// A u64 element count, sanity-bounded by the bytes actually left in
    /// the payload — a hostile count can never cause an over-allocation.
    fn count(&mut self, elem_bytes: usize, what: &str) -> Result<usize> {
        let n = self.u64(what)?;
        let remaining = self.buf.len() - self.at;
        let need =
            usize::try_from(n).ok().and_then(|n| n.checked_mul(elem_bytes.max(1)));
        match need {
            Some(need) if need <= remaining => Ok(n as usize),
            _ => bail!("{what} count {n} exceeds the {remaining} payload bytes remaining"),
        }
    }

    fn finish(self, what: &str) -> Result<()> {
        if self.at != self.buf.len() {
            bail!("{what}: {} trailing bytes after payload", self.buf.len() - self.at);
        }
        Ok(())
    }
}

// ---- classify request ---------------------------------------------------

/// The circuit half of a classify request: either raw ASCII-AIGER text
/// (parsed server-side, full ingestion path) or a pre-encoded compact
/// [`crate::graph::CircuitGraph`] column store
/// ([`crate::graph::CircuitGraph::to_bytes`]) that decodes without
/// re-deriving features.
#[derive(Clone, Debug, PartialEq)]
pub enum GraphPayload {
    AagText(String),
    CircuitBytes(Vec<u8>),
}

const FLAG_HAS_PARTITIONS: u8 = 1 << 0;
const FLAG_HAS_REGROW: u8 = 1 << 1;
const FLAG_REGROW_VALUE: u8 = 1 << 2;
const FLAG_HAS_SEED: u8 = 1 << 3;

const GRAPH_TAG_AAG: u8 = 0;
const GRAPH_TAG_CIRCUIT: u8 = 1;

/// Payload layout:
/// `flags u8 | [partitions u64] | [seed u64] | tag u8 | len u64 | bytes`.
/// Option presence lives in `flags` (bit0 partitions, bit1 regrow
/// present, bit2 regrow value, bit3 seed).
pub fn encode_classify(options: &VerifyOptions, graph: &GraphPayload) -> Vec<u8> {
    let bytes: &[u8] = match graph {
        GraphPayload::AagText(t) => t.as_bytes(),
        GraphPayload::CircuitBytes(b) => b,
    };
    let mut out = Vec::with_capacity(1 + 8 + 8 + 1 + 8 + bytes.len());
    let mut flags = 0u8;
    if options.partitions.is_some() {
        flags |= FLAG_HAS_PARTITIONS;
    }
    if let Some(r) = options.regrow {
        flags |= FLAG_HAS_REGROW;
        if r {
            flags |= FLAG_REGROW_VALUE;
        }
    }
    if options.seed.is_some() {
        flags |= FLAG_HAS_SEED;
    }
    out.push(flags);
    if let Some(p) = options.partitions {
        put_u64(&mut out, p as u64);
    }
    if let Some(s) = options.seed {
        put_u64(&mut out, s);
    }
    out.push(match graph {
        GraphPayload::AagText(_) => GRAPH_TAG_AAG,
        GraphPayload::CircuitBytes(_) => GRAPH_TAG_CIRCUIT,
    });
    put_u64(&mut out, bytes.len() as u64);
    out.extend_from_slice(bytes);
    out
}

pub fn decode_classify(payload: &[u8]) -> Result<(VerifyOptions, GraphPayload)> {
    let mut rd = Reader::new(payload);
    let flags = rd.u8("flags")?;
    if flags & !(FLAG_HAS_PARTITIONS | FLAG_HAS_REGROW | FLAG_REGROW_VALUE | FLAG_HAS_SEED) != 0 {
        bail!("classify request: unknown option flags {flags:#04x}");
    }
    let partitions = if flags & FLAG_HAS_PARTITIONS != 0 {
        let p = rd.u64("partitions")?;
        Some(usize::try_from(p).map_err(|_| anyhow::anyhow!("partitions {p} out of range"))?)
    } else {
        None
    };
    let regrow =
        (flags & FLAG_HAS_REGROW != 0).then_some(flags & FLAG_REGROW_VALUE != 0);
    let seed = if flags & FLAG_HAS_SEED != 0 { Some(rd.u64("seed")?) } else { None };
    let tag = rd.u8("graph tag")?;
    let len = rd.count(1, "graph bytes")?;
    let bytes = rd.take(len, "graph bytes")?;
    let graph = match tag {
        GRAPH_TAG_AAG => GraphPayload::AagText(
            std::str::from_utf8(bytes)
                .map_err(|e| anyhow::anyhow!("aag payload is not utf-8: {e}"))?
                .to_string(),
        ),
        GRAPH_TAG_CIRCUIT => GraphPayload::CircuitBytes(bytes.to_vec()),
        other => bail!("classify request: unknown graph tag {other}"),
    };
    rd.finish("classify request")?;
    Ok((VerifyOptions { partitions, regrow, seed }, graph))
}

// ---- classify delta ------------------------------------------------------

const EDIT_TAG_SET_FUNCTION: u8 = 0;
const EDIT_TAG_ADD_EDGE: u8 = 1;
const EDIT_TAG_REMOVE_EDGE: u8 = 2;
const EDIT_TAG_APPEND_CONE: u8 = 3;

const EDIT_INV_L: u8 = 1 << 0;
const EDIT_INV_R: u8 = 1 << 1;

fn put_edit(out: &mut Vec<u8>, edit: &GraphEdit) {
    match edit {
        GraphEdit::SetFunction { node, kind, inv_l, inv_r } => {
            out.push(EDIT_TAG_SET_FUNCTION);
            put_u64(out, *node as u64);
            out.push(*kind);
            let mut inv = 0u8;
            if *inv_l {
                inv |= EDIT_INV_L;
            }
            if *inv_r {
                inv |= EDIT_INV_R;
            }
            out.push(inv);
        }
        GraphEdit::AddEdge { src, dst } => {
            out.push(EDIT_TAG_ADD_EDGE);
            put_u64(out, *src as u64);
            put_u64(out, *dst as u64);
        }
        GraphEdit::RemoveEdge { src, dst } => {
            out.push(EDIT_TAG_REMOVE_EDGE);
            put_u64(out, *src as u64);
            put_u64(out, *dst as u64);
        }
        GraphEdit::AppendCone { desc, labels, fanins } => {
            out.push(EDIT_TAG_APPEND_CONE);
            put_u64(out, desc.len() as u64);
            out.extend_from_slice(desc);
            out.extend_from_slice(labels);
            put_u64(out, fanins.len() as u64);
            for &(src, dst) in fanins {
                put_u64(out, src as u64);
                put_u64(out, dst as u64);
            }
        }
    }
}

fn read_node_id(rd: &mut Reader<'_>, what: &str) -> Result<u32> {
    let v = rd.u64(what)?;
    u32::try_from(v).map_err(|_| anyhow::anyhow!("{what} {v} exceeds the u32 node-id space"))
}

fn read_edit(rd: &mut Reader<'_>, i: usize) -> Result<GraphEdit> {
    match rd.u8("edit tag")? {
        EDIT_TAG_SET_FUNCTION => {
            let node = read_node_id(rd, "edit node")?;
            let kind = rd.u8("edit kind")?;
            let inv = rd.u8("edit polarity flags")?;
            if inv & !(EDIT_INV_L | EDIT_INV_R) != 0 {
                bail!("edit {i}: unknown polarity flags {inv:#04x}");
            }
            Ok(GraphEdit::SetFunction {
                node,
                kind,
                inv_l: inv & EDIT_INV_L != 0,
                inv_r: inv & EDIT_INV_R != 0,
            })
        }
        EDIT_TAG_ADD_EDGE => Ok(GraphEdit::AddEdge {
            src: read_node_id(rd, "edge src")?,
            dst: read_node_id(rd, "edge dst")?,
        }),
        EDIT_TAG_REMOVE_EDGE => Ok(GraphEdit::RemoveEdge {
            src: read_node_id(rd, "edge src")?,
            dst: read_node_id(rd, "edge dst")?,
        }),
        EDIT_TAG_APPEND_CONE => {
            // desc and labels are parallel byte arrays of one length:
            // bound the count by BOTH (2 bytes per cone node minimum).
            let k = rd.count(2, "cone size")?;
            let desc = rd.take(k, "cone descriptors")?.to_vec();
            let labels = rd.take(k, "cone labels")?.to_vec();
            let nfan = rd.count(16, "cone fanins")?;
            let mut fanins = Vec::with_capacity(nfan);
            for _ in 0..nfan {
                fanins.push((read_node_id(rd, "fanin src")?, read_node_id(rd, "fanin dst")?));
            }
            Ok(GraphEdit::AppendCone { desc, labels, fanins })
        }
        other => bail!("edit {i}: unknown edit tag {other}"),
    }
}

/// Payload layout:
/// `flags u8 | [partitions u64] | [seed u64] | base_fp u64 | nedits u64 |
/// edits` — the option prefix is identical to [`encode_classify`]; each
/// edit is `tag u8` + tag-specific fields (see `put_edit`).
pub fn encode_delta(options: &VerifyOptions, base_fingerprint: u64, edits: &[GraphEdit]) -> Vec<u8> {
    let mut out = Vec::with_capacity(1 + 8 + 8 + 8 + 8 + edits.len() * 17);
    let mut flags = 0u8;
    if options.partitions.is_some() {
        flags |= FLAG_HAS_PARTITIONS;
    }
    if let Some(r) = options.regrow {
        flags |= FLAG_HAS_REGROW;
        if r {
            flags |= FLAG_REGROW_VALUE;
        }
    }
    if options.seed.is_some() {
        flags |= FLAG_HAS_SEED;
    }
    out.push(flags);
    if let Some(p) = options.partitions {
        put_u64(&mut out, p as u64);
    }
    if let Some(s) = options.seed {
        put_u64(&mut out, s);
    }
    put_u64(&mut out, base_fingerprint);
    put_u64(&mut out, edits.len() as u64);
    for edit in edits {
        put_edit(&mut out, edit);
    }
    out
}

#[allow(clippy::type_complexity)]
pub fn decode_delta(payload: &[u8]) -> Result<(VerifyOptions, u64, Vec<GraphEdit>)> {
    let mut rd = Reader::new(payload);
    let flags = rd.u8("flags")?;
    if flags & !(FLAG_HAS_PARTITIONS | FLAG_HAS_REGROW | FLAG_REGROW_VALUE | FLAG_HAS_SEED) != 0 {
        bail!("delta request: unknown option flags {flags:#04x}");
    }
    let partitions = if flags & FLAG_HAS_PARTITIONS != 0 {
        let p = rd.u64("partitions")?;
        Some(usize::try_from(p).map_err(|_| anyhow::anyhow!("partitions {p} out of range"))?)
    } else {
        None
    };
    let regrow = (flags & FLAG_HAS_REGROW != 0).then_some(flags & FLAG_REGROW_VALUE != 0);
    let seed = if flags & FLAG_HAS_SEED != 0 { Some(rd.u64("seed")?) } else { None };
    let base_fingerprint = rd.u64("base fingerprint")?;
    // the smallest edit (SetFunction) is 11 bytes — bound the count by it
    let nedits = rd.count(11, "edits")?;
    let mut edits = Vec::with_capacity(nedits);
    for i in 0..nedits {
        edits.push(read_edit(&mut rd, i)?);
    }
    rd.finish("delta request")?;
    Ok((VerifyOptions { partitions, regrow, seed }, base_fingerprint, edits))
}

const DELTA_FLAG_REPARTITIONED: u8 = 1 << 0;

/// Payload layout: `result_len u64 | encode_result bytes | edited_fp u64
/// | dirty u64 | clean u64 | flags u8` — the embedded classify result is
/// length-prefixed so its decoder keeps its own strict trailing check.
pub fn encode_delta_result(res: &DeltaResult) -> Vec<u8> {
    let inner = encode_result(&res.result);
    let mut out = Vec::with_capacity(8 + inner.len() + 8 * 3 + 1);
    put_u64(&mut out, inner.len() as u64);
    out.extend_from_slice(&inner);
    put_u64(&mut out, res.edited_fingerprint);
    put_u64(&mut out, res.dirty as u64);
    put_u64(&mut out, res.clean as u64);
    let mut flags = 0u8;
    if res.repartitioned {
        flags |= DELTA_FLAG_REPARTITIONED;
    }
    out.push(flags);
    out
}

pub fn decode_delta_result(payload: &[u8]) -> Result<DeltaResult> {
    let mut rd = Reader::new(payload);
    let inner_len = rd.count(1, "embedded result")?;
    let inner = rd.take(inner_len, "embedded result")?;
    let result = decode_result(inner)?;
    let edited_fingerprint = rd.u64("edited fingerprint")?;
    let dirty = rd.u64("dirty partitions")? as usize;
    let clean = rd.u64("clean partitions")? as usize;
    let flags = rd.u8("delta flags")?;
    if flags & !DELTA_FLAG_REPARTITIONED != 0 {
        bail!("delta result: unknown flags {flags:#04x}");
    }
    rd.finish("delta result")?;
    Ok(DeltaResult {
        result,
        edited_fingerprint,
        dirty,
        clean,
        repartitioned: flags & DELTA_FLAG_REPARTITIONED != 0,
    })
}

// ---- classify result ----------------------------------------------------

const RESULT_FLAG_REGROWN: u8 = 1 << 0;
const RESULT_FLAG_CACHE_HIT: u8 = 1 << 1;

/// Payload layout: `npred u64 | pred bytes | accuracy f64 | 8 × u64
/// counters | 4 × u64 stage nanos | flags u8` — the full [`RunStats`]
/// surface, so a socket client sees exactly what an in-process caller
/// sees (including `plan_cache_hit`, which the warm-restart tests read).
pub fn encode_result(res: &ClassifyResult) -> Vec<u8> {
    let s = &res.stats;
    let mut out = Vec::with_capacity(8 + res.pred.len() + 8 + 12 * 8 + 1);
    put_u64(&mut out, res.pred.len() as u64);
    out.extend_from_slice(&res.pred);
    put_f64(&mut out, res.accuracy);
    for v in [
        s.num_partitions,
        s.total_nodes,
        s.total_boundary_nodes,
        s.total_crossing_edges,
        s.max_partition_nodes,
        s.peak_bucket_n,
        s.batch_size,
        s.peak_resident_bytes,
    ] {
        put_u64(&mut out, v as u64);
    }
    for d in [s.partition_time, s.regrowth_time, s.pack_time, s.infer_time] {
        put_u64(&mut out, d.as_nanos().min(u64::MAX as u128) as u64);
    }
    let mut flags = 0u8;
    if s.regrown {
        flags |= RESULT_FLAG_REGROWN;
    }
    if s.plan_cache_hit {
        flags |= RESULT_FLAG_CACHE_HIT;
    }
    out.push(flags);
    out
}

pub fn decode_result(payload: &[u8]) -> Result<ClassifyResult> {
    let mut rd = Reader::new(payload);
    let npred = rd.count(1, "pred")?;
    let pred = rd.take(npred, "pred")?.to_vec();
    let accuracy = rd.f64("accuracy")?;
    let mut counters = [0u64; 8];
    for (i, c) in counters.iter_mut().enumerate() {
        *c = rd.u64(&format!("counter {i}"))?;
    }
    let mut nanos = [0u64; 4];
    for (i, n) in nanos.iter_mut().enumerate() {
        *n = rd.u64(&format!("stage nanos {i}"))?;
    }
    let flags = rd.u8("result flags")?;
    rd.finish("classify result")?;
    let stats = RunStats {
        num_partitions: counters[0] as usize,
        regrown: flags & RESULT_FLAG_REGROWN != 0,
        partition_time: Duration::from_nanos(nanos[0]),
        regrowth_time: Duration::from_nanos(nanos[1]),
        pack_time: Duration::from_nanos(nanos[2]),
        infer_time: Duration::from_nanos(nanos[3]),
        total_nodes: counters[1] as usize,
        total_boundary_nodes: counters[2] as usize,
        total_crossing_edges: counters[3] as usize,
        max_partition_nodes: counters[4] as usize,
        peak_bucket_n: counters[5] as usize,
        plan_cache_hit: flags & RESULT_FLAG_CACHE_HIT != 0,
        batch_size: counters[6] as usize,
        peak_resident_bytes: counters[7] as usize,
    };
    Ok(ClassifyResult { pred, accuracy, stats })
}

// ---- structured errors ---------------------------------------------------

/// Payload layout: `code u16 | len u32 | utf-8 message`.
pub fn encode_error(code: u16, message: &str) -> Vec<u8> {
    let msg = message.as_bytes();
    let msg = &msg[..msg.len().min(u32::MAX as usize)];
    let mut out = Vec::with_capacity(2 + 4 + msg.len());
    put_u16(&mut out, code);
    out.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    out.extend_from_slice(msg);
    out
}

pub fn decode_error(payload: &[u8]) -> Result<(u16, String)> {
    let mut rd = Reader::new(payload);
    let code = rd.u16("error code")?;
    let len = rd.take(4, "error message length")?;
    let len = u32::from_le_bytes(len.try_into().unwrap()) as usize;
    let msg = rd.take(len, "error message")?;
    let msg = std::str::from_utf8(msg)
        .map_err(|e| anyhow::anyhow!("error message is not utf-8: {e}"))?
        .to_string();
    rd.finish("error reply")?;
    Ok((code, msg))
}

// ---- metrics scrape ------------------------------------------------------

/// Payload layout: `format u8` ([`MetricsFormat::as_u8`]). An **empty**
/// payload is also accepted by the decoder and means Prometheus — a
/// scrape is `printf 'GRT1\x03\0\0\0\0' | nc`-able without knowing the
/// format byte.
pub fn encode_metrics_request(format: MetricsFormat) -> Vec<u8> {
    vec![format.as_u8()]
}

pub fn decode_metrics_request(payload: &[u8]) -> Result<MetricsFormat> {
    match payload {
        [] => Ok(MetricsFormat::Prometheus),
        [b] => MetricsFormat::from_u8(*b)
            .ok_or_else(|| anyhow::anyhow!("metrics request: unknown format byte {b:#04x}")),
        _ => bail!("metrics request: expected 0 or 1 payload bytes, got {}", payload.len()),
    }
}

/// Payload is the rendered exposition text, UTF-8, no length prefix (the
/// frame header already carries the length).
pub fn encode_metrics_response(text: &str) -> Vec<u8> {
    text.as_bytes().to_vec()
}

pub fn decode_metrics_response(payload: &[u8]) -> Result<String> {
    Ok(std::str::from_utf8(payload)
        .map_err(|e| anyhow::anyhow!("metrics reply is not utf-8: {e}"))?
        .to_string())
}

// ---- server stats --------------------------------------------------------

/// The STATS reply: queue/worker/plan-cache observability from
/// [`crate::coordinator::server::ServerStats`] plus the daemon-level
/// request latency distribution.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireStats {
    pub queue_depth: u64,
    pub workers: u64,
    pub per_worker_requests: Vec<u64>,
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    pub plan_disk_hits: u64,
    pub plan_store_writes: u64,
    pub plan_store_quarantined: u64,
    /// Classify requests the daemon has answered with RESP_RESULT.
    pub requests_served: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

/// Payload layout: `8 × u64 scalars | 3 × f64 percentiles | nworkers u64
/// | per-worker u64s`.
pub fn encode_stats(s: &WireStats) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 * 12 + 8 * s.per_worker_requests.len());
    for v in [
        s.queue_depth,
        s.workers,
        s.plan_cache_hits,
        s.plan_cache_misses,
        s.plan_disk_hits,
        s.plan_store_writes,
        s.plan_store_quarantined,
        s.requests_served,
    ] {
        put_u64(&mut out, v);
    }
    for v in [s.p50_ms, s.p95_ms, s.p99_ms] {
        put_f64(&mut out, v);
    }
    put_u64(&mut out, s.per_worker_requests.len() as u64);
    for &v in &s.per_worker_requests {
        put_u64(&mut out, v);
    }
    out
}

pub fn decode_stats(payload: &[u8]) -> Result<WireStats> {
    let mut rd = Reader::new(payload);
    let mut scalars = [0u64; 8];
    for (i, v) in scalars.iter_mut().enumerate() {
        *v = rd.u64(&format!("stats scalar {i}"))?;
    }
    let p50_ms = rd.f64("p50")?;
    let p95_ms = rd.f64("p95")?;
    let p99_ms = rd.f64("p99")?;
    let n = rd.count(8, "per-worker counts")?;
    let mut per_worker_requests = Vec::with_capacity(n);
    for _ in 0..n {
        per_worker_requests.push(rd.u64("per-worker count")?);
    }
    rd.finish("stats reply")?;
    Ok(WireStats {
        queue_depth: scalars[0],
        workers: scalars[1],
        per_worker_requests,
        plan_cache_hits: scalars[2],
        plan_cache_misses: scalars[3],
        plan_disk_hits: scalars[4],
        plan_store_writes: scalars[5],
        plan_store_quarantined: scalars[6],
        requests_served: scalars[7],
        p50_ms,
        p95_ms,
        p99_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame_roundtrip(kind: u8, payload: &[u8]) -> (u8, Vec<u8>) {
        let mut buf = Vec::new();
        write_frame(&mut buf, kind, payload).unwrap();
        read_frame(&mut buf.as_slice(), DEFAULT_MAX_FRAME).unwrap()
    }

    #[test]
    fn frames_roundtrip() {
        for (kind, payload) in [
            (REQ_CLASSIFY, b"hello".to_vec()),
            (REQ_STATS, Vec::new()),
            (RESP_RESULT, vec![0u8; 10_000]),
        ] {
            let (k, p) = frame_roundtrip(kind, &payload);
            assert_eq!((k, p), (kind, payload));
        }
    }

    #[test]
    fn read_frame_rejects_bad_magic_oversize_and_truncation() {
        // wrong magic
        let mut buf = Vec::new();
        write_frame(&mut buf, REQ_STATS, b"").unwrap();
        buf[0] = b'X';
        assert!(matches!(
            read_frame(&mut buf.as_slice(), DEFAULT_MAX_FRAME),
            Err(FrameError::BadMagic(_))
        ));

        // oversize declared length is rejected before allocation
        let mut buf = Vec::new();
        write_frame(&mut buf, REQ_CLASSIFY, &vec![0u8; 100]).unwrap();
        buf[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            read_frame(&mut buf.as_slice(), DEFAULT_MAX_FRAME),
            Err(FrameError::Oversize { len: u32::MAX, .. })
        ));

        // truncation at every prefix length inside the frame
        let mut full = Vec::new();
        write_frame(&mut full, REQ_CLASSIFY, b"abcdef").unwrap();
        for cut in 1..full.len() {
            let err = read_frame(&mut full[..cut].to_vec().as_slice(), DEFAULT_MAX_FRAME)
                .expect_err("truncated frame accepted");
            assert!(
                matches!(err, FrameError::Truncated | FrameError::BadMagic(_)),
                "cut {cut}: {err}"
            );
        }
        // clean EOF at a boundary is Eof, not Truncated
        let mut empty: &[u8] = &[];
        assert!(matches!(read_frame(&mut empty, DEFAULT_MAX_FRAME), Err(FrameError::Eof)));
    }

    #[test]
    fn classify_request_roundtrips_all_option_shapes() {
        let graphs = [
            GraphPayload::AagText("aag 0 0 0 0 0\n".into()),
            GraphPayload::CircuitBytes(vec![1, 2, 3, 4]),
        ];
        let options = [
            VerifyOptions::default(),
            VerifyOptions { partitions: Some(8), regrow: Some(false), seed: Some(7) },
            VerifyOptions { partitions: None, regrow: Some(true), seed: None },
            VerifyOptions { partitions: Some(3), regrow: None, seed: Some(u64::MAX) },
        ];
        for g in &graphs {
            for o in &options {
                let enc = encode_classify(o, g);
                let (o2, g2) = decode_classify(&enc).unwrap();
                assert_eq!(o2.partitions, o.partitions);
                assert_eq!(o2.regrow, o.regrow);
                assert_eq!(o2.seed, o.seed);
                assert_eq!(&g2, g);
            }
        }
    }

    #[test]
    fn classify_request_rejects_malformed_payloads() {
        let good = encode_classify(
            &VerifyOptions::partitions(4),
            &GraphPayload::CircuitBytes(vec![9; 16]),
        );
        // truncation at every cut
        for cut in 0..good.len() {
            assert!(decode_classify(&good[..cut]).is_err(), "cut {cut} accepted");
        }
        // trailing junk
        let mut junk = good.clone();
        junk.push(0);
        assert!(decode_classify(&junk).is_err());
        // unknown flags
        let mut bad = good.clone();
        bad[0] |= 1 << 7;
        assert!(decode_classify(&bad).is_err());
        // unknown graph tag (tag sits after flags + partitions u64)
        let mut bad = good.clone();
        bad[9] = 42;
        assert!(decode_classify(&bad).is_err());
        // hostile length prefix: count far beyond the buffer
        let mut bad = good;
        let len_at = 10;
        bad[len_at..len_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_classify(&bad).is_err());
        // non-utf8 aag text
        let mut enc =
            encode_classify(&VerifyOptions::default(), &GraphPayload::AagText("ok".into()));
        let n = enc.len();
        enc[n - 1] = 0xFF;
        assert!(decode_classify(&enc).is_err());
    }

    #[test]
    fn delta_request_roundtrips_every_edit_kind() {
        let edits = vec![
            GraphEdit::SetFunction { node: 7, kind: 1, inv_l: true, inv_r: false },
            GraphEdit::AddEdge { src: 3, dst: 9 },
            GraphEdit::RemoveEdge { src: 2, dst: 9 },
            GraphEdit::AppendCone {
                desc: vec![0, 1, 1],
                labels: vec![4, 3, 3],
                fanins: vec![(0, 1), (1, 2), (100, 2)],
            },
        ];
        let options = [
            VerifyOptions::default(),
            VerifyOptions { partitions: Some(8), regrow: Some(true), seed: Some(5) },
        ];
        for o in &options {
            let enc = encode_delta(o, 0xDEAD_BEEF_CAFE_F00D, &edits);
            let (o2, fp, e2) = decode_delta(&enc).unwrap();
            assert_eq!(o2.partitions, o.partitions);
            assert_eq!(o2.regrow, o.regrow);
            assert_eq!(o2.seed, o.seed);
            assert_eq!(fp, 0xDEAD_BEEF_CAFE_F00D);
            assert_eq!(e2, edits);
        }
        // strict truncation + trailing-bytes checks
        let enc = encode_delta(&VerifyOptions::default(), 1, &edits);
        for cut in 0..enc.len() {
            assert!(decode_delta(&enc[..cut]).is_err(), "cut {cut} accepted");
        }
        let mut junk = enc.clone();
        junk.push(0);
        assert!(decode_delta(&junk).is_err());
        // unknown edit tag (first edit starts after flags + fp + count)
        let mut bad = enc;
        bad[17] = 99;
        assert!(decode_delta(&bad).is_err());
        // node ids above u32 are rejected, not silently truncated
        let big = encode_delta(
            &VerifyOptions::default(),
            1,
            &[GraphEdit::AddEdge { src: 1, dst: 2 }],
        );
        let mut bad = big;
        bad[18..26].copy_from_slice(&u64::MAX.to_le_bytes()); // src field
        assert!(decode_delta(&bad).is_err());
    }

    #[test]
    fn delta_result_roundtrips() {
        let res = DeltaResult {
            result: ClassifyResult {
                pred: vec![1, 2, 3, 0, 4],
                accuracy: 0.6,
                stats: RunStats { num_partitions: 3, batch_size: 1, ..Default::default() },
            },
            edited_fingerprint: 0xABCD,
            dirty: 1,
            clean: 2,
            repartitioned: false,
        };
        let enc = encode_delta_result(&res);
        let dec = decode_delta_result(&enc).unwrap();
        assert_eq!(dec.result.pred, res.result.pred);
        assert_eq!(dec.result.accuracy, res.result.accuracy);
        assert_eq!(dec.edited_fingerprint, res.edited_fingerprint);
        assert_eq!(dec.dirty, 1);
        assert_eq!(dec.clean, 2);
        assert!(!dec.repartitioned);
        let rep = DeltaResult { repartitioned: true, ..res };
        assert!(decode_delta_result(&encode_delta_result(&rep)).unwrap().repartitioned);
        for cut in 0..enc.len() {
            assert!(decode_delta_result(&enc[..cut]).is_err(), "cut {cut}");
        }
        let mut junk = enc;
        junk.push(7);
        assert!(decode_delta_result(&junk).is_err());
    }

    #[test]
    fn result_roundtrips_with_full_stats() {
        let res = ClassifyResult {
            pred: vec![0, 3, 1, 4, 4, 2],
            accuracy: 0.875,
            stats: RunStats {
                num_partitions: 4,
                regrown: true,
                partition_time: Duration::from_micros(1234),
                regrowth_time: Duration::from_micros(567),
                pack_time: Duration::from_micros(89),
                infer_time: Duration::from_micros(1011),
                total_nodes: 6,
                total_boundary_nodes: 2,
                total_crossing_edges: 5,
                max_partition_nodes: 3,
                peak_bucket_n: 12,
                plan_cache_hit: true,
                batch_size: 4,
                peak_resident_bytes: 4096,
            },
        };
        let enc = encode_result(&res);
        let dec = decode_result(&enc).unwrap();
        assert_eq!(dec.pred, res.pred);
        assert_eq!(dec.accuracy, res.accuracy);
        let (a, b) = (&dec.stats, &res.stats);
        assert_eq!(a.num_partitions, b.num_partitions);
        assert_eq!(a.regrown, b.regrown);
        assert_eq!(a.partition_time, b.partition_time);
        assert_eq!(a.regrowth_time, b.regrowth_time);
        assert_eq!(a.pack_time, b.pack_time);
        assert_eq!(a.infer_time, b.infer_time);
        assert_eq!(a.total_nodes, b.total_nodes);
        assert_eq!(a.total_boundary_nodes, b.total_boundary_nodes);
        assert_eq!(a.total_crossing_edges, b.total_crossing_edges);
        assert_eq!(a.max_partition_nodes, b.max_partition_nodes);
        assert_eq!(a.peak_bucket_n, b.peak_bucket_n);
        assert_eq!(a.plan_cache_hit, b.plan_cache_hit);
        assert_eq!(a.batch_size, b.batch_size);
        assert_eq!(a.peak_resident_bytes, b.peak_resident_bytes);
        // decoder is strict about truncation + trailing bytes
        for cut in 0..enc.len() {
            assert!(decode_result(&enc[..cut]).is_err(), "cut {cut}");
        }
        let mut junk = enc;
        junk.push(1);
        assert!(decode_result(&junk).is_err());
    }

    #[test]
    fn metrics_request_accepts_empty_and_one_byte_only() {
        assert_eq!(
            decode_metrics_request(&encode_metrics_request(MetricsFormat::Prometheus)).unwrap(),
            MetricsFormat::Prometheus
        );
        assert_eq!(
            decode_metrics_request(&encode_metrics_request(MetricsFormat::Json)).unwrap(),
            MetricsFormat::Json
        );
        // empty payload defaults to Prometheus (netcat-able scrape)
        assert_eq!(decode_metrics_request(&[]).unwrap(), MetricsFormat::Prometheus);
        assert!(decode_metrics_request(&[9]).is_err());
        assert!(decode_metrics_request(&[0, 0]).is_err());

        let text = "# TYPE groot_requests_served_total counter\ngroot_requests_served_total 3\n";
        let enc = encode_metrics_response(text);
        assert_eq!(decode_metrics_response(&enc).unwrap(), text);
        assert!(decode_metrics_response(&[0xFF, 0xFE]).is_err());
    }

    #[test]
    fn error_and_stats_roundtrip() {
        let enc = encode_error(ERR_BAD_REQUEST, "line 3: bad output literal \"x7\"");
        let (code, msg) = decode_error(&enc).unwrap();
        assert_eq!(code, ERR_BAD_REQUEST);
        assert!(msg.contains("line 3"));

        let stats = WireStats {
            queue_depth: 2,
            workers: 4,
            per_worker_requests: vec![10, 11, 12, 13],
            plan_cache_hits: 7,
            plan_cache_misses: 3,
            plan_disk_hits: 1,
            plan_store_writes: 3,
            plan_store_quarantined: 0,
            requests_served: 46,
            p50_ms: 1.5,
            p95_ms: 9.25,
            p99_ms: 20.0,
        };
        let enc = encode_stats(&stats);
        assert_eq!(decode_stats(&enc).unwrap(), stats);
        for cut in 0..enc.len() {
            assert!(decode_stats(&enc[..cut]).is_err(), "cut {cut}");
        }
    }
}

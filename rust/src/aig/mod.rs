//! And-Inverter Graph (AIG) core — the circuit substrate.
//!
//! The paper uses ABC to turn netlists into AIGs; this module is our ABC
//! substitute: a structurally-hashed AIG with the usual constructor algebra
//! (and/or/xor/mux/maj, adders in [`adders`]), generator frontends for the
//! paper's datasets (CSA array multipliers in [`mult`], radix-4 Booth in
//! [`booth`]), 64-way bit-parallel simulation in [`sim`], and AIGER I/O in
//! [`aiger`].
//!
//! Representation: nodes are numbered 0..n, node 0 is constant FALSE.
//! A *literal* is `node_id << 1 | complement`. AND nodes are created in
//! topological order (fanins always precede), so iteration over node ids is
//! a topological traversal — every downstream pass relies on this.

pub mod adders;
pub mod aiger;
pub mod booth;
pub mod mult;
pub mod sim;
pub mod wallace;

use std::collections::HashMap;

/// A literal: AIG node id with a complement bit in the LSB.
pub type Lit = u32;

/// Constant false / true literals (node 0).
pub const LIT_FALSE: Lit = 0;
pub const LIT_TRUE: Lit = 1;

#[inline]
pub fn lit(var: u32, compl: bool) -> Lit {
    (var << 1) | compl as u32
}
#[inline]
pub fn lit_var(l: Lit) -> u32 {
    l >> 1
}
#[inline]
pub fn lit_compl(l: Lit) -> bool {
    l & 1 != 0
}
#[inline]
pub fn lit_not(l: Lit) -> Lit {
    l ^ 1
}

/// Node kinds stored per id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// Constant false (id 0 only).
    Const,
    /// Primary input with its PI index.
    Pi(u32),
    /// Two-input AND; fanins are literals.
    And,
}

/// A named primary output driven by a literal.
#[derive(Clone, Debug)]
pub struct Output {
    pub name: String,
    pub lit: Lit,
}

/// Structurally-hashed And-Inverter Graph.
#[derive(Clone, Debug, Default)]
pub struct Aig {
    kinds: Vec<NodeKind>,
    fanin0: Vec<Lit>,
    fanin1: Vec<Lit>,
    pis: Vec<u32>,
    pub outputs: Vec<Output>,
    strash: HashMap<(Lit, Lit), u32>,
    pub name: String,
}

impl Aig {
    pub fn new(name: impl Into<String>) -> Self {
        let mut a = Aig { name: name.into(), ..Default::default() };
        a.kinds.push(NodeKind::Const);
        a.fanin0.push(LIT_FALSE);
        a.fanin1.push(LIT_FALSE);
        a
    }

    /// Number of nodes (const + PIs + ANDs).
    pub fn num_nodes(&self) -> usize {
        self.kinds.len()
    }

    pub fn num_pis(&self) -> usize {
        self.pis.len()
    }

    pub fn num_ands(&self) -> usize {
        self.kinds.len() - 1 - self.pis.len()
    }

    pub fn num_outputs(&self) -> usize {
        self.outputs.len()
    }

    pub fn kind(&self, id: u32) -> NodeKind {
        self.kinds[id as usize]
    }

    pub fn is_and(&self, id: u32) -> bool {
        matches!(self.kinds[id as usize], NodeKind::And)
    }

    pub fn is_pi(&self, id: u32) -> bool {
        matches!(self.kinds[id as usize], NodeKind::Pi(_))
    }

    /// Fanin literals of an AND node.
    pub fn fanins(&self, id: u32) -> (Lit, Lit) {
        debug_assert!(self.is_and(id));
        (self.fanin0[id as usize], self.fanin1[id as usize])
    }

    /// All PI node ids in PI order.
    pub fn pi_ids(&self) -> &[u32] {
        &self.pis
    }

    /// Create a new primary input, returning its (positive) literal.
    pub fn pi(&mut self) -> Lit {
        let id = self.kinds.len() as u32;
        self.kinds.push(NodeKind::Pi(self.pis.len() as u32));
        self.fanin0.push(LIT_FALSE);
        self.fanin1.push(LIT_FALSE);
        self.pis.push(id);
        lit(id, false)
    }

    /// Create `n` primary inputs.
    pub fn pis_n(&mut self, n: usize) -> Vec<Lit> {
        (0..n).map(|_| self.pi()).collect()
    }

    /// Register a primary output.
    pub fn po(&mut self, name: impl Into<String>, l: Lit) {
        self.outputs.push(Output { name: name.into(), lit: l });
    }

    /// Structurally-hashed AND with constant/idempotence simplification —
    /// the same one-level rules ABC applies on construction.
    pub fn and(&mut self, a: Lit, b: Lit) -> Lit {
        // Normalize order for hashing.
        let (a, b) = if a <= b { (a, b) } else { (b, a) };
        // Trivial cases.
        if a == LIT_FALSE {
            return LIT_FALSE;
        }
        if a == LIT_TRUE {
            return b;
        }
        if a == b {
            return a;
        }
        if a == lit_not(b) {
            return LIT_FALSE;
        }
        if let Some(&id) = self.strash.get(&(a, b)) {
            return lit(id, false);
        }
        let id = self.kinds.len() as u32;
        self.kinds.push(NodeKind::And);
        self.fanin0.push(a);
        self.fanin1.push(b);
        self.strash.insert((a, b), id);
        lit(id, false)
    }

    pub fn not(&self, l: Lit) -> Lit {
        lit_not(l)
    }

    pub fn or(&mut self, a: Lit, b: Lit) -> Lit {
        let n = self.and(lit_not(a), lit_not(b));
        lit_not(n)
    }

    pub fn nand(&mut self, a: Lit, b: Lit) -> Lit {
        lit_not(self.and(a, b))
    }

    pub fn nor(&mut self, a: Lit, b: Lit) -> Lit {
        let o = self.or(a, b);
        lit_not(o)
    }

    /// XOR built the way ABC's strashed miters do: (a·¬b) + (¬a·b).
    pub fn xor(&mut self, a: Lit, b: Lit) -> Lit {
        let t0 = self.and(a, lit_not(b));
        let t1 = self.and(lit_not(a), b);
        self.or(t0, t1)
    }

    pub fn xnor(&mut self, a: Lit, b: Lit) -> Lit {
        lit_not(self.xor(a, b))
    }

    /// 3-input XOR (full-adder sum), sharing the inner xor.
    pub fn xor3(&mut self, a: Lit, b: Lit, c: Lit) -> Lit {
        let ab = self.xor(a, b);
        self.xor(ab, c)
    }

    /// 3-input majority (full-adder carry): ab + c(a⊕b) — the shape that
    /// shares the inner XOR with `xor3`, as FA synthesis produces.
    pub fn maj(&mut self, a: Lit, b: Lit, c: Lit) -> Lit {
        let ab = self.and(a, b);
        let axb = self.xor(a, b);
        let cx = self.and(c, axb);
        self.or(ab, cx)
    }

    /// Majority in its symmetric sum-of-products shape ab + ac + bc.
    pub fn maj_sop(&mut self, a: Lit, b: Lit, c: Lit) -> Lit {
        let ab = self.and(a, b);
        let ac = self.and(a, c);
        let bc = self.and(b, c);
        let o = self.or(ab, ac);
        self.or(o, bc)
    }

    /// If-then-else mux: s ? t : e.
    pub fn mux(&mut self, s: Lit, t: Lit, e: Lit) -> Lit {
        let st = self.and(s, t);
        let se = self.and(lit_not(s), e);
        self.or(st, se)
    }

    /// AND over a slice (balanced tree to keep depth logarithmic).
    pub fn and_many(&mut self, xs: &[Lit]) -> Lit {
        match xs.len() {
            0 => LIT_TRUE,
            1 => xs[0],
            _ => {
                let mid = xs.len() / 2;
                let l = self.and_many(&xs[..mid]);
                let r = self.and_many(&xs[mid..]);
                self.and(l, r)
            }
        }
    }

    pub fn or_many(&mut self, xs: &[Lit]) -> Lit {
        let inv: Vec<Lit> = xs.iter().map(|&l| lit_not(l)).collect();
        lit_not(self.and_many(&inv))
    }

    /// Drop the structural-hashing table. It exists only to dedupe
    /// during construction and costs far more per AND than the fanin
    /// columns; finished circuits headed into streaming ingestion
    /// ([`crate::features::AigSource`]) shed it so the resident producer
    /// is just kinds + fanins. Further `and()` calls on this AIG will
    /// stop deduplicating structurally (they still simplify constants).
    pub fn clear_strash(&mut self) {
        self.strash = HashMap::new();
    }

    /// Total number of edges in the EDA-graph view: 2 per AND + 1 per PO.
    pub fn num_graph_edges(&self) -> usize {
        2 * self.num_ands() + self.num_outputs()
    }

    /// Check structural invariants (fanins precede, literals in range).
    pub fn check(&self) -> anyhow::Result<()> {
        for id in 0..self.kinds.len() as u32 {
            if self.is_and(id) {
                let (f0, f1) = self.fanins(id);
                anyhow::ensure!(lit_var(f0) < id, "fanin0 of {id} not topological");
                anyhow::ensure!(lit_var(f1) < id, "fanin1 of {id} not topological");
            }
        }
        for o in &self.outputs {
            anyhow::ensure!(
                (lit_var(o.lit) as usize) < self.kinds.len(),
                "output {} literal out of range",
                o.name
            );
        }
        Ok(())
    }

    /// Fanout counts per node in the EDA-graph view (AND fanins + PO edges).
    pub fn fanout_counts(&self) -> Vec<u32> {
        let mut fo = vec![0u32; self.num_nodes()];
        for id in 0..self.num_nodes() as u32 {
            if self.is_and(id) {
                let (f0, f1) = self.fanins(id);
                fo[lit_var(f0) as usize] += 1;
                fo[lit_var(f1) as usize] += 1;
            }
        }
        for o in &self.outputs {
            fo[lit_var(o.lit) as usize] += 1;
        }
        fo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_simplifications() {
        let mut g = Aig::new("t");
        let a = g.pi();
        assert_eq!(g.and(a, LIT_FALSE), LIT_FALSE);
        assert_eq!(g.and(LIT_TRUE, a), a);
        assert_eq!(g.and(a, a), a);
        assert_eq!(g.and(a, lit_not(a)), LIT_FALSE);
    }

    #[test]
    fn structural_hashing_dedups() {
        let mut g = Aig::new("t");
        let a = g.pi();
        let b = g.pi();
        let x = g.and(a, b);
        let y = g.and(b, a);
        assert_eq!(x, y);
        assert_eq!(g.num_ands(), 1);
    }

    #[test]
    fn xor_truth_table() {
        let mut g = Aig::new("t");
        let a = g.pi();
        let b = g.pi();
        let x = g.xor(a, b);
        g.po("x", x);
        for (va, vb) in [(false, false), (false, true), (true, false), (true, true)] {
            let out = sim::eval_bool(&g, &[va, vb]);
            assert_eq!(out[0], va ^ vb, "a={va} b={vb}");
        }
    }

    #[test]
    fn maj_and_mux_truth_tables() {
        let mut g = Aig::new("t");
        let a = g.pi();
        let b = g.pi();
        let c = g.pi();
        let m = g.maj(a, b, c);
        let ms = g.maj_sop(a, b, c);
        let x3 = g.xor3(a, b, c);
        let mx = g.mux(a, b, c);
        g.po("maj", m);
        g.po("maj_sop", ms);
        g.po("xor3", x3);
        g.po("mux", mx);
        for v in 0..8u32 {
            let (va, vb, vc) = (v & 1 != 0, v & 2 != 0, v & 4 != 0);
            let out = sim::eval_bool(&g, &[va, vb, vc]);
            let maj = (va & vb) | (va & vc) | (vb & vc);
            assert_eq!(out[0], maj);
            assert_eq!(out[1], maj);
            assert_eq!(out[2], va ^ vb ^ vc);
            assert_eq!(out[3], if va { vb } else { vc });
        }
    }

    #[test]
    fn and_or_many() {
        let mut g = Aig::new("t");
        let xs: Vec<Lit> = (0..5).map(|_| g.pi()).collect();
        let all = g.and_many(&xs);
        let any = g.or_many(&xs);
        g.po("all", all);
        g.po("any", any);
        for v in 0..32u32 {
            let ins: Vec<bool> = (0..5).map(|i| v & (1 << i) != 0).collect();
            let out = sim::eval_bool(&g, &ins);
            assert_eq!(out[0], ins.iter().all(|&x| x));
            assert_eq!(out[1], ins.iter().any(|&x| x));
        }
    }

    #[test]
    fn invariants_hold() {
        let mut g = Aig::new("t");
        let a = g.pi();
        let b = g.pi();
        let c = g.xor(a, b);
        g.po("c", c);
        g.check().unwrap();
        assert_eq!(g.num_graph_edges(), 2 * g.num_ands() + 1);
    }
}

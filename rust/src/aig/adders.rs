//! Adder building blocks: half/full adders, ripple-carry chains, and
//! carry-save reduction — the arithmetic substrate all multiplier
//! generators share.

use super::{Aig, Lit, LIT_FALSE};

/// Half adder: returns (sum, carry).
pub fn half_adder(g: &mut Aig, a: Lit, b: Lit) -> (Lit, Lit) {
    let s = g.xor(a, b);
    let c = g.and(a, b);
    (s, c)
}

/// Full adder: returns (sum, carry). Shares the inner a⊕b between sum and
/// carry, the canonical FA shape that the XOR3/MAJ labeler recognizes.
pub fn full_adder(g: &mut Aig, a: Lit, b: Lit, c: Lit) -> (Lit, Lit) {
    let s = g.xor3(a, b, c);
    let co = g.maj(a, b, c);
    (s, co)
}

/// Ripple-carry adder over equal-width operands with carry-in.
/// Returns `width+1` sum bits (last = carry-out).
pub fn ripple_adder(g: &mut Aig, a: &[Lit], b: &[Lit], mut cin: Lit) -> Vec<Lit> {
    assert_eq!(a.len(), b.len());
    let mut out = Vec::with_capacity(a.len() + 1);
    for i in 0..a.len() {
        let (s, c) = full_adder(g, a[i], b[i], cin);
        out.push(s);
        cin = c;
    }
    out.push(cin);
    out
}

/// Carry-save (3:2) compression of three equal-width rows into
/// (sums, carries) where carries are already shifted left by one
/// (i.e. `carries[0]` corresponds to bit position 1).
pub fn carry_save_row(g: &mut Aig, a: &[Lit], b: &[Lit], c: &[Lit]) -> (Vec<Lit>, Vec<Lit>) {
    assert!(a.len() == b.len() && b.len() == c.len());
    let mut sums = Vec::with_capacity(a.len());
    let mut carries = Vec::with_capacity(a.len());
    for i in 0..a.len() {
        let (s, co) = full_adder(g, a[i], b[i], c[i]);
        sums.push(s);
        carries.push(co);
    }
    (sums, carries)
}

/// Pad a bit-vector to `width` with constant-false literals.
pub fn zero_extend(bits: &[Lit], width: usize) -> Vec<Lit> {
    let mut out = bits.to_vec();
    while out.len() < width {
        out.push(LIT_FALSE);
    }
    out
}

/// Shift a bit-vector left by `k` (LSB-first), appending zeros at the bottom.
pub fn shift_left(bits: &[Lit], k: usize) -> Vec<Lit> {
    let mut out = vec![LIT_FALSE; k];
    out.extend_from_slice(bits);
    out
}

/// Standalone n-bit ripple-carry adder circuit (a[0..n], b[0..n] →
/// s[0..n+1]) — the adder-family workload for ingestion and training
/// experiments that want FA chains without a multiplier around them.
pub fn ripple_adder_circuit(n: usize) -> Aig {
    assert!(n >= 1);
    let mut g = Aig::new(format!("ripple_add_{n}"));
    let a = g.pis_n(n);
    let b = g.pis_n(n);
    let sum = ripple_adder(&mut g, &a, &b, LIT_FALSE);
    for (i, &s) in sum.iter().enumerate() {
        g.po(format!("s{i}"), s);
    }
    g
}

/// Streaming frontend: the ripple-carry adder as a chunked
/// [`crate::graph::GraphSource`].
pub fn ripple_source(n: usize, chunk: usize) -> crate::features::AigSource {
    crate::features::AigSource::new(ripple_adder_circuit(n), chunk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aig::sim::eval_bool;
    use crate::aig::Aig;

    #[test]
    fn full_adder_exhaustive() {
        let mut g = Aig::new("fa");
        let a = g.pi();
        let b = g.pi();
        let c = g.pi();
        let (s, co) = full_adder(&mut g, a, b, c);
        g.po("s", s);
        g.po("co", co);
        for v in 0..8u32 {
            let ins: Vec<bool> = (0..3).map(|i| v & (1 << i) != 0).collect();
            let out = eval_bool(&g, &ins);
            let total = ins.iter().filter(|&&x| x).count();
            assert_eq!(out[0], total % 2 == 1);
            assert_eq!(out[1], total >= 2);
        }
    }

    #[test]
    fn ripple_adder_exhaustive_4bit() {
        let mut g = Aig::new("rca");
        let a: Vec<Lit> = (0..4).map(|_| g.pi()).collect();
        let b: Vec<Lit> = (0..4).map(|_| g.pi()).collect();
        let sum = ripple_adder(&mut g, &a, &b, LIT_FALSE);
        for (i, &s) in sum.iter().enumerate() {
            g.po(format!("s{i}"), s);
        }
        for va in 0..16u32 {
            for vb in 0..16u32 {
                let mut ins = Vec::new();
                for i in 0..4 {
                    ins.push(va & (1 << i) != 0);
                }
                for i in 0..4 {
                    ins.push(vb & (1 << i) != 0);
                }
                let out = eval_bool(&g, &ins);
                let got: u32 = out
                    .iter()
                    .enumerate()
                    .map(|(i, &b)| (b as u32) << i)
                    .sum();
                assert_eq!(got, va + vb, "{va}+{vb}");
            }
        }
    }

    #[test]
    fn carry_save_preserves_sum() {
        let mut g = Aig::new("csa");
        let rows: Vec<Vec<Lit>> = (0..3).map(|_| (0..3).map(|_| g.pi()).collect()).collect();
        let (s, c) = carry_save_row(&mut g, &rows[0], &rows[1], &rows[2]);
        for (i, &x) in s.iter().enumerate() {
            g.po(format!("s{i}"), x);
        }
        for (i, &x) in c.iter().enumerate() {
            g.po(format!("c{i}"), x);
        }
        for v in 0..512u32 {
            let ins: Vec<bool> = (0..9).map(|i| v & (1 << i) != 0).collect();
            let out = eval_bool(&g, &ins);
            let val = |bits: &[bool]| -> u32 {
                bits.iter().enumerate().map(|(i, &b)| (b as u32) << i).sum()
            };
            let (r0, r1, r2) = (v & 7, (v >> 3) & 7, (v >> 6) & 7);
            let sums = val(&out[0..3]);
            let carries = val(&out[3..6]) << 1;
            assert_eq!(sums + carries, r0 + r1 + r2);
        }
    }
}

//! Radix-4 Booth multiplier generator — the paper's "complex" dataset
//! (Fig 6c, Fig 8c, Fig 9 Booth columns).
//!
//! Unsigned n×n multiplication via modified Booth encoding: overlapping
//! triplets of the multiplicand select digits in {-2,-1,0,1,2}; partial
//! products are formed with select/negate logic, sign-extended, and summed
//! with the correction bits through a carry-save tree plus a final ripple
//! adder. The resulting AIG is structurally much more irregular than the
//! CSA array (negation XOR rows, correction injections), which is exactly
//! why the paper uses it to stress classification accuracy.

use super::adders::{full_adder, half_adder, ripple_adder};
use super::{lit_not, Aig, Lit, LIT_FALSE};

/// Generate an n×n unsigned radix-4 Booth multiplier.
/// PIs: a[0..n] then b[0..n] (LSB first); POs m[0..2n].
pub fn booth_multiplier(n: usize) -> Aig {
    assert!(n >= 1);
    let mut g = Aig::new(format!("booth_mult_{n}"));
    let a = g.pis_n(n);
    let b = g.pis_n(n);
    let m = booth_multiplier_into(&mut g, &a, &b);
    for (i, &bit) in m.iter().enumerate() {
        g.po(format!("m{i}"), bit);
    }
    g
}

/// Streaming frontend: the radix-4 Booth multiplier as a chunked
/// [`crate::graph::GraphSource`].
pub fn booth_source(n: usize, chunk: usize) -> crate::features::AigSource {
    crate::features::AigSource::new(booth_multiplier(n), chunk)
}

/// Build booth multiplier logic; returns 2n product bits.
pub fn booth_multiplier_into(g: &mut Aig, a: &[Lit], b: &[Lit]) -> Vec<Lit> {
    let n = a.len();
    assert_eq!(n, b.len());
    let w = 2 * n;
    if n == 1 {
        let p = g.and(a[0], b[0]);
        return vec![p, LIT_FALSE];
    }

    // Booth digits from triplets (b[2k+1], b[2k], b[2k-1]), b[-1]=0,
    // b[j>=n]=0. K digits cover the unsigned operand.
    let ndigits = n.div_ceil(2) + 1;
    let bit = |g: &Aig, j: i64| -> Lit {
        let _ = g;
        if j < 0 || j >= n as i64 {
            LIT_FALSE
        } else {
            b[j as usize]
        }
    };

    // Rows to sum: each row is a (position, literal) sparse vector.
    let mut rows: Vec<Vec<(usize, Lit)>> = Vec::new();

    for k in 0..ndigits {
        let j = 2 * k as i64;
        let b_m1 = bit(g, j - 1);
        let b_0 = bit(g, j);
        let b_p1 = bit(g, j + 1);

        // Encoder: digit = -2*b_p1 + b_0 + b_m1.
        // one  = b_0 XOR b_m1              (|d| == 1)
        // two  = (b_p1 & !b_0 & !b_m1) | (!b_p1 & b_0 & b_m1)   (|d| == 2)
        // neg  = b_p1 & !(b_0 & b_m1)      (d < 0)
        let one = g.xor(b_0, b_m1);
        let t_both0 = g.nor(b_0, b_m1);
        let t_both1 = g.and(b_0, b_m1);
        let two_neg = g.and(b_p1, t_both0);
        let two_pos = g.and(lit_not(b_p1), t_both1);
        let two = g.or(two_neg, two_pos);
        let neg = g.and(b_p1, lit_not(t_both1));

        // Raw magnitude bits: mag[j] = one·a[j] | two·a[j-1], j = 0..n
        // (one/two are mutually exclusive, so OR is exact).
        let base = 2 * k;
        if base >= w {
            break;
        }
        let mut row: Vec<(usize, Lit)> = Vec::new();
        for jj in 0..=n {
            let pos = base + jj;
            if pos >= w {
                break;
            }
            let a_j = if jj < n { a[jj] } else { LIT_FALSE };
            let a_jm1 = if jj >= 1 { a[jj - 1] } else { LIT_FALSE };
            let m1 = g.and(one, a_j);
            let m2 = g.and(two, a_jm1);
            let mag = g.or(m1, m2);
            // Conditional negation: bit ⊕ neg; sign extension beyond n
            // follows as `neg` (handled below).
            let v = g.xor(mag, neg);
            row.push((pos, v));
        }
        // Sign extension: positions base+n+1 .. w-1 all equal `neg`.
        for pos in (base + n + 1)..w {
            row.push((pos, neg));
        }
        // Two's complement correction: +neg at position `base`.
        row.push((base, neg));
        rows.push(row);
    }

    reduce_rows(g, rows, w)
}

/// Column-wise carry-save reduction of sparse rows, then final ripple merge.
/// This is a Dadda-style reducer shared by booth and wallace generators.
pub fn reduce_rows(g: &mut Aig, rows: Vec<Vec<(usize, Lit)>>, w: usize) -> Vec<Lit> {
    // Bucket literals per column.
    let mut cols: Vec<Vec<Lit>> = vec![Vec::new(); w];
    for row in rows {
        for (pos, l) in row {
            if pos < w && l != LIT_FALSE {
                cols[pos].push(l);
            }
        }
    }
    // Compress until every column has ≤ 2 entries.
    loop {
        let maxh = cols.iter().map(|c| c.len()).max().unwrap_or(0);
        if maxh <= 2 {
            break;
        }
        let mut next: Vec<Vec<Lit>> = vec![Vec::new(); w];
        for pos in 0..w {
            let col = &cols[pos];
            let mut i = 0;
            while col.len() - i >= 3 {
                let (s, c) = full_adder(g, col[i], col[i + 1], col[i + 2]);
                next[pos].push(s);
                if pos + 1 < w {
                    next[pos + 1].push(c);
                }
                i += 3;
            }
            if col.len() - i == 2 {
                let (s, c) = half_adder(g, col[i], col[i + 1]);
                next[pos].push(s);
                if pos + 1 < w {
                    next[pos + 1].push(c);
                }
            } else if col.len() - i == 1 {
                next[pos].push(col[i]);
            }
        }
        cols = next;
    }
    // Final two rows → ripple adder.
    let mut ra = vec![LIT_FALSE; w];
    let mut rb = vec![LIT_FALSE; w];
    for pos in 0..w {
        if !cols[pos].is_empty() {
            ra[pos] = cols[pos][0];
        }
        if cols[pos].len() > 1 {
            rb[pos] = cols[pos][1];
        }
    }
    let merged = ripple_adder(g, &ra, &rb, LIT_FALSE);
    merged[..w].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aig::sim::{eval_bool, eval_u64, random_patterns};
    use crate::util::rng::Rng;

    #[test]
    fn exhaustive_small_widths() {
        for n in 1..=5usize {
            let g = booth_multiplier(n);
            g.check().unwrap();
            for va in 0..(1u32 << n) {
                for vb in 0..(1u32 << n) {
                    let mut ins = Vec::new();
                    for i in 0..n {
                        ins.push(va & (1 << i) != 0);
                    }
                    for i in 0..n {
                        ins.push(vb & (1 << i) != 0);
                    }
                    let out = eval_bool(&g, &ins);
                    let got: u64 = out
                        .iter()
                        .enumerate()
                        .map(|(i, &b)| (b as u64) << i)
                        .sum();
                    assert_eq!(got, (va as u64) * (vb as u64), "n={n} {va}*{vb}");
                }
            }
        }
    }

    #[test]
    fn random_medium_widths() {
        for n in [8usize, 13, 16, 32, 63] {
            let g = booth_multiplier(n);
            g.check().unwrap();
            let mut rng = Rng::new(7 + n as u64);
            let ins = random_patterns(2 * n, &mut rng);
            let outs = eval_u64(&g, &ins);
            for pat in 0..64 {
                let mut a = 0u128;
                let mut b = 0u128;
                for i in 0..n {
                    a |= (((ins[i] >> pat) & 1) as u128) << i;
                    b |= (((ins[n + i] >> pat) & 1) as u128) << i;
                }
                let mut m = 0u128;
                for (i, &wd) in outs.iter().enumerate() {
                    m |= (((wd >> pat) & 1) as u128) << i;
                }
                assert_eq!(m, a * b, "n={n} a={a} b={b}");
            }
        }
    }

    #[test]
    fn booth_is_more_irregular_than_csa() {
        // The booth AIG should differ structurally from the CSA one:
        // compare XOR-ish density proxies via node counts.
        let b = booth_multiplier(16);
        let c = crate::aig::mult::csa_multiplier(16);
        assert_ne!(b.num_ands(), c.num_ands());
    }
}

//! Wallace-tree multiplier generator — an extra dataset family used by the
//! ablation benches (not in the paper's evaluation, but exercised by the
//! harness to show GROOT generalizes across reduction-tree topologies).

use super::booth::reduce_rows;
use super::{Aig, Lit, LIT_FALSE};

/// Streaming frontend: the Wallace-tree multiplier as a chunked
/// [`crate::graph::GraphSource`].
pub fn wallace_source(n: usize, chunk: usize) -> crate::features::AigSource {
    crate::features::AigSource::new(wallace_multiplier(n), chunk)
}

/// Generate an n×n unsigned Wallace-tree multiplier.
/// PIs: a[0..n] then b[0..n]; POs m[0..2n].
pub fn wallace_multiplier(n: usize) -> Aig {
    assert!(n >= 1);
    let mut g = Aig::new(format!("wallace_mult_{n}"));
    let a = g.pis_n(n);
    let b = g.pis_n(n);
    let w = 2 * n;
    if n == 1 {
        let p = g.and(a[0], b[0]);
        g.po("m0", p);
        g.po("m1", LIT_FALSE);
        return g;
    }
    // All partial products as sparse rows, reduced with the shared
    // column-wise 3:2 compressor tree.
    let mut rows: Vec<Vec<(usize, Lit)>> = Vec::new();
    for (i, &bi) in b.iter().enumerate() {
        let row = a
            .iter()
            .enumerate()
            .map(|(j, &aj)| {
                let p = g.and(aj, bi);
                (i + j, p)
            })
            .collect();
        rows.push(row);
    }
    let m = reduce_rows(&mut g, rows, w);
    for (i, &bit) in m.iter().enumerate() {
        g.po(format!("m{i}"), bit);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aig::sim::eval_bool;

    #[test]
    fn exhaustive_small_widths() {
        for n in 1..=4usize {
            let g = wallace_multiplier(n);
            g.check().unwrap();
            for va in 0..(1u32 << n) {
                for vb in 0..(1u32 << n) {
                    let mut ins = Vec::new();
                    for i in 0..n {
                        ins.push(va & (1 << i) != 0);
                    }
                    for i in 0..n {
                        ins.push(vb & (1 << i) != 0);
                    }
                    let out = eval_bool(&g, &ins);
                    let got: u64 = out
                        .iter()
                        .enumerate()
                        .map(|(i, &b)| (b as u64) << i)
                        .sum();
                    assert_eq!(got, (va as u64) * (vb as u64), "n={n} {va}*{vb}");
                }
            }
        }
    }

    #[test]
    fn shallower_than_array() {
        // Wallace trees have logarithmic reduction depth; just check the
        // generator builds and is in the same node-count ballpark as CSA.
        let w = wallace_multiplier(16);
        let c = crate::aig::mult::csa_multiplier(16);
        let ratio = w.num_ands() as f64 / c.num_ands() as f64;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }
}

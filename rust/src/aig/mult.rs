//! Carry-save array (CSA) multiplier generator — the paper's primary
//! dataset family ("CSA multiplier", Figs 1/6/8/10, Tab II).
//!
//! Classic n×n array: AND partial products, rows reduced in carry-save form
//! through a full-adder array, final vector-merge via a ripple-carry adder.
//! Matches the structure ABC's `gen -m` / GAMORA's CSA benchmarks exhibit:
//! O(n²) AND gates with the FA XOR3/MAJ pairs the verifier hunts for.

use super::adders::{full_adder, half_adder, ripple_adder};
use super::{Aig, Lit, LIT_FALSE};

/// Generate an n×n unsigned CSA array multiplier. PIs are ordered
/// a[0..n] then b[0..n] (LSB first); POs are m[0..2n] (LSB first).
pub fn csa_multiplier(n: usize) -> Aig {
    assert!(n >= 1);
    let mut g = Aig::new(format!("csa_mult_{n}"));
    let a = g.pis_n(n);
    let b = g.pis_n(n);
    let m = csa_multiplier_into(&mut g, &a, &b);
    for (i, &bit) in m.iter().enumerate() {
        g.po(format!("m{i}"), bit);
    }
    g
}

/// Streaming frontend: the n×n CSA multiplier as a chunked
/// [`crate::graph::GraphSource`] — the ingestion path that never builds a
/// dense-feature `EdaGraph`.
pub fn csa_source(n: usize, chunk: usize) -> crate::features::AigSource {
    crate::features::AigSource::new(csa_multiplier(n), chunk)
}

/// Build the multiplier logic into an existing AIG; returns 2n product bits.
pub fn csa_multiplier_into(g: &mut Aig, a: &[Lit], b: &[Lit]) -> Vec<Lit> {
    let n = a.len();
    assert_eq!(n, b.len());
    if n == 1 {
        let p = g.and(a[0], b[0]);
        return vec![p, LIT_FALSE];
    }

    // Partial products pp[i][j] = a[j] & b[i], weight i+j.
    let mut pp: Vec<Vec<Lit>> = Vec::with_capacity(n);
    for bi in b.iter() {
        pp.push(a.iter().map(|&aj| g.and(aj, *bi)).collect());
    }

    // Row-by-row carry-save accumulation (the "array" in array multiplier):
    // carry chain of row i is saved and injected into row i+1.
    let mut product = vec![LIT_FALSE; 2 * n];
    // running sum/carry vectors, aligned to weights [i .. i+n)
    let mut sum: Vec<Lit> = pp[0].clone(); // weights 0..n
    let mut carry: Vec<Lit> = vec![LIT_FALSE; n]; // carries into next row
    product[0] = sum[0];

    for i in 1..n {
        let row = &pp[i]; // weights i..i+n
        let mut new_sum = vec![LIT_FALSE; n];
        let mut new_carry = vec![LIT_FALSE; n];
        for j in 0..n {
            // at weight i+j: row bit pp[i][j], previous sum bit (weight
            // i+j ⇒ sum index j+1 of the previous alignment), previous carry.
            let prev_sum = if j + 1 < n { sum[j + 1] } else { LIT_FALSE };
            let prev_carry = carry[j];
            let (s, c) = add3(g, row[j], prev_sum, prev_carry);
            new_sum[j] = s;
            new_carry[j] = c;
        }
        product[i] = new_sum[0];
        sum = new_sum;
        carry = new_carry;
    }

    // Vector-merge: sum[1..] + carry[..] at weights n..2n-1.
    let hi_a: Vec<Lit> = (1..n).map(|j| sum[j]).chain(std::iter::once(LIT_FALSE)).collect();
    let hi_b: Vec<Lit> = carry.to_vec();
    let merged = ripple_adder(g, &hi_a, &hi_b, LIT_FALSE);
    for (k, &bit) in merged.iter().take(n).enumerate() {
        product[n + k] = bit;
    }
    product
}

/// 3:2 compress with degenerate-input simplification (uses HA when one
/// input is constant false, as a real array generator does).
fn add3(g: &mut Aig, a: Lit, b: Lit, c: Lit) -> (Lit, Lit) {
    match (a == LIT_FALSE, b == LIT_FALSE, c == LIT_FALSE) {
        (true, true, true) => (LIT_FALSE, LIT_FALSE),
        (false, true, true) => (a, LIT_FALSE),
        (true, false, true) => (b, LIT_FALSE),
        (true, true, false) => (c, LIT_FALSE),
        (false, false, true) => half_adder(g, a, b),
        (false, true, false) => half_adder(g, a, c),
        (true, false, false) => half_adder(g, b, c),
        (false, false, false) => full_adder(g, a, b, c),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aig::sim::{eval_u64, random_patterns};
    use crate::util::rng::Rng;

    /// Check an n-bit multiplier AIG against u128 multiplication over 64
    /// random patterns (n ≤ 63).
    pub fn check_multiplier_u128(g: &Aig, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let ins = random_patterns(2 * n, &mut rng);
        let outs = eval_u64(g, &ins);
        assert_eq!(outs.len(), 2 * n);
        for pat in 0..64 {
            let mut a = 0u128;
            let mut b = 0u128;
            for i in 0..n {
                a |= (((ins[i] >> pat) & 1) as u128) << i;
                b |= (((ins[n + i] >> pat) & 1) as u128) << i;
            }
            let mut m = 0u128;
            for (i, &w) in outs.iter().enumerate() {
                m |= (((w >> pat) & 1) as u128) << i;
            }
            assert_eq!(m, a * b, "n={n} a={a} b={b}");
        }
    }

    #[test]
    fn exhaustive_small_widths() {
        for n in 1..=4usize {
            let g = csa_multiplier(n);
            g.check().unwrap();
            for va in 0..(1u32 << n) {
                for vb in 0..(1u32 << n) {
                    let mut ins = Vec::new();
                    for i in 0..n {
                        ins.push(va & (1 << i) != 0);
                    }
                    for i in 0..n {
                        ins.push(vb & (1 << i) != 0);
                    }
                    let out = crate::aig::sim::eval_bool(&g, &ins);
                    let got: u64 = out
                        .iter()
                        .enumerate()
                        .map(|(i, &b)| (b as u64) << i)
                        .sum();
                    assert_eq!(got, (va as u64) * (vb as u64), "n={n} {va}*{vb}");
                }
            }
        }
    }

    #[test]
    fn random_medium_widths() {
        for n in [8usize, 16, 24, 32, 48, 63] {
            let g = csa_multiplier(n);
            g.check().unwrap();
            check_multiplier_u128(&g, n, 42 + n as u64);
        }
    }

    #[test]
    fn node_count_is_quadratic() {
        let g8 = csa_multiplier(8);
        let g16 = csa_multiplier(16);
        let r = g16.num_ands() as f64 / g8.num_ands() as f64;
        assert!((3.0..5.0).contains(&r), "scaling ratio {r}");
    }
}

//! Bit-parallel AIG simulation.
//!
//! 64 input patterns are evaluated per pass using one `u64` word per node.
//! Used by tests to check generator correctness against software big-integer
//! multiplication, and by the labeler's sanity oracles.

use super::{lit_compl, lit_var, Aig, NodeKind};

/// Evaluate all outputs for a single boolean input assignment.
pub fn eval_bool(aig: &Aig, inputs: &[bool]) -> Vec<bool> {
    let words: Vec<u64> = inputs.iter().map(|&b| if b { !0u64 } else { 0 }).collect();
    let out = eval_u64(aig, &words);
    out.iter().map(|&w| w & 1 != 0).collect()
}

/// Evaluate all outputs over 64 parallel patterns; `inputs[i]` holds the
/// 64 values of PI i (bit k = pattern k).
pub fn eval_u64(aig: &Aig, inputs: &[u64]) -> Vec<u64> {
    assert_eq!(inputs.len(), aig.num_pis(), "input width mismatch");
    let vals = node_values_u64(aig, inputs);
    aig.outputs
        .iter()
        .map(|o| {
            let v = vals[lit_var(o.lit) as usize];
            if lit_compl(o.lit) {
                !v
            } else {
                v
            }
        })
        .collect()
}

/// Per-node simulation values over 64 parallel patterns.
pub fn node_values_u64(aig: &Aig, inputs: &[u64]) -> Vec<u64> {
    let n = aig.num_nodes();
    let mut vals = vec![0u64; n];
    for id in 0..n as u32 {
        match aig.kind(id) {
            NodeKind::Const => vals[id as usize] = 0,
            NodeKind::Pi(k) => vals[id as usize] = inputs[k as usize],
            NodeKind::And => {
                let (f0, f1) = aig.fanins(id);
                let a = vals[lit_var(f0) as usize] ^ if lit_compl(f0) { !0 } else { 0 };
                let b = vals[lit_var(f1) as usize] ^ if lit_compl(f1) { !0 } else { 0 };
                vals[id as usize] = a & b;
            }
        }
    }
    vals
}

/// Interpret a slice of output values (LSB-first bit order) for pattern
/// `pat` (0..64) as an unsigned big integer, returned as u64 words.
pub fn outputs_as_words(out_bits: &[u64], pat: usize) -> Vec<u64> {
    let nbits = out_bits.len();
    let nwords = nbits.div_ceil(64);
    let mut words = vec![0u64; nwords.max(1)];
    for (i, &w) in out_bits.iter().enumerate() {
        if (w >> pat) & 1 != 0 {
            words[i / 64] |= 1u64 << (i % 64);
        }
    }
    words
}

/// Build 64 random input patterns for `n` PIs.
pub fn random_patterns(n: usize, rng: &mut crate::util::rng::Rng) -> Vec<u64> {
    (0..n).map(|_| rng.next_u64()).collect()
}

/// Extract PI values (LSB-first within the given range) for pattern `pat`
/// as u64 words — used to reconstruct the integer operands fed to a
/// multiplier under simulation.
pub fn inputs_as_words(inputs: &[u64], range: std::ops::Range<usize>, pat: usize) -> Vec<u64> {
    let nbits = range.len();
    let nwords = nbits.div_ceil(64);
    let mut words = vec![0u64; nwords.max(1)];
    for (k, i) in range.enumerate() {
        if (inputs[i] >> pat) & 1 != 0 {
            words[k / 64] |= 1u64 << (k % 64);
        }
    }
    words
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aig::Aig;

    #[test]
    fn parallel_sim_matches_bool_sim() {
        let mut g = Aig::new("t");
        let a = g.pi();
        let b = g.pi();
        let c = g.pi();
        let x = g.xor3(a, b, c);
        let m = g.maj(a, b, c);
        g.po("x", x);
        g.po("m", m);

        // 8 exhaustive patterns packed into one word.
        let mut ins = vec![0u64; 3];
        for v in 0..8u64 {
            for i in 0..3 {
                if v & (1 << i) != 0 {
                    ins[i] |= 1 << v;
                }
            }
        }
        let out = eval_u64(&g, &ins);
        for v in 0..8usize {
            let bools: Vec<bool> = (0..3).map(|i| v & (1 << i) != 0).collect();
            let expect = eval_bool(&g, &bools);
            assert_eq!((out[0] >> v) & 1 != 0, expect[0]);
            assert_eq!((out[1] >> v) & 1 != 0, expect[1]);
        }
    }

    #[test]
    fn words_roundtrip() {
        let bits = [0u64, !0u64, 0u64, !0u64]; // pattern-independent 0101
        let w = outputs_as_words(&bits, 17);
        assert_eq!(w, vec![0b1010]);
    }
}

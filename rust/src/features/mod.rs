//! EDA-graph construction with the paper's node features (§III-B).
//!
//! The AIG is turned into a directed graph whose nodes are {const, PIs,
//! AND gates, POs} — POs are materialized as their own nodes, unlike
//! GAMORA, which is one of GROOT's stated feature-engineering points.
//! Each node carries a 4-dim feature vector encoding (node type, input
//! edge polarities):
//!
//! | node | bits [t1 t0 pL pR] |
//! |------|--------------------|
//! | PI / const | 0 0 0 0 |
//! | AND, both inputs plain | 1 1 0 0 |
//! | AND, left inverted     | 1 1 1 0 |
//! | AND, right inverted    | 1 1 0 1 |
//! | AND, both inverted     | 1 1 1 1 |
//! | PO, plain driver       | 0 1 0 0 |
//! | PO, inverted driver    | 0 1 1 1 |
//!
//! Note: the paper's Fig. 3 encoding table is internally inconsistent
//! (its PO type code '0X' collides with PI '00' and its example vector
//! `0011` contradicts the prose); we use the unambiguous scheme above,
//! which carries the identical information content. The GAMORA-style
//! 3-feature encoding (no PI/PO distinction) is provided for the
//! feature-ablation experiments.

pub mod stream;

pub use stream::{AigSource, EdaGraphSource};

use crate::aig::{lit_compl, lit_var, Aig, NodeKind};
use crate::labels::{label_aig_nodes, NodeClass};

/// Feature dimensionality of the GROOT encoding.
pub const GROOT_FEATURE_DIM: usize = 4;

/// A verification-ready EDA graph: AIG nodes + PO nodes, directed edges
/// fanin→node, features and ground-truth labels per node.
#[derive(Clone, Debug)]
pub struct EdaGraph {
    pub name: String,
    /// Total graph nodes = aig nodes + num POs.
    pub num_nodes: usize,
    /// Number of underlying AIG nodes (PO graph nodes start at this index).
    pub num_aig_nodes: usize,
    /// Directed edges (src, dst): AND fanins and PO drivers.
    pub edges: Vec<(u32, u32)>,
    /// GROOT 4-dim features.
    pub features: Vec<[f32; GROOT_FEATURE_DIM]>,
    /// Ground-truth class per node.
    pub labels: Vec<NodeClass>,
}

impl EdaGraph {
    /// Build from an AIG with ground-truth labels from the cut matcher.
    pub fn from_aig(aig: &Aig) -> EdaGraph {
        let aig_labels = label_aig_nodes(aig);
        Self::from_aig_with_labels(aig, &aig_labels)
    }

    pub fn from_aig_with_labels(aig: &Aig, aig_labels: &[NodeClass]) -> EdaGraph {
        let n_aig = aig.num_nodes();
        let n_po = aig.num_outputs();
        let num_nodes = n_aig + n_po;
        let mut edges = Vec::with_capacity(2 * aig.num_ands() + n_po);
        let mut features = vec![[0.0f32; GROOT_FEATURE_DIM]; num_nodes];
        let mut labels = vec![NodeClass::Pi; num_nodes];

        for id in 0..n_aig as u32 {
            match aig.kind(id) {
                NodeKind::Const | NodeKind::Pi(_) => {
                    features[id as usize] = [0.0, 0.0, 0.0, 0.0];
                    labels[id as usize] = NodeClass::Pi;
                }
                NodeKind::And => {
                    let (f0, f1) = aig.fanins(id);
                    edges.push((lit_var(f0), id));
                    edges.push((lit_var(f1), id));
                    features[id as usize] = [
                        1.0,
                        1.0,
                        lit_compl(f0) as u8 as f32,
                        lit_compl(f1) as u8 as f32,
                    ];
                    labels[id as usize] = aig_labels[id as usize];
                }
            }
        }
        for (k, o) in aig.outputs.iter().enumerate() {
            let po_id = (n_aig + k) as u32;
            let drv = lit_var(o.lit);
            edges.push((drv, po_id));
            let inv = lit_compl(o.lit) as u8 as f32;
            features[po_id as usize] = [0.0, 1.0, inv, inv];
            labels[po_id as usize] = NodeClass::Po;
        }

        EdaGraph {
            name: aig.name.clone(),
            num_nodes,
            num_aig_nodes: n_aig,
            edges,
            features,
            labels,
        }
    }

    /// GAMORA-style 3-dim features: [is_internal, polL, polR] — drops the
    /// PI/PO distinction the paper adds. Used by the ablation harness.
    pub fn gamora_features(&self) -> Vec<[f32; 3]> {
        self.features
            .iter()
            .map(|f| {
                let internal = if f[0] == 1.0 && f[1] == 1.0 { 1.0 } else { 0.0 };
                [internal, f[2], f[3]]
            })
            .collect()
    }

    /// Labels as raw u8 (paper's numeric classes).
    pub fn labels_u8(&self) -> Vec<u8> {
        self.labels.iter().map(|&l| l as u8).collect()
    }

    /// The feature matrix as one flat row-major `&[f32]` — zero-copy:
    /// `Vec<[f32; 4]>` storage is already `num_nodes × 4` contiguous
    /// floats, so consumers that want a dense matrix (the eager pipeline,
    /// validation eval) reinterpret instead of duplicating 16 B/node.
    pub fn features_flat(&self) -> &[f32] {
        // SAFETY: `[f32; GROOT_FEATURE_DIM]` is exactly GROOT_FEATURE_DIM
        // consecutive f32s with f32 alignment and no padding, and the
        // element count cannot overflow isize (the rows are in memory).
        unsafe {
            std::slice::from_raw_parts(
                self.features.as_ptr().cast::<f32>(),
                self.features.len() * GROOT_FEATURE_DIM,
            )
        }
    }

    /// Heap bytes of this representation's content (feature rows, labels,
    /// edge tuples) — the legacy side of BENCH_memory.json.
    pub fn resident_bytes(&self) -> usize {
        self.features.len() * std::mem::size_of::<[f32; GROOT_FEATURE_DIM]>()
            + self.labels.len() * std::mem::size_of::<NodeClass>()
            + self.edges.len() * std::mem::size_of::<(u32, u32)>()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Graph replicated `batch` times (disjoint copies) — the paper's
    /// "batch size 16" workloads are 16 disjoint graph copies processed
    /// together.
    pub fn replicate(&self, batch: usize) -> EdaGraph {
        assert!(batch >= 1);
        if batch == 1 {
            return self.clone();
        }
        let n = self.num_nodes;
        let mut edges = Vec::with_capacity(self.edges.len() * batch);
        let mut features = Vec::with_capacity(n * batch);
        let mut labels = Vec::with_capacity(n * batch);
        for b in 0..batch {
            let off = (b * n) as u32;
            edges.extend(self.edges.iter().map(|&(s, d)| (s + off, d + off)));
            features.extend_from_slice(&self.features);
            labels.extend_from_slice(&self.labels);
        }
        EdaGraph {
            name: format!("{}_x{batch}", self.name),
            num_nodes: n * batch,
            num_aig_nodes: self.num_aig_nodes * batch, // per-copy layout preserved
            edges,
            features,
            labels,
        }
    }

    /// Batch replication with SHARED PI/const nodes: all copies read the
    /// same input nodes, so PI fanout scales with the batch — this is
    /// what creates the paper's high-degree "macro rows" (§IV: rows with
    /// degree ≥ 512 in batched workloads) that the HD kernel exists for.
    pub fn replicate_shared_inputs(&self, batch: usize) -> EdaGraph {
        assert!(batch >= 1);
        if batch == 1 {
            return self.clone();
        }
        // Input nodes = nodes with PI features (label 4 covers const too).
        let is_input: Vec<bool> = self
            .labels
            .iter()
            .map(|&l| l == crate::labels::NodeClass::Pi)
            .collect();
        let num_inputs = is_input.iter().filter(|&&b| b).count();
        // Map: input nodes keep one shared id; others replicate per copy.
        let mut shared_id = vec![0u32; self.num_nodes];
        let mut next = 0u32;
        for (u, &inp) in is_input.iter().enumerate() {
            if inp {
                shared_id[u] = next;
                next += 1;
            }
        }
        let per_copy = self.num_nodes - num_inputs;
        let mut local_id = vec![0u32; self.num_nodes];
        let mut k = 0u32;
        for (u, &inp) in is_input.iter().enumerate() {
            if !inp {
                local_id[u] = k;
                k += 1;
            }
        }
        let total = num_inputs + per_copy * batch;
        let map = |u: usize, copy: usize| -> u32 {
            if is_input[u] {
                shared_id[u]
            } else {
                (num_inputs + copy * per_copy) as u32 + local_id[u]
            }
        };
        let mut edges = Vec::with_capacity(self.edges.len() * batch);
        let mut features = vec![[0.0f32; GROOT_FEATURE_DIM]; total];
        let mut labels = vec![NodeClass::Pi; total];
        for copy in 0..batch {
            for &(s, d) in &self.edges {
                edges.push((map(s as usize, copy), map(d as usize, copy)));
            }
            for u in 0..self.num_nodes {
                let nu = map(u, copy) as usize;
                features[nu] = self.features[u];
                labels[nu] = self.labels[u];
            }
        }
        EdaGraph {
            name: format!("{}_shared_x{batch}", self.name),
            num_nodes: total,
            num_aig_nodes: total, // layout no longer AIG-prefixed
            edges,
            features,
            labels,
        }
    }

    /// Structural sanity checks. Checkpoint and AIGER ingestion make
    /// malformed graphs a real input, so beyond the column lengths this
    /// rejects an AIG-prefix overrun and dangling edge endpoints. Label
    /// range needs no check here — `NodeClass` is a closed 5-variant
    /// enum, so every value is in `0..NUM_CLASSES` by construction; the
    /// raw-`u8` label column of `CircuitGraph` is where out-of-range
    /// labels can actually occur, and its `check()` rejects them.
    pub fn check(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.num_aig_nodes <= self.num_nodes,
            "num_aig_nodes {} exceeds num_nodes {}",
            self.num_aig_nodes,
            self.num_nodes
        );
        anyhow::ensure!(
            self.features.len() == self.num_nodes,
            "feature rows {} != num_nodes {}",
            self.features.len(),
            self.num_nodes
        );
        anyhow::ensure!(
            self.labels.len() == self.num_nodes,
            "labels {} != num_nodes {}",
            self.labels.len(),
            self.num_nodes
        );
        for &(s, d) in &self.edges {
            anyhow::ensure!(
                (s as usize) < self.num_nodes && (d as usize) < self.num_nodes,
                "edge ({s}, {d}) out of range (num_nodes {})",
                self.num_nodes
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aig::mult::csa_multiplier;
    use crate::aig::Aig;

    #[test]
    fn two_bit_multiplier_graph_shape() {
        let g = csa_multiplier(2);
        let eg = EdaGraph::from_aig(&g);
        eg.check().unwrap();
        // nodes = const + 4 PIs + ANDs + 4 POs
        assert_eq!(eg.num_nodes, g.num_nodes() + 4);
        assert_eq!(eg.num_edges(), 2 * g.num_ands() + 4);
        // PO nodes are labeled 0 and carry the PO feature code.
        for k in 0..4 {
            let po = eg.num_aig_nodes + k;
            assert_eq!(eg.labels[po], NodeClass::Po);
            assert_eq!(eg.features[po][0], 0.0);
            assert_eq!(eg.features[po][1], 1.0);
        }
    }

    #[test]
    fn polarity_features_match_fanins() {
        let mut g = Aig::new("t");
        let a = g.pi();
        let b = g.pi();
        let x = g.and(a, crate::aig::lit_not(b));
        g.po("x", x);
        let eg = EdaGraph::from_aig(&g);
        let id = crate::aig::lit_var(x) as usize;
        // one inverted input → exactly one polarity bit set
        assert_eq!(eg.features[id][0..2], [1.0, 1.0]);
        assert_eq!(eg.features[id][2] + eg.features[id][3], 1.0);
    }

    #[test]
    fn gamora_features_drop_po_distinction() {
        let g = csa_multiplier(2);
        let eg = EdaGraph::from_aig(&g);
        let gf = eg.gamora_features();
        // PI and PO rows become identical under GAMORA encoding (both
        // non-internal, no polarity on PI; PO keeps polarity only).
        let pi_row = gf[1];
        assert_eq!(pi_row, [0.0, 0.0, 0.0]);
    }

    #[test]
    fn shared_input_batching_creates_macro_rows() {
        let g = csa_multiplier(8);
        let eg = EdaGraph::from_aig(&g);
        let b = eg.replicate_shared_inputs(16);
        b.check().unwrap();
        // PI degree scales ~16x: that's the HD macro-row population
        let csr = crate::graph::Csr::symmetric_from_edges(b.num_nodes, &b.edges);
        let base = crate::graph::Csr::symmetric_from_edges(eg.num_nodes, &eg.edges);
        let max_b = (0..csr.num_nodes()).map(|u| csr.degree(u)).max().unwrap();
        let max_1 = (0..base.num_nodes()).map(|u| base.degree(u)).max().unwrap();
        assert!(max_b >= 8 * max_1, "batched max degree {max_b} vs {max_1}");
        // node count: shared inputs counted once
        assert!(b.num_nodes < 16 * eg.num_nodes);
    }

    #[test]
    fn features_flat_is_zero_copy() {
        let eg = EdaGraph::from_aig(&csa_multiplier(3));
        let flat = eg.features_flat();
        assert_eq!(flat.len(), eg.num_nodes * GROOT_FEATURE_DIM);
        // same storage, not a copy
        assert!(std::ptr::eq(flat.as_ptr(), eg.features.as_ptr().cast::<f32>()));
        for u in 0..eg.num_nodes {
            assert_eq!(&flat[u * 4..u * 4 + 4], &eg.features[u]);
        }
    }

    #[test]
    fn check_rejects_malformed_graphs() {
        let good = EdaGraph::from_aig(&csa_multiplier(3));
        good.check().unwrap();

        let mut bad = good.clone();
        bad.num_aig_nodes = bad.num_nodes + 1;
        assert!(bad.check().is_err(), "aig prefix overrun must be rejected");

        let mut bad = good.clone();
        bad.edges.push((bad.num_nodes as u32, 0));
        assert!(bad.check().is_err(), "dangling edge must be rejected");

        let mut bad = good;
        bad.features.pop();
        assert!(bad.check().is_err(), "short feature column must be rejected");
    }

    #[test]
    fn replicate_makes_disjoint_copies() {
        let g = csa_multiplier(2);
        let eg = EdaGraph::from_aig(&g);
        let r = eg.replicate(3);
        r.check().unwrap();
        assert_eq!(r.num_nodes, 3 * eg.num_nodes);
        assert_eq!(r.num_edges(), 3 * eg.num_edges());
        // No edge crosses copies.
        let n = eg.num_nodes as u32;
        for &(s, d) in &r.edges {
            assert_eq!(s / n, d / n);
        }
    }
}

//! Streaming emitters for the feature layer: the [`GraphSource`]
//! implementations that feed [`CircuitGraph`] ingestion.
//!
//! * [`AigSource`] — chunked emission straight from an [`Aig`]: node
//!   descriptors are derived per chunk from (kind, fanin polarity), PO
//!   nodes are appended after the AIG prefix, and the strash table is
//!   dropped up front so the resident producer is just the fanin
//!   columns. This is the path every generator frontend
//!   (`aig::{adders, mult, booth, wallace}::*_source`) and the AIGER
//!   reader (`aig::aiger::source_from_aag`) return.
//! * [`EdaGraphSource`] — back-compat adapter over a legacy [`EdaGraph`]
//!   (owned or borrowed): feature rows are re-packed into descriptor
//!   bytes and the tuple edge list is re-grouped by destination once at
//!   construction.
//!
//! Both emit the same node order (and per-destination edge order) as
//! `EdaGraph::from_aig`, so the compact and legacy representations of a
//! circuit carry identical content — the parity the pipeline's
//! representation-independent fingerprint and the streaming-vs-eager
//! byte-identical-prediction tests rely on.

use super::EdaGraph;
use crate::aig::{lit_compl, lit_var, Aig, NodeKind};
use crate::graph::circuit::{pack_desc, KIND_AND, KIND_INPUT, KIND_PO};
use crate::graph::{CircuitGraph, GraphSource, NodeChunk};
use crate::labels::{label_aig_nodes, NodeClass};
use anyhow::Result;
use std::borrow::Borrow;

/// Pack one legacy 4-dim feature row back into a descriptor byte.
/// Rejects rows outside the documented encoding (see the table in
/// [`super`]) — malformed graphs must fail ingestion, not classify.
pub fn desc_from_feature_row(f: &[f32; 4]) -> Result<u8> {
    let bit = |x: f32| -> Result<bool> {
        if x == 0.0 {
            Ok(false)
        } else if x == 1.0 {
            Ok(true)
        } else {
            anyhow::bail!("feature value {x} is not a 0/1 bit")
        }
    };
    let (t1, t0, pl, pr) = (bit(f[0])?, bit(f[1])?, bit(f[2])?, bit(f[3])?);
    match (t1, t0) {
        (false, false) => {
            anyhow::ensure!(!pl && !pr, "PI row with polarity bits set");
            Ok(pack_desc(KIND_INPUT, false, false))
        }
        (true, true) => Ok(pack_desc(KIND_AND, pl, pr)),
        (false, true) => {
            anyhow::ensure!(pl == pr, "PO row with disagreeing polarity bits");
            Ok(pack_desc(KIND_PO, pl, pr))
        }
        (true, false) => anyhow::bail!("unrecognized node-type bits [1, 0]"),
    }
}

/// Chunked [`GraphSource`] over an AIG: emits the AIG nodes (in id
/// order) followed by one PO node per output — the exact layout
/// [`EdaGraph::from_aig`] builds, without ever holding dense features.
pub struct AigSource {
    aig: Aig,
    /// Ground-truth class per AIG node (PO graph nodes are labeled on
    /// emission).
    labels: Vec<NodeClass>,
    chunk: usize,
    cursor: usize,
}

impl AigSource {
    /// Label the AIG and prepare chunked emission. The strash table —
    /// construction-only state that can dwarf the fanin columns — is
    /// dropped immediately.
    pub fn new(mut aig: Aig, chunk: usize) -> AigSource {
        let labels = label_aig_nodes(&aig);
        aig.clear_strash();
        AigSource { aig, labels, chunk: chunk.max(1), cursor: 0 }
    }

    fn total_nodes(&self) -> usize {
        self.aig.num_nodes() + self.aig.num_outputs()
    }
}

impl GraphSource for AigSource {
    fn name(&self) -> &str {
        &self.aig.name
    }

    fn num_nodes_hint(&self) -> Option<usize> {
        Some(self.total_nodes())
    }

    fn aig_prefix(&self) -> Option<usize> {
        Some(self.aig.num_nodes())
    }

    fn next_chunk(&mut self) -> Result<Option<NodeChunk>> {
        let total = self.total_nodes();
        if self.cursor >= total {
            return Ok(None);
        }
        let n_aig = self.aig.num_nodes();
        let start = self.cursor;
        let take = self.chunk.min(total - start);
        let mut desc = Vec::with_capacity(take);
        let mut labels = Vec::with_capacity(take);
        let mut edges = Vec::with_capacity(2 * take);
        for id in start..start + take {
            if id < n_aig {
                match self.aig.kind(id as u32) {
                    NodeKind::Const | NodeKind::Pi(_) => {
                        desc.push(pack_desc(KIND_INPUT, false, false));
                        labels.push(NodeClass::Pi as u8);
                    }
                    NodeKind::And => {
                        let (f0, f1) = self.aig.fanins(id as u32);
                        edges.push((lit_var(f0), id as u32));
                        edges.push((lit_var(f1), id as u32));
                        desc.push(pack_desc(KIND_AND, lit_compl(f0), lit_compl(f1)));
                        labels.push(self.labels[id] as u8);
                    }
                }
            } else {
                let o = &self.aig.outputs[id - n_aig];
                edges.push((lit_var(o.lit), id as u32));
                let inv = lit_compl(o.lit);
                desc.push(pack_desc(KIND_PO, inv, inv));
                labels.push(NodeClass::Po as u8);
            }
        }
        self.cursor += take;
        Ok(Some(NodeChunk { start, desc, labels, edges }))
    }
}

/// Back-compat [`GraphSource`] over a legacy [`EdaGraph`] (owned for
/// `Box<dyn GraphSource>` pipelines, or borrowed via
/// [`EdaGraph::to_circuit`]): feature rows become descriptor bytes and
/// the tuple edge list is re-grouped by destination (stable, so graphs
/// whose edges are already destination-ordered — every AIG-built one —
/// stream out in their original edge order).
pub struct EdaGraphSource<G: Borrow<EdaGraph> = EdaGraph> {
    graph: G,
    /// Edges regrouped by destination: sources of `v` are
    /// `src[ptr[v] as usize..ptr[v + 1] as usize]`.
    ptr: Vec<u32>,
    src: Vec<u32>,
    chunk: usize,
    cursor: usize,
}

impl EdaGraphSource<EdaGraph> {
    pub fn new(graph: EdaGraph, chunk: usize) -> EdaGraphSource<EdaGraph> {
        Self::with_graph(graph, chunk)
    }
}

impl<'g> EdaGraphSource<&'g EdaGraph> {
    pub fn borrowed(graph: &'g EdaGraph, chunk: usize) -> EdaGraphSource<&'g EdaGraph> {
        Self::with_graph(graph, chunk)
    }
}

impl<G: Borrow<EdaGraph>> EdaGraphSource<G> {
    fn with_graph(graph: G, chunk: usize) -> EdaGraphSource<G> {
        let (ptr, src) = {
            let g: &EdaGraph = graph.borrow();
            let n = g.num_nodes;
            let mut ptr = vec![0u32; n + 1];
            for &(_, d) in &g.edges {
                ptr[d as usize + 1] += 1;
            }
            for v in 0..n {
                ptr[v + 1] += ptr[v];
            }
            let mut cursor: Vec<u32> = ptr[..n].to_vec();
            let mut src = vec![0u32; g.edges.len()];
            for &(s, d) in &g.edges {
                src[cursor[d as usize] as usize] = s;
                cursor[d as usize] += 1;
            }
            (ptr, src)
        };
        EdaGraphSource { graph, ptr, src, chunk: chunk.max(1), cursor: 0 }
    }
}

impl<G: Borrow<EdaGraph>> GraphSource for EdaGraphSource<G> {
    fn name(&self) -> &str {
        &self.graph.borrow().name
    }

    fn num_nodes_hint(&self) -> Option<usize> {
        Some(self.graph.borrow().num_nodes)
    }

    fn aig_prefix(&self) -> Option<usize> {
        Some(self.graph.borrow().num_aig_nodes)
    }

    fn next_chunk(&mut self) -> Result<Option<NodeChunk>> {
        let g = self.graph.borrow();
        if self.cursor >= g.num_nodes {
            return Ok(None);
        }
        let start = self.cursor;
        let take = self.chunk.min(g.num_nodes - start);
        let mut desc = Vec::with_capacity(take);
        let mut labels = Vec::with_capacity(take);
        let mut edges = Vec::new();
        for v in start..start + take {
            desc.push(desc_from_feature_row(&g.features[v]).map_err(|e| {
                e.context(format!("graph '{}' node {v}: cannot pack feature row", g.name))
            })?);
            labels.push(g.labels[v] as u8);
            for &s in &self.src[self.ptr[v] as usize..self.ptr[v + 1] as usize] {
                edges.push((s, v as u32));
            }
        }
        self.cursor += take;
        Ok(Some(NodeChunk { start, desc, labels, edges }))
    }
}

impl EdaGraph {
    /// Convert the legacy representation into the compact columnar store
    /// (borrow-based: no clone of the dense feature matrix).
    pub fn to_circuit(&self) -> Result<CircuitGraph> {
        CircuitGraph::from_source(EdaGraphSource::borrowed(self, crate::graph::DEFAULT_CHUNK_NODES))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aig::mult::csa_multiplier;
    use crate::graph::Csr;

    /// The compact store produced by streaming an AIG must carry exactly
    /// the content of the legacy eager construction.
    fn assert_matches_legacy(g: &CircuitGraph, eg: &EdaGraph) {
        assert_eq!(g.num_nodes(), eg.num_nodes);
        assert_eq!(g.num_aig_nodes(), eg.num_aig_nodes);
        assert_eq!(g.num_edges(), eg.num_edges());
        for u in 0..eg.num_nodes {
            assert_eq!(g.feature_row(u), eg.features[u], "node {u} features");
            assert_eq!(g.labels_u8()[u], eg.labels[u] as u8, "node {u} label");
        }
        let streamed: Vec<(u32, u32)> = g.edges_iter().collect();
        assert_eq!(streamed, eg.edges, "edge sequence");
    }

    #[test]
    fn aig_source_matches_eager_construction() {
        let aig = csa_multiplier(6);
        let eg = EdaGraph::from_aig(&aig);
        // tiny chunks to exercise chunk boundaries
        let g = CircuitGraph::from_source(AigSource::new(aig, 17)).unwrap();
        assert_matches_legacy(&g, &eg);
    }

    #[test]
    fn eda_adapter_matches_borrowed_conversion() {
        let aig = csa_multiplier(5);
        let eg = EdaGraph::from_aig(&aig);
        let owned = CircuitGraph::from_source(EdaGraphSource::new(eg.clone(), 13)).unwrap();
        let borrowed = eg.to_circuit().unwrap();
        assert_matches_legacy(&owned, &eg);
        assert_matches_legacy(&borrowed, &eg);
    }

    #[test]
    fn adapter_handles_replicated_and_mapped_feature_rows() {
        // replicate_shared_inputs interleaves PO rows and sets
        // num_aig_nodes == num_nodes; the adapter must still round-trip.
        let eg = EdaGraph::from_aig(&csa_multiplier(3)).replicate_shared_inputs(4);
        let g = eg.to_circuit().unwrap();
        assert_eq!(g.num_nodes(), eg.num_nodes);
        assert_eq!(g.num_aig_nodes(), eg.num_nodes);
        let csr_legacy = Csr::symmetric_from_edges(eg.num_nodes, &eg.edges);
        assert_eq!(g.symmetric_csr(), csr_legacy);

        let mapped = crate::datasets::build(crate::datasets::DatasetKind::Mapped7nm, 4).unwrap();
        let gm = mapped.to_circuit().unwrap();
        assert_eq!(gm.num_nodes(), mapped.num_nodes);
        for u in 0..mapped.num_nodes {
            assert_eq!(gm.feature_row(u), mapped.features[u]);
        }
    }

    #[test]
    fn adapter_rejects_non_bit_feature_rows() {
        let mut eg = EdaGraph::from_aig(&csa_multiplier(3));
        eg.features[2] = [0.5, 0.0, 0.0, 0.0];
        assert!(eg.to_circuit().is_err());
        let mut eg2 = EdaGraph::from_aig(&csa_multiplier(3));
        eg2.features[1] = [1.0, 0.0, 0.0, 0.0]; // type bits [1,0] unused
        assert!(eg2.to_circuit().is_err());
    }
}

//! L3 coordinator — the GROOT verification pipeline (Fig. 2).
//!
//! ```text
//! circuit ──► EDA graph ──► partition (METIS-substitute) ──► re-growth
//!     (Alg. 1) ──► per-partition GNN inference through a pluggable
//!     InferenceBackend (native rust or PJRT executables) ──► stitch core
//!     predictions ──► algebraic verification (crate::verify)
//! ```
//!
//! The coordinator never sees a device: each re-grown partition's local
//! CSR + features go through [`crate::backend::InferenceBackend::infer`],
//! which packs/pads however its executor needs. Execution stays on the
//! session thread (the `xla` crate's client is `Rc`-based and not
//! `Send`), matching the paper's single-GPU model: one device,
//! partitions streamed through it.

pub mod server;

use crate::backend::{InferenceBackend, NativeBackend, PartitionInput};
use crate::features::EdaGraph;
use crate::gnn::SageModel;
use crate::graph::Csr;
use crate::partition::{partition_kway, Partitioning};
use crate::regrowth::{regrow_partitions, RegrownPartition};
use anyhow::{Context, Result};
use std::time::{Duration, Instant};

/// Session configuration (the CLI mirrors these).
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Number of partitions (1 = no partitioning).
    pub num_partitions: usize,
    /// Apply Algorithm-1 boundary re-growth.
    pub regrow: bool,
    /// Partitioner seed.
    pub seed: u64,
    /// Threads for packing / native inference.
    pub threads: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            num_partitions: 1,
            regrow: true,
            seed: 0,
            threads: crate::util::pool::default_threads(),
        }
    }
}

/// The boxed inference backend a session drives: see
/// [`crate::backend::InferenceBackend`] for the trait and
/// [`crate::backend::backend_by_name`] for name-based construction.
pub type Backend = Box<dyn InferenceBackend>;

/// Per-run observability the harnesses print.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub num_partitions: usize,
    pub regrown: bool,
    pub partition_time: Duration,
    pub regrowth_time: Duration,
    pub pack_time: Duration,
    pub infer_time: Duration,
    pub total_nodes: usize,
    pub total_boundary_nodes: usize,
    pub total_crossing_edges: usize,
    pub max_partition_nodes: usize,
    /// Peak bucket footprint actually used (elements, see memmodel for
    /// byte conversion).
    pub peak_bucket_n: usize,
}

/// Classification output: one predicted class per graph node + stats.
#[derive(Clone, Debug)]
pub struct ClassifyResult {
    pub pred: Vec<u8>,
    pub accuracy: f64,
    pub stats: RunStats,
}

/// A verification session: backend + config.
pub struct Session {
    pub backend: Backend,
    pub config: SessionConfig,
}

impl Session {
    pub fn new(backend: Backend, config: SessionConfig) -> Session {
        Session { backend, config }
    }

    /// Convenience: a session on the rust-native backend (GROOT SpMM
    /// engine sized to `config.threads`) — the path every environment can
    /// run, artifacts or not.
    pub fn native(model: SageModel, config: SessionConfig) -> Session {
        let backend = NativeBackend::with_threads(model, config.threads);
        Session::new(Box::new(backend), config)
    }

    /// Run the full classification pipeline on one EDA graph.
    pub fn classify(&self, graph: &EdaGraph) -> Result<ClassifyResult> {
        self.classify_with(graph, &self.config)
    }

    /// Same, with a per-request config override (used by the server's
    /// router so one backend serves differently-partitioned requests).
    pub fn classify_with(&self, graph: &EdaGraph, cfg: &SessionConfig) -> Result<ClassifyResult> {
        let csr = Csr::symmetric_from_edges(graph.num_nodes, &graph.edges);

        let t0 = Instant::now();
        let partitioning = if cfg.num_partitions <= 1 {
            Partitioning { k: 1, assignment: vec![0; graph.num_nodes] }
        } else {
            partition_kway(&csr, cfg.num_partitions, cfg.seed)
        };
        let partition_time = t0.elapsed();

        let t1 = Instant::now();
        let parts = regrow_partitions(&csr, &partitioning, cfg.regrow);
        let regrowth_time = t1.elapsed();
        let rstats = crate::regrowth::stats(&parts);

        let mut pred = vec![0u8; graph.num_nodes];
        let mut stats = RunStats {
            num_partitions: parts.len(),
            regrown: cfg.regrow,
            partition_time,
            regrowth_time,
            total_nodes: graph.num_nodes,
            total_boundary_nodes: rstats.total_boundary_nodes,
            total_crossing_edges: rstats.total_crossing_edges,
            max_partition_nodes: rstats.max_partition_nodes,
            ..Default::default()
        };

        for part in &parts {
            self.classify_partition(graph, part, &mut pred, &mut stats)?;
        }

        let labels = graph.labels_u8();
        let accuracy = crate::gnn::accuracy(&pred, &labels);
        Ok(ClassifyResult { pred, accuracy, stats })
    }

    fn classify_partition(
        &self,
        graph: &EdaGraph,
        part: &RegrownPartition,
        pred: &mut [u8],
        stats: &mut RunStats,
    ) -> Result<()> {
        if part.nodes.is_empty() {
            return Ok(());
        }
        let local_csr = part.csr();
        // Gather local features (backend-specific packing — bucket
        // padding, ELL layout — happens inside the backend and counts as
        // inference time).
        let fdim = crate::features::GROOT_FEATURE_DIM;
        let t_pack = Instant::now();
        let mut feats = Vec::with_capacity(part.nodes.len() * fdim);
        for &g in &part.nodes {
            feats.extend_from_slice(&graph.features[g as usize]);
        }
        stats.pack_time += t_pack.elapsed();

        let t_inf = Instant::now();
        let out = self.backend.infer(PartitionInput {
            csr: &local_csr,
            features: &feats,
            feature_dim: fdim,
        })?;
        stats.infer_time += t_inf.elapsed();
        stats.peak_bucket_n = stats.peak_bucket_n.max(out.bucket_rows);

        let classes = self.backend.num_classes();
        for (i, &g) in part.nodes[..part.num_core].iter().enumerate() {
            let row = &out.logits[i * classes..(i + 1) * classes];
            pred[g as usize] = argmax(row);
        }
        Ok(())
    }
}

fn argmax(row: &[f32]) -> u8 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as u8
}

/// Load the weight bundle from the default artifacts location.
pub fn load_weights(path: &std::path::Path) -> Result<crate::util::tensor::Bundle> {
    crate::util::tensor::read_bundle(path)
        .with_context(|| format!("load weights {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aig::mult::csa_multiplier;
    use crate::gnn::{SageLayer, SageModel};

    /// A hand-built model that implements the classification rule exactly
    /// from the features: the feature encoding is nearly label-revealing
    /// for PI/PO vs AND (type bits), so a native sanity model can reach
    /// high accuracy on those classes without training.
    fn type_bit_model() -> SageModel {
        // logits = x · W, no aggregation: W maps [t1,t0,pl,pr] to classes.
        // PI (0,0,_,_) → class 4; AND-ish (1,1,_,_) → class 3;
        // PO (0,1,_,_) → class 0.
        #[rustfmt::skip]
        let w_self = vec![
            // classes:       po    maj   xor   and   pi
            /* t1 */         -10.0,  0.0,  0.0, 10.0,  -10.0,
            /* t0 */          10.0,  0.0,  0.0,  0.0,  -10.0,
            /* pl */           0.0,  0.0,  0.0,  0.0,   0.0,
            /* pr */           0.0,  0.0,  0.0,  0.0,   0.0,
        ];
        SageModel {
            layers: vec![SageLayer {
                din: 4,
                dout: 5,
                w_self,
                w_neigh: vec![0.0; 20],
                bias: vec![0.0, -5.0, -5.0, 0.0, 5.0],
            }],
        }
    }

    #[test]
    fn native_pipeline_runs_and_stitches_every_node() {
        let g = csa_multiplier(6);
        let eg = crate::features::EdaGraph::from_aig(&g);
        let session = Session::native(
            type_bit_model(),
            SessionConfig { num_partitions: 4, regrow: true, ..Default::default() },
        );
        let res = session.classify(&eg).unwrap();
        assert_eq!(res.pred.len(), eg.num_nodes);
        // The type-bit rule classifies PI/PO/AND-family perfectly; XOR and
        // MAJ collapse into AND (same type bits), so accuracy equals the
        // fraction of nodes that are PI/PO/plain-AND.
        let labels = eg.labels_u8();
        let easy = labels.iter().filter(|&&l| l == 0 || l == 4).count();
        assert!(res.accuracy >= easy as f64 / labels.len() as f64 * 0.99);
        assert_eq!(res.stats.num_partitions, 4);
        assert!(res.stats.total_crossing_edges > 0);
    }

    #[test]
    fn partitioned_equals_unpartitioned_with_enough_regrowth_for_easy_classes() {
        // For a 0-aggregation model, partitioning cannot change results:
        // predictions depend only on node features.
        let g = csa_multiplier(5);
        let eg = crate::features::EdaGraph::from_aig(&g);
        let mk = |parts| {
            Session::native(
                type_bit_model(),
                SessionConfig { num_partitions: parts, regrow: false, ..Default::default() },
            )
        };
        let full = mk(1).classify(&eg).unwrap();
        let parted = mk(6).classify(&eg).unwrap();
        assert_eq!(full.pred, parted.pred);
    }
}

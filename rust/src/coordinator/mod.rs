//! L3 coordinator — the GROOT verification pipeline (Fig. 2), staged.
//!
//! ```text
//! circuit ──► EDA graph ──► PreparedGraph (CSR + features + fingerprint)
//!     ──► PartitionPlan (partition → Alg.-1 re-growth → gathered buffers,
//!         LRU-cacheable by (fingerprint, PlanOptions))
//!     ──► execute_plan: ONE InferenceBackend::infer_batch call over all
//!         partitions, core predictions stitched back
//!     ──► algebraic verification (crate::verify)
//! ```
//!
//! The stage objects live in [`pipeline`]; [`Session::classify`] is the
//! thin eager composition kept for callers that don't reuse anything.
//! The coordinator never sees a device: partitions go through
//! [`crate::backend::InferenceBackend::infer_batch`], which packs/pads
//! (and, since backends are `Send + Sync`, fans independent partitions
//! out across its thread budget) however its executor needs. The
//! serving layer ([`server`]) stacks request-level concurrency on top:
//! N workers over a bounded queue, one backend each, one shared
//! [`ShardedPlanCache`] — with predictions byte-identical to this
//! single-threaded session path at every concurrency level.

pub mod pipeline;
pub mod planstore;
pub mod server;

pub use pipeline::{
    combine_part_digests, execute_plan, execute_plan_streaming,
    execute_plan_streaming_overlapped, ExecStats, PartitionPlan, PlanCache, PlannedPartition,
    PlanOptions, PlanStats, PreparedGraph, ShardedPlanCache, StreamPlan, StreamStats,
    DEFAULT_PLAN_CACHE_CAPACITY, DEFAULT_PLAN_CACHE_SHARDS,
};
pub use planstore::PlanStore;

use crate::backend::{InferenceBackend, NativeBackend};
use crate::features::EdaGraph;
use crate::gnn::SageModel;
use crate::graph::CircuitGraph;
use crate::incremental::{apply_edits, GraphEdit, IncrementalState};
use anyhow::{Context, Result};
use std::sync::Arc;
use std::time::Duration;

/// Session configuration (the CLI mirrors these).
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Number of partitions (1 = no partitioning).
    pub num_partitions: usize,
    /// Apply Algorithm-1 boundary re-growth.
    pub regrow: bool,
    /// Partitioner seed.
    pub seed: u64,
    /// Per-backend thread budget (partition lanes × SpMM/matmul threads
    /// share it — see [`crate::util::pool::split_threads`]). Explicit
    /// values override the process-wide `GROOT_THREADS` default.
    pub threads: usize,
    /// Serving worker threads ([`server::Server`]); ignored by a plain
    /// [`Session`]. Deployments splitting a machine budget typically set
    /// `workers × threads ≈ cores`.
    pub workers: usize,
    /// Inference precision for the native backend (`--precision`):
    /// f32 (byte-exact reference) or int8 weights with fused dequant.
    pub precision: crate::gnn::Precision,
    /// HD/LD degree cutoff used for the plan-stats row-split report
    /// (`--hd-threshold`; default 512 or the `GROOT_HD_THRESHOLD` env).
    /// The GROOT SpMM engines minted inside backend lane pools read the
    /// same default, so set the env — not just this field — to move the
    /// engine's split; see [`crate::spmm::default_hd_threshold`].
    pub hd_threshold: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            num_partitions: 1,
            regrow: true,
            seed: 0,
            threads: crate::util::pool::default_threads(),
            workers: 1,
            precision: crate::gnn::Precision::F32,
            hd_threshold: crate::spmm::default_hd_threshold(),
        }
    }
}

/// The boxed inference backend a session drives: see
/// [`crate::backend::InferenceBackend`] for the trait and
/// [`crate::backend::backend_by_name`] for name-based construction.
pub type Backend = Box<dyn InferenceBackend>;

/// Per-run observability the harnesses print. Plan-stage times are zero
/// when the run executed a cached plan (`plan_cache_hit`).
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub num_partitions: usize,
    pub regrown: bool,
    pub partition_time: Duration,
    pub regrowth_time: Duration,
    /// Plan-time local-CSR build + feature gather (was per-request "pack").
    pub pack_time: Duration,
    pub infer_time: Duration,
    pub total_nodes: usize,
    pub total_boundary_nodes: usize,
    pub total_crossing_edges: usize,
    pub max_partition_nodes: usize,
    /// Peak bucket footprint actually used (elements, see memmodel for
    /// byte conversion).
    pub peak_bucket_n: usize,
    /// This run reused a cached [`PartitionPlan`] — no partitioning,
    /// re-growth, or gathering happened.
    pub plan_cache_hit: bool,
    /// Partitions per `infer_batch` call (the whole plan on the eager
    /// path; the window size on the streaming path).
    pub batch_size: usize,
    /// Execution-buffer bytes live at once (local CSRs + gathered
    /// features + logits): the whole plan for the eager path, the
    /// largest window for [`execute_plan_streaming`] — the measured
    /// out-of-core claim.
    pub peak_resident_bytes: usize,
}

/// Classification output: one predicted class per graph node + stats.
#[derive(Clone, Debug)]
pub struct ClassifyResult {
    pub pred: Vec<u8>,
    pub accuracy: f64,
    pub stats: RunStats,
}

/// Outcome of [`Session::classify_delta`]: the classification of the
/// edited design (byte-identical to a from-scratch [`Session::classify`]
/// of it) plus the incremental-execution accounting.
#[derive(Clone, Debug)]
pub struct DeltaResult {
    pub result: ClassifyResult,
    /// Content fingerprint of the edited graph — the base fingerprint
    /// for a chained follow-up delta (the session registers the edited
    /// design automatically).
    pub edited_fingerprint: u64,
    /// Non-empty partitions that went through `infer_batch`.
    pub dirty: usize,
    /// Non-empty partitions stitched verbatim from the prediction cache.
    pub clean: usize,
    /// The edit list changed the topology, so the k-way partitioner ran
    /// from scratch instead of reusing the base assignment.
    pub repartitioned: bool,
}

/// A verification session: backend + config (+ shared incremental state).
pub struct Session {
    pub backend: Backend,
    pub config: SessionConfig,
    /// Base-design registry + prediction cache driving
    /// [`Self::classify_delta`]. Private by design: a standalone session
    /// owns its own, the serving layer injects one shared instance via
    /// [`Self::with_incremental`].
    incremental: IncrementalState,
}

impl Session {
    pub fn new(backend: Backend, config: SessionConfig) -> Session {
        Session { backend, config, incremental: IncrementalState::new() }
    }

    /// Replace the incremental state — the serving layer creates ONE
    /// [`IncrementalState`] and hands a clone to every worker session so
    /// registered bases and cached predictions are visible across
    /// workers.
    pub fn with_incremental(mut self, incremental: IncrementalState) -> Session {
        self.incremental = incremental;
        self
    }

    /// The session's incremental state (shared handle).
    pub fn incremental(&self) -> &IncrementalState {
        &self.incremental
    }

    /// Convenience: a session on the rust-native backend (GROOT SpMM
    /// engine sized to `config.threads`) — the path every environment can
    /// run, artifacts or not.
    pub fn native(model: SageModel, config: SessionConfig) -> Session {
        let backend = NativeBackend::with_precision(model, config.threads, config.precision);
        Session::new(Box::new(backend), config)
    }

    /// Run the full classification pipeline on one EDA graph.
    ///
    /// Thin wrapper: prepare → plan → [`classify_plan`](Self::classify_plan).
    /// Callers that verify the same circuit repeatedly should hold a
    /// [`PreparedGraph`] and a [`PlanCache`] instead (or go through the
    /// serving workers, which share a [`ShardedPlanCache`]).
    pub fn classify(&self, graph: &EdaGraph) -> Result<ClassifyResult> {
        self.classify_with(graph, &self.config)
    }

    /// Same, with a per-request config override.
    pub fn classify_with(&self, graph: &EdaGraph, cfg: &SessionConfig) -> Result<ClassifyResult> {
        let prepared = PreparedGraph::new(graph);
        let plan = prepared.plan(&PlanOptions::from_config(cfg));
        // This eager path stamps and re-checks a fingerprint it just
        // computed — a deliberate redundancy: the word-wise hash is
        // trivial next to partitioning, and one code path serving both
        // eager and cached callers beats a second unchecked variant.
        self.classify_plan(&prepared, &plan, false)
    }

    /// Execute a pre-built plan: the batched stage-3 call plus label
    /// lookup. The plan's fingerprint must match the prepared graph's —
    /// a stale plan (same-size but different or since-mutated graph) is
    /// rejected instead of silently classifying from stale buffers.
    /// `cache_hit` marks the plan as reused so the stats report zero
    /// plan-stage time (the work was paid by an earlier request).
    pub fn classify_plan(
        &self,
        prepared: &PreparedGraph<'_>,
        plan: &PartitionPlan,
        cache_hit: bool,
    ) -> Result<ClassifyResult> {
        anyhow::ensure!(
            plan.fingerprint == prepared.fingerprint(),
            "stale plan for graph '{}': plan expected fingerprint {:016x} but the graph's \
             actual fingerprint is {:016x} (plan is stale or was built from a different graph)",
            prepared.name(),
            plan.fingerprint,
            prepared.fingerprint()
        );
        // Belt-and-suspenders alongside the (non-cryptographic) 64-bit
        // fingerprint: a colliding graph of a different size must error
        // here rather than panic downstream in the accuracy check.
        anyhow::ensure!(
            plan.num_nodes == prepared.num_nodes(),
            "plan was built for {} nodes but the graph has {}",
            plan.num_nodes,
            prepared.num_nodes()
        );
        let (pred, exec) = execute_plan(self.backend.as_ref(), plan)?;
        let stats = RunStats {
            num_partitions: plan.num_partitions(),
            regrown: plan.options.regrow,
            partition_time: if cache_hit { Duration::ZERO } else { plan.stats.partition_time },
            regrowth_time: if cache_hit { Duration::ZERO } else { plan.stats.regrowth_time },
            pack_time: if cache_hit { Duration::ZERO } else { plan.stats.gather_time },
            infer_time: exec.infer_time,
            total_nodes: prepared.num_nodes(),
            total_boundary_nodes: plan.stats.regrowth.total_boundary_nodes,
            total_crossing_edges: plan.stats.regrowth.total_crossing_edges,
            max_partition_nodes: plan.stats.regrowth.max_partition_nodes,
            peak_bucket_n: exec.peak_bucket_n,
            plan_cache_hit: cache_hit,
            batch_size: exec.batch_size,
            peak_resident_bytes: exec.peak_resident_bytes,
        };
        let labels = prepared.labels_u8();
        let accuracy = crate::gnn::accuracy(&pred, &labels);
        Ok(ClassifyResult { pred, accuracy, stats })
    }

    /// Classify a compact circuit AND register it as an incremental
    /// base: the circuit, its k-way assignment, and its per-partition
    /// core predictions all land in the session's [`IncrementalState`],
    /// so a follow-up [`Self::classify_delta`] against the returned
    /// fingerprint re-infers only the partitions an edit dirties.
    pub fn prime_base(&self, circuit: Arc<CircuitGraph>) -> Result<(u64, ClassifyResult)> {
        let prepared = PreparedGraph::from_circuit_ref(&circuit);
        let opts = PlanOptions::from_config(&self.config);
        let plan = prepared.plan(&opts);
        let result = self.classify_plan(&prepared, &plan, false)?;
        let fingerprint = prepared.fingerprint();
        self.note_base(fingerprint, circuit.clone(), &plan, &result.pred);
        Ok((fingerprint, result))
    }

    /// Register an already-classified circuit as an incremental base
    /// (the zero-recompute path the serving workers use after a normal
    /// classify): stores the circuit, the plan's recovered assignment,
    /// and the per-partition core predictions.
    pub fn note_base(
        &self,
        fingerprint: u64,
        circuit: Arc<CircuitGraph>,
        plan: &PartitionPlan,
        pred: &[u8],
    ) {
        self.incremental.register_base(fingerprint, circuit);
        self.incremental.store_assignment(fingerprint, &plan.options, plan.extract_assignment());
        self.incremental.prime_predictions(plan, pred);
    }

    /// Incremental verification: apply `edits` to the registered base
    /// design and classify the edited graph, re-inferring ONLY the
    /// partitions whose content digest the edit moved (the rest stitch
    /// cached core predictions verbatim). Output is byte-identical to a
    /// from-scratch [`Self::classify`] of the edited graph.
    ///
    /// Topology-preserving edit lists (all [`GraphEdit::SetFunction`])
    /// additionally reuse the base k-way assignment, skipping the
    /// partitioner entirely; topology-changing lists repartition from
    /// scratch (`DeltaResult::repartitioned`).
    ///
    /// The edited design is registered as a new base under
    /// `DeltaResult::edited_fingerprint`, so deltas chain.
    pub fn classify_delta(
        &self,
        base_fingerprint: u64,
        edits: &[GraphEdit],
    ) -> Result<DeltaResult> {
        self.classify_delta_with(base_fingerprint, edits, &self.config)
    }

    /// Same, with a per-request config override (the daemon resolves
    /// request flags into one of these).
    pub fn classify_delta_with(
        &self,
        base_fingerprint: u64,
        edits: &[GraphEdit],
        cfg: &SessionConfig,
    ) -> Result<DeltaResult> {
        let base = self.incremental.base(base_fingerprint).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown base fingerprint {base_fingerprint:016x}: register the base design \
                 first (classify it through this session, or prime_base it)"
            )
        })?;
        let edited = Arc::new(apply_edits(&base, edits)?);
        let prepared = PreparedGraph::from_circuit_ref(&edited);
        let opts = PlanOptions::from_config(cfg);

        // Topology-preserving edits keep the symmetric CSR identical, so
        // the deterministic partitioner would reproduce the base
        // assignment bit-for-bit — reuse it and skip k-way entirely.
        let reusable = edits.iter().all(|e| e.preserves_topology());
        let assignment =
            if reusable { self.incremental.assignment(base_fingerprint, &opts) } else { None };
        let repartitioned = assignment.is_none();
        let plan = match assignment {
            Some(a) => prepared.plan_with_assignment(&opts, &a)?,
            None => prepared.plan(&opts),
        };

        let delta = crate::incremental::execute_plan_delta(
            self.backend.as_ref(),
            &plan,
            self.incremental.predictions(),
        )?;
        let stats = RunStats {
            num_partitions: plan.num_partitions(),
            regrown: plan.options.regrow,
            partition_time: plan.stats.partition_time,
            regrowth_time: plan.stats.regrowth_time,
            pack_time: plan.stats.gather_time,
            infer_time: delta.stats.infer_time,
            total_nodes: prepared.num_nodes(),
            total_boundary_nodes: plan.stats.regrowth.total_boundary_nodes,
            total_crossing_edges: plan.stats.regrowth.total_crossing_edges,
            max_partition_nodes: plan.stats.regrowth.max_partition_nodes,
            peak_bucket_n: delta.stats.peak_bucket_n,
            plan_cache_hit: false,
            batch_size: delta.stats.batch_size,
            peak_resident_bytes: delta.stats.peak_resident_bytes,
        };
        let labels = prepared.labels_u8();
        let accuracy = crate::gnn::accuracy(&delta.pred, &labels);
        let edited_fingerprint = prepared.fingerprint();

        // Chain: the edited design becomes a registered base, and its
        // (possibly freshly inferred) core predictions prime the cache.
        self.note_base(edited_fingerprint, edited.clone(), &plan, &delta.pred);

        Ok(DeltaResult {
            result: ClassifyResult { pred: delta.pred, accuracy, stats },
            edited_fingerprint,
            dirty: delta.dirty,
            clean: delta.clean,
            repartitioned,
        })
    }

    /// Out-of-core classification: build a lean [`StreamPlan`] from the
    /// session config and drive it through
    /// [`execute_plan_streaming`] `window` partitions at a time.
    /// Predictions are byte-identical to [`Self::classify`] /
    /// [`Self::classify_plan`] on the same `(graph, options)`; peak
    /// execution memory is ∝ the largest window instead of the whole
    /// plan (`RunStats::peak_resident_bytes` reports it, measured).
    pub fn classify_streaming(
        &self,
        prepared: &PreparedGraph<'_>,
        window: usize,
    ) -> Result<ClassifyResult> {
        let plan = prepared.plan_stream(&PlanOptions::from_config(&self.config));
        self.classify_stream_plan(prepared, &plan, window)
    }

    /// Out-of-core classification with gather/infer overlap: window W+1
    /// materializes on a second thread while W infers
    /// ([`execute_plan_streaming_overlapped`]). Same predictions, better
    /// wall time, TWO windows of peak memory instead of one.
    pub fn classify_streaming_overlapped(
        &self,
        prepared: &PreparedGraph<'_>,
        window: usize,
    ) -> Result<ClassifyResult> {
        let plan = prepared.plan_stream(&PlanOptions::from_config(&self.config));
        self.classify_stream_plan_with(prepared, &plan, window, true)
    }

    /// Execute a pre-built [`StreamPlan`] (same staleness guard as
    /// [`Self::classify_plan`], enforced by the executor).
    pub fn classify_stream_plan(
        &self,
        prepared: &PreparedGraph<'_>,
        plan: &StreamPlan,
        window: usize,
    ) -> Result<ClassifyResult> {
        self.classify_stream_plan_with(prepared, plan, window, false)
    }

    /// [`Self::classify_stream_plan`] with an explicit overlap choice.
    pub fn classify_stream_plan_with(
        &self,
        prepared: &PreparedGraph<'_>,
        plan: &StreamPlan,
        window: usize,
        overlap: bool,
    ) -> Result<ClassifyResult> {
        let (pred, exec) = if overlap {
            execute_plan_streaming_overlapped(self.backend.as_ref(), prepared, plan, window)?
        } else {
            execute_plan_streaming(self.backend.as_ref(), prepared, plan, window)?
        };
        let stats = RunStats {
            num_partitions: plan.num_partitions(),
            regrown: plan.options.regrow,
            partition_time: plan.partition_time,
            regrowth_time: exec.regrowth_time,
            pack_time: exec.gather_time,
            infer_time: exec.infer_time,
            total_nodes: prepared.num_nodes(),
            total_boundary_nodes: exec.regrowth.total_boundary_nodes,
            total_crossing_edges: exec.regrowth.total_crossing_edges,
            max_partition_nodes: exec.regrowth.max_partition_nodes,
            peak_bucket_n: exec.peak_bucket_n,
            plan_cache_hit: false,
            batch_size: exec.max_window,
            peak_resident_bytes: exec.peak_resident_bytes,
        };
        let labels = prepared.labels_u8();
        let accuracy = crate::gnn::accuracy(&pred, &labels);
        Ok(ClassifyResult { pred, accuracy, stats })
    }
}

/// Row argmax with deterministic tie- and NaN-handling — re-exported
/// from [`crate::gnn::argmax`], the crate's single implementation, so the
/// tie/NaN rule cannot diverge between serving (plan stitching) and
/// training eval ([`crate::gnn::argmax_rows`]). The behavioral tests
/// below stay in this module: they pin the serving-visible contract.
pub use crate::gnn::argmax;

/// Load the weight bundle from the default artifacts location.
pub fn load_weights(path: &std::path::Path) -> Result<crate::util::tensor::Bundle> {
    crate::util::tensor::read_bundle(path)
        .with_context(|| format!("load weights {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aig::mult::csa_multiplier;
    use crate::gnn::{SageLayer, SageModel};

    /// A hand-built model that implements the classification rule exactly
    /// from the features: the feature encoding is nearly label-revealing
    /// for PI/PO vs AND (type bits), so a native sanity model can reach
    /// high accuracy on those classes without training.
    fn type_bit_model() -> SageModel {
        // logits = x · W, no aggregation: W maps [t1,t0,pl,pr] to classes.
        // PI (0,0,_,_) → class 4; AND-ish (1,1,_,_) → class 3;
        // PO (0,1,_,_) → class 0.
        #[rustfmt::skip]
        let w_self = vec![
            // classes:       po    maj   xor   and   pi
            /* t1 */         -10.0,  0.0,  0.0, 10.0,  -10.0,
            /* t0 */          10.0,  0.0,  0.0,  0.0,  -10.0,
            /* pl */           0.0,  0.0,  0.0,  0.0,   0.0,
            /* pr */           0.0,  0.0,  0.0,  0.0,   0.0,
        ];
        SageModel {
            layers: vec![SageLayer {
                din: 4,
                dout: 5,
                w_self,
                w_neigh: vec![0.0; 20],
                bias: vec![0.0, -5.0, -5.0, 0.0, 5.0],
            }],
        }
    }

    #[test]
    fn native_pipeline_runs_and_stitches_every_node() {
        let g = csa_multiplier(6);
        let eg = crate::features::EdaGraph::from_aig(&g);
        let session = Session::native(
            type_bit_model(),
            SessionConfig { num_partitions: 4, regrow: true, ..Default::default() },
        );
        let res = session.classify(&eg).unwrap();
        assert_eq!(res.pred.len(), eg.num_nodes);
        // The type-bit rule classifies PI/PO/AND-family perfectly; XOR and
        // MAJ collapse into AND (same type bits), so accuracy equals the
        // fraction of nodes that are PI/PO/plain-AND.
        let labels = eg.labels_u8();
        let easy = labels.iter().filter(|&&l| l == 0 || l == 4).count();
        assert!(res.accuracy >= easy as f64 / labels.len() as f64 * 0.99);
        assert_eq!(res.stats.num_partitions, 4);
        assert!(res.stats.total_crossing_edges > 0);
        assert_eq!(res.stats.batch_size, 4);
        assert!(!res.stats.plan_cache_hit);
    }

    #[test]
    fn partitioned_equals_unpartitioned_with_enough_regrowth_for_easy_classes() {
        // For a 0-aggregation model, partitioning cannot change results:
        // predictions depend only on node features.
        let g = csa_multiplier(5);
        let eg = crate::features::EdaGraph::from_aig(&g);
        let mk = |parts| {
            Session::native(
                type_bit_model(),
                SessionConfig { num_partitions: parts, regrow: false, ..Default::default() },
            )
        };
        let full = mk(1).classify(&eg).unwrap();
        let parted = mk(6).classify(&eg).unwrap();
        assert_eq!(full.pred, parted.pred);
    }

    #[test]
    fn staged_composition_matches_eager_classify() {
        let g = csa_multiplier(5);
        let eg = crate::features::EdaGraph::from_aig(&g);
        let cfg = SessionConfig { num_partitions: 3, regrow: true, ..Default::default() };
        let session = Session::native(type_bit_model(), cfg.clone());
        let eager = session.classify(&eg).unwrap();

        let prepared = PreparedGraph::new(&eg);
        let plan = prepared.plan(&PlanOptions::from_config(&cfg));
        let staged = session.classify_plan(&prepared, &plan, false).unwrap();
        assert_eq!(eager.pred, staged.pred);
        assert_eq!(eager.accuracy, staged.accuracy);
    }

    #[test]
    fn streaming_matches_eager_and_bounds_memory() {
        let g = csa_multiplier(6);
        let eg = crate::features::EdaGraph::from_aig(&g);
        let cfg = SessionConfig { num_partitions: 5, regrow: true, ..Default::default() };
        let session = Session::native(type_bit_model(), cfg);
        let eager = session.classify(&eg).unwrap();
        assert!(eager.stats.peak_resident_bytes > 0);
        let prepared = PreparedGraph::new(&eg);
        for window in [1usize, 2, 16] {
            let streamed = session.classify_streaming(&prepared, window).unwrap();
            assert_eq!(streamed.pred, eager.pred, "window {window}");
            assert_eq!(streamed.accuracy, eager.accuracy);
            assert_eq!(streamed.stats.batch_size, window.min(5));
            // the windowed working set never exceeds the whole-plan one,
            // and is a strict fraction of it for small windows
            assert!(
                streamed.stats.peak_resident_bytes <= eager.stats.peak_resident_bytes,
                "window {window}: {} > {}",
                streamed.stats.peak_resident_bytes,
                eager.stats.peak_resident_bytes
            );
            if window == 1 {
                assert!(
                    streamed.stats.peak_resident_bytes
                        < eager.stats.peak_resident_bytes / 2,
                    "single-partition window should be far below the full plan"
                );
            }
        }
    }

    #[test]
    fn overlapped_streaming_matches_sequential_streaming() {
        let g = csa_multiplier(6);
        let eg = crate::features::EdaGraph::from_aig(&g);
        let cfg = SessionConfig { num_partitions: 5, regrow: true, ..Default::default() };
        let session = Session::native(type_bit_model(), cfg);
        let prepared = PreparedGraph::new(&eg);
        for window in [1usize, 2, 16] {
            let seq = session.classify_streaming(&prepared, window).unwrap();
            let ovl = session.classify_streaming_overlapped(&prepared, window).unwrap();
            assert_eq!(ovl.pred, seq.pred, "window {window}: overlap changed predictions");
            assert_eq!(ovl.accuracy, seq.accuracy);
            // the overlapped executor holds up to two windows: its honest
            // accounting is ≥ the sequential single-window peak and ≤ 2×
            assert!(ovl.stats.peak_resident_bytes >= seq.stats.peak_resident_bytes);
            assert!(
                ovl.stats.peak_resident_bytes <= 2 * seq.stats.peak_resident_bytes,
                "window {window}: {} > 2×{}",
                ovl.stats.peak_resident_bytes,
                seq.stats.peak_resident_bytes
            );
        }
    }

    #[test]
    fn classify_plan_rejects_mismatched_graph() {
        let eg5 = crate::features::EdaGraph::from_aig(&csa_multiplier(5));
        let session = Session::native(type_bit_model(), SessionConfig::default());
        let plan = PreparedGraph::new(&eg5).plan(&PlanOptions::default());

        // different circuit entirely
        let eg6 = crate::features::EdaGraph::from_aig(&csa_multiplier(6));
        assert!(session.classify_plan(&PreparedGraph::new(&eg6), &plan, false).is_err());

        // same-size graph whose content was mutated after planning
        let mut altered = eg5.clone();
        altered.features[0][0] += 1.0;
        let err = session
            .classify_plan(&PreparedGraph::new(&altered), &plan, false)
            .unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err:#}");
    }

    #[test]
    fn classify_delta_matches_cold_classify_of_edited_graph() {
        let base =
            Arc::new(CircuitGraph::from_source(crate::aig::mult::csa_source(6, 64)).unwrap());
        let cfg = SessionConfig { num_partitions: 6, regrow: true, ..Default::default() };
        let session = Session::native(type_bit_model(), cfg.clone());
        let (fp, primed) = session.prime_base(base.clone()).unwrap();
        assert_eq!(session.incremental().num_bases(), 1);
        assert_eq!(primed.pred.len(), base.num_nodes());

        // one polarity flip: most partitions must stitch from cache
        let edits = crate::incremental::synthetic_polarity_edits(&base, 1, 7);
        assert_eq!(edits.len(), 1);
        let delta = session.classify_delta(fp, &edits).unwrap();
        assert!(!delta.repartitioned, "topology-preserving edit must reuse the assignment");
        assert!(delta.dirty >= 1, "the edited node's partition must re-infer");
        assert!(delta.clean >= 1, "untouched partitions must stitch from cache");

        // byte-identity against a cold session classifying the edited graph
        let edited = crate::incremental::apply_edits(&base, &edits).unwrap();
        let cold = Session::native(type_bit_model(), cfg);
        let prepared = PreparedGraph::from_circuit(edited);
        let plan = prepared.plan(&PlanOptions::from_config(&cold.config));
        let reference = cold.classify_plan(&prepared, &plan, false).unwrap();
        assert_eq!(delta.result.pred, reference.pred);
        assert_eq!(delta.result.accuracy, reference.accuracy);
        assert_eq!(delta.edited_fingerprint, prepared.fingerprint());

        // deltas chain: the edited design is now a registered base
        let edits2 = crate::incremental::synthetic_polarity_edits(&base, 1, 8);
        let chained = session.classify_delta(delta.edited_fingerprint, &edits2).unwrap();
        assert!(chained.clean >= 1);

        // unknown bases are rejected with a helpful message
        let err = session.classify_delta(0xdead_beef, &[]).unwrap_err().to_string();
        assert!(err.contains("unknown base fingerprint"), "{err}");
    }

    #[test]
    fn topology_changing_delta_repartitions_and_still_matches() {
        let base =
            Arc::new(CircuitGraph::from_source(crate::aig::mult::csa_source(5, 64)).unwrap());
        let cfg = SessionConfig { num_partitions: 4, regrow: true, ..Default::default() };
        let session = Session::native(type_bit_model(), cfg.clone());
        let (fp, _) = session.prime_base(base.clone()).unwrap();

        // an ECO cone changes topology → full repartition, still correct
        let at = base.num_aig_nodes() as u32;
        let cone = crate::incremental::GraphEdit::AppendCone {
            desc: vec![
                crate::graph::circuit::pack_desc(crate::graph::circuit::KIND_INPUT, false, false),
                crate::graph::circuit::pack_desc(crate::graph::circuit::KIND_AND, true, false),
            ],
            labels: vec![4, 3],
            fanins: vec![(0, 1), (at, 1)],
        };
        let delta = session.classify_delta(fp, &[cone.clone()]).unwrap();
        assert!(delta.repartitioned, "an appended cone must force a repartition");

        let edited = crate::incremental::apply_edits(&base, &[cone]).unwrap();
        let cold = Session::native(type_bit_model(), cfg);
        let prepared = PreparedGraph::from_circuit(edited);
        let plan = prepared.plan(&PlanOptions::from_config(&cold.config));
        let reference = cold.classify_plan(&prepared, &plan, false).unwrap();
        assert_eq!(delta.result.pred, reference.pred);
    }

    #[test]
    fn argmax_picks_lowest_index_on_ties() {
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0);
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[0.0]), 0);
    }

    #[test]
    fn argmax_never_selects_nan() {
        // A leading NaN used to win by default (every comparison against
        // NaN is false); it must lose to any real value.
        assert_eq!(argmax(&[f32::NAN, 1.0, 0.5]), 1);
        assert_eq!(argmax(&[0.5, f32::NAN, 1.0]), 2);
        assert_eq!(argmax(&[-1.0, f32::NAN]), 0);
        // Degenerate all-NaN row: deterministic fallback to 0.
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_handles_negative_infinities() {
        assert_eq!(argmax(&[f32::NEG_INFINITY, -3.0]), 1);
        assert_eq!(argmax(&[f32::INFINITY, 1.0, f32::NAN]), 0);
    }
}

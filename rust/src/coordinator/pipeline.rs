//! Staged verification pipeline — the composable, cacheable form of the
//! Fig. 2 flow.
//!
//! The monolithic `classify` call is decomposed into three reusable
//! stage objects so repeated work on the same circuit is paid once:
//!
//! ```text
//! EdaGraph ──► PreparedGraph        symmetric CSR + dense feature matrix
//!     │            │                + content fingerprint (built once)
//!     │            ▼ .plan(&PlanOptions)
//!     │        PartitionPlan        partition → re-grow → per-partition
//!     │            │                local CSRs + gathered feature buffers
//!     │            ▼ execute_plan(backend, plan)
//!     │        one InferenceBackend::infer_batch call over ALL partitions,
//!     │        core predictions stitched back into graph order
//!     ▼
//! ClassifyResult (via Session::classify_plan, which adds labels/accuracy)
//! ```
//!
//! `PartitionPlan` is fully owned (no borrows into the source graph), so
//! plans are cacheable: [`PlanCache`] is a small LRU keyed by
//! `(fingerprint, PlanOptions)` — a warm hit skips partitioning,
//! re-growth, and feature gathering entirely. The serving router
//! ([`super::server`]) owns one cache per backend; `Session::classify`
//! remains as the thin eager composition of the three stages.

use super::SessionConfig;
use crate::backend::{InferenceBackend, PartitionInput};
use crate::features::{EdaGraph, GROOT_FEATURE_DIM};
use crate::graph::Csr;
use crate::partition::{partition_kway, Partitioning};
use crate::regrowth::{regrow_partitions, RegrownPartition, RegrowthStats};
use anyhow::Result;
use std::cell::OnceCell;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The per-request knobs a plan depends on. Everything else in
/// [`SessionConfig`] (threads) belongs to the backend, not the plan, so
/// this is the complete plan-cache key alongside the graph fingerprint.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanOptions {
    /// Number of partitions (1 = no partitioning).
    pub partitions: usize,
    /// Apply Algorithm-1 boundary re-growth.
    pub regrow: bool,
    /// Partitioner seed.
    pub seed: u64,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions { partitions: 1, regrow: true, seed: 0 }
    }
}

impl PlanOptions {
    /// The plan-relevant subset of a session config.
    pub fn from_config(cfg: &SessionConfig) -> PlanOptions {
        PlanOptions { partitions: cfg.num_partitions, regrow: cfg.regrow, seed: cfg.seed }
    }
}

/// Stage 1: a graph made inference-ready. Construction is free; each
/// derived artifact — the content fingerprint (FNV-1a over node count,
/// edges, and feature bits — the plan-cache key), the symmetric CSR
/// closure, and the dense row-major feature matrix — materializes
/// lazily on first use and is then reused, so every consumer pays only
/// for what it touches: a kernel harness that wants the CSR never
/// hashes, and a plan-cache hit never builds the CSR or the matrix.
pub struct PreparedGraph<'g> {
    pub graph: &'g EdaGraph,
    fingerprint: OnceCell<u64>,
    csr: OnceCell<Csr>,
    features: OnceCell<Vec<f32>>,
}

impl<'g> PreparedGraph<'g> {
    pub fn new(graph: &'g EdaGraph) -> PreparedGraph<'g> {
        PreparedGraph {
            graph,
            fingerprint: OnceCell::new(),
            csr: OnceCell::new(),
            features: OnceCell::new(),
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.graph.num_nodes
    }

    /// Content fingerprint: equal fingerprints ⇒ equal plan inputs.
    /// Hashed on first call (O(edges + features), far cheaper than one
    /// partitioning pass — the integrity price of cacheable plans).
    pub fn fingerprint(&self) -> u64 {
        *self.fingerprint.get_or_init(|| fingerprint_graph(self.graph))
    }

    /// Symmetric closure of the directed EDA edges — the aggregation
    /// operand every downstream stage partitions and multiplies against.
    /// Built on first call, shared by every later plan.
    pub fn csr(&self) -> &Csr {
        self.csr
            .get_or_init(|| Csr::symmetric_from_edges(self.graph.num_nodes, &self.graph.edges))
    }

    /// Dense features, row-major `[num_nodes × GROOT_FEATURE_DIM]` — the
    /// gather source for every plan's per-partition buffers. Built on
    /// first call.
    pub fn features(&self) -> &[f32] {
        self.features.get_or_init(|| {
            let mut f = Vec::with_capacity(self.graph.num_nodes * GROOT_FEATURE_DIM);
            for row in &self.graph.features {
                f.extend_from_slice(row);
            }
            f
        })
    }

    /// Shared front half of [`Self::plan`] / [`Self::plan_stats`]:
    /// partition + Algorithm-1 re-growth, with gather_time left at zero.
    fn partition_and_regrow(&self, opts: &PlanOptions) -> (Vec<RegrownPartition>, PlanStats) {
        // Force lazy CSR materialization outside the stage timer so
        // partition_time means the same thing on every plan, not just
        // the first one on this PreparedGraph.
        let graph_csr = self.csr();

        let t0 = Instant::now();
        let partitioning = if opts.partitions <= 1 {
            Partitioning { k: 1, assignment: vec![0; self.graph.num_nodes] }
        } else {
            partition_kway(graph_csr, opts.partitions, opts.seed)
        };
        let partition_time = t0.elapsed();

        let t1 = Instant::now();
        let parts = regrow_partitions(graph_csr, &partitioning, opts.regrow);
        let regrowth_time = t1.elapsed();
        let regrowth = crate::regrowth::stats(&parts);
        let stats = PlanStats {
            partition_time,
            regrowth_time,
            gather_time: Duration::ZERO,
            regrowth,
        };
        (parts, stats)
    }

    /// Stats-only probe: run the partitioner and re-growth and report the
    /// timings/boundary arithmetic WITHOUT materializing per-partition
    /// CSRs or gathering feature buffers. This is what the memory
    /// harnesses sweep — a full [`Self::plan`] would inflate the very
    /// RSS they measure with buffers nobody executes.
    pub fn plan_stats(&self, opts: &PlanOptions) -> PlanStats {
        self.partition_and_regrow(opts).1
    }

    /// Stage 2: partition, re-grow, and gather — everything request-shaped
    /// that does not need the backend. The result owns all its buffers and
    /// can be cached, shared (`Arc`), and executed any number of times.
    pub fn plan(&self, opts: &PlanOptions) -> PartitionPlan {
        let (parts, mut stats) = self.partition_and_regrow(opts);
        let dense = self.features();

        let t2 = Instant::now();
        let parts: Vec<PlannedPartition> = parts
            .into_iter()
            .map(|part| {
                let csr = part.csr();
                let mut features =
                    Vec::with_capacity(part.nodes.len() * GROOT_FEATURE_DIM);
                for &g in &part.nodes {
                    let at = g as usize * GROOT_FEATURE_DIM;
                    features.extend_from_slice(&dense[at..at + GROOT_FEATURE_DIM]);
                }
                // Keep only what execution needs — the edge list is fully
                // encoded in the local CSR; retaining it too would double
                // every cached plan's adjacency footprint.
                PlannedPartition {
                    part_id: part.part_id,
                    nodes: part.nodes,
                    num_core: part.num_core,
                    csr,
                    features,
                }
            })
            .collect();
        stats.gather_time = t2.elapsed();

        PartitionPlan {
            fingerprint: self.fingerprint(),
            options: opts.clone(),
            num_nodes: self.graph.num_nodes,
            parts,
            stats,
        }
    }
}

/// One partition, execution-ready: the re-grown node set plus its local
/// CSR and pre-gathered feature buffer (all built at plan time so a
/// cached plan re-executes without touching the source graph). The
/// re-grown edge list is not retained — the local CSR already encodes
/// it, and cached plans should carry the adjacency once, not twice.
#[derive(Clone, Debug)]
pub struct PlannedPartition {
    pub part_id: usize,
    /// Global node ids; core first, then boundary.
    pub nodes: Vec<u32>,
    /// Locals `0..num_core` are core nodes (classified by this
    /// partition); the rest are re-grown boundary feature providers.
    pub num_core: usize,
    /// Local symmetric adjacency (partition-local ids, core nodes first).
    pub csr: Csr,
    /// Gathered features, row-major `[nodes.len() × GROOT_FEATURE_DIM]`.
    pub features: Vec<f32>,
}

impl PlannedPartition {
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

/// Where the plan-build time went (paid once per `(graph, options)` when
/// the plan cache is warm).
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanStats {
    pub partition_time: Duration,
    pub regrowth_time: Duration,
    /// Per-partition local-CSR build + feature gather.
    pub gather_time: Duration,
    pub regrowth: RegrowthStats,
}

/// Stage-2 output: a reusable, backend-independent execution plan.
#[derive(Clone, Debug)]
pub struct PartitionPlan {
    /// Fingerprint of the graph this plan was built from.
    pub fingerprint: u64,
    pub options: PlanOptions,
    /// Node count of the source graph (stitch target size).
    pub num_nodes: usize,
    pub parts: Vec<PlannedPartition>,
    pub stats: PlanStats,
}

impl PartitionPlan {
    pub fn num_partitions(&self) -> usize {
        self.parts.len()
    }
}

/// Stage-3 observability, folded into [`super::RunStats`] by
/// `Session::classify_plan`.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub infer_time: Duration,
    /// Largest row count any backend call materialized (bucket padding
    /// included on the PJRT path).
    pub peak_bucket_n: usize,
    /// Partitions submitted in the single `infer_batch` call.
    pub batch_size: usize,
}

/// Stage 3: submit every (non-empty) partition of the plan through ONE
/// [`InferenceBackend::infer_batch`] call and stitch each partition's
/// core-node argmax back into a graph-ordered prediction vector.
///
/// Batching at this seam is what lets the PJRT path amortize bucket
/// padding across partitions and the native path reuse one scratch
/// acquisition for the whole plan.
pub fn execute_plan(
    backend: &dyn InferenceBackend,
    plan: &PartitionPlan,
) -> Result<(Vec<u8>, ExecStats)> {
    let live: Vec<&PlannedPartition> =
        plan.parts.iter().filter(|p| !p.is_empty()).collect();
    let inputs: Vec<PartitionInput<'_>> = live
        .iter()
        .map(|p| PartitionInput {
            csr: &p.csr,
            features: &p.features,
            feature_dim: GROOT_FEATURE_DIM,
        })
        .collect();

    let t0 = Instant::now();
    let outs = backend.infer_batch(&inputs)?;
    let infer_time = t0.elapsed();
    anyhow::ensure!(
        outs.len() == inputs.len(),
        "backend returned {} outputs for {} partitions",
        outs.len(),
        inputs.len()
    );

    let classes = backend.num_classes();
    let mut pred = vec![0u8; plan.num_nodes];
    let mut peak_bucket_n = 0usize;
    for (p, out) in live.iter().zip(&outs) {
        peak_bucket_n = peak_bucket_n.max(out.bucket_rows);
        anyhow::ensure!(
            out.logits.len() >= p.num_core * classes,
            "partition {}: {} logits < {} core nodes × {classes} classes",
            p.part_id,
            out.logits.len(),
            p.num_core
        );
        for (i, &g) in p.nodes[..p.num_core].iter().enumerate() {
            let row = &out.logits[i * classes..(i + 1) * classes];
            pred[g as usize] = super::argmax(row);
        }
    }
    Ok((pred, ExecStats { infer_time, peak_bucket_n, batch_size: inputs.len() }))
}

#[derive(Clone, Debug, PartialEq)]
struct PlanKey {
    fingerprint: u64,
    options: PlanOptions,
}

/// A small LRU of `Arc<PartitionPlan>` keyed by `(graph fingerprint,
/// PlanOptions)`. A hit skips partitioning, re-growth, and feature
/// gathering entirely; the serving router owns one of these so every
/// repeat request on the same circuit is plan-free.
///
/// Entries are kept most-recently-used last; inserting at capacity
/// evicts the least-recently-used entry.
pub struct PlanCache {
    capacity: usize,
    /// (key, plan), LRU order: index 0 is the eviction candidate.
    entries: Vec<(PlanKey, Arc<PartitionPlan>)>,
    hits: u64,
    misses: u64,
}

/// Default router plan-cache capacity (plans for a handful of distinct
/// circuits × option sets; each entry holds one graph's partition data).
pub const DEFAULT_PLAN_CACHE_CAPACITY: usize = 16;

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new(DEFAULT_PLAN_CACHE_CAPACITY)
    }
}

impl PlanCache {
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache { capacity: capacity.max(1), entries: Vec::new(), hits: 0, misses: 0 }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Look up a plan, refreshing its recency on a hit.
    pub fn get(&mut self, fingerprint: u64, opts: &PlanOptions) -> Option<Arc<PartitionPlan>> {
        match self
            .entries
            .iter()
            .position(|(k, _)| k.fingerprint == fingerprint && &k.options == opts)
        {
            Some(i) => {
                let entry = self.entries.remove(i);
                let plan = entry.1.clone();
                self.entries.push(entry);
                self.hits += 1;
                Some(plan)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) a plan, evicting the LRU entry at capacity.
    pub fn insert(&mut self, plan: Arc<PartitionPlan>) {
        let key = PlanKey { fingerprint: plan.fingerprint, options: plan.options.clone() };
        if let Some(i) = self.entries.iter().position(|(k, _)| *k == key) {
            self.entries.remove(i);
        } else if self.entries.len() >= self.capacity {
            self.entries.remove(0);
        }
        self.entries.push((key, plan));
    }

    /// The staged lookup the router runs per request: returns the cached
    /// plan (hit = `true`) or builds, caches, and returns a fresh one.
    pub fn get_or_build(
        &mut self,
        prepared: &PreparedGraph<'_>,
        opts: &PlanOptions,
    ) -> (Arc<PartitionPlan>, bool) {
        if let Some(plan) = self.get(prepared.fingerprint(), opts) {
            return (plan, true);
        }
        let plan = Arc::new(prepared.plan(opts));
        self.insert(plan.clone());
        (plan, false)
    }
}

/// FNV-1a-style hash over the plan-relevant graph content: node count,
/// edge list, feature bits. Mixes one 64-bit word per multiply (an edge
/// pair, or an f32's bits) rather than byte-at-a-time — this runs on
/// every server request as the cache key, and word granularity is an 8×
/// cheaper mix with the same discrimination for that job. Not a
/// cryptographic digest: `classify_plan` backstops collisions across
/// different-sized graphs with a structural node-count check, and equal
/// content always produces equal plans regardless.
fn fingerprint_graph(graph: &EdaGraph) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |word: u64| {
        h ^= word;
        h = h.wrapping_mul(PRIME);
    };
    eat(graph.num_nodes as u64);
    eat(graph.edges.len() as u64);
    for &(a, b) in &graph.edges {
        eat(((a as u64) << 32) | b as u64);
    }
    for f in &graph.features {
        for &v in f {
            eat(v.to_bits() as u64);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetKind};

    fn graph() -> EdaGraph {
        datasets::build(DatasetKind::Csa, 6).unwrap()
    }

    #[test]
    fn fingerprint_tracks_content() {
        let g1 = graph();
        let g2 = graph();
        assert_eq!(fingerprint_graph(&g1), fingerprint_graph(&g2));
        let mut g3 = g2.clone();
        g3.features[0][0] += 1.0;
        assert_ne!(fingerprint_graph(&g2), fingerprint_graph(&g3));
        let mut g4 = g2.clone();
        g4.edges.swap(0, 1);
        assert_ne!(fingerprint_graph(&g2), fingerprint_graph(&g4));
    }

    #[test]
    fn prepared_graph_flattens_features_lazily() {
        let g = graph();
        let p = PreparedGraph::new(&g);
        assert_eq!(p.features().len(), g.num_nodes * GROOT_FEATURE_DIM);
        assert_eq!(p.csr().num_nodes(), g.num_nodes);
        assert_eq!(&p.features()[..GROOT_FEATURE_DIM], &g.features[0]);
        // repeated access reuses the materialized buffers
        assert!(std::ptr::eq(p.csr(), p.csr()));
        assert!(std::ptr::eq(p.features(), p.features()));
    }

    #[test]
    fn plan_partitions_cover_all_nodes_exactly_once() {
        let g = graph();
        let p = PreparedGraph::new(&g);
        let plan = p.plan(&PlanOptions { partitions: 4, regrow: true, seed: 0 });
        assert_eq!(plan.num_partitions(), 4);
        let mut seen = vec![0usize; g.num_nodes];
        for part in &plan.parts {
            assert_eq!(part.features.len(), part.nodes.len() * GROOT_FEATURE_DIM);
            assert_eq!(part.csr.num_nodes(), part.nodes.len());
            for &n in &part.nodes[..part.num_core] {
                seen[n as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "core cover is not a partition");
    }

    #[test]
    fn plan_cache_hits_and_evicts_lru() {
        let g = graph();
        let p = PreparedGraph::new(&g);
        let mut cache = PlanCache::new(2);
        let o1 = PlanOptions { partitions: 1, regrow: true, seed: 0 };
        let o2 = PlanOptions { partitions: 2, regrow: true, seed: 0 };
        let o3 = PlanOptions { partitions: 3, regrow: true, seed: 0 };

        let (_, hit) = cache.get_or_build(&p, &o1);
        assert!(!hit);
        let (_, hit) = cache.get_or_build(&p, &o1);
        assert!(hit, "same (fingerprint, options) must hit");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));

        cache.get_or_build(&p, &o2);
        cache.get_or_build(&p, &o3); // capacity 2: evicts o1 (LRU)
        assert_eq!(cache.len(), 2);
        assert!(cache.get(p.fingerprint(), &o1).is_none(), "o1 must be evicted");
        assert!(cache.get(p.fingerprint(), &o2).is_some());
        assert!(cache.get(p.fingerprint(), &o3).is_some());
    }

    #[test]
    fn cache_misses_on_different_options_or_content() {
        let g = graph();
        let p = PreparedGraph::new(&g);
        let mut cache = PlanCache::default();
        let o = PlanOptions { partitions: 2, regrow: true, seed: 0 };
        cache.get_or_build(&p, &o);
        assert!(cache
            .get(p.fingerprint(), &PlanOptions { seed: 1, ..o.clone() })
            .is_none());
        assert!(cache
            .get(p.fingerprint(), &PlanOptions { regrow: false, ..o.clone() })
            .is_none());
        assert!(cache.get(p.fingerprint() ^ 1, &o).is_none());
    }
}

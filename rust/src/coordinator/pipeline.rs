//! Staged verification pipeline — the composable, cacheable form of the
//! Fig. 2 flow.
//!
//! The monolithic `classify` call is decomposed into three reusable
//! stage objects so repeated work on the same circuit is paid once:
//!
//! ```text
//! EdaGraph ─────┐
//! GraphSource ──┴► PreparedGraph     legacy borrow OR compact columnar
//!     │              │               CircuitGraph; symmetric CSR +
//!     │              │               content fingerprint (built once);
//!     │              │               dense features only where a
//!     │              │               consumer actually asks
//!     │              ├─ .plan(&PlanOptions)
//!     │              │    PartitionPlan   partition → re-grow → per-
//!     │              │    partition local CSRs + gathered features
//!     │              │    (fully owned ⇒ LRU-cacheable) → execute_plan:
//!     │              │    ONE infer_batch over ALL partitions
//!     │              └─ .plan_stream(&PlanOptions)
//!     │                   StreamPlan      assignment + core lists only;
//!     │                   execute_plan_streaming re-grows/gathers one
//!     │                   bounded WINDOW of partitions at a time —
//!     │                   out-of-core: peak f32 working set ∝ largest
//!     │                   window, not the whole graph
//!     ▼
//! ClassifyResult (via Session::classify_plan / classify_streaming)
//! ```
//!
//! `PartitionPlan` is fully owned (no borrows into the source graph), so
//! plans are cacheable: [`PlanCache`] is a small LRU keyed by
//! `(fingerprint, PlanOptions)` — a warm hit skips partitioning,
//! re-growth, and feature gathering entirely. The serving workers
//! ([`super::server`]) share one [`ShardedPlanCache`]; `Session::classify`
//! remains as the thin eager composition of the three stages.
//!
//! The fingerprint is representation-independent: a circuit ingested
//! through a [`GraphSource`] hashes identically to its legacy `EdaGraph`
//! form (same node features, same destination-grouped edge sequence), so
//! cached plans and staleness guards work across both.

use super::SessionConfig;
use crate::backend::{InferenceBackend, PartitionInput};
use crate::features::{EdaGraph, GROOT_FEATURE_DIM};
use crate::graph::{CircuitGraph, Csr, GraphSource};
use crate::obs::{self, metrics};
use crate::partition::{partition_kway_threads, Partitioning};
use crate::regrowth::{regrow_one, regrow_partitions_threads, RegrownPartition, RegrowthStats};
use crate::util::pool::{default_threads, parallel_map};
use anyhow::Result;
use std::sync::Arc;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// The per-request knobs a plan depends on, plus one execution hint
/// (`threads`). The four semantic fields form the complete plan-cache key
/// alongside the graph fingerprint; `threads` only changes how fast the
/// plan is built — the parallel partitioner/regrowth/gather are pinned
/// byte-identical across budgets — so it is deliberately EXCLUDED from
/// the manual `PartialEq`/`Hash` impls below and never serialized to the
/// plan store (two requests differing only in thread budget share one
/// cached plan).
#[derive(Clone, Debug)]
pub struct PlanOptions {
    /// Number of partitions (1 = no partitioning).
    pub partitions: usize,
    /// Apply Algorithm-1 boundary re-growth.
    pub regrow: bool,
    /// Partitioner seed.
    pub seed: u64,
    /// HD/LD degree threshold: rows with degree ≥ this take the GROOT
    /// engine's HD path, and [`PlanStats`] reports the resulting row
    /// split (so the bench harness can correlate threshold with
    /// throughput). Default 512 or the `GROOT_HD_THRESHOLD` env.
    pub hd_threshold: usize,
    /// Thread budget for building the plan (0 = process default). An
    /// execution hint, not part of the plan's identity.
    pub threads: usize,
}

// Manual equality/hashing over the four SEMANTIC fields only: `threads`
// must not fragment the plan cache (both impls are written by hand so
// Hash and Eq stay consistent).
impl PartialEq for PlanOptions {
    fn eq(&self, other: &Self) -> bool {
        self.partitions == other.partitions
            && self.regrow == other.regrow
            && self.seed == other.seed
            && self.hd_threshold == other.hd_threshold
    }
}

impl Eq for PlanOptions {}

impl std::hash::Hash for PlanOptions {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.partitions.hash(state);
        self.regrow.hash(state);
        self.seed.hash(state);
        self.hd_threshold.hash(state);
    }
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions {
            partitions: 1,
            regrow: true,
            seed: 0,
            hd_threshold: crate::spmm::default_hd_threshold(),
            threads: 0,
        }
    }
}

impl PlanOptions {
    /// The plan-relevant subset of a session config.
    pub fn from_config(cfg: &SessionConfig) -> PlanOptions {
        PlanOptions {
            partitions: cfg.num_partitions,
            regrow: cfg.regrow,
            seed: cfg.seed,
            hd_threshold: cfg.hd_threshold,
            threads: cfg.threads,
        }
    }

    /// Resolved plan-build thread budget (`0` means the process default).
    pub fn build_threads(&self) -> usize {
        if self.threads == 0 {
            default_threads()
        } else {
            self.threads
        }
    }
}

/// The two circuit representations a prepared graph can sit on.
enum Repr<'g> {
    /// Borrowed legacy graph: dense `[f32; 4]` rows + tuple edge list.
    Legacy(&'g EdaGraph),
    /// Compact columnar store from streaming ingestion: packed
    /// descriptor bytes + flat CSR edge arrays; feature rows are decoded
    /// on gather, never held whole-graph. `Cow` so the serving workers
    /// can prepare a queued request's circuit by reference
    /// ([`PreparedGraph::from_circuit_ref`]) while ingestion hands over
    /// owned stores ([`PreparedGraph::from_circuit`]).
    Compact(std::borrow::Cow<'g, CircuitGraph>),
}

/// Stage 1: a graph made inference-ready, over either representation.
/// Construction is free; each derived artifact — the content fingerprint
/// (FNV-1a over node count, edges, and feature bits — the plan-cache
/// key) and the symmetric CSR closure — materializes lazily on first use
/// and is then reused. Dense whole-graph features exist only where a
/// consumer explicitly asks ([`Self::features`]): on the legacy
/// representation that is a zero-copy reinterpret of the graph's own
/// row storage; on the compact representation it is a decode-once
/// fallback the streaming execution path never touches.
pub struct PreparedGraph<'g> {
    repr: Repr<'g>,
    // OnceLock (not cell::OnceCell): prepared graphs are shared across
    // threads — the overlapped streaming executor gathers window W+1 on
    // a second thread while W infers — so lazy materialization must be
    // thread-safe.
    fingerprint: OnceLock<u64>,
    csr: OnceLock<Csr>,
    /// Compact-representation dense fallback only (legacy borrows the
    /// source rows directly).
    dense: OnceLock<Vec<f32>>,
}

impl PreparedGraph<'static> {
    /// Ingest a [`GraphSource`] into a compact [`CircuitGraph`] and wrap
    /// it — the streaming entry point: no dense feature matrix, no tuple
    /// edge list, at any point of the pipeline.
    pub fn from_source<S: GraphSource>(src: S) -> Result<PreparedGraph<'static>> {
        Ok(Self::from_circuit(CircuitGraph::from_source(src)?))
    }

    /// Wrap an already-ingested compact circuit.
    pub fn from_circuit(circuit: CircuitGraph) -> PreparedGraph<'static> {
        PreparedGraph {
            repr: Repr::Compact(std::borrow::Cow::Owned(circuit)),
            fingerprint: OnceLock::new(),
            csr: OnceLock::new(),
            dense: OnceLock::new(),
        }
    }
}

impl<'g> PreparedGraph<'g> {
    /// Wrap a borrowed compact circuit — the serving-worker entry point:
    /// a queued request owns its `CircuitGraph`, and preparation must
    /// not clone a 134M-node column store just to hash and plan it.
    pub fn from_circuit_ref(circuit: &'g CircuitGraph) -> PreparedGraph<'g> {
        PreparedGraph {
            repr: Repr::Compact(std::borrow::Cow::Borrowed(circuit)),
            fingerprint: OnceLock::new(),
            csr: OnceLock::new(),
            dense: OnceLock::new(),
        }
    }
}

impl<'g> PreparedGraph<'g> {
    pub fn new(graph: &'g EdaGraph) -> PreparedGraph<'g> {
        PreparedGraph {
            repr: Repr::Legacy(graph),
            fingerprint: OnceLock::new(),
            csr: OnceLock::new(),
            dense: OnceLock::new(),
        }
    }

    pub fn num_nodes(&self) -> usize {
        match &self.repr {
            Repr::Legacy(g) => g.num_nodes,
            Repr::Compact(c) => c.num_nodes(),
        }
    }

    pub fn num_edges(&self) -> usize {
        match &self.repr {
            Repr::Legacy(g) => g.num_edges(),
            Repr::Compact(c) => c.num_edges(),
        }
    }

    pub fn name(&self) -> &str {
        match &self.repr {
            Repr::Legacy(g) => &g.name,
            Repr::Compact(c) => &c.name,
        }
    }

    /// AIG-node prefix length (PO graph nodes start here for single-copy
    /// layouts) — what algebraic verification consumes.
    pub fn num_aig_nodes(&self) -> usize {
        match &self.repr {
            Repr::Legacy(g) => g.num_aig_nodes,
            Repr::Compact(c) => c.num_aig_nodes(),
        }
    }

    /// The legacy graph, when this prepared graph borrows one.
    pub fn eda(&self) -> Option<&EdaGraph> {
        match &self.repr {
            Repr::Legacy(g) => Some(g),
            Repr::Compact(_) => None,
        }
    }

    /// The compact columnar store, when this prepared graph holds one.
    pub fn circuit(&self) -> Option<&CircuitGraph> {
        match &self.repr {
            Repr::Legacy(_) => None,
            Repr::Compact(c) => Some(&**c),
        }
    }

    /// Ground-truth class per node. Borrowed on the compact
    /// representation (its label column is already `u8`): the streaming
    /// path must not clone a whole-graph column per run just to score
    /// accuracy. Legacy converts `NodeClass` → `u8` into an owned Vec.
    pub fn labels_u8(&self) -> std::borrow::Cow<'_, [u8]> {
        match &self.repr {
            Repr::Legacy(g) => std::borrow::Cow::Owned(g.labels_u8()),
            Repr::Compact(c) => std::borrow::Cow::Borrowed(c.labels_u8()),
        }
    }

    /// Heap bytes of the underlying representation's content — what the
    /// memory harness compares across layouts.
    pub fn resident_bytes(&self) -> usize {
        match &self.repr {
            Repr::Legacy(g) => g.resident_bytes(),
            Repr::Compact(c) => c.resident_bytes(),
        }
    }

    /// Content fingerprint: equal fingerprints ⇒ equal plan inputs.
    /// Hashed on first call (O(edges + features), far cheaper than one
    /// partitioning pass — the integrity price of cacheable plans), and
    /// identical across representations of the same circuit.
    pub fn fingerprint(&self) -> u64 {
        *self.fingerprint.get_or_init(|| match &self.repr {
            Repr::Legacy(g) => fingerprint_graph(g),
            Repr::Compact(c) => fingerprint_content(
                c.num_nodes(),
                c.num_edges(),
                c.edges_iter(),
                (0..c.num_nodes()).map(|u| c.feature_row(u)),
            ),
        })
    }

    /// Symmetric closure of the directed EDA edges — the aggregation
    /// operand every downstream stage partitions and multiplies against.
    /// Built on first call, shared by every later plan.
    pub fn csr(&self) -> &Csr {
        self.csr.get_or_init(|| match &self.repr {
            Repr::Legacy(g) => Csr::symmetric_from_edges(g.num_nodes, &g.edges),
            Repr::Compact(c) => c.symmetric_csr(),
        })
    }

    /// Dense features, row-major `[num_nodes × GROOT_FEATURE_DIM]`.
    /// Legacy representation: a zero-copy reinterpret of the graph's
    /// contiguous `Vec<[f32; 4]>` storage (no duplicate matrix). Compact
    /// representation: a decode-once fallback for full-graph consumers
    /// (validation eval, the GAMORA-style comparator) — the partitioned
    /// execution stages never call this; they gather per partition.
    pub fn features(&self) -> &[f32] {
        match &self.repr {
            Repr::Legacy(g) => g.features_flat(),
            Repr::Compact(c) => self.dense.get_or_init(|| {
                let mut f = Vec::with_capacity(c.num_nodes() * GROOT_FEATURE_DIM);
                for u in 0..c.num_nodes() {
                    f.extend_from_slice(&c.feature_row(u));
                }
                f
            }),
        }
    }

    /// Append the feature rows of `nodes` to `out` — the per-partition
    /// gather. On the compact representation this decodes packed bytes
    /// directly; no whole-graph matrix is ever materialized.
    pub fn gather_features_into(&self, nodes: &[u32], out: &mut Vec<f32>) {
        match &self.repr {
            Repr::Legacy(g) => {
                let dense = g.features_flat();
                out.reserve(nodes.len() * GROOT_FEATURE_DIM);
                for &u in nodes {
                    let at = u as usize * GROOT_FEATURE_DIM;
                    out.extend_from_slice(&dense[at..at + GROOT_FEATURE_DIM]);
                }
            }
            Repr::Compact(c) => c.gather_features_into(nodes, out),
        }
    }

    /// Shared front half of [`Self::plan`] / [`Self::plan_stats`]:
    /// partition + Algorithm-1 re-growth, with gather_time left at zero.
    fn partition_and_regrow(&self, opts: &PlanOptions) -> (Vec<RegrownPartition>, PlanStats) {
        // Force lazy CSR materialization outside the stage timer so
        // partition_time means the same thing on every plan, not just
        // the first one on this PreparedGraph.
        {
            let _span = obs::span("prepare", "pipeline");
            self.csr();
        }

        let t0 = Instant::now();
        let partitioning = {
            let _span = obs::span_with_arg("partition", "pipeline", "k", || {
                opts.partitions.to_string()
            });
            self.partition(opts)
        };
        let partition_time = t0.elapsed();
        plan_build_metrics().partition.observe(partition_time.as_secs_f64());
        self.regrow_and_stats(&partitioning, opts, partition_time)
    }

    /// Back half of [`Self::partition_and_regrow`], callable with an
    /// externally supplied assignment (the incremental reuse path):
    /// Algorithm-1 re-growth + the degree-split scan, with gather_time
    /// (and per-partition digests) left to the plan finisher.
    fn regrow_and_stats(
        &self,
        partitioning: &Partitioning,
        opts: &PlanOptions,
        partition_time: Duration,
    ) -> (Vec<RegrownPartition>, PlanStats) {
        let graph_csr = self.csr();
        let t1 = Instant::now();
        let parts = {
            let _span = obs::span("regrowth", "pipeline");
            regrow_partitions_threads(graph_csr, partitioning, opts.regrow, opts.build_threads())
        };
        let regrowth_time = t1.elapsed();
        plan_build_metrics().regrowth.observe(regrowth_time.as_secs_f64());
        let regrowth = crate::regrowth::stats(&parts);
        // HD/LD row split at the configured threshold — one O(n) scan of
        // the degree array, reported by `plan_stats` too so the memory
        // harnesses and bench sweeps see it without building partitions.
        let (mut hd_rows, mut ld_rows) = (0usize, 0usize);
        for u in 0..graph_csr.num_nodes() {
            let d = graph_csr.degree(u);
            if d >= opts.hd_threshold.max(1) {
                hd_rows += 1;
            } else if d > 0 {
                ld_rows += 1;
            }
        }
        // Partition quality (ROADMAP 5a): with re-growth on, every cut
        // edge appears as a crossing edge in both endpoint partitions, so
        // crossing/2 IS the edge cut — no extra scan. The ablation path
        // (regrow=false) has no crossing edges and pays one O(m) count.
        let edge_cut = if opts.regrow {
            regrowth.total_crossing_edges / 2
        } else {
            partitioning.edge_cut(graph_csr)
        };
        let replication = if regrowth.total_core_nodes == 0 {
            1.0
        } else {
            (regrowth.total_core_nodes + regrowth.total_boundary_nodes) as f64
                / regrowth.total_core_nodes as f64
        };
        let stats = PlanStats {
            partition_time,
            regrowth_time,
            gather_time: Duration::ZERO,
            regrowth,
            hd_rows,
            ld_rows,
            edge_cut,
            replication,
            balance: partitioning.balance(),
            content_digest: 0,
        };
        (parts, stats)
    }

    fn partition(&self, opts: &PlanOptions) -> Partitioning {
        if opts.partitions <= 1 {
            Partitioning { k: 1, assignment: vec![0; self.num_nodes()] }
        } else {
            partition_kway_threads(self.csr(), opts.partitions, opts.seed, opts.build_threads())
        }
    }

    /// Stats-only probe: run the partitioner and re-growth and report the
    /// timings/boundary arithmetic WITHOUT retaining per-partition CSRs
    /// or feature buffers. This is what the memory harnesses sweep — a
    /// full [`Self::plan`] would inflate the very RSS they measure with
    /// buffers nobody executes. Per-partition content digests ARE
    /// computed (folded into [`PlanStats::content_digest`]) from
    /// transient one-partition scratch buffers, so the transient
    /// high-water mark is one partition's CSR + features, never the
    /// whole plan.
    pub fn plan_stats(&self, opts: &PlanOptions) -> PlanStats {
        let (parts, mut stats) = self.partition_and_regrow(opts);
        let mut features = Vec::new();
        let digests = parts.iter().map(|part| {
            let csr = part.csr();
            features.clear();
            self.gather_features_into(&part.nodes, &mut features);
            PlannedPartition::compute_digest(part.num_core, &part.nodes, &csr, &features)
        });
        stats.content_digest = combine_part_digests(digests);
        stats
    }

    /// Stage 2 (eager): partition, re-grow, and gather — everything
    /// request-shaped that does not need the backend. The result owns all
    /// its buffers and can be cached, shared (`Arc`), and executed any
    /// number of times.
    pub fn plan(&self, opts: &PlanOptions) -> PartitionPlan {
        let (parts, stats) = self.partition_and_regrow(opts);
        self.finish_plan(parts, stats, opts)
    }

    /// [`Self::plan`] with an externally supplied partition assignment —
    /// the incremental reuse path. When an edit is topology-preserving
    /// (node descriptors change, edges do not), the symmetric CSR is
    /// identical to the base graph's, so the deterministic k-way
    /// partitioner would return exactly the base assignment; reusing it
    /// skips that invocation while producing a byte-identical plan.
    /// Rejects assignments whose shape does not match the graph/options
    /// (callers must not feed a stale assignment past the digest layer).
    pub fn plan_with_assignment(
        &self,
        opts: &PlanOptions,
        partitioning: &Partitioning,
    ) -> Result<PartitionPlan> {
        anyhow::ensure!(
            partitioning.assignment.len() == self.num_nodes(),
            "assignment covers {} nodes but the graph has {}",
            partitioning.assignment.len(),
            self.num_nodes()
        );
        anyhow::ensure!(
            partitioning.k == opts.partitions.max(1),
            "assignment has k={} but the options ask for {} partitions",
            partitioning.k,
            opts.partitions.max(1)
        );
        {
            let _span = obs::span("prepare", "pipeline");
            self.csr();
        }
        let (parts, stats) = self.regrow_and_stats(partitioning, opts, Duration::ZERO);
        Ok(self.finish_plan(parts, stats, opts))
    }

    /// Shared back half of the eager planners: build each partition's
    /// local CSR, gather its features, stamp its content digest, and
    /// fold the plan-level digest into the stats.
    fn finish_plan(
        &self,
        parts: Vec<RegrownPartition>,
        mut stats: PlanStats,
        opts: &PlanOptions,
    ) -> PartitionPlan {
        let t2 = Instant::now();
        let _span = obs::span("gather", "pipeline");
        // Partitions are independent: build local CSRs, gather features,
        // and stamp digests concurrently (`PreparedGraph` is Sync — the
        // overlapped streaming executor already shares it across threads).
        // `parallel_map`'s indexed slots keep partition order, so the
        // plan-level digest fold below is thread-count-invariant.
        let nthreads = opts.build_threads().max(1).min(parts.len().max(1));
        let built: Vec<(Csr, Vec<f32>, u64)> = parallel_map(nthreads, parts.len(), |i| {
            let part = &parts[i];
            let csr = part.csr();
            let mut features = Vec::new();
            self.gather_features_into(&part.nodes, &mut features);
            let digest =
                PlannedPartition::compute_digest(part.num_core, &part.nodes, &csr, &features);
            (csr, features, digest)
        });
        // Keep only what execution needs — the edge list is fully encoded
        // in the local CSR; retaining it too would double every cached
        // plan's adjacency footprint. Node lists move, not clone.
        let parts: Vec<PlannedPartition> = parts
            .into_iter()
            .zip(built)
            .map(|(part, (csr, features, digest))| PlannedPartition {
                part_id: part.part_id,
                nodes: part.nodes,
                num_core: part.num_core,
                csr,
                features,
                digest,
            })
            .collect();
        stats.gather_time = t2.elapsed();
        plan_build_metrics().gather.observe(stats.gather_time.as_secs_f64());
        stats.content_digest = combine_part_digests(parts.iter().map(|p| p.digest));

        PartitionPlan {
            fingerprint: self.fingerprint(),
            options: opts.clone(),
            num_nodes: self.num_nodes(),
            parts,
            stats,
        }
    }

    /// Stage 2 (out-of-core): partition only. The result carries the
    /// assignment plus per-partition core COUNTS (4 B/node + 8 B/part) —
    /// no core node lists, no local CSRs, no gathered features.
    /// [`execute_plan_streaming`] inverts the assignment for one bounded
    /// window of partitions at a time, then re-grows and gathers just
    /// that window, so the working set peaks at the largest window
    /// instead of the whole graph.
    pub fn plan_stream(&self, opts: &PlanOptions) -> StreamPlan {
        // CSR outside the timer, as in partition_and_regrow.
        let _ = self.csr();
        let t0 = Instant::now();
        let partitioning = self.partition(opts);
        let partition_time = t0.elapsed();
        let mut core_counts = vec![0usize; partitioning.k];
        for &p in &partitioning.assignment {
            core_counts[p as usize] += 1;
        }
        StreamPlan {
            fingerprint: self.fingerprint(),
            options: opts.clone(),
            num_nodes: self.num_nodes(),
            partitioning,
            core_counts,
            partition_time,
        }
    }
}

/// One partition, execution-ready: the re-grown node set plus its local
/// CSR and pre-gathered feature buffer (all built at plan time so a
/// cached plan re-executes without touching the source graph). The
/// re-grown edge list is not retained — the local CSR already encodes
/// it, and cached plans should carry the adjacency once, not twice.
#[derive(Clone, Debug)]
pub struct PlannedPartition {
    pub part_id: usize,
    /// Global node ids; core first, then boundary.
    pub nodes: Vec<u32>,
    /// Locals `0..num_core` are core nodes (classified by this
    /// partition); the rest are re-grown boundary feature providers.
    pub num_core: usize,
    /// Local symmetric adjacency (partition-local ids, core nodes first).
    pub csr: Csr,
    /// Gathered features, row-major `[nodes.len() × GROOT_FEATURE_DIM]`.
    pub features: Vec<f32>,
    /// Content digest over (core count, global node list, local CSR,
    /// feature bits) — see [`PlannedPartition::compute_digest`]. Equal
    /// digests ⇒ byte-identical core predictions under a deterministic
    /// backend, which is the incremental prediction-cache key.
    pub digest: u64,
}

impl PlannedPartition {
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Per-partition content digest: word-wise FNV-1a over the core
    /// count, the global node-id list (core-first order), the local
    /// symmetric CSR, and the gathered feature bits. This is everything
    /// `infer_batch` + `stitch_core` consume for the partition, plus the
    /// stitch TARGETS (the global ids), so digest equality implies
    /// byte-identical stitched core predictions under a deterministic
    /// backend — regardless of graph representation, thread count,
    /// eager-vs-streaming materialization, or kernel selection (none of
    /// which appear in the hashed content).
    pub fn compute_digest(num_core: usize, nodes: &[u32], csr: &Csr, features: &[f32]) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |word: u64| {
            h ^= word;
            h = h.wrapping_mul(PRIME);
        };
        eat(num_core as u64);
        eat(nodes.len() as u64);
        for &g in nodes {
            eat(g as u64);
        }
        eat(csr.col_idx.len() as u64);
        for &r in &csr.row_ptr {
            eat(r as u64);
        }
        for &c in &csr.col_idx {
            eat(c as u64);
        }
        for &v in features {
            eat(v.to_bits() as u64);
        }
        h
    }

    /// [`Self::compute_digest`] over this partition's own content.
    pub fn content_digest(&self) -> u64 {
        Self::compute_digest(self.num_core, &self.nodes, &self.csr, &self.features)
    }
}

/// Fold per-partition digests into one plan-level content digest
/// (order-sensitive FNV-1a, seeded with the partition count).
pub fn combine_part_digests(digests: impl Iterator<Item = u64>) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut n = 0u64;
    for d in digests {
        h ^= d;
        h = h.wrapping_mul(PRIME);
        n += 1;
    }
    h ^= n;
    h.wrapping_mul(PRIME)
}

/// Per-stage plan-build histograms (`groot_plan_build_seconds`), labeled
/// by stage so the exposition endpoint shows where cold planning time
/// goes — the bench sweep's in-process counterpart.
struct PlanBuildMetrics {
    partition: metrics::Histogram,
    regrowth: metrics::Histogram,
    gather: metrics::Histogram,
}

fn plan_build_metrics() -> &'static PlanBuildMetrics {
    static M: OnceLock<PlanBuildMetrics> = OnceLock::new();
    M.get_or_init(|| {
        let r = metrics::registry();
        const BUCKETS: &[f64] = &[0.001, 0.005, 0.025, 0.1, 0.5, 2.5, 10.0];
        let h = |stage: &str| {
            r.histogram(
                "groot_plan_build_seconds",
                "Cold plan-build wall time by stage (partition / regrowth / gather).",
                &[("stage", stage)],
                BUCKETS,
            )
        };
        PlanBuildMetrics {
            partition: h("partition"),
            regrowth: h("regrowth"),
            gather: h("gather"),
        }
    })
}

/// Where the plan-build time went (paid once per `(graph, options)` when
/// the plan cache is warm).
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanStats {
    pub partition_time: Duration,
    pub regrowth_time: Duration,
    /// Per-partition local-CSR build + feature gather.
    pub gather_time: Duration,
    pub regrowth: RegrowthStats,
    /// Rows with degree ≥ `PlanOptions::hd_threshold` (the GROOT HD
    /// path) / positive-degree rows below it. Isolated nodes count as
    /// neither, so `hd_rows + ld_rows ≤ n`.
    pub hd_rows: usize,
    pub ld_rows: usize,
    /// Partition quality (ROADMAP 5a): undirected edges whose endpoints
    /// land in different partitions — what the multilevel partitioner
    /// minimizes.
    pub edge_cut: usize,
    /// Boundary replication factor: (core + re-grown boundary nodes) /
    /// core nodes. 1.0 means no re-growth overhead; the paper's "≈10%
    /// boundary" claim corresponds to ≈1.1 here.
    pub replication: f64,
    /// Max core-partition size over the ideal n/k (1.0 = perfectly
    /// balanced), matching [`Partitioning::balance`].
    pub balance: f64,
    /// Combined per-partition content digest
    /// ([`combine_part_digests`] over [`PlannedPartition::digest`] in
    /// partition order) — the plan-level identity the incremental layer
    /// compares to decide whether anything changed at all.
    pub content_digest: u64,
}

/// Stage-2 output: a reusable, backend-independent execution plan.
#[derive(Clone, Debug)]
pub struct PartitionPlan {
    /// Fingerprint of the graph this plan was built from.
    pub fingerprint: u64,
    pub options: PlanOptions,
    /// Node count of the source graph (stitch target size).
    pub num_nodes: usize,
    pub parts: Vec<PlannedPartition>,
    pub stats: PlanStats,
}

impl PartitionPlan {
    pub fn num_partitions(&self) -> usize {
        self.parts.len()
    }

    /// Per-partition content digests in partition order — the full list
    /// behind the scalar [`PlanStats::content_digest`] (which stays
    /// `Copy`); the incremental layer diffs these to find dirty
    /// partitions.
    pub fn digests(&self) -> Vec<u64> {
        self.parts.iter().map(|p| p.digest).collect()
    }

    /// Reconstruct the k-way assignment this plan was built from: each
    /// partition's core nodes (`nodes[..num_core]`) are exactly the
    /// nodes assigned to it, and the core sets tile the graph. This is
    /// how the incremental layer recovers a reusable [`Partitioning`]
    /// from a cached plan without re-running the partitioner.
    pub fn extract_assignment(&self) -> Partitioning {
        let mut assignment = vec![0u32; self.num_nodes];
        for part in &self.parts {
            for &g in &part.nodes[..part.num_core] {
                assignment[g as usize] = part.part_id as u32;
            }
        }
        Partitioning { k: self.parts.len().max(1), assignment }
    }
}

/// Stage-2 output of the out-of-core path: the partition assignment
/// (4 B/node) and per-partition core counts only. Core node lists are
/// inverted from the assignment per window, and everything
/// per-partition (re-grown boundary, local CSR, gathered features,
/// logits) is materialized window-by-window inside
/// [`execute_plan_streaming`] and dropped when the window ends.
#[derive(Clone, Debug)]
pub struct StreamPlan {
    pub fingerprint: u64,
    pub options: PlanOptions,
    pub num_nodes: usize,
    pub partitioning: Partitioning,
    /// Core node count per partition (for empty-partition and window
    /// accounting without holding the node lists).
    pub core_counts: Vec<usize>,
    pub partition_time: Duration,
}

impl StreamPlan {
    pub fn num_partitions(&self) -> usize {
        self.core_counts.len()
    }

    /// Invert the assignment for one window of partition ids: core node
    /// lists in ascending global id, exactly `Partitioning::parts()`
    /// order, so windowed re-growth sees the same cores the eager plan
    /// does. Cost: one O(n) scan per window; memory: the window only.
    fn window_cores(&self, ids: &[usize]) -> Vec<Vec<u32>> {
        let mut slot = vec![usize::MAX; self.num_partitions()];
        let mut cores: Vec<Vec<u32>> = Vec::with_capacity(ids.len());
        for (i, &p) in ids.iter().enumerate() {
            slot[p] = i;
            cores.push(Vec::with_capacity(self.core_counts[p]));
        }
        for (u, &p) in self.partitioning.assignment.iter().enumerate() {
            let s = slot[p as usize];
            if s != usize::MAX {
                cores[s].push(u as u32);
            }
        }
        cores
    }
}

/// Stage-3 observability, folded into [`super::RunStats`] by
/// `Session::classify_plan`.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub infer_time: Duration,
    /// Largest row count any backend call materialized (bucket padding
    /// included on the PJRT path).
    pub peak_bucket_n: usize,
    /// Partitions submitted in the single `infer_batch` call.
    pub batch_size: usize,
    /// Execution-buffer bytes live at once: Σ over ALL partitions of
    /// local CSR + gathered features + logits (the eager path holds the
    /// whole plan simultaneously — the number the streaming executor's
    /// windowed peak is compared against).
    pub peak_resident_bytes: usize,
}

/// Stage 3 (eager): submit every (non-empty) partition of the plan
/// through ONE [`InferenceBackend::infer_batch`] call and stitch each
/// partition's core-node argmax back into a graph-ordered prediction
/// vector.
///
/// Batching at this seam is what lets the PJRT path amortize bucket
/// padding across partitions and the native path reuse one scratch
/// acquisition for the whole plan.
pub fn execute_plan(
    backend: &dyn InferenceBackend,
    plan: &PartitionPlan,
) -> Result<(Vec<u8>, ExecStats)> {
    let live: Vec<&PlannedPartition> =
        plan.parts.iter().filter(|p| !p.is_empty()).collect();
    let inputs: Vec<PartitionInput<'_>> = live
        .iter()
        .map(|p| PartitionInput {
            csr: &p.csr,
            features: &p.features,
            feature_dim: GROOT_FEATURE_DIM,
        })
        .collect();

    let classes = backend.num_classes();
    let peak_resident_bytes: usize =
        inputs.iter().map(|i| partition_exec_bytes(i, classes)).sum();

    let t0 = Instant::now();
    let outs = {
        let _span = obs::span_with_arg("infer", "pipeline", "partitions", || {
            inputs.len().to_string()
        });
        backend.infer_batch(&inputs)?
    };
    let infer_time = t0.elapsed();
    anyhow::ensure!(
        outs.len() == inputs.len(),
        "backend returned {} outputs for {} partitions",
        outs.len(),
        inputs.len()
    );

    let mut pred = vec![0u8; plan.num_nodes];
    let mut peak_bucket_n = 0usize;
    {
        let _span = obs::span("stitch", "pipeline");
        for (p, out) in live.iter().zip(&outs) {
            peak_bucket_n = peak_bucket_n.max(out.bucket_rows);
            stitch_core(&mut pred, &p.nodes, p.num_core, &out.logits, classes, p.part_id)?;
        }
    }
    Ok((
        pred,
        ExecStats {
            infer_time,
            peak_bucket_n,
            batch_size: inputs.len(),
            peak_resident_bytes,
        },
    ))
}

/// Execution-buffer bytes one partition holds live: local CSR +
/// gathered features + the logits the backend will return. Shared by
/// both executors so the eager-vs-streaming memory comparisons (tier-1
/// tests, `harness memory`, the capped CI jobs) always compare
/// byte-identical accounting units.
fn partition_exec_bytes(input: &PartitionInput<'_>, classes: usize) -> usize {
    input.resident_bytes() + input.csr.num_nodes() * classes * std::mem::size_of::<f32>()
}

/// Copy one partition's core-node argmax into the graph-ordered
/// prediction vector (shared by the eager and streaming executors so the
/// stitch rule cannot diverge).
fn stitch_core(
    pred: &mut [u8],
    nodes: &[u32],
    num_core: usize,
    logits: &[f32],
    classes: usize,
    part_id: usize,
) -> Result<()> {
    anyhow::ensure!(
        logits.len() >= num_core * classes,
        "partition {part_id}: {} logits < {num_core} core nodes × {classes} classes",
        logits.len(),
    );
    for (i, &g) in nodes[..num_core].iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        pred[g as usize] = super::argmax(row);
    }
    Ok(())
}

/// Stage-3 observability of the out-of-core executor.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    pub regrowth_time: Duration,
    pub gather_time: Duration,
    pub infer_time: Duration,
    /// `infer_batch` calls issued (⌈live partitions / window⌉).
    pub windows: usize,
    /// Largest partition count in any single `infer_batch` call.
    pub max_window: usize,
    /// Largest row count any backend call materialized.
    pub peak_bucket_n: usize,
    /// Peak execution-buffer bytes live at once: max over windows of the
    /// window's local CSRs + gathered features + logits. This is the
    /// out-of-core claim, measured: ∝ largest window, not whole graph.
    pub peak_resident_bytes: usize,
    pub regrowth: RegrowthStats,
}

/// Stage 3 (out-of-core): drive a [`StreamPlan`]'s partitions through
/// the backend one bounded window at a time. Each window re-grows its
/// partitions (Algorithm 1), gathers their features from the prepared
/// graph's store (packed-byte decode on the compact representation),
/// executes ONE `infer_batch` over the window, stitches, and drops every
/// buffer before the next window starts.
///
/// Predictions are byte-identical to [`execute_plan`] on the same
/// `(graph, options)`: partitions are independent under every backend
/// (the batch seam amortizes, it does not mix), re-growth is
/// deterministic per partition, and both paths share [`stitch_core`].
pub fn execute_plan_streaming(
    backend: &dyn InferenceBackend,
    prepared: &PreparedGraph<'_>,
    plan: &StreamPlan,
    window: usize,
) -> Result<(Vec<u8>, StreamStats)> {
    run_streaming(backend, prepared, plan, window, false)
}

/// [`execute_plan_streaming`] with gather/infer overlap: window W+1 is
/// re-grown and gathered on a second thread ([`parallel_join`]) while
/// window W runs `infer_batch` — the outer-pipeline analogue of the
/// paper's kernel-level latency hiding. Predictions stay byte-identical
/// (the work per window is unchanged, only its schedule moves); the
/// memory bound doubles to TWO live windows, which
/// `StreamStats::peak_resident_bytes` reports honestly — memory-capped
/// deployments keep the sequential executor.
pub fn execute_plan_streaming_overlapped(
    backend: &dyn InferenceBackend,
    prepared: &PreparedGraph<'_>,
    plan: &StreamPlan,
    window: usize,
) -> Result<(Vec<u8>, StreamStats)> {
    run_streaming(backend, prepared, plan, window, true)
}

/// One materialized streaming window: re-grown partitions with their
/// local CSRs and gathered feature buffers, plus the regrow/gather time
/// spent building it.
type StreamWindow = (Vec<(RegrownPartition, Csr, Vec<f32>)>, Duration, Duration);

fn run_streaming(
    backend: &dyn InferenceBackend,
    prepared: &PreparedGraph<'_>,
    plan: &StreamPlan,
    window: usize,
    overlap: bool,
) -> Result<(Vec<u8>, StreamStats)> {
    anyhow::ensure!(
        plan.fingerprint == prepared.fingerprint(),
        "stale stream plan for graph '{}': plan expected fingerprint {:016x} but the graph's actual fingerprint is {:016x}",
        prepared.name(),
        plan.fingerprint,
        prepared.fingerprint()
    );
    anyhow::ensure!(
        plan.num_nodes == prepared.num_nodes(),
        "stream plan was built for {} nodes but the graph has {}",
        plan.num_nodes,
        prepared.num_nodes()
    );
    let window = window.max(1);
    let csr = prepared.csr();
    let classes = backend.num_classes();
    let mut pred = vec![0u8; plan.num_nodes];
    let mut stats = StreamStats::default();

    let live: Vec<usize> =
        (0..plan.num_partitions()).filter(|&p| plan.core_counts[p] > 0).collect();
    let chunks: Vec<&[usize]> = live.chunks(window).collect();

    // Materialize one window: invert its core lists, re-grow (Algorithm
    // 1), build local CSRs, gather features. Pure function of the shared
    // plan/prepared state, so the overlapped mode may run it on a second
    // thread while the previous window infers.
    let materialize = |ids: &[usize]| -> StreamWindow {
        let window_cores = plan.window_cores(ids);
        let mut parts: Vec<(RegrownPartition, Csr, Vec<f32>)> = Vec::with_capacity(ids.len());
        let mut regrow_time = Duration::ZERO;
        let mut gather_time = Duration::ZERO;
        for (wi, &p) in ids.iter().enumerate() {
            let t0 = Instant::now();
            let part = regrow_one(
                csr,
                &plan.partitioning.assignment,
                p,
                &window_cores[wi],
                plan.options.regrow,
            );
            regrow_time += t0.elapsed();
            let t1 = Instant::now();
            let local = part.csr();
            let mut features = Vec::new();
            prepared.gather_features_into(&part.nodes, &mut features);
            gather_time += t1.elapsed();
            parts.push((part, local, features));
        }
        (parts, regrow_time, gather_time)
    };

    // Overlapped mode pipelines windows through `pending`; sequential
    // mode materializes each window HERE, at the top of its own
    // iteration, strictly after the previous window's buffers dropped —
    // one live window is the sequential executor's memory contract
    // (the memcap CI jobs run under hard caps sized to it).
    let mut pending: Option<StreamWindow> = if overlap {
        chunks.first().copied().map(&materialize)
    } else {
        None
    };
    for (ci, ids) in chunks.iter().enumerate() {
        // window-local buffers: everything below dies when this window's
        // iteration (sequential) or the NEXT one (overlapped: the
        // prefetched window lives alongside) finishes — that bound IS
        // the memory claim, and `resident` below accounts it
        let (parts, regrow_time, gather_time) = match pending.take() {
            Some(window) => window,
            None => materialize(*ids),
        };
        stats.regrowth_time += regrow_time;
        stats.gather_time += gather_time;
        let inputs: Vec<PartitionInput<'_>> = parts
            .iter()
            .map(|(_, local, features)| PartitionInput {
                csr: local,
                features,
                feature_dim: GROOT_FEATURE_DIM,
            })
            .collect();
        let resident: usize =
            inputs.iter().map(|i| partition_exec_bytes(i, classes)).sum();

        let next_ids: Option<&[usize]> = chunks.get(ci + 1).copied();
        let infer = || {
            let t = Instant::now();
            backend.infer_batch(&inputs).map(|outs| (outs, t.elapsed()))
        };
        let (infer_res, next) = if overlap {
            crate::util::pool::parallel_join(infer, || next_ids.map(&materialize))
        } else {
            // sequential: the next window is NOT built here — doing so
            // would hold two windows live and break the memory bound
            (infer(), None)
        };
        pending = next;

        // Overlapped mode holds the freshly prefetched window alongside
        // the one that just inferred — count both, honestly.
        let prefetched: usize = if overlap {
            pending
                .as_ref()
                .map(|(next_parts, _, _)| {
                    next_parts
                        .iter()
                        .map(|(_, local, features)| {
                            let input = PartitionInput {
                                csr: local,
                                features,
                                feature_dim: GROOT_FEATURE_DIM,
                            };
                            partition_exec_bytes(&input, classes)
                        })
                        .sum()
                })
                .unwrap_or(0)
        } else {
            0
        };
        stats.peak_resident_bytes = stats.peak_resident_bytes.max(resident + prefetched);

        let (outs, infer_time) = infer_res?;
        stats.infer_time += infer_time;
        anyhow::ensure!(
            outs.len() == inputs.len(),
            "backend returned {} outputs for a window of {}",
            outs.len(),
            inputs.len()
        );
        for ((part, _, _), out) in parts.iter().zip(&outs) {
            stats.peak_bucket_n = stats.peak_bucket_n.max(out.bucket_rows);
            stitch_core(&mut pred, &part.nodes, part.num_core, &out.logits, classes, part.part_id)?;
            // fold this partition into the run totals without cloning it
            let r = &mut stats.regrowth;
            r.total_core_nodes += part.num_core;
            r.total_boundary_nodes += part.num_boundary();
            r.total_internal_edges += part.edges.len() - part.num_crossing;
            r.total_crossing_edges += part.num_crossing;
            r.max_partition_nodes = r.max_partition_nodes.max(part.num_nodes());
        }
        stats.windows += 1;
        stats.max_window = stats.max_window.max(ids.len());
    }
    Ok((pred, stats))
}

#[derive(Clone, Debug, PartialEq)]
struct PlanKey {
    fingerprint: u64,
    options: PlanOptions,
}

/// Process-wide plan-cache counters mirrored into the metrics registry
/// (labeled by tier so one family covers the memory LRU and the disk
/// store). The sharded cache keeps its own per-instance atomics for
/// `ServerStats`; these aggregate across all cache instances for the
/// exposition endpoint.
struct CacheMetrics {
    hits: metrics::Counter,
    misses: metrics::Counter,
    disk_hits: metrics::Counter,
}

fn cache_metrics() -> &'static CacheMetrics {
    static M: OnceLock<CacheMetrics> = OnceLock::new();
    M.get_or_init(|| {
        let r = metrics::registry();
        const HELP: &str =
            "Plan-cache lookups by tier and outcome, across every cache instance.";
        CacheMetrics {
            hits: r.counter(
                "groot_plan_cache_lookups_total",
                HELP,
                &[("tier", "memory"), ("outcome", "hit")],
            ),
            misses: r.counter(
                "groot_plan_cache_lookups_total",
                HELP,
                &[("tier", "memory"), ("outcome", "miss")],
            ),
            disk_hits: r.counter(
                "groot_plan_cache_lookups_total",
                HELP,
                &[("tier", "disk"), ("outcome", "hit")],
            ),
        }
    })
}

/// A small LRU of `Arc<PartitionPlan>` keyed by `(graph fingerprint,
/// PlanOptions)`. A hit skips partitioning, re-growth, and feature
/// gathering entirely; single-threaded callers own one of these so every
/// repeat request on the same circuit is plan-free (the multi-worker
/// server shares a [`ShardedPlanCache`] instead).
///
/// Entries are kept most-recently-used last; inserting at capacity
/// evicts the least-recently-used entry.
pub struct PlanCache {
    capacity: usize,
    /// (key, plan), LRU order: index 0 is the eviction candidate.
    entries: Vec<(PlanKey, Arc<PartitionPlan>)>,
    hits: u64,
    misses: u64,
}

/// Default serving plan-cache capacity (plans for a handful of distinct
/// circuits × option sets; each entry holds one graph's partition data).
pub const DEFAULT_PLAN_CACHE_CAPACITY: usize = 16;

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new(DEFAULT_PLAN_CACHE_CAPACITY)
    }
}

impl PlanCache {
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache { capacity: capacity.max(1), entries: Vec::new(), hits: 0, misses: 0 }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Non-mutating lookup: no recency refresh, no counter updates. The
    /// sharded cache's read-locked fast path uses this; single-threaded
    /// callers should prefer [`Self::get`].
    pub fn peek(&self, fingerprint: u64, opts: &PlanOptions) -> Option<Arc<PartitionPlan>> {
        self.entries
            .iter()
            .find(|(k, _)| k.fingerprint == fingerprint && &k.options == opts)
            .map(|(_, plan)| plan.clone())
    }

    /// Look up a plan, refreshing its recency on a hit.
    pub fn get(&mut self, fingerprint: u64, opts: &PlanOptions) -> Option<Arc<PartitionPlan>> {
        match self
            .entries
            .iter()
            .position(|(k, _)| k.fingerprint == fingerprint && &k.options == opts)
        {
            Some(i) => {
                let entry = self.entries.remove(i);
                let plan = entry.1.clone();
                self.entries.push(entry);
                self.hits += 1;
                Some(plan)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) a plan, evicting the LRU entry at capacity.
    pub fn insert(&mut self, plan: Arc<PartitionPlan>) {
        let key = PlanKey { fingerprint: plan.fingerprint, options: plan.options.clone() };
        if let Some(i) = self.entries.iter().position(|(k, _)| *k == key) {
            self.entries.remove(i);
        } else if self.entries.len() >= self.capacity {
            self.entries.remove(0);
        }
        self.entries.push((key, plan));
    }

    /// The staged per-request lookup: returns the cached
    /// plan (hit = `true`) or builds, caches, and returns a fresh one.
    pub fn get_or_build(
        &mut self,
        prepared: &PreparedGraph<'_>,
        opts: &PlanOptions,
    ) -> (Arc<PartitionPlan>, bool) {
        if let Some(plan) = self.get(prepared.fingerprint(), opts) {
            return (plan, true);
        }
        let plan = Arc::new(prepared.plan(opts));
        self.insert(plan.clone());
        (plan, false)
    }
}

/// Concurrent plan cache: [`PlanCache`] shards behind `RwLock`s, shared
/// by every serving worker (`Arc<ShardedPlanCache>`). A plan's shard is
/// chosen by hashing the FULL key — (fingerprint, options) — so one
/// circuit's different option sets spread across shards instead of
/// fighting over one shard's capacity; lock contention is then mostly
/// (not only: keys can share a shard) between requests for the same key
/// — exactly the requests that hit.
///
/// Single-flight guarantee: a miss builds the plan **while holding the
/// shard's write lock**, so N concurrent requests for one (fingerprint,
/// options) build it exactly once — the other N−1 block on the lock,
/// re-check, and hit. The deliberate cost: a cold build holds its
/// shard's write lock, so OTHER keys hashing to that shard (including
/// their read-path hits) stall behind it for the build's duration.
/// Sharding keeps the blast radius at ~1/shards; workloads dominated by
/// huge cold builds beside hot small circuits would want a per-key
/// in-flight marker with the build outside the lock instead.
///
/// Every lookup takes the shard's WRITE lock: hits must refresh LRU
/// recency, or a constantly-hot key would age out in insertion order
/// while cold keys churn past it (FIFO masquerading as LRU, evicting
/// precisely the hottest plan). The lock is held for a Vec scan + Arc
/// clone on hits — nanoseconds next to the inference each request then
/// performs — so exact LRU is cheap; the read half of the `RwLock`
/// serves introspection ([`Self::len`], [`PlanCache::peek`]) without
/// queueing behind builds. Hit/miss counters are shard-independent
/// atomics.
pub struct ShardedPlanCache {
    shards: Vec<std::sync::RwLock<PlanCache>>,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
    /// Optional persistent tier ([`PlanStore`]): an in-memory miss falls
    /// back to disk before building, and a fresh build is written back —
    /// the restart path that makes a known design's first request
    /// plan-free (zero partitioner invocations) on a new process.
    store: Option<super::planstore::PlanStore>,
    /// In-memory misses that the persistent tier answered.
    disk_hits: std::sync::atomic::AtomicU64,
}

/// Default shard count for the serving cache. Few enough that
/// `capacity / shards` entries per shard still hold a realistic working
/// set of keys per shard; single-flight blocking only ever affects keys
/// that hash together.
pub const DEFAULT_PLAN_CACHE_SHARDS: usize = 4;

impl ShardedPlanCache {
    /// `capacity` total entries spread over [`DEFAULT_PLAN_CACHE_SHARDS`]
    /// shards (each shard holds at least one).
    pub fn new(capacity: usize) -> ShardedPlanCache {
        Self::with_shards(DEFAULT_PLAN_CACHE_SHARDS, capacity)
    }

    pub fn with_shards(shards: usize, capacity: usize) -> ShardedPlanCache {
        let shards = shards.max(1);
        let per_shard = capacity.div_ceil(shards).max(1);
        ShardedPlanCache {
            shards: (0..shards)
                .map(|_| std::sync::RwLock::new(PlanCache::new(per_shard)))
                .collect(),
            hits: std::sync::atomic::AtomicU64::new(0),
            misses: std::sync::atomic::AtomicU64::new(0),
            store: None,
            disk_hits: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// [`Self::with_shards`] plus a persistent [`PlanStore`] tier:
    /// in-memory miss → disk load (validated, quarantine-on-corruption)
    /// → build + write-back.
    pub fn with_store(
        shards: usize,
        capacity: usize,
        store: super::planstore::PlanStore,
    ) -> ShardedPlanCache {
        let mut cache = Self::with_shards(shards, capacity);
        cache.store = Some(store);
        cache
    }

    /// The persistent tier, when one is attached.
    pub fn store(&self) -> Option<&super::planstore::PlanStore> {
        self.store.as_ref()
    }

    /// In-memory misses answered by the persistent tier.
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.load(std::sync::atomic::Ordering::SeqCst)
    }

    fn shard(&self, fingerprint: u64, opts: &PlanOptions) -> &std::sync::RwLock<PlanCache> {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        fingerprint.hash(&mut h);
        opts.hash(&mut h);
        &self.shards[(h.finish() % self.shards.len() as u64) as usize]
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(std::sync::atomic::Ordering::SeqCst)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(std::sync::atomic::Ordering::SeqCst)
    }

    /// Cached entries across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The concurrent counterpart of [`PlanCache::get_or_build`]: returns
    /// the cached plan (hit = `true`) or builds, caches, and returns a
    /// fresh one — at most one build per key across all threads while
    /// the key stays resident (an LRU-evicted key rebuilds, once, when
    /// it next appears).
    pub fn get_or_build(
        &self,
        prepared: &PreparedGraph<'_>,
        opts: &PlanOptions,
    ) -> (Arc<PartitionPlan>, bool) {
        use std::sync::atomic::Ordering;
        let fp = prepared.fingerprint();
        let shard = self.shard(fp, opts);
        let mut guard = shard.write().unwrap();
        // Under the write lock so hits refresh recency (exact LRU) and a
        // concurrent miss for the same key can never build twice.
        if let Some(plan) = guard.get(fp, opts) {
            self.hits.fetch_add(1, Ordering::SeqCst);
            cache_metrics().hits.inc();
            return (plan, true);
        }
        self.misses.fetch_add(1, Ordering::SeqCst);
        cache_metrics().misses.inc();
        // Persistent tier: a validated disk load skips partitioning,
        // re-growth, and gathering exactly like a memory hit (the
        // reported `plan_cache_hit` says so), still under the shard's
        // write lock so concurrent misses load once.
        if let Some(store) = &self.store {
            if let Some(plan) = store.load(fp, opts) {
                let plan = Arc::new(plan);
                guard.insert(plan.clone());
                self.disk_hits.fetch_add(1, Ordering::SeqCst);
                cache_metrics().disk_hits.inc();
                return (plan, true);
            }
        }
        let plan = Arc::new(prepared.plan(opts));
        guard.insert(plan.clone());
        if let Some(store) = &self.store {
            // Best-effort write-back: a full disk must not fail the
            // request the plan was just built for.
            let _ = store.save(&plan);
        }
        (plan, false)
    }
}

/// FNV-1a-style hash over the plan-relevant graph content: node count,
/// edge list, feature bits. Mixes one 64-bit word per multiply (an edge
/// pair, or an f32's bits) rather than byte-at-a-time — this runs on
/// every server request as the cache key, and word granularity is an 8×
/// cheaper mix with the same discrimination for that job. Not a
/// cryptographic digest: `classify_plan` backstops collisions across
/// different-sized graphs with a structural node-count check, and equal
/// content always produces equal plans regardless.
///
/// Both representations hash through [`fingerprint_content`]; the legacy
/// tuple list and the compact CSR-by-destination arrays yield the same
/// edge sequence for every AIG-built circuit (legacy emission is already
/// destination-grouped), which is what makes the fingerprint
/// representation-independent.
fn fingerprint_graph(graph: &EdaGraph) -> u64 {
    fingerprint_content(
        graph.num_nodes,
        graph.edges.len(),
        graph.edges.iter().copied(),
        graph.features.iter().copied(),
    )
}

fn fingerprint_content(
    num_nodes: usize,
    num_edges: usize,
    edges: impl Iterator<Item = (u32, u32)>,
    features: impl Iterator<Item = [f32; GROOT_FEATURE_DIM]>,
) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |word: u64| {
        h ^= word;
        h = h.wrapping_mul(PRIME);
    };
    eat(num_nodes as u64);
    eat(num_edges as u64);
    for (a, b) in edges {
        eat(((a as u64) << 32) | b as u64);
    }
    for f in features {
        for &v in &f {
            eat(v.to_bits() as u64);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetKind};

    fn graph() -> EdaGraph {
        datasets::build(DatasetKind::Csa, 6).unwrap()
    }

    #[test]
    fn fingerprint_tracks_content() {
        let g1 = graph();
        let g2 = graph();
        assert_eq!(fingerprint_graph(&g1), fingerprint_graph(&g2));
        let mut g3 = g2.clone();
        g3.features[0][0] += 1.0;
        assert_ne!(fingerprint_graph(&g2), fingerprint_graph(&g3));
        let mut g4 = g2.clone();
        g4.edges.swap(0, 1);
        assert_ne!(fingerprint_graph(&g2), fingerprint_graph(&g4));
    }

    #[test]
    fn fingerprint_is_representation_independent() {
        let eg = graph();
        let legacy = PreparedGraph::new(&eg);
        let compact =
            PreparedGraph::from_source(crate::aig::mult::csa_source(6, 64)).unwrap();
        assert_eq!(legacy.fingerprint(), compact.fingerprint());
        assert_eq!(legacy.num_nodes(), compact.num_nodes());
        assert_eq!(legacy.labels_u8(), compact.labels_u8());
        assert_eq!(legacy.csr(), compact.csr());
    }

    #[test]
    fn prepared_graph_features_are_zero_copy_on_legacy() {
        let g = graph();
        let p = PreparedGraph::new(&g);
        assert_eq!(p.features().len(), g.num_nodes * GROOT_FEATURE_DIM);
        assert_eq!(p.csr().num_nodes(), g.num_nodes);
        assert_eq!(&p.features()[..GROOT_FEATURE_DIM], &g.features[0]);
        // the legacy path reinterprets the graph's own storage — NOT a copy
        assert!(std::ptr::eq(
            p.features().as_ptr(),
            g.features.as_ptr().cast::<f32>()
        ));
        // repeated access reuses the materialized CSR
        assert!(std::ptr::eq(p.csr(), p.csr()));
    }

    #[test]
    fn compact_dense_fallback_matches_legacy() {
        let eg = graph();
        let legacy = PreparedGraph::new(&eg);
        let compact = PreparedGraph::from_circuit(eg.to_circuit().unwrap());
        assert_eq!(legacy.features(), compact.features());
        assert!(std::ptr::eq(compact.features(), compact.features()));
    }

    #[test]
    fn plan_partitions_cover_all_nodes_exactly_once() {
        let g = graph();
        let p = PreparedGraph::new(&g);
        let plan = p.plan(&PlanOptions { partitions: 4, ..PlanOptions::default() });
        assert_eq!(plan.num_partitions(), 4);
        let mut seen = vec![0usize; g.num_nodes];
        for part in &plan.parts {
            assert_eq!(part.features.len(), part.nodes.len() * GROOT_FEATURE_DIM);
            assert_eq!(part.csr.num_nodes(), part.nodes.len());
            for &n in &part.nodes[..part.num_core] {
                seen[n as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "core cover is not a partition");
    }

    #[test]
    fn stream_plan_is_lean_and_covers_all_nodes() {
        let g = graph();
        let p = PreparedGraph::new(&g);
        let opts = PlanOptions { partitions: 4, ..PlanOptions::default() };
        let sp = p.plan_stream(&opts);
        assert_eq!(sp.num_partitions(), 4);
        let total: usize = sp.core_counts.iter().sum();
        assert_eq!(total, g.num_nodes);
        // per-window inversion reproduces the eager plan's core sets
        // exactly (any window slicing, including out-of-order ids)
        let plan = p.plan(&opts);
        for (&count, part) in sp.core_counts.iter().zip(&plan.parts) {
            assert_eq!(count, part.num_core);
        }
        let cores = sp.window_cores(&[2, 0]);
        assert_eq!(cores[0], plan.parts[2].nodes[..plan.parts[2].num_core]);
        assert_eq!(cores[1], plan.parts[0].nodes[..plan.parts[0].num_core]);
    }

    #[test]
    fn digests_are_stable_and_representation_independent() {
        let eg = graph();
        let legacy = PreparedGraph::new(&eg);
        let compact =
            PreparedGraph::from_source(crate::aig::mult::csa_source(6, 64)).unwrap();
        let opts = PlanOptions { partitions: 4, ..PlanOptions::default() };
        let a = legacy.plan(&opts);
        let b = legacy.plan(&opts);
        let c = compact.plan(&opts);
        assert_eq!(a.digests(), b.digests(), "rebuild changed digests");
        assert_eq!(a.digests(), c.digests(), "representation changed digests");
        assert_eq!(a.stats.content_digest, c.stats.content_digest);
        assert_ne!(a.stats.content_digest, 0);
        // the stats-only probe computes the same plan-level digest
        assert_eq!(legacy.plan_stats(&opts).content_digest, a.stats.content_digest);
        // stored digests match recomputation from partition content
        for part in &a.parts {
            assert_eq!(part.digest, part.content_digest());
        }
        // digests hash plan content, not kernel thresholds
        let other = legacy.plan(&PlanOptions { hd_threshold: 1, ..opts.clone() });
        assert_eq!(a.digests(), other.digests());
        // but they do track content: a different seed moves partitions
        let moved = legacy.plan(&PlanOptions { seed: 7, ..opts });
        assert_ne!(a.stats.content_digest, moved.stats.content_digest);
    }

    #[test]
    fn plan_with_assignment_reproduces_plan() {
        let g = graph();
        let p = PreparedGraph::new(&g);
        let opts = PlanOptions { partitions: 4, seed: 3, ..PlanOptions::default() };
        let base = p.plan(&opts);
        let assignment = base.extract_assignment();
        let rebuilt = p.plan_with_assignment(&opts, &assignment).unwrap();
        assert_eq!(rebuilt.parts.len(), base.parts.len());
        for (a, b) in base.parts.iter().zip(&rebuilt.parts) {
            assert_eq!(a.nodes, b.nodes);
            assert_eq!(a.num_core, b.num_core);
            assert_eq!(a.csr, b.csr);
            assert_eq!(a.features, b.features);
            assert_eq!(a.digest, b.digest);
        }
        assert_eq!(rebuilt.stats.content_digest, base.stats.content_digest);
        // shape mismatches are rejected loudly
        let short = Partitioning { k: 4, assignment: vec![0; 3] };
        assert!(p.plan_with_assignment(&opts, &short).is_err());
        let wrong_k = Partitioning { k: 2, assignment: vec![0; g.num_nodes] };
        assert!(p.plan_with_assignment(&opts, &wrong_k).is_err());
    }

    #[test]
    fn plan_cache_hits_and_evicts_lru() {
        let g = graph();
        let p = PreparedGraph::new(&g);
        let mut cache = PlanCache::new(2);
        let o1 = PlanOptions { partitions: 1, ..PlanOptions::default() };
        let o2 = PlanOptions { partitions: 2, ..PlanOptions::default() };
        let o3 = PlanOptions { partitions: 3, ..PlanOptions::default() };

        let (_, hit) = cache.get_or_build(&p, &o1);
        assert!(!hit);
        let (_, hit) = cache.get_or_build(&p, &o1);
        assert!(hit, "same (fingerprint, options) must hit");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));

        cache.get_or_build(&p, &o2);
        cache.get_or_build(&p, &o3); // capacity 2: evicts o1 (LRU)
        assert_eq!(cache.len(), 2);
        assert!(cache.get(p.fingerprint(), &o1).is_none(), "o1 must be evicted");
        assert!(cache.get(p.fingerprint(), &o2).is_some());
        assert!(cache.get(p.fingerprint(), &o3).is_some());
    }

    #[test]
    fn sharded_cache_builds_each_key_exactly_once_under_contention() {
        let g = graph();
        let cache = ShardedPlanCache::new(32);
        let options: Vec<PlanOptions> = (1..=3usize)
            .map(|partitions| PlanOptions { partitions, ..PlanOptions::default() })
            .collect();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let p = PreparedGraph::new(&g);
                    for opts in &options {
                        let (plan, _) = cache.get_or_build(&p, opts);
                        assert_eq!(plan.num_partitions(), opts.partitions);
                    }
                });
            }
        });
        // 8 threads × 3 keys: exactly 3 builds ever, 21 hits.
        assert_eq!(cache.misses(), 3, "a concurrent miss built a duplicate plan");
        assert_eq!(cache.hits(), 8 * 3 - 3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn sharded_cache_results_match_unsharded() {
        let g = graph();
        let p = PreparedGraph::new(&g);
        let sharded = ShardedPlanCache::with_shards(4, 8);
        let mut plain = PlanCache::new(8);
        let opts = PlanOptions { partitions: 4, seed: 3, ..PlanOptions::default() };
        let (a, hit_a) = sharded.get_or_build(&p, &opts);
        let (b, hit_b) = plain.get_or_build(&p, &opts);
        assert!(!hit_a && !hit_b);
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(a.parts.len(), b.parts.len());
        for (pa, pb) in a.parts.iter().zip(&b.parts) {
            assert_eq!(pa.nodes, pb.nodes);
            assert_eq!(pa.features, pb.features);
        }
        let (_, hit) = sharded.get_or_build(&p, &opts);
        assert!(hit, "second sharded lookup must hit");
    }

    #[test]
    fn cache_misses_on_different_options_or_content() {
        let g = graph();
        let p = PreparedGraph::new(&g);
        let mut cache = PlanCache::default();
        let o = PlanOptions { partitions: 2, ..PlanOptions::default() };
        cache.get_or_build(&p, &o);
        assert!(cache
            .get(p.fingerprint(), &PlanOptions { seed: 1, ..o.clone() })
            .is_none());
        assert!(cache
            .get(p.fingerprint(), &PlanOptions { regrow: false, ..o.clone() })
            .is_none());
        assert!(cache.get(p.fingerprint() ^ 1, &o).is_none());
    }
}

//! Verification service — the staged pipeline behind a multi-worker
//! request queue.
//!
//! The paper frames GROOT as a run-time verification system; this module
//! provides the serving shape: callers submit circuits with per-request
//! [`VerifyOptions`], **N worker threads** (config `workers`) pull from a
//! bounded submission queue, and answers go back on per-request channels.
//!
//! ```text
//!            try_submit ──► TrySubmit::Busy  when the bounded queue is full
//! clients ──► submit ─────► [ bounded queue ] ──► worker 0 (backend 0)
//!                                            ├──► worker 1 (backend 1)
//!                                            └──► worker N (backend N)
//!                               shared Arc<ShardedPlanCache> (RwLock shards)
//! ```
//!
//! * Each worker builds its OWN backend on its own thread via the
//!   [`BackendFactory`] — backends never cross threads, and a worker's
//!   scratch/lane pool stays thread-local-warm.
//! * The **plan cache is shared** ([`ShardedPlanCache`]): any worker's
//!   cold plan warms every other worker, and concurrent requests for one
//!   (fingerprint, options) build the plan exactly once (single-flight
//!   under the shard's write lock).
//! * The queue is **bounded**: [`ServerHandle::submit`] blocks when the
//!   server is saturated (back-pressure propagates to the producer), and
//!   [`ServerHandle::try_submit`] returns [`TrySubmit::Busy`] with the
//!   request handed back, for callers that would rather shed load.
//! * Responses are **byte-identical** to a sequential
//!   [`Session::classify`] run regardless of worker count: stitch order
//!   is fixed by partition index and every kernel's reduction order is
//!   thread-count-invariant (pinned by rust/tests/concurrent_serving.rs).
//!
//! Shutdown preserves the PR-2 sentinel semantics in flag form: closing
//! the queue (NOT dropping the channel — user-cloned [`ServerHandle`]s
//! keep that alive indefinitely) wakes every worker; requests already
//! queued are drained and answered, later submissions fail with "server
//! stopped", and `join()` terminates deterministically.

use super::{
    Backend, ClassifyResult, DeltaResult, PlanOptions, PreparedGraph, Session, SessionConfig,
    ShardedPlanCache,
};
use crate::features::EdaGraph;
use crate::graph::CircuitGraph;
use crate::incremental::{GraphEdit, IncrementalState};
use crate::obs::{self, log, metrics};
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::AtomicU64;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Per-request plan options; `None` fields inherit the server's base
/// [`SessionConfig`].
#[derive(Clone, Debug, Default)]
pub struct VerifyOptions {
    pub partitions: Option<usize>,
    pub regrow: Option<bool>,
    pub seed: Option<u64>,
}

impl VerifyOptions {
    /// Shorthand for the common "just override the partition count" case.
    pub fn partitions(n: usize) -> VerifyOptions {
        VerifyOptions { partitions: Some(n), ..Default::default() }
    }

    /// Resolve against the server's base config into a full plan key.
    pub fn resolve(&self, base: &SessionConfig) -> PlanOptions {
        PlanOptions {
            partitions: self.partitions.unwrap_or(base.num_partitions),
            regrow: self.regrow.unwrap_or(base.regrow),
            seed: self.seed.unwrap_or(base.seed),
            hd_threshold: base.hd_threshold,
            threads: base.threads,
        }
    }
}

/// Either circuit representation, submitted as-is: legacy dense
/// [`EdaGraph`]s from in-process callers, compact columnar
/// [`CircuitGraph`]s from streaming ingestion and the network daemon
/// (whose wire payloads decode straight into the columnar form). The
/// worker prepares both through the same staged pipeline, and
/// fingerprints are representation-independent, so either form of one
/// circuit shares one plan-cache entry.
pub enum RequestGraph {
    Eda(EdaGraph),
    Circuit(CircuitGraph),
}

impl RequestGraph {
    pub fn num_nodes(&self) -> usize {
        match self {
            RequestGraph::Eda(g) => g.num_nodes,
            RequestGraph::Circuit(c) => c.num_nodes(),
        }
    }

    pub fn name(&self) -> &str {
        match self {
            RequestGraph::Eda(g) => &g.name,
            RequestGraph::Circuit(c) => &c.name,
        }
    }

    /// Borrowing preparation — no column store is cloned to plan it.
    fn prepare(&self) -> PreparedGraph<'_> {
        match self {
            RequestGraph::Eda(g) => PreparedGraph::new(g),
            RequestGraph::Circuit(c) => PreparedGraph::from_circuit_ref(c),
        }
    }
}

impl From<EdaGraph> for RequestGraph {
    fn from(g: EdaGraph) -> RequestGraph {
        RequestGraph::Eda(g)
    }
}

impl From<CircuitGraph> for RequestGraph {
    fn from(c: CircuitGraph) -> RequestGraph {
        RequestGraph::Circuit(c)
    }
}

/// A verification request: graph + per-request plan options.
pub struct Request {
    pub graph: RequestGraph,
    pub options: VerifyOptions,
    pub reply: mpsc::Sender<Result<ClassifyResult>>,
}

/// An incremental-verification request: a registered base fingerprint
/// plus the edit list to apply — no graph payload. The worker resolves
/// the base from the shared [`IncrementalState`] and answers through
/// [`Session::classify_delta_with`], re-inferring only dirty partitions.
pub struct DeltaRequest {
    pub base_fingerprint: u64,
    pub edits: Vec<GraphEdit>,
    pub options: VerifyOptions,
    pub reply: mpsc::Sender<Result<DeltaResult>>,
}

/// One unit of queued work: a full classify or an incremental delta.
/// Both kinds share the one bounded queue so back-pressure and shutdown
/// semantics are uniform.
pub enum Job {
    Classify(Request),
    Delta(DeltaRequest),
}

/// Outcome of a non-blocking submission attempt.
pub enum TrySubmit {
    /// Queued; await the result on the receiver.
    Accepted(mpsc::Receiver<Result<ClassifyResult>>),
    /// The bounded queue is full — back-pressure. The request is handed
    /// back untouched so the caller can retry, redirect, or shed it
    /// (the network daemon maps this to a BUSY wire reply).
    Busy { graph: RequestGraph, options: VerifyOptions },
}

/// Outcome of a non-blocking delta submission attempt.
pub enum DeltaSubmit {
    /// Queued; await the result on the receiver.
    Accepted(mpsc::Receiver<Result<DeltaResult>>),
    /// Queue full — the request is handed back (see [`TrySubmit::Busy`]).
    Busy { base_fingerprint: u64, edits: Vec<GraphEdit>, options: VerifyOptions },
}

/// Builds one backend per worker, ON that worker's thread (weights load,
/// artifact mmaps, engine pools — none of it crosses threads). Called
/// `workers` times; every invocation must produce an equivalent backend,
/// or cross-worker responses would diverge.
pub type BackendFactory = dyn Fn() -> Result<Backend> + Send + Sync;

/// Bounded MPMC submission queue. `open: false` + empty is the worker
/// exit condition; closing never discards queued requests.
struct SubmitQueue {
    inner: Mutex<QueueInner>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct QueueInner {
    q: VecDeque<Box<Job>>,
    open: bool,
}

impl SubmitQueue {
    fn new(capacity: usize) -> SubmitQueue {
        SubmitQueue {
            inner: Mutex::new(QueueInner { q: VecDeque::new(), open: true }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Block until there is room (back-pressure), then enqueue.
    /// `Err` hands the request back when the server has stopped.
    fn push_blocking(&self, req: Box<Job>) -> std::result::Result<(), Box<Job>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.open {
                return Err(req);
            }
            if inner.q.len() < self.capacity {
                inner.q.push_back(req);
                drop(inner);
                self.not_empty.notify_one();
                return Ok(());
            }
            inner = self.not_full.wait(inner).unwrap();
        }
    }

    /// Non-blocking enqueue: `Ok(None)` on success, `Ok(Some(req))` when
    /// full (request handed back), `Err(req)` when stopped.
    #[allow(clippy::type_complexity)]
    fn try_push(&self, req: Box<Job>) -> std::result::Result<Option<Box<Job>>, Box<Job>> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.open {
            return Err(req);
        }
        if inner.q.len() >= self.capacity {
            return Ok(Some(req));
        }
        inner.q.push_back(req);
        drop(inner);
        self.not_empty.notify_one();
        Ok(None)
    }

    /// Dequeue, blocking while the queue is open and empty; `None` once
    /// it is closed AND drained — the worker exit signal.
    fn pop(&self) -> Option<Box<Job>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(req) = inner.q.pop_front() {
                drop(inner);
                self.not_full.notify_one();
                return Some(req);
            }
            if !inner.open {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    /// Requests currently queued (waiting for a worker) — the STATS
    /// observability number; instantaneous, not a synchronization point.
    fn depth(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    /// Stop accepting; wake everyone (workers drain, producers error).
    fn close(&self) {
        self.inner.lock().unwrap().open = false;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Last-resort close: stop accepting AND drop everything still
    /// queued. Dropping a request disconnects its reply channel, so
    /// blocked callers get "server dropped reply" instead of hanging on
    /// a queue no live worker will ever drain again.
    fn fail_pending(&self) {
        let dropped: Vec<Box<Job>> = {
            let mut inner = self.inner.lock().unwrap();
            inner.open = false;
            inner.q.drain(..).collect()
        };
        drop(dropped);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// Armed for the duration of a worker thread: if the thread dies by
/// PANIC (a kernel assert on a malformed graph, a poisoned lock) and it
/// was the last live worker, the queue is closed and drained so pending
/// and future clients error out — the single-router design got this for
/// free from channel closure, and the multi-worker runtime must not
/// regress it into an eternal hang. Disarmed (`mem::forget`) on normal
/// exit paths, which have their own accounting.
struct WorkerDeathGuard<'a> {
    queue: &'a SubmitQueue,
    live: &'a std::sync::atomic::AtomicUsize,
}

impl Drop for WorkerDeathGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking()
            && self.live.fetch_sub(1, std::sync::atomic::Ordering::SeqCst) == 1
        {
            self.queue.fail_pending();
        }
    }
}

/// Handle for submitting requests to a running server. Cheap-clone
/// (`Arc` internally) and `Send`; outliving the `Server` is safe
/// (submissions then fail with "server stopped").
#[derive(Clone)]
pub struct ServerHandle {
    queue: Arc<SubmitQueue>,
}

impl ServerHandle {
    /// Submit and wait (convenience for examples/tests).
    pub fn verify_blocking(
        &self,
        graph: impl Into<RequestGraph>,
        options: VerifyOptions,
    ) -> Result<ClassifyResult> {
        let rx = self.submit(graph, options)?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped reply"))?
    }

    /// Submit without waiting for the RESULT; returns the reply receiver.
    /// Blocks while the bounded queue is full (back-pressure) — use
    /// [`Self::try_submit`] to shed load instead.
    pub fn submit(
        &self,
        graph: impl Into<RequestGraph>,
        options: VerifyOptions,
    ) -> Result<mpsc::Receiver<Result<ClassifyResult>>> {
        let (reply, rx) = mpsc::channel();
        self.queue
            .push_blocking(Box::new(Job::Classify(Request { graph: graph.into(), options, reply })))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx)
    }

    /// Non-blocking submit: [`TrySubmit::Busy`] (request handed back)
    /// when the bounded queue is full, `Err` when the server stopped.
    pub fn try_submit(
        &self,
        graph: impl Into<RequestGraph>,
        options: VerifyOptions,
    ) -> Result<TrySubmit> {
        let (reply, rx) = mpsc::channel();
        let job = Job::Classify(Request { graph: graph.into(), options, reply });
        match self.queue.try_push(Box::new(job)) {
            Ok(None) => Ok(TrySubmit::Accepted(rx)),
            Ok(Some(job)) => match *job {
                Job::Classify(req) => Ok(TrySubmit::Busy { graph: req.graph, options: req.options }),
                Job::Delta(_) => unreachable!("classify submission handed back a delta job"),
            },
            Err(_) => Err(anyhow::anyhow!("server stopped")),
        }
    }

    /// Submit an incremental delta and wait (convenience for tests).
    pub fn verify_delta_blocking(
        &self,
        base_fingerprint: u64,
        edits: Vec<GraphEdit>,
        options: VerifyOptions,
    ) -> Result<DeltaResult> {
        let rx = self.submit_delta(base_fingerprint, edits, options)?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped reply"))?
    }

    /// Submit an incremental delta without waiting for the result;
    /// blocks while the bounded queue is full (back-pressure).
    pub fn submit_delta(
        &self,
        base_fingerprint: u64,
        edits: Vec<GraphEdit>,
        options: VerifyOptions,
    ) -> Result<mpsc::Receiver<Result<DeltaResult>>> {
        let (reply, rx) = mpsc::channel();
        let job = Job::Delta(DeltaRequest { base_fingerprint, edits, options, reply });
        self.queue.push_blocking(Box::new(job)).map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx)
    }

    /// Non-blocking delta submit — [`DeltaSubmit::Busy`] hands the edit
    /// list back when the queue is full (the daemon maps it to BUSY).
    pub fn try_submit_delta(
        &self,
        base_fingerprint: u64,
        edits: Vec<GraphEdit>,
        options: VerifyOptions,
    ) -> Result<DeltaSubmit> {
        let (reply, rx) = mpsc::channel();
        let job = Job::Delta(DeltaRequest { base_fingerprint, edits, options, reply });
        match self.queue.try_push(Box::new(job)) {
            Ok(None) => Ok(DeltaSubmit::Accepted(rx)),
            Ok(Some(job)) => match *job {
                Job::Delta(req) => Ok(DeltaSubmit::Busy {
                    base_fingerprint: req.base_fingerprint,
                    edits: req.edits,
                    options: req.options,
                }),
                Job::Classify(_) => unreachable!("delta submission handed back a classify job"),
            },
            Err(_) => Err(anyhow::anyhow!("server stopped")),
        }
    }

    /// Requests currently queued (instantaneous).
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }
}

/// A consistent observability snapshot of a running server — what the
/// network daemon's STATS reply is built from.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// Requests queued but not yet claimed by a worker.
    pub queue_depth: usize,
    /// Worker threads spawned.
    pub workers: usize,
    /// Requests answered by each worker (index = spawn order). A healthy
    /// fleet spreads load; a worker that failed backend init stays at 0.
    pub per_worker_requests: Vec<u64>,
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    /// In-memory misses answered by the persistent plan store.
    pub plan_disk_hits: u64,
    pub plan_store_writes: u64,
    pub plan_store_quarantined: u64,
}

/// The running server; closes the queue and joins every worker on drop.
pub struct Server {
    handle: ServerHandle,
    cache: Arc<ShardedPlanCache>,
    incremental: IncrementalState,
    worker_counts: Arc<Vec<AtomicU64>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Spawn `config.workers` worker threads with the default plan-cache
    /// and queue capacities. `make_backend` runs once *on each worker
    /// thread*; see [`BackendFactory`].
    pub fn spawn<F>(config: SessionConfig, make_backend: F) -> Server
    where
        F: Fn() -> Result<Backend> + Send + Sync + 'static,
    {
        Self::spawn_with_cache(config, super::DEFAULT_PLAN_CACHE_CAPACITY, make_backend)
    }

    /// Spawn with an explicit plan-cache capacity (0 is clamped to 1).
    ///
    /// Capacity is an entry count, not a byte budget: each cached plan
    /// holds its circuit's partition node lists, local CSRs, and
    /// gathered f32 feature buffers — roughly one graph's worth of data
    /// per entry. Deployments serving many distinct large circuits
    /// should size this against `capacity × largest-graph footprint`.
    pub fn spawn_with_cache<F>(
        config: SessionConfig,
        plan_cache_capacity: usize,
        make_backend: F,
    ) -> Server
    where
        F: Fn() -> Result<Backend> + Send + Sync + 'static,
    {
        // Default queue bound: deep enough to keep every worker busy
        // with headroom, small enough that latency (and memory: queued
        // requests own their graphs) stays bounded under overload.
        let queue_capacity = (config.workers.max(1) * 8).max(32);
        Self::spawn_with_queue(config, plan_cache_capacity, queue_capacity, make_backend)
    }

    /// Fully explicit spawn: plan-cache entries AND submission-queue
    /// bound (both clamped to ≥ 1).
    pub fn spawn_with_queue<F>(
        config: SessionConfig,
        plan_cache_capacity: usize,
        queue_capacity: usize,
        make_backend: F,
    ) -> Server
    where
        F: Fn() -> Result<Backend> + Send + Sync + 'static,
    {
        Self::spawn_on_cache(
            config,
            Arc::new(ShardedPlanCache::new(plan_cache_capacity.max(1))),
            queue_capacity,
            make_backend,
        )
    }

    /// Spawn against a caller-built plan cache — the entry point for a
    /// cache with a persistent [`super::PlanStore`] tier attached
    /// ([`ShardedPlanCache::with_store`]), which is how `groot serve
    /// --plan-dir` gets its zero-cold-start restarts.
    pub fn spawn_on_cache<F>(
        config: SessionConfig,
        cache: Arc<ShardedPlanCache>,
        queue_capacity: usize,
        make_backend: F,
    ) -> Server
    where
        F: Fn() -> Result<Backend> + Send + Sync + 'static,
    {
        Self::spawn_with_incremental(
            config,
            cache,
            queue_capacity,
            IncrementalState::new(),
            make_backend,
        )
    }

    /// Fully explicit spawn with a caller-built [`IncrementalState`]
    /// (e.g. one whose prediction cache has a persistent [`super::PlanStore`]
    /// tier). ONE state is shared by every worker: a base registered or
    /// a partition primed by any worker serves delta requests on all.
    pub fn spawn_with_incremental<F>(
        config: SessionConfig,
        cache: Arc<ShardedPlanCache>,
        queue_capacity: usize,
        incremental: IncrementalState,
        make_backend: F,
    ) -> Server
    where
        F: Fn() -> Result<Backend> + Send + Sync + 'static,
    {
        let queue = Arc::new(SubmitQueue::new(queue_capacity));
        let make_backend: Arc<BackendFactory> = Arc::new(make_backend);
        let worker_count = config.workers.max(1);
        let live = Arc::new(std::sync::atomic::AtomicUsize::new(worker_count));
        let worker_counts: Arc<Vec<AtomicU64>> =
            Arc::new((0..worker_count).map(|_| AtomicU64::new(0)).collect());
        let workers = (0..worker_count)
            .map(|i| {
                let queue = Arc::clone(&queue);
                let cache = Arc::clone(&cache);
                let make_backend = Arc::clone(&make_backend);
                let live = Arc::clone(&live);
                let counts = Arc::clone(&worker_counts);
                let config = config.clone();
                let incremental = incremental.clone();
                std::thread::Builder::new()
                    .name(format!("groot-serve-{i}"))
                    .spawn(move || {
                        let guard = WorkerDeathGuard { queue: &*queue, live: &*live };
                        worker_loop(
                            &queue,
                            &cache,
                            &config,
                            &*make_backend,
                            &incremental,
                            &live,
                            &counts[i],
                        );
                        std::mem::forget(guard); // normal exit: not a death
                    })
                    .expect("spawn serving worker")
            })
            .collect();
        Server { handle: ServerHandle { queue }, cache, incremental, worker_counts, workers }
    }

    /// The shared incremental state (base registry + prediction cache).
    pub fn incremental(&self) -> &IncrementalState {
        &self.incremental
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Shared plan-cache counters: (hits, misses) across all workers.
    /// The single-flight guarantee makes `misses` exactly the number of
    /// distinct (circuit, options) keys ever planned.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.cache.hits(), self.cache.misses())
    }

    /// Observability snapshot (queue depth, per-worker request counts,
    /// plan-cache and plan-store counters). Each number is individually
    /// atomic; the snapshot as a whole is not a barrier.
    pub fn stats(&self) -> ServerStats {
        use std::sync::atomic::Ordering;
        let store = self.cache.store();
        ServerStats {
            queue_depth: self.handle.queue.depth(),
            workers: self.workers.len(),
            per_worker_requests: self
                .worker_counts
                .iter()
                .map(|c| c.load(Ordering::SeqCst))
                .collect(),
            plan_cache_hits: self.cache.hits(),
            plan_cache_misses: self.cache.misses(),
            plan_disk_hits: self.cache.disk_hits(),
            plan_store_writes: store.map_or(0, |s| s.writes()),
            plan_store_quarantined: store.map_or(0, |s| s.quarantined()),
        }
    }

    /// Explicit deterministic shutdown: requests already queued are
    /// drained and answered; later submissions fail. (Dropping the
    /// server does the same.)
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // The closed FLAG — not channel closure — stops the workers:
        // cloned user handles may keep the queue allocation alive
        // indefinitely, which must never block this join.
        self.handle.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn worker_loop(
    queue: &SubmitQueue,
    cache: &ShardedPlanCache,
    config: &SessionConfig,
    make_backend: &BackendFactory,
    incremental: &IncrementalState,
    live: &std::sync::atomic::AtomicUsize,
    served: &AtomicU64,
) {
    use std::sync::atomic::Ordering;
    // Per-worker served counter, labeled by spawn index (recovered from
    // the "groot-serve-i" thread name). Same-index workers of successive
    // Server instances share one process-wide series.
    let worker_label: String = std::thread::current()
        .name()
        .map(|n| n.strip_prefix("groot-serve-").unwrap_or(n).to_string())
        .unwrap_or_else(|| "?".to_string());
    let served_metric = metrics::registry().counter(
        "groot_worker_requests_total",
        "Requests answered per serving worker (label worker = spawn index).",
        &[("worker", &worker_label)],
    );
    let backend = match make_backend() {
        Ok(b) => b,
        Err(e) => {
            log::error(
                "coordinator::server",
                format_args!("worker {worker_label}: backend init failed: {e:#}"),
            );
            // A partially-failed fleet must not race healthy workers and
            // error a random subset of requests: a failed worker steps
            // aside quietly — UNLESS it is the last live one, in which
            // case it stays to answer everything with the construction
            // error rather than letting submissions hang forever.
            if live.fetch_sub(1, Ordering::SeqCst) > 1 {
                return;
            }
            while let Some(job) = queue.pop() {
                let err = || anyhow::anyhow!("backend init failed: {e:#}");
                match *job {
                    Job::Classify(req) => drop(req.reply.send(Err(err()))),
                    Job::Delta(req) => drop(req.reply.send(Err(err()))),
                }
            }
            return;
        }
    };
    let session = Session::new(backend, config.clone()).with_incremental(incremental.clone());
    while let Some(job) = queue.pop() {
        match *job {
            Job::Classify(req) => {
                let _span = obs::span_with_arg("worker_request", "server", "graph", || {
                    req.graph.name().to_string()
                });
                let opts = req.options.resolve(&session.config);
                // Preparation is cheap (content hash); the CSR and feature
                // matrix only materialize on a cache miss, inside plan().
                let prepared = req.graph.prepare();
                let (plan, hit) = cache.get_or_build(&prepared, &opts);
                let out = session.classify_plan(&prepared, &plan, hit);
                let fingerprint = prepared.fingerprint();
                drop(prepared);
                // A compact-circuit classify doubles as delta priming:
                // register the circuit as an incremental base and seed the
                // prediction cache, so a follow-up delta against this
                // fingerprint re-infers only what an edit dirties.
                if let (Ok(result), RequestGraph::Circuit(c)) = (&out, req.graph) {
                    session.note_base(fingerprint, Arc::new(c), &plan, &result.pred);
                }
                served.fetch_add(1, Ordering::SeqCst);
                served_metric.inc();
                let _ = req.reply.send(out);
            }
            Job::Delta(req) => {
                let _span = obs::span_with_arg("worker_delta", "server", "base", || {
                    format!("{:016x}", req.base_fingerprint)
                });
                // Resolve per-request overrides into a full session
                // config for the delta path (same inheritance rule as
                // VerifyOptions::resolve).
                let mut cfg = session.config.clone();
                if let Some(p) = req.options.partitions {
                    cfg.num_partitions = p;
                }
                if let Some(r) = req.options.regrow {
                    cfg.regrow = r;
                }
                if let Some(s) = req.options.seed {
                    cfg.seed = s;
                }
                let out = session.classify_delta_with(req.base_fingerprint, &req.edits, &cfg);
                served.fetch_add(1, Ordering::SeqCst);
                served_metric.inc();
                let _ = req.reply.send(out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::gnn::{SageLayer, SageModel};
    use std::time::Duration;

    fn dummy_model() -> SageModel {
        SageModel {
            layers: vec![SageLayer {
                din: 4,
                dout: 5,
                w_self: vec![0.1; 20],
                w_neigh: vec![0.1; 20],
                bias: vec![0.0; 5],
            }],
        }
    }

    fn dummy_backend() -> Result<Backend> {
        Ok(Box::new(NativeBackend::new(dummy_model())))
    }

    #[test]
    fn server_round_trips_requests() {
        let server = Server::spawn(SessionConfig::default(), dummy_backend);
        let h = server.handle();
        let g = crate::aig::mult::csa_multiplier(4);
        let eg = crate::features::EdaGraph::from_aig(&g);
        // overlapping async submissions
        let rx1 = h.submit(eg.clone(), VerifyOptions::partitions(2)).unwrap();
        let rx2 = h.submit(eg.clone(), VerifyOptions::partitions(4)).unwrap();
        let r1 = rx1.recv().unwrap().unwrap();
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r1.pred.len(), eg.num_nodes);
        assert_eq!(r2.stats.num_partitions, 4);
    }

    #[test]
    fn server_survives_many_sequential_requests() {
        let server = Server::spawn(SessionConfig::default(), dummy_backend);
        let h = server.handle();
        let g = crate::aig::mult::csa_multiplier(3);
        let eg = crate::features::EdaGraph::from_aig(&g);
        for k in 1..=6 {
            let r = h.verify_blocking(eg.clone(), VerifyOptions::partitions(k)).unwrap();
            assert_eq!(r.stats.num_partitions, k.min(eg.num_nodes));
        }
    }

    #[test]
    fn repeat_requests_hit_the_plan_cache() {
        let server = Server::spawn(SessionConfig::default(), dummy_backend);
        let h = server.handle();
        let eg = crate::features::EdaGraph::from_aig(&crate::aig::mult::csa_multiplier(4));
        let cold = h.verify_blocking(eg.clone(), VerifyOptions::partitions(3)).unwrap();
        assert!(!cold.stats.plan_cache_hit);
        let warm = h.verify_blocking(eg.clone(), VerifyOptions::partitions(3)).unwrap();
        assert!(warm.stats.plan_cache_hit, "same circuit+options must reuse the plan");
        assert_eq!(warm.stats.partition_time, Duration::ZERO);
        assert_eq!(warm.stats.regrowth_time, Duration::ZERO);
        assert_eq!(warm.pred, cold.pred);
        // different options on the same circuit: a different plan
        let other = h.verify_blocking(eg, VerifyOptions::partitions(2)).unwrap();
        assert!(!other.stats.plan_cache_hit);
        assert_eq!(server.cache_stats(), (1, 2), "(hits, misses)");
    }

    #[test]
    fn circuit_classify_primes_delta_and_delta_round_trips() {
        let server = Server::spawn(
            SessionConfig { num_partitions: 4, ..Default::default() },
            dummy_backend,
        );
        let h = server.handle();
        let circuit = crate::graph::CircuitGraph::from_source(crate::aig::mult::csa_source(5, 64))
            .unwrap();
        let base = circuit.clone();
        // a compact-circuit classify registers the base + primes the cache
        let cold = h.verify_blocking(circuit, VerifyOptions::default()).unwrap();
        assert_eq!(server.incremental().num_bases(), 1);
        let fp = PreparedGraph::from_circuit_ref(&base).fingerprint();

        let edits = crate::incremental::synthetic_polarity_edits(&base, 1, 11);
        let delta = h.verify_delta_blocking(fp, edits.clone(), VerifyOptions::default()).unwrap();
        assert!(delta.clean >= 1, "warm delta must stitch clean partitions from cache");
        assert!(!delta.repartitioned);
        assert_eq!(delta.result.pred.len(), cold.pred.len());

        // byte-identity against a full classify of the edited circuit
        let edited = crate::incremental::apply_edits(&base, &edits).unwrap();
        let full = h.verify_blocking(edited, VerifyOptions::default()).unwrap();
        assert_eq!(delta.result.pred, full.pred);

        // unknown base → an error reply, not a hang
        let err = h
            .verify_delta_blocking(0x1234, Vec::new(), VerifyOptions::default())
            .unwrap_err();
        assert!(err.to_string().contains("unknown base"), "{err:#}");
    }

    #[test]
    fn multi_worker_server_answers_everything() {
        let server = Server::spawn(
            SessionConfig { workers: 4, threads: 1, ..Default::default() },
            dummy_backend,
        );
        let h = server.handle();
        let eg = crate::features::EdaGraph::from_aig(&crate::aig::mult::csa_multiplier(4));
        let pending: Vec<_> = (0..16)
            .map(|i| h.submit(eg.clone(), VerifyOptions::partitions(1 + i % 4)).unwrap())
            .collect();
        for (i, rx) in pending.into_iter().enumerate() {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.pred.len(), eg.num_nodes, "request {i}");
        }
    }

    #[test]
    fn dropping_server_with_live_handle_clone_terminates() {
        // Regression (PR 2): shutdown must not wait for the request
        // channel/queue to be released — a cloned handle keeps it alive.
        let server = Server::spawn(SessionConfig::default(), dummy_backend);
        let clone = server.handle();
        let (done_tx, done_rx) = mpsc::channel();
        std::thread::spawn(move || {
            drop(server);
            let _ = done_tx.send(());
        });
        done_rx
            .recv_timeout(Duration::from_secs(30))
            .expect("Server::drop hung with a live ServerHandle clone");
        // The surviving handle reports a stopped server instead of
        // queueing into the void.
        let eg = crate::features::EdaGraph::from_aig(&crate::aig::mult::csa_multiplier(3));
        assert!(clone.submit(eg, VerifyOptions::default()).is_err());
    }

    #[test]
    fn explicit_shutdown_then_submit_errors() {
        let server = Server::spawn(SessionConfig::default(), dummy_backend);
        let h = server.handle();
        server.shutdown();
        let eg = crate::features::EdaGraph::from_aig(&crate::aig::mult::csa_multiplier(3));
        assert!(h.verify_blocking(eg.clone(), VerifyOptions::default()).is_err());
        match h.try_submit(eg, VerifyOptions::default()) {
            Err(e) => assert!(e.to_string().contains("server stopped"), "{e:#}"),
            Ok(_) => panic!("try_submit accepted after shutdown"),
        }
    }

    #[test]
    fn partially_failed_worker_fleet_serves_from_healthy_workers() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // 3 workers, the first two factory calls fail: the failed
        // workers must step aside, and every request must succeed via
        // the healthy worker — no nondeterministic error subset.
        let calls = Arc::new(AtomicUsize::new(0));
        let calls_f = Arc::clone(&calls);
        let server = Server::spawn(
            SessionConfig { workers: 3, threads: 1, ..Default::default() },
            move || {
                if calls_f.fetch_add(1, Ordering::SeqCst) < 2 {
                    anyhow::bail!("synthetic init failure");
                }
                dummy_backend()
            },
        );
        let h = server.handle();
        let eg = crate::features::EdaGraph::from_aig(&crate::aig::mult::csa_multiplier(4));
        for _ in 0..6 {
            let r = h.verify_blocking(eg.clone(), VerifyOptions::partitions(2));
            assert!(r.is_ok(), "healthy worker must absorb the whole load: {r:?}");
        }
        server.shutdown();
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn fully_failed_worker_fleet_answers_errors_instead_of_hanging() {
        let server = Server::spawn(
            SessionConfig { workers: 3, threads: 1, ..Default::default() },
            || anyhow::bail!("no backend today"),
        );
        let h = server.handle();
        let eg = crate::features::EdaGraph::from_aig(&crate::aig::mult::csa_multiplier(3));
        let err = h.verify_blocking(eg, VerifyOptions::default()).unwrap_err();
        assert!(err.to_string().contains("backend init failed"), "{err:#}");
    }

    /// Backend whose inference always panics — stands in for a kernel
    /// assert tripping on a request the shape validation admitted.
    struct PanickingBackend;

    impl crate::backend::InferenceBackend for PanickingBackend {
        fn name(&self) -> &'static str {
            "panicking"
        }
        fn num_classes(&self) -> usize {
            5
        }
        fn infer(
            &self,
            _part: crate::backend::PartitionInput<'_>,
        ) -> Result<crate::backend::PartitionLogits> {
            panic!("synthetic kernel panic");
        }
        fn infer_batch(
            &self,
            _parts: &[crate::backend::PartitionInput<'_>],
        ) -> Result<Vec<crate::backend::PartitionLogits>> {
            panic!("synthetic kernel panic");
        }
    }

    #[test]
    fn worker_panic_fails_clients_instead_of_hanging_them() {
        // Single worker dies mid-request: the triggering caller must get
        // an error (its reply channel disconnects during unwind), and
        // the dead fleet must fail later submissions rather than queue
        // them for a drain that will never come.
        let server = Server::spawn(
            SessionConfig { workers: 1, threads: 1, ..Default::default() },
            || Ok(Box::new(PanickingBackend) as Backend),
        );
        let h = server.handle();
        let eg = crate::features::EdaGraph::from_aig(&crate::aig::mult::csa_multiplier(3));
        let err = h
            .verify_blocking(eg.clone(), VerifyOptions::default())
            .expect_err("a panicked worker must not produce an answer");
        assert!(err.to_string().contains("dropped reply"), "{err:#}");
        // Give the death guard a moment to close the queue, then later
        // submissions must error instead of queueing into the void.
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        loop {
            match h.submit(eg.clone(), VerifyOptions::default()) {
                Err(_) => break, // "server stopped" — guard fired
                Ok(rx) => {
                    // Raced ahead of the guard: the queued request must
                    // still be failed by fail_pending, not stranded.
                    assert!(
                        rx.recv_timeout(Duration::from_secs(30)).is_err(),
                        "request queued after a fleet-wide death was silently kept"
                    );
                }
            }
            assert!(std::time::Instant::now() < deadline, "death guard never closed the queue");
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    #[test]
    fn requests_queued_before_shutdown_are_answered() {
        let server = Server::spawn(SessionConfig::default(), dummy_backend);
        let h = server.handle();
        let eg = crate::features::EdaGraph::from_aig(&crate::aig::mult::csa_multiplier(4));
        let pending: Vec<_> = (0..6)
            .map(|_| h.submit(eg.clone(), VerifyOptions::partitions(2)).unwrap())
            .collect();
        server.shutdown(); // drains, answers, then joins
        for rx in pending {
            let r = rx.recv().expect("queued request dropped").unwrap();
            assert_eq!(r.pred.len(), eg.num_nodes);
        }
    }
}

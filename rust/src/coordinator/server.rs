//! Verification service — the staged pipeline behind a request channel.
//!
//! The paper frames GROOT as a run-time verification system; this module
//! provides the serving shape: callers submit circuits with per-request
//! [`VerifyOptions`], a router thread owns the (non-`Send`) backend *and
//! the plan cache*, and answers on per-request channels. For every
//! request the router prepares the graph, looks its
//! [`PartitionPlan`](super::PartitionPlan) up in an LRU keyed by
//! `(content fingerprint, PlanOptions)` — so repeat verifications of the
//! same circuit skip partitioning/re-growth/gathering entirely — and
//! submits all partitions through one `infer_batch` call.
//! [`RunStats::plan_cache_hit`](super::RunStats) and
//! [`RunStats::batch_size`](super::RunStats) expose both effects per
//! response.
//!
//! Shutdown is an explicit sentinel message: dropping (or
//! [`Server::shutdown`]-ing) the server wakes the router even while
//! user-cloned [`ServerHandle`]s keep the request channel open, so
//! `join()` terminates deterministically. Used by `examples/serve.rs`.

use super::{Backend, ClassifyResult, PlanCache, PlanOptions, PreparedGraph, Session, SessionConfig};
use crate::features::EdaGraph;
use anyhow::Result;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// Per-request plan options; `None` fields inherit the server's base
/// [`SessionConfig`].
#[derive(Clone, Debug, Default)]
pub struct VerifyOptions {
    pub partitions: Option<usize>,
    pub regrow: Option<bool>,
    pub seed: Option<u64>,
}

impl VerifyOptions {
    /// Shorthand for the common "just override the partition count" case.
    pub fn partitions(n: usize) -> VerifyOptions {
        VerifyOptions { partitions: Some(n), ..Default::default() }
    }

    /// Resolve against the server's base config into a full plan key.
    pub fn resolve(&self, base: &SessionConfig) -> PlanOptions {
        PlanOptions {
            partitions: self.partitions.unwrap_or(base.num_partitions),
            regrow: self.regrow.unwrap_or(base.regrow),
            seed: self.seed.unwrap_or(base.seed),
        }
    }
}

/// A verification request: graph + per-request plan options.
pub struct Request {
    pub graph: EdaGraph,
    pub options: VerifyOptions,
    pub reply: mpsc::Sender<Result<ClassifyResult>>,
}

/// Router mailbox: work, or the explicit shutdown sentinel the owning
/// [`Server`] sends on drop (closing the channel alone is not enough —
/// cloned handles keep it open).
enum Msg {
    Verify(Box<Request>),
    Shutdown,
}

/// Handle for submitting requests to a running server. Cloneable and
/// `Send`; outliving the `Server` is safe (submissions then fail with
/// "server stopped").
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
}

impl ServerHandle {
    /// Submit and wait (convenience for examples/tests).
    pub fn verify_blocking(
        &self,
        graph: EdaGraph,
        options: VerifyOptions,
    ) -> Result<ClassifyResult> {
        let rx = self.submit(graph, options)?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped reply"))?
    }

    /// Submit without waiting; returns the reply receiver.
    pub fn submit(
        &self,
        graph: EdaGraph,
        options: VerifyOptions,
    ) -> Result<mpsc::Receiver<Result<ClassifyResult>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Verify(Box::new(Request { graph, options, reply })))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx)
    }
}

/// The running server; shuts its router down (sentinel + join) on drop.
pub struct Server {
    handle: ServerHandle,
    join: Option<JoinHandle<()>>,
}

impl Server {
    /// Spawn the router thread with the default plan-cache capacity.
    /// `make_backend` runs *on* the router thread because backends need
    /// not be `Send` (PJRT clients are `Rc`-based); only the constructor
    /// closure crosses threads.
    pub fn spawn<F>(config: SessionConfig, make_backend: F) -> Server
    where
        F: FnOnce() -> Result<Backend> + Send + 'static,
    {
        Self::spawn_with_cache(config, super::DEFAULT_PLAN_CACHE_CAPACITY, make_backend)
    }

    /// Spawn with an explicit plan-cache capacity (0 is clamped to 1).
    ///
    /// Capacity is an entry count, not a byte budget: each cached plan
    /// holds its circuit's partition node lists, local CSRs, and
    /// gathered f32 feature buffers — roughly one graph's worth of data
    /// per entry. Deployments serving many distinct large circuits
    /// should size this against `capacity × largest-graph footprint`.
    pub fn spawn_with_cache<F>(
        config: SessionConfig,
        plan_cache_capacity: usize,
        make_backend: F,
    ) -> Server
    where
        F: FnOnce() -> Result<Backend> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = std::thread::Builder::new()
            .name("groot-router".into())
            .spawn(move || {
                let backend = match make_backend() {
                    Ok(b) => b,
                    Err(e) => {
                        // Answer requests with the construction error
                        // until shutdown.
                        for msg in rx.iter() {
                            match msg {
                                Msg::Verify(req) => {
                                    let _ = req.reply.send(Err(anyhow::anyhow!(
                                        "backend init failed: {e:#}"
                                    )));
                                }
                                Msg::Shutdown => return,
                            }
                        }
                        return;
                    }
                };
                let session = Session::new(backend, config);
                let mut plans = PlanCache::new(plan_cache_capacity);
                for msg in rx.iter() {
                    let req = match msg {
                        Msg::Verify(req) => req,
                        Msg::Shutdown => break,
                    };
                    let opts = req.options.resolve(&session.config);
                    // Preparation is cheap (content hash); the CSR and
                    // feature matrix only materialize on a cache miss,
                    // inside plan().
                    let prepared = PreparedGraph::new(&req.graph);
                    let (plan, hit) = plans.get_or_build(&prepared, &opts);
                    let out = session.classify_plan(&prepared, &plan, hit);
                    let _ = req.reply.send(out);
                }
            })
            .expect("spawn router");
        Server { handle: ServerHandle { tx }, join: Some(join) }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Explicit deterministic shutdown: in-flight requests already queued
    /// ahead of the sentinel are answered; later submissions fail.
    /// (Dropping the server does the same.)
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // The sentinel — NOT channel closure — stops the router: cloned
        // user handles may keep the channel alive indefinitely, which
        // used to deadlock this join.
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::gnn::{SageLayer, SageModel};
    use std::time::Duration;

    fn dummy_model() -> SageModel {
        SageModel {
            layers: vec![SageLayer {
                din: 4,
                dout: 5,
                w_self: vec![0.1; 20],
                w_neigh: vec![0.1; 20],
                bias: vec![0.0; 5],
            }],
        }
    }

    fn dummy_backend() -> Result<Backend> {
        Ok(Box::new(NativeBackend::new(dummy_model())))
    }

    #[test]
    fn server_round_trips_requests() {
        let server = Server::spawn(SessionConfig::default(), dummy_backend);
        let h = server.handle();
        let g = crate::aig::mult::csa_multiplier(4);
        let eg = crate::features::EdaGraph::from_aig(&g);
        // overlapping async submissions
        let rx1 = h.submit(eg.clone(), VerifyOptions::partitions(2)).unwrap();
        let rx2 = h.submit(eg.clone(), VerifyOptions::partitions(4)).unwrap();
        let r1 = rx1.recv().unwrap().unwrap();
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r1.pred.len(), eg.num_nodes);
        assert_eq!(r2.stats.num_partitions, 4);
    }

    #[test]
    fn server_survives_many_sequential_requests() {
        let server = Server::spawn(SessionConfig::default(), dummy_backend);
        let h = server.handle();
        let g = crate::aig::mult::csa_multiplier(3);
        let eg = crate::features::EdaGraph::from_aig(&g);
        for k in 1..=6 {
            let r = h.verify_blocking(eg.clone(), VerifyOptions::partitions(k)).unwrap();
            assert_eq!(r.stats.num_partitions, k.min(eg.num_nodes));
        }
    }

    #[test]
    fn repeat_requests_hit_the_plan_cache() {
        let server = Server::spawn(SessionConfig::default(), dummy_backend);
        let h = server.handle();
        let eg = crate::features::EdaGraph::from_aig(&crate::aig::mult::csa_multiplier(4));
        let cold = h.verify_blocking(eg.clone(), VerifyOptions::partitions(3)).unwrap();
        assert!(!cold.stats.plan_cache_hit);
        let warm = h.verify_blocking(eg.clone(), VerifyOptions::partitions(3)).unwrap();
        assert!(warm.stats.plan_cache_hit, "same circuit+options must reuse the plan");
        assert_eq!(warm.stats.partition_time, Duration::ZERO);
        assert_eq!(warm.stats.regrowth_time, Duration::ZERO);
        assert_eq!(warm.pred, cold.pred);
        // different options on the same circuit: a different plan
        let other = h.verify_blocking(eg, VerifyOptions::partitions(2)).unwrap();
        assert!(!other.stats.plan_cache_hit);
    }

    #[test]
    fn dropping_server_with_live_handle_clone_terminates() {
        // Regression: `Server::drop` used to wait for the request channel
        // to close, which never happens while a cloned handle is alive.
        let server = Server::spawn(SessionConfig::default(), dummy_backend);
        let clone = server.handle();
        let (done_tx, done_rx) = mpsc::channel();
        std::thread::spawn(move || {
            drop(server);
            let _ = done_tx.send(());
        });
        done_rx
            .recv_timeout(Duration::from_secs(30))
            .expect("Server::drop hung with a live ServerHandle clone");
        // The surviving handle reports a stopped server instead of
        // queueing into the void.
        let eg = crate::features::EdaGraph::from_aig(&crate::aig::mult::csa_multiplier(3));
        assert!(clone.submit(eg, VerifyOptions::default()).is_err());
    }

    #[test]
    fn explicit_shutdown_then_submit_errors() {
        let server = Server::spawn(SessionConfig::default(), dummy_backend);
        let h = server.handle();
        server.shutdown();
        let eg = crate::features::EdaGraph::from_aig(&crate::aig::mult::csa_multiplier(3));
        assert!(h.verify_blocking(eg, VerifyOptions::default()).is_err());
    }
}

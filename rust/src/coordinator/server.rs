//! Verification service — request router + dynamic batcher.
//!
//! The paper frames GROOT as a run-time verification system; this module
//! provides the serving shape: callers submit circuits, a router thread
//! owns the (non-`Send`) session and drains the queue, grouping partition
//! work so padding waste is amortized, and answers on per-request
//! channels. Used by `examples/serve.rs`.

use super::{Backend, ClassifyResult, Session, SessionConfig};
use crate::features::EdaGraph;
use anyhow::Result;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// A verification request: graph + per-request partitioning override.
pub struct Request {
    pub graph: EdaGraph,
    pub num_partitions: Option<usize>,
    pub reply: mpsc::Sender<Result<ClassifyResult>>,
}

/// Handle for submitting requests to a running server.
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Request>,
}

impl ServerHandle {
    /// Submit and wait (convenience for examples/tests).
    pub fn verify_blocking(
        &self,
        graph: EdaGraph,
        num_partitions: Option<usize>,
    ) -> Result<ClassifyResult> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request { graph, num_partitions, reply })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped reply"))?
    }

    /// Submit without waiting; returns the reply receiver.
    pub fn submit(
        &self,
        graph: EdaGraph,
        num_partitions: Option<usize>,
    ) -> Result<mpsc::Receiver<Result<ClassifyResult>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request { graph, num_partitions, reply })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx)
    }
}

/// The running server; joins its router thread on drop.
pub struct Server {
    handle: ServerHandle,
    join: Option<JoinHandle<()>>,
}

impl Server {
    /// Spawn the router thread. `make_backend` runs *on* the router thread
    /// because backends need not be `Send` (PJRT clients are `Rc`-based);
    /// only the constructor closure crosses threads.
    pub fn spawn<F>(config: SessionConfig, make_backend: F) -> Server
    where
        F: FnOnce() -> Result<Backend> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let join = std::thread::Builder::new()
            .name("groot-router".into())
            .spawn(move || {
                let backend = match make_backend() {
                    Ok(b) => b,
                    Err(e) => {
                        // Drain requests with the construction error.
                        for req in rx.iter() {
                            let _ = req
                                .reply
                                .send(Err(anyhow::anyhow!("backend init failed: {e:#}")));
                        }
                        return;
                    }
                };
                let base = Session::new(backend, config);
                for req in rx.iter() {
                    let mut cfg = base.config.clone();
                    if let Some(p) = req.num_partitions {
                        cfg.num_partitions = p;
                    }
                    let out = base.classify_with(&req.graph, &cfg);
                    let _ = req.reply.send(out);
                }
            })
            .expect("spawn router");
        Server { handle: ServerHandle { tx }, join: Some(join) }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Closing the channel stops the router loop.
        let (dead_tx, _) = mpsc::channel();
        self.handle = ServerHandle { tx: dead_tx };
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::gnn::{SageLayer, SageModel};

    fn dummy_model() -> SageModel {
        SageModel {
            layers: vec![SageLayer {
                din: 4,
                dout: 5,
                w_self: vec![0.1; 20],
                w_neigh: vec![0.1; 20],
                bias: vec![0.0; 5],
            }],
        }
    }

    fn dummy_backend() -> Result<Backend> {
        Ok(Box::new(NativeBackend::new(dummy_model())))
    }

    #[test]
    fn server_round_trips_requests() {
        let server = Server::spawn(SessionConfig::default(), dummy_backend);
        let h = server.handle();
        let g = crate::aig::mult::csa_multiplier(4);
        let eg = crate::features::EdaGraph::from_aig(&g);
        // overlapping async submissions
        let rx1 = h.submit(eg.clone(), Some(2)).unwrap();
        let rx2 = h.submit(eg.clone(), Some(4)).unwrap();
        let r1 = rx1.recv().unwrap().unwrap();
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r1.pred.len(), eg.num_nodes);
        assert_eq!(r2.stats.num_partitions, 4);
    }

    #[test]
    fn server_survives_many_sequential_requests() {
        let server = Server::spawn(SessionConfig::default(), dummy_backend);
        let h = server.handle();
        let g = crate::aig::mult::csa_multiplier(3);
        let eg = crate::features::EdaGraph::from_aig(&g);
        for k in 1..=6 {
            let r = h.verify_blocking(eg.clone(), Some(k)).unwrap();
            assert_eq!(r.stats.num_partitions, k.min(eg.num_nodes));
        }
    }
}

//! Persistent plan store — the disk tier under [`super::ShardedPlanCache`].
//!
//! A [`crate::coordinator::PartitionPlan`] is fully owned (node lists,
//! local CSRs, gathered feature buffers), which makes it serializable as
//! well as cacheable. The store writes one file per `(graph fingerprint,
//! PlanOptions)` key under a `--plan-dir`, so a RESTARTED server answers
//! its first request for a known design from disk with **zero**
//! partitioner invocations (pinned by `rust/tests/net_serving.rs`
//! against [`crate::partition::kway_invocations`]).
//!
//! Trust model — a store file is never taken at its word:
//! * **format-versioned**: magic `"GPLN"` + version; an unknown version
//!   is quarantined, not "best-effort parsed".
//! * **checksummed**: FNV-1a over the entire payload; bit rot and
//!   truncation fail closed.
//! * **key-checked**: the payload re-states fingerprint + options; a
//!   renamed or mis-keyed file cannot impersonate another design.
//! * **structurally validated**: node ids, CSR shape, feature-buffer
//!   arithmetic, and core-cover counts are re-checked on load — exactly
//!   the invariants `execute_plan` would otherwise trip over.
//!
//! Any failure **quarantines** the file (rename to `*.quarantined-N`) and
//! reports a miss; the caller rebuilds and writes back a fresh copy.
//! Writes are write-temp-then-rename, so a crash mid-write leaves a
//! stale temp file, never a torn store entry.

use super::pipeline::{PlanStats, PlannedPartition};
use super::{PartitionPlan, PlanOptions};
use crate::features::GROOT_FEATURE_DIM;
use crate::graph::Csr;
use crate::obs::{log, metrics};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Duration;

/// Store file magic + format version. Bump the version on ANY layout
/// change: old files then quarantine and rebuild instead of misparsing.
/// v2 adds a per-partition content digest after `num_core`; v1 files
/// remain readable ([`STORE_MIN_VERSION`]) with digests recomputed at
/// load.
pub const STORE_MAGIC: [u8; 4] = *b"GPLN";
pub const STORE_VERSION: u16 = 2;
/// Oldest GPLN version `load` still accepts.
pub const STORE_MIN_VERSION: u16 = 1;

/// Prediction-record magic + version — the sibling record type storing
/// one partition's core predictions keyed by content digest + model
/// tag (see [`PlanStore::save_predictions`]).
pub const PRED_MAGIC: [u8; 4] = *b"GPPR";
pub const PRED_VERSION: u16 = 1;

/// Fixed-size file header: magic, version, reserved pad, payload
/// checksum, payload length.
const HEADER_LEN: usize = 4 + 2 + 2 + 8 + 8;

const LOG_TARGET: &str = "coordinator::planstore";

/// Process-wide disk-tier counters for the metrics registry, one family
/// labeled by operation (every [`PlanStore`] instance feeds the same
/// series; per-instance numbers stay on the store's own atomics).
struct StoreMetrics {
    loads: metrics::Counter,
    writes: metrics::Counter,
    quarantined: metrics::Counter,
    pred_loads: metrics::Counter,
    pred_writes: metrics::Counter,
}

fn store_metrics() -> &'static StoreMetrics {
    static M: OnceLock<StoreMetrics> = OnceLock::new();
    M.get_or_init(|| {
        let r = metrics::registry();
        const HELP: &str = "Persistent plan-store operations by kind (load = validated \
                            disk read, write = plan file written, quarantine = file \
                            rejected by validation and renamed aside; pred_load / \
                            pred_write = the prediction-record sibling type).";
        StoreMetrics {
            loads: r.counter("groot_plan_store_ops_total", HELP, &[("op", "load")]),
            writes: r.counter("groot_plan_store_ops_total", HELP, &[("op", "write")]),
            quarantined: r.counter("groot_plan_store_ops_total", HELP, &[("op", "quarantine")]),
            pred_loads: r.counter("groot_plan_store_ops_total", HELP, &[("op", "pred_load")]),
            pred_writes: r.counter("groot_plan_store_ops_total", HELP, &[("op", "pred_write")]),
        }
    })
}

/// Fingerprint+options-keyed persistent plan files under one directory.
/// `Sync` (path + atomic counters only), shared by all serving workers
/// through the [`super::ShardedPlanCache`] that owns it.
pub struct PlanStore {
    dir: PathBuf,
    loads: AtomicU64,
    writes: AtomicU64,
    quarantined: AtomicU64,
    pred_loads: AtomicU64,
    pred_writes: AtomicU64,
}

impl PlanStore {
    /// Open (creating if needed) a plan directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<PlanStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create plan dir {}", dir.display()))?;
        Ok(PlanStore {
            dir,
            loads: AtomicU64::new(0),
            writes: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            pred_loads: AtomicU64::new(0),
            pred_writes: AtomicU64::new(0),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Successful (fully validated) disk loads.
    pub fn loads(&self) -> u64 {
        self.loads.load(Ordering::SeqCst)
    }

    /// Plan files written.
    pub fn writes(&self) -> u64 {
        self.writes.load(Ordering::SeqCst)
    }

    /// Files rejected by validation and renamed aside.
    pub fn quarantined(&self) -> u64 {
        self.quarantined.load(Ordering::SeqCst)
    }

    /// Successful (fully validated) prediction-record loads.
    pub fn pred_loads(&self) -> u64 {
        self.pred_loads.load(Ordering::SeqCst)
    }

    /// Prediction records written.
    pub fn pred_writes(&self) -> u64 {
        self.pred_writes.load(Ordering::SeqCst)
    }

    /// The store path of a key at a specific format version.
    fn path_for_version(&self, fingerprint: u64, opts: &PlanOptions, version: u16) -> PathBuf {
        self.dir.join(format!(
            "plan-{fingerprint:016x}-{:016x}.v{version}.gpln",
            options_hash(opts)
        ))
    }

    /// The store path of a key. Options are folded into the file name by
    /// hash (the payload re-states them exactly, so a hash collision is
    /// caught at load time, not trusted).
    pub fn path_for(&self, fingerprint: u64, opts: &PlanOptions) -> PathBuf {
        self.path_for_version(fingerprint, opts, STORE_VERSION)
    }

    /// The store path of a prediction record (one partition's core
    /// predictions, keyed by content digest + model tag).
    pub fn pred_path_for(&self, digest: u64, model_tag: u64) -> PathBuf {
        self.dir
            .join(format!("pred-{digest:016x}-{model_tag:016x}.v{PRED_VERSION}.gppr"))
    }

    /// Rename a failed-validation file aside and record the event.
    fn quarantine(&self, path: &Path, what: &str, e: anyhow::Error) {
        let n = self.quarantined.fetch_add(1, Ordering::SeqCst);
        store_metrics().quarantined.inc();
        let aside = path.with_extension(format!("quarantined-{n}"));
        log::warn(
            LOG_TARGET,
            format_args!(
                "quarantining {what} file {} ({e:#}); renamed to {}",
                path.display(),
                aside.display()
            ),
        );
        let _ = std::fs::rename(path, aside);
    }

    /// Load and validate the plan for a key. `None` means "not stored"
    /// OR "stored but untrustworthy" — the latter also renames the file
    /// to `*.quarantined-N` so the rebuilt plan's write-back replaces it
    /// and the bad bytes stay on disk for postmortems. Tries the current
    /// format first, then falls back to still-readable older versions
    /// (a v1 file loads with its digests recomputed; the next write-back
    /// persists it as v2).
    pub fn load(&self, fingerprint: u64, opts: &PlanOptions) -> Option<PartitionPlan> {
        for version in (STORE_MIN_VERSION..=STORE_VERSION).rev() {
            let path = self.path_for_version(fingerprint, opts, version);
            let bytes = match std::fs::read(&path) {
                Ok(b) => b,
                Err(_) => continue,
            };
            return match decode_plan(&bytes, fingerprint, opts) {
                Ok(plan) => {
                    self.loads.fetch_add(1, Ordering::SeqCst);
                    store_metrics().loads.inc();
                    Some(plan)
                }
                Err(e) => {
                    self.quarantine(&path, "plan", e);
                    None
                }
            };
        }
        None
    }

    /// Serialize a plan to its key's file: write `*.tmp-<pid>`, then
    /// rename into place (atomic on POSIX), so concurrent writers and
    /// crashes can only ever race whole files.
    pub fn save(&self, plan: &PartitionPlan) -> Result<()> {
        let bytes = encode_plan(plan);
        let path = self.path_for(plan.fingerprint, &plan.options);
        let tmp = path.with_extension(format!("tmp-{}", std::process::id()));
        std::fs::write(&tmp, &bytes)
            .with_context(|| format!("write plan temp {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("rename plan into {}", path.display()))?;
        self.writes.fetch_add(1, Ordering::SeqCst);
        store_metrics().writes.inc();
        Ok(())
    }

    /// Persist one partition's core predictions under its content
    /// digest + model tag. The model tag pins records to one weight
    /// bundle: content digests identify the *inputs* to inference, so
    /// predictions are only reusable under the same weights. Same
    /// trust model as plans: versioned, checksummed, key-re-stated,
    /// write-temp-then-rename.
    pub fn save_predictions(&self, digest: u64, model_tag: u64, core: &[u8]) -> Result<()> {
        let mut p = Vec::with_capacity(24 + core.len());
        put_u64(&mut p, digest);
        put_u64(&mut p, model_tag);
        put_u64(&mut p, core.len() as u64);
        p.extend_from_slice(core);

        let mut out = Vec::with_capacity(HEADER_LEN + p.len());
        out.extend_from_slice(&PRED_MAGIC);
        out.extend_from_slice(&PRED_VERSION.to_le_bytes());
        out.extend_from_slice(&[0u8; 2]); // reserved
        put_u64(&mut out, checksum(&p));
        put_u64(&mut out, p.len() as u64);
        out.extend_from_slice(&p);

        let path = self.pred_path_for(digest, model_tag);
        let tmp = path.with_extension(format!("tmp-{}", std::process::id()));
        std::fs::write(&tmp, &out)
            .with_context(|| format!("write prediction temp {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("rename prediction into {}", path.display()))?;
        self.pred_writes.fetch_add(1, Ordering::SeqCst);
        store_metrics().pred_writes.inc();
        Ok(())
    }

    /// Load and validate the prediction record for `(digest, model
    /// tag)`. `None` means "not stored" or "failed validation" (the
    /// latter quarantines the file, like plan loads).
    pub fn load_predictions(&self, digest: u64, model_tag: u64) -> Option<Vec<u8>> {
        let path = self.pred_path_for(digest, model_tag);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(_) => return None,
        };
        match decode_predictions(&bytes, digest, model_tag) {
            Ok(core) => {
                self.pred_loads.fetch_add(1, Ordering::SeqCst);
                store_metrics().pred_loads.inc();
                Some(core)
            }
            Err(e) => {
                self.quarantine(&path, "prediction", e);
                None
            }
        }
    }
}

/// FNV-1a over the options fields — the file-name key component.
fn options_hash(opts: &PlanOptions) -> u64 {
    let mut h = Fnv::new();
    h.eat(opts.partitions as u64);
    h.eat(opts.regrow as u64);
    h.eat(opts.seed);
    h.eat(opts.hd_threshold as u64);
    h.finish()
}

/// Word-wise FNV-1a, shared by the file-name key and the payload
/// checksum (byte stream padded into words).
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn eat(&mut self, word: u64) {
        self.0 ^= word;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    fn eat_bytes(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut w = [0u8; 8];
            w[..chunk.len()].copy_from_slice(chunk);
            self.eat(u64::from_le_bytes(w));
        }
        self.eat(bytes.len() as u64);
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

fn checksum(payload: &[u8]) -> u64 {
    let mut h = Fnv::new();
    h.eat_bytes(payload);
    h.finish()
}

// ---- encoding -------------------------------------------------------------

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u32_slice(b: &mut Vec<u8>, vs: &[u32]) {
    put_u64(b, vs.len() as u64);
    for &v in vs {
        b.extend_from_slice(&v.to_le_bytes());
    }
}

/// Serialize: header (magic | version | reserved | checksum | payload
/// length) + payload. Payload layout (all little-endian u64 unless
/// noted):
///
/// ```text
/// fingerprint | num_nodes |
/// partitions | regrow u8 | seed | hd_threshold |
/// partition_ns | regrowth_ns | gather_ns |
/// core_nodes | boundary_nodes | internal_edges | crossing_edges | max_part |
/// hd_rows | ld_rows |
/// num_parts | per part:
///   part_id | num_core | digest (v2+) |
///   nodes     (count | u32 × count)
///   row_ptr   (count | u64 × count)
///   col_idx   (count | u32 × count)
///   features  (count | f32-bits u32 × count)
/// ```
fn encode_plan(plan: &PartitionPlan) -> Vec<u8> {
    let mut p = Vec::new();
    put_u64(&mut p, plan.fingerprint);
    put_u64(&mut p, plan.num_nodes as u64);
    put_u64(&mut p, plan.options.partitions as u64);
    p.push(plan.options.regrow as u8);
    put_u64(&mut p, plan.options.seed);
    put_u64(&mut p, plan.options.hd_threshold as u64);
    put_u64(&mut p, plan.stats.partition_time.as_nanos() as u64);
    put_u64(&mut p, plan.stats.regrowth_time.as_nanos() as u64);
    put_u64(&mut p, plan.stats.gather_time.as_nanos() as u64);
    put_u64(&mut p, plan.stats.regrowth.total_core_nodes as u64);
    put_u64(&mut p, plan.stats.regrowth.total_boundary_nodes as u64);
    put_u64(&mut p, plan.stats.regrowth.total_internal_edges as u64);
    put_u64(&mut p, plan.stats.regrowth.total_crossing_edges as u64);
    put_u64(&mut p, plan.stats.regrowth.max_partition_nodes as u64);
    put_u64(&mut p, plan.stats.hd_rows as u64);
    put_u64(&mut p, plan.stats.ld_rows as u64);
    put_u64(&mut p, plan.parts.len() as u64);
    for part in &plan.parts {
        put_u64(&mut p, part.part_id as u64);
        put_u64(&mut p, part.num_core as u64);
        put_u64(&mut p, part.digest);
        put_u32_slice(&mut p, &part.nodes);
        put_u64(&mut p, part.csr.row_ptr.len() as u64);
        for &r in &part.csr.row_ptr {
            put_u64(&mut p, r as u64);
        }
        put_u32_slice(&mut p, &part.csr.col_idx);
        put_u64(&mut p, part.features.len() as u64);
        for &f in &part.features {
            p.extend_from_slice(&f.to_bits().to_le_bytes());
        }
    }

    let mut out = Vec::with_capacity(HEADER_LEN + p.len());
    out.extend_from_slice(&STORE_MAGIC);
    out.extend_from_slice(&STORE_VERSION.to_le_bytes());
    out.extend_from_slice(&[0u8; 2]); // reserved
    put_u64(&mut out, checksum(&p));
    put_u64(&mut out, p.len() as u64);
    out.extend_from_slice(&p);
    out
}

// ---- decoding -------------------------------------------------------------

/// Bounds-checked little-endian reader over the payload.
struct Rd<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.buf.len() - self.at >= n,
            "plan store: truncated {what} (need {n} bytes at offset {}, have {})",
            self.at,
            self.buf.len() - self.at
        );
        let out = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(out)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    /// Length-prefixed count, sanity-bounded against the remaining
    /// buffer so a corrupt count cannot drive a huge allocation.
    fn count(&mut self, elem_bytes: usize, what: &str) -> Result<usize> {
        let n = self.u64(what)?;
        anyhow::ensure!(
            (n as usize).checked_mul(elem_bytes).is_some_and(|b| b <= self.buf.len() - self.at),
            "plan store: {what} count {n} exceeds remaining payload"
        );
        Ok(n as usize)
    }

    fn u32_vec(&mut self, what: &str) -> Result<Vec<u32>> {
        let n = self.count(4, what)?;
        Ok(self
            .take(n * 4, what)?
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

fn decode_plan(bytes: &[u8], fingerprint: u64, opts: &PlanOptions) -> Result<PartitionPlan> {
    anyhow::ensure!(bytes.len() >= HEADER_LEN, "plan store: short header");
    anyhow::ensure!(bytes[..4] == STORE_MAGIC, "plan store: bad magic");
    let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
    anyhow::ensure!(
        (STORE_MIN_VERSION..=STORE_VERSION).contains(&version),
        "plan store: version {version} (want {STORE_MIN_VERSION}..={STORE_VERSION})"
    );
    let want_sum = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let payload_len = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    let payload = &bytes[HEADER_LEN..];
    anyhow::ensure!(
        payload.len() as u64 == payload_len,
        "plan store: payload length mismatch ({} on disk, header says {payload_len})",
        payload.len()
    );
    anyhow::ensure!(checksum(payload) == want_sum, "plan store: checksum mismatch");

    let mut r = Rd { buf: payload, at: 0 };
    let stored_fp = r.u64("fingerprint")?;
    let num_nodes = r.u64("num_nodes")? as usize;
    let options = PlanOptions {
        partitions: r.u64("partitions")? as usize,
        regrow: r.u8("regrow")? != 0,
        seed: r.u64("seed")?,
        hd_threshold: r.u64("hd_threshold")? as usize,
        // Build-thread hint: an execution knob, never serialized (and
        // excluded from PlanOptions equality, so the key check below
        // still matches requests made with any budget).
        threads: 0,
    };
    // Key check: the file content must name the key it was looked up
    // under. (The file name already encodes both, but names are cheap to
    // forge or mangle; the payload is what the checksum covers.)
    anyhow::ensure!(
        stored_fp == fingerprint && &options == opts,
        "plan store: stored key (fp {stored_fp:016x}, {options:?}) \
         does not match requested (fp {fingerprint:016x}, {opts:?})"
    );
    let mut stats = PlanStats {
        partition_time: Duration::from_nanos(r.u64("partition_ns")?),
        regrowth_time: Duration::from_nanos(r.u64("regrowth_ns")?),
        gather_time: Duration::from_nanos(r.u64("gather_ns")?),
        regrowth: crate::regrowth::RegrowthStats {
            total_core_nodes: r.u64("core_nodes")? as usize,
            total_boundary_nodes: r.u64("boundary_nodes")? as usize,
            total_internal_edges: r.u64("internal_edges")? as usize,
            total_crossing_edges: r.u64("crossing_edges")? as usize,
            max_partition_nodes: r.u64("max_part")? as usize,
        },
        hd_rows: r.u64("hd_rows")? as usize,
        ld_rows: r.u64("ld_rows")? as usize,
        edge_cut: 0,
        replication: 0.0,
        balance: 0.0,
        content_digest: 0,
    };
    // Quality stats are derived, not serialized (no format bump): with
    // re-growth every cut edge is a crossing edge in both endpoint
    // partitions; without it crossing edges are zero and a loaded plan
    // reports edge_cut 0 — the stored RegrowthStats carry no substitute.
    stats.edge_cut = stats.regrowth.total_crossing_edges / 2;
    stats.replication = if stats.regrowth.total_core_nodes == 0 {
        1.0
    } else {
        (stats.regrowth.total_core_nodes + stats.regrowth.total_boundary_nodes) as f64
            / stats.regrowth.total_core_nodes as f64
    };

    let num_parts = r.count(16, "partition")?;
    let mut parts = Vec::with_capacity(num_parts);
    let mut core_total = 0usize;
    for i in 0..num_parts {
        let part_id = r.u64("part_id")? as usize;
        let num_core = r.u64("num_core")? as usize;
        // v1 has no stored digest (recomputed below); v2 re-states it
        // so content corruption that survives the checksum cannot slip
        // a wrong-content partition past the incremental cache.
        let stored_digest = if version >= 2 { Some(r.u64("digest")?) } else { None };
        let nodes = r.u32_vec("nodes")?;
        let row_ptr_len = r.count(8, "row_ptr")?;
        let row_ptr: Vec<usize> = r
            .take(row_ptr_len * 8, "row_ptr")?
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect();
        let col_idx = r.u32_vec("col_idx")?;
        let feat_len = r.count(4, "features")?;
        let features: Vec<f32> = r
            .take(feat_len * 4, "features")?
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect();

        // Structural validation — the execute_plan invariants, checked
        // here so a tampered file errors at load, not mid-inference.
        anyhow::ensure!(num_core <= nodes.len(), "partition {i}: core count overruns nodes");
        anyhow::ensure!(
            nodes.iter().all(|&u| (u as usize) < num_nodes),
            "partition {i}: node id out of range"
        );
        anyhow::ensure!(
            row_ptr.len() == nodes.len() + 1
                && row_ptr.first() == Some(&0)
                && row_ptr.last() == Some(&col_idx.len())
                && row_ptr.windows(2).all(|w| w[0] <= w[1]),
            "partition {i}: malformed local CSR row pointers"
        );
        anyhow::ensure!(
            col_idx.iter().all(|&v| (v as usize) < nodes.len()),
            "partition {i}: local CSR column out of range"
        );
        anyhow::ensure!(
            features.len() == nodes.len() * GROOT_FEATURE_DIM,
            "partition {i}: feature buffer is {} floats for {} nodes",
            features.len(),
            nodes.len()
        );
        core_total += num_core;
        let csr = Csr { row_ptr, col_idx };
        let digest = PlannedPartition::compute_digest(num_core, &nodes, &csr, &features);
        if let Some(stored) = stored_digest {
            anyhow::ensure!(
                stored == digest,
                "partition {i}: stored digest {stored:016x} does not match \
                 recomputed content digest {digest:016x}"
            );
        }
        parts.push(PlannedPartition { part_id, nodes, num_core, csr, features, digest });
    }
    anyhow::ensure!(r.at == payload.len(), "plan store: trailing bytes after last partition");
    anyhow::ensure!(
        core_total == num_nodes,
        "plan store: core cover {core_total} != {num_nodes} nodes"
    );
    // Balance from the decoded core sizes (max over ideal n/k), matching
    // Partitioning::balance on the assignment this plan tiles.
    let max_core = parts.iter().map(|p| p.num_core).max().unwrap_or(0) as f64;
    let ideal = num_nodes as f64 / parts.len().max(1) as f64;
    stats.balance = if ideal == 0.0 { 1.0 } else { max_core / ideal };
    stats.content_digest =
        super::pipeline::combine_part_digests(parts.iter().map(|p| p.digest));
    Ok(PartitionPlan { fingerprint: stored_fp, options, num_nodes, parts, stats })
}

/// Decode + validate a prediction record (`PRED_MAGIC` layout: header
/// as for plans, payload = digest | model_tag | count | class bytes).
fn decode_predictions(bytes: &[u8], digest: u64, model_tag: u64) -> Result<Vec<u8>> {
    anyhow::ensure!(bytes.len() >= HEADER_LEN, "prediction store: short header");
    anyhow::ensure!(bytes[..4] == PRED_MAGIC, "prediction store: bad magic");
    let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
    anyhow::ensure!(
        version == PRED_VERSION,
        "prediction store: version {version} (want {PRED_VERSION})"
    );
    let want_sum = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let payload_len = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    let payload = &bytes[HEADER_LEN..];
    anyhow::ensure!(
        payload.len() as u64 == payload_len,
        "prediction store: payload length mismatch ({} on disk, header says {payload_len})",
        payload.len()
    );
    anyhow::ensure!(checksum(payload) == want_sum, "prediction store: checksum mismatch");

    let mut r = Rd { buf: payload, at: 0 };
    let stored_digest = r.u64("digest")?;
    let stored_tag = r.u64("model_tag")?;
    anyhow::ensure!(
        stored_digest == digest && stored_tag == model_tag,
        "prediction store: stored key (digest {stored_digest:016x}, tag {stored_tag:016x}) \
         does not match requested (digest {digest:016x}, tag {model_tag:016x})"
    );
    let n = r.count(1, "core predictions")?;
    let core = r.take(n, "core predictions")?.to_vec();
    anyhow::ensure!(r.at == payload.len(), "prediction store: trailing bytes");
    anyhow::ensure!(
        core.iter().all(|&c| (c as usize) < crate::labels::NUM_CLASSES),
        "prediction store: class byte out of range"
    );
    Ok(core)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PreparedGraph;
    use crate::features::EdaGraph;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("groot-planstore-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn small_plan() -> PartitionPlan {
        let eg = EdaGraph::from_aig(&crate::aig::mult::csa_multiplier(4));
        let p = PreparedGraph::new(&eg);
        p.plan(&PlanOptions { partitions: 3, seed: 7, ..PlanOptions::default() })
    }

    fn assert_plans_equal(a: &PartitionPlan, b: &PartitionPlan) {
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(a.options, b.options);
        assert_eq!(a.num_nodes, b.num_nodes);
        assert_eq!(a.parts.len(), b.parts.len());
        for (pa, pb) in a.parts.iter().zip(&b.parts) {
            assert_eq!(pa.part_id, pb.part_id);
            assert_eq!(pa.num_core, pb.num_core);
            assert_eq!(pa.nodes, pb.nodes);
            assert_eq!(pa.csr, pb.csr);
            assert_eq!(pa.features, pb.features);
            assert_eq!(pa.digest, pb.digest);
        }
        assert_eq!(a.stats.content_digest, b.stats.content_digest);
    }

    /// The v1 on-disk layout (no per-partition digest), for the
    /// backward-compatible-read test.
    fn encode_plan_v1(plan: &PartitionPlan) -> Vec<u8> {
        let mut p = Vec::new();
        put_u64(&mut p, plan.fingerprint);
        put_u64(&mut p, plan.num_nodes as u64);
        put_u64(&mut p, plan.options.partitions as u64);
        p.push(plan.options.regrow as u8);
        put_u64(&mut p, plan.options.seed);
        put_u64(&mut p, plan.options.hd_threshold as u64);
        put_u64(&mut p, plan.stats.partition_time.as_nanos() as u64);
        put_u64(&mut p, plan.stats.regrowth_time.as_nanos() as u64);
        put_u64(&mut p, plan.stats.gather_time.as_nanos() as u64);
        put_u64(&mut p, plan.stats.regrowth.total_core_nodes as u64);
        put_u64(&mut p, plan.stats.regrowth.total_boundary_nodes as u64);
        put_u64(&mut p, plan.stats.regrowth.total_internal_edges as u64);
        put_u64(&mut p, plan.stats.regrowth.total_crossing_edges as u64);
        put_u64(&mut p, plan.stats.regrowth.max_partition_nodes as u64);
        put_u64(&mut p, plan.stats.hd_rows as u64);
        put_u64(&mut p, plan.stats.ld_rows as u64);
        put_u64(&mut p, plan.parts.len() as u64);
        for part in &plan.parts {
            put_u64(&mut p, part.part_id as u64);
            put_u64(&mut p, part.num_core as u64);
            put_u32_slice(&mut p, &part.nodes);
            put_u64(&mut p, part.csr.row_ptr.len() as u64);
            for &r in &part.csr.row_ptr {
                put_u64(&mut p, r as u64);
            }
            put_u32_slice(&mut p, &part.csr.col_idx);
            put_u64(&mut p, part.features.len() as u64);
            for &f in &part.features {
                p.extend_from_slice(&f.to_bits().to_le_bytes());
            }
        }
        let mut out = Vec::with_capacity(HEADER_LEN + p.len());
        out.extend_from_slice(&STORE_MAGIC);
        out.extend_from_slice(&1u16.to_le_bytes());
        out.extend_from_slice(&[0u8; 2]);
        put_u64(&mut out, checksum(&p));
        put_u64(&mut out, p.len() as u64);
        out.extend_from_slice(&p);
        out
    }

    #[test]
    fn save_load_roundtrip_is_lossless() {
        let dir = temp_dir("roundtrip");
        let store = PlanStore::open(&dir).unwrap();
        let plan = small_plan();
        store.save(&plan).unwrap();
        let loaded = store
            .load(plan.fingerprint, &plan.options)
            .expect("saved plan must load");
        assert_plans_equal(&plan, &loaded);
        assert_eq!((store.writes(), store.loads(), store.quarantined()), (1, 1, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_and_mismatched_keys_miss() {
        let dir = temp_dir("misses");
        let store = PlanStore::open(&dir).unwrap();
        let plan = small_plan();
        assert!(store.load(plan.fingerprint, &plan.options).is_none());
        store.save(&plan).unwrap();
        // other options: different file, clean miss
        let other = PlanOptions { partitions: 5, ..plan.options.clone() };
        assert!(store.load(plan.fingerprint, &other).is_none());
        // other fingerprint: different file, clean miss
        assert!(store.load(plan.fingerprint ^ 1, &plan.options).is_none());
        assert_eq!(store.quarantined(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_truncated_and_mismatched_files_quarantine() {
        let dir = temp_dir("quarantine");
        let store = PlanStore::open(&dir).unwrap();
        let plan = small_plan();
        let path = store.path_for(plan.fingerprint, &plan.options);

        // bit flip in the payload body → checksum rejects
        store.save(&plan).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(store.load(plan.fingerprint, &plan.options).is_none());
        assert!(!path.exists(), "corrupt file must be renamed aside");
        assert_eq!(store.quarantined(), 1);

        // truncation → length/checksum rejects
        store.save(&plan).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 9]).unwrap();
        assert!(store.load(plan.fingerprint, &plan.options).is_none());
        assert_eq!(store.quarantined(), 2);

        // version mismatch → rejected before any parsing
        store.save(&plan).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4] = 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(store.load(plan.fingerprint, &plan.options).is_none());
        assert_eq!(store.quarantined(), 3);

        // a file stored under the WRONG key (copied/renamed) is caught by
        // the payload key check even though name + checksum pass
        store.save(&plan).unwrap();
        let other = PlanOptions { seed: 99, ..plan.options.clone() };
        std::fs::copy(&path, store.path_for(plan.fingerprint, &other)).unwrap();
        assert!(store.load(plan.fingerprint, &other).is_none());
        assert_eq!(store.quarantined(), 4);

        // after all that, a fresh save works and loads
        store.save(&plan).unwrap();
        let loaded = store.load(plan.fingerprint, &plan.options).unwrap();
        assert_plans_equal(&plan, &loaded);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn v1_files_load_with_recomputed_digests() {
        let dir = temp_dir("v1-compat");
        let store = PlanStore::open(&dir).unwrap();
        let plan = small_plan();
        // a pre-digest store entry, exactly as a v1 process wrote it
        let v1_path = store.path_for_version(plan.fingerprint, &plan.options, 1);
        std::fs::write(&v1_path, encode_plan_v1(&plan)).unwrap();
        let loaded = store
            .load(plan.fingerprint, &plan.options)
            .expect("v1 file must remain readable");
        assert_plans_equal(&plan, &loaded);
        assert_eq!(store.quarantined(), 0);
        // write-back (as the cache tier does) persists v2; both versions
        // now resolve, preferring v2
        store.save(&loaded).unwrap();
        assert!(store.path_for(plan.fingerprint, &plan.options).exists());
        let again = store.load(plan.fingerprint, &plan.options).unwrap();
        assert_plans_equal(&plan, &again);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn digest_mismatch_quarantines_even_with_valid_checksum() {
        let dir = temp_dir("digest-check");
        let store = PlanStore::open(&dir).unwrap();
        let plan = small_plan();
        store.save(&plan).unwrap();
        let path = store.path_for(plan.fingerprint, &plan.options);
        let mut bytes = std::fs::read(&path).unwrap();
        // Tamper the LAST feature f32 (the final 4 payload bytes) and
        // re-stamp a VALID checksum — only the stored-digest re-check
        // can catch this class of rewrite.
        let n = bytes.len();
        bytes[n - 1] ^= 0x3F;
        let sum = checksum(&bytes[HEADER_LEN..]);
        bytes[8..16].copy_from_slice(&sum.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(store.load(plan.fingerprint, &plan.options).is_none());
        assert_eq!(store.quarantined(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prediction_records_roundtrip_and_validate() {
        let dir = temp_dir("pred");
        let store = PlanStore::open(&dir).unwrap();
        let core = vec![0u8, 3, 1, 4, 1];
        assert!(store.load_predictions(0xD1, 0x7A6).is_none());
        store.save_predictions(0xD1, 0x7A6, &core).unwrap();
        assert_eq!(store.load_predictions(0xD1, 0x7A6).unwrap(), core);
        assert_eq!((store.pred_writes(), store.pred_loads()), (1, 1));
        // a different model tag is a different record — clean miss
        assert!(store.load_predictions(0xD1, 0x7A7).is_none());
        // corruption quarantines
        let path = store.pred_path_for(0xD1, 0x7A6);
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        assert!(store.load_predictions(0xD1, 0x7A6).is_none());
        assert!(!path.exists(), "corrupt prediction record must be renamed aside");
        assert_eq!(store.quarantined(), 1);
        // out-of-range class bytes are rejected even with a valid checksum
        let bad = vec![crate::labels::NUM_CLASSES as u8];
        store.save_predictions(0xD2, 0x7A6, &bad).unwrap();
        assert!(store.load_predictions(0xD2, 0x7A6).is_none());
        assert_eq!(store.quarantined(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sharded_cache_falls_back_to_store_and_writes_back() {
        use crate::coordinator::ShardedPlanCache;
        let dir = temp_dir("cache-tier");
        let eg = EdaGraph::from_aig(&crate::aig::mult::csa_multiplier(4));
        let opts = PlanOptions { partitions: 3, ..PlanOptions::default() };

        // first process: build + write-back
        let built = {
            let cache =
                ShardedPlanCache::with_store(2, 8, PlanStore::open(&dir).unwrap());
            let p = PreparedGraph::new(&eg);
            let (plan, hit) = cache.get_or_build(&p, &opts);
            assert!(!hit);
            assert_eq!(cache.store().unwrap().writes(), 1);
            assert_eq!(cache.disk_hits(), 0);
            (*plan).clone()
        };

        // "restarted" process: cold memory, warm disk → reported as hit
        // (The zero-partitioner-invocation contract is pinned by the
        // serialized integration tests in rust/tests/net_serving.rs —
        // the global counter is racy under this binary's parallel tests.)
        let cache = ShardedPlanCache::with_store(2, 8, PlanStore::open(&dir).unwrap());
        let p = PreparedGraph::new(&eg);
        let (plan, hit) = cache.get_or_build(&p, &opts);
        assert!(hit, "disk tier must report a cache hit");
        assert_eq!(cache.disk_hits(), 1);
        assert_plans_equal(&built, &plan);
        // and the NEXT lookup is a pure memory hit
        let (_, hit) = cache.get_or_build(&p, &opts);
        assert!(hit);
        assert_eq!(cache.disk_hits(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! `groot` — CLI for the GROOT verification framework.
//!
//! Subcommands:
//!   gen-dataset   build EDA-graph datasets (training export for python)
//!   classify      run the partition→regrow→GNN pipeline, report accuracy
//!   verify        full verification (classification + algebraic check)
//!   harness       regenerate a paper table/figure (fig6a, tab2, ...)
//!   metrics       dump the metrics registry (local, or a daemon's)
//!   info          dataset statistics (nodes, edges, degree profile)

use anyhow::{bail, Context, Result};
use groot::backend::InferenceBackend;
use groot::coordinator::{Backend, Session, SessionConfig};
use groot::datasets::{self, DatasetKind};
use groot::util::cli::Args;
use std::path::PathBuf;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut args = Args::parse(&[
        "no-regrow",
        "help-args",
        "gamora-features",
        "quick",
        "train",
        "serve",
        "assert-improves",
        "stream",
        "prefetch",
        "oracle",
        "kernels",
        "plan",
        "expect-cache-hit",
        "expect-cache-miss",
        "delta",
        "expect-clean",
        "json",
    ]);
    // Tracing: `GROOT_TRACE=out.json` or `--trace out.json` turns the
    // span tracer on for the whole command; the buffer is drained to a
    // Chrome trace file (Perfetto-loadable) after the command finishes.
    groot::obs::trace::init_from_env();
    let trace_out = args.get("trace");
    if trace_out.is_some() {
        groot::obs::trace::enable();
    }
    let cmd = args.positional.first().cloned().unwrap_or_else(|| "help".into());
    let result = match cmd.as_str() {
        "gen-dataset" => gen_dataset(&mut args),
        "classify" => classify(&mut args),
        "verify" => verify(&mut args),
        "train" => train_cmd(&mut args),
        "harness" => harness(&mut args),
        "serve" => serve_cmd(&mut args),
        "client" => client_cmd(&mut args),
        "metrics" => metrics_cmd(&mut args),
        "info" => info(&mut args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' (try: groot help)"),
    };
    // Flush traces even when the command failed — a trace of the failing
    // run is exactly what the flag was for.
    if let Some(path) = trace_out {
        match groot::obs::trace::write_chrome_trace(std::path::Path::new(&path)) {
            Ok(n) => eprintln!("trace: wrote {n} span events -> {path}"),
            Err(e) => eprintln!("trace: failed to write {path}: {e}"),
        }
    } else {
        match groot::obs::trace::flush_env_trace() {
            Ok(0) => {}
            Ok(n) => eprintln!(
                "trace: wrote {n} span events -> {}",
                std::env::var("GROOT_TRACE").unwrap_or_default()
            ),
            Err(e) => eprintln!("trace: failed to write GROOT_TRACE file: {e}"),
        }
    }
    result
}

/// `groot metrics` — dump every registered metric family: the local
/// process registry, or a running daemon's via the REQ_METRICS frame
/// when `--connect` is given. `--json` switches the exposition format.
fn metrics_cmd(args: &mut Args) -> Result<()> {
    use groot::obs::MetricsFormat;
    let format = if args.flag("json") { MetricsFormat::Json } else { MetricsFormat::Prometheus };
    let text = match args.get("connect") {
        Some(addr) => {
            let mut client = groot::net::GrootClient::connect_str(&addr)?;
            client.metrics(format)?
        }
        None => groot::obs::registry().render(format),
    };
    print!("{text}");
    Ok(())
}

const HELP: &str = "\
groot — GNN-based verification of large designs (GROOT, ICCAD'25)

USAGE:
  groot gen-dataset --out DIR [--specs csa8,csa16,fpga64,...]
  groot classify --dataset csa --bits 16 [--partitions 8] [--no-regrow]
                 [--backend native|xla] [--artifacts DIR] [--weights FILE]
                 [--threads N (per-backend budget: partition lanes × SpMM)]
                 [--batch N (disjoint graph copies)]
                 [--precision f32|int8 (native backend; int8 = per-channel
                  symmetric weight quantization, f32 activations)]
                 [--hd-threshold N (HD/LD degree cutoff for the GROOT SpMM
                  engine; default 512 or GROOT_HD_THRESHOLD)]
                 [--stream [--window 4] [--chunk 8192] [--prefetch]]
  groot verify   --dataset csa --bits 16 [same options as classify]
                 [--oracle (ground-truth labels feed the algebraic stage)]

  --stream ingests the circuit through a chunked GraphSource into the
  compact columnar store and executes partitions through the backend one
  bounded window at a time: peak execution memory ~ largest window, not
  the whole graph. Predictions are byte-identical to the eager path.
  --prefetch overlaps the next window's gather with the current window's
  inference (2 live windows: faster, but double the windowed memory).
  groot train    --dataset csa --bits 8 [--val-bits 16,32] [--epochs 200]
                 [--lr 0.01] [--hidden 64,64] [--partitions 4] [--seed 0]
                 [--threads N (SpMM engine lanes; matmuls follow GROOT_THREADS)]
                 [--out FILE] [--checkpoint-every 25] [--eval-every 10]
                 [--resume CKPT] [--assert-improves]
  groot harness  fig1a|fig6a|fig6b|fig6c|fig6d|fig7|fig8|fig9|fig10|tab2|bench|memory|profile
                 |incremental (edit-size sweep: delta vs cold classify
                  latency for edit sizes 1..64; asserts byte-identity and
                  writes BENCH_incremental.json)
                 [--weights FILE] [--quick] [--train (bench)] [--out FILE (bench|memory)]
                 [--serve (bench: concurrency sweep — in-flight clients ×
                  worker counts at a fixed total thread budget; --workers N
                  pins the sweep to 1 and N; writes BENCH_serve.json with
                  throughput + p50/p95)]
                 [--kernels (bench: SpMM/GEMM kernel microbench — per-engine
                  SIMD-vs-scalar speedup, int8-vs-f32 forward, fused batched
                  GEMM; writes BENCH_kernels.json;
                  --assert-simd-speedup X fails below X× when SIMD is active)]
                 [--plan (bench: cold plan-build thread sweep {1,2,4,8} +
                  plan-store warm load, with the in-process byte-identity
                  check; writes BENCH_plan.json; --assert-plan-speedup X
                  fails below X× at 4 threads, skipped under 4 cores)]
                 (profile: run the classify pipeline and report HD/LD
                  kernel time/rows/nnz deltas from the metrics registry)
  groot serve    --listen ADDR (host:port or unix:/path.sock)
                 [--workers N] [--threads N] [--weights FILE]
                 [--plan-dir DIR (persistent plan + prediction stores:
                  plans AND per-partition predictions survive restarts —
                  a restarted daemon answers repeat designs without
                  re-partitioning, and stitches unchanged partitions
                  without re-inference; prediction records are tagged
                  with the weight-bundle hash, so retrained weights
                  never stitch stale records)]
                 [--plan-cache N (in-memory entries)] [--queue N]
                 [--max-frame-mb N (reject larger request frames)]
  groot client   classify|verify|stats|fuzz --connect ADDR
                 [--dataset csa --bits 16 | --aag FILE]
                 [--partitions N] [--seed S] [--no-regrow]
                 [--pred-out FILE (raw predicted-class bytes)]
                 [--expect-cache-hit | --expect-cache-miss (assert the
                  server's plan_cache_hit flag — CI warm-start checks)]
                 [--delta (classify: incremental round trip — classify
                  the base through the daemon, then send a synthetic
                  edit list keyed by the base fingerprint; the daemon
                  re-infers only the dirtied partitions.
                  --edit-nodes N (default 1) polarity flips,
                  --edit-seed S (default 7) edit-site selection,
                  --expect-clean fails unless some partition was
                  stitched from cache — CI incremental checks)]
                 [--json (stats: machine-readable output)]
  groot metrics  [--connect ADDR] [--json]
                 dump every registered metric family: Prometheus text
                 exposition by default, --json for the JSON form; with
                 --connect, scrape a running daemon over REQ_METRICS
  groot info     --dataset csa --bits 16

Observability: every command accepts --trace FILE (or GROOT_TRACE=FILE)
to record pipeline/kernel/request spans and write a Chrome trace-event
JSON on exit — load it at https://ui.perfetto.dev or chrome://tracing.
Tracing never changes results: predictions are byte-identical on or off.
GROOT_LOG=error|warn|info|debug gates diagnostics on stderr (default
warn); GROOT_SLOW_REQUEST_MS sets the daemon's slow-request warn
threshold (default 1000).

Serving: worker count lives in SessionConfig.workers (the `--workers`
option feeds it; consumed by `groot serve`, `harness bench --serve`, the
serve example, and library `Server::spawn` users — plain classify/verify
runs ignore it). Each worker owns a backend, all share one plan cache.
Keep workers × --threads ≤ cores — the runtime splits, never multiplies.
`groot serve` drains on SIGTERM: the listener closes first, in-flight
and queued requests are answered, then workers join.

The paper's flow end-to-end from nothing but the circuit generators:
  groot train --dataset csa --bits 8 --seed 1        # writes artifacts/ckpt_csa8.bin
  groot harness fig6a --weights artifacts/ckpt_csa8.bin
";

fn parse_dataset(args: &mut Args) -> Result<(DatasetKind, usize)> {
    let kind = DatasetKind::parse(&args.get_or("dataset", "csa"))?;
    let bits = args.parse_or("bits", 8usize)?;
    Ok((kind, bits))
}

fn gen_dataset(args: &mut Args) -> Result<()> {
    let out = PathBuf::from(args.get_or("out", "artifacts/datasets"));
    let specs = args.get_or(
        "specs",
        "csa8,csa16,csa32,booth8,booth16,7nm8,7nm16,fpga8,fpga16,fpga64",
    );
    for spec in specs.split(',') {
        let spec = spec.trim();
        // split after the LAST non-digit so "7nm8" parses as ("7nm", 8)
        let split = spec
            .rfind(|c: char| !c.is_ascii_digit())
            .map(|i| i + 1)
            .with_context(|| format!("bad spec '{spec}' (want e.g. csa8)"))?;
        let kind = DatasetKind::parse(&spec[..split])?;
        let bits: usize = spec[split..].parse()?;
        let g = datasets::generate(kind, bits, &out)?;
        println!(
            "wrote {spec}: {} nodes, {} edges -> {}",
            g.num_nodes,
            g.num_edges(),
            out.display()
        );
    }
    Ok(())
}

fn build_backend(args: &mut Args, threads: usize) -> Result<Backend> {
    let backend = args.get_or("backend", "native");
    let weights_path = PathBuf::from(args.get_or("weights", "artifacts/weights_csa8.bin"));
    let bundle = groot::util::tensor::read_bundle(&weights_path)
        .with_context(|| format!("load weights {}", weights_path.display()))?;
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let max_bucket = args.parse_or("max-bucket", usize::MAX)?;
    let precision: groot::gnn::Precision = args.parse_or("precision", Default::default())?;
    groot::backend::backend_by_name_precise(
        &backend,
        &bundle,
        &artifacts,
        max_bucket,
        threads,
        precision,
    )
}

fn session_config(args: &mut Args) -> Result<SessionConfig> {
    Ok(SessionConfig {
        num_partitions: args.parse_or("partitions", 1usize)?,
        regrow: !args.flag("no-regrow"),
        seed: args.parse_or("seed", 0u64)?,
        threads: args.parse_or("threads", groot::util::pool::default_threads())?,
        workers: args.parse_or("workers", 1usize)?,
        precision: args.parse_or("precision", Default::default())?,
        hd_threshold: args.parse_or("hd-threshold", groot::spmm::default_hd_threshold())?,
    })
}

/// The classify/verify ingestion knobs shared by both subcommands.
struct IngestOptions {
    stream: bool,
    batch: usize,
    window: usize,
    chunk: usize,
    /// Gather window W+1 on a second thread while W infers: better wall
    /// time, ~2× the windowed working set (so NOT the default under
    /// memory caps).
    prefetch: bool,
}

fn ingest_options(args: &mut Args) -> Result<IngestOptions> {
    Ok(IngestOptions {
        stream: args.flag("stream"),
        batch: args.parse_or("batch", 1usize)?,
        window: args.parse_or("window", 4usize)?,
        chunk: args.parse_or("chunk", groot::graph::DEFAULT_CHUNK_NODES)?,
        prefetch: args.flag("prefetch"),
    })
}

/// Run classification through either ingestion path; returns the result
/// plus the graph-shape facts verification needs. Ground-truth labels
/// are materialized only when asked for (`verify --oracle`) — `classify`
/// must not copy a whole-graph column just to drop it.
fn run_classify(
    session: &Session,
    kind: DatasetKind,
    bits: usize,
    ing: &IngestOptions,
    want_labels: bool,
) -> Result<(groot::coordinator::ClassifyResult, usize, usize, Option<Vec<u8>>)> {
    if ing.stream {
        let prepared = groot::coordinator::PreparedGraph::from_source(
            datasets::replicated_source(kind, bits, ing.batch, ing.chunk)?,
        )?;
        println!(
            "dataset {}{} (batch {}): {} nodes, {} edges; compact store {:.1} B/node, \
             streaming window {}{}",
            kind.name(),
            bits,
            ing.batch,
            prepared.num_nodes(),
            prepared.num_edges(),
            prepared.resident_bytes() as f64 / prepared.num_nodes().max(1) as f64,
            ing.window,
            if ing.prefetch { " (prefetch overlap)" } else { "" }
        );
        let res = if ing.prefetch {
            session.classify_streaming_overlapped(&prepared, ing.window)?
        } else {
            session.classify_streaming(&prepared, ing.window)?
        };
        let labels = want_labels.then(|| prepared.labels_u8().into_owned());
        Ok((res, prepared.num_nodes(), prepared.num_aig_nodes(), labels))
    } else {
        let mut graph = datasets::build(kind, bits)?;
        if ing.batch > 1 {
            graph = graph.replicate(ing.batch);
        }
        println!(
            "dataset {}{} (batch {}): {} nodes, {} edges; eager pipeline",
            kind.name(),
            bits,
            ing.batch,
            graph.num_nodes,
            graph.num_edges()
        );
        let res = session.classify(&graph)?;
        let labels = want_labels.then(|| graph.labels_u8());
        Ok((res, graph.num_nodes, graph.num_aig_nodes, labels))
    }
}

fn print_run_stats(res: &groot::coordinator::ClassifyResult) {
    println!(
        "accuracy {:.4}  (partition {:?}, regrowth {:?}, gather {:?}, infer {:?}; \
         batch of {} partitions)",
        res.accuracy,
        res.stats.partition_time,
        res.stats.regrowth_time,
        res.stats.pack_time,
        res.stats.infer_time,
        res.stats.batch_size
    );
    println!(
        "boundary nodes {}, crossing edges {}, max partition {} nodes, peak bucket {}, \
         exec working set {:.2} MB",
        res.stats.total_boundary_nodes,
        res.stats.total_crossing_edges,
        res.stats.max_partition_nodes,
        res.stats.peak_bucket_n,
        res.stats.peak_resident_bytes as f64 / 1e6
    );
}

fn classify(args: &mut Args) -> Result<()> {
    let (kind, bits) = parse_dataset(args)?;
    let cfg = session_config(args)?;
    let ing = ingest_options(args)?;
    let backend = build_backend(args, cfg.threads)?;
    println!(
        "backend={}, partitions={}, regrow={}",
        backend.name(),
        cfg.num_partitions,
        cfg.regrow
    );
    let session = Session::new(backend, cfg);
    let (res, _, _, _) = run_classify(&session, kind, bits, &ing, false)?;
    print_run_stats(&res);
    if let Some(path) = args.get("pred-out") {
        std::fs::write(&path, &res.pred)
            .with_context(|| format!("write predictions to {path}"))?;
        println!("wrote {} prediction bytes -> {path}", res.pred.len());
    }
    Ok(())
}

fn verify(args: &mut Args) -> Result<()> {
    let (kind, bits) = parse_dataset(args)?;
    let cfg = session_config(args)?;
    let ing = ingest_options(args)?;
    let oracle = args.flag("oracle");
    let backend = build_backend(args, cfg.threads)?;
    let session = Session::new(backend, cfg);
    let aig = match kind {
        DatasetKind::Csa => groot::aig::mult::csa_multiplier(bits),
        DatasetKind::Booth => groot::aig::booth::booth_multiplier(bits),
        DatasetKind::Wallace => groot::aig::wallace::wallace_multiplier(bits),
        _ => bail!("algebraic verification targets AIG datasets (csa|booth|wallace)"),
    };
    let t0 = std::time::Instant::now();
    let (res, num_nodes, num_aig_nodes, labels) =
        run_classify(&session, kind, bits, &ing, oracle)?;
    print_run_stats(&res);
    // --oracle: the classification stage still ran above (the memory
    // path CI caps), but the algebraic stage consumes ground-truth
    // labels — removes model-quality variance from memory-cap jobs.
    let pred = match &labels {
        Some(l) => l,
        None => &res.pred,
    };
    let outcome =
        groot::verify::verify_multiplier_pred(&aig, num_nodes, num_aig_nodes, pred)?;
    println!(
        "classification accuracy {:.4}{}; algebraic check: {} ({} adders used; {:?} total)",
        res.accuracy,
        if oracle { " [oracle predictions for rewriting]" } else { "" },
        if outcome.equivalent { "EQUIVALENT ✓" } else { "NOT PROVEN ✗" },
        outcome.adders_used,
        t0.elapsed()
    );
    if !outcome.equivalent {
        std::process::exit(2);
    }
    Ok(())
}

/// `groot train` — train GraphSAGE on an 8-bit design, validate on the
/// family's held-out larger designs, and write a GRTW checkpoint that
/// loads straight back into `Session`/`NativeBackend` (verified here by
/// re-classifying through the served path before returning).
fn train_cmd(args: &mut Args) -> Result<()> {
    use groot::train::{self, checkpoint, TrainConfig};

    let (kind, bits) = parse_dataset(args)?;
    let val_bits: Vec<usize> = args.parse_list("val-bits", &[bits * 2])?;
    let out = PathBuf::from(
        args.get_or("out", &format!("artifacts/ckpt_{}.bin", kind.stem(bits))),
    );
    let (resume, epoch_offset) = match args.get("resume") {
        Some(p) => {
            let (model, epoch) = checkpoint::load(std::path::Path::new(&p))?;
            println!("resuming from {p} (epochs already trained: {})", epoch.unwrap_or(0));
            if args.options.contains_key("hidden") {
                println!(
                    "note: --hidden is ignored with --resume \
                     (architecture comes from the checkpoint)"
                );
            }
            // carry the checkpoint's progress forward so meta.epoch stays
            // cumulative across resumed runs
            (Some(model), epoch.unwrap_or(0))
        }
        None => (None, 0),
    };
    let cfg = TrainConfig {
        hidden: args.parse_list("hidden", &[64usize, 64])?,
        epochs: args.parse_or("epochs", 200usize)?,
        lr: args.parse_or("lr", 0.01f32)?,
        partitions: args.parse_or("partitions", 4usize)?,
        seed: args.parse_or("seed", 0u64)?,
        threads: args.parse_or("threads", groot::util::pool::default_threads())?,
        eval_every: args.parse_or("eval-every", 10usize)?,
        checkpoint_every: args.parse_or("checkpoint-every", 25usize)?,
        out: Some(out.clone()),
        resume,
        epoch_offset,
    };

    let train_graph = datasets::build(kind, bits)?;
    let mut val_graphs = Vec::new();
    for &vb in &val_bits {
        val_graphs.push((kind.stem(vb), datasets::build(kind, vb)?));
    }
    // Report the architecture actually trained: on --resume it comes from
    // the checkpoint, not from --hidden.
    let arch: Vec<usize> = match &cfg.resume {
        Some(m) => m.layers[..m.layers.len() - 1].iter().map(|l| l.dout).collect(),
        None => cfg.hidden.clone(),
    };
    println!(
        "training on {}: {} nodes, {} partitions/epoch; validating on {:?}; \
         model 4→{:?}→5, lr {}, seed {}",
        kind.stem(bits),
        train_graph.num_nodes,
        cfg.partitions,
        val_graphs.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>(),
        arch,
        cfg.lr,
        cfg.seed
    );

    let report = train::train(
        std::slice::from_ref(&train_graph),
        &val_graphs,
        &cfg,
        |e| {
            let val = match e.val_acc {
                Some(a) => format!("  val acc {a:.4}"),
                None => String::new(),
            };
            println!(
                "epoch {:>4}  loss {:.5}  train acc {:.4}{}  ({:.1} knodes/s)",
                e.epoch,
                e.loss,
                e.train_acc,
                val,
                e.core_nodes as f64 / e.secs.max(1e-9) / 1e3
            );
        },
    )?;

    println!("\nwrote checkpoint {}", out.display());
    for (name, acc) in &report.val_results {
        println!("held-out {name}: accuracy {acc:.4}");
    }

    if args.flag("assert-improves") {
        anyhow::ensure!(
            report.final_loss() < report.first_loss(),
            "training loss did not decrease: {} -> {}",
            report.first_loss(),
            report.final_loss()
        );
        println!(
            "loss improved {:.5} -> {:.5} ✓",
            report.first_loss(),
            report.final_loss()
        );
    }

    // Close the loop: the checkpoint must load through the exact serving
    // path (weight bundle → NativeBackend → partitioned Session) and
    // reproduce the trained model's accuracy on the training design.
    let bundle = groot::util::tensor::read_bundle(&out)?;
    let backend = groot::backend::backend_by_name(
        "native",
        &bundle,
        std::path::Path::new("artifacts"),
        usize::MAX,
        cfg.threads,
    )?;
    let session = Session::new(
        backend,
        SessionConfig { num_partitions: cfg.partitions, ..Default::default() },
    );
    let res = session.classify(&train_graph)?;
    println!(
        "checkpoint reloaded through Session::classify: accuracy {:.4} on {}",
        res.accuracy,
        kind.stem(bits)
    );
    Ok(())
}

/// `groot serve` — the socket daemon: multi-worker serving runtime
/// behind the wire protocol, with an optional persistent plan store.
fn serve_cmd(args: &mut Args) -> Result<()> {
    use groot::coordinator::{PlanStore, ShardedPlanCache};
    use groot::coordinator::server::Server;
    use groot::net::{BindAddr, NetConfig, NetDaemon};

    let listen = args
        .get("listen")
        .context("serve needs --listen host:port or --listen unix:/path.sock")?;
    let addr = BindAddr::parse(&listen)?;
    let cfg = session_config(args)?;
    let plan_cache = args.parse_or(
        "plan-cache",
        groot::coordinator::DEFAULT_PLAN_CACHE_CAPACITY,
    )?;
    let queue = args.parse_or("queue", (cfg.workers.max(1) * 8).max(32))?;
    let max_frame_mb: u32 = args.parse_or("max-frame-mb", 64u32)?;

    // The backend factory runs once per worker, ON that worker's thread.
    // Weights are read (and tagged) up front: the model tag pins
    // persisted prediction records to this exact weight bundle.
    let backend_name = args.get_or("backend", "native");
    let weights_path = PathBuf::from(args.get_or("weights", "artifacts/weights_csa8.bin"));
    let raw_weights = std::fs::read(&weights_path)
        .with_context(|| format!("load weights {}", weights_path.display()))?;
    let model_tag = groot::incremental::model_tag_for_bytes(&raw_weights);
    let bundle = groot::util::tensor::parse_bundle(&raw_weights)
        .with_context(|| format!("parse weights {}", weights_path.display()))?;
    drop(raw_weights);

    // With a plan directory, BOTH persistent tiers come up: the plan
    // store (GPLN) and the prediction store (GPPR, model-tagged) — a
    // restarted daemon answers repeat designs without re-partitioning
    // AND stitches unchanged partitions without re-inference.
    let (cache, incremental) = match args.get("plan-dir") {
        Some(dir) => {
            let store = PlanStore::open(&dir)?;
            println!("plan store: {} (plans persist across restarts)", store.dir().display());
            let pred_store = PlanStore::open(&dir)?;
            let incremental = groot::incremental::IncrementalState::with_predictions(
                groot::incremental::PredictionCache::with_store(
                    groot::incremental::DEFAULT_PREDICTION_CACHE_CAPACITY,
                    pred_store,
                    model_tag,
                ),
            );
            let cache = std::sync::Arc::new(ShardedPlanCache::with_store(
                groot::coordinator::DEFAULT_PLAN_CACHE_SHARDS,
                plan_cache,
                store,
            ));
            (cache, incremental)
        }
        None => (
            std::sync::Arc::new(ShardedPlanCache::new(plan_cache)),
            groot::incremental::IncrementalState::new(),
        ),
    };
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let max_bucket = args.parse_or("max-bucket", usize::MAX)?;
    let threads = cfg.threads;
    let precision = cfg.precision;
    let factory = move || {
        groot::backend::backend_by_name_precise(
            &backend_name,
            &bundle,
            &artifacts,
            max_bucket,
            threads,
            precision,
        )
    };

    let workers = cfg.workers.max(1);
    let server = Server::spawn_with_incremental(cfg, cache, queue, incremental, factory);
    groot::net::install_sigterm_handler();
    let net_cfg = NetConfig {
        max_frame: max_frame_mb.saturating_mul(1024 * 1024),
        watch_sigterm: true,
        ..Default::default()
    };
    let daemon = NetDaemon::bind(&addr, server, net_cfg)?;
    println!(
        "groot serve: listening on {} ({} workers × {} threads, queue {}, plan cache {})",
        daemon.bound(),
        workers,
        threads,
        queue,
        plan_cache
    );
    println!("SIGTERM drains: listener closes, in-flight requests answered, workers join");
    daemon.join();
    println!("groot serve: drained and stopped");
    Ok(())
}

/// The client side of a classify request: resolve the circuit payload
/// (generated dataset → compact circuit bytes, or an `.aag` file sent as
/// text) and per-request options from the CLI.
fn client_request(
    args: &mut Args,
) -> Result<(groot::net::wire::GraphPayload, groot::coordinator::server::VerifyOptions)> {
    use groot::net::wire::GraphPayload;
    let payload = match args.get("aag") {
        Some(path) => GraphPayload::AagText(
            std::fs::read_to_string(&path).with_context(|| format!("read {path}"))?,
        ),
        None => {
            let (kind, bits) = parse_dataset(args)?;
            let graph = datasets::build(kind, bits)?;
            GraphPayload::CircuitBytes(graph.to_circuit()?.to_bytes())
        }
    };
    let options = groot::coordinator::server::VerifyOptions {
        partitions: args.parse_or("partitions", 0usize).map(|p| (p > 0).then_some(p))?,
        regrow: args.flag("no-regrow").then_some(false),
        seed: args.get("seed").map(|s| s.parse::<u64>()).transpose()?,
    };
    Ok((payload, options))
}

/// `groot client` — classify/verify/stats/fuzz against a running daemon.
fn client_cmd(args: &mut Args) -> Result<()> {
    use groot::net::wire;
    use groot::net::{GrootClient, Reply};

    let sub = args
        .positional
        .get(1)
        .cloned()
        .context("client needs a subcommand: classify | verify | stats | fuzz")?;
    let connect = args.get("connect").context("client needs --connect ADDR")?;

    match sub.as_str() {
        "stats" => {
            let mut client = GrootClient::connect_str(&connect)?;
            let s = client.stats()?;
            if args.flag("json") {
                let per_worker = s
                    .per_worker_requests
                    .iter()
                    .map(|n| n.to_string())
                    .collect::<Vec<_>>()
                    .join(", ");
                println!(
                    "{{\"queue_depth\": {}, \"workers\": {}, \
                     \"per_worker_requests\": [{per_worker}], \
                     \"plan_cache_hits\": {}, \"plan_cache_misses\": {}, \
                     \"plan_disk_hits\": {}, \"plan_store_writes\": {}, \
                     \"plan_store_quarantined\": {}, \"requests_served\": {}, \
                     \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \"p99_ms\": {:.3}}}",
                    s.queue_depth,
                    s.workers,
                    s.plan_cache_hits,
                    s.plan_cache_misses,
                    s.plan_disk_hits,
                    s.plan_store_writes,
                    s.plan_store_quarantined,
                    s.requests_served,
                    s.p50_ms,
                    s.p95_ms,
                    s.p99_ms
                );
                return Ok(());
            }
            println!("queue depth      {}", s.queue_depth);
            println!("workers          {} (requests: {:?})", s.workers, s.per_worker_requests);
            println!(
                "plan cache       {} hits / {} misses ({} answered from disk)",
                s.plan_cache_hits, s.plan_cache_misses, s.plan_disk_hits
            );
            println!(
                "plan store       {} writes, {} quarantined",
                s.plan_store_writes, s.plan_store_quarantined
            );
            println!("requests served  {}", s.requests_served);
            println!(
                "latency ms       p50 {:.3}  p95 {:.3}  p99 {:.3}",
                s.p50_ms, s.p95_ms, s.p99_ms
            );
            Ok(())
        }
        "classify" | "verify" => {
            if args.flag("delta") {
                if sub == "verify" {
                    bail!("--delta is a classify flow (use: groot client classify --delta)");
                }
                return client_delta(args, &connect);
            }
            let (payload, options) = client_request(args)?;
            let mut client = GrootClient::connect_str(&connect)?;
            let res = match client.classify_payload(&payload, &options)? {
                Reply::Result(r) => r,
                Reply::Busy => bail!("server is busy (bounded queue full) — retry later"),
            };
            println!(
                "accuracy {:.4}  partitions {}  plan_cache_hit {}  infer {:?}",
                res.accuracy,
                res.stats.num_partitions,
                res.stats.plan_cache_hit,
                res.stats.infer_time
            );
            if args.flag("expect-cache-hit") && !res.stats.plan_cache_hit {
                bail!("--expect-cache-hit: server re-planned (plan_cache_hit=false)");
            }
            if args.flag("expect-cache-miss") && res.stats.plan_cache_hit {
                bail!("--expect-cache-miss: server reused a plan (plan_cache_hit=true)");
            }
            if let Some(path) = args.get("pred-out") {
                std::fs::write(&path, &res.pred)
                    .with_context(|| format!("write predictions to {path}"))?;
                println!("wrote {} prediction bytes -> {path}", res.pred.len());
            }
            if sub == "verify" {
                let (kind, bits) = parse_dataset(args)?;
                let aig = match kind {
                    DatasetKind::Csa => groot::aig::mult::csa_multiplier(bits),
                    DatasetKind::Booth => groot::aig::booth::booth_multiplier(bits),
                    DatasetKind::Wallace => groot::aig::wallace::wallace_multiplier(bits),
                    _ => bail!("client verify targets AIG datasets (csa|booth|wallace)"),
                };
                let graph = datasets::build(kind, bits)?;
                let outcome = groot::verify::verify_multiplier_pred(
                    &aig,
                    graph.num_nodes,
                    graph.num_aig_nodes,
                    &res.pred,
                )?;
                println!(
                    "algebraic check: {} ({} adders used)",
                    if outcome.equivalent { "EQUIVALENT ✓" } else { "NOT PROVEN ✗" },
                    outcome.adders_used
                );
                if !outcome.equivalent {
                    std::process::exit(2);
                }
            }
            Ok(())
        }
        "fuzz" => {
            // Protocol-abuse sweep: each case must get a structured
            // ERROR (or a clean close) and the daemon must still answer
            // a well-formed STATS request afterwards.
            // (name, bytes, expect_reply): a truncated frame gets no
            // reply — the daemon is still waiting for the missing bytes,
            // so the client just hangs up (EOF mid-frame on the server).
            let cases: Vec<(&str, Vec<u8>, bool)> = vec![
                ("bad magic", b"XXXX\x01\x00\x00\x00\x00".to_vec(), true),
                ("oversize length", {
                    let mut f = b"GRT1\x01".to_vec();
                    f.extend_from_slice(&u32::MAX.to_le_bytes());
                    f
                }, true),
                ("truncated frame", {
                    let mut f = Vec::new();
                    wire::write_frame(&mut f, wire::REQ_CLASSIFY, &[1, 2, 3, 4, 5]).unwrap();
                    f.truncate(f.len() - 3);
                    f
                }, false),
                ("unknown kind", {
                    let mut f = Vec::new();
                    wire::write_frame(&mut f, 0x7F, &[]).unwrap();
                    f
                }, true),
                ("garbage classify payload", {
                    let mut f = Vec::new();
                    wire::write_frame(&mut f, wire::REQ_CLASSIFY, &[0xFF; 32]).unwrap();
                    f
                }, true),
            ];
            for (name, bytes, expect_reply) in &cases {
                let mut client = GrootClient::connect_str(&connect)?;
                client.send_raw(bytes)?;
                if !expect_reply {
                    drop(client);
                    println!("{name}: sent, connection dropped");
                    continue;
                }
                match client.recv_frame() {
                    Ok((kind, payload)) if kind == wire::RESP_ERROR => {
                        let (code, msg) = wire::decode_error(&payload)?;
                        println!("{name}: ERROR {code} ({msg})");
                    }
                    Ok((kind, _)) => bail!("{name}: unexpected reply kind {kind:#04x}"),
                    Err(e) => bail!("{name}: no structured error reply: {e:#}"),
                }
            }
            let mut client = GrootClient::connect_str(&connect)?;
            let s = client.stats()?;
            println!(
                "daemon survived {} malformed cases (served {} requests so far)",
                cases.len(),
                s.requests_served
            );
            Ok(())
        }
        other => bail!("unknown client subcommand '{other}' (classify|verify|stats|fuzz)"),
    }
}

/// `groot client classify --delta` — the incremental round trip:
/// classify the base design through the daemon (which registers it
/// under its content fingerprint), build a synthetic edit list locally,
/// and send ONLY the edits keyed by that fingerprint. The daemon
/// re-infers just the partitions the edits dirtied and stitches the
/// rest from its prediction cache.
fn client_delta(args: &mut Args, connect: &str) -> Result<()> {
    use groot::net::{DeltaReply, GrootClient, Reply};

    if args.get("aag").is_some() {
        bail!("--delta builds its base from --dataset/--bits (.aag bases are not supported)");
    }
    let (kind, bits) = parse_dataset(args)?;
    let edit_nodes = args.parse_or("edit-nodes", 1usize)?;
    let edit_seed = args.parse_or("edit-seed", 7u64)?;
    let options = groot::coordinator::server::VerifyOptions {
        partitions: args.parse_or("partitions", 0usize).map(|p| (p > 0).then_some(p))?,
        regrow: args.flag("no-regrow").then_some(false),
        seed: args.get("seed").map(|s| s.parse::<u64>()).transpose()?,
    };

    let graph = datasets::build(kind, bits)?;
    let circuit = graph.to_circuit()?;
    let base_fp = groot::coordinator::PreparedGraph::from_circuit_ref(&circuit).fingerprint();

    let mut client = GrootClient::connect_str(connect)?;
    let base = match client.classify_circuit(&circuit, &options)? {
        Reply::Result(r) => r,
        Reply::Busy => bail!("server is busy (bounded queue full) — retry later"),
    };
    println!(
        "base {}{}: fingerprint {:016x}  accuracy {:.4}  {} partitions",
        kind.name(),
        bits,
        base_fp,
        base.accuracy,
        base.stats.num_partitions
    );

    let edits = groot::incremental::synthetic_polarity_edits(&circuit, edit_nodes, edit_seed);
    if edits.is_empty() {
        bail!("dataset has no editable AND nodes for a synthetic edit list");
    }
    let res = match client.classify_delta(base_fp, &edits, &options)? {
        DeltaReply::Result(r) => r,
        DeltaReply::Busy => bail!("server is busy (bounded queue full) — retry later"),
    };
    println!(
        "delta ({} edits): accuracy {:.4}  dirty {} / clean {} partitions{}  infer {:?}  \
         edited fingerprint {:016x}",
        edits.len(),
        res.result.accuracy,
        res.dirty,
        res.clean,
        if res.repartitioned { " (repartitioned)" } else { "" },
        res.result.stats.infer_time,
        res.edited_fingerprint
    );
    if args.flag("expect-clean") && res.clean == 0 {
        bail!("--expect-clean: the daemon re-inferred every partition (clean=0)");
    }
    if let Some(path) = args.get("pred-out") {
        std::fs::write(&path, &res.result.pred)
            .with_context(|| format!("write predictions to {path}"))?;
        println!("wrote {} prediction bytes -> {path}", res.result.pred.len());
    }
    Ok(())
}

fn harness(args: &mut Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .cloned()
        .context("harness needs a target, e.g. `groot harness fig6a`")?;
    groot::harness::run(&which, args)
}

fn info(args: &mut Args) -> Result<()> {
    let (kind, bits) = parse_dataset(args)?;
    let graph = datasets::build(kind, bits)?;
    let csr = groot::graph::Csr::symmetric_from_edges(graph.num_nodes, &graph.edges);
    let profile = groot::graph::DegreeProfile::with_paper_thresholds(&csr);
    let hist = groot::labels::class_histogram(&graph.labels);
    println!("dataset {}{}", kind.name(), bits);
    println!("  nodes {}  edges {}", graph.num_nodes, graph.num_edges());
    println!(
        "  classes: PO {}  MAJ {}  XOR {}  AND {}  PI {}",
        hist[0], hist[1], hist[2], hist[3], hist[4]
    );
    println!(
        "  degree: max {}  hd rows(≥{}) {}  ld rows {}  hd-nnz share {:.2}%",
        profile.max_degree,
        profile.hd_threshold,
        profile.hd_rows.len(),
        profile.ld_rows.len(),
        100.0 * profile.hd_nnz_fraction(&csr)
    );
    Ok(())
}

//! Algebraic verification (§III-D) — big integers, multilinear
//! polynomials, backward rewriting, and the structural (non-GNN)
//! baseline.
//!
//! Entry point: [`verify_multiplier`] — takes the circuit, its EDA graph,
//! and per-node class *predictions* (from the GNN pipeline) and proves or
//! refutes equivalence against the multiplier spec polynomial.

pub mod abc_like;
pub mod bigint;
pub mod poly;
pub mod rewrite;

pub use rewrite::Outcome;

use crate::aig::Aig;
use crate::features::EdaGraph;
use anyhow::Result;

/// Default transient-term cap: generous headroom over the spec size n².
pub fn default_max_terms(aig: &Aig) -> usize {
    let n = aig.num_pis() / 2;
    (64 * n * n).max(200_000)
}

/// Verify `aig` (an n×n multiplier candidate) against the spec
/// (Σ2ⁱaᵢ)(Σ2ʲbⱼ) using GNN node-class predictions to guide rewriting.
///
/// `pred` is indexed by EDA-graph node id; only the AIG-node prefix
/// (ids < graph.num_aig_nodes) is consulted — PO graph nodes have no
/// substitution role.
pub fn verify_multiplier(aig: &Aig, graph: &EdaGraph, pred: &[u8]) -> Result<Outcome> {
    verify_multiplier_pred(aig, graph.num_nodes, graph.num_aig_nodes, pred)
}

/// Representation-independent form of [`verify_multiplier`]: takes the
/// graph-shape facts (total node count, AIG-node prefix) instead of a
/// legacy `EdaGraph`, so the streaming pipeline can verify straight from
/// a compact `CircuitGraph` / `PreparedGraph` without ever materializing
/// the dense representation.
pub fn verify_multiplier_pred(
    aig: &Aig,
    num_graph_nodes: usize,
    num_aig_nodes: usize,
    pred: &[u8],
) -> Result<Outcome> {
    anyhow::ensure!(
        pred.len() == num_graph_nodes,
        "prediction length {} != graph nodes {}",
        pred.len(),
        num_graph_nodes
    );
    anyhow::ensure!(
        num_aig_nodes == aig.num_nodes() || num_aig_nodes % aig.num_nodes() == 0,
        "graph does not correspond to this AIG"
    );
    anyhow::ensure!(
        pred.len() >= aig.num_nodes(),
        "{} predictions cannot cover the {}-node AIG",
        pred.len(),
        aig.num_nodes()
    );
    let aig_pred = &pred[..aig.num_nodes()];
    let plan = rewrite::plan_from_predictions(aig, aig_pred);
    let sig = rewrite::output_signature(aig);
    let spec = rewrite::multiplier_spec(aig);
    Ok(rewrite::backward_rewrite(
        aig,
        &plan,
        sig,
        &spec,
        default_max_terms(aig),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aig::mult::csa_multiplier;
    use crate::features::EdaGraph;

    #[test]
    fn end_to_end_with_ground_truth_predictions() {
        let g = csa_multiplier(6);
        let eg = EdaGraph::from_aig(&g);
        let pred = eg.labels_u8();
        let out = verify_multiplier(&g, &eg, &pred).unwrap();
        assert!(out.equivalent, "{:?}", out.reason);
    }

    #[test]
    fn rejects_mismatched_prediction_length() {
        let g = csa_multiplier(3);
        let eg = EdaGraph::from_aig(&g);
        assert!(verify_multiplier(&g, &eg, &[0u8; 3]).is_err());
    }
}

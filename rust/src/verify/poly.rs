//! Sparse multilinear polynomials over AIG node variables with BigInt
//! coefficients — the algebra of Table I.
//!
//! A monomial is a sorted set of variables (multilinear: x² = x, so sets
//! suffice). [`Poly`] keeps monomials bucketed by their **largest**
//! variable: backward rewriting substitutes variables in strictly
//! decreasing order, so a monomial is touched exactly once — when its max
//! variable is eliminated. This bucket discipline is what makes function
//! extraction (Ciesielski et al.) run in time proportional to the number
//! of monomials ever created.

use super::bigint::BigInt;
use std::collections::HashMap;

/// Sorted variable set.
pub type Mono = Box<[u32]>;

/// Multilinear merge of two sorted var sets.
pub fn mono_union(a: &[u32], b: &[u32]) -> Mono {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() || j < b.len() {
        let v = if i < a.len() && (j >= b.len() || a[i] <= b[j]) {
            let v = a[i];
            if j < b.len() && b[j] == v {
                j += 1;
            }
            i += 1;
            v
        } else {
            let v = b[j];
            j += 1;
            v
        };
        out.push(v);
    }
    out.into_boxed_slice()
}

/// A sparse multilinear polynomial bucketed by max variable. Bucket
/// `None` holds the constant term; bucket `Some(v)` holds monomials whose
/// largest variable is v.
#[derive(Clone, Debug, Default)]
pub struct Poly {
    buckets: HashMap<u32, HashMap<Mono, BigInt>>,
    constant: BigInt,
    num_terms: usize,
    /// Coefficients live in Z/2^k when set — the carry-truncation trick:
    /// outputs and spec are < 2^(2n), so equality mod 2^(2n) is equality,
    /// and truncated ripple carries (weight 2^(2n)) vanish instead of
    /// dragging exponential telescoping terms through the rewrite.
    mod_pow2: Option<usize>,
}

impl Poly {
    pub fn zero() -> Poly {
        Poly::default()
    }

    /// Polynomial with coefficients in Z/2^k.
    pub fn zero_mod(k: usize) -> Poly {
        Poly { mod_pow2: Some(k), ..Poly::default() }
    }

    fn reduce(&self, x: BigInt) -> BigInt {
        match self.mod_pow2 {
            Some(k) => x.mod_pow2(k),
            None => x,
        }
    }

    pub fn num_terms(&self) -> usize {
        self.num_terms + !self.constant.is_zero() as usize
    }

    pub fn constant(&self) -> &BigInt {
        &self.constant
    }

    /// Add `coeff · mono` (mono must be sorted; empty = constant).
    pub fn add_term(&mut self, mono: &[u32], coeff: BigInt) {
        let coeff = self.reduce(coeff);
        if coeff.is_zero() {
            return;
        }
        if mono.is_empty() {
            self.constant = self.reduce(self.constant.add(&coeff));
            return;
        }
        let max = *mono.last().unwrap();
        let modp = self.mod_pow2;
        let bucket = self.buckets.entry(max).or_default();
        match bucket.entry(mono.to_vec().into_boxed_slice()) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                let mut sum = e.get().add(&coeff);
                if let Some(k) = modp {
                    sum = sum.mod_pow2(k);
                }
                if sum.is_zero() {
                    e.remove();
                    self.num_terms -= 1;
                } else {
                    *e.get_mut() = sum;
                }
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(coeff);
                self.num_terms += 1;
            }
        }
        if self
            .buckets
            .get(&max)
            .map(|b| b.is_empty())
            .unwrap_or(false)
        {
            self.buckets.remove(&max);
        }
    }

    /// Largest variable with live monomials.
    pub fn max_var(&self) -> Option<u32> {
        self.buckets.keys().max().copied()
    }

    /// Remove and return the whole bucket of monomials whose max var is v.
    pub fn take_bucket(&mut self, v: u32) -> Vec<(Mono, BigInt)> {
        match self.buckets.remove(&v) {
            None => Vec::new(),
            Some(b) => {
                self.num_terms -= b.len();
                b.into_iter().collect()
            }
        }
    }

    /// All live (mono, coeff) pairs, constant included as empty mono.
    pub fn terms(&self) -> Vec<(Mono, BigInt)> {
        let mut out: Vec<(Mono, BigInt)> = self
            .buckets
            .values()
            .flat_map(|b| b.iter().map(|(m, c)| (m.clone(), c.clone())))
            .collect();
        if !self.constant.is_zero() {
            out.push((Vec::new().into_boxed_slice(), self.constant.clone()));
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    pub fn is_zero(&self) -> bool {
        self.num_terms == 0 && self.constant.is_zero()
    }

    /// self -= other (used for the final spec comparison).
    pub fn sub_assign(&mut self, other: &Poly) {
        for (m, c) in other.terms() {
            self.add_term(&m, c.neg());
        }
    }

    /// Evaluate over a boolean assignment (tests only).
    pub fn eval_bool(&self, assign: &dyn Fn(u32) -> bool) -> BigInt {
        let mut acc = self.constant.clone();
        for bucket in self.buckets.values() {
            for (m, c) in bucket {
                if m.iter().all(|&v| assign(v)) {
                    acc = acc.add(c);
                }
            }
        }
        acc
    }
}

/// The unique multilinear polynomial of a boolean function given as a
/// truth table over `leaves` (LSB-first rows, leaf 0 cycles fastest),
/// via the Möbius transform: c_S = Σ_{T ⊆ S} (-1)^{|S|-|T|} f(T).
///
/// Returns (subset-mask, coefficient) pairs with nonzero coefficients;
/// masks index into `leaves`.
pub fn multilinear_of_tt(tt: u16, k: usize) -> Vec<(u8, i64)> {
    assert!(k <= 4);
    let rows = 1usize << k;
    let mut out = Vec::new();
    for s in 0..rows {
        let mut c: i64 = 0;
        // iterate subsets t of s
        let mut t = s;
        loop {
            let f = ((tt >> t) & 1) as i64;
            let parity = ((s ^ t).count_ones() & 1) as i64;
            c += if parity == 1 { -f } else { f };
            if t == 0 {
                break;
            }
            t = (t - 1) & s;
        }
        if c != 0 {
            out.push((s as u8, c));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn add_and_cancel() {
        let mut p = Poly::zero();
        p.add_term(&[1, 3], BigInt::from_i64(2));
        p.add_term(&[1, 3], BigInt::from_i64(-2));
        assert!(p.is_zero());
        p.add_term(&[], BigInt::from_i64(5));
        p.add_term(&[2], BigInt::from_i64(1));
        assert_eq!(p.num_terms(), 2);
        assert_eq!(p.max_var(), Some(2));
    }

    #[test]
    fn bucket_discipline() {
        let mut p = Poly::zero();
        p.add_term(&[1, 7], BigInt::one());
        p.add_term(&[7], BigInt::one());
        p.add_term(&[2, 3], BigInt::one());
        let b7 = p.take_bucket(7);
        assert_eq!(b7.len(), 2);
        assert_eq!(p.max_var(), Some(3));
    }

    #[test]
    fn mono_union_dedups() {
        assert_eq!(&*mono_union(&[1, 3], &[2, 3]), &[1, 2, 3]);
        assert_eq!(&*mono_union(&[], &[5]), &[5]);
    }

    #[test]
    fn table1_algebraic_models() {
        // Table I of the paper via the Möbius transform.
        // NOT: 1 - a
        assert_eq!(multilinear_of_tt(0b01, 1), vec![(0, 1), (1, -1)]);
        // AND: ab
        assert_eq!(multilinear_of_tt(0b1000, 2), vec![(3, 1)]);
        // XOR: a + b - 2ab
        assert_eq!(
            multilinear_of_tt(0b0110, 2),
            vec![(1, 1), (2, 1), (3, -2)]
        );
        // XOR3: a+b+c -2ab -2ac -2bc +4abc
        assert_eq!(
            multilinear_of_tt(0x96, 3),
            vec![(1, 1), (2, 1), (3, -2), (4, 1), (5, -2), (6, -2), (7, 4)]
        );
        // MAJ: ab + ac + bc - 2abc
        assert_eq!(
            multilinear_of_tt(0xE8, 3),
            vec![(3, 1), (5, 1), (6, 1), (7, -2)]
        );
    }

    #[test]
    fn xor3_plus_2maj_is_linear() {
        // The paper's §III-D identity: XOR3 + 2·MAJ = a + b + c.
        let mut p = Poly::zero();
        let leaves = [1u32, 2, 3];
        for (mask, c) in multilinear_of_tt(0x96, 3) {
            p.add_term(&mask_to_mono(mask, &leaves), BigInt::from_i64(c));
        }
        for (mask, c) in multilinear_of_tt(0xE8, 3) {
            p.add_term(&mask_to_mono(mask, &leaves), BigInt::from_i64(2 * c));
        }
        let terms = p.terms();
        assert_eq!(terms.len(), 3, "{terms:?}");
        for (m, c) in terms {
            assert_eq!(m.len(), 1);
            assert_eq!(c.to_i128(), Some(1));
        }
    }

    fn mask_to_mono(mask: u8, leaves: &[u32]) -> Mono {
        let mut m: Vec<u32> = leaves
            .iter()
            .enumerate()
            .filter(|(i, _)| mask & (1 << i) != 0)
            .map(|(_, &l)| l)
            .collect();
        m.sort_unstable();
        m.into_boxed_slice()
    }

    #[test]
    fn multilinear_matches_tt_property() {
        check("mobius poly == tt", 100, |g| {
            let k = g.usize(1..4);
            let tt = (g.u64() & ((1u64 << (1 << k)) - 1)) as u16;
            let coeffs = multilinear_of_tt(tt, k);
            for row in 0..(1usize << k) {
                let mut val: i64 = 0;
                for &(mask, c) in &coeffs {
                    if mask as usize & row == mask as usize {
                        val += c;
                    }
                }
                assert_eq!(val, ((tt >> row) & 1) as i64, "tt={tt:#x} row={row}");
            }
        });
    }
}
